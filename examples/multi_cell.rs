//! Multi-cell ICC topology demo: four gNBs sharing one compute tier.
//!
//! The paper's ICC framework places compute *inside* RAN nodes, so the
//! interesting system-level question is how placement behaves once
//! several cells contend for the tier. This example runs the same
//! 4-cell radio workload under two placements:
//!
//! * `cell_affinity` — the ICC shape: each prompt is served at its
//!   originating gNB's node, spilling to neighbors only when the home
//!   queue backs up;
//! * `least_loaded` — a pooled MEC-style tier that ignores origin.
//!
//! Cells are stepped on all cores (`threads(0)`); the thread count
//! never changes the numbers, only the wall clock.
//!
//! Run: `cargo run --release --example multi_cell`

use icc6g::config::SchemeConfig;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{CellSpec, RoutingPolicy, ScenarioBuilder, WorkloadClass};

const N_CELLS: usize = 4;
const UES_PER_CELL: u32 = 15;

fn run(label: &str, routing: RoutingPolicy) {
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(8.0)
        .warmup(1.0)
        .seed(1)
        .threads(0)
        .routing(routing)
        .workload(WorkloadClass::translation());
    for _ in 0..N_CELLS {
        b = b.cell(CellSpec::new(UES_PER_CELL)).node(GpuSpec::gh200_nvl2(), 1);
    }
    let scenario = b.build();
    let res = scenario.run();
    println!(
        "\n{label}: {} cells x {} UEs, {:.0} jobs/s offered, satisfaction {:.4}",
        N_CELLS,
        UES_PER_CELL,
        scenario.offered_rate(),
        res.report.satisfaction_rate()
    );
    for c in &res.report.per_cell {
        println!(
            "  {:>6}: {:>4} jobs  sat {:.4}  comm {:>6.2} ms  e2e {:>6.2} ms",
            c.name,
            c.n_jobs,
            c.satisfaction_rate(),
            c.comm.mean() * 1e3,
            c.e2e.mean() * 1e3,
        );
    }
}

fn main() {
    println!("=== Multi-cell placement: ICC cell affinity vs pooled tier ===");
    run(
        "cell_affinity (serve at the originating gNB, spill at queue > 8)",
        RoutingPolicy::CellAffinity { spill_queue: 8 },
    );
    run("least_loaded (pooled MEC-style tier)", RoutingPolicy::LeastLoaded);
}
