//! Quickstart: the public API in ~60 lines.
//!
//! 1. Closed-form theory (Fig 4): service capacity of ICC vs 5G MEC.
//! 2. One system-level simulation run of each scheme.
//! 3. (If `make artifacts` has run) a real LLM generation over PJRT.
//!
//! Run: `cargo run --release --example quickstart`

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::{service_capacity, Scheme};
use icc6g::runtime::{tokenizer, Engine};
use icc6g::sim::run_scheme;

fn main() -> anyhow::Result<()> {
    // --- 1. Theory: tandem M/M/1 with joint vs disjoint budgets -----
    let params = SystemParams::paper(); // μ1=900, μ2=100, b=80 ms
    println!("== Theory (Fig 4) ==");
    for scheme in Scheme::fig4_schemes() {
        let cap = service_capacity(
            |l| scheme_satisfaction(&params, &scheme, l),
            0.95,
            params.stability_limit() - 1e-6,
            1e-6,
        );
        println!("  {:<24} λ* = {:>6.2} jobs/s", scheme.name, cap.lambda_star);
    }

    // --- 2. System-level simulation (Fig 6 point at 60 prompts/s) ---
    println!("\n== SLS (one Fig 6 point, 60 UEs × 1 prompt/s) ==");
    let mut cfg = SimConfig::table1();
    cfg.horizon = 10.0;
    for scheme in SchemeConfig::fig6_schemes() {
        let r = run_scheme(&cfg, scheme.clone(), 1);
        println!(
            "  {:<32} satisfaction {:.3}  (comm {:.1} ms, comp {:.1} ms)",
            scheme.name,
            r.satisfaction_rate(),
            r.comm.mean() * 1e3,
            r.comp.mean() * 1e3,
        );
    }

    // --- 3. Real serving path (needs `make artifacts`) --------------
    let dir = Engine::default_artifacts_dir();
    if dir.join("prefill.hlo.txt").exists() {
        println!("\n== Real LLM over PJRT ==");
        let engine = Engine::load(&dir)?;
        let prompt = tokenizer::encode("Integrated communication and computing");
        let (out, stats) = engine.generate(&prompt, 12)?;
        println!(
            "  generated {} tokens in {:.1} ms ({:.0} tok/s)",
            out.len(),
            (stats.prefill_s + stats.decode_s) * 1e3,
            stats.tokens_per_sec()
        );
    } else {
        println!("\n(skipping real-model demo: run `make artifacts` first)");
    }
    Ok(())
}
