//! Coupled-radio cells: dynamic inter-cell interference, UE mobility
//! and A3 handover.
//!
//! The legacy multi-cell engine keeps cells radio-independent — a
//! fixed 2 dB margin stands in for every neighbor. This example places
//! the same 7-cell workload on a hexagonal site grid and couples the
//! radios: each cell's noise floor carries a dynamic
//! interference-over-thermal term computed from its neighbors'
//! previous-slot granted-PRB activity, UEs drive through the
//! deployment at vehicular speed, and A3 handover (RSRP hysteresis +
//! time-to-trigger) migrates them between gNBs with their buffers and
//! HARQ state carried over.
//!
//! Three configurations of the identical traffic:
//!
//! * legacy    — radio-independent cells (fixed margin, static UEs);
//! * coupled   — geometry-driven interference, static UEs;
//! * mobile    — interference + 30 m/s UEs + A3 handover.
//!
//! Run: `cargo run --release --example interference_handover`

use icc6g::config::SchemeConfig;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{
    CellSpec, HandoverSpec, MobilitySpec, RoutingPolicy, ScenarioBuilder, ScenarioResult,
    TopologySpec, WorkloadClass,
};

const N_CELLS: usize = 7; // one hex ring
const UES_PER_CELL: u32 = 10;
const ISD_M: f64 = 400.0;

fn base() -> ScenarioBuilder {
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(8.0)
        .warmup(1.0)
        .seed(1)
        .threads(0)
        .routing(RoutingPolicy::CellAffinity { spill_queue: 8 })
        .workload(WorkloadClass::translation());
    for _ in 0..N_CELLS {
        b = b.cell(CellSpec::new(UES_PER_CELL)).node(GpuSpec::gh200_nvl2(), 1);
    }
    b
}

fn report(label: &str, res: &ScenarioResult) {
    println!(
        "\n{label}: {} jobs, satisfaction {:.4}, avg comm {:.2} ms",
        res.report.n_jobs,
        res.report.satisfaction_rate(),
        res.report.comm.mean() * 1e3,
    );
    if res.report.radio.is_empty() {
        println!("  (radio-independent cells: fixed 2 dB interference margin)");
        return;
    }
    for (k, r) in res.report.radio.iter().enumerate() {
        let slice = &res.report.per_cell[k];
        println!(
            "  cell{k}: {:>4} jobs  sat {:.4}  IoT avg {:>5.2} dB (max {:>5.2})  HO in/out {:>2}/{:>2}",
            slice.n_jobs,
            slice.satisfaction_rate(),
            r.iot_db.mean(),
            r.iot_db.max(),
            r.handovers_in,
            r.handovers_out,
        );
    }
    let ho: u64 = res.report.radio.iter().map(|r| r.handovers_out).sum();
    println!("  total handovers: {ho}");
}

fn main() {
    println!(
        "=== Coupled-radio cells: {N_CELLS} gNBs on a hex grid (ISD {ISD_M:.0} m) ==="
    );

    let legacy = base().build().run();
    report("legacy (radio-independent)", &legacy);

    let coupled = base().topology(TopologySpec::hex(ISD_M)).build().run();
    report("coupled (dynamic interference, static UEs)", &coupled);

    let mobile = base()
        .topology(TopologySpec::hex(ISD_M))
        .mobility(MobilitySpec::fixed(30.0))
        .handover(HandoverSpec::default())
        .build()
        .run();
    report("mobile (interference + 30 m/s UEs + A3 handover)", &mobile);

    println!(
        "\nGeometry-driven interference prices the uplink against real neighbor\n\
         activity instead of a fixed margin, and handover keeps moving UEs on\n\
         their best server — multi-cell capacity numbers stop being optimistic."
    );
}
