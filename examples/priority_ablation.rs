//! Ablation of the ICC priority scheme's two components (paper §IV-B):
//!
//! 1. **Job-aware packet prioritization** (MAC: job SDUs preempt
//!    background traffic), and
//! 2. **Priority-based job queueing + hopeless-drop** (compute node:
//!    EDF on `T_gen + b_total − T_comm`, drop jobs that cannot finish).
//!
//! We run the joint-RAN deployment with each combination toggled,
//! showing where the gains actually come from.
//!
//! Run: `cargo run --release --example priority_ablation`

use icc6g::config::{Deployment, Management, SchemeConfig, SimConfig};
use icc6g::sim::Sls;
use icc6g::util::bench::{cell, Table};

fn main() {
    let rates = [60u32, 75, 90];
    let mut t = Table::new(
        "ICC priority-scheme ablation (joint management, RAN 5ms)",
        &["prompts/s", "packet-prio", "job-queue", "satisfaction", "dropped", "avg_comm_ms", "avg_comp_ms"],
    );

    for &rate in &rates {
        for (pkt, queue) in [(false, false), (true, false), (false, true), (true, true)] {
            let mut cfg = SimConfig::table1();
            cfg.n_ues = rate;
            cfg.horizon = 12.0;
            cfg.seed = 17;
            // Custom scheme: joint management at the RAN with the two
            // priority components controlled independently. (We bypass
            // `with_scheme`, which would re-sync the MAC toggle.)
            cfg.scheme = SchemeConfig::builder()
                .name("custom")
                .deployment(Deployment::Ran)
                .management(Management::Joint)
                .priority(queue) // drives the compute-node queue
                .build();
            cfg.mac.job_priority = pkt; // the MAC half, decoupled
            let r = Sls::new(cfg).run().report;
            t.row(&[
                cell(rate as f64, 0),
                pkt.to_string(),
                queue.to_string(),
                cell(r.satisfaction_rate(), 4),
                r.n_dropped.to_string(),
                cell(r.comm.mean() * 1e3, 2),
                cell(r.comp.mean() * 1e3, 2),
            ]);
        }
    }
    t.print();
    let _ = t.write_csv("ablation_priority.csv");
    println!(
        "\nReading: packet-prio shaves the uplink tail; the deadline job\n\
         queue + drop rule is what preserves satisfaction past the knee\n\
         (it stops wasting GPU time on already-hopeless jobs)."
    );
}
