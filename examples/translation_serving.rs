//! **End-to-end serving driver** — the full-system validation run
//! recorded in EXPERIMENTS.md.
//!
//! Loads the real tiny-Llama artifacts, serves them over TCP through
//! the ICC coordinator (deadline-priority) and the 5G-baseline (FIFO),
//! drives both with the paper's workload shape (Poisson arrivals of
//! 15-token translation requests with an 80 ms-style budget scaled to
//! this CPU model), and reports latency percentiles, throughput and
//! deadline satisfaction per policy.
//!
//! Run: `make artifacts && cargo run --release --example translation_serving`

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use icc6g::rng::Rng;
use icc6g::runtime::Engine;
use icc6g::server::{inference_loop, spawn_accept_loop, Request, ServePolicy};
use icc6g::util::stats::percentile;

const N_REQUESTS: usize = 60;
const OUT_TOKENS: usize = 15; // Table I output prompt size
const PROMPTS: &[&str] = &[
    "Guten Morgen, wie komme ich zum Bahnhof?",
    "Please translate the meeting notes for tomorrow.",
    "El tren llega a las ocho y media.",
    "Where can I find a pharmacy nearby?",
    "今日の天気はどうですか。",
];

struct Outcome {
    e2e_ms: f64,
    dropped: bool,
}

/// Drive one policy: spin a full server (TCP accept + inference
/// thread), fire Poisson-paced requests from client threads, collect
/// outcomes.
fn drive(policy: ServePolicy, rate_per_s: f64, budget_ms: f64) -> anyhow::Result<Vec<Outcome>> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let port = listener.local_addr()?.port();
    let (tx, rx) = mpsc::channel::<Request>();
    spawn_accept_loop(listener, tx, 64);

    // Inference thread owns the engine.
    let inference = std::thread::spawn(move || {
        let engine = Engine::load(&Engine::default_artifacts_dir()).expect("artifacts");
        inference_loop(&engine, rx, policy)
    });
    // Wait for the engine to come up (compile takes ~1 s).
    std::thread::sleep(Duration::from_millis(50));

    // Client threads: each sends its requests Poisson-paced.
    let n_clients = 4usize;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let per_client = N_REQUESTS / n_clients;
        let rate = rate_per_s / n_clients as f64;
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<Outcome>> {
            let mut rng = Rng::substream(0xC11E27, c as u64);
            let stream = TcpStream::connect(("127.0.0.1", port))?;
            stream.set_nodelay(true)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut stream = stream;
            let mut out = Vec::new();
            for i in 0..per_client {
                std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
                let prompt = PROMPTS[(c + i) % PROMPTS.len()];
                let t0 = Instant::now();
                writeln!(stream, "GEN {OUT_TOKENS} {budget_ms} {prompt}")?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let e2e_ms = t0.elapsed().as_secs_f64() * 1e3;
                let dropped = line.starts_with("DROPPED");
                if !dropped && !line.starts_with("OK") {
                    anyhow::bail!("unexpected response: {line}");
                }
                out.push(Outcome { e2e_ms, dropped });
            }
            Ok(out)
        }));
    }
    let mut outcomes = Vec::new();
    for h in handles {
        outcomes.extend(h.join().expect("client thread panicked")?);
    }
    // Closing client sockets ends connection threads; dropping their
    // channel senders ends the inference loop.
    drop(inference); // detach: loop exits when all senders are gone
    Ok(outcomes)
}

fn report(name: &str, budget_ms: f64, outs: &[Outcome], wall_s: f64) {
    let served: Vec<f64> = outs.iter().filter(|o| !o.dropped).map(|o| o.e2e_ms).collect();
    let dropped = outs.len() - served.len();
    let within = served.iter().filter(|&&ms| ms <= budget_ms).count();
    let sat = within as f64 / outs.len() as f64;
    println!(
        "  {name:<22} served {:>3}/{:<3} dropped {dropped:<3} p50 {:>7.1} ms  p95 {:>7.1} ms  \
         satisfied {:>5.1}%  thpt {:>5.1} req/s",
        served.len(),
        outs.len(),
        percentile(&served, 50.0),
        percentile(&served, 95.0),
        sat * 100.0,
        outs.len() as f64 / wall_s,
    );
}

fn main() -> anyhow::Result<()> {
    let dir = Engine::default_artifacts_dir();
    if !dir.join("prefill.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    // Budget scaled to this CPU model: the tiny Llama decodes ~15
    // tokens in ~70–90 ms here, so a 250 ms budget plays the role the
    // paper's 80 ms plays for Llama-2-7B on GH200s.
    let budget_ms = 250.0;
    let rate = 8.0; // offered load (req/s) — near this CPU's capacity

    println!(
        "translation serving: {} requests, {OUT_TOKENS} output tokens, \
         {budget_ms} ms budget, {rate} req/s offered\n",
        N_REQUESTS
    );
    for (name, policy) in [
        ("5G-baseline (FIFO)", ServePolicy::Fifo),
        ("ICC (EDF + drop)", ServePolicy::DeadlinePriority),
    ] {
        let t0 = Instant::now();
        let outs = drive(policy, rate, budget_ms)?;
        report(name, budget_ms, &outs, t0.elapsed().as_secs_f64());
    }
    println!("\n(record of this run lives in EXPERIMENTS.md §End-to-end)");
    Ok(())
}
