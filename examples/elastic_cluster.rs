//! Elastic compute tier under a diurnal load cycle with node churn.
//!
//! The ICC tier is rented by the hour, so the system-level figure of
//! merit is not raw satisfaction but *capacity per dollar*: satisfied
//! prompts per unit of GPU rental spend. This example sweeps a
//! four-phase diurnal cycle (night / morning / peak / evening, modeled
//! as separate runs at different UE populations) over a 4-node tier
//! whose nodes fail and recover (MTBF 20 s, MTTR 2 s at this
//! compressed timescale), and compares two control planes:
//!
//! * `fixed` — all four nodes powered for the whole window, the
//!   static-provisioning baseline;
//! * `queue_depth` — the autoscaler powers nodes with the queue-depth
//!   hysteresis policy, draining idle capacity off-peak.
//!
//! Failed nodes evict their jobs back through routing (one retry, then
//! the work is lost), so the table also shows the churn bill:
//! failures, re-dispatches and lost jobs. Runs are deterministic per
//! seed and invariant to the thread count.
//!
//! Run: `cargo run --release --example elastic_cluster`

use icc6g::config::SchemeConfig;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{
    AutoscalerKind, CellSpec, ClusterSpec, NodeChurnSpec, ScenarioBuilder, WorkloadClass,
};

const N_NODES: usize = 4;
const HORIZON: f64 = 10.0;
const PHASES: [(&str, u32); 4] =
    [("night", 4), ("morning", 12), ("peak", 24), ("evening", 10)];

struct PhaseRow {
    satisfaction: f64,
    dollars: f64,
    cap_per_dollar: f64,
    failures: u64,
    redispatched: u64,
    lost: u64,
}

fn run(ues_per_cell: u32, policy: AutoscalerKind) -> PhaseRow {
    let churn = NodeChurnSpec { mtbf: 20.0, mttr: 2.0, spinup: 0.5 };
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(HORIZON)
        .warmup(0.0)
        .seed(7)
        .threads(0)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .cells(2, CellSpec::new(ues_per_cell));
    for _ in 0..N_NODES {
        b = b.node(GpuSpec::gh200_nvl2().scaled(2.0), 1).node_churn(churn);
    }
    let res = b
        .cluster(ClusterSpec { policy, min_nodes: 1, retry_budget: 1, ..Default::default() })
        .build()
        .run();
    let cl = &res.report.cluster;
    PhaseRow {
        satisfaction: res.report.satisfaction_rate(),
        dollars: cl.total_dollars(),
        cap_per_dollar: cl.capacity_per_dollar(res.report.n_satisfied),
        failures: cl.nodes.iter().map(|n| n.failures).sum(),
        redispatched: cl.nodes.iter().map(|n| n.redispatched).sum(),
        lost: cl.nodes.iter().map(|n| n.lost).sum(),
    }
}

fn main() {
    println!("=== Elastic ICC tier: diurnal load, node churn, capacity per dollar ===");
    println!(
        "{N_NODES} x {} nodes, {HORIZON} s per phase, MTBF 20 s / MTTR 2 s / spin-up 0.5 s\n",
        GpuSpec::gh200_nvl2().scaled(2.0).display_name()
    );
    println!(
        "{:<9} {:<12} {:>4} {:>7} {:>8} {:>9} {:>6} {:>7} {:>5}",
        "phase", "policy", "ues", "sat", "usd", "sat/usd", "fails", "redisp", "lost"
    );
    let mut totals = [(0.0f64, 0.0f64), (0.0f64, 0.0f64)]; // (satisfied-ish dollars, spend) per policy
    for (phase, ues_per_cell) in PHASES {
        for (pi, policy) in [
            AutoscalerKind::Fixed,
            AutoscalerKind::QueueDepth { high: 8, low: 1 },
        ]
        .into_iter()
        .enumerate()
        {
            let r = run(ues_per_cell, policy);
            println!(
                "{:<9} {:<12} {:>4} {:>7.4} {:>8.4} {:>9.1} {:>6} {:>7} {:>5}",
                phase,
                policy.name(),
                2 * ues_per_cell,
                r.satisfaction,
                r.dollars,
                r.cap_per_dollar,
                r.failures,
                r.redispatched,
                r.lost,
            );
            totals[pi].0 += r.cap_per_dollar * r.dollars; // satisfied jobs
            totals[pi].1 += r.dollars;
        }
    }
    println!();
    for (pi, name) in ["fixed", "queue_depth"].into_iter().enumerate() {
        println!(
            "{name:<12}: {:.0} satisfied jobs for ${:.4} over the cycle = {:.1} per dollar",
            totals[pi].0,
            totals[pi].1,
            totals[pi].0 / totals[pi].1,
        );
    }
    println!("\nThe autoscaler gives up a little peak satisfaction but buys it back");
    println!("several times over in off-peak rental spend; node churn costs both");
    println!("tiers the same re-dispatch work because eviction recovery rides the");
    println!("same routing path either way.");
}
