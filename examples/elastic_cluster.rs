//! Elastic compute tier under a diurnal load cycle with node churn.
//!
//! The ICC tier is rented by the hour, so the system-level figure of
//! merit is not raw satisfaction but *capacity per dollar*: satisfied
//! prompts per unit of GPU rental spend. This example drives a
//! four-phase diurnal cycle (night / morning / peak / evening) as a
//! piecewise-constant *rate schedule* on each workload class — one
//! continuous run per control plane, so the autoscaler actually rides
//! the load curve up and back down instead of being re-benchmarked on
//! four disconnected steady states. The 4-node tier churns underneath
//! it (MTBF 20 s, MTTR 2 s at this compressed timescale), and two
//! control planes are compared:
//!
//! * `fixed` — all four nodes powered for the whole window, the
//!   static-provisioning baseline;
//! * `queue_depth` — the autoscaler powers nodes with the queue-depth
//!   hysteresis policy, draining idle capacity off-peak.
//!
//! Failed nodes evict their jobs back through routing (one retry, then
//! the work is lost), so the table also shows the churn bill:
//! failures, re-dispatches and lost jobs. Runs are deterministic per
//! seed and invariant to the thread count.
//!
//! Run: `cargo run --release --example elastic_cluster`

use icc6g::config::SchemeConfig;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{
    AutoscalerKind, CellSpec, ClusterSpec, NodeChurnSpec, ScenarioBuilder, WorkloadClass,
};

const N_NODES: usize = 4;
const UES_PER_CELL: u32 = 24;
const PHASE_S: f64 = 10.0;
/// Diurnal load curve as a fraction of the peak per-UE rate. The
/// population stays fixed at the peak headcount; what varies is how
/// often each UE speaks, which is what a rate schedule expresses.
const PHASES: [(&str, f64); 4] = [
    ("night", 4.0 / 24.0),
    ("morning", 12.0 / 24.0),
    ("peak", 1.0),
    ("evening", 10.0 / 24.0),
];
const HORIZON: f64 = PHASE_S * PHASES.len() as f64;

/// Stretch a class's constant rate into the diurnal schedule: the base
/// rate becomes the night phase, and each later phase re-arms arrivals
/// at its own multiple of the class's peak rate.
fn diurnal(class: WorkloadClass) -> WorkloadClass {
    let peak = class.rate_per_ue;
    let mut class = class.with_rate(peak * PHASES[0].1);
    for (i, (_, load)) in PHASES.iter().enumerate().skip(1) {
        class = class.with_rate_phase(i as f64 * PHASE_S, peak * load);
    }
    class
}

struct PolicyRow {
    satisfaction: f64,
    satisfied: u64,
    dollars: f64,
    cap_per_dollar: f64,
    failures: u64,
    redispatched: u64,
    lost: u64,
}

fn run(policy: AutoscalerKind) -> PolicyRow {
    let churn = NodeChurnSpec { mtbf: 20.0, mttr: 2.0, spinup: 0.5 };
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(HORIZON)
        .warmup(0.0)
        .seed(7)
        .threads(0)
        .workload(diurnal(WorkloadClass::chat()))
        .workload(diurnal(WorkloadClass::translation()))
        .cells(2, CellSpec::new(UES_PER_CELL));
    for _ in 0..N_NODES {
        b = b.node(GpuSpec::gh200_nvl2().scaled(2.0), 1).node_churn(churn);
    }
    let res = b
        .cluster(ClusterSpec { policy, min_nodes: 1, retry_budget: 1, ..Default::default() })
        .build()
        .run();
    let cl = &res.report.cluster;
    PolicyRow {
        satisfaction: res.report.satisfaction_rate(),
        satisfied: res.report.n_satisfied,
        dollars: cl.total_dollars(),
        cap_per_dollar: cl.capacity_per_dollar(res.report.n_satisfied),
        failures: cl.nodes.iter().map(|n| n.failures).sum(),
        redispatched: cl.nodes.iter().map(|n| n.redispatched).sum(),
        lost: cl.nodes.iter().map(|n| n.lost).sum(),
    }
}

fn main() {
    println!("=== Elastic ICC tier: diurnal load, node churn, capacity per dollar ===");
    println!(
        "{N_NODES} x {} nodes, {} UEs, one {HORIZON} s run per policy, MTBF 20 s / MTTR 2 s / spin-up 0.5 s",
        GpuSpec::gh200_nvl2().scaled(2.0).display_name(),
        2 * UES_PER_CELL,
    );
    print!("load curve:");
    for (i, (phase, load)) in PHASES.iter().enumerate() {
        print!(
            " {phase} {:.0}% @ t={:.0}s",
            100.0 * load,
            i as f64 * PHASE_S
        );
    }
    println!("\n");
    println!(
        "{:<12} {:>7} {:>10} {:>8} {:>9} {:>6} {:>7} {:>5}",
        "policy", "sat", "satisfied", "usd", "sat/usd", "fails", "redisp", "lost"
    );
    let mut rows = Vec::new();
    for policy in [
        AutoscalerKind::Fixed,
        AutoscalerKind::QueueDepth { high: 8, low: 1 },
    ] {
        let r = run(policy);
        println!(
            "{:<12} {:>7.4} {:>10} {:>8.4} {:>9.1} {:>6} {:>7} {:>5}",
            policy.name(),
            r.satisfaction,
            r.satisfied,
            r.dollars,
            r.cap_per_dollar,
            r.failures,
            r.redispatched,
            r.lost,
        );
        rows.push(r);
    }
    println!();
    println!(
        "autoscaler spend ratio: {:.2}x the fixed tier's bill for {:.1}% of its",
        rows[1].dollars / rows[0].dollars.max(1e-12),
        100.0 * rows[1].satisfied as f64 / rows[0].satisfied.max(1) as f64,
    );
    println!("satisfied prompts — the rate schedule lets it shed nodes through the");
    println!("night and evening shoulders inside the same run where it must also");
    println!("absorb the morning ramp, which per-phase steady-state reruns could");
    println!("never show.");
}
