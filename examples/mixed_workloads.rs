//! Mixed workloads over a multi-node edge tier — the Scenario API in
//! one page.
//!
//! Three job classes (translation / chat / summarization, each with
//! its own arrival rate, token distributions and latency budget) share
//! one cell and two GH200-class compute nodes. The token-sampled
//! service model draws each job's output length, and the least-loaded
//! router balances the nodes. We run the same mix under ICC and the
//! 5G-MEC baseline and print the per-class satisfaction rates.
//!
//! Run: `cargo run --release --example mixed_workloads`

use icc6g::config::SchemeConfig;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{
    RoutingPolicy, ScenarioBuilder, ServiceModelKind, WorkloadClass,
};
use icc6g::util::bench::{cell, Table};

fn main() {
    let mut t = Table::new(
        "Mixed workloads: per-class satisfaction (2 nodes, token-sampled service)",
        &["scheme", "class", "jobs", "dropped", "satisfaction", "avg_e2e_ms"],
    );

    for scheme in [SchemeConfig::icc(), SchemeConfig::mec()] {
        let scenario = ScenarioBuilder::new()
            .scheme(scheme.clone())
            .n_ues(20)
            .horizon(12.0)
            .warmup(2.0)
            .seed(7)
            .workload(WorkloadClass::translation())
            .workload(WorkloadClass::chat())
            .workload(WorkloadClass::summarization())
            .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
            .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
            .service_kind(ServiceModelKind::TokenSampled)
            .routing(RoutingPolicy::LeastLoaded)
            .build();
        let res = scenario.run();
        for c in &res.report.per_class {
            t.row(&[
                scheme.name.clone(),
                c.name.clone(),
                c.n_jobs.to_string(),
                c.n_dropped.to_string(),
                cell(c.satisfaction_rate(), 4),
                cell(c.e2e.mean() * 1e3, 2),
            ]);
        }
        println!(
            "{}: overall satisfaction {:.4} over {} jobs ({} events, {:.0}x realtime)",
            scheme.name,
            res.report.satisfaction_rate(),
            res.report.n_jobs,
            res.events,
            res.speedup,
        );
    }
    t.print();
    let _ = t.write_csv("mixed_workloads.csv");
    println!(
        "\nReading: the tight 80 ms translation budget is where ICC's joint\n\
         management earns its keep; the relaxed chat/summarization budgets\n\
         survive the MEC baseline's extra wireline + disjoint split."
    );
}
