//! Hybrid-fidelity scale-out: a 127-cell hex deployment with a
//! 19-cell per-UE focus neighborhood and a fluid far ring.
//!
//! The focus set is the center site plus two rings (19 cells) — every
//! cell there keeps the full per-UE MAC/PHY pipeline. The remaining
//! 108 far-ring cells collapse to the mean-field fluid tier: one
//! activity scalar per cell feeding the same interference exchange the
//! focus cells consume, plus the paper's Eq 3–6 closed forms for the
//! background compute load (DESIGN.md §15). The all-per-UE reference
//! run prices the fidelity trade: the hybrid run must reproduce the
//! focus cells' interference environment within an order of magnitude
//! while running several times faster.
//!
//! Run: `cargo run --release --example far_ring`

use std::time::Instant;

use icc6g::config::SchemeConfig;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{
    CellSpec, FluidSpec, RoutingPolicy, ScenarioBuilder, ScenarioResult,
    ServiceModelKind, TopologySpec, WorkloadClass,
};
use icc6g::util::bench::{cell, Table};

const N_CELLS: usize = 127;
const UES_PER_CELL: u32 = 6;
const HORIZON: f64 = 2.0;

fn run(fluid: bool) -> (ScenarioResult, f64) {
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(HORIZON)
        .warmup(0.3)
        .seed(7)
        .threads(0)
        .routing(RoutingPolicy::LeastLoaded)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat().with_rate(0.05))
        .workload(WorkloadClass::translation().with_rate(0.1))
        .cells(N_CELLS, CellSpec::new(UES_PER_CELL))
        .topology(TopologySpec::hex(300.0))
        .node(GpuSpec::gh200_nvl2().scaled(8.0), 4)
        .node(GpuSpec::gh200_nvl2().scaled(8.0), 4);
    if fluid {
        b = b.fluid(FluidSpec { focus: vec![0], rings: 2, ..Default::default() });
    }
    let t0 = Instant::now();
    let res = b.build().run();
    (res, t0.elapsed().as_secs_f64())
}

fn main() {
    println!(
        "hybrid-fidelity far ring: {N_CELLS} hex cells x {UES_PER_CELL} UEs, focus = center + 2 rings\n"
    );
    let (dense, dense_wall) = run(false);
    let (hybrid, wall) = run(true);
    let fl = hybrid.fluid.as_ref().expect("hybrid run must report the fluid tier");
    assert_eq!(fl.cells.len(), 108, "19 focus + 108 fluid cells");

    let mut t = Table::new(
        "all-per-UE reference vs hybrid (19 per-UE + 108 fluid cells)",
        &["run", "sim_ues", "events", "jobs", "wall_s", "events_per_s", "focus_iot_db"],
    );
    for (name, res, w) in [("dense", &dense, dense_wall), ("hybrid", &hybrid, wall)] {
        let n_fluid = res.fluid.as_ref().map_or(0, |f| f.cells.len());
        let sim_ues = (N_CELLS - n_fluid) as u32 * UES_PER_CELL;
        t.row(&[
            name.into(),
            sim_ues.to_string(),
            res.events.to_string(),
            res.report.n_jobs.to_string(),
            cell(w, 2),
            cell(res.events as f64 / w.max(1e-12), 0),
            cell(res.report.radio[0].iot_db.mean(), 2),
        ]);
    }
    t.print();
    let _ = t.write_csv("far_ring_runs.csv");

    let mut f = Table::new(
        "fluid tier closed forms (Eq 3-6 at the mean far-ring cell)",
        &["class", "lambda_per_cell", "mean_sojourn_ms", "satisfaction"],
    );
    for c in &fl.classes {
        f.row(&[
            c.name.clone(),
            cell(c.lambda_per_cell, 3),
            c.mean_sojourn.map_or("unstable".into(), |w| cell(w * 1e3, 2)),
            cell(c.satisfaction, 4),
        ]);
    }
    f.print();
    let _ = f.write_csv("far_ring_fluid.csv");

    let mean_act =
        fl.cells.iter().map(|c| c.mean_activity).sum::<f64>() / fl.cells.len() as f64;
    let speedup = dense_wall / wall.max(1e-12);
    println!(
        "\nfar ring: mean activity {mean_act:.3} over {} fluid cells, background rho \
         {:.3}/node\nwall clock: dense {dense_wall:.2} s -> hybrid {wall:.2} s ({speedup:.1}x)",
        fl.cells.len(),
        fl.node_rho,
    );

    // Fidelity: the interference environment at the focus cell must
    // stay within an order of magnitude (10 dB) of the reference.
    let d_iot = dense.report.radio[0].iot_db.mean();
    let h_iot = hybrid.report.radio[0].iot_db.mean();
    assert!(
        (d_iot - h_iot).abs() <= 10.0,
        "focus-cell IoT drifted: {h_iot:.2} dB hybrid vs {d_iot:.2} dB dense"
    );
    // ... and the hybrid run must actually buy the speed it promises.
    assert!(
        speedup >= 3.0,
        "hybrid must be >= 3x faster than all-per-UE: got {speedup:.2}x"
    );
    println!(
        "\nReading: 85% of the grid runs as two scalars per cell instead of a per-UE\n\
         pipeline; the focus neighborhood keeps full fidelity while the far ring\n\
         still shapes its interference floor and the shared compute tier's load."
    );
}
