//! Multi-model serving: quality floors vs a one-model fleet, and
//! shared-prefix KV reuse.
//!
//! Two classes share a two-node edge tier: a premium chat class whose
//! quality floor only accepts the 70B tier, and a bulk translation
//! class that accepts either tier. The baseline fleet serves *every*
//! job on the 70B model (the safe single-model deployment); the zoo
//! fleet keeps the premium floor on node 0 and moves bulk traffic to a
//! resident 7B on node 1 — same hardware, same routing, only the model
//! catalog and acceptance sets change. The second sweep turns on a
//! shared 448-token system prompt for a KV-starved batching node and
//! measures the admission capacity the refcounted prefix blocks buy.
//!
//! Run: `cargo run --release --example multi_model`

use icc6g::config::SchemeConfig;
use icc6g::llm::{GpuSpec, ModelSpec};
use icc6g::metrics::JobFate;
use icc6g::scenario::{
    CellSpec, ExecutionModel, RoutingPolicy, ScenarioBuilder, ScenarioResult,
    ServiceModelKind, TokenDist, WorkloadClass,
};
use icc6g::util::bench::{cell, Table};

const HORIZON: f64 = 8.0;
const WARMUP: f64 = 1.0;

/// Two-node tier; `bulk_models` decides where the bulk class may run.
fn fleet(bulk_models: &[&str], node1_models: &[&str]) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(HORIZON)
        .warmup(WARMUP)
        .seed(3)
        .routing(RoutingPolicy::ClassAffinity)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat().with_rate(0.2).with_models(&["70b"]))
        .workload(WorkloadClass::translation().with_rate(8.0).with_models(bulk_models))
        .cell(CellSpec::new(30))
        .model(ModelSpec::llama_70b().with_resident_bytes(140e9))
        .model(ModelSpec::llama_7b().with_resident_bytes(14e9))
        .node_exec(
            GpuSpec::gh200_nvl2().scaled(2.0),
            1,
            ExecutionModel::ContinuousBatching { max_batch: 32, kv_budget: 80e9 },
        )
        .node_models(&["70b"])
        .node_swap_s(0.5)
        .node_exec(
            GpuSpec::gh200_nvl2().scaled(2.0),
            1,
            ExecutionModel::ContinuousBatching { max_batch: 32, kv_budget: 80e9 },
        )
        .node_models(node1_models)
        .node_swap_s(0.5)
        .build()
        .run()
}

/// Tokens served per second per A100-equivalent device.
fn tokens_per_sec_per_gpu(res: &ScenarioResult, gpus: f64) -> f64 {
    let tokens: u64 = res
        .outcomes
        .iter()
        .filter(|o| o.fate == JobFate::Completed)
        .map(|o| o.tokens as u64)
        .sum();
    tokens as f64 / (HORIZON - WARMUP) / gpus
}

/// One KV-starved batching node; `prefix` declares the shared system
/// prompt the bulk jobs have in common.
fn prefixed(prefix: u32) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(HORIZON)
        .warmup(WARMUP)
        .seed(11)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(
            WorkloadClass::chat()
                .with_rate(3.0)
                .with_input(TokenDist::Fixed(512))
                .with_output(TokenDist::Fixed(64))
                .with_budget(2.0)
                .with_models(&["7b"])
                .with_prefix_tokens(prefix),
        )
        .cell(CellSpec::new(12))
        .model(ModelSpec::llama_7b().with_kv_bytes_per_token(1e6).with_resident_bytes(14e9))
        .node_exec(
            GpuSpec::gh200_nvl2().scaled(2.0),
            1,
            ExecutionModel::ContinuousBatching { max_batch: 16, kv_budget: 1.3e9 },
        )
        .build()
        .run()
}

fn main() {
    let gpus = 2.0 * GpuSpec::gh200_nvl2().scaled(2.0).a100_equivalents();
    let mut t = Table::new(
        "one-model fleet vs zoo with quality floors (same hardware, same routing)",
        &["fleet", "model", "jobs", "satisfaction", "avg_e2e_ms", "tok/s/gpu"],
    );

    let baseline = fleet(&["70b"], &["70b"]);
    let zoo = fleet(&["7b", "70b"], &["7b"]);
    for (name, res) in [("all-70b", &baseline), ("zoo+floors", &zoo)] {
        let rate = tokens_per_sec_per_gpu(res, gpus);
        for m in &res.report.per_model {
            if m.n_jobs == 0 {
                continue;
            }
            t.row(&[
                name.into(),
                m.name.clone(),
                m.n_jobs.to_string(),
                cell(m.satisfaction_rate(), 4),
                cell(m.e2e.mean() * 1e3, 2),
                cell(rate, 1),
            ]);
        }
    }
    t.print();
    let _ = t.write_csv("multi_model_fleets.csv");

    // The premium floor must hold in both fleets: chat (class 0) is
    // never priced below the 70B tier.
    for res in [&baseline, &zoo] {
        for o in &res.outcomes {
            if o.class_id == 0 && o.fate != JobFate::InFlight {
                assert_eq!(o.model_id, 0, "premium job served below its floor");
            }
        }
    }
    let base_rate = tokens_per_sec_per_gpu(&baseline, gpus);
    let zoo_rate = tokens_per_sec_per_gpu(&zoo, gpus);
    assert!(
        zoo_rate > base_rate,
        "the zoo fleet must raise per-GPU throughput: {zoo_rate:.1} vs {base_rate:.1}"
    );
    println!(
        "\nper-GPU throughput: {base_rate:.1} tok/s/GPU all-70B → {zoo_rate:.1} tok/s/GPU \
         with the 7B tier ({:.2}x)",
        zoo_rate / base_rate
    );

    let mut p = Table::new(
        "shared-prefix KV reuse on a KV-starved node (1.3 GB budget, 1 MB/token)",
        &["prefix_tokens", "served/s", "dropped", "satisfaction"],
    );
    let window = HORIZON - WARMUP;
    let mut served = Vec::new();
    for prefix in [0u32, 256, 448] {
        let res = prefixed(prefix);
        let c = &res.report.per_class[0];
        served.push(c.comp.count());
        p.row(&[
            prefix.to_string(),
            cell(c.comp.count() as f64 / window, 1),
            c.n_dropped.to_string(),
            cell(c.satisfaction_rate(), 4),
        ]);
    }
    p.print();
    let _ = p.write_csv("multi_model_prefix.csv");
    assert!(
        served[2] > served[0],
        "prefix reuse must admit more work: {} vs {} jobs",
        served[2],
        served[0]
    );
    println!(
        "\nReading: quality floors route bulk tokens to the cheap tier without letting a\n\
         single premium job drop below its accepted set; shared-prefix blocks reserve\n\
         only the unshared suffix per job, so a binding KV budget holds ~3x the batch."
    );
}
