//! Warm-started capacity sweep: share one warm-up per seed.
//!
//! A capacity sweep re-simulates the same warm-up transient at every
//! rate point — per seed, `grid_points × warm_s` simulated seconds
//! that produce no measurements. Engine snapshots remove the
//! redundancy: simulate the warm-up **once** per seed, checkpoint it
//! (`ScenarioEngine::snapshot`), then fork the checkpoint across the
//! rate axis and simulate only the measured remainder of each run.
//!
//! The demo grid steps its arrival rate at the warm-up boundary, so
//! the warm-up prefix is rate-invariant and [`WarmStart::Exact`]
//! applies: the warm sweep is **bit-identical** to the cold one —
//! identical merged reports, identical capacity estimate — it just
//! skips `(grid_points − 1) × warm_s` simulated seconds per seed.
//! (Grids that vary the rate from t = 0 can still warm-start behind
//! the explicit `WarmStart::Forced` approximation flag; see
//! DESIGN.md §13 for the validity contract.)
//!
//! Run: `cargo run --release --example warm_sweep`

use std::time::Instant;

use icc6g::config::SchemeConfig;
use icc6g::coordinator::{capacity_from_curve, CurvePoint};
use icc6g::llm::GpuSpec;
use icc6g::scenario::{CellSpec, Scenario, ScenarioBuilder, WorkloadClass};
use icc6g::sweep::{replication_seeds, sweep_grid, sweep_grid_warm, GridPoint, WarmStart};

/// Warm-up seconds shared across the grid (also the phase boundary).
const WARM_S: f64 = 6.0;
const HORIZON: f64 = 8.0;
const UES: u32 = 120;

/// One grid point: a fixed 120-UE population whose per-UE rate steps
/// to `x / UES` at the warm-up boundary after a light shared prefix.
fn make(x: f64, seed: u64) -> Scenario {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(HORIZON)
        .warmup(1.0)
        .seed(seed)
        .workload(
            WorkloadClass::translation()
                .with_rate(10.0 / UES as f64)
                .with_rate_phase(WARM_S, x / UES as f64),
        )
        .cells(2, CellSpec::new(UES / 2))
        .node(GpuSpec::gh200_nvl2(), 1)
        .node(GpuSpec::gh200_nvl2(), 1)
        .build()
}

fn capacity(points: &[GridPoint], alpha: f64) -> f64 {
    let curve: Vec<CurvePoint> =
        points.iter().map(|p| CurvePoint::from_report(p.x, &p.report)).collect();
    capacity_from_curve(&curve, alpha)
}

fn main() {
    let xs: Vec<f64> = (1..=8).map(|i| i as f64 * 15.0).collect();
    let seeds = replication_seeds(1, 3);
    let alpha = 0.95;
    println!("=== Warm-started capacity sweep: fork one checkpoint per seed ===\n");
    println!(
        "{} rate points x {} seeds, {WARM_S:.0} s shared warm-up of a {HORIZON:.0} s horizon\n",
        xs.len(),
        seeds.len(),
    );

    let t0 = Instant::now();
    let cold = sweep_grid(&xs, &seeds, 0, |x, s| make(x, s).run().report);
    let cold_wall = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let warm = sweep_grid_warm(&xs, &seeds, WARM_S, 0, WarmStart::Exact, make);
    let warm_wall = t0.elapsed().as_secs_f64();

    println!("{:>8}  {:>10}  {:>10}", "rate", "cold sat", "warm sat");
    for (c, w) in cold.iter().zip(&warm) {
        println!(
            "{:>8.1}  {:>10.4}  {:>10.4}",
            c.x,
            c.report.satisfaction_rate(),
            w.report.satisfaction_rate(),
        );
        assert_eq!(
            c.report.to_json(),
            w.report.to_json(),
            "warm point diverged from cold at rate {}",
            c.x
        );
    }

    let (cap_cold, cap_warm) = (capacity(&cold, alpha), capacity(&warm, alpha));
    println!("\ncapacity at alpha = {alpha}: cold {cap_cold:.1}, warm {cap_warm:.1} prompts/s");
    assert_eq!(
        cap_cold.to_bits(),
        cap_warm.to_bits(),
        "capacity estimates must be identical"
    );
    println!(
        "wall: cold {cold_wall:.2} s, warm {warm_wall:.2} s ({:.1}x)",
        cold_wall / warm_wall.max(1e-12),
    );
    println!("\nevery warm point is bit-identical to its cold twin (asserted above).");
}
