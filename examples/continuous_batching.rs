//! Continuous batching on a saturated edge node — capacity vs
//! `max_batch`.
//!
//! One A100 serves Llama-2-7B sequentially in ≈ 110 ms/job, i.e. ≈ 9
//! jobs/s — far below the 40 jobs/s this cell offers. Iteration-level
//! continuous batching amortizes the weight stream across the decode
//! batch: while decode stays memory-bound (batch < the saturation
//! batch, ≈ 153 here), every extra slot is almost free throughput.
//! This example sweeps the batch cap and prints sustained throughput,
//! satisfaction, and the TTFT/TPOT tails against the sequential
//! baseline.
//!
//! Run: `cargo run --release --example continuous_batching`

use icc6g::config::{Deployment, Management, SchemeConfig};
use icc6g::llm::{CostModel, GpuSpec, JobSpec};
use icc6g::scenario::{ExecutionModel, ScenarioBuilder, ScenarioResult, WorkloadClass};
use icc6g::util::bench::{cell, Table};

const HORIZON: f64 = 10.0;
const WARMUP: f64 = 1.0;

fn run(exec: ExecutionModel) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(
            SchemeConfig::builder()
                .name("joint RAN")
                .deployment(Deployment::Ran)
                .management(Management::Joint)
                .build(),
        )
        .n_ues(40) // 40 jobs/s offered — saturates the sequential node
        .horizon(HORIZON)
        .warmup(WARMUP)
        .seed(7)
        .workload(WorkloadClass::translation().with_budget(0.5))
        .node_exec(GpuSpec::a100(), 1, exec)
        .build()
        .run()
}

fn main() {
    let gpu = GpuSpec::a100();
    let job = JobSpec::table1();
    let m = CostModel::new(gpu);
    println!(
        "node: {} — sequential service {:.1} ms/job, saturation batch {}",
        gpu.display_name(),
        m.total_latency(&job) * 1e3,
        m.saturation_batch(&job),
    );

    let mut t = Table::new(
        "capacity vs max_batch (one A100, 40 jobs/s offered, 0.5 s budget)",
        &["max_batch", "served/s", "satisfaction", "ttft_p95_ms", "tpot_p95_ms"],
    );
    let window = HORIZON - WARMUP;

    let seq = run(ExecutionModel::Sequential);
    let c = &seq.report.per_class[0];
    t.row(&[
        "sequential".into(),
        cell(c.comp.count() as f64 / window, 1),
        cell(c.satisfaction_rate(), 4),
        cell(c.ttft_percentile(95.0) * 1e3, 1),
        cell(c.tpot_percentile(95.0) * 1e3, 3),
    ]);
    let seq_served = c.comp.count();

    for max_batch in [1u32, 2, 4, 8, 16, 32, 64, 128, 256] {
        let res = run(ExecutionModel::ContinuousBatching { max_batch, kv_budget: 0.0 });
        let c = &res.report.per_class[0];
        t.row(&[
            max_batch.to_string(),
            cell(c.comp.count() as f64 / window, 1),
            cell(c.satisfaction_rate(), 4),
            cell(c.ttft_percentile(95.0) * 1e3, 1),
            cell(c.tpot_percentile(95.0) * 1e3, 3),
        ]);
        if max_batch >= 64 {
            assert!(
                c.comp.count() > seq_served,
                "a wide batch must out-serve the sequential node"
            );
        }
    }
    t.print();
    let _ = t.write_csv("continuous_batching.csv");
    println!(
        "\nReading: throughput climbs ≈ linearly with max_batch until the KV budget or\n\
         the saturation batch binds; TPOT p95 grows once decode turns compute-bound."
    );
}
