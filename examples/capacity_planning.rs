//! Capacity planning: "how many GPUs does my edge site need?"
//!
//! The operator-facing workflow the paper motivates (Fig 7 / the 27%
//! cost saving): given a target prompt rate, a latency budget and a
//! satisfaction SLO, sweep compute capacity under each
//! latency-management scheme and report the cheapest feasible
//! deployment — first with the fast analytic tandem model, then
//! validated with the full SLS.
//!
//! Run: `cargo run --release --example capacity_planning -- [--rate 60] [--alpha 0.95]`

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::{min_capacity_from_curve, sweep_gpu_capacity};
use icc6g::llm::{CostModel, GpuSpec, JobSpec};
use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::{Policy, Scheme};
use icc6g::util::args::{Args, OptSpec};
use icc6g::util::bench::{cell, Table};

fn main() -> anyhow::Result<()> {
    let specs = [
        OptSpec { name: "rate", help: "target prompt rate (prompts/s)", takes_value: true, default: Some("60") },
        OptSpec { name: "alpha", help: "satisfaction SLO", takes_value: true, default: Some("0.95") },
        OptSpec { name: "horizon", help: "SLS seconds per point", takes_value: true, default: Some("10") },
    ];
    let args = Args::parse(std::env::args().skip(1), &specs)?;
    let rate = args.get_f64("rate")?.unwrap();
    let alpha = args.get_f64("alpha")?.unwrap();
    let horizon = args.get_f64("horizon")?.unwrap();

    let job = JobSpec::table1();
    println!(
        "workload: {} prompts/s of {}+{} token jobs, {} ms budget, SLO {alpha}\n",
        rate,
        job.n_input,
        job.n_output,
        job.b_total * 1e3
    );

    // --- analytic first pass: tandem M/M/1 with μ2 from the roofline --
    println!("== analytic screening (tandem M/M/1) ==");
    let mut analytic = Table::new(
        "min ×A100 by scheme (analytic)",
        &["scheme", "min xA100", "T_comp@cap (ms)"],
    );
    for scheme in Scheme::fig4_schemes() {
        // smallest g where satisfaction(rate) >= alpha
        let mut found: Option<f64> = None;
        for g10 in 10..400u32 {
            let g = g10 as f64 / 10.0;
            let mu2 = 1.0 / CostModel::new(GpuSpec::a100().scaled(g)).total_latency(&job);
            if mu2 <= rate {
                continue; // unstable
            }
            let p = SystemParams { mu1: 900.0, mu2, b_total: job.b_total };
            let sat = match scheme.policy {
                Policy::Joint => scheme_satisfaction(&p, &scheme, rate),
                Policy::Disjoint { .. } => scheme_satisfaction(&p, &scheme, rate),
            };
            if sat >= alpha {
                found = Some(g);
                break;
            }
        }
        match found {
            Some(g) => {
                let t = CostModel::new(GpuSpec::a100().scaled(g)).total_latency(&job);
                analytic.row(&[scheme.name.to_string(), cell(g, 1), cell(t * 1e3, 1)]);
            }
            None => analytic.row(&[scheme.name.to_string(), "infeasible".into(), "-".into()]),
        }
    }
    analytic.print();

    // --- SLS validation ----------------------------------------------
    println!("\n== SLS validation (full 5G uplink + compute queue) ==");
    let mut base = SimConfig::table1();
    base.n_ues = rate.round() as u32; // 1 prompt/s/UE
    base.horizon = horizon;
    let grid: Vec<f64> = (4..=20).map(|i| i as f64).collect();
    let mut sls = Table::new("min ×A100 by scheme (SLS)", &["scheme", "min xA100"]);
    let mut icc_min = None;
    let mut dis_min = None;
    for scheme in SchemeConfig::fig6_schemes() {
        let pts = sweep_gpu_capacity(&base, &scheme, &grid, 2);
        let m = min_capacity_from_curve(&pts, alpha);
        if scheme.priority_scheme {
            icc_min = m;
        } else if m.is_some() && dis_min.is_none() {
            dis_min = m;
        }
        sls.row(&[
            scheme.name.to_string(),
            m.map(|x| cell(x, 1)).unwrap_or_else(|| "not reached".into()),
        ]);
    }
    sls.print();

    if let (Some(icc), Some(dis)) = (icc_min, dis_min) {
        println!(
            "\nICC saves {:.0}% of compute vs the best disjoint deployment \
             ({:.1} vs {:.1} ×A100; paper reports 27%).",
            (1.0 - icc / dis) * 100.0,
            icc,
            dis
        );
    }
    Ok(())
}
