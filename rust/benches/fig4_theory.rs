//! Bench target regenerating **Fig 4** (paper §III-B): job-satisfaction
//! curves of the three latency-management schemes over the tandem
//! M/M/1 model, the α = 95% service capacities, the +98% headline, and
//! a Monte-Carlo cross-validation of the closed forms.
//!
//! Run: `cargo bench --bench fig4_theory`
//! Output: console tables + CSVs under bench_out/.

use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::tandem_mc::empirical_satisfaction;
use icc6g::queueing::{service_capacity, Scheme};
use icc6g::util::bench::{bench_fn, cell, fmt_ns, Table};

fn main() {
    let p = SystemParams::paper();
    let schemes = Scheme::fig4_schemes();
    let alpha = 0.95;

    // --- the paper's curves (25 λ points, 3 schemes) -----------------
    let mut curves = Table::new(
        "Fig 4 — satisfaction vs λ (μ1=900, μ2=100, b_total=80ms)",
        &["lambda", schemes[0].name, schemes[1].name, schemes[2].name],
    );
    let npts = 25;
    for i in 0..npts {
        let lambda = 2.0 + (p.stability_limit() - 4.0) * i as f64 / (npts - 1) as f64;
        curves.row(&[
            cell(lambda, 1),
            cell(scheme_satisfaction(&p, &schemes[0], lambda), 4),
            cell(scheme_satisfaction(&p, &schemes[1], lambda), 4),
            cell(scheme_satisfaction(&p, &schemes[2], lambda), 4),
        ]);
    }
    curves.print();
    curves.write_csv("fig4_curves.csv").expect("csv");

    // --- service capacities + headline -------------------------------
    let caps: Vec<f64> = schemes
        .iter()
        .map(|s| {
            service_capacity(
                |l| scheme_satisfaction(&p, s, l),
                alpha,
                p.stability_limit() - 1e-6,
                1e-6,
            )
            .lambda_star
        })
        .collect();
    let mut cap_t = Table::new(
        "Fig 4 — service capacity at α=0.95 (paper headline: +98%)",
        &["scheme", "lambda*", "vs MEC"],
    );
    for (s, c) in schemes.iter().zip(&caps) {
        cap_t.row(&[
            s.name.to_string(),
            cell(*c, 2),
            format!("{:+.1}%", (c / caps[2] - 1.0) * 100.0),
        ]);
    }
    cap_t.print();
    cap_t.write_csv("fig4_capacity.csv").expect("csv");
    println!(
        "\nheadline: ICC joint-RAN vs 5G MEC = {:+.1}% (paper: +98%)",
        (caps[0] / caps[2] - 1.0) * 100.0
    );

    // --- Monte-Carlo validation of the closed forms ------------------
    let mut mc = Table::new(
        "Fig 4 — analytic vs 60k-job Monte Carlo",
        &["lambda", "scheme", "analytic", "simulated", "abs_err"],
    );
    let mut max_err: f64 = 0.0;
    for &lambda in &[20.0, 40.0, 60.0, 80.0] {
        for s in &schemes {
            let ana = scheme_satisfaction(&p, s, lambda);
            let emp = empirical_satisfaction(&p, s, lambda, 60_000, 42);
            max_err = max_err.max((ana - emp).abs());
            mc.row(&[
                cell(lambda, 0),
                s.name.to_string(),
                cell(ana, 4),
                cell(emp, 4),
                cell((ana - emp).abs(), 4),
            ]);
        }
    }
    mc.print();
    mc.write_csv("fig4_mc.csv").expect("csv");
    assert!(max_err < 0.02, "closed forms diverge from MC: {max_err}");
    println!("max |analytic − MC| = {max_err:.4} (< 0.02 required)");

    // --- timing: how fast is the analytic layer? ---------------------
    let r = bench_fn("scheme_satisfaction (1 eval)", 100, 10_000, 0.2, || {
        scheme_satisfaction(&p, &schemes[0], 55.0)
    });
    println!("\n{}", r.report());
    let r = bench_fn("service_capacity (full bisection)", 5, 200, 0.2, || {
        service_capacity(
            |l| scheme_satisfaction(&p, &schemes[0], l),
            alpha,
            p.stability_limit() - 1e-6,
            1e-6,
        )
    });
    println!("{}", r.report());
    println!("\n(capacity solve = {} — interactive capacity planning is free)", fmt_ns(r.mean_ns));
}
