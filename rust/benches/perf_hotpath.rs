//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! L3 targets (DESIGN.md §7): the SLS event loop must sustain ≥1 M
//! events/s; queue operations must be allocation-light (drain-style
//! node event APIs, no per-event boxing); the analytic layer must be
//! effectively free. The PJRT serving path reports tokens/s when
//! artifacts exist.
//!
//! Results also land machine-readable in `BENCH_hotpath.json` so the
//! perf trajectory accumulates across commits.
//!
//! Run: `cargo bench --bench perf_hotpath`

use icc6g::compute::{
    BatchEngine, BatchEvent, ComputeJob, ComputeNode, Discipline, ExecutionModel,
};
use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::dess::EventQueue;
use icc6g::llm::GpuSpec;
use icc6g::mac::{drop_ues, MacConfig, Sdu, SduKind, SlotWorkspace, UeBank, UlScheduler};
use icc6g::phy::Carrier;
use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::tandem_mc::simulate_tandem;
use icc6g::queueing::Scheme;
use icc6g::rng::Rng;
use icc6g::runtime::{tokenizer, Engine};
use icc6g::scenario::ScenarioBuilder;
use icc6g::sim::Sls;
use icc6g::util::bench::{bench_fn, write_bench_json, BenchResult};

fn bench_event_queue(out: &mut Vec<BenchResult>) {
    // Schedule + pop 10k events per iteration.
    let r = bench_fn("dess: 10k schedule+pop", 3, 50, 0.3, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u32 {
            q.schedule_at((i % 97) as f64, i);
        }
        let mut acc = 0u64;
        while let Some((_, e)) = q.pop() {
            acc += e as u64;
        }
        acc
    });
    println!("{}", r.report());
    let events_per_sec = 20_000.0 / (r.mean_ns * 1e-9);
    println!("  → {:.1} M queue ops/s", events_per_sec / 1e6);
    out.push(r);
}

fn bench_compute_node(out: &mut Vec<BenchResult>) {
    // Dispatch through the drain-style API with one reused event
    // buffer — the allocation-free pattern the scenario loop uses.
    let mut events = Vec::with_capacity(16);
    let r = bench_fn("compute: 1k enqueue+dispatch+complete (EDF+drop)", 3, 100, 0.3, || {
        let mut node =
            ComputeNode::new(Discipline::DeadlinePriority { drop_hopeless: true }, 2);
        let mut t = 0.0;
        for i in 0..1000u64 {
            t += 0.001;
            events.clear();
            node.enqueue(
                ComputeJob {
                    job_id: i,
                    t_gen: t,
                    t_comm: 0.002,
                    deadline: t + 0.08,
                    service_time: 0.011,
                },
                t,
                &mut events,
            );
            std::hint::black_box(&events);
            if node.busy_servers() > 0 && i % 3 == 0 {
                events.clear();
                node.complete(t + 0.011, &mut events);
                std::hint::black_box(&events);
            }
        }
        node.queue_len()
    });
    println!("{}", r.report());
    out.push(r);
}

fn bench_batch_engine(out: &mut Vec<BenchResult>) {
    // Iteration-level engine under a saturating arrival pattern:
    // enqueue + step until drained, reused event buffer.
    let gpu = GpuSpec::a100();
    let mut events: Vec<BatchEvent> = Vec::with_capacity(64);
    let r = bench_fn("compute: batch engine 256 jobs, max_batch 32", 3, 50, 0.3, || {
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 32, 64e9);
        let mut pending: Option<f64> = None;
        let mut finished = 0u64;
        for i in 0..256u64 {
            events.clear();
            e.enqueue(
                icc6g::compute::BatchJob {
                    job_id: i,
                    t_gen: 0.0,
                    t_comm: 0.0,
                    deadline: 10.0,
                    n_input: 15,
                    n_output: 15,
                    prefill_time: 0.00687,
                    decode_time: 15.0 * 0.00687,
                    c_llm: 14e9,
                    m_llm: 14e9,
                    kv_bytes_per_token: 524_288.0,
                    prefix_id: 0,
                    prefix_tokens: 0,
                },
                0.0,
                &mut events,
            );
            for ev in &events {
                if let BatchEvent::StepAt { at } = ev {
                    pending = Some(*at);
                }
            }
        }
        while let Some(at) = pending {
            pending = None;
            events.clear();
            e.step(at, &mut events);
            for ev in &events {
                match ev {
                    BatchEvent::StepAt { at } => pending = Some(*at),
                    BatchEvent::Finished { .. } => finished += 1,
                    _ => {}
                }
            }
        }
        finished
    });
    println!("{}", r.report());
    out.push(r);
}

fn bench_mac_slot(out: &mut Vec<BenchResult>) {
    let carrier = Carrier::table1();
    let sched = UlScheduler::new(MacConfig::default(), carrier);
    let mut rng = Rng::new(1);
    let mut drop_rng = Rng::new(2);
    let mut bank = UeBank::new(drop_ues(&mut drop_rng, 60, 35.0, 300.0));
    let mut ws = SlotWorkspace::new();
    let mut slot = 0u64;
    let r = bench_fn("mac: one 60-UE slot (backlogged)", 10, 2_000, 0.3, || {
        for i in 0..bank.len() {
            if bank.ue(i).buffered_bytes() < 2000 {
                bank.note_arrival(i, slot, 4, 2);
                bank.push_bg_sdu(i, Sdu {
                    kind: SduKind::Background,
                    total_bytes: 500,
                    bytes_left: 500,
                    t_arrival: slot as f64 * 0.00025 + i as f64 * 1e-9,
                });
            }
        }
        sched.schedule_slot(slot, &mut bank, &mut rng, &mut ws);
        slot += 1;
        ws.grants.len()
    });
    println!("{}", r.report());
    let slots_per_sec = 1.0 / (r.mean_ns * 1e-9);
    println!(
        "  → {:.0} slots/s = {:.0}× realtime at 60 kHz SCS",
        slots_per_sec,
        slots_per_sec * 0.25e-3
    );
    out.push(r);
}

fn bench_tandem_mc(out: &mut Vec<BenchResult>) {
    let p = SystemParams::paper();
    let r = bench_fn("queueing: 50k-job tandem MC", 1, 20, 0.5, || {
        simulate_tandem(&p, 60.0, 0.005, 50_000, 7).len()
    });
    println!("{}", r.report());
    let jobs_per_sec = 50_000.0 / (r.mean_ns * 1e-9);
    println!("  → {:.1} M simulated jobs/s", jobs_per_sec / 1e6);
    out.push(r);
}

fn bench_analytic(out: &mut Vec<BenchResult>) {
    let p = SystemParams::paper();
    let s = Scheme::mec_disjoint();
    let r = bench_fn("queueing: disjoint closed form", 1000, 100_000, 0.2, || {
        scheme_satisfaction(&p, &s, 55.0)
    });
    println!("{}", r.report());
    out.push(r);
}

fn bench_full_sls(out: &mut Vec<BenchResult>) {
    let mut cfg = SimConfig::table1().with_scheme(SchemeConfig::icc());
    cfg.n_ues = 60;
    cfg.horizon = 5.0;
    cfg.warmup = 0.5;
    let r = bench_fn("sls: 5s simulated, 60 UEs, ICC", 1, 5, 1.0, || {
        Sls::new(cfg.clone()).run().report.n_jobs
    });
    println!("{}", r.report());
    let sim_per_wall = 5.0 / (r.mean_ns * 1e-9);
    println!("  → {sim_per_wall:.0}× realtime (5 s simulated per {:.0} ms wall)", r.mean_ns / 1e6);
    out.push(r);
}

fn bench_batching_scenario(out: &mut Vec<BenchResult>) {
    // Same radio substrate, continuous-batching node: measures the
    // per-iteration event overhead of the batch execution model.
    let r = bench_fn("scenario: 5s, 60 UEs, batching node", 1, 5, 1.0, || {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .n_ues(60)
            .horizon(5.0)
            .warmup(0.5)
            .node_exec(
                GpuSpec::gh200_nvl2().scaled(2.0),
                1,
                ExecutionModel::ContinuousBatching { max_batch: 32, kv_budget: 0.0 },
            )
            .build()
            .run()
            .report
            .n_jobs
    });
    println!("{}", r.report());
    out.push(r);
}

fn bench_engine(out: &mut Vec<BenchResult>) {
    let dir = Engine::default_artifacts_dir();
    if !dir.join("prefill.hlo.txt").exists() {
        println!("engine: skipped (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).expect("engine");
    let prompt = tokenizer::encode("benchmarking the serving hot path");
    let r = bench_fn("engine: prefill(34 tok)", 2, 20, 1.0, || {
        engine.prefill(&prompt).unwrap().0.len()
    });
    println!("{}", r.report());
    out.push(r);
    let r = bench_fn("engine: generate 15 tokens", 1, 10, 2.0, || {
        engine.generate(&prompt, 15).unwrap().0.len()
    });
    println!("{}", r.report());
    let toks_per_sec = 15.0 / (r.mean_ns * 1e-9);
    println!("  → {toks_per_sec:.0} tok/s end-to-end (prefill amortized)");
    out.push(r);
}

fn main() {
    println!("=== §Perf hot-path microbenchmarks ===\n");
    let mut results = Vec::new();
    bench_event_queue(&mut results);
    bench_compute_node(&mut results);
    bench_batch_engine(&mut results);
    bench_mac_slot(&mut results);
    bench_tandem_mc(&mut results);
    bench_analytic(&mut results);
    bench_full_sls(&mut results);
    bench_batching_scenario(&mut results);
    bench_engine(&mut results);
    match write_bench_json("BENCH_hotpath.json", &results) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json ({} results)", results.len()),
        Err(e) => eprintln!("\ncannot write BENCH_hotpath.json: {e}"),
    }
}
