//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! L3 targets (DESIGN.md §7): the SLS event loop must sustain ≥1 M
//! events/s; queue operations must be allocation-light; the analytic
//! layer must be effectively free. The PJRT serving path reports
//! tokens/s when artifacts exist.
//!
//! Run: `cargo bench --bench perf_hotpath`

use icc6g::compute::{ComputeJob, ComputeNode, Discipline};
use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::dess::EventQueue;
use icc6g::mac::{MacConfig, Sdu, SduKind, UeMac, UlScheduler};
use icc6g::phy::channel::LargeScale;
use icc6g::phy::Carrier;
use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::tandem_mc::simulate_tandem;
use icc6g::queueing::Scheme;
use icc6g::rng::Rng;
use icc6g::runtime::{tokenizer, Engine};
use icc6g::sim::Sls;
use icc6g::util::bench::bench_fn;

fn bench_event_queue() {
    // Schedule + pop 10k events per iteration.
    let r = bench_fn("dess: 10k schedule+pop", 3, 50, 0.3, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u32 {
            q.schedule_at((i % 97) as f64, i);
        }
        let mut acc = 0u64;
        while let Some((_, e)) = q.pop() {
            acc += e as u64;
        }
        acc
    });
    println!("{}", r.report());
    let events_per_sec = 20_000.0 / (r.mean_ns * 1e-9);
    println!("  → {:.1} M queue ops/s", events_per_sec / 1e6);
}

fn bench_compute_node() {
    let r = bench_fn("compute: 1k enqueue+complete (EDF+drop)", 3, 100, 0.3, || {
        let mut node =
            ComputeNode::new(Discipline::DeadlinePriority { drop_hopeless: true }, 2);
        let mut t = 0.0;
        for i in 0..1000u64 {
            t += 0.001;
            let evs = node.enqueue(
                ComputeJob {
                    job_id: i,
                    t_gen: t,
                    t_comm: 0.002,
                    deadline: t + 0.08,
                    service_time: 0.011,
                },
                t,
            );
            std::hint::black_box(&evs);
            if node.busy_servers() > 0 && i % 3 == 0 {
                let evs = node.complete(t + 0.011);
                std::hint::black_box(&evs);
            }
        }
        node.queue_len()
    });
    println!("{}", r.report());
}

fn bench_mac_slot() {
    let carrier = Carrier::table1();
    let sched = UlScheduler::new(MacConfig::default(), carrier);
    let mut rng = Rng::new(1);
    let mut drop_rng = Rng::new(2);
    let mut ues: Vec<UeMac> = (0..60)
        .map(|i| {
            UeMac::new(LargeScale::drop(&mut drop_rng, 35.0, 300.0)).with_sr_phase(i)
        })
        .collect();
    let mut slot = 0u64;
    let r = bench_fn("mac: one 60-UE slot (backlogged)", 10, 2_000, 0.3, || {
        for (i, ue) in ues.iter_mut().enumerate() {
            if ue.buffered_bytes() < 2000 {
                ue.note_arrival(slot, 4, 2);
                ue.push_bg_sdu(Sdu {
                    kind: SduKind::Background,
                    total_bytes: 500,
                    bytes_left: 500,
                    t_arrival: slot as f64 * 0.00025 + i as f64 * 1e-9,
                });
            }
        }
        let out = sched.schedule_slot(slot, &mut ues, &mut rng);
        slot += 1;
        out.len()
    });
    println!("{}", r.report());
    let slots_per_sec = 1.0 / (r.mean_ns * 1e-9);
    println!(
        "  → {:.0} slots/s = {:.0}× realtime at 60 kHz SCS",
        slots_per_sec,
        slots_per_sec * 0.25e-3
    );
}

fn bench_tandem_mc() {
    let p = SystemParams::paper();
    let r = bench_fn("queueing: 50k-job tandem MC", 1, 20, 0.5, || {
        simulate_tandem(&p, 60.0, 0.005, 50_000, 7).len()
    });
    println!("{}", r.report());
    let jobs_per_sec = 50_000.0 / (r.mean_ns * 1e-9);
    println!("  → {:.1} M simulated jobs/s", jobs_per_sec / 1e6);
}

fn bench_analytic() {
    let p = SystemParams::paper();
    let s = Scheme::mec_disjoint();
    let r = bench_fn("queueing: disjoint closed form", 1000, 100_000, 0.2, || {
        scheme_satisfaction(&p, &s, 55.0)
    });
    println!("{}", r.report());
}

fn bench_full_sls() {
    let mut cfg = SimConfig::table1().with_scheme(SchemeConfig::icc());
    cfg.n_ues = 60;
    cfg.horizon = 5.0;
    cfg.warmup = 0.5;
    let r = bench_fn("sls: 5s simulated, 60 UEs, ICC", 1, 5, 1.0, || {
        Sls::new(cfg.clone()).run().report.n_jobs
    });
    println!("{}", r.report());
    let sim_per_wall = 5.0 / (r.mean_ns * 1e-9);
    println!("  → {sim_per_wall:.0}× realtime (5 s simulated per {:.0} ms wall)", r.mean_ns / 1e6);
}

fn bench_engine() {
    let dir = Engine::default_artifacts_dir();
    if !dir.join("prefill.hlo.txt").exists() {
        println!("engine: skipped (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).expect("engine");
    let prompt = tokenizer::encode("benchmarking the serving hot path");
    let r = bench_fn("engine: prefill(34 tok)", 2, 20, 1.0, || {
        engine.prefill(&prompt).unwrap().0.len()
    });
    println!("{}", r.report());
    let r = bench_fn("engine: generate 15 tokens", 1, 10, 2.0, || {
        engine.generate(&prompt, 15).unwrap().0.len()
    });
    println!("{}", r.report());
    let toks_per_sec = 15.0 / (r.mean_ns * 1e-9);
    println!("  → {toks_per_sec:.0} tok/s end-to-end (prefill amortized)");
}

fn main() {
    println!("=== §Perf hot-path microbenchmarks ===\n");
    bench_event_queue();
    bench_compute_node();
    bench_mac_slot();
    bench_tandem_mc();
    bench_analytic();
    bench_full_sls();
    bench_engine();
}
