//! Population-scaling benchmark — the active-set scheduler's headline.
//!
//! Sweeps the cell population at **fixed offered load** (20 jobs/s
//! across the cell, near-zero background), so per-slot *activity* is
//! constant while the population grows. Pre-active-set, every slot
//! cost O(population) (candidate scan + PF decay + backlog scan); now
//! it costs O(active). Each population also runs with
//! `MacConfig::dense_scan` — the retained reference path, equivalent
//! to the pre-PR scheduler — so the speedup is measured in-run rather
//! than against a stale baseline. The sweep-runner rows measure the
//! parallel replication harness on the same workload.
//!
//! Results land machine-readable in `BENCH_scale.json`:
//! events/sec vs n_ues for both paths + the active/dense speedup.
//!
//! Run: `cargo bench --bench perf_scale`

use std::fmt::Write as _;
use std::time::Instant;

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::sweep_arrival_rates_threaded;
use icc6g::llm::GpuSpec;
use icc6g::scenario::{
    CellSpec, CellSync, HandoverSpec, MobilitySpec, RoutingPolicy, ScenarioBuilder,
    TopologySpec, WorkloadClass,
};
use icc6g::sim::Sls;

struct ScaleRow {
    n_ues: u32,
    mode: &'static str,
    events: u64,
    wall_s: f64,
    events_per_sec: f64,
    jobs: u64,
}

/// The scheme under test, resolved through the same shared preset
/// helper the sweep CLI uses (`SchemeConfig::select`), so the bench
/// and the CLI cannot drift apart in how presets are constructed.
fn bench_scheme() -> SchemeConfig {
    SchemeConfig::select("icc")
        .expect("'icc' must be a known preset")
        .remove(0)
}

/// Fixed-offered-load config: 20 jobs/s across the cell regardless of
/// population, background throttled to ~1 packet/UE/hour so activity
/// is driven by jobs alone (the "1% job-active fraction" regime).
fn scale_cfg(n_ues: u32, dense: bool) -> SimConfig {
    let mut cfg = SimConfig::table1().with_scheme(bench_scheme());
    cfg.n_ues = n_ues;
    cfg.job_traffic.rate_per_ue = 20.0 / n_ues as f64;
    cfg.background.rate_bps = 1.0; // 500 B packets ≈ 1 per 67 min
    cfg.horizon = 2.0;
    cfg.warmup = 0.2;
    cfg.mac.dense_scan = dense;
    cfg
}

fn run_scale(n_ues: u32, dense: bool) -> ScaleRow {
    let cfg = scale_cfg(n_ues, dense);
    // one warmup run, then the timed run
    let _ = Sls::new(cfg.clone()).run();
    let t0 = Instant::now();
    let res = Sls::new(cfg).run();
    let wall = t0.elapsed().as_secs_f64();
    ScaleRow {
        n_ues,
        mode: if dense { "dense" } else { "active_set" },
        events: res.events,
        wall_s: wall,
        events_per_sec: res.events as f64 / wall.max(1e-12),
        jobs: res.report.n_jobs,
    }
}

fn main() {
    println!("=== §Perf population-scaling benchmark (fixed 20 jobs/s offered) ===\n");
    let mut rows: Vec<ScaleRow> = Vec::new();
    let mut speedups: Vec<(u32, f64)> = Vec::new();
    for n_ues in [100u32, 1_000, 10_000] {
        let active = run_scale(n_ues, false);
        let dense = run_scale(n_ues, true);
        let speedup = active.events_per_sec / dense.events_per_sec.max(1e-12);
        println!(
            "{:>6} UEs  active-set {:>12.0} ev/s ({} jobs)   dense {:>12.0} ev/s   speedup {:>6.1}x",
            n_ues, active.events_per_sec, active.jobs, dense.events_per_sec, speedup
        );
        assert_eq!(
            active.jobs, dense.jobs,
            "active-set and dense runs diverged at {n_ues} UEs"
        );
        speedups.push((n_ues, speedup));
        rows.push(active);
        rows.push(dense);
    }

    // Coupled-radio row: the same fixed offered load sharded over 4
    // hex cells with geometry-driven interference, 30 m/s UEs and A3
    // handover — the batched slot-SINR pipeline's headline workload.
    let coupled_json = {
        let n_ues_total = 1_000u32;
        let run = || {
            let mut b = ScenarioBuilder::new()
                .scheme(bench_scheme())
                .horizon(2.0)
                .warmup(0.2)
                .seed(1)
                .routing(RoutingPolicy::CellAffinity { spill_queue: 8 })
                .workload(
                    WorkloadClass::translation().with_rate(20.0 / n_ues_total as f64),
                )
                .topology(TopologySpec::hex(400.0))
                .mobility(MobilitySpec::fixed(30.0))
                .handover(HandoverSpec::default());
            for _ in 0..4 {
                b = b
                    .cell(CellSpec::new(n_ues_total / 4))
                    .node(GpuSpec::gh200_nvl2(), 1);
            }
            b.build().run()
        };
        let _ = run(); // warmup
        let t0 = Instant::now();
        let res = run();
        let wall = t0.elapsed().as_secs_f64();
        let eps = res.events as f64 / wall.max(1e-12);
        println!(
            "coupled-radio {:>6} UEs / 4 cells  {:>12.0} ev/s ({} jobs, {} handovers)",
            n_ues_total,
            eps,
            res.report.n_jobs,
            res.report.radio.iter().map(|r| r.handovers_out).sum::<u64>(),
        );
        format!(
            ",\n  {{\"name\": \"coupled_radio\", \"n_ues\": {n_ues_total}, \"events\": {}, \
             \"jobs\": {}, \"wall_s\": {wall:.4}, \"events_per_sec\": {eps:.1}}}",
            res.events, res.report.n_jobs,
        )
    };

    // Multi-model row: the zoo-routing + shared-prefix admission hot
    // path — two classes with quality floors over two batching nodes
    // hosting different model tiers, bulk jobs declaring a shared
    // prefix. Gated via `scale/multi_model/...` so the RouteCtx model
    // views and prefix-block bookkeeping cannot silently slow the loop.
    let multi_model_json = {
        use icc6g::llm::ModelSpec;
        use icc6g::scenario::ExecutionModel;
        let n_ues_total = 600u32;
        let run = || {
            ScenarioBuilder::new()
                .scheme(bench_scheme())
                .horizon(2.0)
                .warmup(0.2)
                .seed(1)
                .routing(RoutingPolicy::ClassAffinity)
                .workload(
                    WorkloadClass::chat()
                        .with_rate(10.0 / n_ues_total as f64)
                        .with_models(&["70b"]),
                )
                .workload(
                    WorkloadClass::translation()
                        .with_rate(10.0 / n_ues_total as f64)
                        .with_models(&["7b", "70b"])
                        .with_prefix_tokens(8),
                )
                .cell(CellSpec::new(n_ues_total))
                .model(ModelSpec::llama_70b().with_resident_bytes(140e9))
                .model(ModelSpec::llama_7b().with_resident_bytes(14e9))
                .node_exec(
                    GpuSpec::gh200_nvl2().scaled(2.0),
                    1,
                    ExecutionModel::ContinuousBatching { max_batch: 32, kv_budget: 80e9 },
                )
                .node_models(&["70b"])
                .node_exec(
                    GpuSpec::gh200_nvl2().scaled(2.0),
                    1,
                    ExecutionModel::ContinuousBatching { max_batch: 32, kv_budget: 80e9 },
                )
                .node_models(&["7b"])
                .build()
                .run()
        };
        let _ = run(); // warmup
        let t0 = Instant::now();
        let res = run();
        let wall = t0.elapsed().as_secs_f64();
        let eps = res.events as f64 / wall.max(1e-12);
        println!(
            "multi-model   {:>6} UEs / 2 classes x 2 tiers {:>12.0} ev/s ({} jobs)",
            n_ues_total, eps, res.report.n_jobs,
        );
        format!(
            ",\n  {{\"name\": \"multi_model\", \"n_ues\": {n_ues_total}, \"events\": {}, \
             \"jobs\": {}, \"wall_s\": {wall:.4}, \"events_per_sec\": {eps:.1}}}",
            res.events, res.report.n_jobs,
        )
    };

    // Conservative-PDES rows: the coupled-radio pipeline sharded over
    // 16 and 64 hex cells with mobility + handover, stepped on all
    // cores under the frontier scheduler vs the legacy per-slot
    // barrier pool. Both protocols are bit-identical to serial, so
    // their event counts must agree — asserted here, gated in CI via
    // the `scale/pdes/...` baseline floors.
    let pdes_json = {
        let mut js = String::new();
        for (n_cells, ues_per_cell, horizon) in [(16usize, 32u32, 2.0f64), (64, 8, 1.0)] {
            let run = |sync: CellSync| {
                let n_ues_total = n_cells as u32 * ues_per_cell;
                let mut b = ScenarioBuilder::new()
                    .scheme(bench_scheme())
                    .horizon(horizon)
                    .warmup(0.2)
                    .seed(1)
                    .threads(0)
                    .cell_sync(sync)
                    .routing(RoutingPolicy::CellAffinity { spill_queue: 8 })
                    .workload(
                        WorkloadClass::translation().with_rate(20.0 / n_ues_total as f64),
                    )
                    .topology(TopologySpec::hex(400.0))
                    .mobility(MobilitySpec::fixed(30.0))
                    .handover(HandoverSpec::default())
                    .node(GpuSpec::gh200_nvl2().scaled(4.0), 2);
                for _ in 0..n_cells {
                    b = b.cell(CellSpec::new(ues_per_cell));
                }
                b.build().run()
            };
            let mut events = [0u64; 2];
            for (i, (sync, label)) in
                [(CellSync::Frontier, "frontier"), (CellSync::Barrier, "barrier")]
                    .into_iter()
                    .enumerate()
            {
                let _ = run(sync); // warmup
                let t0 = Instant::now();
                let res = run(sync);
                let wall = t0.elapsed().as_secs_f64();
                let eps = res.events as f64 / wall.max(1e-12);
                events[i] = res.events;
                println!(
                    "pdes {label:>8}  {n_cells:>3} cells x {ues_per_cell:>3} UEs  \
                     {eps:>12.0} ev/s ({} jobs)",
                    res.report.n_jobs
                );
                let _ = write!(
                    js,
                    ",\n  {{\"name\": \"pdes\", \"cells\": {n_cells}, \"sync\": \"{label}\", \
                     \"events\": {}, \"jobs\": {}, \"wall_s\": {wall:.4}, \
                     \"events_per_sec\": {eps:.1}}}",
                    res.events, res.report.n_jobs,
                );
            }
            assert_eq!(
                events[0], events[1],
                "frontier and barrier diverged at {n_cells} cells"
            );
        }
        js
    };

    // Hybrid-fidelity rows (DESIGN.md §15): a 128-cell hex grid with
    // the center site + ring 1 kept per-UE and the far rings fluid,
    // against the all-per-UE dense run at equal cell count.
    // `equiv_events_per_sec` divides the dense run's event count by the
    // hybrid wall clock — the throughput an equally-faithful dense run
    // would need — and `speedup_vs_dense` is the machine-independent
    // wall ratio, asserted >= 3x here and floored in the baseline. The
    // 256-cell row is hybrid-only (the dense reference gets too slow to
    // re-run per gate) and floors raw hybrid events/s.
    let fluid_json = {
        use icc6g::scenario::FluidSpec;
        let run = |n_cells: usize, fluid: bool| {
            let ues_per_cell = 8u32;
            let n_ues_total = n_cells as u32 * ues_per_cell;
            let mut b = ScenarioBuilder::new()
                .scheme(bench_scheme())
                .horizon(1.0)
                .warmup(0.2)
                .seed(1)
                .threads(0)
                .cell_sync(CellSync::Frontier)
                .routing(RoutingPolicy::LeastLoaded)
                .workload(
                    WorkloadClass::translation().with_rate(20.0 / n_ues_total as f64),
                )
                .topology(TopologySpec::hex(400.0))
                .node(GpuSpec::gh200_nvl2().scaled(4.0), 2);
            for _ in 0..n_cells {
                b = b.cell(CellSpec::new(ues_per_cell));
            }
            if fluid {
                b = b.fluid(FluidSpec { focus: vec![0], rings: 1, ..Default::default() });
            }
            b.build().run()
        };
        let mut js = String::new();
        let time = |n_cells: usize, fluid: bool| {
            let _ = run(n_cells, fluid); // warmup
            let t0 = Instant::now();
            let res = run(n_cells, fluid);
            (res, t0.elapsed().as_secs_f64())
        };
        let (dense, dense_wall) = time(128, false);
        let (hybrid, wall) = time(128, true);
        let n_fluid = hybrid.fluid.as_ref().map_or(0, |f| f.cells.len());
        assert!(n_fluid > 100, "expected a fluid far ring, got {n_fluid} cells");
        let eps = hybrid.events as f64 / wall.max(1e-12);
        let eeps = dense.events as f64 / wall.max(1e-12);
        let speedup = dense_wall / wall.max(1e-12);
        println!(
            "fluid hybrid  128 cells ({n_fluid} fluid)  {eps:>12.0} ev/s  \
             equiv {eeps:>12.0} ev/s  speedup {speedup:>6.1}x vs dense"
        );
        assert!(
            speedup >= 3.0,
            "hybrid tier must be >= 3x faster than dense at 128 cells, got {speedup:.2}x"
        );
        let _ = write!(
            js,
            ",\n  {{\"name\": \"fluid\", \"cells\": 128, \"events\": {}, \"jobs\": {}, \
             \"wall_s\": {wall:.4}, \"events_per_sec\": {eps:.1}, \"dense_events\": {}, \
             \"dense_wall_s\": {dense_wall:.4}, \"equiv_events_per_sec\": {eeps:.1}, \
             \"speedup_vs_dense\": {speedup:.2}}}",
            hybrid.events, hybrid.report.n_jobs, dense.events,
        );
        let (big, wall) = time(256, true);
        let eps = big.events as f64 / wall.max(1e-12);
        println!("fluid hybrid  256 cells  {eps:>12.0} ev/s ({} jobs)", big.report.n_jobs);
        let _ = write!(
            js,
            ",\n  {{\"name\": \"fluid\", \"cells\": 256, \"events\": {}, \"jobs\": {}, \
             \"wall_s\": {wall:.4}, \"events_per_sec\": {eps:.1}}}",
            big.events, big.report.n_jobs,
        );
        js
    };

    // Warm-start sweep: one shared warm-up segment per seed vs
    // re-simulating it at every rate point. Warm-up-heavy grid (4 s
    // warm-up of a 5 s horizon, 6 rate points), serial so the wall
    // ratio is a clean per-run comparison. The rate steps at the
    // warm-up boundary, so WarmStart::Exact applies and the bench
    // doubles as an end-to-end bit-identity check.
    let warm_json = {
        use icc6g::sweep::{sweep_grid, sweep_grid_warm, WarmStart};
        let (warm_s, xs) = (4.0f64, [0.05, 0.075, 0.1, 0.125, 0.15, 0.175]);
        let seeds = [1u64, 1001];
        let make = |x: f64, seed: u64| {
            ScenarioBuilder::new()
                .scheme(bench_scheme())
                .horizon(5.0)
                .warmup(0.5)
                .seed(seed)
                .workload(
                    WorkloadClass::translation().with_rate(0.05).with_rate_phase(warm_s, x),
                )
                .cell(CellSpec::new(200))
                .node(GpuSpec::gh200_nvl2(), 1)
                .build()
        };
        let t0 = Instant::now();
        let cold = sweep_grid(&xs, &seeds, 1, |x, s| make(x, s).run().report);
        let cold_wall = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let warm = sweep_grid_warm(&xs, &seeds, warm_s, 1, WarmStart::Exact, make);
        let warm_wall = t0.elapsed().as_secs_f64();
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(
                c.report.to_json(),
                w.report.to_json(),
                "warm sweep diverged from cold at x = {}",
                c.x
            );
        }
        let speedup = cold_wall / warm_wall.max(1e-12);
        println!(
            "sweep warm-start: {} points x {} seeds  cold {cold_wall:.2} s  \
             warm {warm_wall:.2} s  speedup {speedup:.1}x",
            xs.len(),
            seeds.len(),
        );
        format!(
            ",\n  {{\"name\": \"sweep_warm\", \"points\": {}, \"seeds\": {}, \
             \"cold_wall_s\": {cold_wall:.4}, \"warm_wall_s\": {warm_wall:.4}, \
             \"speedup\": {speedup:.2}}}",
            xs.len(),
            seeds.len(),
        )
    };

    // Parallel sweep harness on the same fixed-load workload.
    let base = scale_cfg(1_000, false);
    let scheme = bench_scheme();
    let rates = [10.0, 20.0, 40.0, 60.0];
    let mut sweep_json = String::new();
    for (label, threads) in [("serial", 1usize), ("parallel", 0usize)] {
        let t0 = Instant::now();
        let pts = sweep_arrival_rates_threaded(&base, &scheme, &rates, 3, threads);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "sweep {label:>8}: {} points x 3 seeds in {wall:.2} s",
            pts.len()
        );
        let _ = write!(
            sweep_json,
            ",\n  {{\"name\": \"sweep_{label}\", \"points\": {}, \"seeds\": 3, \"wall_s\": {wall:.4}}}",
            pts.len()
        );
    }

    let mut js = String::from("[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            js.push(',');
        }
        let _ = write!(
            js,
            "\n  {{\"name\": \"sls_scale\", \"n_ues\": {}, \"mode\": \"{}\", \"events\": {}, \
             \"jobs\": {}, \"wall_s\": {:.4}, \"events_per_sec\": {:.1}}}",
            r.n_ues, r.mode, r.events, r.jobs, r.wall_s, r.events_per_sec
        );
    }
    for (n_ues, s) in &speedups {
        let _ = write!(
            js,
            ",\n  {{\"name\": \"speedup_vs_dense\", \"n_ues\": {n_ues}, \"speedup\": {s:.2}}}"
        );
    }
    js.push_str(&coupled_json);
    js.push_str(&multi_model_json);
    js.push_str(&pdes_json);
    js.push_str(&fluid_json);
    js.push_str(&warm_json);
    js.push_str(&sweep_json);
    js.push_str("\n]\n");
    match std::fs::write("BENCH_scale.json", &js) {
        Ok(()) => println!("\nwrote BENCH_scale.json ({} scale rows)", rows.len()),
        Err(e) => eprintln!("\ncannot write BENCH_scale.json: {e}"),
    }
}
