//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * wireline-latency sweep (where does "move compute closer" stop
//!   paying?) — extends Fig 6's 5 vs 20 ms comparison,
//! * disjoint budget-split sweep (is the paper's 24/56 split a good
//!   one?),
//! * SR-period sensitivity (the MAC grant-cycle modeling knob),
//! * scheduler policy (PF vs RR),
//! * priority-scheme decomposition (packet prio vs deadline queue),
//! * execution model (sequential vs continuous batching at several
//!   batch caps on a saturated node).
//!
//! Run: `cargo bench --bench ablations`

use icc6g::config::{Deployment, Management, SchemeConfig, SimConfig};
use icc6g::coordinator::{capacity_from_curve, sweep_arrival_rates};
use icc6g::llm::GpuSpec;
use icc6g::mac::SchedulingPolicy;
use icc6g::queueing::analytic::{disjoint_satisfaction, SystemParams};
use icc6g::queueing::{service_capacity, Scheme};
use icc6g::scenario::{ExecutionModel, ScenarioBuilder, WorkloadClass};
use icc6g::sim::Sls;
use icc6g::util::bench::{cell, Table};

fn base() -> SimConfig {
    let mut c = SimConfig::table1();
    c.horizon = 12.0;
    c.warmup = 1.5;
    c
}

/// Capacity of an arbitrary scheme config over a coarse rate grid.
fn capacity(schm: SchemeConfig, mutate: impl Fn(&mut SimConfig)) -> f64 {
    let rates: Vec<f64> = (2..=11).map(|i| 10.0 * i as f64).collect();
    let mut b = base();
    mutate(&mut b);
    let pts = sweep_arrival_rates(&b, &schm, &rates, 2);
    capacity_from_curve(&pts, 0.95)
}

fn ablate_wireline() {
    let mut t = Table::new(
        "Ablation A — wireline latency sweep (joint mgmt + priority)",
        &["t_wireline_ms", "capacity (prompts/s)"],
    );
    for (dep, ms) in [
        (Deployment::Ran, 5.0),
        (Deployment::Mec, 20.0),
        (Deployment::Cloud, 50.0),
    ] {
        let schm = SchemeConfig::builder()
            .name("joint+prio")
            .deployment(dep)
            .management(Management::Joint)
            .priority(true)
            .build();
        t.row(&[cell(ms, 0), cell(capacity(schm, |_| {}), 1)]);
    }
    t.print();
    t.write_csv("ablation_wireline.csv").expect("csv");
}

fn ablate_budget_split() {
    // Analytic: the 24/56 split vs alternatives, at the paper's rates.
    let p = SystemParams::paper();
    let mut t = Table::new(
        "Ablation B — disjoint budget split (analytic capacity, RAN 5ms)",
        &["b_comm_ms", "b_comp_ms", "capacity (jobs/s)"],
    );
    let mut best = (0.0, 0.0f64);
    for comm_ms in [8.0, 16.0, 24.0, 32.0, 40.0] {
        let bc = comm_ms / 1e3;
        let cap = service_capacity(
            |l| disjoint_satisfaction(&p, l, 0.005, bc, p.b_total - bc),
            0.95,
            p.stability_limit() - 1e-6,
            1e-6,
        )
        .lambda_star;
        if cap > best.1 {
            best = (comm_ms, cap);
        }
        t.row(&[cell(comm_ms, 0), cell(80.0 - comm_ms, 0), cell(cap, 2)]);
    }
    // joint as the upper bound
    let joint = service_capacity(
        |l| icc6g::queueing::analytic::scheme_satisfaction(&p, &Scheme::icc_joint_ran(), l),
        0.95,
        p.stability_limit() - 1e-6,
        1e-6,
    )
    .lambda_star;
    t.row(&["joint".into(), "joint".into(), cell(joint, 2)]);
    t.print();
    t.write_csv("ablation_budget_split.csv").expect("csv");
    println!(
        "best static split ({} ms comm) still {:.0}% below joint",
        best.0,
        (1.0 - best.1 / joint) * 100.0
    );
}

fn ablate_sr_period() {
    // The shared-PUCCH scaling term dominates the floor period, so the
    // meaningful knob is slots-per-UE. Swept for the MEC baseline,
    // whose 4 ms effective comm budget makes it the sensitive scheme.
    let mut t = Table::new(
        "Ablation C — SR dimensioning sensitivity (capacity, MEC vs ICC)",
        &["sr_slots_per_ue", "MEC capacity", "ICC capacity"],
    );
    for per_ue in [0.0, 0.125, 0.25, 0.5, 1.0] {
        let mec = capacity(SchemeConfig::mec(), |c| {
            c.mac.sr_slots_per_ue = per_ue;
        });
        let icc = capacity(SchemeConfig::icc(), |c| {
            c.mac.sr_slots_per_ue = per_ue;
        });
        t.row(&[cell(per_ue, 3), cell(mec, 1), cell(icc, 1)]);
    }
    t.print();
    t.write_csv("ablation_sr_period.csv").expect("csv");
    println!("(ICC is insensitive — its dedicated job-SR bypasses the shared cycle)");
}

fn ablate_scheduler_policy() {
    let mut t = Table::new(
        "Ablation D — MAC scheduler policy (ICC)",
        &["policy", "capacity (prompts/s)"],
    );
    for (name, pol) in [
        ("proportional-fair", SchedulingPolicy::ProportionalFair),
        ("round-robin", SchedulingPolicy::RoundRobin),
    ] {
        let cap = capacity(SchemeConfig::icc(), |c| {
            c.mac.policy = pol;
        });
        t.row(&[name.to_string(), cell(cap, 1)]);
    }
    t.print();
    t.write_csv("ablation_scheduler.csv").expect("csv");
}

fn ablate_priority_components() {
    let mut t = Table::new(
        "Ablation E — priority-scheme decomposition (90 prompts/s, joint RAN)",
        &["packet_prio", "deadline_queue", "satisfaction", "dropped"],
    );
    for (pkt, queue) in [(false, false), (true, false), (false, true), (true, true)] {
        let mut cfg = base();
        cfg.n_ues = 90;
        cfg.scheme = SchemeConfig::builder()
            .name("custom")
            .deployment(Deployment::Ran)
            .management(Management::Joint)
            .priority(queue)
            .build();
        cfg.mac.job_priority = pkt;
        cfg.seed = 21;
        let r = Sls::new(cfg).run().report;
        t.row(&[
            pkt.to_string(),
            queue.to_string(),
            cell(r.satisfaction_rate(), 4),
            r.n_dropped.to_string(),
        ]);
    }
    t.print();
    t.write_csv("ablation_components.csv").expect("csv");
}

fn ablate_execution_model() {
    // One saturated A100 (sequential service ≈ 110 ms/job, so 40
    // offered jobs/s is far beyond sequential capacity): sweep the
    // continuous-batching cap and watch throughput and TTFT/TPOT
    // tails. Past the saturation batch (~153 for Llama-7B on A100)
    // decode turns compute-bound and extra slots stop paying.
    let mut t = Table::new(
        "Ablation F — execution model on a saturated A100 (40 jobs/s offered, 0.5s budget)",
        &["execution", "completed", "satisfaction", "ttft_p95_ms", "tpot_p95_ms"],
    );
    let configs = [
        ("sequential", ExecutionModel::Sequential),
        ("batch 4", ExecutionModel::ContinuousBatching { max_batch: 4, kv_budget: 0.0 }),
        ("batch 16", ExecutionModel::ContinuousBatching { max_batch: 16, kv_budget: 0.0 }),
        ("batch 64", ExecutionModel::ContinuousBatching { max_batch: 64, kv_budget: 0.0 }),
        ("batch 256", ExecutionModel::ContinuousBatching { max_batch: 256, kv_budget: 0.0 }),
    ];
    for (label, exec) in configs {
        let res = ScenarioBuilder::new()
            .scheme(
                SchemeConfig::builder()
                    .name("joint RAN")
                    .deployment(Deployment::Ran)
                    .management(Management::Joint)
                    .build(),
            )
            .n_ues(40)
            .horizon(10.0)
            .warmup(1.0)
            .seed(11)
            .workload(WorkloadClass::translation().with_budget(0.5))
            .node_exec(GpuSpec::a100(), 1, exec)
            .build()
            .run();
        let c = &res.report.per_class[0];
        t.row(&[
            label.to_string(),
            c.comp.count().to_string(),
            cell(c.satisfaction_rate(), 4),
            cell(c.ttft_percentile(95.0) * 1e3, 1),
            cell(c.tpot_percentile(95.0) * 1e3, 3),
        ]);
    }
    t.print();
    t.write_csv("ablation_execution_model.csv").expect("csv");
    println!("(completed = jobs served in the measured window; sequential queues unboundedly)");
}

fn main() {
    let t0 = std::time::Instant::now();
    ablate_wireline();
    ablate_budget_split();
    ablate_sr_period();
    ablate_scheduler_policy();
    ablate_priority_components();
    ablate_execution_model();
    println!("\nablation suite wall: {:.1}s", t0.elapsed().as_secs_f64());
}
