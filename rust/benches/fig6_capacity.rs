//! Bench target regenerating **Fig 6** (paper §IV-C): SLS
//! job-satisfaction + average latency bars vs prompt arrival rate for
//! the three schemes, plus the α = 95% service capacities and the
//! +60% headline.
//!
//! Run: `cargo bench --bench fig6_capacity`
//! (≈ 1 min: 12 rates × 3 schemes × 3 seeds × 20 s simulated)

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::{capacity_from_curve, sweep_arrival_rates};
use icc6g::util::bench::{cell, Table};

fn main() {
    let mut base = SimConfig::table1();
    base.horizon = 20.0;
    base.warmup = 3.0;
    let seeds = 4;
    let alpha = 0.95;
    // 5-prompt/s grid resolves the α-crossings; the paper's plot uses
    // a similar resolution (10..120 prompts/s).
    let rates: Vec<f64> = (2..=24).map(|i| 5.0 * i as f64).collect();
    let schemes = SchemeConfig::fig6_schemes();

    let t0 = std::time::Instant::now();
    let mut curves = Table::new(
        "Fig 6 — SLS satisfaction + latency bars vs prompt arrival rate",
        &["rate", "scheme", "satisfaction", "avg_comm_ms", "avg_comp_ms"],
    );
    let mut caps = Vec::new();
    let mut total_jobs = 0u64;
    for scheme in &schemes {
        let pts = sweep_arrival_rates(&base, scheme, &rates, seeds);
        for p in &pts {
            curves.row(&[
                cell(p.x, 0),
                scheme.name.clone(),
                cell(p.satisfaction, 4),
                cell(p.avg_comm_ms, 2),
                cell(p.avg_comp_ms, 2),
            ]);
            total_jobs += (p.x * (base.horizon - base.warmup) * seeds as f64) as u64;
        }
        caps.push((scheme.name.clone(), capacity_from_curve(&pts, alpha)));
    }
    let wall = t0.elapsed().as_secs_f64();
    curves.print();
    curves.write_csv("fig6_curves.csv").expect("csv");

    let mut cap_t = Table::new(
        "Fig 6 — service capacity at α=0.95 (paper: ICC 80, MEC 50, +60%)",
        &["scheme", "capacity (prompts/s)", "vs MEC"],
    );
    let mec = caps[2].1;
    for (name, c) in &caps {
        cap_t.row(&[
            name.to_string(),
            cell(*c, 1),
            format!("{:+.1}%", (c / mec - 1.0) * 100.0),
        ]);
    }
    cap_t.print();
    cap_t.write_csv("fig6_capacity.csv").expect("csv");

    let icc = caps[0].1;
    println!(
        "\nheadline: ICC {icc:.0} vs MEC {mec:.0} prompts/s = {:+.1}% (paper: +60%)",
        (icc / mec - 1.0) * 100.0
    );
    println!(
        "bench wall: {wall:.1}s for {} scheme-rate points (~{:.0} simulated jobs)",
        rates.len() * 3,
        total_jobs as f64
    );
    assert!(icc > mec, "ICC must beat MEC");
}
