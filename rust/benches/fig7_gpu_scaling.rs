//! Bench target regenerating **Fig 7** (paper §IV-C): SLS
//! job-satisfaction + average tokens/s vs compute-node capacity
//! (×A100) at 60 UEs × 1 prompt/s, plus the minimum capacity meeting
//! α = 95% and the −27% hardware-cost headline.
//!
//! Run: `cargo bench --bench fig7_gpu_scaling`

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::{min_capacity_from_curve, sweep_gpu_capacity};
use icc6g::util::bench::{cell, Table};

fn main() {
    let mut base = SimConfig::table1();
    base.n_ues = 60;
    base.horizon = 20.0;
    base.warmup = 2.0;
    let seeds = 3;
    let alpha = 0.95;
    let grid: Vec<f64> = (4..=16).map(|i| i as f64).collect();
    let schemes = SchemeConfig::fig6_schemes();

    let t0 = std::time::Instant::now();
    let mut curves = Table::new(
        "Fig 7 — SLS satisfaction + tokens/s vs compute capacity (×A100)",
        &["xA100", "scheme", "satisfaction", "avg_tokens_per_s"],
    );
    let mut mins = Vec::new();
    for scheme in &schemes {
        let pts = sweep_gpu_capacity(&base, scheme, &grid, seeds);
        for p in &pts {
            curves.row(&[
                cell(p.x, 0),
                scheme.name.clone(),
                cell(p.satisfaction, 4),
                cell(p.avg_tokens_per_sec, 1),
            ]);
        }
        mins.push((scheme.name.clone(), min_capacity_from_curve(&pts, alpha)));
    }
    let wall = t0.elapsed().as_secs_f64();
    curves.print();
    curves.write_csv("fig7_curves.csv").expect("csv");

    let mut m = Table::new(
        "Fig 7 — min ×A100 for α=0.95 (paper: ICC 8, disjoint-RAN 11, −27%)",
        &["scheme", "min xA100"],
    );
    for (name, v) in &mins {
        m.row(&[
            name.to_string(),
            v.map(|x| cell(x, 1)).unwrap_or_else(|| "not reached".into()),
        ]);
    }
    m.print();
    m.write_csv("fig7_capacity.csv").expect("csv");

    let icc = mins[0].1.expect("ICC must reach the SLO");
    let best_disjoint = mins[1].1.or(mins[2].1);
    if let Some(d) = best_disjoint {
        println!(
            "\nheadline: ICC {icc:.1} vs best-disjoint {d:.1} ×A100 = −{:.0}% hardware (paper: −27%)",
            (1.0 - icc / d) * 100.0
        );
        assert!(icc < d, "ICC must need less compute");
    }
    println!("bench wall: {wall:.1}s for {} scheme-capacity points", grid.len() * 3);
}
