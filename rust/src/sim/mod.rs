//! The end-to-end system-level simulator (paper §IV, Fig 5).
//!
//! Composes every substrate into the pipeline of Fig 5:
//!
//! ```text
//! UE job gen ──► RLC buffers ──► slot scheduler (PHY/MAC) ──► gNB
//!      │              ▲                                        │
//!  background ────────┘                         wireline (RAN/MEC)
//!                                                              ▼
//!                outcome records ◄── LLM service ◄── compute queue
//! ```
//!
//! Jobs arrive per-UE as Poisson processes; prompts become RLC SDUs
//! contending with background traffic for uplink PRBs; delivered
//! prompts cross the wireline constant and queue at the computing node
//! whose service time comes from the roofline model (Eqs 7–8). The
//! scheme configuration decides packet prioritization, the queue
//! discipline + drop rule, and how satisfaction is judged.

use crate::compute::{ComputeJob, ComputeNode, Discipline, NodeEvent};
use crate::config::{Management, SchemeConfig, SimConfig};
use crate::dess::EventQueue;
use crate::llm::CostModel;
use crate::mac::{Sdu, SduKind, UeMac, UlScheduler};
use crate::metrics::{JobFate, JobOutcome, LatencyManagement, SimReport};
use crate::phy::channel::LargeScale;
use crate::rng::Rng;

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// MAC slot boundary.
    Slot,
    /// Translation job generated at UE `ue`.
    JobArrival { ue: usize },
    /// Background packet at UE `ue`.
    BgArrival { ue: usize },
    /// Prompt fully received at gNB crossed the wireline.
    ComputeEnqueue { job: u64 },
    /// A compute server finished `job`.
    ComputeDone { job: u64 },
}

#[derive(Debug, Clone, Copy)]
struct JobState {
    t_gen: f64,
    /// Set when the last prompt byte reaches the gNB.
    t_comm: Option<f64>,
    /// Set when service starts / job enters node queue.
    t_node_arrival: Option<f64>,
    t_service_start: Option<f64>,
    fate: JobFate,
    /// Counted in metrics (generated after warmup)?
    measured: bool,
}

/// The composed simulator.
pub struct Sls {
    cfg: SimConfig,
    scheduler: UlScheduler,
    node: ComputeNode,
    /// Roofline model (kept for callers inspecting per-phase costs).
    pub cost: CostModel,
    t_wireline: f64,
    service_time: f64,
}

/// Result of one SLS run.
#[derive(Debug)]
pub struct SlsResult {
    pub outcomes: Vec<JobOutcome>,
    pub report: SimReport,
    /// Simulated events processed (perf counter).
    pub events: u64,
    /// Simulated seconds per wall-clock second (perf counter).
    pub speedup: f64,
}

/// Map a scheme to the node queue discipline.
fn discipline_of(scheme: &SchemeConfig) -> Discipline {
    if scheme.priority_scheme {
        Discipline::DeadlinePriority { drop_hopeless: true }
    } else {
        Discipline::Fifo
    }
}

/// Map a scheme to the satisfaction policy.
pub fn management_of(scheme: &SchemeConfig, b_total: f64) -> LatencyManagement {
    match scheme.management {
        Management::Joint => LatencyManagement::Joint { b_total },
        Management::Disjoint { b_comm, b_comp } => {
            LatencyManagement::Disjoint { b_total, b_comm, b_comp }
        }
    }
}

impl Sls {
    pub fn new(cfg: SimConfig) -> Self {
        let scheduler = UlScheduler::new(cfg.mac, cfg.carrier);
        let node = ComputeNode::new(discipline_of(&cfg.scheme), cfg.n_gpus);
        let cost = CostModel::new(cfg.gpu);
        let service_time = cost.total_latency(&cfg.job);
        let t_wireline = cfg.scheme.deployment.wireline_latency();
        Self { cfg, scheduler, node, cost, t_wireline, service_time }
    }

    /// Deterministic LLM service time used for every job.
    pub fn service_time(&self) -> f64 {
        self.service_time
    }

    /// Run the simulation and aggregate the report.
    pub fn run(mut self) -> SlsResult {
        let wall0 = std::time::Instant::now();
        let cfg = self.cfg.clone();
        let master = cfg.seed;
        let slot_dur = cfg.carrier.slot_duration();

        // Independent randomness per concern.
        let mut rng_drop = Rng::substream(master, 0xD0);
        let mut rng_mac = Rng::substream(master, 0xAC);
        let mut ue_job_rng: Vec<Rng> = (0..cfg.n_ues)
            .map(|i| Rng::substream(master, 0x1000 + i as u64))
            .collect();
        let mut ue_bg_rng: Vec<Rng> = (0..cfg.n_ues)
            .map(|i| Rng::substream(master, 0x2000 + i as u64))
            .collect();

        // Drop UEs in the cell (staggered SR phases).
        let mut ues: Vec<UeMac> = (0..cfg.n_ues)
            .map(|i| {
                UeMac::new(LargeScale::drop(&mut rng_drop, cfg.cell_r_min, cfg.cell_r_max))
                    .with_sr_phase(i as u64)
            })
            .collect();

        let mut jobs: Vec<JobState> = Vec::with_capacity(4096);
        let mut q: EventQueue<Ev> = EventQueue::new();

        // Prime arrival processes + the slot clock.
        for ue in 0..cfg.n_ues as usize {
            let gap = ue_job_rng[ue].exp(cfg.job_traffic.rate_per_ue);
            q.schedule_at(gap, Ev::JobArrival { ue });
            let bg_rate = 1.0 / cfg.background.mean_interval();
            q.schedule_at(ue_bg_rng[ue].exp(bg_rate), Ev::BgArrival { ue });
        }
        q.schedule_at(slot_dur, Ev::Slot);

        let sr_period = cfg.mac.effective_sr_period(cfg.n_ues);
        let sr_proc = cfg.mac.grant_proc_slots;
        let request_bytes = cfg.job_traffic.request_bytes();
        let bg_bytes = cfg.background.packet_bytes;
        let b_total = cfg.job.b_total;
        let drain_horizon = cfg.horizon + 2.0;
        let mut slot_idx: u64 = 0;

        // Node-event plumbing: schedule completions for started jobs,
        // mark drops.
        fn apply_node_events(
            events: Vec<NodeEvent>,
            jobs: &mut [JobState],
            q: &mut EventQueue<Ev>,
            now: f64,
        ) {
            for ev in events {
                match ev {
                    NodeEvent::Started { job, completes_at } => {
                        jobs[job.job_id as usize].t_service_start = Some(now);
                        q.schedule_at(completes_at, Ev::ComputeDone { job: job.job_id });
                    }
                    NodeEvent::Dropped { job } => {
                        jobs[job.job_id as usize].fate = JobFate::Dropped;
                    }
                }
            }
        }

        while let Some(&_t) = q.peek_time().as_ref() {
            if q.peek_time().unwrap() > drain_horizon {
                break;
            }
            let (now, ev) = q.pop().unwrap();
            match ev {
                Ev::JobArrival { ue } => {
                    if now < cfg.horizon {
                        let job_id = jobs.len() as u64;
                        jobs.push(JobState {
                            t_gen: now,
                            t_comm: None,
                            t_node_arrival: None,
                            t_service_start: None,
                            fate: JobFate::InFlight,
                            measured: now >= cfg.warmup,
                        });
                        let arrival_slot = (now / slot_dur) as u64;
                        if cfg.mac.job_priority {
                            // ICC job-aware prioritization: dedicated
                            // SR resource bypasses the shared cycle.
                            ues[ue].note_arrival(arrival_slot, sr_period, sr_proc);
                            ues[ue].note_job_arrival_expedited(arrival_slot, sr_proc);
                        } else {
                            ues[ue].note_arrival(arrival_slot, sr_period, sr_proc);
                        }
                        ues[ue].push_job_sdu(Sdu {
                            kind: SduKind::Job { job_id },
                            total_bytes: request_bytes,
                            bytes_left: request_bytes,
                            t_arrival: now,
                        });
                        let gap = ue_job_rng[ue].exp(cfg.job_traffic.rate_per_ue);
                        q.schedule_in(gap, Ev::JobArrival { ue });
                    }
                }
                Ev::BgArrival { ue } => {
                    if now < cfg.horizon {
                        let arrival_slot = (now / slot_dur) as u64;
                        ues[ue].note_arrival(arrival_slot, sr_period, sr_proc);
                        ues[ue].push_bg_sdu(Sdu {
                            kind: SduKind::Background,
                            total_bytes: bg_bytes,
                            bytes_left: bg_bytes,
                            t_arrival: now,
                        });
                        let bg_rate = 1.0 / cfg.background.mean_interval();
                        q.schedule_in(ue_bg_rng[ue].exp(bg_rate), Ev::BgArrival { ue });
                    }
                }
                Ev::Slot => {
                    let results = self.scheduler.schedule_slot(slot_idx, &mut ues, &mut rng_mac);
                    slot_idx += 1;
                    // TBs land at the end of the slot.
                    let t_rx = now + slot_dur;
                    for r in results {
                        for d in r.delivered {
                            if let SduKind::Job { job_id } = d.kind {
                                let js = &mut jobs[job_id as usize];
                                js.t_comm = Some(t_rx - js.t_gen);
                                q.schedule_at(
                                    t_rx + self.t_wireline,
                                    Ev::ComputeEnqueue { job: job_id },
                                );
                            }
                        }
                    }
                    // Keep the slot clock running while anything is active.
                    let active = now < cfg.horizon
                        || ues.iter().any(|u| u.buffered_bytes() > 0);
                    if active {
                        q.schedule_in(slot_dur, Ev::Slot);
                    }
                }
                Ev::ComputeEnqueue { job } => {
                    let js = &jobs[job as usize];
                    let cj = ComputeJob {
                        job_id: job,
                        t_gen: js.t_gen,
                        t_comm: js.t_comm.expect("enqueue before comm done"),
                        deadline: js.t_gen + b_total,
                        service_time: self.service_time,
                    };
                    jobs[job as usize].t_node_arrival = Some(now);
                    let evs = self.node.enqueue(cj, now);
                    apply_node_events(evs, &mut jobs, &mut q, now);
                }
                Ev::ComputeDone { job } => {
                    jobs[job as usize].fate = JobFate::Completed;
                    // stash completion via service fields (outcome below)
                    let evs = self.node.complete(now);
                    apply_node_events(evs, &mut jobs, &mut q, now);
                }
            }
        }

        // Assemble outcomes for measured jobs.
        let tokens = cfg.job.total_tokens();
        let outcomes: Vec<JobOutcome> = jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.measured)
            .map(|(id, j)| {
                let (t_queue, t_service) = match (j.t_node_arrival, j.t_service_start) {
                    (Some(a), Some(s)) => (s - a, self.service_time),
                    _ => (0.0, 0.0),
                };
                JobOutcome {
                    job_id: id as u64,
                    t_gen: j.t_gen,
                    t_comm: j.t_comm.unwrap_or(0.0),
                    t_wireline: self.t_wireline,
                    t_queue,
                    t_service,
                    tokens,
                    fate: j.fate,
                }
            })
            .collect();

        let policy = management_of(&cfg.scheme, b_total);
        let report = SimReport::from_outcomes(&outcomes, &policy);
        let wall = wall0.elapsed().as_secs_f64();
        SlsResult {
            outcomes,
            report,
            events: 0, // filled by caller-visible counter below
            speedup: if wall > 0.0 { cfg.horizon / wall } else { f64::INFINITY },
        }
    }
}

/// Convenience: run one scheme at a given cell size and return the
/// satisfaction rate + report.
pub fn run_scheme(cfg: &SimConfig, scheme: SchemeConfig, seed: u64) -> SimReport {
    let mut c = cfg.clone().with_scheme(scheme);
    c.seed = seed;
    Sls::new(c).run().report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeConfig;

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::table1();
        c.n_ues = 20;
        c.horizon = 6.0;
        c.warmup = 1.0;
        c
    }

    #[test]
    fn sls_runs_and_completes_jobs() {
        let cfg = small_cfg().with_scheme(SchemeConfig::icc());
        let res = Sls::new(cfg).run();
        assert!(res.report.n_jobs > 50, "n = {}", res.report.n_jobs);
        // At 20 prompts/s the system is uncongested → high satisfaction.
        assert!(
            res.report.satisfaction_rate() > 0.9,
            "sat = {}",
            res.report.satisfaction_rate()
        );
    }

    #[test]
    fn job_count_matches_poisson_mean() {
        let cfg = small_cfg();
        let res = Sls::new(cfg.clone().with_scheme(SchemeConfig::mec())).run();
        // measured window ≈ (horizon - warmup) · n_ues · rate = 100
        let expect = (cfg.horizon - cfg.warmup) * cfg.offered_rate();
        let n = res.report.n_jobs as f64;
        assert!((n / expect - 1.0).abs() < 0.35, "n = {n}, expect ≈ {expect}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg().with_scheme(SchemeConfig::icc());
        let a = Sls::new(cfg.clone()).run();
        let b = Sls::new(cfg).run();
        assert_eq!(a.report.n_jobs, b.report.n_jobs);
        assert_eq!(a.report.n_satisfied, b.report.n_satisfied);
        assert!((a.report.e2e.mean() - b.report.e2e.mean()).abs() < 1e-12);
    }

    #[test]
    fn latency_components_positive_and_ordered() {
        let cfg = small_cfg().with_scheme(SchemeConfig::icc());
        let res = Sls::new(cfg).run();
        let done: Vec<_> = res
            .outcomes
            .iter()
            .filter(|o| o.fate == JobFate::Completed)
            .collect();
        assert!(!done.is_empty());
        for o in done {
            assert!(o.t_comm > 0.0, "comm must include ≥1 slot");
            assert!(o.t_service > 0.0);
            assert!(o.t_queue >= -1e-12);
            assert!(o.e2e() >= o.t_comm + o.t_wireline + o.t_service - 1e-9);
        }
    }

    #[test]
    fn icc_beats_mec_under_load() {
        // At an arrival rate between the two capacities, ICC must hold a
        // higher satisfaction rate than 5G MEC.
        let mut cfg = small_cfg();
        cfg.n_ues = 60; // 60 prompts/s — above MEC capacity, near ICC's
        cfg.horizon = 10.0;
        let icc = run_scheme(&cfg, SchemeConfig::icc(), 3);
        let mec = run_scheme(&cfg, SchemeConfig::mec(), 3);
        assert!(
            icc.satisfaction_rate() > mec.satisfaction_rate(),
            "icc {} vs mec {}",
            icc.satisfaction_rate(),
            mec.satisfaction_rate()
        );
    }

    #[test]
    fn service_time_matches_roofline() {
        let cfg = small_cfg();
        let sls = Sls::new(cfg.clone());
        let m = CostModel::new(cfg.gpu);
        assert!((sls.service_time() - m.total_latency(&cfg.job)).abs() < 1e-15);
    }
}
