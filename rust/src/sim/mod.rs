//! The legacy end-to-end system-level simulator API (paper §IV, Fig 5).
//!
//! [`Sls`] is now a thin wrapper over the composable Scenario API
//! ([`crate::scenario`]): `Sls::new(cfg)` mirrors the [`SimConfig`] as
//! a single-class, single-node scenario whose deterministic roofline
//! service model and fixed token lengths preserve the legacy SLS
//! behavior (same event loop, deterministic per seed). New code should use
//! [`crate::scenario::ScenarioBuilder`] directly; this module keeps
//! the Figs 4/6/7 reproduction path (and its tests) stable.

use crate::config::{SchemeConfig, SimConfig};
use crate::llm::CostModel;
use crate::metrics::{JobOutcome, SimReport};
use crate::scenario::{Scenario, ScenarioBuilder};

pub use crate::scenario::{discipline_of, management_of};

/// The composed simulator (legacy single-scenario facade).
pub struct Sls {
    scenario: Scenario,
    /// Roofline model (kept for callers inspecting per-phase costs).
    pub cost: CostModel,
    service_time: f64,
}

/// Result of one SLS run.
#[derive(Debug)]
pub struct SlsResult {
    pub outcomes: Vec<JobOutcome>,
    pub report: SimReport,
    /// Simulated events processed (perf counter).
    pub events: u64,
    /// Simulated seconds per wall-clock second (perf counter).
    pub speedup: f64,
}

impl Sls {
    pub fn new(cfg: SimConfig) -> Self {
        let cost = CostModel::new(cfg.gpu);
        let service_time = cost.total_latency(&cfg.job);
        let scenario = ScenarioBuilder::from_sim_config(&cfg).build();
        Self { scenario, cost, service_time }
    }

    /// Deterministic LLM service time used for every job.
    pub fn service_time(&self) -> f64 {
        self.service_time
    }

    /// Run the simulation and aggregate the report.
    pub fn run(self) -> SlsResult {
        let r = self.scenario.run();
        SlsResult {
            outcomes: r.outcomes,
            report: r.report,
            events: r.events,
            speedup: r.speedup,
        }
    }
}

/// Convenience: run one scheme at a given cell size and return the
/// satisfaction rate + report.
pub fn run_scheme(cfg: &SimConfig, scheme: SchemeConfig, seed: u64) -> SimReport {
    let mut c = cfg.clone().with_scheme(scheme);
    c.seed = seed;
    Sls::new(c).run().report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeConfig;
    use crate::metrics::JobFate;

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::table1();
        c.n_ues = 20;
        c.horizon = 6.0;
        c.warmup = 1.0;
        c
    }

    #[test]
    fn sls_runs_and_completes_jobs() {
        let cfg = small_cfg().with_scheme(SchemeConfig::icc());
        let res = Sls::new(cfg).run();
        assert!(res.report.n_jobs > 50, "n = {}", res.report.n_jobs);
        // At 20 prompts/s the system is uncongested → high satisfaction.
        assert!(
            res.report.satisfaction_rate() > 0.9,
            "sat = {}",
            res.report.satisfaction_rate()
        );
    }

    #[test]
    fn job_count_matches_poisson_mean() {
        let cfg = small_cfg();
        let res = Sls::new(cfg.clone().with_scheme(SchemeConfig::mec())).run();
        // measured window ≈ (horizon - warmup) · n_ues · rate = 100
        let expect = (cfg.horizon - cfg.warmup) * cfg.offered_rate();
        let n = res.report.n_jobs as f64;
        assert!((n / expect - 1.0).abs() < 0.35, "n = {n}, expect ≈ {expect}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg().with_scheme(SchemeConfig::icc());
        let a = Sls::new(cfg.clone()).run();
        let b = Sls::new(cfg).run();
        assert_eq!(a.report.n_jobs, b.report.n_jobs);
        assert_eq!(a.report.n_satisfied, b.report.n_satisfied);
        assert!((a.report.e2e.mean() - b.report.e2e.mean()).abs() < 1e-12);
    }

    #[test]
    fn latency_components_positive_and_ordered() {
        let cfg = small_cfg().with_scheme(SchemeConfig::icc());
        let res = Sls::new(cfg).run();
        let done: Vec<_> = res
            .outcomes
            .iter()
            .filter(|o| o.fate == JobFate::Completed)
            .collect();
        assert!(!done.is_empty());
        for o in done {
            assert!(o.t_comm > 0.0, "comm must include ≥1 slot");
            assert!(o.t_service > 0.0);
            assert!(o.t_queue >= -1e-12);
            assert!(o.e2e() >= o.t_comm + o.t_wireline + o.t_service - 1e-9);
        }
    }

    #[test]
    fn icc_beats_mec_under_load() {
        // At an arrival rate between the two capacities, ICC must hold a
        // higher satisfaction rate than 5G MEC.
        let mut cfg = small_cfg();
        cfg.n_ues = 60; // 60 prompts/s — above MEC capacity, near ICC's
        cfg.horizon = 10.0;
        let icc = run_scheme(&cfg, SchemeConfig::icc(), 3);
        let mec = run_scheme(&cfg, SchemeConfig::mec(), 3);
        assert!(
            icc.satisfaction_rate() > mec.satisfaction_rate(),
            "icc {} vs mec {}",
            icc.satisfaction_rate(),
            mec.satisfaction_rate()
        );
    }

    #[test]
    fn service_time_matches_roofline() {
        let cfg = small_cfg();
        let sls = Sls::new(cfg.clone());
        let m = CostModel::new(cfg.gpu);
        assert!((sls.service_time() - m.total_latency(&cfg.job)).abs() < 1e-15);
    }

    // The SlsResult.events != 0 regression is covered at the public
    // crate surface in tests/integration_sim.rs.
}
