//! Traffic generation: translation jobs (Poisson per UE) + constant
//! background load (Table I: 0.5 Mbps/UE).
//!
//! Token↔byte mapping: a prompt of `n_tokens` becomes
//! `n_tokens · bytes_per_token + request_overhead` bytes on the air
//! interface (UTF-8 text plus framing/PDCP/IP overhead).

use crate::rng::Rng;

/// Job-traffic parameters.
#[derive(Debug, Clone, Copy)]
pub struct JobTrafficConfig {
    /// Poisson rate per UE (Table I: 1 job/s/UE).
    pub rate_per_ue: f64,
    /// Input prompt size in tokens (Table I: 15).
    pub input_tokens: u32,
    /// Payload bytes per token (UTF-8 text ≈ 4 B/token).
    pub bytes_per_token: u32,
    /// Fixed per-request overhead (JSON framing + IP/PDCP headers).
    pub overhead_bytes: u32,
}

impl Default for JobTrafficConfig {
    fn default() -> Self {
        Self { rate_per_ue: 1.0, input_tokens: 15, bytes_per_token: 4, overhead_bytes: 120 }
    }
}

impl JobTrafficConfig {
    /// Uplink bytes of one translation request.
    pub fn request_bytes(&self) -> u32 {
        self.input_tokens * self.bytes_per_token + self.overhead_bytes
    }
}

/// Background-traffic parameters (constant bit rate, packetized).
#[derive(Debug, Clone, Copy)]
pub struct BackgroundConfig {
    /// Offered load per UE in bits/s (Table I: 0.5 Mbps).
    pub rate_bps: f64,
    /// Packet size in bytes.
    pub packet_bytes: u32,
}

impl Default for BackgroundConfig {
    fn default() -> Self {
        Self { rate_bps: 500_000.0, packet_bytes: 500 }
    }
}

impl BackgroundConfig {
    /// Mean inter-packet gap achieving `rate_bps`.
    pub fn mean_interval(&self) -> f64 {
        (self.packet_bytes as f64 * 8.0) / self.rate_bps
    }
}

/// Poisson process generator: produces the next inter-arrival gap.
#[derive(Debug)]
pub struct PoissonProcess {
    rate: f64,
    rng: Rng,
}

impl PoissonProcess {
    pub fn new(rate: f64, rng: Rng) -> Self {
        assert!(rate > 0.0);
        Self { rate, rng }
    }

    /// Next inter-arrival time (exponential).
    pub fn next_gap(&mut self) -> f64 {
        self.rng.exp(self.rate)
    }
}

/// Poisson-packetized background source: exponential gaps with the CBR
/// mean (mean rate 0.5 Mbps; burstiness exercises the scheduler the
/// way a mix of best-effort apps would).
#[derive(Debug)]
pub struct BackgroundSource {
    cfg: BackgroundConfig,
    rng: Rng,
}

impl BackgroundSource {
    pub fn new(cfg: BackgroundConfig, rng: Rng) -> Self {
        Self { cfg, rng }
    }

    pub fn packet_bytes(&self) -> u32 {
        self.cfg.packet_bytes
    }

    pub fn next_gap(&mut self) -> f64 {
        self.rng.exp(1.0 / self.cfg.mean_interval())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_bytes_table1() {
        let c = JobTrafficConfig::default();
        assert_eq!(c.request_bytes(), 15 * 4 + 120);
    }

    #[test]
    fn background_interval_matches_rate() {
        let c = BackgroundConfig::default();
        // 500 B · 8 / 0.5 Mb/s = 8 ms
        assert!((c.mean_interval() - 0.008).abs() < 1e-12);
    }

    #[test]
    fn poisson_process_rate() {
        let mut p = PoissonProcess::new(5.0, Rng::new(1));
        let n = 100_000;
        let total: f64 = (0..n).map(|_| p.next_gap()).sum();
        let rate = n as f64 / total;
        assert!((rate / 5.0 - 1.0).abs() < 0.02, "rate = {rate}");
    }

    #[test]
    fn background_source_long_run_rate() {
        let cfg = BackgroundConfig::default();
        let mut src = BackgroundSource::new(cfg, Rng::new(2));
        let n = 50_000;
        let span: f64 = (0..n).map(|_| src.next_gap()).sum();
        let bps = (n as f64 * cfg.packet_bytes as f64 * 8.0) / span;
        assert!((bps / cfg.rate_bps - 1.0).abs() < 0.03, "bps = {bps}");
    }
}
