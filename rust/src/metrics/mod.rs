//! Simulation metrics: per-job outcome records and aggregated reports
//! (satisfaction rate, latency breakdowns, tokens/s — the quantities
//! plotted in Figs 6–7).

use crate::util::stats::Welford;

/// Terminal state of one translation job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobFate {
    /// Completed; satisfaction judged by the latency-management policy.
    Completed,
    /// Dropped at the computing node (hopeless deadline).
    Dropped,
    /// Still in flight when the simulation horizon hit (ignored).
    InFlight,
}

/// Full per-job record produced by the SLS.
#[derive(Debug, Clone, Copy)]
pub struct JobOutcome {
    pub job_id: u64,
    /// Generation time at the UE.
    pub t_gen: f64,
    /// UE→BS communication latency (uplink queueing + transmission).
    pub t_comm: f64,
    /// Constant wireline latency BS→node.
    pub t_wireline: f64,
    /// Queueing delay at the computing node.
    pub t_queue: f64,
    /// LLM service time.
    pub t_service: f64,
    /// Total tokens (input + output) — for the tokens/s bar in Fig 7.
    pub tokens: u32,
    pub fate: JobFate,
}

impl JobOutcome {
    /// Computing latency as the paper measures it (queue + service).
    pub fn t_comp(&self) -> f64 {
        self.t_queue + self.t_service
    }

    /// End-to-end latency (Eq 1).
    pub fn e2e(&self) -> f64 {
        self.t_comm + self.t_wireline + self.t_comp()
    }

    /// Tokens per second of this job (Fig 7 bar metric).
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.e2e()
    }
}

/// Latency-management evaluation (paper §III-A definitions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyManagement {
    /// Satisfied iff E2E ≤ b_total.
    Joint { b_total: f64 },
    /// Satisfied iff E2E ≤ b_total AND comm (incl. wireline) ≤ b_comm
    /// AND comp ≤ b_comp.
    Disjoint { b_total: f64, b_comm: f64, b_comp: f64 },
}

impl LatencyManagement {
    pub fn b_total(&self) -> f64 {
        match *self {
            LatencyManagement::Joint { b_total } => b_total,
            LatencyManagement::Disjoint { b_total, .. } => b_total,
        }
    }

    /// Definition 1: is this completed job satisfied?
    pub fn satisfied(&self, j: &JobOutcome) -> bool {
        if j.fate != JobFate::Completed {
            return false;
        }
        match *self {
            LatencyManagement::Joint { b_total } => j.e2e() <= b_total,
            LatencyManagement::Disjoint { b_total, b_comm, b_comp } => {
                j.e2e() <= b_total
                    && j.t_comm + j.t_wireline <= b_comm
                    && j.t_comp() <= b_comp
            }
        }
    }
}

/// Aggregated simulation report.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub n_jobs: u64,
    pub n_satisfied: u64,
    pub n_dropped: u64,
    pub comm: Welford,
    pub comp: Welford,
    pub e2e: Welford,
    pub tokens_per_sec: Welford,
}

impl SimReport {
    pub fn from_outcomes(outcomes: &[JobOutcome], policy: &LatencyManagement) -> Self {
        let mut r = Self {
            n_jobs: 0,
            n_satisfied: 0,
            n_dropped: 0,
            comm: Welford::new(),
            comp: Welford::new(),
            e2e: Welford::new(),
            tokens_per_sec: Welford::new(),
        };
        for j in outcomes {
            match j.fate {
                JobFate::InFlight => continue,
                JobFate::Dropped => {
                    r.n_jobs += 1;
                    r.n_dropped += 1;
                    // comm latency still observed for dropped jobs
                    r.comm.push(j.t_comm);
                }
                JobFate::Completed => {
                    r.n_jobs += 1;
                    if policy.satisfied(j) {
                        r.n_satisfied += 1;
                    }
                    r.comm.push(j.t_comm);
                    r.comp.push(j.t_comp());
                    r.e2e.push(j.e2e());
                    r.tokens_per_sec.push(j.tokens_per_sec());
                }
            }
        }
        r
    }

    /// Fraction of (non-in-flight) jobs satisfied — the Y axis of
    /// Figs 4/6/7.
    pub fn satisfaction_rate(&self) -> f64 {
        if self.n_jobs == 0 {
            f64::NAN
        } else {
            self.n_satisfied as f64 / self.n_jobs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done(t_comm: f64, t_queue: f64, t_service: f64) -> JobOutcome {
        JobOutcome {
            job_id: 0,
            t_gen: 0.0,
            t_comm,
            t_wireline: 0.005,
            t_queue,
            t_service,
            tokens: 30,
            fate: JobFate::Completed,
        }
    }

    #[test]
    fn e2e_composition() {
        let j = done(0.010, 0.020, 0.030);
        assert!((j.e2e() - 0.065).abs() < 1e-12);
        assert!((j.t_comp() - 0.050).abs() < 1e-12);
        assert!((j.tokens_per_sec() - 30.0 / 0.065).abs() < 1e-9);
    }

    #[test]
    fn joint_satisfaction_boundary() {
        let p = LatencyManagement::Joint { b_total: 0.080 };
        assert!(p.satisfied(&done(0.010, 0.030, 0.035))); // 80 ms exactly
        assert!(!p.satisfied(&done(0.010, 0.031, 0.035)));
    }

    #[test]
    fn disjoint_requires_both_budgets() {
        let p = LatencyManagement::Disjoint { b_total: 0.080, b_comm: 0.024, b_comp: 0.056 };
        // comm = 10+5 = 15 <= 24, comp = 50 <= 56, e2e = 65 <= 80 → ok
        assert!(p.satisfied(&done(0.010, 0.020, 0.030)));
        // comm budget violated even though e2e fine
        assert!(!p.satisfied(&done(0.022, 0.010, 0.010)));
        // comp budget violated
        assert!(!p.satisfied(&done(0.005, 0.030, 0.030)));
    }

    #[test]
    fn joint_dominates_disjoint() {
        let joint = LatencyManagement::Joint { b_total: 0.080 };
        let dis = LatencyManagement::Disjoint { b_total: 0.080, b_comm: 0.024, b_comp: 0.056 };
        // a job satisfying disjoint always satisfies joint
        for j in [done(0.01, 0.02, 0.03), done(0.018, 0.03, 0.025), done(0.001, 0.05, 0.005)] {
            if dis.satisfied(&j) {
                assert!(joint.satisfied(&j));
            }
        }
    }

    #[test]
    fn dropped_jobs_count_against_satisfaction() {
        let mut j = done(0.01, 0.0, 0.0);
        j.fate = JobFate::Dropped;
        let outcomes = vec![j, done(0.01, 0.02, 0.03)];
        let r = SimReport::from_outcomes(&outcomes, &LatencyManagement::Joint { b_total: 0.080 });
        assert_eq!(r.n_jobs, 2);
        assert_eq!(r.n_dropped, 1);
        assert_eq!(r.n_satisfied, 1);
        assert!((r.satisfaction_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn in_flight_ignored() {
        let mut j = done(0.01, 0.0, 0.0);
        j.fate = JobFate::InFlight;
        let r = SimReport::from_outcomes(&[j], &LatencyManagement::Joint { b_total: 0.080 });
        assert_eq!(r.n_jobs, 0);
        assert!(r.satisfaction_rate().is_nan());
    }
}
