//! Simulation metrics: per-job outcome records and aggregated reports
//! (satisfaction rate, latency breakdowns, tokens/s — the quantities
//! plotted in Figs 6–7 — plus the serving-level TTFT/TPOT quantities
//! an iteration-level execution model exposes).

use crate::util::stats::{percentile, percentile_sorted, Welford};

/// Terminal state of one translation job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobFate {
    /// Completed; satisfaction judged by the latency-management policy.
    Completed,
    /// Dropped at the computing node (hopeless deadline).
    Dropped,
    /// Evicted from a failed node with its re-dispatch retry budget
    /// exhausted (elastic-cluster runs only) — lost work.
    Lost,
    /// Still in flight when the simulation horizon hit (ignored).
    InFlight,
}

/// Full per-job record produced by the SLS.
#[derive(Debug, Clone, Copy)]
pub struct JobOutcome {
    pub job_id: u64,
    /// Workload class the job belongs to (0 for single-class runs).
    pub class_id: u32,
    /// Zoo model the job was served on; `u32::MAX` when the run had no
    /// model zoo (or the job was never dispatched to a node).
    pub model_id: u32,
    /// Originating cell (gNB) of the job (0 for single-cell runs).
    pub cell_id: u32,
    /// Generation time at the UE.
    pub t_gen: f64,
    /// UE→BS communication latency (uplink queueing + transmission).
    pub t_comm: f64,
    /// Constant wireline latency BS→node.
    pub t_wireline: f64,
    /// Queueing delay at the computing node (arrival → service start).
    pub t_queue: f64,
    /// LLM service time (prefill + decode, as executed — batched
    /// decode stretches this relative to the lone roofline).
    pub t_service: f64,
    /// Time-to-first-token measured from generation at the UE
    /// (comm + wireline + queue + prefill + first decode step).
    /// 0 for non-completed jobs.
    pub ttft: f64,
    /// Time-per-output-token over the decode phase:
    /// `(t_last − t_first) / (N_output − 1)`; 0 when `N_output = 1`
    /// (TPOT is undefined for single-token jobs — reports exclude
    /// these from the TPOT sample set) or the job did not complete.
    pub tpot: f64,
    /// Total tokens (input + output) — for the tokens/s bar in Fig 7.
    pub tokens: u32,
    pub fate: JobFate,
}

impl JobOutcome {
    /// Computing latency as the paper measures it (queue + service).
    pub fn t_comp(&self) -> f64 {
        self.t_queue + self.t_service
    }

    /// End-to-end latency (Eq 1).
    pub fn e2e(&self) -> f64 {
        self.t_comm + self.t_wireline + self.t_comp()
    }

    /// Tokens per second of this job (Fig 7 bar metric).
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.e2e()
    }
}

/// Latency-management evaluation (paper §III-A definitions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyManagement {
    /// Satisfied iff E2E ≤ b_total.
    Joint { b_total: f64 },
    /// Satisfied iff E2E ≤ b_total AND comm (incl. wireline) ≤ b_comm
    /// AND comp ≤ b_comp.
    Disjoint { b_total: f64, b_comm: f64, b_comp: f64 },
}

impl LatencyManagement {
    pub fn b_total(&self) -> f64 {
        match *self {
            LatencyManagement::Joint { b_total } => b_total,
            LatencyManagement::Disjoint { b_total, .. } => b_total,
        }
    }

    /// Definition 1: is this completed job satisfied?
    pub fn satisfied(&self, j: &JobOutcome) -> bool {
        if j.fate != JobFate::Completed {
            return false;
        }
        match *self {
            LatencyManagement::Joint { b_total } => j.e2e() <= b_total,
            LatencyManagement::Disjoint { b_total, b_comm, b_comp } => {
                j.e2e() <= b_total
                    && j.t_comm + j.t_wireline <= b_comm
                    && j.t_comp() <= b_comp
            }
        }
    }
}

/// Per-workload-class slice of a [`SimReport`] (multi-class scenarios;
/// the quantities a per-class SLO would be judged on).
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub name: String,
    pub n_jobs: u64,
    pub n_satisfied: u64,
    pub n_dropped: u64,
    /// Jobs lost to node failures (retry budget exhausted).
    pub n_lost: u64,
    pub comm: Welford,
    pub comp: Welford,
    pub e2e: Welford,
    pub tokens_per_sec: Welford,
    /// Time-to-first-token over completed jobs.
    pub ttft: Welford,
    /// Time-per-output-token over completed jobs with ≥ 2 output
    /// tokens (TPOT is undefined for single-token jobs).
    pub tpot: Welford,
    /// Retained samples for exact percentiles (and exact merging of
    /// replication percentiles — summaries alone cannot merge tails).
    ttft_samples: Vec<f64>,
    tpot_samples: Vec<f64>,
}

impl ClassReport {
    fn new(name: String) -> Self {
        Self {
            name,
            n_jobs: 0,
            n_satisfied: 0,
            n_dropped: 0,
            n_lost: 0,
            comm: Welford::new(),
            comp: Welford::new(),
            e2e: Welford::new(),
            tokens_per_sec: Welford::new(),
            ttft: Welford::new(),
            tpot: Welford::new(),
            ttft_samples: Vec::new(),
            tpot_samples: Vec::new(),
        }
    }

    fn observe(&mut self, j: &JobOutcome, policy: &LatencyManagement) {
        match j.fate {
            JobFate::InFlight => {}
            JobFate::Dropped => {
                self.n_jobs += 1;
                self.n_dropped += 1;
                // comm latency still observed for dropped jobs
                self.comm.push(j.t_comm);
            }
            JobFate::Lost => {
                self.n_jobs += 1;
                self.n_lost += 1;
                // the air interface did its part before the node died
                self.comm.push(j.t_comm);
            }
            JobFate::Completed => {
                self.n_jobs += 1;
                if policy.satisfied(j) {
                    self.n_satisfied += 1;
                }
                self.comm.push(j.t_comm);
                self.comp.push(j.t_comp());
                self.e2e.push(j.e2e());
                self.tokens_per_sec.push(j.tokens_per_sec());
                self.ttft.push(j.ttft);
                self.ttft_samples.push(j.ttft);
                // TPOT is undefined for single-token jobs (marked 0);
                // recording the zeros would deflate means/percentiles
                // for variable-decode-length workloads.
                if j.tpot > 0.0 {
                    self.tpot.push(j.tpot);
                    self.tpot_samples.push(j.tpot);
                }
            }
        }
    }

    pub fn satisfaction_rate(&self) -> f64 {
        if self.n_jobs == 0 {
            f64::NAN
        } else {
            self.n_satisfied as f64 / self.n_jobs as f64
        }
    }

    /// TTFT percentile (`q` in [0, 100]) over completed jobs.
    pub fn ttft_percentile(&self, q: f64) -> f64 {
        percentile(&self.ttft_samples, q)
    }

    /// TPOT percentile (`q` in [0, 100]) over completed multi-token
    /// jobs.
    pub fn tpot_percentile(&self, q: f64) -> f64 {
        percentile(&self.tpot_samples, q)
    }

    /// Several TTFT percentiles with a single sort of the sample set
    /// (use for report rendering; the single-`q` getters re-sort per
    /// call).
    pub fn ttft_percentiles(&self, qs: &[f64]) -> Vec<f64> {
        percentiles_of(&self.ttft_samples, qs)
    }

    /// Several TPOT percentiles with a single sort of the sample set.
    pub fn tpot_percentiles(&self, qs: &[f64]) -> Vec<f64> {
        percentiles_of(&self.tpot_samples, qs)
    }

    /// Retained TTFT samples (one per completed job, arrival order;
    /// replication merges concatenate).
    pub fn ttft_samples(&self) -> &[f64] {
        &self.ttft_samples
    }

    /// Retained TPOT samples (one per completed job, arrival order).
    pub fn tpot_samples(&self) -> &[f64] {
        &self.tpot_samples
    }

    fn merge(&mut self, other: &ClassReport) {
        self.n_jobs += other.n_jobs;
        self.n_satisfied += other.n_satisfied;
        self.n_dropped += other.n_dropped;
        self.n_lost += other.n_lost;
        self.comm.merge(&other.comm);
        self.comp.merge(&other.comp);
        self.e2e.merge(&other.e2e);
        self.tokens_per_sec.merge(&other.tokens_per_sec);
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.ttft_samples.extend_from_slice(&other.ttft_samples);
        self.tpot_samples.extend_from_slice(&other.tpot_samples);
    }
}

/// Per-cell radio-layer statistics of a coupled-radio run: A3
/// handover counts and the interference-over-thermal term the cell's
/// scheduler actually applied, sampled once per stepped slot. Empty
/// for legacy (fixed-margin, static) runs.
#[derive(Debug, Clone)]
pub struct CellRadioReport {
    /// UEs migrated into this cell.
    pub handovers_in: u64,
    /// UEs migrated out of this cell.
    pub handovers_out: u64,
    /// IoT (dB) applied per scheduled slot (mean/min/max via Welford).
    pub iot_db: Welford,
}

impl Default for CellRadioReport {
    fn default() -> Self {
        Self { handovers_in: 0, handovers_out: 0, iot_db: Welford::new() }
    }
}

impl CellRadioReport {
    fn merge(&mut self, other: &CellRadioReport) {
        self.handovers_in += other.handovers_in;
        self.handovers_out += other.handovers_out;
        self.iot_db.merge(&other.iot_db);
    }
}

/// Per-node accounting of an elastic-cluster run: powered time priced
/// through the node's `GpuSpec` TDP/price fields, plus lifecycle and
/// re-dispatch counters (DESIGN.md §11 has the formulas).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeClusterReport {
    /// `node0`, `node1`, … — index into the scenario's tier.
    pub name: String,
    /// The node's accelerator pool label (`GpuSpec::display_name`).
    pub gpu: String,
    /// Wall-seconds the node spent powered (provisioning + up +
    /// draining).
    pub up_seconds: f64,
    /// `up_seconds × gpu.scale` — device-seconds consumed.
    pub gpu_seconds: f64,
    /// `up_seconds × tdp_watts` (TDP is pool-scaled).
    pub joules: f64,
    /// `up_seconds / 3600 × price_per_hour` (price is pool-scaled).
    pub dollars: f64,
    /// Jobs completed on this node.
    pub served: u64,
    /// Jobs evicted from this node and re-dispatched elsewhere.
    pub redispatched: u64,
    /// Jobs evicted from this node whose retry budget was exhausted.
    pub lost: u64,
    /// Failure events the node suffered.
    pub failures: u64,
}

impl NodeClusterReport {
    fn merge(&mut self, other: &NodeClusterReport) {
        self.up_seconds += other.up_seconds;
        self.gpu_seconds += other.gpu_seconds;
        self.joules += other.joules;
        self.dollars += other.dollars;
        self.served += other.served;
        self.redispatched += other.redispatched;
        self.lost += other.lost;
        self.failures += other.failures;
    }
}

/// Per-class attributed compute cost: each completed job's roofline
/// work seconds priced on the node that served it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassClusterReport {
    pub name: String,
    pub gpu_seconds: f64,
    pub joules: f64,
    pub dollars: f64,
    pub redispatched: u64,
    pub lost: u64,
}

impl ClassClusterReport {
    fn merge(&mut self, other: &ClassClusterReport) {
        self.gpu_seconds += other.gpu_seconds;
        self.joules += other.joules;
        self.dollars += other.dollars;
        self.redispatched += other.redispatched;
        self.lost += other.lost;
    }
}

/// Cluster section of a [`SimReport`]: empty unless the scenario ran
/// with the elastic compute control plane enabled.
#[derive(Debug, Clone, Default)]
pub struct ClusterReport {
    pub nodes: Vec<NodeClusterReport>,
    pub classes: Vec<ClassClusterReport>,
}

impl ClusterReport {
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty() && self.classes.is_empty()
    }

    /// Total rental cost of the tier over the run.
    pub fn total_dollars(&self) -> f64 {
        self.nodes.iter().map(|n| n.dollars).sum()
    }

    /// Total energy drawn by the tier over the run.
    pub fn total_joules(&self) -> f64 {
        self.nodes.iter().map(|n| n.joules).sum()
    }

    /// Satisfied jobs per dollar — the capacity-per-dollar figure the
    /// elastic scenarios optimize for (`NaN` when nothing was spent).
    pub fn capacity_per_dollar(&self, n_satisfied: u64) -> f64 {
        let d = self.total_dollars();
        if d > 0.0 {
            n_satisfied as f64 / d
        } else {
            f64::NAN
        }
    }

    /// Replication merge: element-wise when the tier shape matches
    /// (same node count and class names), cleared on mismatch — the
    /// same rule as the radio and per-cell slices.
    fn merge(&mut self, other: &ClusterReport) {
        let matches = self.nodes.len() == other.nodes.len()
            && self.classes.len() == other.classes.len()
            && self
                .classes
                .iter()
                .zip(&other.classes)
                .all(|(a, b)| a.name == b.name);
        if matches {
            for (a, b) in self.nodes.iter_mut().zip(&other.nodes) {
                a.merge(b);
            }
            for (a, b) in self.classes.iter_mut().zip(&other.classes) {
                a.merge(b);
            }
        } else {
            self.nodes.clear();
            self.classes.clear();
        }
    }
}

/// Aggregated simulation report.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub n_jobs: u64,
    pub n_satisfied: u64,
    pub n_dropped: u64,
    /// Jobs lost to node failures (elastic-cluster runs; otherwise 0).
    pub n_lost: u64,
    pub comm: Welford,
    pub comp: Welford,
    pub e2e: Welford,
    pub tokens_per_sec: Welford,
    /// Time-to-first-token over all completed jobs.
    pub ttft: Welford,
    /// Time-per-output-token over all completed jobs.
    pub tpot: Welford,
    /// Per-workload-class breakdown. Populated by
    /// [`SimReport::from_outcomes_per_class`]; empty for single-policy
    /// reports built with [`SimReport::from_outcomes`].
    pub per_class: Vec<ClassReport>,
    /// Per-cell (gNB) breakdown, named `cell0`, `cell1`, … Populated by
    /// [`SimReport::from_outcomes_per_class`] for multi-cell runs
    /// (`n_cells > 1`); empty otherwise, so single-cell reports carry
    /// no duplicate sample sets. Each job is judged by its own class
    /// policy, exactly as in `per_class`.
    pub per_cell: Vec<ClassReport>,
    /// Per-model breakdown of a model-zoo run, one slice per `[[model]]`
    /// entry in zoo order (named by model name). Populated via
    /// [`SimReport::bucket_per_model`]; empty for single-model runs.
    /// Each job is judged by its own class policy, exactly as in
    /// `per_class`; jobs that never reached a model contribute nothing.
    pub per_model: Vec<ClassReport>,
    /// Per-cell radio-layer stats (handover counts, applied IoT) of a
    /// coupled-radio run, indexed by cell. Empty for legacy
    /// fixed-margin runs; merges element-wise across replications with
    /// the same topology, clears on mismatch (same rule as
    /// `per_cell`).
    pub radio: Vec<CellRadioReport>,
    /// Elastic-cluster accounting (per-node cost/energy/lifecycle and
    /// per-class attributed cost). Empty unless the scenario enabled
    /// the cluster control plane; merges element-wise on matching tier
    /// shapes, clears on mismatch.
    pub cluster: ClusterReport,
}

impl SimReport {
    pub fn from_outcomes(outcomes: &[JobOutcome], policy: &LatencyManagement) -> Self {
        let mut all = ClassReport::new(String::new());
        for j in outcomes {
            all.observe(j, policy);
        }
        let mut r = Self::empty();
        r.absorb(&all);
        r
    }

    /// Build the report for a multi-class (and, with `n_cells > 1`,
    /// multi-cell) run: each outcome is judged by its own class policy,
    /// and the overall totals are the exact sums/merges of the
    /// per-class slices. The per-cell slices re-bucket the same
    /// observations by originating gNB.
    pub fn from_outcomes_per_class(
        outcomes: &[JobOutcome],
        classes: &[(String, LatencyManagement)],
        n_cells: usize,
    ) -> Self {
        let mut per: Vec<ClassReport> =
            classes.iter().map(|(name, _)| ClassReport::new(name.clone())).collect();
        // Single-cell runs skip the per-cell slices entirely (they
        // would just duplicate the totals and their sample sets).
        let mut per_cell: Vec<ClassReport> = if n_cells > 1 {
            (0..n_cells).map(|i| ClassReport::new(format!("cell{i}"))).collect()
        } else {
            Vec::new()
        };
        for j in outcomes {
            let cls = j.class_id as usize;
            assert!(cls < per.len(), "outcome class {cls} out of range");
            per[cls].observe(j, &classes[cls].1);
            if !per_cell.is_empty() {
                let cell = j.cell_id as usize;
                assert!(cell < per_cell.len(), "outcome cell {cell} out of range");
                per_cell[cell].observe(j, &classes[cls].1);
            }
        }
        let mut r = Self::empty();
        for cr in &per {
            r.absorb(cr);
        }
        r.per_class = per;
        r.per_cell = per_cell;
        r
    }

    /// Re-bucket the same outcomes by served model (model-zoo runs):
    /// one slice per zoo entry, in zoo order, each job judged by its
    /// own class policy exactly as in `per_class`. Jobs carrying
    /// `model_id == u32::MAX` (no zoo, or never dispatched) are
    /// skipped, so the slices need not sum to the overall totals.
    pub fn bucket_per_model(
        outcomes: &[JobOutcome],
        model_names: &[String],
        classes: &[(String, LatencyManagement)],
    ) -> Vec<ClassReport> {
        let mut per: Vec<ClassReport> =
            model_names.iter().map(|n| ClassReport::new(n.clone())).collect();
        for j in outcomes {
            if j.model_id == u32::MAX {
                continue;
            }
            let m = j.model_id as usize;
            assert!(m < per.len(), "outcome model {m} out of range");
            let cls = j.class_id as usize;
            per[m].observe(j, &classes[cls].1);
        }
        per
    }

    /// Fold one per-class slice into the overall totals.
    fn absorb(&mut self, cr: &ClassReport) {
        self.n_jobs += cr.n_jobs;
        self.n_satisfied += cr.n_satisfied;
        self.n_dropped += cr.n_dropped;
        self.n_lost += cr.n_lost;
        self.comm.merge(&cr.comm);
        self.comp.merge(&cr.comp);
        self.e2e.merge(&cr.e2e);
        self.tokens_per_sec.merge(&cr.tokens_per_sec);
        self.ttft.merge(&cr.ttft);
        self.tpot.merge(&cr.tpot);
    }

    /// Merge an independent replication into this report, keeping the
    /// "per-class slices sum to the totals" invariant: matching class
    /// lists merge slice-wise (percentile sample sets concatenate);
    /// mismatched ones clear `per_class` rather than leave a stale
    /// single-replication breakdown.
    pub fn merge(&mut self, other: &SimReport) {
        self.n_jobs += other.n_jobs;
        self.n_satisfied += other.n_satisfied;
        self.n_dropped += other.n_dropped;
        self.n_lost += other.n_lost;
        self.comm.merge(&other.comm);
        self.comp.merge(&other.comp);
        self.e2e.merge(&other.e2e);
        self.tokens_per_sec.merge(&other.tokens_per_sec);
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        let classes_match = self.per_class.len() == other.per_class.len()
            && self
                .per_class
                .iter()
                .zip(&other.per_class)
                .all(|(a, b)| a.name == b.name);
        if classes_match {
            for (a, b) in self.per_class.iter_mut().zip(&other.per_class) {
                a.merge(b);
            }
        } else {
            self.per_class.clear();
        }
        // Per-cell slices merge under the same rule: matching cell
        // lists merge slice-wise, mismatched topologies clear the
        // breakdown rather than leave a stale one.
        let cells_match = self.per_cell.len() == other.per_cell.len()
            && self
                .per_cell
                .iter()
                .zip(&other.per_cell)
                .all(|(a, b)| a.name == b.name);
        if cells_match {
            for (a, b) in self.per_cell.iter_mut().zip(&other.per_cell) {
                a.merge(b);
            }
        } else {
            self.per_cell.clear();
        }
        // Per-model slices: matching zoos merge slice-wise, mismatched
        // zoos clear the breakdown (same rule as per_class/per_cell).
        let models_match = self.per_model.len() == other.per_model.len()
            && self
                .per_model
                .iter()
                .zip(&other.per_model)
                .all(|(a, b)| a.name == b.name);
        if models_match {
            for (a, b) in self.per_model.iter_mut().zip(&other.per_model) {
                a.merge(b);
            }
        } else {
            self.per_model.clear();
        }
        // Radio slices: element-wise on matching topologies, cleared
        // on mismatch.
        if self.radio.len() == other.radio.len() {
            for (a, b) in self.radio.iter_mut().zip(&other.radio) {
                a.merge(b);
            }
        } else {
            self.radio.clear();
        }
        self.cluster.merge(&other.cluster);
    }

    fn empty() -> Self {
        Self {
            n_jobs: 0,
            n_satisfied: 0,
            n_dropped: 0,
            n_lost: 0,
            comm: Welford::new(),
            comp: Welford::new(),
            e2e: Welford::new(),
            tokens_per_sec: Welford::new(),
            ttft: Welford::new(),
            tpot: Welford::new(),
            per_class: Vec::new(),
            per_cell: Vec::new(),
            per_model: Vec::new(),
            radio: Vec::new(),
            cluster: ClusterReport::default(),
        }
    }

    /// Fraction of (non-in-flight) jobs satisfied — the Y axis of
    /// Figs 4/6/7.
    pub fn satisfaction_rate(&self) -> f64 {
        if self.n_jobs == 0 {
            f64::NAN
        } else {
            self.n_satisfied as f64 / self.n_jobs as f64
        }
    }

    /// Machine-readable report (hand-rolled JSON; the dependency
    /// universe has no serde). Latencies are reported in milliseconds;
    /// non-finite values (empty slices) serialize as `null`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"n_jobs\": {},\n", self.n_jobs));
        out.push_str(&format!("  \"n_satisfied\": {},\n", self.n_satisfied));
        out.push_str(&format!("  \"n_dropped\": {},\n", self.n_dropped));
        out.push_str(&format!("  \"n_lost\": {},\n", self.n_lost));
        out.push_str(&format!(
            "  \"satisfaction_rate\": {},\n",
            jnum(self.satisfaction_rate())
        ));
        out.push_str(&format!("  \"avg_comm_ms\": {},\n", jnum(self.comm.mean() * 1e3)));
        out.push_str(&format!("  \"avg_comp_ms\": {},\n", jnum(self.comp.mean() * 1e3)));
        out.push_str(&format!("  \"avg_e2e_ms\": {},\n", jnum(self.e2e.mean() * 1e3)));
        out.push_str(&format!(
            "  \"avg_tokens_per_sec\": {},\n",
            jnum(self.tokens_per_sec.mean())
        ));
        out.push_str(&format!("  \"avg_ttft_ms\": {},\n", jnum(self.ttft.mean() * 1e3)));
        out.push_str(&format!("  \"avg_tpot_ms\": {},\n", jnum(self.tpot.mean() * 1e3)));
        out.push_str("  \"per_class\": [");
        for (i, c) in self.per_class.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"name\": \"{}\", ", jstr(&c.name)));
            out.push_str(&format!("\"n_jobs\": {}, ", c.n_jobs));
            out.push_str(&format!("\"n_satisfied\": {}, ", c.n_satisfied));
            out.push_str(&format!("\"n_dropped\": {}, ", c.n_dropped));
            out.push_str(&format!(
                "\"satisfaction_rate\": {}, ",
                jnum(c.satisfaction_rate())
            ));
            out.push_str(&format!("\"avg_comm_ms\": {}, ", jnum(c.comm.mean() * 1e3)));
            out.push_str(&format!("\"avg_comp_ms\": {}, ", jnum(c.comp.mean() * 1e3)));
            out.push_str(&format!("\"avg_e2e_ms\": {}, ", jnum(c.e2e.mean() * 1e3)));
            let qs = [50.0, 95.0, 99.0];
            let ttft = c.ttft_percentiles(&qs);
            let tpot = c.tpot_percentiles(&qs);
            out.push_str(&format!(
                "\"ttft_ms\": {{\"mean\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}}}, ",
                jnum(c.ttft.mean() * 1e3),
                jnum(ttft[0] * 1e3),
                jnum(ttft[1] * 1e3),
                jnum(ttft[2] * 1e3),
            ));
            out.push_str(&format!(
                "\"tpot_ms\": {{\"mean\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}}}",
                jnum(c.tpot.mean() * 1e3),
                jnum(tpot[0] * 1e3),
                jnum(tpot[1] * 1e3),
                jnum(tpot[2] * 1e3),
            ));
            out.push('}');
        }
        if !self.per_class.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"per_cell\": [");
        for (i, c) in self.per_cell.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"name\": \"{}\", ", jstr(&c.name)));
            out.push_str(&format!("\"n_jobs\": {}, ", c.n_jobs));
            out.push_str(&format!("\"n_satisfied\": {}, ", c.n_satisfied));
            out.push_str(&format!("\"n_dropped\": {}, ", c.n_dropped));
            out.push_str(&format!(
                "\"satisfaction_rate\": {}, ",
                jnum(c.satisfaction_rate())
            ));
            out.push_str(&format!("\"avg_comm_ms\": {}, ", jnum(c.comm.mean() * 1e3)));
            out.push_str(&format!("\"avg_e2e_ms\": {}", jnum(c.e2e.mean() * 1e3)));
            out.push('}');
        }
        if !self.per_cell.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"per_model\": [");
        for (i, c) in self.per_model.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"name\": \"{}\", ", jstr(&c.name)));
            out.push_str(&format!("\"n_jobs\": {}, ", c.n_jobs));
            out.push_str(&format!("\"n_satisfied\": {}, ", c.n_satisfied));
            out.push_str(&format!("\"n_dropped\": {}, ", c.n_dropped));
            out.push_str(&format!(
                "\"satisfaction_rate\": {}, ",
                jnum(c.satisfaction_rate())
            ));
            out.push_str(&format!("\"avg_comp_ms\": {}, ", jnum(c.comp.mean() * 1e3)));
            out.push_str(&format!("\"avg_e2e_ms\": {}, ", jnum(c.e2e.mean() * 1e3)));
            out.push_str(&format!(
                "\"avg_tokens_per_sec\": {}, ",
                jnum(c.tokens_per_sec.mean())
            ));
            out.push_str(&format!(
                "\"ttft_ms\": {{\"mean\": {}, \"p95\": {}}}",
                jnum(c.ttft.mean() * 1e3),
                jnum(c.ttft_percentile(95.0) * 1e3),
            ));
            out.push('}');
        }
        if !self.per_model.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"per_cell_radio\": [");
        for (i, r) in self.radio.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"cell\": {i}, "));
            out.push_str(&format!("\"handovers_in\": {}, ", r.handovers_in));
            out.push_str(&format!("\"handovers_out\": {}, ", r.handovers_out));
            out.push_str(&format!("\"avg_iot_db\": {}, ", jnum(r.iot_db.mean())));
            out.push_str(&format!("\"max_iot_db\": {}", jnum(r.iot_db.max())));
            out.push('}');
        }
        if !self.radio.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"cluster\": {\n    \"total_dollars\": ");
        out.push_str(&jnum(self.cluster.total_dollars()));
        out.push_str(",\n    \"total_joules\": ");
        out.push_str(&jnum(self.cluster.total_joules()));
        out.push_str(",\n    \"capacity_per_dollar\": ");
        out.push_str(&jnum(self.cluster.capacity_per_dollar(self.n_satisfied)));
        out.push_str(",\n    \"nodes\": [");
        for (i, n) in self.cluster.nodes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n      {");
            out.push_str(&format!("\"name\": \"{}\", ", jstr(&n.name)));
            out.push_str(&format!("\"gpu\": \"{}\", ", jstr(&n.gpu)));
            out.push_str(&format!("\"up_seconds\": {}, ", jnum(n.up_seconds)));
            out.push_str(&format!("\"gpu_seconds\": {}, ", jnum(n.gpu_seconds)));
            out.push_str(&format!("\"joules\": {}, ", jnum(n.joules)));
            out.push_str(&format!("\"dollars\": {}, ", jnum(n.dollars)));
            out.push_str(&format!("\"served\": {}, ", n.served));
            out.push_str(&format!("\"redispatched\": {}, ", n.redispatched));
            out.push_str(&format!("\"lost\": {}, ", n.lost));
            out.push_str(&format!("\"failures\": {}", n.failures));
            out.push('}');
        }
        if !self.cluster.nodes.is_empty() {
            out.push_str("\n    ");
        }
        out.push_str("],\n    \"classes\": [");
        for (i, c) in self.cluster.classes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n      {");
            out.push_str(&format!("\"name\": \"{}\", ", jstr(&c.name)));
            out.push_str(&format!("\"gpu_seconds\": {}, ", jnum(c.gpu_seconds)));
            out.push_str(&format!("\"joules\": {}, ", jnum(c.joules)));
            out.push_str(&format!("\"dollars\": {}, ", jnum(c.dollars)));
            out.push_str(&format!("\"redispatched\": {}, ", c.redispatched));
            out.push_str(&format!("\"lost\": {}", c.lost));
            out.push('}');
        }
        if !self.cluster.classes.is_empty() {
            out.push_str("\n    ");
        }
        out.push_str("]\n  }\n}\n");
        out
    }
}

/// Sort once, read many percentiles.
fn percentiles_of(samples: &[f64], qs: &[f64]) -> Vec<f64> {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter().map(|&q| percentile_sorted(&v, q)).collect()
}

/// JSON number: non-finite → `null`.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Minimal JSON string escaping (class names come from configs).
fn jstr(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' | '\r' | '\t' => vec![' '],
            c => vec![c],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done(t_comm: f64, t_queue: f64, t_service: f64) -> JobOutcome {
        JobOutcome {
            job_id: 0,
            class_id: 0,
            model_id: u32::MAX,
            cell_id: 0,
            t_gen: 0.0,
            t_comm,
            t_wireline: 0.005,
            t_queue,
            t_service,
            ttft: t_comm + 0.005 + t_queue + t_service / 2.0,
            tpot: t_service / 30.0,
            tokens: 30,
            fate: JobFate::Completed,
        }
    }

    #[test]
    fn e2e_composition() {
        let j = done(0.010, 0.020, 0.030);
        assert!((j.e2e() - 0.065).abs() < 1e-12);
        assert!((j.t_comp() - 0.050).abs() < 1e-12);
        assert!((j.tokens_per_sec() - 30.0 / 0.065).abs() < 1e-9);
    }

    #[test]
    fn joint_satisfaction_boundary() {
        let p = LatencyManagement::Joint { b_total: 0.080 };
        assert!(p.satisfied(&done(0.010, 0.030, 0.035))); // 80 ms exactly
        assert!(!p.satisfied(&done(0.010, 0.031, 0.035)));
    }

    #[test]
    fn disjoint_requires_both_budgets() {
        let p = LatencyManagement::Disjoint { b_total: 0.080, b_comm: 0.024, b_comp: 0.056 };
        // comm = 10+5 = 15 <= 24, comp = 50 <= 56, e2e = 65 <= 80 → ok
        assert!(p.satisfied(&done(0.010, 0.020, 0.030)));
        // comm budget violated even though e2e fine
        assert!(!p.satisfied(&done(0.022, 0.010, 0.010)));
        // comp budget violated
        assert!(!p.satisfied(&done(0.005, 0.030, 0.030)));
    }

    #[test]
    fn joint_dominates_disjoint() {
        let joint = LatencyManagement::Joint { b_total: 0.080 };
        let dis = LatencyManagement::Disjoint { b_total: 0.080, b_comm: 0.024, b_comp: 0.056 };
        // a job satisfying disjoint always satisfies joint
        for j in [done(0.01, 0.02, 0.03), done(0.018, 0.03, 0.025), done(0.001, 0.05, 0.005)] {
            if dis.satisfied(&j) {
                assert!(joint.satisfied(&j));
            }
        }
    }

    #[test]
    fn dropped_jobs_count_against_satisfaction() {
        let mut j = done(0.01, 0.0, 0.0);
        j.fate = JobFate::Dropped;
        let outcomes = vec![j, done(0.01, 0.02, 0.03)];
        let r = SimReport::from_outcomes(&outcomes, &LatencyManagement::Joint { b_total: 0.080 });
        assert_eq!(r.n_jobs, 2);
        assert_eq!(r.n_dropped, 1);
        assert_eq!(r.n_satisfied, 1);
        assert!((r.satisfaction_rate() - 0.5).abs() < 1e-12);
        // dropped jobs contribute no TTFT/TPOT sample
        assert_eq!(r.ttft.count(), 1);
        assert_eq!(r.tpot.count(), 1);
    }

    #[test]
    fn in_flight_ignored() {
        let mut j = done(0.01, 0.0, 0.0);
        j.fate = JobFate::InFlight;
        let r = SimReport::from_outcomes(&[j], &LatencyManagement::Joint { b_total: 0.080 });
        assert_eq!(r.n_jobs, 0);
        assert!(r.satisfaction_rate().is_nan());
    }

    #[test]
    fn per_class_totals_sum_to_overall() {
        // Two classes with different budgets: the strict class fails
        // where the lenient one passes, and the overall report is the
        // exact sum of the slices.
        let mut tight = done(0.010, 0.030, 0.035); // e2e = 80 ms
        tight.class_id = 0;
        let mut loose = done(0.010, 0.030, 0.035);
        loose.class_id = 1;
        let mut dropped = done(0.02, 0.0, 0.0);
        dropped.class_id = 1;
        dropped.fate = JobFate::Dropped;
        let classes = vec![
            ("tight".to_string(), LatencyManagement::Joint { b_total: 0.070 }),
            ("loose".to_string(), LatencyManagement::Joint { b_total: 0.100 }),
        ];
        let r = SimReport::from_outcomes_per_class(&[tight, loose, dropped], &classes, 1);
        assert_eq!(r.per_class.len(), 2);
        assert!(r.per_cell.is_empty(), "single-cell runs skip per-cell slices");
        assert_eq!(r.per_class[0].name, "tight");
        assert_eq!(r.per_class[0].n_satisfied, 0);
        assert_eq!(r.per_class[1].n_satisfied, 1);
        assert_eq!(r.per_class[1].n_dropped, 1);
        let (mut jobs, mut sat, mut drop_) = (0, 0, 0);
        for c in &r.per_class {
            jobs += c.n_jobs;
            sat += c.n_satisfied;
            drop_ += c.n_dropped;
        }
        assert_eq!(r.n_jobs, jobs);
        assert_eq!(r.n_satisfied, sat);
        assert_eq!(r.n_dropped, drop_);
        assert_eq!(r.comm.count(), 3);
        // TTFT totals are the merge of the slices
        let slice_ttft: u64 = r.per_class.iter().map(|c| c.ttft.count()).sum();
        assert_eq!(r.ttft.count(), slice_ttft);
    }

    #[test]
    fn ttft_percentiles_merge_exactly_under_replication() {
        let policy = LatencyManagement::Joint { b_total: 1.0 };
        let mk = |ttfts: &[f64]| {
            let outcomes: Vec<JobOutcome> = ttfts
                .iter()
                .map(|&t| JobOutcome { ttft: t, tpot: t / 10.0, ..done(0.01, 0.0, 0.05) })
                .collect();
            SimReport::from_outcomes_per_class(
                &outcomes,
                &[("c".to_string(), policy)],
                1,
            )
        };
        let mut a = mk(&[0.010, 0.020, 0.030]);
        let b = mk(&[0.040, 0.050]);
        a.merge(&b);
        let c = &a.per_class[0];
        assert_eq!(c.ttft_samples().len(), 5);
        // exact percentile over the concatenated sample set
        let expect = crate::util::stats::percentile(&[0.01, 0.02, 0.03, 0.04, 0.05], 50.0);
        assert!((c.ttft_percentile(50.0) - expect).abs() < 1e-15);
        assert!((c.ttft_percentile(0.0) - 0.01).abs() < 1e-15);
        assert!((c.ttft_percentile(100.0) - 0.05).abs() < 1e-15);
        assert_eq!(a.ttft.count(), 5);
        // tpot merged alongside
        assert_eq!(c.tpot_samples().len(), 5);
    }

    #[test]
    fn json_report_is_well_formed() {
        let policy = LatencyManagement::Joint { b_total: 1.0 };
        let outcomes = vec![done(0.01, 0.0, 0.05)];
        let r = SimReport::from_outcomes_per_class(
            &outcomes,
            &[("chat \"v2\"".to_string(), policy)],
            1,
        );
        let js = r.to_json();
        assert!(js.contains("\"n_jobs\": 1"));
        assert!(js.contains("\"ttft_ms\""));
        assert!(js.contains("\"p99\""));
        assert!(js.contains("chat \\\"v2\\\""), "{js}");
        // crude balance check
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        assert_eq!(js.matches('[').count(), js.matches(']').count());
        // empty reports serialize NaNs as null
        let empty = SimReport::from_outcomes(&[], &policy);
        assert!(empty.to_json().contains("\"satisfaction_rate\": null"));
    }

    /// Satellite: the full report JSON — per-class slices with
    /// TTFT/TPOT percentile objects, per-cell slices, the new
    /// per-cell radio array, and escaped class names — must round-trip
    /// through the crate's own `util::jsonmini` parser with the exact
    /// values the report getters expose.
    #[test]
    fn json_report_round_trips_through_jsonmini() {
        use crate::util::jsonmini::Value;
        let policy = LatencyManagement::Joint { b_total: 1.0 };
        let classes = vec![
            ("chat \"v2\" \\ beta".to_string(), policy),
            ("plain".to_string(), policy),
        ];
        let mut outcomes = Vec::new();
        for (i, cell) in [0u32, 1, 2, 0, 1].iter().enumerate() {
            let mut j = done(0.01 + i as f64 * 0.001, 0.002, 0.05);
            j.cell_id = *cell;
            j.class_id = (i % 2) as u32;
            outcomes.push(j);
        }
        let mut r = SimReport::from_outcomes_per_class(&outcomes, &classes, 3);
        let mut radio = Vec::new();
        for k in 0..3u64 {
            let mut cr = CellRadioReport {
                handovers_in: k,
                handovers_out: 2 * k,
                ..Default::default()
            };
            cr.iot_db.push(1.5 * k as f64);
            cr.iot_db.push(2.5 * k as f64);
            radio.push(cr);
        }
        r.radio = radio;
        r.n_lost = 2;
        r.cluster = ClusterReport {
            nodes: vec![
                NodeClusterReport {
                    name: "node0".into(),
                    gpu: "A100-SXM-80GB x2".into(),
                    up_seconds: 10.0,
                    gpu_seconds: 20.0,
                    joules: 8000.0,
                    dollars: 0.01,
                    served: 5,
                    redispatched: 2,
                    lost: 1,
                    failures: 1,
                },
                NodeClusterReport {
                    name: "node1".into(),
                    gpu: "L40S".into(),
                    up_seconds: 4.0,
                    gpu_seconds: 4.0,
                    joules: 1400.0,
                    dollars: 0.002,
                    served: 3,
                    redispatched: 0,
                    lost: 0,
                    failures: 0,
                },
            ],
            classes: vec![ClassClusterReport {
                name: "chat \"v2\" \\ beta".into(),
                gpu_seconds: 1.5,
                joules: 600.0,
                dollars: 0.0008,
                redispatched: 2,
                lost: 1,
            }],
        };

        let js = r.to_json();
        let v = Value::parse(&js).unwrap_or_else(|e| panic!("report JSON unparsable: {e}\n{js}"));
        assert_eq!(v.get("n_jobs").and_then(Value::as_f64), Some(r.n_jobs as f64));
        assert_eq!(
            v.get("satisfaction_rate").and_then(Value::as_f64),
            Some(r.satisfaction_rate())
        );
        // per-class: escaped names round-trip, percentile objects match
        let pc = v.get("per_class").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(pc.len(), 2);
        assert_eq!(
            pc[0].get("name").and_then(Value::as_str),
            Some("chat \"v2\" \\ beta")
        );
        for (slot, cr) in pc.iter().zip(&r.per_class) {
            assert_eq!(slot.get("n_jobs").and_then(Value::as_f64), Some(cr.n_jobs as f64));
            let ttft = slot.get("ttft_ms").unwrap();
            let expect = cr.ttft_percentile(95.0) * 1e3;
            let got = ttft.get("p95").and_then(Value::as_f64).unwrap();
            assert!((got - expect).abs() < 1e-9, "{got} vs {expect}");
        }
        // per-cell slices
        let cells = v.get("per_cell").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(cells.len(), 3);
        for (k, (slot, cr)) in cells.iter().zip(&r.per_cell).enumerate() {
            assert_eq!(slot.get("name").and_then(Value::as_str), Some(format!("cell{k}").as_str()));
            assert_eq!(slot.get("n_jobs").and_then(Value::as_f64), Some(cr.n_jobs as f64));
            assert_eq!(
                slot.get("avg_comm_ms").and_then(Value::as_f64),
                Some(cr.comm.mean() * 1e3)
            );
        }
        // per-cell radio: handover counts + IoT summary
        let radio = v.get("per_cell_radio").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(radio.len(), 3);
        for (k, (slot, cr)) in radio.iter().zip(&r.radio).enumerate() {
            assert_eq!(slot.get("cell").and_then(Value::as_f64), Some(k as f64));
            assert_eq!(
                slot.get("handovers_in").and_then(Value::as_f64),
                Some(cr.handovers_in as f64)
            );
            assert_eq!(
                slot.get("handovers_out").and_then(Value::as_f64),
                Some(cr.handovers_out as f64)
            );
            let got = slot.get("avg_iot_db").and_then(Value::as_f64).unwrap();
            assert!((got - cr.iot_db.mean()).abs() < 1e-9);
            let max = slot.get("max_iot_db").and_then(Value::as_f64).unwrap();
            assert!((max - cr.iot_db.max()).abs() < 1e-9);
        }
        // cluster section: totals, per-node and per-class rows
        assert_eq!(v.get("n_lost").and_then(Value::as_f64), Some(2.0));
        let cl = v.get("cluster").unwrap();
        let got = cl.get("total_dollars").and_then(Value::as_f64).unwrap();
        assert!((got - r.cluster.total_dollars()).abs() < 1e-12);
        let got = cl.get("total_joules").and_then(Value::as_f64).unwrap();
        assert!((got - r.cluster.total_joules()).abs() < 1e-9);
        let got = cl.get("capacity_per_dollar").and_then(Value::as_f64).unwrap();
        assert!((got - r.cluster.capacity_per_dollar(r.n_satisfied)).abs() < 1e-9);
        let nodes = cl.get("nodes").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(nodes.len(), 2);
        for (slot, nr) in nodes.iter().zip(&r.cluster.nodes) {
            assert_eq!(slot.get("name").and_then(Value::as_str), Some(nr.name.as_str()));
            assert_eq!(slot.get("gpu").and_then(Value::as_str), Some(nr.gpu.as_str()));
            for (key, want) in [
                ("up_seconds", nr.up_seconds),
                ("gpu_seconds", nr.gpu_seconds),
                ("joules", nr.joules),
                ("dollars", nr.dollars),
                ("served", nr.served as f64),
                ("redispatched", nr.redispatched as f64),
                ("lost", nr.lost as f64),
                ("failures", nr.failures as f64),
            ] {
                let got = slot.get(key).and_then(Value::as_f64).unwrap();
                assert!((got - want).abs() < 1e-12, "{key}: {got} vs {want}");
            }
        }
        let ccs = cl.get("classes").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(ccs.len(), 1);
        assert_eq!(
            ccs[0].get("name").and_then(Value::as_str),
            Some("chat \"v2\" \\ beta")
        );
        let got = ccs[0].get("gpu_seconds").and_then(Value::as_f64).unwrap();
        assert!((got - 1.5).abs() < 1e-12);
        assert_eq!(ccs[0].get("lost").and_then(Value::as_f64), Some(1.0));
        // an empty report still parses; NaN fields become null
        let empty = SimReport::from_outcomes(&[], &policy);
        let ev = Value::parse(&empty.to_json()).unwrap();
        assert_eq!(ev.get("satisfaction_rate"), Some(&Value::Null));
        assert_eq!(ev.get("per_cell_radio").and_then(|x| x.as_arr()).unwrap().len(), 0);
        let ecl = ev.get("cluster").unwrap();
        assert_eq!(ecl.get("nodes").and_then(|x| x.as_arr()).unwrap().len(), 0);
        assert_eq!(ecl.get("classes").and_then(|x| x.as_arr()).unwrap().len(), 0);
        assert_eq!(ecl.get("capacity_per_dollar"), Some(&Value::Null));
    }

    /// Satellite: per-model slices bucket by `model_id` under each
    /// job's own class policy, skip never-dispatched jobs, merge
    /// slice-wise across matching zoos, clear on mismatch, and ride in
    /// the JSON report.
    #[test]
    fn per_model_slices_bucket_judge_and_merge() {
        let classes = vec![
            ("tight".to_string(), LatencyManagement::Joint { b_total: 0.070 }),
            ("loose".to_string(), LatencyManagement::Joint { b_total: 0.100 }),
        ];
        let names = vec!["70b".to_string(), "7b".to_string()];
        let mk = |specs: &[(u32, u32)]| {
            let outcomes: Vec<JobOutcome> = specs
                .iter()
                .map(|&(cls, model)| JobOutcome {
                    class_id: cls,
                    model_id: model,
                    ..done(0.010, 0.030, 0.035) // e2e = 80 ms
                })
                .collect();
            let mut r = SimReport::from_outcomes_per_class(&outcomes, &classes, 1);
            r.per_model = SimReport::bucket_per_model(&outcomes, &names, &classes);
            r
        };
        // class 0 (tight) fails its 70 ms budget at 80 ms; class 1
        // (loose) passes — the same job is judged per its own class
        // whichever model served it.
        let mut a = mk(&[(0, 0), (1, 0), (1, 1), (0, u32::MAX)]);
        assert_eq!(a.per_model.len(), 2);
        assert_eq!(a.per_model[0].name, "70b");
        assert_eq!(a.per_model[0].n_jobs, 2);
        assert_eq!(a.per_model[0].n_satisfied, 1);
        assert_eq!(a.per_model[1].n_jobs, 1);
        // the u32::MAX job is counted overall but in no model slice
        let sliced: u64 = a.per_model.iter().map(|c| c.n_jobs).sum();
        assert_eq!(a.n_jobs, 4);
        assert_eq!(sliced, 3);
        // matching zoos merge slice-wise
        a.merge(&mk(&[(1, 1)]));
        assert_eq!(a.per_model[1].n_jobs, 2);
        // JSON carries the section and stays balanced
        let js = a.to_json();
        assert!(js.contains("\"per_model\""));
        assert!(js.contains("\"name\": \"70b\""));
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        // a different zoo clears the breakdown instead of lying
        let mut b = mk(&[(0, 0)]);
        b.per_model.pop();
        a.merge(&b);
        assert!(a.per_model.is_empty());
    }

    #[test]
    fn lost_jobs_count_against_satisfaction_like_drops() {
        let mut lost = done(0.012, 0.0, 0.0);
        lost.fate = JobFate::Lost;
        lost.ttft = 0.0;
        lost.tpot = 0.0;
        let outcomes = vec![lost, done(0.01, 0.02, 0.03)];
        let r = SimReport::from_outcomes(&outcomes, &LatencyManagement::Joint { b_total: 0.080 });
        assert_eq!(r.n_jobs, 2);
        assert_eq!(r.n_lost, 1);
        assert_eq!(r.n_dropped, 0);
        assert_eq!(r.n_satisfied, 1);
        assert!((r.satisfaction_rate() - 0.5).abs() < 1e-12);
        // lost jobs contribute their comm latency but no service stats
        assert_eq!(r.comm.count(), 2);
        assert_eq!(r.ttft.count(), 1);
    }

    #[test]
    fn cluster_sections_merge_elementwise_and_clear_on_mismatch() {
        let policy = LatencyManagement::Joint { b_total: 1.0 };
        let mk = |dollars: f64, served: u64| {
            let mut r = SimReport::from_outcomes(&[done(0.01, 0.0, 0.05)], &policy);
            r.cluster = ClusterReport {
                nodes: vec![NodeClusterReport {
                    name: "node0".into(),
                    gpu: "L40S".into(),
                    up_seconds: 1.0,
                    gpu_seconds: 1.0,
                    joules: 350.0,
                    dollars,
                    served,
                    ..Default::default()
                }],
                classes: vec![ClassClusterReport {
                    name: "c".into(),
                    gpu_seconds: 0.5,
                    ..Default::default()
                }],
            };
            r
        };
        let mut a = mk(0.01, 3);
        a.merge(&mk(0.02, 4));
        assert_eq!(a.cluster.nodes.len(), 1);
        assert!((a.cluster.nodes[0].dollars - 0.03).abs() < 1e-12);
        assert_eq!(a.cluster.nodes[0].served, 7);
        assert!((a.cluster.classes[0].gpu_seconds - 1.0).abs() < 1e-12);
        assert!((a.cluster.total_dollars() - 0.03).abs() < 1e-12);
        // a different tier shape clears the section rather than lying
        let mut b = mk(0.01, 1);
        b.cluster.nodes.push(NodeClusterReport::default());
        a.merge(&b);
        assert!(a.cluster.is_empty());
        // merging two disabled (empty) reports stays empty
        let mut x = SimReport::from_outcomes(&[], &policy);
        x.merge(&SimReport::from_outcomes(&[], &policy));
        assert!(x.cluster.is_empty());
    }

    #[test]
    fn radio_slices_merge_elementwise_and_clear_on_mismatch() {
        let policy = LatencyManagement::Joint { b_total: 1.0 };
        let mk = |ho: u64, iot: f64| {
            let mut r = SimReport::from_outcomes(&[done(0.01, 0.0, 0.05)], &policy);
            let mut cr = CellRadioReport {
                handovers_in: ho,
                handovers_out: ho + 1,
                ..Default::default()
            };
            cr.iot_db.push(iot);
            r.radio = vec![cr];
            r
        };
        let mut a = mk(2, 1.0);
        a.merge(&mk(3, 3.0));
        assert_eq!(a.radio.len(), 1);
        assert_eq!(a.radio[0].handovers_in, 5);
        assert_eq!(a.radio[0].handovers_out, 7);
        assert_eq!(a.radio[0].iot_db.count(), 2);
        assert!((a.radio[0].iot_db.mean() - 2.0).abs() < 1e-12);
        // mismatched topology clears the radio breakdown
        let mut b = mk(1, 1.0);
        b.radio.push(CellRadioReport::default());
        a.merge(&b);
        assert!(a.radio.is_empty());
    }

    #[test]
    fn per_cell_slices_sum_to_overall_and_merge_exactly() {
        let policy = LatencyManagement::Joint { b_total: 1.0 };
        let classes = vec![("c".to_string(), policy)];
        let mk = |cells: &[u32]| {
            let outcomes: Vec<JobOutcome> = cells
                .iter()
                .map(|&cell| JobOutcome { cell_id: cell, ..done(0.01, 0.0, 0.05) })
                .collect();
            SimReport::from_outcomes_per_class(&outcomes, &classes, 3)
        };
        let mut a = mk(&[0, 2, 2]);
        assert_eq!(a.per_cell.len(), 3);
        assert_eq!(a.per_cell[0].name, "cell0");
        assert_eq!(a.per_cell[0].n_jobs, 1);
        assert_eq!(a.per_cell[1].n_jobs, 0);
        assert_eq!(a.per_cell[2].n_jobs, 2);
        let sum: u64 = a.per_cell.iter().map(|c| c.n_jobs).sum();
        assert_eq!(sum, a.n_jobs);
        // replications with the same topology merge slice-wise
        let b = mk(&[1, 2]);
        a.merge(&b);
        assert_eq!(a.per_cell[1].n_jobs, 1);
        assert_eq!(a.per_cell[2].n_jobs, 3);
        let sum: u64 = a.per_cell.iter().map(|c| c.n_jobs).sum();
        assert_eq!(sum, a.n_jobs);
        // the JSON report carries the slices
        assert!(a.to_json().contains("\"per_cell\""));
        // a mismatched topology clears the breakdown instead of lying
        let other = SimReport::from_outcomes_per_class(
            &[done(0.01, 0.0, 0.05)],
            &classes,
            2,
        );
        a.merge(&other);
        assert!(a.per_cell.is_empty());
    }
}
