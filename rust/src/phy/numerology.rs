//! 5G NR numerology: subcarrier spacing, slot timing, PRB grid.
//!
//! Table I uses 60 kHz SCS (μ = 2) over a 100 MHz carrier at 3.7 GHz —
//! FR1. Per TS 38.101-1 Table 5.3.2-1, a 100 MHz / 60 kHz carrier has
//! N_RB = 135 resource blocks; a slot at μ = 2 lasts 0.25 ms.

/// NR numerology μ ∈ {0..4}: SCS = 15·2^μ kHz.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Numerology {
    pub mu: u8,
}

impl Numerology {
    pub fn new(mu: u8) -> Self {
        assert!(mu <= 4, "NR defines μ in 0..=4");
        Self { mu }
    }

    /// Table I: 60 kHz SCS.
    pub fn scs60() -> Self {
        Self::new(2)
    }

    /// Subcarrier spacing in Hz.
    pub fn scs_hz(&self) -> f64 {
        15_000.0 * (1 << self.mu) as f64
    }

    /// Slot duration in seconds (1 ms / 2^μ).
    pub fn slot_duration(&self) -> f64 {
        1e-3 / (1 << self.mu) as f64
    }

    /// Slots per subframe (1 ms).
    pub fn slots_per_subframe(&self) -> u32 {
        1 << self.mu
    }
}

/// OFDM symbols per slot (normal cyclic prefix).
pub const SYMBOLS_PER_SLOT: u32 = 14;
/// Subcarriers per PRB.
pub const SUBCARRIERS_PER_PRB: u32 = 12;

/// Carrier configuration.
#[derive(Debug, Clone, Copy)]
pub struct Carrier {
    pub numerology: Numerology,
    /// Carrier frequency in Hz (Table I: 3.7 GHz).
    pub freq_hz: f64,
    /// Channel bandwidth in Hz (Table I: 100 MHz).
    pub bandwidth_hz: f64,
    /// Number of usable PRBs.
    pub n_prb: u32,
}

impl Carrier {
    /// Table I carrier: 3.7 GHz, 100 MHz, 60 kHz SCS → 135 PRBs
    /// (TS 38.101-1 Table 5.3.2-1).
    pub fn table1() -> Self {
        Self {
            numerology: Numerology::scs60(),
            freq_hz: 3.7e9,
            bandwidth_hz: 100e6,
            n_prb: 135,
        }
    }

    /// Approximate usable PRBs for a given BW/SCS (guard-band aware
    /// values for the common FR1 cases, else a 0.95-utilization
    /// approximation). Used for non-Table-I configs.
    pub fn derive_n_prb(bandwidth_hz: f64, num: Numerology) -> u32 {
        let known = [
            // (bw_mhz, mu, n_rb) — TS 38.101-1 Table 5.3.2-1 excerpts
            (100.0, 1, 273u32),
            (100.0, 2, 135),
            (50.0, 2, 66),
            (40.0, 1, 106),
            (20.0, 0, 106),
            (20.0, 1, 51),
        ];
        let bw_mhz = bandwidth_hz / 1e6;
        for (b, mu, n) in known {
            if (bw_mhz - b).abs() < 0.5 && num.mu == mu {
                return n;
            }
        }
        let prb_bw = num.scs_hz() * SUBCARRIERS_PER_PRB as f64;
        ((bandwidth_hz * 0.95) / prb_bw) as u32
    }

    /// Data resource elements per PRB per slot after control/DMRS
    /// overhead (~2 of 14 symbols for UL DMRS + PUCCH).
    pub fn data_re_per_prb_slot(&self) -> u32 {
        SUBCARRIERS_PER_PRB * (SYMBOLS_PER_SLOT - 2)
    }

    /// Slot duration shortcut.
    pub fn slot_duration(&self) -> f64 {
        self.numerology.slot_duration()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scs60_timing() {
        let n = Numerology::scs60();
        assert_eq!(n.scs_hz(), 60_000.0);
        assert_eq!(n.slot_duration(), 0.25e-3);
        assert_eq!(n.slots_per_subframe(), 4);
    }

    #[test]
    fn table1_carrier() {
        let c = Carrier::table1();
        assert_eq!(c.n_prb, 135);
        assert_eq!(c.freq_hz, 3.7e9);
        assert_eq!(c.slot_duration(), 0.25e-3);
        assert_eq!(c.data_re_per_prb_slot(), 144);
    }

    #[test]
    fn derive_known_and_approx() {
        assert_eq!(Carrier::derive_n_prb(100e6, Numerology::new(2)), 135);
        assert_eq!(Carrier::derive_n_prb(100e6, Numerology::new(1)), 273);
        // Unknown combo falls back near 0.95 utilization
        let n = Carrier::derive_n_prb(30e6, Numerology::new(2));
        assert!((35..=41).contains(&n), "n = {n}");
    }

    #[test]
    #[should_panic]
    fn mu_out_of_range() {
        Numerology::new(5);
    }
}
