//! 5G physical-layer substrate of the SLS (paper §IV-A "implemented a
//! system level simulator … using certain channel realization and
//! protocols").
//!
//! * [`numerology`] — SCS/slot/PRB grid (Table I: 60 kHz, 100 MHz).
//! * [`channel`] — TR 38.901 UMa pathloss, LOS, shadowing, fast fading.
//! * [`link`] — UL power control, SINR, CQI/MCS mapping, TBS.

pub mod channel;
pub mod link;
pub mod numerology;

pub use channel::{LargeScale, Position};
pub use link::{PowerControl, Receiver};
pub use numerology::{Carrier, Numerology};
