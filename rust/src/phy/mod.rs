//! 5G physical-layer substrate of the SLS (paper §IV-A "implemented a
//! system level simulator … using certain channel realization and
//! protocols").
//!
//! * [`numerology`] — SCS/slot/PRB grid (Table I: 60 kHz, 100 MHz).
//! * [`channel`] — TR 38.901 UMa pathloss, LOS, shadowing, fast fading.
//! * [`link`] — UL power control, SINR, CQI/MCS mapping, TBS.
//! * [`geometry`] — multi-site layouts + per-(UE, cell) coupling-loss
//!   cache for coupled-radio scenarios.
//! * [`mobility`] — random-waypoint / fixed-velocity UE motion on a
//!   coarse tick.

pub mod channel;
pub mod geometry;
pub mod link;
pub mod mobility;
pub mod numerology;

pub use channel::{LargeScale, Position};
pub use geometry::{CellGeo, LinkState, SiteLayout, TopologySpec, UeGeo};
pub use link::{PowerControl, Receiver};
pub use mobility::{MobilityModel, MobilitySpec};
pub use numerology::{Carrier, Numerology};
