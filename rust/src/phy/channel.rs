//! Urban-macrocell channel model (3GPP TR 38.901 §7.4.1/7.4.2 UMa).
//!
//! Implements what the SLS needs for "certain channel realization"
//! (paper §IV-A): UMa LOS probability, LOS/NLOS pathloss, log-normal
//! shadowing (σ = 4 dB LOS / 6 dB NLOS), and per-slot fast fading as a
//! Rayleigh/Rician SINR perturbation. Distances in meters, frequencies
//! in Hz, gains in dB.

use crate::rng::Rng;

/// Antenna/geometry constants for the UMa scenario.
pub const BS_HEIGHT_M: f64 = 25.0;
pub const UT_HEIGHT_M: f64 = 1.5;
const C: f64 = 299_792_458.0;

/// A UE's (planar) position relative to the gNB at the origin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Position {
    pub x: f64,
    pub y: f64,
}

impl Position {
    pub fn dist_2d(&self) -> f64 {
        (self.x * self.x + self.y * self.y).sqrt()
    }

    pub fn dist_3d(&self) -> f64 {
        let dh = BS_HEIGHT_M - UT_HEIGHT_M;
        (self.dist_2d().powi(2) + dh * dh).sqrt()
    }

    /// Uniform placement in an annulus [r_min, r_max] around the gNB.
    pub fn random_in_cell(rng: &mut Rng, r_min: f64, r_max: f64) -> Self {
        // Uniform over area: r = sqrt(U·(r_max²−r_min²) + r_min²)
        let u = rng.f64();
        let r = (u * (r_max * r_max - r_min * r_min) + r_min * r_min).sqrt();
        let theta = rng.range(0.0, 2.0 * std::f64::consts::PI);
        Self { x: r * theta.cos(), y: r * theta.sin() }
    }
}

/// UMa LOS probability (TR 38.901 Table 7.4.2-1, h_UT ≤ 13 m).
pub fn los_probability(d2d: f64) -> f64 {
    if d2d <= 18.0 {
        1.0
    } else {
        (18.0 / d2d + (-d2d / 63.0).exp() * (1.0 - 18.0 / d2d)).clamp(0.0, 1.0)
    }
}

/// Breakpoint distance d'_BP = 4 h'_BS h'_UT f / c (effective heights:
/// h − 1 m for UMa).
fn breakpoint_distance(freq_hz: f64) -> f64 {
    4.0 * (BS_HEIGHT_M - 1.0) * (UT_HEIGHT_M - 1.0).max(0.1) * freq_hz / C
}

/// UMa LOS pathloss in dB (TR 38.901 Table 7.4.1-1).
pub fn pathloss_los_db(d3d: f64, freq_hz: f64) -> f64 {
    let fc_ghz = freq_hz / 1e9;
    let d2d = (d3d.powi(2) - (BS_HEIGHT_M - UT_HEIGHT_M).powi(2)).max(1.0).sqrt();
    let dbp = breakpoint_distance(freq_hz);
    if d2d <= dbp {
        28.0 + 22.0 * d3d.max(1.0).log10() + 20.0 * fc_ghz.log10()
    } else {
        28.0 + 40.0 * d3d.max(1.0).log10() + 20.0 * fc_ghz.log10()
            - 9.0 * (dbp.powi(2) + (BS_HEIGHT_M - UT_HEIGHT_M).powi(2)).log10()
    }
}

/// UMa NLOS pathloss in dB: max(PL_LOS, PL'_NLOS).
pub fn pathloss_nlos_db(d3d: f64, freq_hz: f64) -> f64 {
    let fc_ghz = freq_hz / 1e9;
    let pl_nlos = 13.54 + 39.08 * d3d.max(1.0).log10() + 20.0 * fc_ghz.log10()
        - 0.6 * (UT_HEIGHT_M - 1.5);
    pathloss_los_db(d3d, freq_hz).max(pl_nlos)
}

/// Shadow-fading standard deviations (TR 38.901 Table 7.4.1-1).
pub const SHADOW_STD_LOS_DB: f64 = 4.0;
pub const SHADOW_STD_NLOS_DB: f64 = 6.0;

/// A UE's large-scale channel state (drawn once at drop time).
#[derive(Debug, Clone, Copy)]
pub struct LargeScale {
    pub pos: Position,
    pub los: bool,
    pub shadow_db: f64,
}

impl LargeScale {
    /// Drop a UE uniformly in the cell and draw LOS + shadowing.
    pub fn drop(rng: &mut Rng, r_min: f64, r_max: f64) -> Self {
        let pos = Position::random_in_cell(rng, r_min, r_max);
        let los = rng.bernoulli(los_probability(pos.dist_2d()));
        let sigma = if los { SHADOW_STD_LOS_DB } else { SHADOW_STD_NLOS_DB };
        Self { pos, los, shadow_db: rng.normal(0.0, sigma) }
    }

    /// Total large-scale loss (pathloss + shadowing) in dB.
    pub fn coupling_loss_db(&self, freq_hz: f64) -> f64 {
        let d3d = self.pos.dist_3d();
        let pl = if self.los {
            pathloss_los_db(d3d, freq_hz)
        } else {
            pathloss_nlos_db(d3d, freq_hz)
        };
        pl + self.shadow_db
    }
}

/// Per-slot fast-fading power gain (linear). LOS → Rician (K = 9 dB),
/// NLOS → Rayleigh. Mean power is normalized to 1.
pub fn fast_fading_gain(rng: &mut Rng, los: bool) -> f64 {
    if los {
        // Rician with K = 9 dB: dominant + scattered component.
        let k = 10f64.powf(0.9);
        let sigma = (1.0 / (2.0 * (k + 1.0))).sqrt();
        let mean = (k / (k + 1.0)).sqrt();
        let i = mean + sigma * rng.gauss();
        let q = sigma * rng.gauss();
        (i * i + q * q).max(1e-6)
    } else {
        // Rayleigh: |h|² ~ Exp(1).
        rng.exp(1.0).max(1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn los_probability_monotone_decreasing() {
        let mut prev = 1.0;
        for d in [1.0, 18.0, 50.0, 100.0, 200.0, 500.0] {
            let p = los_probability(d);
            assert!((0.0..=1.0).contains(&p));
            assert!(p <= prev + 1e-12, "d={d}");
            prev = p;
        }
        assert_eq!(los_probability(10.0), 1.0);
        assert!(los_probability(500.0) < 0.1);
    }

    #[test]
    fn pathloss_increases_with_distance() {
        let f = 3.7e9;
        let mut prev = 0.0;
        for d in [30.0, 60.0, 120.0, 240.0, 480.0] {
            let pl = pathloss_los_db(d, f);
            assert!(pl > prev, "d={d}: {pl}");
            prev = pl;
        }
    }

    #[test]
    fn nlos_never_below_los() {
        let f = 3.7e9;
        for d in [30.0, 100.0, 300.0, 800.0] {
            assert!(pathloss_nlos_db(d, f) >= pathloss_los_db(d, f) - 1e-9);
        }
    }

    #[test]
    fn pathloss_sane_at_table1_geometry() {
        // 3.7 GHz, 150 m: expect roughly 90–125 dB coupling loss.
        let pl = pathloss_los_db(150.0, 3.7e9);
        assert!((85.0..=115.0).contains(&pl), "LOS PL = {pl}");
        let pn = pathloss_nlos_db(150.0, 3.7e9);
        assert!((100.0..=135.0).contains(&pn), "NLOS PL = {pn}");
    }

    #[test]
    fn annulus_placement_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let p = Position::random_in_cell(&mut rng, 35.0, 300.0);
            let d = p.dist_2d();
            assert!((35.0..=300.0).contains(&d), "d = {d}");
        }
    }

    #[test]
    fn annulus_placement_uniform_over_area() {
        // Half-area radius of [35, 300]: r_h = sqrt((35²+300²)/2) ≈ 213.6
        let mut rng = Rng::new(2);
        let n = 20_000;
        let r_half = ((35.0f64.powi(2) + 300.0f64.powi(2)) / 2.0).sqrt();
        let inside = (0..n)
            .filter(|_| Position::random_in_cell(&mut rng, 35.0, 300.0).dist_2d() < r_half)
            .count();
        let frac = inside as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "frac = {frac}");
    }

    #[test]
    fn fast_fading_mean_power_unit() {
        let mut rng = Rng::new(3);
        for los in [true, false] {
            let n = 100_000;
            let mean: f64 =
                (0..n).map(|_| fast_fading_gain(&mut rng, los)).sum::<f64>() / n as f64;
            assert!((mean - 1.0).abs() < 0.03, "los={los}: mean = {mean}");
        }
    }

    #[test]
    fn rician_has_lower_variance_than_rayleigh() {
        let mut rng = Rng::new(4);
        let var = |los: bool, rng: &mut Rng| {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| fast_fading_gain(rng, los)).collect();
            let m = xs.iter().sum::<f64>() / n as f64;
            xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n as f64
        };
        assert!(var(true, &mut rng) < var(false, &mut rng));
    }

    #[test]
    fn coupling_loss_includes_shadowing() {
        let mut rng = Rng::new(5);
        let ls = LargeScale::drop(&mut rng, 35.0, 300.0);
        let base = if ls.los {
            pathloss_los_db(ls.pos.dist_3d(), 3.7e9)
        } else {
            pathloss_nlos_db(ls.pos.dist_3d(), 3.7e9)
        };
        assert!((ls.coupling_loss_db(3.7e9) - base - ls.shadow_db).abs() < 1e-9);
    }
}
