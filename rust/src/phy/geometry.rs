//! Multi-site geometry for coupled-radio scenarios: site layouts,
//! per-(UE, cell) link state, and the cached coupling-loss table that
//! the dynamic inter-cell interference and A3 handover layers read.
//!
//! The legacy radio model keeps every cell at the origin and absorbs
//! neighbor-cell interference into a fixed margin. With a
//! [`TopologySpec`] the scenario instead places its gNBs on a
//! hexagonal or linear site grid (configurable inter-site distance),
//! gives every UE a *global* 2D position, and maintains a per-(UE,
//! site) coupling-loss cache (`pathloss + per-link shadowing`, LOS
//! state drawn once per link at drop time) that is refreshed only when
//! the UE moves — so the per-slot hot path never recomputes a
//! pathloss.
//!
//! All large-scale draws come from dedicated substreams (`0xD1` for
//! the neighbor-link LOS/shadowing of a cell, `0x4000_0000_0000 + ue`
//! for per-UE mobility), disjoint from every legacy stream id, so a
//! topology-disabled run consumes exactly the legacy draw sequence.

use crate::phy::channel::{
    los_probability, pathloss_los_db, pathloss_nlos_db, LargeScale, Position,
    SHADOW_STD_LOS_DB, SHADOW_STD_NLOS_DB,
};
use crate::rng::Rng;

use super::mobility::MobilitySpec;

/// Site grid shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteLayout {
    /// Hexagonal spiral: cell 0 at the origin, ring `r` holds `6r`
    /// sites at hex distance `r` (the classic 7/19-site deployments).
    Hex,
    /// Sites on a line along +x, `isd` apart.
    Linear,
}

impl SiteLayout {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hex" | "hexagonal" => Some(Self::Hex),
            "linear" | "line" => Some(Self::Linear),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Hex => "hex",
            Self::Linear => "linear",
        }
    }
}

/// Site layout of a coupled-radio scenario: grid shape + inter-site
/// distance. Presence of a topology is what switches the radio stack
/// from the fixed interference margin to geometry-driven coupling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopologySpec {
    pub layout: SiteLayout,
    /// Inter-site distance in meters.
    pub isd_m: f64,
}

impl TopologySpec {
    pub fn hex(isd_m: f64) -> Self {
        assert!(isd_m > 0.0, "inter-site distance must be positive");
        Self { layout: SiteLayout::Hex, isd_m }
    }

    pub fn linear(isd_m: f64) -> Self {
        assert!(isd_m > 0.0, "inter-site distance must be positive");
        Self { layout: SiteLayout::Linear, isd_m }
    }

    /// Ring (graph) distance between sites `a` and `b`: hex distance
    /// on the spiral's axial coordinates for [`SiteLayout::Hex`]
    /// (ring `r` of the spiral is exactly the set at distance `r`
    /// from cell 0), index distance for [`SiteLayout::Linear`]. The
    /// fluid-tier focus classification is defined in terms of this
    /// metric, not Euclidean meters, so it is ISD-independent.
    pub fn ring_distance(&self, a: usize, b: usize) -> u64 {
        match self.layout {
            SiteLayout::Linear => a.abs_diff(b) as u64,
            SiteLayout::Hex => {
                let (qa, ra) = hex_axial(a);
                let (qb, rb) = hex_axial(b);
                let (dq, dr) = (qa - qb, ra - rb);
                ((dq.abs() + dr.abs() + (dq + dr).abs()) / 2) as u64
            }
        }
    }

    /// Global position of site `k`.
    pub fn site_position(&self, k: usize) -> Position {
        match self.layout {
            SiteLayout::Linear => Position { x: k as f64 * self.isd_m, y: 0.0 },
            SiteLayout::Hex => {
                let (q, r) = hex_axial(k);
                // pointy-top axial → pixel with unit hex distance = isd
                Position {
                    x: self.isd_m * (q as f64 + r as f64 / 2.0),
                    y: self.isd_m * (3f64.sqrt() / 2.0) * r as f64,
                }
            }
        }
    }
}

/// Axial coordinates of the `k`-th cell of a hexagonal spiral
/// (ring 0 = center, ring r traversed side by side).
fn hex_axial(k: usize) -> (i64, i64) {
    if k == 0 {
        return (0, 0);
    }
    const DIRS: [(i64, i64); 6] = [(1, 0), (1, -1), (0, -1), (-1, 0), (-1, 1), (0, 1)];
    let mut ring = 1usize;
    let mut first = 1usize; // index of the first cell of this ring
    while k >= first + 6 * ring {
        first += 6 * ring;
        ring += 1;
    }
    let idx = k - first;
    let (side, step) = (idx / ring, idx % ring);
    // ring start is dir[4] scaled by the ring radius
    let (mut q, mut r) = (-(ring as i64), ring as i64);
    for d in DIRS.iter().take(side) {
        q += d.0 * ring as i64;
        r += d.1 * ring as i64;
    }
    q += DIRS[side].0 * step as i64;
    r += DIRS[side].1 * step as i64;
    (q, r)
}

/// Large-scale state of one UE↔site link: LOS and shadowing are drawn
/// once per link (drop time); the coupling loss is a cache refreshed
/// whenever the UE moves.
#[derive(Debug, Clone, Copy)]
pub struct LinkState {
    pub los: bool,
    pub shadow_db: f64,
    /// Cached total coupling loss (pathloss + shadowing), dB.
    pub cl_db: f64,
}

/// Total coupling loss of a UE at global position `ue` toward `site`
/// (same UMa pathloss family as [`LargeScale::coupling_loss_db`]).
pub fn link_loss_db(ue: Position, site: Position, freq_hz: f64, los: bool, shadow_db: f64) -> f64 {
    let rel = Position { x: ue.x - site.x, y: ue.y - site.y };
    let d3d = rel.dist_3d();
    let pl = if los { pathloss_los_db(d3d, freq_hz) } else { pathloss_nlos_db(d3d, freq_hz) };
    pl + shadow_db
}

/// Per-UE geometry state: global position, the per-site link cache,
/// the UE's own mobility stream (it migrates with the UE across
/// handovers, so trajectories are independent of serving-cell
/// history), and the A3 time-to-trigger bookkeeping.
#[derive(Debug, Clone)]
pub struct UeGeo {
    /// Global 2D position.
    pub pos: Position,
    /// Per-site link state, indexed by cell.
    pub links: Vec<LinkState>,
    /// Current speed (m/s; random-waypoint redraws it per leg).
    pub speed: f64,
    /// Unit heading (fixed-velocity model).
    pub heading: (f64, f64),
    /// Current leg target (random-waypoint model).
    pub waypoint: Position,
    /// Mobility randomness of this UE.
    pub rng: Rng,
    /// Current A3 candidate cell (`u32::MAX` = none).
    pub a3_target: u32,
    /// Consecutive radio ticks the A3 condition has held.
    pub a3_ticks: u32,
}

impl UeGeo {
    /// Recompute the cached coupling losses after a position change.
    pub fn refresh_losses(&mut self, sites: &[Position], freq_hz: f64) {
        for (j, l) in self.links.iter_mut().enumerate() {
            l.cl_db = link_loss_db(self.pos, sites[j], freq_hz, l.los, l.shadow_db);
        }
    }

    /// Gudmundson spatially-correlated shadowing: after moving
    /// `dist_m` meters, each link's shadow fading evolves as the
    /// exponentially-decorrelated AR(1) process
    ///
    /// ```text
    /// rho = exp(-dist / d_corr)
    /// shadow' = rho * shadow + sqrt(1 - rho^2) * N(0, sigma)
    /// ```
    ///
    /// with `sigma` the link's own LOS/NLOS shadowing std, so the
    /// marginal distribution is preserved while long drives forget the
    /// drop-time draw. One normal draw per link, ascending site order,
    /// from the UE's own mobility stream — the caller skips the call
    /// entirely when correlation is disabled, so the default
    /// configuration consumes exactly the legacy draw sequence. The
    /// caller refreshes the coupling-loss cache afterwards.
    pub fn decorrelate_shadowing(&mut self, dist_m: f64, d_corr_m: f64) {
        debug_assert!(d_corr_m > 0.0, "decorrelation distance must be positive");
        if dist_m <= 0.0 {
            return;
        }
        let rho = (-dist_m / d_corr_m).exp();
        let scale = (1.0 - rho * rho).sqrt();
        for l in &mut self.links {
            let sigma = if l.los { SHADOW_STD_LOS_DB } else { SHADOW_STD_NLOS_DB };
            l.shadow_db = rho * l.shadow_db + scale * self.rng.normal(0.0, sigma);
        }
    }
}

/// Geometry state of one cell: the shared site table, which neighbor
/// cells couple (same carrier — they interfere and are handover
/// candidates), the deployment disc for mobility, and the per-UE
/// records (parallel to the cell's `UeBank`, kept in lockstep across
/// handovers).
#[derive(Debug, Clone)]
pub struct CellGeo {
    /// This cell's index in the site table.
    pub cell: usize,
    /// Global site positions of every cell.
    pub sites: Vec<Position>,
    /// `coupled[j]`: cell `j` shares this cell's carrier (frequency +
    /// numerology) — it contributes interference and is a valid
    /// handover target. `coupled[cell]` is false.
    pub coupled: Vec<bool>,
    /// Mobility area: UEs roam inside this disc.
    pub area_center: Position,
    pub area_radius: f64,
    /// Per-UE geometry, index-parallel to the cell's bank.
    pub ues: Vec<UeGeo>,
}

impl CellGeo {
    /// Build the geometry of cell `cell` from its dropped population.
    /// `serving[i]` is UE `i`'s legacy serving-link state (position
    /// relative to the cell site, LOS, shadowing) — reused verbatim so
    /// the serving link is exactly the one the scheduler prices.
    /// Neighbor-link LOS/shadowing draw from substream `0xD1` of the
    /// cell seed; per-UE mobility streams from `0x4000_0000_0000 + i`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cell: usize,
        sites: Vec<Position>,
        coupled: Vec<bool>,
        freq_hz: f64,
        cell_seed: u64,
        serving: &[LargeScale],
        cell_r_max: f64,
        mobility: Option<&MobilitySpec>,
    ) -> Self {
        let n_sites = sites.len();
        let site = sites[cell];
        let (mut cx, mut cy) = (0.0, 0.0);
        for s in &sites {
            cx += s.x;
            cy += s.y;
        }
        let area_center =
            Position { x: cx / n_sites as f64, y: cy / n_sites as f64 };
        let area_radius = sites
            .iter()
            .map(|s| {
                let (dx, dy) = (s.x - area_center.x, s.y - area_center.y);
                (dx * dx + dy * dy).sqrt()
            })
            .fold(0.0f64, f64::max)
            + cell_r_max;
        let mut rng_geo = Rng::substream(cell_seed, 0xD1);
        let ues = serving
            .iter()
            .enumerate()
            .map(|(i, ls)| {
                let pos = Position { x: site.x + ls.pos.x, y: site.y + ls.pos.y };
                let links: Vec<LinkState> = (0..n_sites)
                    .map(|j| {
                        if j == cell {
                            LinkState { los: ls.los, shadow_db: ls.shadow_db, cl_db: 0.0 }
                        } else {
                            let rel = Position {
                                x: pos.x - sites[j].x,
                                y: pos.y - sites[j].y,
                            };
                            let los = rng_geo.bernoulli(los_probability(rel.dist_2d()));
                            let sigma =
                                if los { SHADOW_STD_LOS_DB } else { SHADOW_STD_NLOS_DB };
                            LinkState {
                                los,
                                shadow_db: rng_geo.normal(0.0, sigma),
                                cl_db: 0.0,
                            }
                        }
                    })
                    .collect();
                let mut ue = UeGeo {
                    pos,
                    links,
                    speed: 0.0,
                    heading: (1.0, 0.0),
                    waypoint: pos,
                    rng: Rng::substream(cell_seed, 0x4000_0000_0000 + i as u64),
                    a3_target: u32::MAX,
                    a3_ticks: 0,
                };
                if let Some(mob) = mobility {
                    mob.model.init(&mut ue, area_center, area_radius);
                }
                ue.refresh_losses(&sites, freq_hz);
                ue
            })
            .collect();
        Self { cell, sites, coupled, area_center, area_radius, ues }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_layout_spaces_sites_by_isd() {
        let t = TopologySpec::linear(500.0);
        for k in 0..5 {
            let p = t.site_position(k);
            assert_eq!(p.x, 500.0 * k as f64);
            assert_eq!(p.y, 0.0);
        }
    }

    #[test]
    fn hex_layout_first_ring_is_isd_away_and_distinct() {
        let t = TopologySpec::hex(500.0);
        let center = t.site_position(0);
        assert_eq!((center.x, center.y), (0.0, 0.0));
        let mut seen: Vec<(i64, i64)> = Vec::new();
        for k in 1..=6 {
            let p = t.site_position(k);
            let d = (p.x * p.x + p.y * p.y).sqrt();
            assert!((d - 500.0).abs() < 1e-9, "site {k} at distance {d}");
            let key = ((p.x * 1e6) as i64, (p.y * 1e6) as i64);
            assert!(!seen.contains(&key), "duplicate site {k}");
            seen.push(key);
        }
        // second ring sits strictly farther out
        for k in 7..=18 {
            let p = t.site_position(k);
            let d = (p.x * p.x + p.y * p.y).sqrt();
            assert!(d > 500.0 + 1e-9, "site {k} at distance {d}");
            assert!(d < 2.0 * 500.0 + 1e-9, "site {k} at distance {d}");
        }
    }

    #[test]
    fn hex_spiral_positions_are_unique_over_many_rings() {
        let t = TopologySpec::hex(200.0);
        let mut seen: Vec<(i64, i64)> = Vec::new();
        for k in 0..61 {
            let p = t.site_position(k);
            let key = ((p.x * 1e6).round() as i64, (p.y * 1e6).round() as i64);
            assert!(!seen.contains(&key), "site {k} collides");
            seen.push(key);
        }
    }

    #[test]
    fn ring_distance_matches_spiral_rings() {
        let t = TopologySpec::hex(500.0);
        // spiral ring r = hex distance r from the center
        for k in 1..=6 {
            assert_eq!(t.ring_distance(0, k), 1, "site {k}");
        }
        for k in 7..=18 {
            assert_eq!(t.ring_distance(0, k), 2, "site {k}");
        }
        for k in 19..=36 {
            assert_eq!(t.ring_distance(0, k), 3, "site {k}");
        }
        // symmetric, zero on the diagonal
        for a in 0..19 {
            assert_eq!(t.ring_distance(a, a), 0);
            for b in 0..19 {
                assert_eq!(t.ring_distance(a, b), t.ring_distance(b, a));
            }
        }
        // triangle inequality over the first two rings
        for a in 0..19 {
            for b in 0..19 {
                for c in 0..19 {
                    assert!(
                        t.ring_distance(a, c)
                            <= t.ring_distance(a, b) + t.ring_distance(b, c)
                    );
                }
            }
        }
        let l = TopologySpec::linear(500.0);
        assert_eq!(l.ring_distance(2, 5), 3);
        assert_eq!(l.ring_distance(5, 2), 3);
        assert_eq!(l.ring_distance(4, 4), 0);
    }

    #[test]
    fn gudmundson_decorrelation_limits_are_exact() {
        use crate::rng::Rng;
        let mk = || UeGeo {
            pos: Position { x: 10.0, y: 0.0 },
            links: vec![
                LinkState { los: true, shadow_db: 3.0, cl_db: 0.0 },
                LinkState { los: false, shadow_db: -2.0, cl_db: 0.0 },
            ],
            speed: 0.0,
            heading: (1.0, 0.0),
            waypoint: Position { x: 10.0, y: 0.0 },
            rng: Rng::new(5),
            a3_target: u32::MAX,
            a3_ticks: 0,
        };
        // zero travel: identity, zero draws
        let mut ue = mk();
        ue.decorrelate_shadowing(0.0, 50.0);
        assert_eq!(ue.links[0].shadow_db.to_bits(), 3f64.to_bits());
        assert_eq!(ue.links[1].shadow_db.to_bits(), (-2f64).to_bits());
        // a huge hop forgets the old draw entirely (rho ~ 0): the new
        // value is a fresh N(0, sigma) sample, one per link
        let mut far = mk();
        far.decorrelate_shadowing(1e9, 50.0);
        let mut rng = Rng::new(5);
        let e0 = rng.normal(0.0, SHADOW_STD_LOS_DB);
        let e1 = rng.normal(0.0, SHADOW_STD_NLOS_DB);
        assert!((far.links[0].shadow_db - e0).abs() < 1e-9);
        assert!((far.links[1].shadow_db - e1).abs() < 1e-9);
        // short hops stay near the old value and are deterministic
        let mut a = mk();
        let mut b = mk();
        for _ in 0..10 {
            a.decorrelate_shadowing(1.0, 50.0);
            b.decorrelate_shadowing(1.0, 50.0);
        }
        assert_eq!(a.links[0].shadow_db.to_bits(), b.links[0].shadow_db.to_bits());
        assert!(a.links[0].shadow_db.is_finite());
        assert_ne!(a.links[0].shadow_db.to_bits(), 3f64.to_bits());
    }

    #[test]
    fn link_loss_matches_large_scale_for_the_serving_site() {
        let mut rng = Rng::new(7);
        let ls = LargeScale::drop(&mut rng, 35.0, 300.0);
        let site = Position { x: 1000.0, y: -400.0 };
        let global = Position { x: site.x + ls.pos.x, y: site.y + ls.pos.y };
        let via_geo = link_loss_db(global, site, 3.7e9, ls.los, ls.shadow_db);
        let via_ls = ls.coupling_loss_db(3.7e9);
        assert!((via_geo - via_ls).abs() < 1e-9, "{via_geo} vs {via_ls}");
    }

    #[test]
    fn cell_geo_builds_consistent_link_cache() {
        let topo = TopologySpec::hex(500.0);
        let sites: Vec<Position> = (0..3).map(|k| topo.site_position(k)).collect();
        let mut rng = Rng::new(3);
        let serving: Vec<LargeScale> =
            (0..4).map(|_| LargeScale::drop(&mut rng, 35.0, 300.0)).collect();
        let geo = CellGeo::new(
            1,
            sites.clone(),
            vec![true, false, true],
            3.7e9,
            42,
            &serving,
            300.0,
            None,
        );
        assert_eq!(geo.ues.len(), 4);
        for (i, ue) in geo.ues.iter().enumerate() {
            assert_eq!(ue.links.len(), 3);
            // serving link reproduces the legacy coupling loss
            let expect = serving[i].coupling_loss_db(3.7e9);
            assert!(
                (ue.links[1].cl_db - expect).abs() < 1e-9,
                "UE {i}: {} vs {expect}",
                ue.links[1].cl_db
            );
            // every cached loss is finite and positive at these ranges
            for l in &ue.links {
                assert!(l.cl_db.is_finite() && l.cl_db > 0.0);
            }
        }
        // deterministic per seed
        let geo2 = CellGeo::new(
            1,
            sites,
            vec![true, false, true],
            3.7e9,
            42,
            &serving,
            300.0,
            None,
        );
        for (a, b) in geo.ues.iter().zip(&geo2.ues) {
            for (la, lb) in a.links.iter().zip(&b.links) {
                assert_eq!(la.cl_db.to_bits(), lb.cl_db.to_bits());
            }
        }
    }
}
