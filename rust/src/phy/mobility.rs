//! UE mobility models on a coarse tick.
//!
//! Positions advance only on the scenario's radio tick (default
//! 100 ms — hundreds of slots apart), so mobility costs nothing on the
//! per-slot hot path: a move refreshes the UE's cached coupling losses
//! (`phy::geometry`) and invalidates its cached link budget, and the
//! slot pipeline keeps reading caches in between.
//!
//! Two classic models:
//!
//! * **Random waypoint** — pick a uniform point in the deployment
//!   disc, walk to it at a per-leg speed drawn from `[v_min, v_max]`,
//!   repeat.
//! * **Fixed velocity** — constant speed along a random heading,
//!   re-aimed toward the deployment interior when the UE reaches the
//!   boundary.
//!
//! All draws come from the UE's own mobility stream
//! ([`crate::phy::geometry::UeGeo::rng`]), which migrates with the UE
//! across handovers — trajectories never depend on serving-cell
//! history or on the order cells are visited in.

use crate::phy::channel::Position;

use super::geometry::UeGeo;

/// Motion model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MobilityModel {
    /// Walk to uniform waypoints in the deployment disc; each leg
    /// draws its speed from `[v_min, v_max]` m/s.
    RandomWaypoint { v_min: f64, v_max: f64 },
    /// Constant speed along a random heading; re-aimed inward at the
    /// deployment boundary.
    FixedVelocity { speed: f64 },
}

/// Mobility configuration: the model plus the coarse tick period and
/// the optional Gudmundson shadowing decorrelation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MobilitySpec {
    pub model: MobilityModel,
    /// Seconds between position updates (and A3 handover evaluations).
    pub tick_s: f64,
    /// Gudmundson shadowing decorrelation distance (meters). `None`
    /// (the default) keeps the drop-time shadowing draw for the whole
    /// run — bit-identical to the pre-correlation model, with zero
    /// extra RNG draws. `Some(d)` decorrelates every moved UE's
    /// per-link shadowing on each mobility tick
    /// ([`crate::phy::geometry::UeGeo::decorrelate_shadowing`]).
    pub shadow_corr_m: Option<f64>,
}

impl MobilitySpec {
    pub const DEFAULT_TICK_S: f64 = 0.1;

    pub fn waypoint(v_min: f64, v_max: f64) -> Self {
        assert!(v_min >= 0.0 && v_max >= v_min, "need 0 <= v_min <= v_max");
        Self {
            model: MobilityModel::RandomWaypoint { v_min, v_max },
            tick_s: Self::DEFAULT_TICK_S,
            shadow_corr_m: None,
        }
    }

    pub fn fixed(speed: f64) -> Self {
        assert!(speed >= 0.0, "speed must be >= 0");
        Self {
            model: MobilityModel::FixedVelocity { speed },
            tick_s: Self::DEFAULT_TICK_S,
            shadow_corr_m: None,
        }
    }

    pub fn with_tick(mut self, tick_s: f64) -> Self {
        assert!(tick_s > 0.0, "mobility tick must be positive");
        self.tick_s = tick_s;
        self
    }

    /// Enable Gudmundson spatially-correlated shadowing with the given
    /// decorrelation distance (meters).
    pub fn with_shadow_corr(mut self, d_corr_m: f64) -> Self {
        assert!(d_corr_m > 0.0, "decorrelation distance must be positive");
        self.shadow_corr_m = Some(d_corr_m);
        self
    }
}

impl MobilityModel {
    /// Draw the UE's initial mobility state (leg target / heading).
    pub fn init(&self, ue: &mut UeGeo, center: Position, radius: f64) {
        match *self {
            MobilityModel::RandomWaypoint { v_min, v_max } => {
                ue.waypoint = uniform_in_disc(ue, center, radius);
                ue.speed = ue.rng.range(v_min, v_max.max(v_min + 1e-12));
            }
            MobilityModel::FixedVelocity { speed } => {
                let theta = ue.rng.range(0.0, 2.0 * std::f64::consts::PI);
                ue.heading = (theta.cos(), theta.sin());
                ue.speed = speed;
            }
        }
    }

    /// Advance the UE by `dt` seconds inside the deployment disc.
    /// Returns true if the position changed (the caller then refreshes
    /// the coupling-loss cache).
    pub fn advance(&self, ue: &mut UeGeo, center: Position, radius: f64, dt: f64) -> bool {
        match *self {
            MobilityModel::RandomWaypoint { v_min, v_max } => {
                let mut step = ue.speed * dt;
                if step <= 0.0 {
                    return false;
                }
                // walk leg by leg; a fast UE may finish several legs
                // inside one coarse tick
                loop {
                    let (dx, dy) = (ue.waypoint.x - ue.pos.x, ue.waypoint.y - ue.pos.y);
                    let d = (dx * dx + dy * dy).sqrt();
                    if d <= step {
                        ue.pos = ue.waypoint;
                        step -= d;
                        ue.waypoint = uniform_in_disc(ue, center, radius);
                        ue.speed = ue.rng.range(v_min, v_max.max(v_min + 1e-12));
                        if step <= 0.0 {
                            break;
                        }
                    } else {
                        ue.pos.x += dx / d * step;
                        ue.pos.y += dy / d * step;
                        break;
                    }
                }
                true
            }
            MobilityModel::FixedVelocity { speed } => {
                if speed <= 0.0 {
                    return false;
                }
                ue.pos.x += ue.heading.0 * speed * dt;
                ue.pos.y += ue.heading.1 * speed * dt;
                let (dx, dy) = (ue.pos.x - center.x, ue.pos.y - center.y);
                let d = (dx * dx + dy * dy).sqrt();
                if d > radius {
                    // clamp to the boundary and re-aim into the disc
                    ue.pos.x = center.x + dx / d * radius;
                    ue.pos.y = center.y + dy / d * radius;
                    let inward = (dy).atan2(dx) + std::f64::consts::PI;
                    let theta = inward
                        + ue.rng.range(
                            -std::f64::consts::FRAC_PI_2 * 0.9,
                            std::f64::consts::FRAC_PI_2 * 0.9,
                        );
                    ue.heading = (theta.cos(), theta.sin());
                }
                true
            }
        }
    }
}

/// Uniform point in the disc (area-uniform).
fn uniform_in_disc(ue: &mut UeGeo, center: Position, radius: f64) -> Position {
    let r = radius * ue.rng.f64().sqrt();
    let theta = ue.rng.range(0.0, 2.0 * std::f64::consts::PI);
    Position { x: center.x + r * theta.cos(), y: center.y + r * theta.sin() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phy::geometry::LinkState;
    use crate::rng::Rng;

    fn ue_at(x: f64, y: f64, seed: u64) -> UeGeo {
        UeGeo {
            pos: Position { x, y },
            links: vec![LinkState { los: true, shadow_db: 0.0, cl_db: 0.0 }],
            speed: 0.0,
            heading: (1.0, 0.0),
            waypoint: Position { x, y },
            rng: Rng::new(seed),
            a3_target: u32::MAX,
            a3_ticks: 0,
        }
    }

    const CENTER: Position = Position { x: 0.0, y: 0.0 };

    #[test]
    fn waypoint_walk_stays_in_disc_and_moves() {
        let model = MobilityModel::RandomWaypoint { v_min: 1.0, v_max: 10.0 };
        let mut ue = ue_at(10.0, 0.0, 1);
        model.init(&mut ue, CENTER, 500.0);
        let start = ue.pos;
        let mut moved = false;
        for _ in 0..200 {
            model.advance(&mut ue, CENTER, 500.0, 1.0);
            let d = ue.pos.dist_2d();
            assert!(d <= 500.0 + 1e-6, "escaped the disc: {d}");
            moved |= (ue.pos.x - start.x).abs() > 1.0 || (ue.pos.y - start.y).abs() > 1.0;
        }
        assert!(moved, "waypoint UE never moved");
    }

    #[test]
    fn fixed_velocity_reflects_at_boundary() {
        let model = MobilityModel::FixedVelocity { speed: 30.0 };
        let mut ue = ue_at(90.0, 0.0, 2);
        model.init(&mut ue, CENTER, 100.0);
        for _ in 0..500 {
            model.advance(&mut ue, CENTER, 100.0, 1.0);
            assert!(ue.pos.dist_2d() <= 100.0 + 1e-6);
        }
    }

    #[test]
    fn zero_speed_is_static() {
        let model = MobilityModel::FixedVelocity { speed: 0.0 };
        let mut ue = ue_at(5.0, 7.0, 3);
        model.init(&mut ue, CENTER, 100.0);
        assert!(!model.advance(&mut ue, CENTER, 100.0, 10.0));
        assert_eq!(ue.pos.x, 5.0);
        assert_eq!(ue.pos.y, 7.0);
    }

    #[test]
    fn trajectories_are_deterministic_per_seed() {
        let model = MobilityModel::RandomWaypoint { v_min: 2.0, v_max: 5.0 };
        let run = |seed| {
            let mut ue = ue_at(0.0, 0.0, seed);
            model.init(&mut ue, CENTER, 300.0);
            for _ in 0..50 {
                model.advance(&mut ue, CENTER, 300.0, 0.5);
            }
            (ue.pos.x.to_bits(), ue.pos.y.to_bits())
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn spec_constructors_validate() {
        let w = MobilitySpec::waypoint(1.0, 3.0).with_tick(0.05);
        assert_eq!(w.tick_s, 0.05);
        let f = MobilitySpec::fixed(3.0);
        assert_eq!(f.model, MobilityModel::FixedVelocity { speed: 3.0 });
        assert_eq!(f.tick_s, MobilitySpec::DEFAULT_TICK_S);
    }
}
