//! Uplink link adaptation: power control, SINR, CQI/MCS, transport
//! block sizing.
//!
//! Single-cell (noise-limited) uplink as in the paper's one-gNB setup;
//! inter-cell interference is absorbed into a fixed margin. The
//! SINR→efficiency mapping uses the 3GPP CQI table (TS 38.214 Table
//! 5.2.2.1-3, 256QAM) with thresholds from the standard ~2 dB/CQI
//! spacing; TBS is efficiency × data REs (a faithful simplification of
//! the 38.214 §5.1.3.2 procedure at this granularity).

use super::channel::LargeScale;
use super::numerology::Carrier;

/// UL power-control parameters (TS 38.213 §7.1 open-loop).
#[derive(Debug, Clone, Copy)]
pub struct PowerControl {
    /// Max UE transmit power, dBm (23 dBm = Power Class 3).
    pub p_max_dbm: f64,
    /// Target received power per PRB, dBm.
    pub p0_dbm: f64,
    /// Fractional pathloss-compensation factor α.
    pub alpha: f64,
}

impl Default for PowerControl {
    fn default() -> Self {
        Self { p_max_dbm: 23.0, p0_dbm: -80.0, alpha: 0.9 }
    }
}

/// Receiver-side constants.
#[derive(Debug, Clone, Copy)]
pub struct Receiver {
    /// gNB noise figure, dB.
    pub noise_figure_db: f64,
    /// Fixed interference-over-thermal margin, dB (single-cell sim
    /// absorbing neighbor-cell interference).
    pub interference_margin_db: f64,
}

impl Default for Receiver {
    fn default() -> Self {
        Self { noise_figure_db: 5.0, interference_margin_db: 2.0 }
    }
}

const THERMAL_NOISE_DBM_PER_HZ: f64 = -174.0;

/// Per-PRB *transmit* power (dBm) of a UE with coupling loss `cl_db`
/// under open-loop power control for an `n_prb_granted`-PRB grant:
/// `min(Pmax, P0 + 10log10(M) + α·PL) − 10log10(M)`. The single
/// source of the PC formula — the serving-cell link budget and the
/// inter-cell interference publication both price it through here.
#[inline]
pub fn tx_power_prb_dbm(cl_db: f64, pc: &PowerControl, n_prb_granted: u32) -> f64 {
    let m = 10.0 * (n_prb_granted.max(1) as f64).log10();
    // Open-loop PC: P = min(Pmax, P0 + 10log10(M) + α·PL)
    let p_tx = pc.p_max_dbm.min(pc.p0_dbm + m + pc.alpha * cl_db);
    p_tx - m
}

/// Per-PRB *received* power (dBm) at the serving gNB for a UE with
/// coupling loss `cl_db`, under open-loop power control for an
/// `n_prb_granted`-PRB grant. This is the UE-dependent half of the
/// link budget — the batched slot-SINR pass caches it per UE and
/// refreshes it only when the UE moves.
#[inline]
pub fn rx_power_prb_dbm(cl_db: f64, pc: &PowerControl, n_prb_granted: u32) -> f64 {
    tx_power_prb_dbm(cl_db, pc, n_prb_granted) - cl_db
}

/// Per-PRB noise-plus-interference floor (dBm) at the gNB receiver.
/// `iot_db` is the interference-over-thermal term: the legacy
/// single-cell model passes the fixed `interference_margin_db`;
/// coupled-radio scenarios pass the dynamic per-slot IoT computed from
/// neighbor cells' previous-slot granted-PRB activity. The summation
/// order matches the historical monolithic formula exactly, so the
/// fixed-margin path is bit-identical to the pre-refactor code.
#[inline]
pub fn noise_floor_prb_dbm(carrier: &Carrier, rx: &Receiver, iot_db: f64) -> f64 {
    let prb_bw = carrier.numerology.scs_hz() * 12.0;
    THERMAL_NOISE_DBM_PER_HZ + 10.0 * prb_bw.log10() + rx.noise_figure_db + iot_db
}

/// Thermal-noise-plus-noise-figure floor per PRB in **linear mW** (the
/// reference the dynamic interference-over-thermal term is measured
/// against — excludes any interference).
pub fn thermal_floor_prb_mw(carrier: &Carrier, rx: &Receiver) -> f64 {
    let prb_bw = carrier.numerology.scs_hz() * 12.0;
    10f64.powf(
        (THERMAL_NOISE_DBM_PER_HZ + 10.0 * prb_bw.log10() + rx.noise_figure_db) / 10.0,
    )
}

/// Interference-over-thermal (dB) for an aggregate received
/// interference of `i_mw` (linear mW per PRB) over a thermal floor of
/// `noise_mw`. 0 dB when nobody interferes.
#[inline]
pub fn iot_db_from_linear(i_mw: f64, noise_mw: f64) -> f64 {
    10.0 * (1.0 + i_mw / noise_mw).log10()
}

/// Per-PRB uplink SINR (dB) for a UE with the given large-scale state,
/// before fast fading (fixed-margin form; the scheduler composes the
/// same two halves with a dynamic IoT instead).
pub fn mean_sinr_db(
    ls: &LargeScale,
    carrier: &Carrier,
    pc: &PowerControl,
    rx: &Receiver,
    n_prb_granted: u32,
) -> f64 {
    rx_power_prb_dbm(ls.coupling_loss_db(carrier.freq_hz), pc, n_prb_granted)
        - noise_floor_prb_dbm(carrier, rx, rx.interference_margin_db)
}

/// CQI table entry: (SINR threshold dB, spectral efficiency b/s/Hz).
/// Efficiencies from TS 38.214 Table 5.2.2.1-3 (up to 256QAM, 7.4063);
/// thresholds follow the standard link-level mapping (~1.9 dB apart).
const CQI_TABLE: [(f64, f64); 15] = [
    (-6.7, 0.1523),
    (-4.7, 0.3770),
    (-2.3, 0.8770),
    (0.2, 1.4766),
    (2.4, 1.9141),
    (4.3, 2.4063),
    (5.9, 2.7305),
    (8.1, 3.3223),
    (10.3, 3.9023),
    (11.7, 4.5234),
    (14.1, 5.1152),
    (16.3, 5.5547),
    (18.7, 6.2266),
    (21.0, 6.9141),
    (22.7, 7.4063),
];

/// Map SINR (dB) to CQI index (0 = out of range / lowest).
pub fn sinr_to_cqi(sinr_db: f64) -> u8 {
    let mut cqi = 0u8;
    for (i, (thr, _)) in CQI_TABLE.iter().enumerate() {
        if sinr_db >= *thr {
            cqi = (i + 1) as u8;
        }
    }
    cqi
}

/// Width of the batch CQI kernel's inner chunk.
const CQI_LANES: usize = 8;

/// Map a whole SINR array (dB) to CQI indices — the batched slot-SINR
/// kernel. Because the table's thresholds are strictly increasing
/// (pinned by `cqi_table_monotone`), the scalar scan's "last threshold
/// passed" equals the *count* of thresholds ≤ the SINR, so each lane
/// is a branchless sum of 15 compare results: no data-dependent
/// branches, fixed trip counts, contiguous loads — the shape LLVM
/// autovectorizes on any target without `std::simd` or intrinsics.
/// Bit-identical to [`sinr_to_cqi`] per lane, including NaN (compares
/// false against every threshold → CQI 0 on both paths) and ±∞.
pub fn sinr_to_cqi_batch(sinr_db: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.resize(sinr_db.len(), 0);
    let mut chunks = sinr_db.chunks_exact(CQI_LANES);
    let mut outs = out.chunks_exact_mut(CQI_LANES);
    for (s, o) in (&mut chunks).zip(&mut outs) {
        for k in 0..CQI_LANES {
            let mut cqi = 0u8;
            for (thr, _) in CQI_TABLE {
                cqi += (s[k] >= thr) as u8;
            }
            o[k] = cqi;
        }
    }
    for (s, o) in chunks.remainder().iter().zip(outs.into_remainder()) {
        let mut cqi = 0u8;
        for (thr, _) in CQI_TABLE {
            cqi += (*s >= thr) as u8;
        }
        *o = cqi;
    }
}

/// Spectral efficiency (b/s/Hz) for a CQI index (0 → unusable).
pub fn cqi_efficiency(cqi: u8) -> f64 {
    if cqi == 0 || cqi as usize > CQI_TABLE.len() {
        0.0
    } else {
        CQI_TABLE[cqi as usize - 1].1
    }
}

/// Transport block size in **bytes** for a grant of `n_prb` PRBs in one
/// slot at the given CQI.
pub fn tbs_bytes(carrier: &Carrier, cqi: u8, n_prb: u32) -> u32 {
    let re = carrier.data_re_per_prb_slot() as f64 * n_prb as f64;
    let bits = re * cqi_efficiency(cqi);
    (bits / 8.0).floor() as u32
}

/// Initial-transmission BLER at the operating point. Link adaptation
/// targets 10% (TS 38.521 conformance assumption).
pub const TARGET_BLER: f64 = 0.10;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phy::channel::{LargeScale, Position};
    use crate::rng::Rng;

    fn ls_at(d: f64, los: bool) -> LargeScale {
        LargeScale { pos: Position { x: d, y: 0.0 }, los, shadow_db: 0.0 }
    }

    #[test]
    fn cqi_table_monotone() {
        let mut prev_thr = f64::NEG_INFINITY;
        let mut prev_eff = 0.0;
        for (thr, eff) in CQI_TABLE {
            assert!(thr > prev_thr);
            assert!(eff > prev_eff);
            prev_thr = thr;
            prev_eff = eff;
        }
    }

    #[test]
    fn sinr_to_cqi_boundaries() {
        assert_eq!(sinr_to_cqi(-10.0), 0);
        assert_eq!(sinr_to_cqi(-6.7), 1);
        assert_eq!(sinr_to_cqi(0.0), 3);
        assert_eq!(sinr_to_cqi(23.0), 15);
        assert_eq!(sinr_to_cqi(100.0), 15);
    }

    #[test]
    fn batch_cqi_kernel_matches_scalar_bit_for_bit() {
        // Dense sweep across the table's range plus every exact
        // threshold and the non-finite edge cases; lengths straddling
        // the chunk width exercise both the vector body and the
        // remainder loop.
        let mut probes: Vec<f64> = Vec::new();
        let mut x = -12.0;
        while x <= 30.0 {
            probes.push(x);
            x += 0.01;
        }
        for (thr, _) in CQI_TABLE {
            probes.push(thr);
            probes.push(thr - f64::EPSILON * thr.abs());
        }
        probes.extend([f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -0.0]);
        let mut out = Vec::new();
        for len in [0, 1, 7, 8, 9, 16, 23, probes.len()] {
            let slice = &probes[..len.min(probes.len())];
            sinr_to_cqi_batch(slice, &mut out);
            assert_eq!(out.len(), slice.len());
            for (s, &cqi) in slice.iter().zip(&out) {
                assert_eq!(cqi, sinr_to_cqi(*s), "sinr {s}");
            }
        }
    }

    #[test]
    fn cqi_efficiency_range() {
        assert_eq!(cqi_efficiency(0), 0.0);
        assert!((cqi_efficiency(15) - 7.4063).abs() < 1e-9);
        assert_eq!(cqi_efficiency(16), 0.0); // out of range treated as 0
    }

    #[test]
    fn near_ue_gets_high_cqi_far_ue_low() {
        let c = Carrier::table1();
        let pc = PowerControl::default();
        let rx = Receiver::default();
        let near = mean_sinr_db(&ls_at(50.0, true), &c, &pc, &rx, 10);
        let far = mean_sinr_db(&ls_at(290.0, false), &c, &pc, &rx, 10);
        assert!(near > far, "near {near} vs far {far}");
        assert!(sinr_to_cqi(near) >= 10, "near SINR {near} → CQI too low");
        assert!(sinr_to_cqi(far) <= 13, "far SINR {far}");
    }

    #[test]
    fn tbs_scales_with_prbs_and_cqi() {
        let c = Carrier::table1();
        let t1 = tbs_bytes(&c, 10, 1);
        let t10 = tbs_bytes(&c, 10, 10);
        assert!((t10 as f64 / t1 as f64 - 10.0).abs() < 0.2);
        assert!(tbs_bytes(&c, 15, 10) > tbs_bytes(&c, 5, 10));
        assert_eq!(tbs_bytes(&c, 0, 10), 0);
    }

    #[test]
    fn tbs_magnitude_sane() {
        // CQI 15, 135 PRB, one 0.25 ms slot: 135·144·7.4063/8 ≈ 18 kB
        // → ≈ 576 Mb/s instantaneous — the right order for 100 MHz UL.
        let c = Carrier::table1();
        let tbs = tbs_bytes(&c, 15, 135);
        assert!((15_000..=20_000).contains(&tbs), "tbs = {tbs}");
    }

    #[test]
    fn decomposed_link_budget_is_bit_identical_to_the_monolithic_form() {
        // The historical single-expression SINR formula, replicated
        // verbatim: the rx-power/noise-floor decomposition (and hence
        // the batched scheduler's cached composition) must match it to
        // the bit, or the legacy fixed-margin configuration would
        // drift from pre-refactor runs.
        let c = Carrier::table1();
        let pc = PowerControl::default();
        let rx = Receiver::default();
        let mut rng = Rng::new(11);
        for _ in 0..500 {
            let ls = LargeScale::drop(&mut rng, 35.0, 300.0);
            for n_prb in [1u32, 8, 50, 135] {
                let cl = ls.coupling_loss_db(c.freq_hz);
                let p_tx = pc.p_max_dbm.min(
                    pc.p0_dbm
                        + 10.0 * (n_prb.max(1) as f64).log10()
                        + pc.alpha * cl,
                );
                let p_rx = p_tx - 10.0 * (n_prb.max(1) as f64).log10() - cl;
                let prb_bw = c.numerology.scs_hz() * 12.0;
                let noise = -174.0
                    + 10.0 * prb_bw.log10()
                    + rx.noise_figure_db
                    + rx.interference_margin_db;
                let legacy = p_rx - noise;
                assert_eq!(
                    legacy.to_bits(),
                    mean_sinr_db(&ls, &c, &pc, &rx, n_prb).to_bits()
                );
                let composed = rx_power_prb_dbm(cl, &pc, n_prb)
                    - noise_floor_prb_dbm(&c, &rx, rx.interference_margin_db);
                assert_eq!(legacy.to_bits(), composed.to_bits());
            }
        }
    }

    #[test]
    fn iot_term_is_zero_without_interference_and_monotone() {
        let c = Carrier::table1();
        let rx = Receiver::default();
        let n = thermal_floor_prb_mw(&c, &rx);
        assert!(n > 0.0 && n.is_finite());
        assert_eq!(iot_db_from_linear(0.0, n), 0.0);
        // I = N → 3 dB rise; 3N → 6 dB
        assert!((iot_db_from_linear(n, n) - 3.0103).abs() < 1e-3);
        let mut prev = 0.0;
        for k in 1..=10 {
            let v = iot_db_from_linear(n * k as f64, n);
            assert!(v > prev);
            prev = v;
        }
    }

    #[test]
    fn power_control_caps_at_pmax() {
        // At extreme coupling loss the UE transmits at Pmax and SINR
        // degrades 1:1 with further loss.
        let c = Carrier::table1();
        let pc = PowerControl::default();
        let rx = Receiver::default();
        let s1 = mean_sinr_db(&ls_at(250.0, false), &c, &pc, &rx, 50);
        let s2 = mean_sinr_db(&ls_at(400.0, false), &c, &pc, &rx, 50);
        assert!(s1 - s2 > 5.0, "{s1} vs {s2}");
    }

    #[test]
    fn cell_edge_still_connectable_with_few_prbs() {
        // Scheduler must be able to serve the worst drop with a small
        // grant: 300 m NLOS + bad shadowing at 1 PRB must yield CQI ≥ 1.
        let c = Carrier::table1();
        let pc = PowerControl::default();
        let rx = Receiver::default();
        let mut worst = LargeScale::drop(&mut Rng::new(1), 35.0, 300.0);
        worst.shadow_db = 12.0; // 2σ NLOS
        let ls = LargeScale { pos: Position { x: 300.0, y: 0.0 }, ..worst };
        let sinr = mean_sinr_db(&ls, &c, &pc, &rx, 1);
        assert!(sinr_to_cqi(sinr) >= 1, "SINR {sinr} dB unusable at edge");
    }
}
