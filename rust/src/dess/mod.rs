//! Discrete-event simulation core.
//!
//! A deterministic event calendar: events are `(time, seq, payload)`
//! triples in a binary min-heap; ties in time break by insertion
//! sequence so runs are exactly reproducible. The SLS (`sim/`), the
//! tandem-queue Monte Carlo (`queueing/tandem_mc.rs`) and the compute
//! node all run on this engine.
//!
//! Time is `f64` seconds. The engine is intentionally generic over the
//! event payload `E`; components pattern-match their own payloads.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An entry in the event calendar.
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap: earliest time first, then lowest seq.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event calendar / simulation clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Pre-size the calendar. Event loops that prime one event per
    /// entity (the SLS schedules `n_ues × n_classes` arrivals before
    /// the first pop) should reserve up front so priming never regrows
    /// the heap.
    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current heap capacity (diagnostics/tests).
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Current simulation time (seconds).
    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at` (must be >= now).
    pub fn schedule_at(&mut self, at: f64, event: E) {
        debug_assert!(at >= self.now - 1e-12, "scheduling into the past: {at} < {}", self.now);
        debug_assert!(at.is_finite());
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time: at.max(self.now), seq, event });
    }

    /// Schedule `event` after a relative delay.
    #[inline]
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock. Returns `(time, event)`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let entry = self.heap.pop()?;
        debug_assert!(entry.time >= self.now - 1e-12);
        self.now = entry.time;
        self.processed += 1;
        Some((entry.time, entry.event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Run until `horizon` (exclusive) or queue exhaustion, invoking
    /// `handler(now, event, queue)` for each event. The handler may
    /// schedule further events.
    pub fn run_until(&mut self, horizon: f64, mut handler: impl FnMut(f64, E, &mut Self)) {
        loop {
            match self.heap.peek() {
                Some(&Entry { time, .. }) if time < horizon => {
                    let (t, ev) = self.pop().unwrap();
                    handler(t, ev, self);
                }
                _ => break,
            }
        }
        // Advance the clock to the horizon even if the calendar drained.
        if self.now < horizon {
            self.now = horizon;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn relative_scheduling_uses_current_time() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "first");
        q.pop();
        q.schedule_in(2.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 7.0);
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(i as f64, i);
        }
        let mut seen = Vec::new();
        q.run_until(5.0, |_, e, _| seen.push(e));
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        assert_eq!(q.now(), 5.0);
        assert_eq!(q.len(), 5); // 5..9 still queued
    }

    #[test]
    fn handler_can_schedule_cascade() {
        let mut q = EventQueue::new();
        q.schedule_at(0.0, 0u32);
        let mut count = 0;
        q.run_until(100.0, |_, depth, q| {
            count += 1;
            if depth < 9 {
                q.schedule_in(1.0, depth + 1);
            }
        });
        assert_eq!(count, 10);
        assert_eq!(q.now(), 100.0);
    }

    #[test]
    fn with_capacity_presizes_heap() {
        let mut q: EventQueue<u32> = EventQueue::with_capacity(1000);
        assert!(q.capacity() >= 1000);
        for i in 0..1000 {
            q.schedule_at(i as f64, i);
        }
        assert!(q.capacity() >= 1000);
        assert_eq!(q.len(), 1000);
    }

    #[test]
    fn empty_queue_behaviour() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        assert_eq!(q.peek_time(), None);
    }
}
