//! Discrete-event simulation core.
//!
//! A deterministic event calendar: events are `(time, seq, payload)`
//! triples; ties in time break by insertion sequence so runs are
//! exactly reproducible. The SLS (`sim/`), the tandem-queue Monte
//! Carlo (`queueing/tandem_mc.rs`) and the compute node all run on
//! this engine.
//!
//! Two interchangeable backends sit behind [`EventQueue`]:
//!
//! * **Binary heap** — O(log n) everywhere, the safe generic default
//!   ([`EventQueue::new`]).
//! * **Calendar queue** (Brown 1988) — a bucketed timing wheel that
//!   pops near-sorted workloads in amortized O(1). Slot ticks and
//!   Poisson arrivals are near-sorted, which makes this the scenario
//!   engine's default; select it with [`EventQueue::with_kind`].
//!
//! Both backends pop the identical total order `(time, seq)`, so a
//! trajectory never depends on the backend — the
//! `calendar_pop_order_matches_heap` property test pins it.
//!
//! Time is `f64` seconds. The engine is intentionally generic over the
//! event payload `E`; components pattern-match their own payloads.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Event-list backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventListKind {
    /// Binary min-heap (generic fallback).
    Heap,
    /// Calendar queue: amortized O(1) pop for near-sorted schedules.
    Calendar,
}

impl EventListKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "heap" => Some(Self::Heap),
            "calendar" => Some(Self::Calendar),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Heap => "heap",
            Self::Calendar => "calendar",
        }
    }
}

/// An entry in the heap calendar.
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap: earliest time first, then lowest seq.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A calendar-queue entry. The epoch (`floor(time / width)`) is
/// computed once at insertion (and again on rebuilds) so bucket
/// membership tests never re-divide floats — the "does this entry
/// belong to the current virtual bucket?" check is an integer compare,
/// immune to float-boundary disagreements.
struct CalEntry<E> {
    time: f64,
    seq: u64,
    epoch: u64,
    event: E,
}

/// Cached location of the queue's minimum entry.
#[derive(Clone, Copy)]
struct NextRef {
    time: f64,
    seq: u64,
    bucket: usize,
    idx: usize,
}

/// Classic calendar queue: `nbuckets` (power of two) unsorted buckets
/// of width `width` seconds; an entry at time `t` lives in bucket
/// `epoch(t) & mask`. Near-sorted pops scan only the current bucket.
/// The structure grows (and re-estimates its width from the queued
/// span) when occupancy exceeds ~2 entries/bucket.
struct Calendar<E> {
    buckets: Vec<Vec<CalEntry<E>>>,
    mask: usize,
    width: f64,
    len: usize,
    /// Epoch of the most recent pop — no queued entry is older.
    cur_epoch: u64,
    next: Option<NextRef>,
}

impl<E> Calendar<E> {
    fn new(cap: usize) -> Self {
        let nbuckets = (cap / 2).max(16).next_power_of_two();
        Self {
            buckets: (0..nbuckets).map(|_| Vec::new()).collect(),
            mask: nbuckets - 1,
            // Bootstrapping width; re-estimated from the actual queued
            // span at every grow.
            width: 1e-3,
            len: 0,
            cur_epoch: 0,
            next: None,
        }
    }

    #[inline]
    fn epoch_of(&self, time: f64) -> u64 {
        // `as` saturates, so a pathological time/width ratio degrades
        // to one far bucket instead of UB.
        (time / self.width) as u64
    }

    fn push(&mut self, time: f64, seq: u64, event: E) {
        if self.len >= 2 * self.buckets.len() {
            self.grow();
        }
        let epoch = self.epoch_of(time);
        if epoch < self.cur_epoch {
            // Cannot happen for time >= now, but an integer compare is
            // cheap insurance against ever scanning past a live entry.
            self.cur_epoch = epoch;
        }
        let b = (epoch as usize) & self.mask;
        self.buckets[b].push(CalEntry { time, seq, epoch, event });
        self.len += 1;
        match self.next {
            // pushes append, so a cached (bucket, idx) stays valid
            Some(n) if time >= n.time => {}
            _ => {
                self.next =
                    Some(NextRef { time, seq, bucket: b, idx: self.buckets[b].len() - 1 })
            }
        }
    }

    fn pop(&mut self) -> Option<(f64, u64, E)> {
        if self.len == 0 {
            return None;
        }
        let n = match self.next {
            Some(n) => n,
            None => self.find_next().expect("len > 0 must yield a next event"),
        };
        let entry = self.buckets[n.bucket].swap_remove(n.idx);
        debug_assert_eq!(entry.seq, n.seq);
        self.len -= 1;
        self.cur_epoch = entry.epoch;
        self.next = if self.len > 0 { self.find_next() } else { None };
        Some((entry.time, entry.seq, entry.event))
    }

    fn peek(&self) -> Option<f64> {
        self.next.map(|n| n.time)
    }

    /// Locate the minimum `(time, seq)` entry: walk virtual buckets
    /// from `cur_epoch` for one full year, then fall back to a direct
    /// scan (rare — only after a large time jump; the subsequent pop
    /// re-anchors `cur_epoch` so the scan does not repeat).
    fn find_next(&self) -> Option<NextRef> {
        if self.len == 0 {
            return None;
        }
        for offset in 0..self.buckets.len() as u64 {
            let epoch = self.cur_epoch + offset;
            let b = (epoch as usize) & self.mask;
            let mut best: Option<NextRef> = None;
            for (idx, e) in self.buckets[b].iter().enumerate() {
                if e.epoch != epoch {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(n) => e.time < n.time || (e.time == n.time && e.seq < n.seq),
                };
                if better {
                    best = Some(NextRef { time: e.time, seq: e.seq, bucket: b, idx });
                }
            }
            if best.is_some() {
                return best;
            }
        }
        // Direct search across every bucket.
        let mut best: Option<NextRef> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (idx, e) in bucket.iter().enumerate() {
                let better = match &best {
                    None => true,
                    Some(n) => e.time < n.time || (e.time == n.time && e.seq < n.seq),
                };
                if better {
                    best = Some(NextRef { time: e.time, seq: e.seq, bucket: b, idx });
                }
            }
        }
        best
    }

    /// Double the bucket count and re-estimate the bucket width from
    /// the span of queued times (≈ one event per width keeps the
    /// current-bucket scan O(1)).
    fn grow(&mut self) {
        let entries: Vec<CalEntry<E>> =
            self.buckets.iter_mut().flat_map(std::mem::take).collect();
        let nbuckets = (self.buckets.len() * 2).max(16);
        self.buckets = (0..nbuckets).map(|_| Vec::new()).collect();
        self.mask = nbuckets - 1;
        if !entries.is_empty() {
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for e in &entries {
                lo = lo.min(e.time);
                hi = hi.max(e.time);
            }
            if hi > lo {
                self.width = ((hi - lo) / entries.len() as f64).max(1e-9);
            }
            self.cur_epoch = self.epoch_of(lo);
            for mut e in entries {
                e.epoch = self.epoch_of(e.time);
                let b = (e.epoch as usize) & self.mask;
                self.buckets[b].push(e);
            }
        }
        self.next = self.find_next();
    }

    fn capacity(&self) -> usize {
        self.buckets.iter().map(Vec::capacity).sum()
    }
}

enum Backend<E> {
    Heap(BinaryHeap<Entry<E>>),
    Calendar(Calendar<E>),
}

/// The event calendar / simulation clock.
pub struct EventQueue<E> {
    backend: Backend<E>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Heap-backed queue (the generic default).
    pub fn new() -> Self {
        Self::with_kind(EventListKind::Heap, 0)
    }

    /// Pre-size a heap-backed calendar. Event loops that prime one
    /// event per entity (the SLS schedules `n_ues × n_classes`
    /// arrivals before the first pop) should reserve up front so
    /// priming never regrows the structure.
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_kind(EventListKind::Heap, cap)
    }

    /// Choose the backend explicitly (the scenario engine defaults to
    /// the calendar queue; `[scenario] event_queue = "heap"` falls
    /// back).
    pub fn with_kind(kind: EventListKind, cap: usize) -> Self {
        let backend = match kind {
            EventListKind::Heap => Backend::Heap(BinaryHeap::with_capacity(cap)),
            EventListKind::Calendar => Backend::Calendar(Calendar::new(cap)),
        };
        Self { backend, now: 0.0, seq: 0, processed: 0 }
    }

    /// Current backing capacity (diagnostics/tests): heap capacity, or
    /// the summed bucket capacity of a calendar.
    pub fn capacity(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.capacity(),
            Backend::Calendar(c) => c.capacity(),
        }
    }

    /// Current simulation time (seconds).
    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule `event` at absolute time `at` (must be >= now).
    pub fn schedule_at(&mut self, at: f64, event: E) {
        debug_assert!(at >= self.now - 1e-12, "scheduling into the past: {at} < {}", self.now);
        debug_assert!(at.is_finite());
        let seq = self.seq;
        self.seq += 1;
        let at = at.max(self.now);
        match &mut self.backend {
            Backend::Heap(h) => h.push(Entry { time: at, seq, event }),
            Backend::Calendar(c) => c.push(at, seq, event),
        }
    }

    /// Schedule `event` after a relative delay.
    #[inline]
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock. Returns `(time, event)`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let (time, event) = match &mut self.backend {
            Backend::Heap(h) => {
                let entry = h.pop()?;
                (entry.time, entry.event)
            }
            Backend::Calendar(c) => {
                let (time, _seq, event) = c.pop()?;
                (time, event)
            }
        };
        debug_assert!(time >= self.now - 1e-12);
        self.now = time;
        self.processed += 1;
        Some((time, event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|e| e.time),
            Backend::Calendar(c) => c.peek(),
        }
    }

    /// Engine-snapshot view: `(now, seq counter, processed count,
    /// entries)` with entries sorted by the pop order `(time, seq)`.
    /// Both backends yield the same canonical list, so snapshot bytes
    /// do not depend on the backend in use.
    pub fn snapshot_entries(&self) -> (f64, u64, u64, Vec<(f64, u64, E)>)
    where
        E: Clone,
    {
        let mut entries: Vec<(f64, u64, E)> = match &self.backend {
            Backend::Heap(h) => h.iter().map(|e| (e.time, e.seq, e.event.clone())).collect(),
            Backend::Calendar(c) => c
                .buckets
                .iter()
                .flatten()
                .map(|e| (e.time, e.seq, e.event.clone()))
                .collect(),
        };
        entries.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(Ordering::Equal).then_with(|| a.1.cmp(&b.1))
        });
        (self.now, self.seq, self.processed, entries)
    }

    /// Rebuild a queue from [`EventQueue::snapshot_entries`] output.
    /// Seq numbers are preserved verbatim (so restored ties break
    /// exactly as they would have) and the seq counter resumes where
    /// it left off.
    pub fn restore(
        kind: EventListKind,
        now: f64,
        seq: u64,
        processed: u64,
        entries: Vec<(f64, u64, E)>,
    ) -> Self {
        let mut q = Self::with_kind(kind, entries.len());
        q.now = now;
        q.seq = seq;
        q.processed = processed;
        for (time, entry_seq, event) in entries {
            match &mut q.backend {
                Backend::Heap(h) => h.push(Entry { time, seq: entry_seq, event }),
                Backend::Calendar(c) => c.push(time, entry_seq, event),
            }
        }
        q
    }

    /// Run until `horizon` (exclusive) or queue exhaustion, invoking
    /// `handler(now, event, queue)` for each event. The handler may
    /// schedule further events.
    pub fn run_until(&mut self, horizon: f64, mut handler: impl FnMut(f64, E, &mut Self)) {
        loop {
            match self.peek_time() {
                Some(time) if time < horizon => {
                    let (t, ev) = self.pop().unwrap();
                    handler(t, ev, self);
                }
                _ => break,
            }
        }
        // Advance the clock to the horizon even if the calendar drained.
        if self.now < horizon {
            self.now = horizon;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::rng::Rng;
    use crate::util::proptest::check;

    #[test]
    fn pops_in_time_order() {
        for kind in [EventListKind::Heap, EventListKind::Calendar] {
            let mut q = EventQueue::with_kind(kind, 0);
            q.schedule_at(3.0, "c");
            q.schedule_at(1.0, "a");
            q.schedule_at(2.0, "b");
            let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
            assert_eq!(order, vec!["a", "b", "c"], "{kind:?}");
            assert_eq!(q.now(), 3.0);
            assert_eq!(q.processed(), 3);
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in [EventListKind::Heap, EventListKind::Calendar] {
            let mut q = EventQueue::with_kind(kind, 0);
            q.schedule_at(1.0, 1);
            q.schedule_at(1.0, 2);
            q.schedule_at(1.0, 3);
            let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
            assert_eq!(order, vec![1, 2, 3], "{kind:?}");
        }
    }

    #[test]
    fn relative_scheduling_uses_current_time() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "first");
        q.pop();
        q.schedule_in(2.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 7.0);
    }

    #[test]
    fn run_until_respects_horizon() {
        for kind in [EventListKind::Heap, EventListKind::Calendar] {
            let mut q = EventQueue::with_kind(kind, 0);
            for i in 0..10 {
                q.schedule_at(i as f64, i);
            }
            let mut seen = Vec::new();
            q.run_until(5.0, |_, e, _| seen.push(e));
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "{kind:?}");
            assert_eq!(q.now(), 5.0);
            assert_eq!(q.len(), 5); // 5..9 still queued
        }
    }

    #[test]
    fn handler_can_schedule_cascade() {
        for kind in [EventListKind::Heap, EventListKind::Calendar] {
            let mut q = EventQueue::with_kind(kind, 0);
            q.schedule_at(0.0, 0u32);
            let mut count = 0;
            q.run_until(100.0, |_, depth, q| {
                count += 1;
                if depth < 9 {
                    q.schedule_in(1.0, depth + 1);
                }
            });
            assert_eq!(count, 10, "{kind:?}");
            assert_eq!(q.now(), 100.0);
        }
    }

    #[test]
    fn with_capacity_presizes_heap() {
        let mut q: EventQueue<u32> = EventQueue::with_capacity(1000);
        assert!(q.capacity() >= 1000);
        for i in 0..1000 {
            q.schedule_at(i as f64, i);
        }
        assert!(q.capacity() >= 1000);
        assert_eq!(q.len(), 1000);
    }

    #[test]
    fn empty_queue_behaviour() {
        for kind in [EventListKind::Heap, EventListKind::Calendar] {
            let mut q: EventQueue<()> = EventQueue::with_kind(kind, 0);
            assert!(q.is_empty());
            assert!(q.pop().is_none());
            assert_eq!(q.peek_time(), None);
        }
    }

    #[test]
    fn calendar_survives_growth_and_time_jumps() {
        let mut q: EventQueue<u64> = EventQueue::with_kind(EventListKind::Calendar, 4);
        // load enough entries to force several grows, with a huge gap
        // in the middle so the direct-search fallback runs
        for i in 0..500u64 {
            q.schedule_at(i as f64 * 0.00025, i);
        }
        q.schedule_at(1_000.0, 9_999);
        for i in 0..500u64 {
            let (_, e) = q.pop().unwrap();
            assert_eq!(e, i);
        }
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1_000.0, 9_999));
        assert!(q.pop().is_none());
    }

    #[test]
    fn event_list_kind_parses() {
        assert_eq!(EventListKind::parse("heap"), Some(EventListKind::Heap));
        assert_eq!(EventListKind::parse("CALENDAR"), Some(EventListKind::Calendar));
        assert_eq!(EventListKind::parse("wheel"), None);
        assert_eq!(EventListKind::Calendar.name(), "calendar");
    }

    /// Pop-order equivalence: under a randomized near-sorted workload
    /// (slot chains, Poisson gaps, same-instant bursts, interleaved
    /// pops) the calendar queue and the binary heap must produce the
    /// identical `(time, payload)` pop sequence — the property that
    /// makes the backend choice observationally irrelevant to every
    /// simulation.
    #[test]
    fn calendar_pop_order_matches_heap() {
        check(25, |g| {
            let seed = g.u64_below(100_000);
            let mut rng = Rng::new(seed);
            let mut heap: EventQueue<u32> = EventQueue::with_kind(EventListKind::Heap, 0);
            let mut cal: EventQueue<u32> =
                EventQueue::with_kind(EventListKind::Calendar, 0);
            let mut next_id = 0u32;
            for step in 0..600 {
                for _ in 0..rng.below(4) {
                    let dt = match rng.below(4) {
                        0 => 0.00025 * (1 + rng.below(4)) as f64, // slot chain
                        1 => rng.exp(2_000.0),                    // Poisson gap
                        2 => 0.0,                                 // tie at now
                        _ => rng.exp(10.0),                       // long jump
                    };
                    heap.schedule_in(dt, next_id);
                    cal.schedule_in(dt, next_id);
                    next_id += 1;
                }
                prop_assert!(
                    heap.peek_time().map(f64::to_bits) == cal.peek_time().map(f64::to_bits),
                    "step {step}: peek diverged ({:?} vs {:?})",
                    heap.peek_time(),
                    cal.peek_time()
                );
                if rng.bernoulli(0.7) {
                    match (heap.pop(), cal.pop()) {
                        (None, None) => {}
                        (Some((ta, ea)), Some((tb, eb))) => prop_assert!(
                            ta.to_bits() == tb.to_bits() && ea == eb,
                            "step {step}: pop diverged ({ta}, {ea}) vs ({tb}, {eb})"
                        ),
                        (a, b) => {
                            prop_assert!(false, "one backend drained early: {a:?} vs {b:?}")
                        }
                    }
                }
                prop_assert!(heap.len() == cal.len(), "length diverged at step {step}");
            }
            loop {
                match (heap.pop(), cal.pop()) {
                    (None, None) => break,
                    (Some((ta, ea)), Some((tb, eb))) => prop_assert!(
                        ta.to_bits() == tb.to_bits() && ea == eb,
                        "drain diverged: ({ta}, {ea}) vs ({tb}, {eb})"
                    ),
                    (a, b) => prop_assert!(false, "drain length diverged: {a:?} vs {b:?}"),
                }
            }
            prop_assert!(heap.processed() == cal.processed(), "processed counts diverged");
            Ok(())
        });
    }
}
