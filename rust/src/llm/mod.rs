//! LLM inference workload + roofline latency model (paper §IV-A).
//!
//! The paper models LLM inference latency with a two-phase roofline
//! (Eqs 7–8): the prefill phase is `max(compute, weight-load)` and each
//! decode step is `max(per-token compute, weight-load)` — decode is
//! memory-bound for every realistic (model, GPU) pair, which is exactly
//! why constrained edge compute benefits from joint latency management.

pub mod gpu;

pub use gpu::GpuSpec;

/// A translation job `J = {N_input, N_output, C_LLM, M_LLM, b_total}`
/// (paper §IV). `c_llm` is FLOPs per token (≈ 2 × params), `m_llm` is
/// the model footprint in bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub n_input: u32,
    pub n_output: u32,
    /// FLOPs per token of matmul work (≈ 2 × n_params).
    pub c_llm: f64,
    /// Model bytes that must stream from memory per forward pass.
    pub m_llm: f64,
    /// End-to-end latency budget in seconds.
    pub b_total: f64,
}

/// Heuristic KV-cache bytes per token of context for a dense
/// Llama-shaped FP16 model of `m_llm` bytes.
///
/// KV per token is `2 (K+V) · n_layers · d_model · bytes_per_value`.
/// Layers and width are recovered from the parameter count assuming
/// the dense-transformer identity `params ≈ 12 · L · d²` and the
/// Llama-family aspect ratio `d ≈ 128 · L` (7B: L = 32, d = 4096 →
/// ≈ 0.52 MB/token, matching the published figure). Workloads can
/// override the value per class when they serve GQA/MQA models with
/// smaller caches.
pub fn kv_bytes_per_token(m_llm: f64) -> f64 {
    const BYTES_PER_VALUE: f64 = 2.0; // FP16
    const ASPECT: f64 = 128.0; // d_model / n_layers
    let params = (m_llm / BYTES_PER_VALUE).max(1.0);
    let layers = (params / (12.0 * ASPECT * ASPECT)).cbrt();
    let d_model = ASPECT * layers;
    2.0 * layers * d_model * BYTES_PER_VALUE
}

/// One model tier of a serving zoo: parameter count, roofline demand
/// profile, per-token KV footprint, and HBM residency. A scenario's
/// `[[model]]` tables build these; nodes host a subset and routing
/// picks one per job (DESIGN.md §14).
///
/// The KV bytes/token value is owned here: an explicit override and
/// the [`kv_bytes_per_token`] heuristic can never disagree, because
/// every consumer reads [`ModelSpec::kv_bytes_per_token`] and the
/// override is private.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Catalog name (`"7b"`, `"70b"`, …) — referenced by node resident
    /// sets and workload accept-lists.
    pub name: String,
    /// Parameter count (e.g. `7e9`).
    pub params: f64,
    /// FLOPs per token of matmul work (defaults to `2 × params`).
    pub c_llm: f64,
    /// Bytes streamed from memory per forward pass (defaults to
    /// `2 × params`, FP16).
    pub m_llm: f64,
    /// Explicit KV bytes/token; `None` derives from `m_llm` via the
    /// dense-transformer heuristic.
    kv_override: Option<f64>,
    /// Resident HBM footprint of the weights in bytes (defaults to
    /// `m_llm` — FP16 weights are exactly the streamed bytes).
    pub resident_bytes: f64,
}

impl ModelSpec {
    /// A dense FP16 model of `params` parameters with the default
    /// demand profile (`c = m = 2 × params`, heuristic KV, weights
    /// resident at `m_llm` bytes).
    pub fn new(name: &str, params: f64) -> Self {
        Self {
            name: name.to_string(),
            params,
            c_llm: 2.0 * params,
            m_llm: 2.0 * params,
            kv_override: None,
            resident_bytes: 2.0 * params,
        }
    }

    /// The Table-I 7B tier (Llama-2-7B FP16).
    pub fn llama_7b() -> Self {
        Self::new("7b", 7e9)
    }

    /// The 70B quality tier motivating the zoo split.
    pub fn llama_70b() -> Self {
        Self::new("70b", 70e9)
    }

    /// Override the per-token FLOP demand.
    pub fn with_c_llm(mut self, c_llm: f64) -> Self {
        self.c_llm = c_llm;
        self
    }

    /// Override the per-pass byte demand. Does not touch an explicit
    /// KV override; without one the heuristic follows the new `m_llm`.
    pub fn with_m_llm(mut self, m_llm: f64) -> Self {
        self.m_llm = m_llm;
        self
    }

    /// Pin KV bytes/token explicitly (GQA/MQA models cache less than
    /// the dense heuristic predicts).
    pub fn with_kv_bytes_per_token(mut self, kv: f64) -> Self {
        self.kv_override = Some(kv);
        self
    }

    /// Override the resident weight footprint (quantized weights,
    /// shared embeddings).
    pub fn with_resident_bytes(mut self, bytes: f64) -> Self {
        self.resident_bytes = bytes;
        self
    }

    /// KV-cache bytes per context token: the explicit override when
    /// set, the [`kv_bytes_per_token`] heuristic over `m_llm`
    /// otherwise. The single source of truth for this model's KV
    /// footprint.
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.kv_override.unwrap_or_else(|| kv_bytes_per_token(self.m_llm))
    }

    /// Whether the KV footprint was pinned explicitly (TOML
    /// round-trips need to re-emit only explicit overrides).
    pub fn kv_is_explicit(&self) -> bool {
        self.kv_override.is_some()
    }
}

impl JobSpec {
    /// Table I workload: Llama-2-7B FP16, 15 input / 15 output tokens,
    /// 80 ms end-to-end budget.
    pub fn table1() -> Self {
        const N_PARAMS: f64 = 7e9;
        Self {
            n_input: 15,
            n_output: 15,
            c_llm: 2.0 * N_PARAMS,      // 14 GFLOP / token
            m_llm: 2.0 * N_PARAMS,      // FP16: 2 bytes / param = 14 GB
            b_total: 0.080,
        }
    }

    pub fn total_tokens(&self) -> u32 {
        self.n_input + self.n_output
    }

    /// Heuristic KV-cache bytes per context token (see
    /// [`kv_bytes_per_token`]).
    pub fn kv_bytes_per_token(&self) -> f64 {
        kv_bytes_per_token(self.m_llm)
    }
}

/// Roofline latency model over a [`GpuSpec`] (Eqs 7–8).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub gpu: GpuSpec,
}

impl CostModel {
    pub fn new(gpu: GpuSpec) -> Self {
        Self { gpu }
    }

    /// Eq 7: `T_prefill = max(N_input·C_LLM / G_comp, M_LLM / G_membw)`.
    pub fn prefill_latency(&self, job: &JobSpec) -> f64 {
        let compute = job.n_input as f64 * job.c_llm / self.gpu.comp_flops;
        let memory = job.m_llm / self.gpu.mem_bw;
        compute.max(memory)
    }

    /// Per-output-token latency: `max(C_LLM / G_comp, M_LLM / G_membw)`.
    pub fn token_latency(&self, job: &JobSpec) -> f64 {
        let compute = job.c_llm / self.gpu.comp_flops;
        let memory = job.m_llm / self.gpu.mem_bw;
        compute.max(memory)
    }

    /// Eq 8: `T_tokengen = N_output · max(...)`.
    pub fn tokengen_latency(&self, job: &JobSpec) -> f64 {
        job.n_output as f64 * self.token_latency(job)
    }

    /// `T_comp = T_prefill + T_tokengen` (service time, excl. queueing).
    pub fn total_latency(&self, job: &JobSpec) -> f64 {
        self.prefill_latency(job) + self.tokengen_latency(job)
    }

    /// True if decoding is memory-bandwidth-bound on this GPU.
    pub fn decode_is_memory_bound(&self, job: &JobSpec) -> bool {
        job.m_llm / self.gpu.mem_bw > job.c_llm / self.gpu.comp_flops
    }

    /// Batched decode step (extension §IV: continuous batching): the
    /// weight stream is amortized across the batch, compute scales with
    /// batch size. `max(B·C/G_comp, M/G_membw)`.
    pub fn batched_token_latency(&self, job: &JobSpec, batch: u32) -> f64 {
        let compute = batch as f64 * job.c_llm / self.gpu.comp_flops;
        let memory = job.m_llm / self.gpu.mem_bw;
        compute.max(memory)
    }

    /// Arithmetic-intensity crossover batch size: smallest batch at
    /// which batched decode becomes compute-bound.
    pub fn saturation_batch(&self, job: &JobSpec) -> u32 {
        let b = (job.m_llm / self.gpu.mem_bw) * self.gpu.comp_flops / job.c_llm;
        b.ceil().max(1.0) as u32
    }

    /// The documented "model must fit" rule: can this GPU hold the
    /// model weights at all (before any KV budget)?
    pub fn fits(&self, job: &JobSpec) -> bool {
        job.m_llm <= self.gpu.mem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::gpu::GpuSpec;

    fn llama7b() -> JobSpec {
        JobSpec::table1()
    }

    #[test]
    fn table1_constants() {
        let j = llama7b();
        assert_eq!(j.n_input, 15);
        assert_eq!(j.n_output, 15);
        assert!((j.c_llm - 14e9).abs() < 1.0);
        assert!((j.m_llm - 14e9).abs() < 1.0);
        assert!((j.b_total - 0.080).abs() < 1e-12);
        assert_eq!(j.total_tokens(), 30);
    }

    #[test]
    fn a100_decode_is_memory_bound() {
        let m = CostModel::new(GpuSpec::a100());
        let j = llama7b();
        assert!(m.decode_is_memory_bound(&j));
        // 14 GB / 2.039 TB/s ≈ 6.87 ms per token
        let tok = m.token_latency(&j);
        assert!((tok - 14e9 / 2.039e12).abs() < 1e-6, "tok = {tok}");
        // prefill with 15 tokens: compute = 15·14e9/312e12 ≈ 0.67 ms,
        // memory ≈ 6.87 ms → memory-bound
        let pre = m.prefill_latency(&j);
        assert!((pre - tok).abs() < 1e-9);
    }

    #[test]
    fn total_latency_is_sum() {
        let m = CostModel::new(GpuSpec::a100());
        let j = llama7b();
        let total = m.total_latency(&j);
        assert!((total - (m.prefill_latency(&j) + m.tokengen_latency(&j))).abs() < 1e-12);
        // ≈ 16 × 6.87 ms ≈ 110 ms on a single A100 — exceeds the 80 ms
        // budget, which is why Fig 7 needs aggregated capacity ≥ ~8.
        assert!(total > j.b_total);
    }

    #[test]
    fn capacity_scaling_shrinks_latency_linearly() {
        let j = llama7b();
        let m1 = CostModel::new(GpuSpec::a100().scaled(1.0));
        let m8 = CostModel::new(GpuSpec::a100().scaled(8.0));
        let r = m1.total_latency(&j) / m8.total_latency(&j);
        assert!((r - 8.0).abs() < 1e-9, "r = {r}");
        // 8 A100-equivalents bring the job under the 80 ms budget
        assert!(m8.total_latency(&j) < j.b_total);
    }

    #[test]
    fn prefill_becomes_compute_bound_for_long_prompts() {
        let m = CostModel::new(GpuSpec::a100());
        let mut j = llama7b();
        j.n_input = 4096;
        let compute = j.n_input as f64 * j.c_llm / m.gpu.comp_flops;
        assert!((m.prefill_latency(&j) - compute).abs() < 1e-9);
    }

    #[test]
    fn batching_amortizes_memory() {
        let m = CostModel::new(GpuSpec::a100());
        let j = llama7b();
        let single = m.batched_token_latency(&j, 1);
        let b8 = m.batched_token_latency(&j, 8);
        // Still memory-bound at batch 8 → same step latency, 8× thpt
        assert!((single - b8).abs() < 1e-9);
        let sat = m.saturation_batch(&j);
        // A100: (14e9/2.039e12)·312e12/14e9 ≈ 153
        assert!((150..=160).contains(&sat), "sat = {sat}");
        let big = m.batched_token_latency(&j, sat * 2);
        assert!(big > single);
    }

    #[test]
    fn kv_heuristic_matches_llama7b() {
        // Llama-2-7B FP16: 2 · 32 layers · 4096 width · 2 bytes ≈ 0.52 MB
        let kv = kv_bytes_per_token(14e9);
        assert!(
            (0.4e6..0.7e6).contains(&kv),
            "kv/token = {kv} (expect ≈ 0.52 MB)"
        );
        // grows sublinearly with model size (2/3 power of params)
        let kv70 = kv_bytes_per_token(140e9);
        assert!(kv70 > kv && kv70 < 10.0 * kv, "kv70 = {kv70}");
        assert!((JobSpec::table1().kv_bytes_per_token() - kv).abs() < 1.0);
    }

    #[test]
    fn model_spec_owns_kv_resolution() {
        let m = ModelSpec::llama_7b();
        assert_eq!(m.name, "7b");
        assert!((m.c_llm - 14e9).abs() < 1.0);
        assert!((m.m_llm - 14e9).abs() < 1.0);
        assert!((m.resident_bytes - 14e9).abs() < 1.0);
        // heuristic path: identical to the free function
        assert!(!m.kv_is_explicit());
        assert!((m.kv_bytes_per_token() - kv_bytes_per_token(14e9)).abs() < 1e-9);
        // explicit override wins and survives an m_llm change
        let gqa = ModelSpec::llama_70b()
            .with_kv_bytes_per_token(0.1e6)
            .with_m_llm(140e9);
        assert!(gqa.kv_is_explicit());
        assert!((gqa.kv_bytes_per_token() - 0.1e6).abs() < 1e-9);
        // without an override the heuristic follows m_llm
        let dense = ModelSpec::llama_70b().with_m_llm(140e9);
        assert!((dense.kv_bytes_per_token() - kv_bytes_per_token(140e9)).abs() < 1e-9);
        // resident override is independent of demand
        let q4 = ModelSpec::llama_70b().with_resident_bytes(35e9);
        assert!((q4.resident_bytes - 35e9).abs() < 1.0);
        assert!((q4.m_llm - 140e9).abs() < 1.0);
    }

    #[test]
    fn fits_checks_weight_footprint() {
        let j = llama7b(); // 14 GB
        assert!(CostModel::new(GpuSpec::l40s()).fits(&j));
        let mut big = j;
        big.m_llm = 60e9; // 30B FP16 > 48 GB L40S
        assert!(!CostModel::new(GpuSpec::l40s()).fits(&big));
        assert!(CostModel::new(GpuSpec::a100()).fits(&big));
    }

    #[test]
    fn gh200_nvl2_pair_fits_budget() {
        // Fig 6 compute node: two GH200-NVL2 superchips, aggregated.
        let m = CostModel::new(GpuSpec::gh200_nvl2().scaled(2.0));
        let j = llama7b();
        let total = m.total_latency(&j);
        assert!(total < j.b_total, "T_comp = {:.1} ms", total * 1e3);
    }
}
