//! GPU catalog: datasheet specs used by the roofline model.
//!
//! Numbers are dense (non-sparse) FP16/BF16 tensor throughput and peak
//! memory bandwidth from the public datasheets the paper cites ([17]
//! GH200, [18] A100). "Capacity scaling" (Fig 7's ×A100 axis) is
//! modeled as perfect tensor-parallel aggregation of both compute and
//! bandwidth — the same abstraction the paper uses when it scales the
//! computing node "relative to a single A100".

/// Peak specs of one accelerator (or an aggregated pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Dense FP16 tensor throughput, FLOP/s.
    pub comp_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes (sanity checks: model must fit).
    pub mem_bytes: f64,
}

impl GpuSpec {
    /// NVIDIA A100 SXM 80GB: 312 TFLOPS dense FP16, 2.039 TB/s HBM2e.
    pub fn a100() -> Self {
        Self { name: "A100-SXM-80GB", comp_flops: 312e12, mem_bw: 2.039e12, mem_bytes: 80e9 }
    }

    /// NVIDIA H100 SXM: 989 TFLOPS dense FP16, 3.35 TB/s HBM3.
    pub fn h100() -> Self {
        Self { name: "H100-SXM", comp_flops: 989e12, mem_bw: 3.35e12, mem_bytes: 80e9 }
    }

    /// NVIDIA GH200-NVL2 (one superchip of the NVL2 pair): H200-class
    /// GPU — 989 TFLOPS dense FP16, 4.9 TB/s HBM3e, 144 GB.
    pub fn gh200_nvl2() -> Self {
        Self { name: "GH200-NVL2", comp_flops: 989e12, mem_bw: 4.9e12, mem_bytes: 144e9 }
    }

    /// Look up by case-insensitive name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(Self::a100()),
            "h100" => Some(Self::h100()),
            "gh200" | "gh200-nvl2" | "gh200_nvl2" => Some(Self::gh200_nvl2()),
            _ => None,
        }
    }

    /// Aggregate `factor` of these accelerators (perfect tensor-parallel
    /// scaling of compute + bandwidth + capacity, as in Fig 7's x-axis).
    pub fn scaled(&self, factor: f64) -> Self {
        assert!(factor > 0.0);
        Self {
            name: self.name,
            comp_flops: self.comp_flops * factor,
            mem_bw: self.mem_bw * factor,
            mem_bytes: self.mem_bytes * factor,
        }
    }

    /// Capacity of this spec expressed in A100 units (Fig 7's axis).
    pub fn a100_equivalents(&self) -> f64 {
        self.mem_bw / GpuSpec::a100().mem_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_values() {
        let a = GpuSpec::a100();
        assert_eq!(a.comp_flops, 312e12);
        assert_eq!(a.mem_bw, 2.039e12);
        let g = GpuSpec::gh200_nvl2();
        assert!(g.mem_bw > 2.0 * a.mem_bw);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(GpuSpec::by_name("A100").unwrap().name, "A100-SXM-80GB");
        assert_eq!(GpuSpec::by_name("gh200-nvl2").unwrap().name, "GH200-NVL2");
        assert!(GpuSpec::by_name("tpu-v5p").is_none());
    }

    #[test]
    fn scaling_is_linear() {
        let a = GpuSpec::a100().scaled(11.0);
        assert!((a.comp_flops - 11.0 * 312e12).abs() < 1.0);
        assert!((a.a100_equivalents() - 11.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_scale_rejected() {
        GpuSpec::a100().scaled(0.0);
    }

    #[test]
    fn model_fits_in_memory_sanity() {
        // Llama-2-7B FP16 = 14 GB must fit in every catalog entry.
        for g in [GpuSpec::a100(), GpuSpec::h100(), GpuSpec::gh200_nvl2()] {
            assert!(g.mem_bytes > 14e9, "{}", g.name);
        }
    }
}
