//! GPU catalog: datasheet specs used by the roofline model.
//!
//! Numbers are dense (non-sparse) FP16/BF16 tensor throughput and peak
//! memory bandwidth from the public datasheets the paper cites ([17]
//! GH200, [18] A100). "Capacity scaling" (Fig 7's ×A100 axis) is
//! modeled as perfect tensor-parallel aggregation of both compute and
//! bandwidth — the same abstraction the paper uses when it scales the
//! computing node "relative to a single A100".

/// Peak specs of one accelerator (or an aggregated pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Base accelerator name (one physical device).
    pub name: &'static str,
    /// Aggregation factor applied via [`GpuSpec::scaled`] (1 = one
    /// device). Carried so reports and node views can label a pool
    /// `"A100-SXM-80GB x16"` instead of masquerading as one card.
    pub scale: f64,
    /// Dense FP16 tensor throughput, FLOP/s.
    pub comp_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes (model + KV cache must fit).
    pub mem_bytes: f64,
    /// Board TDP in watts (datasheet max power). Used by the cluster
    /// energy accounting: joules = TDP × up-seconds. Scales with the
    /// aggregation factor like every other capacity axis.
    pub tdp_watts: f64,
    /// On-demand rental price, USD per device-hour (representative
    /// public cloud list prices, 2025). Used by the cluster cost
    /// accounting; scaled pools cost `scale ×` this.
    pub price_per_hour: f64,
}

impl GpuSpec {
    /// NVIDIA A100 SXM 80GB: 312 TFLOPS dense FP16, 2.039 TB/s HBM2e.
    /// 400 W SXM board TDP (datasheet); ~$1.79/hr on-demand (Lambda
    /// 2025 list price for A100-80GB).
    pub fn a100() -> Self {
        Self {
            name: "A100-SXM-80GB",
            scale: 1.0,
            comp_flops: 312e12,
            mem_bw: 2.039e12,
            mem_bytes: 80e9,
            tdp_watts: 400.0,
            price_per_hour: 1.79,
        }
    }

    /// NVIDIA H100 SXM: 989 TFLOPS dense FP16, 3.35 TB/s HBM3.
    /// 700 W SXM board TDP; ~$2.99/hr on-demand (Lambda 2025 list).
    pub fn h100() -> Self {
        Self {
            name: "H100-SXM",
            scale: 1.0,
            comp_flops: 989e12,
            mem_bw: 3.35e12,
            mem_bytes: 80e9,
            tdp_watts: 700.0,
            price_per_hour: 2.99,
        }
    }

    /// NVIDIA H200 SXM: H100-class compute with 4.8 TB/s HBM3e and
    /// 141 GB — the bandwidth-upgraded decode workhorse. 700 W SXM
    /// board TDP; ~$3.79/hr on-demand (2025 cloud list).
    pub fn h200() -> Self {
        Self {
            name: "H200-SXM",
            scale: 1.0,
            comp_flops: 989e12,
            mem_bw: 4.8e12,
            mem_bytes: 141e9,
            tdp_watts: 700.0,
            price_per_hour: 3.79,
        }
    }

    /// NVIDIA L40S: 362 TFLOPS dense FP16, 864 GB/s GDDR6, 48 GB —
    /// the realistic *small-memory* edge target (a 7B FP16 model fits,
    /// but a fat KV budget does not). 350 W PCIe board TDP; ~$1.05/hr
    /// on-demand (2025 cloud list).
    pub fn l40s() -> Self {
        Self {
            name: "L40S",
            scale: 1.0,
            comp_flops: 362e12,
            mem_bw: 0.864e12,
            mem_bytes: 48e9,
            tdp_watts: 350.0,
            price_per_hour: 1.05,
        }
    }

    /// NVIDIA GH200-NVL2 (one superchip of the NVL2 pair): H200-class
    /// GPU — 989 TFLOPS dense FP16, 4.9 TB/s HBM3e, 144 GB. 1000 W
    /// module TDP (Grace CPU + Hopper GPU, datasheet max); ~$4.49/hr
    /// on-demand (2025 cloud list for GH200 instances).
    pub fn gh200_nvl2() -> Self {
        Self {
            name: "GH200-NVL2",
            scale: 1.0,
            comp_flops: 989e12,
            mem_bw: 4.9e12,
            mem_bytes: 144e9,
            tdp_watts: 1000.0,
            price_per_hour: 4.49,
        }
    }

    /// Look up by case-insensitive name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(Self::a100()),
            "h100" => Some(Self::h100()),
            "h200" => Some(Self::h200()),
            "l40s" => Some(Self::l40s()),
            "gh200" | "gh200-nvl2" | "gh200_nvl2" => Some(Self::gh200_nvl2()),
            _ => None,
        }
    }

    /// Aggregate `factor` of these accelerators (perfect tensor-parallel
    /// scaling of compute + bandwidth + capacity, as in Fig 7's x-axis).
    /// Scales compose: `a100().scaled(2.0).scaled(8.0)` is a ×16 pool.
    pub fn scaled(&self, factor: f64) -> Self {
        assert!(factor > 0.0);
        Self {
            name: self.name,
            scale: self.scale * factor,
            comp_flops: self.comp_flops * factor,
            mem_bw: self.mem_bw * factor,
            mem_bytes: self.mem_bytes * factor,
            tdp_watts: self.tdp_watts * factor,
            price_per_hour: self.price_per_hour * factor,
        }
    }

    /// Human-readable pool label: the base name, with the aggregation
    /// factor when ≠ 1 (`"A100-SXM-80GB x16"`). Use this — not `name`
    /// — anywhere a spec is reported or logged.
    pub fn display_name(&self) -> String {
        if (self.scale - 1.0).abs() < 1e-9 {
            self.name.to_string()
        } else if (self.scale - self.scale.round()).abs() < 1e-9 {
            format!("{} x{}", self.name, self.scale.round() as i64)
        } else {
            format!("{} x{:.2}", self.name, self.scale)
        }
    }

    /// Capacity of this spec expressed in A100 units (Fig 7's axis).
    pub fn a100_equivalents(&self) -> f64 {
        self.mem_bw / GpuSpec::a100().mem_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_values() {
        let a = GpuSpec::a100();
        assert_eq!(a.comp_flops, 312e12);
        assert_eq!(a.mem_bw, 2.039e12);
        let g = GpuSpec::gh200_nvl2();
        assert!(g.mem_bw > 2.0 * a.mem_bw);
        let h = GpuSpec::h200();
        assert!(h.mem_bw > GpuSpec::h100().mem_bw);
        assert!(h.mem_bytes > GpuSpec::h100().mem_bytes);
        let l = GpuSpec::l40s();
        assert!(l.mem_bytes < a.mem_bytes, "L40S is the small-memory target");
    }

    #[test]
    fn catalog_tdp_and_price_filled_in() {
        for g in [
            GpuSpec::a100(),
            GpuSpec::h100(),
            GpuSpec::h200(),
            GpuSpec::l40s(),
            GpuSpec::gh200_nvl2(),
        ] {
            assert!(g.tdp_watts > 0.0, "{} missing TDP", g.name);
            assert!(g.price_per_hour > 0.0, "{} missing $/hr", g.name);
            // sanity bands: no data-center accelerator is under 100 W
            // or over 2 kW, nor rents under $0.1/hr or over $100/hr
            assert!((100.0..=2000.0).contains(&g.tdp_watts), "{}", g.name);
            assert!((0.1..=100.0).contains(&g.price_per_hour), "{}", g.name);
        }
        assert_eq!(GpuSpec::a100().tdp_watts, 400.0);
        assert_eq!(GpuSpec::h100().tdp_watts, 700.0);
        assert_eq!(GpuSpec::l40s().tdp_watts, 350.0);
        // the GH200 superchip (CPU+GPU module) draws the most
        let most = GpuSpec::gh200_nvl2();
        assert!(most.tdp_watts >= GpuSpec::h200().tdp_watts);
        assert!(most.price_per_hour > GpuSpec::h200().price_per_hour);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(GpuSpec::by_name("A100").unwrap().name, "A100-SXM-80GB");
        assert_eq!(GpuSpec::by_name("gh200-nvl2").unwrap().name, "GH200-NVL2");
        assert_eq!(GpuSpec::by_name("h200").unwrap().name, "H200-SXM");
        assert_eq!(GpuSpec::by_name("L40S").unwrap().name, "L40S");
        assert!(GpuSpec::by_name("tpu-v5p").is_none());
    }

    #[test]
    fn scaling_is_linear() {
        let a = GpuSpec::a100().scaled(11.0);
        assert!((a.comp_flops - 11.0 * 312e12).abs() < 1.0);
        assert!((a.a100_equivalents() - 11.0).abs() < 1e-9);
        // power draw and rental cost aggregate with the pool too
        assert!((a.tdp_watts - 11.0 * 400.0).abs() < 1e-9);
        assert!((a.price_per_hour - 11.0 * GpuSpec::a100().price_per_hour).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_scale_rejected() {
        GpuSpec::a100().scaled(0.0);
    }

    #[test]
    fn display_name_carries_scale() {
        assert_eq!(GpuSpec::a100().display_name(), "A100-SXM-80GB");
        assert_eq!(GpuSpec::a100().scaled(16.0).display_name(), "A100-SXM-80GB x16");
        // scales compose multiplicatively
        let pool = GpuSpec::gh200_nvl2().scaled(2.0).scaled(2.0);
        assert_eq!(pool.display_name(), "GH200-NVL2 x4");
        assert!((pool.scale - 4.0).abs() < 1e-12);
        // fractional scales stay readable
        assert_eq!(GpuSpec::a100().scaled(2.5).display_name(), "A100-SXM-80GB x2.50");
        // every catalog entry labels its scaled pools consistently
        for g in [
            GpuSpec::a100(),
            GpuSpec::h100(),
            GpuSpec::h200(),
            GpuSpec::l40s(),
            GpuSpec::gh200_nvl2(),
        ] {
            assert_eq!(g.scaled(1.0).display_name(), g.name);
            assert_eq!(g.scaled(8.0).display_name(), format!("{} x8", g.name));
            assert_eq!(g.scaled(0.5).display_name(), format!("{} x0.50", g.name));
        }
    }

    #[test]
    fn model_fits_in_memory_sanity() {
        // Llama-2-7B FP16 = 14 GB must fit in every catalog entry.
        for g in [
            GpuSpec::a100(),
            GpuSpec::h100(),
            GpuSpec::h200(),
            GpuSpec::l40s(),
            GpuSpec::gh200_nvl2(),
        ] {
            assert!(g.mem_bytes > 14e9, "{}", g.name);
        }
    }
}
