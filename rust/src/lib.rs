//! # icc6g — 6G EdgeAI: Integrated Communication and Computing
//!
//! Production-grade reproduction of *"6G EdgeAI: Performance Evaluation
//! and Analysis"* (CS.DC 2025): an **Integrated Communication and
//! Computing (ICC)** serving stack in which LLM compute nodes live
//! inside the RAN and communication + computing latency budgets are
//! managed **jointly**.
//!
//! The crate is organized in three tiers (see DESIGN.md):
//!
//! * **Substrates** — [`rng`], [`dess`] (discrete-event engine),
//!   [`util`] (args/config/stats/property tests).
//! * **Models** — [`queueing`] (tandem M/M/1 theory, Fig 4), [`phy`] +
//!   [`mac`] + [`traffic`] (5G uplink SLS), [`llm`] (roofline cost
//!   model, Eqs 7–8), [`compute`] (compute-node queueing).
//! * **System** — [`coordinator`] (joint/disjoint latency management,
//!   the paper's contribution), [`scenario`] (the composable Scenario
//!   API: N workload classes, pluggable service models, multi-node
//!   routing), [`sim`] (the legacy single-scenario SLS, now a thin
//!   wrapper over [`scenario`], Figs 6–7), [`sweep`] (parallel
//!   replication sweeps with exact merge reduction), [`runtime`] +
//!   [`server`] (real PJRT-backed LLM serving path).
//!
//! Python/JAX/Pallas exist only on the build path (`make artifacts`);
//! the serving hot path is pure Rust + PJRT.

pub mod cluster;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod dess;
pub mod llm;
pub mod mac;
pub mod metrics;
pub mod phy;
pub mod queueing;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod sim;
pub mod snapshot;
pub mod sweep;
pub mod traffic;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
