//! Typed simulation configuration with Table I defaults and TOML
//! loading (`util::tomlmini`).

use crate::llm::{GpuSpec, JobSpec};
use crate::mac::{HarqConfig, MacConfig, SchedulingPolicy};
use crate::phy::Carrier;
use crate::traffic::{BackgroundConfig, JobTrafficConfig};
use crate::util::tomlmini::Document;

/// Deployment of the computing node (drives the wireline constant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deployment {
    /// Compute inside the RAN node (paper: 5 ms).
    Ran,
    /// Operator MEC site behind the UPF (paper: 20 ms).
    Mec,
    /// Remote cloud (motivating baseline; not in Fig 4/6 but used by
    /// the examples).
    Cloud,
}

impl Deployment {
    pub fn wireline_latency(&self) -> f64 {
        match self {
            Deployment::Ran => 0.005,
            Deployment::Mec => 0.020,
            Deployment::Cloud => 0.050,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ran" => Some(Self::Ran),
            "mec" => Some(Self::Mec),
            "cloud" => Some(Self::Cloud),
            _ => None,
        }
    }
}

/// Latency-management mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Management {
    Joint,
    /// With the paper's split: b_comm = 24 ms, b_comp = 56 ms.
    Disjoint { b_comm: f64, b_comp: f64 },
}

/// The full ICC-vs-MEC scheme: deployment + management + priority
/// scheme toggle (paper §IV-B).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchemeConfig {
    pub name: &'static str,
    pub deployment: Deployment,
    pub management: Management,
    /// Job-aware packet prioritization + deadline job queue + drop.
    pub priority_scheme: bool,
}

impl SchemeConfig {
    /// ICC: RAN compute, joint management, priority scheme on.
    pub fn icc() -> Self {
        Self {
            name: "ICC (joint, RAN 5ms, priority)",
            deployment: Deployment::Ran,
            management: Management::Joint,
            priority_scheme: true,
        }
    }

    /// Disjoint management at a RAN node (the "move compute closer"
    /// half-step of Fig 6).
    pub fn disjoint_ran() -> Self {
        Self {
            name: "Disjoint (RAN 5ms)",
            deployment: Deployment::Ran,
            management: Management::Disjoint { b_comm: 0.024, b_comp: 0.056 },
            priority_scheme: false,
        }
    }

    /// 5G MEC baseline: disjoint, 20 ms wireline, FIFO everything.
    pub fn mec() -> Self {
        Self {
            name: "5G MEC (disjoint, 20ms)",
            deployment: Deployment::Mec,
            management: Management::Disjoint { b_comm: 0.024, b_comp: 0.056 },
            priority_scheme: false,
        }
    }

    /// The three Fig 6 schemes in paper order.
    pub fn fig6_schemes() -> [SchemeConfig; 3] {
        [Self::icc(), Self::disjoint_ran(), Self::mec()]
    }
}

/// Everything the SLS needs for one run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_ues: u32,
    /// Cell geometry for UE drops (meters).
    pub cell_r_min: f64,
    pub cell_r_max: f64,
    pub carrier: Carrier,
    pub mac: MacConfig,
    pub job_traffic: JobTrafficConfig,
    pub background: BackgroundConfig,
    pub job: JobSpec,
    /// Per-server compute capacity (a tensor-parallel pool is one
    /// "server"; see DESIGN.md on the Fig 6 vs Fig 7 topologies).
    pub gpu: GpuSpec,
    /// Parallel servers at the computing node (jobs are not split
    /// across servers).
    pub n_gpus: u32,
    pub scheme: SchemeConfig,
    /// Simulated wall-clock horizon (seconds).
    pub horizon: f64,
    /// Warmup discarded from metrics (seconds).
    pub warmup: f64,
    pub seed: u64,
}

impl SimConfig {
    /// Table I defaults (Fig 6 setup with 2× GH200-NVL2).
    pub fn table1() -> Self {
        Self {
            n_ues: 60,
            cell_r_min: 35.0,
            cell_r_max: 300.0,
            carrier: Carrier::table1(),
            mac: MacConfig::default(),
            job_traffic: JobTrafficConfig::default(),
            background: BackgroundConfig::default(),
            job: JobSpec::table1(),
            // Fig 6 node: two GH200-NVL2 modules (each module = 2
            // superchips, aggregated) acting as parallel servers.
            gpu: GpuSpec::gh200_nvl2().scaled(2.0),
            n_gpus: 2,
            scheme: SchemeConfig::mec(),
            horizon: 20.0,
            warmup: 2.0,
            seed: 1,
        }
    }

    /// Apply a scheme preset (also syncs the MAC priority flag).
    pub fn with_scheme(mut self, scheme: SchemeConfig) -> Self {
        self.scheme = scheme;
        self.mac.job_priority = scheme.priority_scheme;
        self
    }

    /// Total offered prompt rate (prompts/s) across the cell.
    pub fn offered_rate(&self) -> f64 {
        self.n_ues as f64 * self.job_traffic.rate_per_ue
    }

    /// Override fields from a mini-TOML document. Unknown keys error.
    pub fn apply_toml(&mut self, doc: &Document) -> anyhow::Result<()> {
        for key in doc.keys() {
            match key {
                "sim.n_ues" => self.n_ues = doc.i64(key).unwrap() as u32,
                "sim.horizon" => self.horizon = doc.f64(key).unwrap(),
                "sim.warmup" => self.warmup = doc.f64(key).unwrap(),
                "sim.seed" => self.seed = doc.i64(key).unwrap() as u64,
                "sim.cell_r_min" => self.cell_r_min = doc.f64(key).unwrap(),
                "sim.cell_r_max" => self.cell_r_max = doc.f64(key).unwrap(),
                "traffic.rate_per_ue" => {
                    self.job_traffic.rate_per_ue = doc.f64(key).unwrap()
                }
                "traffic.input_tokens" => {
                    self.job_traffic.input_tokens = doc.i64(key).unwrap() as u32
                }
                "traffic.background_bps" => {
                    self.background.rate_bps = doc.f64(key).unwrap()
                }
                "job.output_tokens" => self.job.n_output = doc.i64(key).unwrap() as u32,
                "job.b_total" => self.job.b_total = doc.f64(key).unwrap(),
                "gpu.model" => {
                    let name = doc.str(key).unwrap();
                    self.gpu = GpuSpec::by_name(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown GPU '{name}'"))?;
                }
                "gpu.scale" => self.gpu = self.gpu.scaled(doc.f64(key).unwrap()),
                "gpu.count" => self.n_gpus = doc.i64(key).unwrap() as u32,
                "mac.policy" => {
                    self.mac.policy = match doc.str(key).unwrap() {
                        "pf" => SchedulingPolicy::ProportionalFair,
                        "rr" => SchedulingPolicy::RoundRobin,
                        other => anyhow::bail!("unknown mac.policy '{other}'"),
                    }
                }
                "mac.bler" => {
                    self.mac.harq = HarqConfig { bler: doc.f64(key).unwrap(), ..self.mac.harq }
                }
                "scheme.preset" => {
                    let s = match doc.str(key).unwrap() {
                        "icc" => SchemeConfig::icc(),
                        "disjoint_ran" => SchemeConfig::disjoint_ran(),
                        "mec" => SchemeConfig::mec(),
                        other => anyhow::bail!("unknown scheme '{other}'"),
                    };
                    *self = self.clone().with_scheme(s);
                }
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        // keep job tokens in sync with traffic tokens
        self.job.n_input = self.job_traffic.input_tokens;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let c = SimConfig::table1();
        assert_eq!(c.carrier.freq_hz, 3.7e9);
        assert_eq!(c.carrier.bandwidth_hz, 100e6);
        assert_eq!(c.carrier.numerology.scs_hz(), 60_000.0);
        assert_eq!(c.background.rate_bps, 500_000.0);
        assert_eq!(c.job_traffic.rate_per_ue, 1.0);
        assert_eq!(c.job_traffic.input_tokens, 15);
        assert_eq!(c.job.n_output, 15);
        assert!((c.job.b_total - 0.080).abs() < 1e-12);
        // Llama-2-7B FP16
        assert!((c.job.c_llm - 14e9).abs() < 1.0);
        assert!((c.job.m_llm - 14e9).abs() < 1.0);
    }

    #[test]
    fn deployment_wireline_constants() {
        assert_eq!(Deployment::Ran.wireline_latency(), 0.005);
        assert_eq!(Deployment::Mec.wireline_latency(), 0.020);
        assert_eq!(Deployment::parse("RAN"), Some(Deployment::Ran));
        assert_eq!(Deployment::parse("x"), None);
    }

    #[test]
    fn scheme_presets() {
        let icc = SchemeConfig::icc();
        assert_eq!(icc.deployment, Deployment::Ran);
        assert_eq!(icc.management, Management::Joint);
        assert!(icc.priority_scheme);
        let mec = SchemeConfig::mec();
        assert_eq!(mec.deployment, Deployment::Mec);
        assert!(!mec.priority_scheme);
        match mec.management {
            Management::Disjoint { b_comm, b_comp } => {
                assert!((b_comm - 0.024).abs() < 1e-12);
                assert!((b_comp - 0.056).abs() < 1e-12);
            }
            _ => panic!("mec must be disjoint"),
        }
    }

    #[test]
    fn with_scheme_syncs_mac_priority() {
        let c = SimConfig::table1().with_scheme(SchemeConfig::icc());
        assert!(c.mac.job_priority);
        let c = c.with_scheme(SchemeConfig::mec());
        assert!(!c.mac.job_priority);
    }

    #[test]
    fn toml_overrides() {
        let mut c = SimConfig::table1();
        let doc = Document::parse(
            "[sim]\nn_ues = 80\nseed = 9\n[gpu]\nmodel = \"a100\"\nscale = 8\n[scheme]\npreset = \"icc\"",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.n_ues, 80);
        assert_eq!(c.seed, 9);
        assert!((c.gpu.a100_equivalents() - 8.0).abs() < 1e-9);
        assert!(c.mac.job_priority);
    }

    #[test]
    fn toml_unknown_key_rejected() {
        let mut c = SimConfig::table1();
        let doc = Document::parse("nonsense = 1").unwrap();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn offered_rate() {
        let mut c = SimConfig::table1();
        c.n_ues = 80;
        assert_eq!(c.offered_rate(), 80.0);
    }
}
