//! Typed simulation configuration with Table I defaults and TOML
//! loading (`util::tomlmini`).

use crate::llm::{GpuSpec, JobSpec};
use crate::mac::{HarqConfig, MacConfig, SchedulingPolicy};
use crate::phy::Carrier;
use crate::traffic::{BackgroundConfig, JobTrafficConfig};
use crate::util::tomlmini::Document;

/// Deployment of the computing node (drives the wireline constant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deployment {
    /// Compute inside the RAN node (paper: 5 ms).
    Ran,
    /// Operator MEC site behind the UPF (paper: 20 ms).
    Mec,
    /// Remote cloud (motivating baseline; not in Fig 4/6 but used by
    /// the examples).
    Cloud,
}

impl Deployment {
    pub fn wireline_latency(&self) -> f64 {
        match self {
            Deployment::Ran => 0.005,
            Deployment::Mec => 0.020,
            Deployment::Cloud => 0.050,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ran" => Some(Self::Ran),
            "mec" => Some(Self::Mec),
            "cloud" => Some(Self::Cloud),
            _ => None,
        }
    }
}

/// Latency-management mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Management {
    Joint,
    /// With the paper's split: b_comm = 24 ms, b_comp = 56 ms.
    Disjoint { b_comm: f64, b_comp: f64 },
}

/// The full ICC-vs-MEC scheme: deployment + management + priority
/// scheme toggle (paper §IV-B). Assemble custom schemes with
/// [`SchemeConfig::builder`]; the paper presets are thin wrappers over
/// the same builder.
#[derive(Debug, Clone, PartialEq)]
pub struct SchemeConfig {
    pub name: String,
    pub deployment: Deployment,
    pub management: Management,
    /// Job-aware packet prioritization + deadline job queue + drop.
    pub priority_scheme: bool,
}

impl SchemeConfig {
    /// Start assembling a custom scheme (defaults: RAN deployment,
    /// joint management, priority scheme off, auto-generated name).
    pub fn builder() -> SchemeBuilder {
        SchemeBuilder::default()
    }

    /// ICC: RAN compute, joint management, priority scheme on.
    pub fn icc() -> Self {
        Self::builder()
            .name("ICC (joint, RAN 5ms, priority)")
            .deployment(Deployment::Ran)
            .management(Management::Joint)
            .priority(true)
            .build()
    }

    /// Disjoint management at a RAN node (the "move compute closer"
    /// half-step of Fig 6).
    pub fn disjoint_ran() -> Self {
        Self::builder()
            .name("Disjoint (RAN 5ms)")
            .deployment(Deployment::Ran)
            .management(Management::Disjoint { b_comm: 0.024, b_comp: 0.056 })
            .build()
    }

    /// 5G MEC baseline: disjoint, 20 ms wireline, FIFO everything.
    pub fn mec() -> Self {
        Self::builder()
            .name("5G MEC (disjoint, 20ms)")
            .deployment(Deployment::Mec)
            .management(Management::Disjoint { b_comm: 0.024, b_comp: 0.056 })
            .build()
    }

    /// The three Fig 6 schemes in paper order.
    pub fn fig6_schemes() -> [SchemeConfig; 3] {
        [Self::icc(), Self::disjoint_ran(), Self::mec()]
    }

    /// Look up a named preset (the `scheme.preset` TOML / CLI values).
    pub fn preset(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "icc" => Some(Self::icc()),
            "disjoint_ran" => Some(Self::disjoint_ran()),
            "mec" => Some(Self::mec()),
            _ => None,
        }
    }

    /// Shared CLI/bench scheme selection: `"all"` expands to the Fig 6
    /// scheme set, anything else resolves through
    /// [`SchemeConfig::preset`]. The sweep CLI and the perf benches
    /// both route through this, so the preset universe cannot drift
    /// between them.
    pub fn select(name: &str) -> Option<Vec<Self>> {
        if name.eq_ignore_ascii_case("all") {
            Some(Self::fig6_schemes().to_vec())
        } else {
            Self::preset(name).map(|s| vec![s])
        }
    }
}

/// Builder for [`SchemeConfig`] — the extension point for schemes the
/// paper does not enumerate (e.g. joint management at a cloud site, or
/// custom disjoint splits).
#[derive(Debug, Clone)]
pub struct SchemeBuilder {
    name: Option<String>,
    deployment: Deployment,
    management: Management,
    priority_scheme: bool,
}

impl Default for SchemeBuilder {
    fn default() -> Self {
        Self {
            name: None,
            deployment: Deployment::Ran,
            management: Management::Joint,
            priority_scheme: false,
        }
    }
}

impl SchemeBuilder {
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    pub fn deployment(mut self, d: Deployment) -> Self {
        self.deployment = d;
        self
    }

    pub fn management(mut self, m: Management) -> Self {
        self.management = m;
        self
    }

    pub fn priority(mut self, on: bool) -> Self {
        self.priority_scheme = on;
        self
    }

    pub fn build(self) -> SchemeConfig {
        let name = self.name.unwrap_or_else(|| {
            let mgmt = match self.management {
                Management::Joint => "joint".to_string(),
                Management::Disjoint { b_comm, b_comp } => {
                    format!("disjoint {:.0}/{:.0}ms", b_comm * 1e3, b_comp * 1e3)
                }
            };
            format!(
                "{mgmt}, {:?} {:.0}ms{}",
                self.deployment,
                self.deployment.wireline_latency() * 1e3,
                if self.priority_scheme { ", priority" } else { "" }
            )
        });
        SchemeConfig {
            name,
            deployment: self.deployment,
            management: self.management,
            priority_scheme: self.priority_scheme,
        }
    }
}

/// Everything the SLS needs for one run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_ues: u32,
    /// Cell geometry for UE drops (meters).
    pub cell_r_min: f64,
    pub cell_r_max: f64,
    pub carrier: Carrier,
    pub mac: MacConfig,
    pub job_traffic: JobTrafficConfig,
    pub background: BackgroundConfig,
    pub job: JobSpec,
    /// Per-server compute capacity (a tensor-parallel pool is one
    /// "server"; see DESIGN.md on the Fig 6 vs Fig 7 topologies).
    pub gpu: GpuSpec,
    /// Parallel servers at the computing node (jobs are not split
    /// across servers).
    pub n_gpus: u32,
    pub scheme: SchemeConfig,
    /// Simulated wall-clock horizon (seconds).
    pub horizon: f64,
    /// Warmup discarded from metrics (seconds).
    pub warmup: f64,
    pub seed: u64,
}

impl SimConfig {
    /// Table I defaults (Fig 6 setup with 2× GH200-NVL2).
    pub fn table1() -> Self {
        Self {
            n_ues: 60,
            cell_r_min: 35.0,
            cell_r_max: 300.0,
            carrier: Carrier::table1(),
            mac: MacConfig::default(),
            job_traffic: JobTrafficConfig::default(),
            background: BackgroundConfig::default(),
            job: JobSpec::table1(),
            // Fig 6 node: two GH200-NVL2 modules (each module = 2
            // superchips, aggregated) acting as parallel servers.
            gpu: GpuSpec::gh200_nvl2().scaled(2.0),
            n_gpus: 2,
            scheme: SchemeConfig::mec(),
            horizon: 20.0,
            warmup: 2.0,
            seed: 1,
        }
    }

    /// Apply a scheme preset (also syncs the MAC priority flag).
    pub fn with_scheme(mut self, scheme: SchemeConfig) -> Self {
        self.mac.job_priority = scheme.priority_scheme;
        self.scheme = scheme;
        self
    }

    /// Total offered prompt rate (prompts/s) across the cell.
    pub fn offered_rate(&self) -> f64 {
        self.n_ues as f64 * self.job_traffic.rate_per_ue
    }

    /// Override fields from a mini-TOML document. Unknown keys error.
    pub fn apply_toml(&mut self, doc: &Document) -> anyhow::Result<()> {
        for key in doc.keys() {
            match key {
                "sim.n_ues" => self.n_ues = doc.i64(key).unwrap() as u32,
                "sim.horizon" => self.horizon = doc.f64(key).unwrap(),
                "sim.warmup" => self.warmup = doc.f64(key).unwrap(),
                "sim.seed" => self.seed = doc.i64(key).unwrap() as u64,
                "sim.cell_r_min" => self.cell_r_min = doc.f64(key).unwrap(),
                "sim.cell_r_max" => self.cell_r_max = doc.f64(key).unwrap(),
                "traffic.rate_per_ue" => {
                    self.job_traffic.rate_per_ue = doc.f64(key).unwrap()
                }
                "traffic.input_tokens" => {
                    self.job_traffic.input_tokens = doc.i64(key).unwrap() as u32
                }
                "traffic.background_bps" => {
                    self.background.rate_bps = doc.f64(key).unwrap()
                }
                "job.output_tokens" => self.job.n_output = doc.i64(key).unwrap() as u32,
                "job.b_total" => self.job.b_total = doc.f64(key).unwrap(),
                "gpu.model" => {
                    let name = doc.str(key).unwrap();
                    self.gpu = GpuSpec::by_name(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown GPU '{name}'"))?;
                }
                "gpu.scale" => self.gpu = self.gpu.scaled(doc.f64(key).unwrap()),
                "gpu.count" => self.n_gpus = doc.i64(key).unwrap() as u32,
                "mac.policy" => {
                    self.mac.policy = match doc.str(key).unwrap() {
                        "pf" => SchedulingPolicy::ProportionalFair,
                        "rr" => SchedulingPolicy::RoundRobin,
                        other => anyhow::bail!("unknown mac.policy '{other}'"),
                    }
                }
                "mac.bler" => {
                    self.mac.harq = HarqConfig { bler: doc.f64(key).unwrap(), ..self.mac.harq }
                }
                // Scheme keys are applied together after this loop so
                // `scheme.preset` composes with field overrides
                // regardless of key order; apply_scheme_toml owns the
                // key set and rejects unknown ones.
                k if k.starts_with("scheme.") => {}
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        self.apply_scheme_toml(doc)?;
        // keep job tokens in sync with traffic tokens
        self.job.n_input = self.job_traffic.input_tokens;
        Ok(())
    }

    /// Assemble the scheme from `[scheme]` keys: an optional preset as
    /// the base, then builder-style field overrides. This function owns
    /// the `[scheme]` key set — callers skip `scheme.`-prefixed keys
    /// and rely on it to reject unknown or mistyped ones.
    pub(crate) fn apply_scheme_toml(&mut self, doc: &Document) -> anyhow::Result<()> {
        let mut present = false;
        for key in doc.keys().filter(|k| k.starts_with("scheme.")) {
            match key {
                "scheme.preset" | "scheme.deployment" | "scheme.management"
                | "scheme.b_comm" | "scheme.b_comp" | "scheme.priority" => present = true,
                other => anyhow::bail!("unknown scheme key '{other}'"),
            }
        }
        if !present {
            return Ok(());
        }
        let base = match typed_str(doc, "scheme.preset")? {
            Some(p) => SchemeConfig::preset(p)
                .ok_or_else(|| anyhow::anyhow!("unknown scheme '{p}'"))?,
            None => self.scheme.clone(),
        };
        let mut deployment = base.deployment;
        let mut management = base.management;
        let mut priority = base.priority_scheme;
        let mut overridden = false;
        if let Some(s) = typed_str(doc, "scheme.deployment")? {
            deployment = Deployment::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown deployment '{s}'"))?;
            overridden = true;
        }
        if let Some(m) = typed_str(doc, "scheme.management")? {
            management = match m {
                "joint" => Management::Joint,
                "disjoint" => Management::Disjoint { b_comm: 0.024, b_comp: 0.056 },
                other => anyhow::bail!("unknown management '{other}'"),
            };
            overridden = true;
        }
        for (key, pick) in [("scheme.b_comm", 0usize), ("scheme.b_comp", 1usize)] {
            if let Some(v) = typed_f64(doc, key)? {
                match &mut management {
                    Management::Disjoint { b_comm, b_comp } => {
                        *(if pick == 0 { b_comm } else { b_comp }) = v;
                    }
                    Management::Joint => {
                        anyhow::bail!("'{key}' requires disjoint management")
                    }
                }
                overridden = true;
            }
        }
        if let Some(v) = doc.get("scheme.priority") {
            priority = v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("'scheme.priority' must be a bool"))?;
            overridden = true;
        }
        // No-op overrides keep the base's recognizable label; real
        // changes get an auto-generated one from the builder.
        let unchanged = deployment == base.deployment
            && management == base.management
            && priority == base.priority_scheme;
        let scheme = if overridden && !unchanged {
            SchemeConfig::builder()
                .deployment(deployment)
                .management(management)
                .priority(priority)
                .build()
        } else {
            base
        };
        *self = self.clone().with_scheme(scheme);
        Ok(())
    }
}

/// Present-but-mistyped config values must error, not be ignored.
/// Shared with the scenario TOML loader.
pub(crate) fn typed_str<'a>(
    doc: &'a Document,
    key: &str,
) -> anyhow::Result<Option<&'a str>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("'{key}' must be a string")),
    }
}

pub(crate) fn typed_f64(doc: &Document, key: &str) -> anyhow::Result<Option<f64>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("'{key}' must be a number")),
    }
}

pub(crate) fn typed_i64(doc: &Document, key: &str) -> anyhow::Result<Option<i64>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("'{key}' must be an integer")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let c = SimConfig::table1();
        assert_eq!(c.carrier.freq_hz, 3.7e9);
        assert_eq!(c.carrier.bandwidth_hz, 100e6);
        assert_eq!(c.carrier.numerology.scs_hz(), 60_000.0);
        assert_eq!(c.background.rate_bps, 500_000.0);
        assert_eq!(c.job_traffic.rate_per_ue, 1.0);
        assert_eq!(c.job_traffic.input_tokens, 15);
        assert_eq!(c.job.n_output, 15);
        assert!((c.job.b_total - 0.080).abs() < 1e-12);
        // Llama-2-7B FP16
        assert!((c.job.c_llm - 14e9).abs() < 1.0);
        assert!((c.job.m_llm - 14e9).abs() < 1.0);
    }

    #[test]
    fn deployment_wireline_constants() {
        assert_eq!(Deployment::Ran.wireline_latency(), 0.005);
        assert_eq!(Deployment::Mec.wireline_latency(), 0.020);
        assert_eq!(Deployment::parse("RAN"), Some(Deployment::Ran));
        assert_eq!(Deployment::parse("x"), None);
    }

    #[test]
    fn scheme_presets() {
        let icc = SchemeConfig::icc();
        assert_eq!(icc.deployment, Deployment::Ran);
        assert_eq!(icc.management, Management::Joint);
        assert!(icc.priority_scheme);
        let mec = SchemeConfig::mec();
        assert_eq!(mec.deployment, Deployment::Mec);
        assert!(!mec.priority_scheme);
        match mec.management {
            Management::Disjoint { b_comm, b_comp } => {
                assert!((b_comm - 0.024).abs() < 1e-12);
                assert!((b_comp - 0.056).abs() < 1e-12);
            }
            _ => panic!("mec must be disjoint"),
        }
    }

    #[test]
    fn with_scheme_syncs_mac_priority() {
        let c = SimConfig::table1().with_scheme(SchemeConfig::icc());
        assert!(c.mac.job_priority);
        let c = c.with_scheme(SchemeConfig::mec());
        assert!(!c.mac.job_priority);
    }

    #[test]
    fn toml_overrides() {
        let mut c = SimConfig::table1();
        let doc = Document::parse(
            "[sim]\nn_ues = 80\nseed = 9\n[gpu]\nmodel = \"a100\"\nscale = 8\n[scheme]\npreset = \"icc\"",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.n_ues, 80);
        assert_eq!(c.seed, 9);
        assert!((c.gpu.a100_equivalents() - 8.0).abs() < 1e-9);
        assert!(c.mac.job_priority);
    }

    #[test]
    fn toml_unknown_key_rejected() {
        let mut c = SimConfig::table1();
        let doc = Document::parse("nonsense = 1").unwrap();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn scheme_builder_assembles_custom_schemes() {
        let s = SchemeConfig::builder()
            .deployment(Deployment::Cloud)
            .management(Management::Disjoint { b_comm: 0.030, b_comp: 0.050 })
            .priority(true)
            .build();
        assert_eq!(s.deployment, Deployment::Cloud);
        assert!(s.priority_scheme);
        assert!(!s.name.is_empty(), "auto-generated label expected");
        let named = SchemeConfig::builder().name("mine").build();
        assert_eq!(named.name, "mine");
        // presets route through the same builder
        assert_eq!(SchemeConfig::preset("icc"), Some(SchemeConfig::icc()));
        assert_eq!(SchemeConfig::preset("zzz"), None);
    }

    #[test]
    fn scheme_selection_covers_presets_and_all() {
        assert_eq!(
            SchemeConfig::select("all").unwrap(),
            SchemeConfig::fig6_schemes().to_vec()
        );
        assert_eq!(SchemeConfig::select("ALL").unwrap().len(), 3);
        assert_eq!(SchemeConfig::select("mec").unwrap(), vec![SchemeConfig::mec()]);
        assert_eq!(SchemeConfig::select("nope"), None);
    }

    #[test]
    fn toml_scheme_field_overrides_compose_with_preset() {
        let mut c = SimConfig::table1();
        let doc = Document::parse(
            "[scheme]\npreset = \"mec\"\ndeployment = \"ran\"\nb_comm = 0.030",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.scheme.deployment, Deployment::Ran);
        match c.scheme.management {
            Management::Disjoint { b_comm, b_comp } => {
                assert!((b_comm - 0.030).abs() < 1e-12);
                assert!((b_comp - 0.056).abs() < 1e-12);
            }
            _ => panic!("must stay disjoint"),
        }
        assert!(!c.scheme.priority_scheme);
        assert!(!c.mac.job_priority);
    }

    #[test]
    fn toml_budget_split_requires_disjoint() {
        let mut c = SimConfig::table1();
        let doc =
            Document::parse("[scheme]\npreset = \"icc\"\nb_comm = 0.030").unwrap();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_scheme_rejects_mistyped_and_unknown_keys() {
        // mistyped values must error, not be silently dropped
        for bad in [
            "[scheme]\ndeployment = 1",
            "[scheme]\nb_comm = \"0.03\"\nmanagement = \"disjoint\"",
            "[scheme]\npriority = \"yes\"",
            "[scheme]\nfrobnicate = true",
        ] {
            let mut c = SimConfig::table1();
            let doc = Document::parse(bad).unwrap();
            assert!(c.apply_toml(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn offered_rate() {
        let mut c = SimConfig::table1();
        c.n_ues = 80;
        assert_eq!(c.offered_rate(), 80.0);
    }
}
