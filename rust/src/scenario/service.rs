//! Pluggable LLM service-time models.
//!
//! The legacy SLS computed one deterministic roofline latency in
//! `Sls::new` and charged it to every job. A [`ServiceModel`] instead
//! realizes each job's compute demand when it reaches a node, which is
//! what lets one scenario mix classes with different models, prompt
//! lengths, and output-length variability:
//!
//! * [`RooflineService`] — the paper's Eqs 7–8: deterministic prefill +
//!   decode at the class's mean output length. Consumes no randomness,
//!   preserving the legacy SLS's deterministic service times.
//! * [`TokenSampledService`] — draws the output length per job from the
//!   class distribution and prices prefill/decode on the realized
//!   token counts. This is the service-time variability that mixed
//!   LLM serving studies (arXiv:2411.17712) show dominates tail
//!   latency.
//!
//! Demands are returned *split* into prefill and decode phases: the
//! sequential execution model charges their sum as one service time,
//! while the continuous-batching engine admits the prefill and batches
//! the decode steps (and both derive TTFT/TPOT from the split).

use crate::llm::{CostModel, GpuSpec, ModelSpec};
use crate::rng::Rng;

use super::workload::WorkloadClass;

/// A realized job's compute demand, split at the prefill/decode
/// boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceDemand {
    /// Output length charged to the job (≥ 1).
    pub n_output: u32,
    /// Prefill latency on the chosen node (Eq 7).
    pub prefill_time: f64,
    /// Sequential decode latency `N_output · max(C/G_comp, M/G_membw)`
    /// (Eq 8).
    pub decode_time: f64,
}

impl ServiceDemand {
    /// Whole-job service time (what the sequential model charges).
    pub fn service_time(&self) -> f64 {
        self.prefill_time + self.decode_time
    }

    /// Per-token decode latency when served alone.
    pub fn token_time(&self) -> f64 {
        self.decode_time / self.n_output.max(1) as f64
    }
}

/// Maps (class, realized prompt, node capacity) → service demand.
pub trait ServiceModel: std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Realize one job. `rng` is a dedicated service stream; models
    /// that are deterministic must not consume it.
    fn realize(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        gpu: &GpuSpec,
        rng: &mut Rng,
    ) -> ServiceDemand;

    /// Re-price an already-realized job on a (possibly different)
    /// destination node: same token counts, destination roofline
    /// (DESIGN.md §11). Called on cluster re-dispatch, where the
    /// original realization's service RNG draw must not be repeated.
    /// Must be deterministic; the default prices the stored counts on
    /// the destination GPU, which reproduces the original demand bit
    /// for bit when the destination tier matches the source.
    fn reprice(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        n_output: u32,
        gpu: &GpuSpec,
    ) -> ServiceDemand {
        price(class, n_input, n_output, gpu)
    }

    /// Realize one job against an explicit zoo model: the demand uses
    /// `model`'s FLOP/byte profile instead of the class's single-model
    /// constants. The default realizes through [`ServiceModel::realize`]
    /// (so the output-length draw — and RNG consumption — is exactly
    /// the single-model one) and re-prices the realized counts on the
    /// model; custom implementations that already price per model can
    /// override.
    fn realize_on(
        &self,
        class: &WorkloadClass,
        model: &ModelSpec,
        n_input: u32,
        gpu: &GpuSpec,
        rng: &mut Rng,
    ) -> ServiceDemand {
        let d = self.realize(class, n_input, gpu, rng);
        price_on(class, model, n_input, d.n_output, gpu)
    }

    /// Re-price an already-realized job on the destination node's
    /// chosen zoo model (cluster re-dispatch may land on a node that
    /// hosts a different tier). Deterministic, consumes no randomness.
    fn reprice_on(
        &self,
        class: &WorkloadClass,
        model: &ModelSpec,
        n_input: u32,
        n_output: u32,
        gpu: &GpuSpec,
    ) -> ServiceDemand {
        price_on(class, model, n_input, n_output, gpu)
    }
}

/// Shared pricing tail: assert the documented "model must fit" rule
/// (scenario build validation should make this unreachable; custom
/// assemblies that bypass the builder still fail loudly here) and
/// price the realized token counts on the node.
///
/// `pub(crate)` so cluster re-dispatch can re-price an
/// already-realized job on a *different* destination tier (same token
/// counts, destination roofline — DESIGN.md §11). Pricing is
/// deterministic in its arguments and consumes no randomness, so a
/// same-tier retry reproduces the original demand bit for bit.
pub(crate) fn price(
    class: &WorkloadClass,
    n_input: u32,
    n_output: u32,
    gpu: &GpuSpec,
) -> ServiceDemand {
    let spec = class.job_spec(n_input, n_output);
    let m = CostModel::new(*gpu);
    assert!(
        m.fits(&spec),
        "model of class '{}' ({:.1} GB) does not fit {} ({:.1} GB)",
        class.name,
        spec.m_llm / 1e9,
        gpu.display_name(),
        gpu.mem_bytes / 1e9,
    );
    ServiceDemand {
        n_output,
        prefill_time: m.prefill_latency(&spec),
        decode_time: m.tokengen_latency(&spec),
    }
}

/// Model-zoo pricing: the class supplies the token counts and budget,
/// the [`ModelSpec`] supplies the FLOP/byte demand profile. Same
/// fit-assertion and roofline as [`price`].
pub(crate) fn price_on(
    class: &WorkloadClass,
    model: &ModelSpec,
    n_input: u32,
    n_output: u32,
    gpu: &GpuSpec,
) -> ServiceDemand {
    let mut spec = class.job_spec(n_input, n_output);
    spec.c_llm = model.c_llm;
    spec.m_llm = model.m_llm;
    let m = CostModel::new(*gpu);
    assert!(
        m.fits(&spec),
        "model '{}' ({:.1} GB) of class '{}' does not fit {} ({:.1} GB)",
        model.name,
        spec.m_llm / 1e9,
        class.name,
        gpu.display_name(),
        gpu.mem_bytes / 1e9,
    );
    ServiceDemand {
        n_output,
        prefill_time: m.prefill_latency(&spec),
        decode_time: m.tokengen_latency(&spec),
    }
}

/// Deterministic two-phase roofline (paper Eqs 7–8) at the class's
/// mean output length.
#[derive(Debug, Clone, Copy, Default)]
pub struct RooflineService;

impl ServiceModel for RooflineService {
    fn name(&self) -> &'static str {
        "roofline"
    }

    fn realize(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        gpu: &GpuSpec,
        _rng: &mut Rng,
    ) -> ServiceDemand {
        let n_output = class.output_tokens.mean().round().max(1.0) as u32;
        price(class, n_input, n_output, gpu)
    }
}

/// Prefill/decode roofline on per-job sampled output lengths.
#[derive(Debug, Clone, Copy, Default)]
pub struct TokenSampledService;

impl ServiceModel for TokenSampledService {
    fn name(&self) -> &'static str {
        "token_sampled"
    }

    fn realize(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        gpu: &GpuSpec,
        rng: &mut Rng,
    ) -> ServiceDemand {
        let n_output = class.output_tokens.sample(rng).max(1);
        price(class, n_input, n_output, gpu)
    }
}

/// Config-level service-model selector (`[service] model = "..."`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServiceModelKind {
    #[default]
    Roofline,
    TokenSampled,
}

impl ServiceModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "roofline" | "deterministic" => Some(Self::Roofline),
            "token_sampled" | "token-sampled" | "sampled" => Some(Self::TokenSampled),
            _ => None,
        }
    }

    pub fn build(self) -> Box<dyn ServiceModel> {
        match self {
            Self::Roofline => Box::new(RooflineService),
            Self::TokenSampled => Box::new(TokenSampledService),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::JobSpec;
    use crate::scenario::workload::TokenDist;
    use crate::traffic::JobTrafficConfig;

    fn table1_class() -> WorkloadClass {
        WorkloadClass::from_legacy(&JobTrafficConfig::default(), &JobSpec::table1())
    }

    #[test]
    fn roofline_matches_cost_model_and_is_deterministic() {
        let class = table1_class();
        let gpu = GpuSpec::gh200_nvl2().scaled(2.0);
        let mut rng = Rng::new(1);
        let before = rng.clone().u64();
        let d = RooflineService.realize(&class, 15, &gpu, &mut rng);
        // no randomness consumed
        assert_eq!(rng.clone().u64(), before);
        let m = CostModel::new(gpu);
        let expect = m.total_latency(&JobSpec::table1());
        assert!((d.service_time() - expect).abs() < 1e-15);
        assert!((d.prefill_time - m.prefill_latency(&JobSpec::table1())).abs() < 1e-18);
        assert!((d.decode_time - m.tokengen_latency(&JobSpec::table1())).abs() < 1e-18);
        assert!((d.token_time() - m.token_latency(&JobSpec::table1())).abs() < 1e-18);
        assert_eq!(d.n_output, 15);
    }

    #[test]
    fn token_sampled_varies_with_output_length() {
        let class = table1_class().with_output(TokenDist::Geometric { mean: 32.0 });
        let gpu = GpuSpec::a100().scaled(8.0);
        let mut rng = Rng::new(7);
        let demands: Vec<ServiceDemand> =
            (0..64).map(|_| TokenSampledService.realize(&class, 15, &gpu, &mut rng)).collect();
        let distinct: std::collections::BTreeSet<u32> =
            demands.iter().map(|d| d.n_output).collect();
        assert!(distinct.len() > 5, "output lengths should vary: {distinct:?}");
        // longer outputs must cost more
        let mut sorted = demands.clone();
        sorted.sort_by(|a, b| a.n_output.cmp(&b.n_output));
        for w in sorted.windows(2) {
            if w[0].n_output < w[1].n_output {
                assert!(w[0].service_time() < w[1].service_time());
                // prefill unchanged — only decode grows
                assert!((w[0].prefill_time - w[1].prefill_time).abs() < 1e-18);
            }
        }
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn pricing_rejects_model_larger_than_memory() {
        // 30B FP16 (60 GB) on a 48 GB L40S must fail loudly.
        let class = table1_class().with_model(60e9, 60e9);
        let gpu = GpuSpec::l40s();
        let mut rng = Rng::new(1);
        RooflineService.realize(&class, 15, &gpu, &mut rng);
    }

    #[test]
    fn realize_on_prices_the_zoo_model_with_legacy_rng_consumption() {
        let class = table1_class();
        let gpu = GpuSpec::gh200_nvl2().scaled(4.0);
        let small = ModelSpec::llama_7b();
        let big = ModelSpec::llama_70b();
        let mut rng = Rng::new(3);
        let before = rng.clone().u64();
        let d7 = RooflineService.realize_on(&class, &small, 15, &gpu, &mut rng);
        assert_eq!(rng.clone().u64(), before, "roofline consumes no randomness");
        let d70 = RooflineService.realize_on(&class, &big, 15, &gpu, &mut rng);
        assert!(
            d70.service_time() > d7.service_time(),
            "the 70B tier must cost more than the 7B tier"
        );
        // re-pricing the realized counts on the same model reproduces
        // the demand bit for bit
        let r = RooflineService.reprice_on(&class, &big, 15, d70.n_output, &gpu);
        assert_eq!(r, d70);
        // token-sampled realization consumes exactly one draw, same as
        // the single-model path
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let sa = TokenSampledService.realize(&class, 15, &gpu, &mut a);
        let sb = TokenSampledService.realize_on(&class, &small, 15, &gpu, &mut b);
        assert_eq!(sa.n_output, sb.n_output, "same draw, same output length");
        assert_eq!(a.u64(), b.u64(), "RNG streams stay in lockstep");
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(ServiceModelKind::parse("roofline"), Some(ServiceModelKind::Roofline));
        assert_eq!(
            ServiceModelKind::parse("token_sampled"),
            Some(ServiceModelKind::TokenSampled)
        );
        assert_eq!(ServiceModelKind::parse("magic"), None);
        assert_eq!(ServiceModelKind::Roofline.build().name(), "roofline");
        assert_eq!(ServiceModelKind::TokenSampled.build().name(), "token_sampled");
    }
}
