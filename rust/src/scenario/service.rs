//! Pluggable LLM service-time models.
//!
//! The legacy SLS computed one deterministic roofline latency in
//! `Sls::new` and charged it to every job. A [`ServiceModel`] instead
//! realizes each job's compute demand when it reaches a node, which is
//! what lets one scenario mix classes with different models, prompt
//! lengths, and output-length variability:
//!
//! * [`RooflineService`] — the paper's Eqs 7–8: deterministic prefill +
//!   decode at the class's mean output length. Consumes no randomness,
//!   preserving the legacy SLS's deterministic service times.
//! * [`TokenSampledService`] — draws the output length per job from the
//!   class distribution and prices prefill/decode on the realized
//!   token counts. This is the service-time variability that mixed
//!   LLM serving studies (arXiv:2411.17712) show dominates tail
//!   latency.

use crate::llm::{CostModel, GpuSpec};
use crate::rng::Rng;

use super::workload::WorkloadClass;

/// A realized job's compute demand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceDemand {
    /// Output length charged to the job.
    pub n_output: u32,
    /// Service time in seconds on the chosen node.
    pub service_time: f64,
}

/// Maps (class, realized prompt, node capacity) → service demand.
pub trait ServiceModel: std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Realize one job. `rng` is a dedicated service stream; models
    /// that are deterministic must not consume it.
    fn realize(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        gpu: &GpuSpec,
        rng: &mut Rng,
    ) -> ServiceDemand;
}

/// Deterministic two-phase roofline (paper Eqs 7–8) at the class's
/// mean output length.
#[derive(Debug, Clone, Copy, Default)]
pub struct RooflineService;

impl ServiceModel for RooflineService {
    fn name(&self) -> &'static str {
        "roofline"
    }

    fn realize(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        gpu: &GpuSpec,
        _rng: &mut Rng,
    ) -> ServiceDemand {
        let n_output = class.output_tokens.mean().round().max(1.0) as u32;
        let spec = class.job_spec(n_input, n_output);
        let m = CostModel::new(*gpu);
        ServiceDemand { n_output, service_time: m.total_latency(&spec) }
    }
}

/// Prefill/decode roofline on per-job sampled output lengths.
#[derive(Debug, Clone, Copy, Default)]
pub struct TokenSampledService;

impl ServiceModel for TokenSampledService {
    fn name(&self) -> &'static str {
        "token_sampled"
    }

    fn realize(
        &self,
        class: &WorkloadClass,
        n_input: u32,
        gpu: &GpuSpec,
        rng: &mut Rng,
    ) -> ServiceDemand {
        let n_output = class.output_tokens.sample(rng).max(1);
        let spec = class.job_spec(n_input, n_output);
        let m = CostModel::new(*gpu);
        ServiceDemand { n_output, service_time: m.total_latency(&spec) }
    }
}

/// Config-level service-model selector (`[service] model = "..."`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServiceModelKind {
    #[default]
    Roofline,
    TokenSampled,
}

impl ServiceModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "roofline" | "deterministic" => Some(Self::Roofline),
            "token_sampled" | "token-sampled" | "sampled" => Some(Self::TokenSampled),
            _ => None,
        }
    }

    pub fn build(self) -> Box<dyn ServiceModel> {
        match self {
            Self::Roofline => Box::new(RooflineService),
            Self::TokenSampled => Box::new(TokenSampledService),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::JobSpec;
    use crate::scenario::workload::TokenDist;
    use crate::traffic::JobTrafficConfig;

    fn table1_class() -> WorkloadClass {
        WorkloadClass::from_legacy(&JobTrafficConfig::default(), &JobSpec::table1())
    }

    #[test]
    fn roofline_matches_cost_model_and_is_deterministic() {
        let class = table1_class();
        let gpu = GpuSpec::gh200_nvl2().scaled(2.0);
        let mut rng = Rng::new(1);
        let before = rng.clone().u64();
        let d = RooflineService.realize(&class, 15, &gpu, &mut rng);
        // no randomness consumed
        assert_eq!(rng.clone().u64(), before);
        let expect = CostModel::new(gpu).total_latency(&JobSpec::table1());
        assert!((d.service_time - expect).abs() < 1e-15);
        assert_eq!(d.n_output, 15);
    }

    #[test]
    fn token_sampled_varies_with_output_length() {
        let class = table1_class().with_output(TokenDist::Geometric { mean: 32.0 });
        let gpu = GpuSpec::a100().scaled(8.0);
        let mut rng = Rng::new(7);
        let demands: Vec<ServiceDemand> =
            (0..64).map(|_| TokenSampledService.realize(&class, 15, &gpu, &mut rng)).collect();
        let distinct: std::collections::BTreeSet<u32> =
            demands.iter().map(|d| d.n_output).collect();
        assert!(distinct.len() > 5, "output lengths should vary: {distinct:?}");
        // longer outputs must cost more
        let mut sorted = demands.clone();
        sorted.sort_by(|a, b| a.n_output.cmp(&b.n_output));
        for w in sorted.windows(2) {
            if w[0].n_output < w[1].n_output {
                assert!(w[0].service_time < w[1].service_time);
            }
        }
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(ServiceModelKind::parse("roofline"), Some(ServiceModelKind::Roofline));
        assert_eq!(
            ServiceModelKind::parse("token_sampled"),
            Some(ServiceModelKind::TokenSampled)
        );
        assert_eq!(ServiceModelKind::parse("magic"), None);
        assert_eq!(ServiceModelKind::Roofline.build().name(), "roofline");
        assert_eq!(ServiceModelKind::TokenSampled.build().name(), "token_sampled");
    }
}
