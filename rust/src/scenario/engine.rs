//! The scenario event loop: the Fig 5 pipeline generalized to N
//! workload classes, K cells and M compute nodes.
//!
//! ```text
//!  cell 0: UE job gen ─► RLC ─► slot scheduler ─► gNB 0 ─┐
//!  cell 1: UE job gen ─► RLC ─► slot scheduler ─► gNB 1 ─┤ wireline
//!    ⋮         (each cell: own UeBank/workspace/RNGs)    ⋮    │
//!                                                             ▼
//!     per-class/per-cell outcomes ◄── ServiceModel ◄── Routing ──► node 0..M
//!                                                  (Sequential server
//!                                                   or BatchEngine)
//! ```
//!
//! Stream discipline: every entity draws from its own substream of its
//! *cell's* seed ([`super::cell_seed`]; cell 0 keeps the master seed)
//! from a disjoint id range, the event-handler logic mirrors the legacy
//! `Sls::run` loop line for line, and `TokenDist::Fixed` consumes no
//! randomness — so single-cell, single-class runs are exactly as
//! deterministic and statistically identical to the seed SLS. The
//! execution models consume no randomness either.
//!
//! Determinism rule for multi-cell merging (DESIGN.md §9, §12): the
//! per-cell slot clocks live *outside* the event calendar. At every
//! instant the engine first drains calendar events (in insertion
//! order, as before), then steps the due cells — inline, on the
//! [`StepPool`] barrier workers, or asynchronously via the
//! [`FrontierPool`] conservative scheduler — and merges their
//! delivered SDUs into the calendar in ascending (slot-time,
//! cell-index) order. Because a slot step touches only its own cell's
//! state and the merge order is fixed, every driver's schedule is
//! bit-identical to the serial one.
//!
//! Checkpointing (DESIGN.md §13): the loop state lives in
//! [`ScenarioEngine`], which runs in bounded segments
//! ([`ScenarioEngine::run_to`]) and can serialize its complete dynamic
//! state between segments ([`ScenarioEngine::snapshot`] /
//! [`ScenarioEngine::from_snapshot`]). `run_to` always stops at a
//! *quiescence point* — every calendar event and slot boundary at or
//! below the bound processed, deliveries merged — so the captured
//! bytes are independent of the step driver and thread count, and a
//! restored engine replays the exact trajectory of an uninterrupted
//! run.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Mutex;

use crate::cluster::{ClusterRt, ClusterRtState};
use crate::compute::{
    BatchEngine, BatchEvent, BatchJob, ComputeJob, ComputeNode, Discipline, ExecutionModel,
    NodeEvent,
};
use crate::config::{Management, SchemeConfig};
use crate::dess::EventQueue;
use crate::mac::{Sdu, SduKind, UeHot};
use crate::metrics::{CellRadioReport, JobFate, JobOutcome, LatencyManagement, SimReport};
use crate::phy::channel::{LargeScale, Position};
use crate::phy::link::iot_db_from_linear;
use crate::phy::mobility::MobilitySpec;
use crate::queueing::analytic::{
    disjoint_satisfaction, joint_satisfaction, tandem_mean_sojourn, SystemParams,
};
use crate::rng::Rng;
use crate::snapshot::{self as snap, Dec, Enc, SnapError};
use crate::sweep::resolve_threads;

use super::cells::{
    cell_seed, CellRt, CellRtState, CellSync, FrontierPool, StepDriver, StepPool, StepRec,
    UeGeoSnap, UeSnap,
};
use super::fluid::{
    self, FluidCell, FluidCellReport, FluidClassReport, FluidReport, FluidRt,
};
use super::routing::{ModelView, NodeView, RouteCtx, Routing};
use super::workload::WorkloadClass;
use super::{CellSpec, NodeSpec, Scenario};

/// Map a scheme to the node queue discipline.
pub fn discipline_of(scheme: &SchemeConfig) -> Discipline {
    if scheme.priority_scheme {
        Discipline::DeadlinePriority { drop_hopeless: true }
    } else {
        Discipline::Fifo
    }
}

/// Map a scheme to the satisfaction policy for one class budget.
pub fn management_of(scheme: &SchemeConfig, b_total: f64) -> LatencyManagement {
    match scheme.management {
        Management::Joint => LatencyManagement::Joint { b_total },
        Management::Disjoint { b_comm, b_comp } => {
            LatencyManagement::Disjoint { b_total, b_comm, b_comp }
        }
    }
}

/// Result of one scenario run.
#[derive(Debug)]
pub struct ScenarioResult {
    pub outcomes: Vec<JobOutcome>,
    /// Aggregate report with `per_class` (and, for multi-cell
    /// scenarios, `per_cell`) populated.
    pub report: SimReport,
    /// Simulated events processed (calendar pops + cell-slot steps).
    pub events: u64,
    /// Simulated seconds per wall-clock second.
    pub speedup: f64,
    /// Fluid-tier summary (hybrid-fidelity runs only, DESIGN.md §15).
    pub fluid: Option<FluidReport>,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Job of `class` generated at UE `ue` of `cell`.
    JobArrival { cell: u32, ue: u32, class: u32 },
    /// Background packet at UE `ue` of `cell`.
    BgArrival { cell: u32, ue: u32 },
    /// Prompt fully received at the gNB crossed the wireline.
    ComputeEnqueue { job: u64 },
    /// Sequential node `node` finished `job`. `epoch` is the node's
    /// cluster epoch at scheduling time (always 0 without a cluster);
    /// the event is stale — the job was evicted — if the epoch lapsed.
    ComputeDone { node: usize, job: u64, epoch: u32 },
    /// Iteration boundary of node `node`'s batch engine (same epoch
    /// staleness rule as `ComputeDone`).
    BatchStep { node: usize, epoch: u32 },
    /// Coarse radio tick: UE mobility + A3 handover evaluation.
    RadioTick,
    /// Cluster control tick: drain completion + autoscaler evaluation.
    ControlTick,
    /// Node `node` fails (stale if its epoch lapsed — it was drained
    /// to `Down` before the failure fired).
    NodeFail { node: usize, epoch: u32 },
    /// Node `node`'s repair completes; it powers on and spins up.
    NodeRepair { node: usize },
    /// Node `node` finishes spin-up and starts serving.
    NodeUp { node: usize, epoch: u32 },
    /// Coarse fluid-tier tick: relax far-ring cell activities and
    /// republish their interference rows (DESIGN.md §15).
    FluidTick,
}

/// Events that mutate per-cell state (UE banks, geometry, fluid rows)
/// and therefore bound how far cells may step ahead of the calendar
/// under the bounded-lag frontier merge (DESIGN.md §12). Everything
/// else (compute, control-plane, churn) is cell-neutral: it may pop
/// and execute while workers keep stepping cells concurrently.
fn is_writer(ev: &Ev) -> bool {
    matches!(
        ev,
        Ev::JobArrival { .. } | Ev::BgArrival { .. } | Ev::RadioTick | Ev::FluidTick
    )
}

/// Rebuild the writer-time min-heap by scanning the calendar (at
/// construction and on snapshot restore).
fn writer_heap(q: &EventQueue<Ev>) -> BinaryHeap<Reverse<u64>> {
    let (_, _, _, entries) = q.snapshot_entries();
    entries
        .iter()
        .filter(|(_, _, ev)| is_writer(ev))
        .map(|(t, _, _)| Reverse(t.to_bits()))
        .collect()
}

#[derive(Debug, Clone, Copy)]
struct JobState {
    class: usize,
    /// Originating cell (gNB) of the job.
    cell: u32,
    t_gen: f64,
    /// Set when the last prompt byte reaches the gNB.
    t_comm: Option<f64>,
    t_node_arrival: Option<f64>,
    t_service_start: Option<f64>,
    /// First output token emitted (batching nodes; sequential nodes
    /// derive it from the roofline split).
    t_first_token: Option<f64>,
    t_done: Option<f64>,
    /// Realized prompt length (sampled at generation).
    n_input: u32,
    /// Realized output length (set when the service model prices it).
    n_output: u32,
    /// Realized prefill latency (set at node arrival).
    prefill_time: f64,
    /// Realized sequential decode latency (set at node arrival).
    decode_time: f64,
    /// Times this job was re-dispatched after losing its node (cluster
    /// runs only; compared against the retry budget).
    retries: u32,
    /// Zoo model serving this job (`u32::MAX` = none: zoo-free run or
    /// model-unconstrained class). Re-set on every (re-)dispatch.
    model: u32,
    fate: JobFate,
    measured: bool,
}

/// `JobState.model` sentinel: no zoo model attached.
const NO_MODEL: u32 = u32::MAX;

/// Per-node runtime: the legacy sequential server bank or the
/// continuous-batching engine.
enum NodeRt {
    Seq(ComputeNode),
    Batch(BatchEngine),
}

impl NodeRt {
    fn view(&self, spec: &NodeSpec) -> NodeView {
        match self {
            NodeRt::Seq(n) => {
                NodeView::new(n.queue_len(), n.busy_servers(), spec.n_servers, spec.gpu)
            }
            NodeRt::Batch(e) => NodeView::new(
                e.queue_len(),
                e.batch_len() as u32,
                match spec.execution {
                    ExecutionModel::ContinuousBatching { max_batch, .. } => max_batch,
                    ExecutionModel::Sequential => spec.n_servers,
                },
                spec.gpu,
            )
            .with_kv_headroom(e.kv_headroom()),
        }
    }
}

/// Per-model state of one node for the router (zoo runs only): which
/// resident models are warm and how many admitted jobs each serves.
/// `warm`/`model_active` are the engine's flattened `node × zoo` rows.
fn model_views(
    spec: &NodeSpec,
    node: usize,
    n_models: usize,
    warm: &[bool],
    model_active: &[u32],
) -> Vec<ModelView> {
    (0..n_models)
        .filter(|&m| spec.hosts_model(m))
        .map(|m| {
            let ix = node * n_models + m;
            ModelView::new(m, warm[ix], model_active[ix])
        })
        .collect()
}

/// Count admitted jobs per (node, model) from a sequential node's
/// event batch (zoo runs only; jobs without a model are not tracked).
fn track_seq_models(
    node: usize,
    events: &[NodeEvent],
    jobs: &[JobState],
    model_active: &mut [u32],
    n_models: usize,
) {
    for ev in events {
        if let NodeEvent::Started { job, .. } = *ev {
            let m = jobs[job.job_id as usize].model;
            if m != NO_MODEL {
                model_active[node * n_models + m as usize] += 1;
            }
        }
    }
}

/// Same per-(node, model) accounting over a batch engine's events.
fn track_batch_models(
    node: usize,
    events: &[BatchEvent],
    jobs: &[JobState],
    model_active: &mut [u32],
    n_models: usize,
) {
    for ev in events {
        let (job_id, up) = match *ev {
            BatchEvent::Admitted { job_id } => (job_id, true),
            BatchEvent::Finished { job_id } => (job_id, false),
            _ => continue,
        };
        let m = jobs[job_id as usize].model;
        if m != NO_MODEL {
            let slot = &mut model_active[node * n_models + m as usize];
            if up {
                *slot += 1;
            } else {
                *slot -= 1;
            }
        }
    }
}

/// Sequential node-event plumbing: schedule completions for started
/// jobs (stamped with the node's cluster epoch), mark drops. `inflight`
/// is the node's in-service job list, maintained only on cluster runs
/// so a failure can evict mid-service jobs.
fn apply_node_events(
    node: usize,
    epoch: u32,
    events: &[NodeEvent],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    now: f64,
    mut inflight: Option<&mut Vec<u64>>,
) {
    for ev in events {
        match *ev {
            NodeEvent::Started { job, completes_at } => {
                jobs[job.job_id as usize].t_service_start = Some(now);
                if let Some(list) = inflight.as_deref_mut() {
                    list.push(job.job_id);
                }
                q.schedule_at(
                    completes_at,
                    Ev::ComputeDone { node, job: job.job_id, epoch },
                );
            }
            NodeEvent::Dropped { job } => {
                jobs[job.job_id as usize].fate = JobFate::Dropped;
            }
        }
    }
}

/// Batch-engine plumbing: record admissions / token boundaries /
/// completions and schedule the next iteration step (stamped with the
/// node's cluster epoch).
fn apply_batch_events(
    node: usize,
    epoch: u32,
    events: &[BatchEvent],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    now: f64,
) {
    for ev in events {
        match *ev {
            BatchEvent::Admitted { job_id } => {
                jobs[job_id as usize].t_service_start = Some(now);
            }
            BatchEvent::FirstToken { job_id } => {
                jobs[job_id as usize].t_first_token = Some(now);
            }
            BatchEvent::Finished { job_id } => {
                let js = &mut jobs[job_id as usize];
                js.fate = JobFate::Completed;
                js.t_done = Some(now);
            }
            BatchEvent::Dropped { job_id } => {
                jobs[job_id as usize].fate = JobFate::Dropped;
            }
            BatchEvent::StepAt { at } => {
                q.schedule_at(at, Ev::BatchStep { node, epoch });
            }
        }
    }
}

/// Cluster bookkeeping for a batch of engine events: TTFT observations
/// and per-class work attribution for every finished job.
fn observe_batch_completions(
    node: usize,
    events: &[BatchEvent],
    jobs: &[JobState],
    cluster: &mut ClusterRt,
) {
    for ev in events {
        if let BatchEvent::Finished { job_id } = *ev {
            let js = &jobs[job_id as usize];
            if let Some(f) = js.t_first_token {
                cluster.observe_ttft(f - js.t_gen);
            }
            cluster.observe_completion(node, js.class, js.prefill_time + js.decode_time);
        }
    }
}

/// Earliest pending slot boundary across the still-ticking cells
/// (`f64::INFINITY` when every slot clock has stopped).
fn next_slot_time(cells: &[Mutex<CellRt>]) -> f64 {
    let mut t = f64::INFINITY;
    for cm in cells {
        let c = cm.lock().unwrap();
        if c.ticking && c.next_slot < t {
            t = c.next_slot;
        }
    }
    t
}

/// The next representable f64 above a positive finite `x` (manual
/// next-up; used to turn the frontier's exclusive bound into an
/// inclusive cut at the segment boundary).
fn above(x: f64) -> f64 {
    debug_assert!(x.is_finite() && x > 0.0);
    f64::from_bits(x.to_bits() + 1)
}

/// Absolute time of the next arrival of `class` on stream `r` at time
/// `now`, honoring the piecewise-constant rate schedule. A positive
/// in-force rate draws exactly the legacy exponential gap (`now +
/// Exp(rate)`), so schedule-free classes consume the identical draw
/// sequence. A zero in-force rate defers the stream to the start of
/// the next positive-rate phase, drawing the first gap at that phase's
/// rate from the phase boundary — the exact thinning of a rate that is
/// identically zero over the gap. `None` means no positive rate ever
/// applies again: the stream goes permanently silent and consumes no
/// draw. (An arrival *already armed* before a zero phase started still
/// lands inside it — the standard piecewise-constant discretization,
/// at most one job per (UE, class) stream per rate drop.)
fn next_arrival(class: &WorkloadClass, r: &mut Rng, now: f64) -> Option<f64> {
    let rate = class.rate_at(now);
    if rate > 0.0 {
        return Some(now + r.exp(rate));
    }
    for p in &class.rate_phases {
        if p.t_start > now && p.rate_per_ue > 0.0 {
            return Some(p.t_start + r.exp(p.rate_per_ue));
        }
    }
    None
}

/// One synchronous slot batch (serial / barrier drivers): refresh the
/// due cells' IoT terms from the one-slot-lagged snapshot, step every
/// due cell, then merge delivered SDUs into the calendar in ascending
/// cell-index order — the determinism rule that makes the threaded
/// schedule bit-identical to a serial cell loop.
#[allow(clippy::too_many_arguments)]
fn batch_step(
    driver: &StepDriver<'_, '_>,
    cells: &[Mutex<CellRt>],
    t_slot: f64,
    radio_coupling: bool,
    itf: &mut [Vec<f64>],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    t_wireline: f64,
    slot_events: &mut u64,
) {
    let t_bits = t_slot.to_bits();
    // Interference-snapshot barrier: before the (possibly parallel)
    // step, every due cell reads the one-slot-lagged neighbor activity
    // into its IoT term. Serial on the engine thread, so the thread
    // count can never reorder it.
    if radio_coupling {
        for (j, cm) in cells.iter().enumerate() {
            let mut c = cm.lock().unwrap();
            if !c.due(t_bits) {
                continue;
            }
            let mut i_mw = 0.0;
            for (k, row) in itf.iter().enumerate() {
                if k != j {
                    i_mw += row[j];
                }
            }
            c.iot_db = iot_db_from_linear(i_mw, c.noise_floor_mw);
        }
    }
    match driver {
        StepDriver::Barrier(p) => p.step_batch(t_slot),
        StepDriver::Serial => {
            for cm in cells {
                let mut c = cm.lock().unwrap();
                if c.due(t_bits) {
                    c.step_slot();
                }
            }
        }
        StepDriver::Frontier(_) => unreachable!("frontier mode never batches"),
    }
    // Merge delivered SDUs into the calendar in ascending cell-index
    // order.
    for (k, cm) in cells.iter().enumerate() {
        let mut c = cm.lock().unwrap();
        if c.last_slot != t_bits {
            continue;
        }
        *slot_events += 1;
        // Gather the stepped cell's outgoing interference for the next
        // batch's snapshot (still on the engine thread — the
        // publication order is cell-index order regardless of which
        // worker stepped the cell). A cell whose clock just stopped
        // (drained past the horizon) transmits nothing more: zero its
        // row instead of letting neighbors price its final slot's
        // activity for the rest of the drain window.
        if radio_coupling {
            if c.ticking {
                itf[k].copy_from_slice(&c.itf_out);
            } else {
                for v in &mut itf[k] {
                    *v = 0.0;
                }
            }
        }
        // TBs land at the end of the slot. The flat delivered buffer
        // is already in grant order.
        let t_rx = t_slot + c.slot_dur;
        for d in &c.ws.delivered {
            if let SduKind::Job { job_id } = d.kind {
                let js = &mut jobs[job_id as usize];
                js.t_comm = Some(t_rx - js.t_gen);
                q.schedule_at(t_rx + t_wireline, Ev::ComputeEnqueue { job: job_id });
            }
        }
        // Invalidate so an un-stepped later batch at the same bit
        // pattern (impossible for monotone clocks, but cheap to rule
        // out) cannot re-merge.
        c.last_slot = u64::MAX;
    }
}

/// Every piece of engine state that evolves during a run — the
/// complete checkpoint surface of [`ScenarioEngine::snapshot`], plus
/// scratch buffers (always empty at quiescence) and config-derived
/// scalars (rebuilt on restore, never serialized).
struct EngineState {
    nodes: Vec<NodeRt>,
    router: Box<dyn Routing>,
    jobs: Vec<JobState>,
    q: EventQueue<Ev>,
    /// Current (serving cell, local index) of every UE by stable tag
    /// (handover runs only).
    locs: Option<Vec<(u32, u32)>>,
    /// Per-cell global-UE-index offsets (config-derived).
    prefix: Vec<usize>,
    /// One-slot-lagged interference snapshot: `itf[k][j]` is cell k's
    /// latest published per-PRB interference (mW) at site j. Updated
    /// serially at the merge barrier, consumed serially before the
    /// next batch — worker threads never touch it. Rebuilt on restore
    /// from the cells' published `itf_out` rows.
    itf: Vec<Vec<f64>>,
    pending_ho: Vec<(u64, usize, usize)>,
    /// Elastic control plane (None = static tier).
    cluster_rt: Option<ClusterRt>,
    eligible_ix: Vec<usize>,
    /// Per-node in-service job ids (sequential nodes, cluster runs).
    inflight_seq: Vec<Vec<u64>>,
    node_loads: Vec<(usize, u32)>,
    power_on: Vec<usize>,
    evicted_ids: Vec<u64>,
    seq_evicted: Vec<ComputeJob>,
    batch_evicted: Vec<BatchJob>,
    views: Vec<NodeView>,
    node_ev: Vec<NodeEvent>,
    batch_ev: Vec<BatchEvent>,
    /// Per-class accept-lists resolved to zoo indices (config-derived;
    /// empty inner list = any model).
    class_model_ids: Vec<Vec<usize>>,
    /// Flattened `node × zoo` warm flags: model was activated on the
    /// node since run start (or its last failure). Empty without a zoo
    /// — the legacy path never touches it.
    warm: Vec<bool>,
    /// Flattened `node × zoo` admitted-job counts (router telemetry).
    /// Empty without a zoo.
    model_active: Vec<u32>,
    /// Cell-slot steps merged so far (counted into `events`).
    slot_events: u64,
    /// Fluid background tier (None = every cell runs per-UE).
    fluid_rt: Option<FluidRt>,
    /// Min-heap over `f64::to_bits` of every scheduled cell-writing
    /// event (see [`is_writer`]) — the bounded-lag frontier bound.
    /// Derived from the calendar, rebuilt on restore.
    writers: BinaryHeap<Reverse<u64>>,
    /// Per-cell handover-target mask (false = fluid cell, which has
    /// no per-UE state to hand into). Config-derived.
    ho_ok: Vec<bool>,
    radio_coupling: bool,
    tick_s: f64,
    ttt_ticks: u32,
    t_wireline: f64,
    bg_rate: f64,
    bg_bytes: u32,
    drain_horizon: f64,
    /// Wall-clock seconds accumulated across `run_to` segments.
    wall: f64,
}

/// A scenario run broken into resumable segments.
///
/// ```ignore
/// let mut eng = ScenarioEngine::new(&sc);
/// eng.run_to(30.0);                  // simulate [0, 30]
/// let blob = eng.snapshot();         // checkpoint at t = 30
/// eng.run_to(f64::INFINITY);         // ... finish this run
/// let a = eng.finish();
///
/// let mut fork = ScenarioEngine::from_snapshot(&sc, &blob)?;
/// fork.run_to(f64::INFINITY);        // bit-identical continuation
/// let b = fork.finish();             // a.report == b.report
/// ```
///
/// `run_to(t)` stops at the quiescence point of the cut `min(t,
/// horizon + 2)`: every calendar event and cell-slot boundary at or
/// below the cut is processed and merged. Snapshots are therefore
/// canonical — independent of the step driver, thread count and
/// calendar backend — and restoring one replays the exact event
/// schedule of an uninterrupted run (property-tested across threads
/// {1, 2, 4, 8} with coupling, mobility, handover, churn and batching
/// all enabled).
pub struct ScenarioEngine<'a> {
    sc: &'a Scenario,
    cells: Vec<Mutex<CellRt>>,
    st: EngineState,
}

impl<'a> ScenarioEngine<'a> {
    /// Build the engine at t = 0 with every arrival process primed
    /// (exactly the prologue of the one-shot run path).
    pub fn new(sc: &'a Scenario) -> Self {
        let n_classes = sc.classes.len();
        assert!(n_classes > 0, "scenario needs at least one workload class");
        assert!(!sc.nodes.is_empty(), "scenario needs at least one compute node");
        assert!(!sc.cells.is_empty(), "scenario needs at least one cell (build() defaults one)");

        // Hybrid-fidelity classification (DESIGN.md §15): cells with
        // no focus site within `rings` hops run the fluid mean-field
        // tier instead of the per-UE pipeline. Ring distance is a
        // site-layout notion, so the tier only arms under a topology;
        // `fluid = None` (or a focus set covering every cell) leaves
        // the engine bit-identical to the dense build.
        let is_fluid: Vec<bool> = match (&sc.fluid, &sc.topology) {
            (Some(f), Some(topo)) => {
                (0..sc.cells.len()).map(|k| f.is_fluid(topo, k)).collect()
            }
            _ => vec![false; sc.cells.len()],
        };

        let cells: Vec<Mutex<CellRt>> = sc
            .cells
            .iter()
            .enumerate()
            .map(|(k, spec)| {
                if is_fluid[k] {
                    // Fluid cells carry no per-UE state: build over an
                    // empty population (no arrival streams, no bank)
                    // and stop the slot clock for good.
                    let mut c =
                        CellRt::new(k, &CellSpec { n_ues: 0, ..*spec }, &sc.base, n_classes);
                    c.fluid = true;
                    c.ticking = false;
                    c.next_slot = f64::INFINITY;
                    Mutex::new(c)
                } else {
                    Mutex::new(CellRt::new(k, spec, &sc.base, n_classes))
                }
            })
            .collect();

        // Coupled-radio geometry: place the sites, build each cell's
        // per-(UE, site) coupling-loss cache, and mark which neighbor
        // pairs couple (same carrier frequency + numerology — they
        // interfere and are handover candidates).
        if let Some(topo) = &sc.topology {
            let sites: Vec<Position> =
                (0..sc.cells.len()).map(|k| topo.site_position(k)).collect();
            for (k, cm) in cells.iter().enumerate() {
                let coupled: Vec<bool> = sc
                    .cells
                    .iter()
                    .enumerate()
                    .map(|(j, other)| {
                        j != k
                            && other.carrier.freq_hz == sc.cells[k].carrier.freq_hz
                            && other.carrier.numerology == sc.cells[k].carrier.numerology
                    })
                    .collect();
                cm.lock().unwrap().init_geometry(
                    k,
                    &sites,
                    coupled,
                    cell_seed(sc.base.seed, k),
                    sc.base.cell_r_max,
                    sc.mobility.as_ref(),
                );
            }
        }

        let cfg = &sc.base;
        let discipline = discipline_of(&cfg.scheme);
        let nodes: Vec<NodeRt> = sc
            .nodes
            .iter()
            .map(|n| match n.execution {
                ExecutionModel::Sequential => {
                    NodeRt::Seq(ComputeNode::new(discipline, n.n_servers))
                }
                ExecutionModel::ContinuousBatching { max_batch, kv_budget } => {
                    NodeRt::Batch(BatchEngine::new(discipline, n.gpu, max_batch, kv_budget))
                }
            })
            .collect();
        let router = sc.make_router();
        let t_wireline = cfg.scheme.deployment.wireline_latency();

        // Effective per-UE populations: fluid cells host none.
        let total_ues: usize = sc
            .cells
            .iter()
            .enumerate()
            .map(|(k, c)| if is_fluid[k] { 0 } else { c.n_ues as usize })
            .sum();
        let jobs: Vec<JobState> = Vec::with_capacity(4096);
        // Pre-size the calendar: priming schedules one arrival per
        // (cell, UE, class) plus one background event per UE, and at
        // steady state each sequential node holds up to `n_servers`
        // in-flight ComputeDone events while each batching node keeps
        // one pending BatchStep — account for those too, plus slack
        // for wireline-crossing enqueues, so large multi-node runs
        // never re-allocate right after priming. Slot clocks live
        // outside the calendar.
        let inflight: usize = sc
            .nodes
            .iter()
            .map(|n| match n.execution {
                ExecutionModel::Sequential => n.n_servers as usize,
                ExecutionModel::ContinuousBatching { .. } => 1,
            })
            .sum();
        // One slot each for the self-re-arming coarse ticks (radio,
        // control, fluid) plus one pending failure event per churning
        // node, so tick-heavy low-UE runs don't re-allocate either.
        let tick_evs = 3 + if sc.cluster.is_some() { sc.nodes.len() } else { 0 };
        let mut q: EventQueue<Ev> = EventQueue::with_kind(
            sc.event_queue,
            total_ues * (n_classes + 1) + inflight + tick_evs + 64,
        );

        // Handover bookkeeping: stable global UE ids (tags) and the
        // current (cell, local index) of every UE. Arrival events
        // address UEs by their *origin* identity — the RNG streams
        // never move — and are routed here to the current serving cell.
        let radio_coupling = sc.topology.is_some() && cells.len() > 1;
        let handover_on = sc.handover.is_some() && radio_coupling;
        let prefix: Vec<usize> = {
            let mut acc = 0usize;
            let mut v = Vec::with_capacity(sc.cells.len());
            for (k, c) in sc.cells.iter().enumerate() {
                v.push(acc);
                // Fluid cells occupy no tag range (empty population).
                acc += if is_fluid[k] { 0 } else { c.n_ues as usize };
            }
            v
        };
        let locs: Option<Vec<(u32, u32)>> = if handover_on {
            let mut v = Vec::with_capacity(total_ues);
            for (k, cm) in cells.iter().enumerate() {
                let mut c = cm.lock().unwrap();
                for i in 0..c.n_ues {
                    c.bank.ue_mut(i).tag = v.len() as u64;
                    v.push((k as u32, i as u32));
                }
            }
            Some(v)
        } else {
            None
        };
        let mut itf: Vec<Vec<f64>> = if radio_coupling {
            (0..cells.len()).map(|_| vec![0.0; cells.len()]).collect()
        } else {
            Vec::new()
        };
        // Handover can only target cells with per-UE state.
        let ho_ok: Vec<bool> = is_fluid.iter().map(|&f| !f).collect();
        let tick_s = sc
            .mobility
            .as_ref()
            .map(|m| m.tick_s)
            .unwrap_or(MobilitySpec::DEFAULT_TICK_S);
        let ttt_ticks: u32 = sc
            .handover
            .as_ref()
            .map(|h| ((h.ttt_s / tick_s).ceil() as u32).max(1))
            .unwrap_or(1);

        // Elastic control plane (None = static tier: no cluster
        // events, no cluster RNG draws, views built over every node —
        // bit-identical to the pre-cluster engine by construction).
        let mut cluster_rt: Option<ClusterRt> = sc.cluster.map(|spec| {
            ClusterRt::new(
                spec,
                sc.node_churn.clone(),
                sc.nodes.iter().map(|n| n.gpu).collect(),
                n_classes,
                cfg.seed,
            )
        });

        // Background packet rate (constant across the run).
        let bg_rate = 1.0 / cfg.background.mean_interval();
        let bg_bytes = cfg.background.packet_bytes;

        // Fluid tier runtime: per-cell capacity and unit interference
        // row priced once at a representative annulus radius, then
        // activities seeded at their t = 0 targets and the initial
        // rows published, so focus cells price far-ring interference
        // from the very first slot (DESIGN.md §15).
        let mut fluid_rt: Option<FluidRt> = None;
        if is_fluid.iter().any(|&f| f) {
            let (fs, topo) = (sc.fluid.as_ref().unwrap(), sc.topology.as_ref().unwrap());
            let d_rep = fluid::representative_radius(cfg.cell_r_min, cfg.cell_r_max);
            let fcells: Vec<FluidCell> = is_fluid
                .iter()
                .enumerate()
                .filter(|&(_, &f)| f)
                .map(|(k, _)| {
                    let c = cells[k].lock().unwrap();
                    FluidCell {
                        cell: k,
                        n_ues: sc.cells[k].n_ues,
                        capacity_bps: fluid::cell_capacity_bytes_per_s(
                            &c.scheduler.carrier,
                            &c.scheduler.pc,
                            &c.scheduler.rx,
                            d_rep,
                        ),
                        unit_itf: fluid::unit_interference_row(
                            topo,
                            k,
                            sc.cells.len(),
                            &c.scheduler.carrier,
                            &c.scheduler.pc,
                            d_rep,
                        ),
                        activity: 0.0,
                        act_sum: 0.0,
                    }
                })
                .collect();
            let mut rt = FluidRt::new(fs, fcells);
            rt.init_activities(&sc.classes, bg_rate, f64::from(bg_bytes));
            for fc in &rt.cells {
                let row = fc.row();
                if radio_coupling {
                    itf[fc.cell].copy_from_slice(&row);
                }
                cells[fc.cell].lock().unwrap().itf_out.copy_from_slice(&row);
            }
            fluid_rt = Some(rt);
        }

        // Prime arrival processes (per cell, same per-UE order as the
        // legacy engine). Time-varying classes prime at their t = 0
        // rate; a class whose t = 0 rate is zero defers to its first
        // positive phase (and a permanently-zero class arms nothing).
        for (k, cm) in cells.iter().enumerate() {
            let mut c = cm.lock().unwrap();
            for ue in 0..c.n_ues {
                for (ci, class) in sc.classes.iter().enumerate() {
                    if let Some(t) = next_arrival(class, &mut c.job_rng[ci][ue], 0.0) {
                        q.schedule_at(
                            t,
                            Ev::JobArrival { cell: k as u32, ue: ue as u32, class: ci as u32 },
                        );
                    }
                }
                let gap = c.bg_rng[ue].exp(bg_rate);
                q.schedule_at(gap, Ev::BgArrival { cell: k as u32, ue: ue as u32 });
            }
        }

        // Prime the radio tick (mobility + handover) when geometry is on.
        if sc.topology.is_some() && (sc.mobility.is_some() || sc.handover.is_some()) {
            q.schedule_at(tick_s, Ev::RadioTick);
        }

        // Prime the fluid tick.
        if let Some(rt) = &fluid_rt {
            q.schedule_at(rt.tick_s, Ev::FluidTick);
        }

        // Prime the control plane: one failure event per churning node
        // (infinite-MTBF nodes draw nothing) and the first control tick.
        if let Some(cl) = cluster_rt.as_mut() {
            for i in 0..cl.n_nodes() {
                if let Some(ttf) = cl.time_to_failure(i) {
                    q.schedule_at(ttf, Ev::NodeFail { node: i, epoch: cl.epoch(i) });
                }
            }
            q.schedule_at(cl.spec().tick_s, Ev::ControlTick);
        }

        // Seed the bounded-lag writer bound from the primed calendar
        // (every arrival plus the coarse ticks).
        let writers = writer_heap(&q);

        let n_nodes = sc.nodes.len();
        let st = EngineState {
            nodes,
            router,
            jobs,
            q,
            locs,
            prefix,
            itf,
            pending_ho: Vec::new(),
            cluster_rt,
            eligible_ix: Vec::with_capacity(n_nodes),
            inflight_seq: vec![Vec::new(); n_nodes],
            node_loads: Vec::with_capacity(n_nodes),
            power_on: Vec::with_capacity(n_nodes),
            evicted_ids: Vec::new(),
            seq_evicted: Vec::new(),
            batch_evicted: Vec::new(),
            views: Vec::with_capacity(n_nodes),
            node_ev: Vec::with_capacity(16),
            batch_ev: Vec::with_capacity(64),
            class_model_ids: sc.class_model_ids(),
            warm: vec![false; n_nodes * sc.models.len()],
            model_active: vec![0; n_nodes * sc.models.len()],
            slot_events: 0,
            fluid_rt,
            writers,
            ho_ok,
            radio_coupling,
            tick_s,
            ttt_ticks,
            t_wireline,
            bg_rate,
            bg_bytes,
            drain_horizon: cfg.horizon + 2.0,
            wall: 0.0,
        };
        Self { sc, cells, st }
    }

    /// Calendar time: the latest event instant processed so far. Slot
    /// machinery in cells that outpaced the calendar may sit slightly
    /// ahead; `run_to` re-synchronizes them at the next cut.
    pub fn now(&self) -> f64 {
        self.st.q.now()
    }

    /// Advance the simulation through the cut `min(bound, horizon + 2)`
    /// (inclusive): process every calendar event and cell-slot boundary
    /// at or below it, merging all deliveries. Idempotent at the same
    /// bound; `run_to(f64::INFINITY)` drains the run completely. The
    /// step pool (when `cell_threads > 1`) lives only for the duration
    /// of the call.
    pub fn run_to(&mut self, bound: f64) {
        let wall0 = std::time::Instant::now();
        let sc = self.sc;
        let cells = &self.cells;
        let st = &mut self.st;
        // `cell_threads = 1` (the default) steps cells inline; `0`
        // uses all cores. More participants than cells would only idle.
        let participants = resolve_threads(sc.cell_threads).min(cells.len());
        if participants <= 1 {
            event_loop_to(sc, cells, st, StepDriver::Serial, bound);
        } else {
            match sc.cell_sync {
                CellSync::Barrier => {
                    let pool = StepPool::new(cells, participants);
                    std::thread::scope(|scope| {
                        // An unwind out of the event loop (or out of a
                        // worker) would leave the other pool
                        // participants parked on a barrier with no
                        // panic path, deadlocking the scope join — the
                        // guard aborts instead so a bug surfaces as a
                        // crash.
                        let _guard = super::cells::AbortOnPanic;
                        for _ in 1..participants {
                            scope.spawn(|| pool.worker());
                        }
                        event_loop_to(sc, cells, st, StepDriver::Barrier(&pool), bound);
                        pool.shutdown();
                    });
                }
                CellSync::Frontier => {
                    let pool = FrontierPool::new(
                        cells,
                        sc.base.horizon + 2.0,
                        st.radio_coupling,
                    );
                    std::thread::scope(|scope| {
                        // A panicking participant poisons the frontier
                        // mutex; the other side's unwrap then panics
                        // too — abort so neither unwind strands the
                        // scope join.
                        let _guard = super::cells::AbortOnPanic;
                        for _ in 1..participants {
                            scope.spawn(|| pool.worker());
                        }
                        event_loop_to(sc, cells, st, StepDriver::Frontier(&pool), bound);
                        pool.shutdown();
                    });
                }
            }
        }
        self.st.wall += wall0.elapsed().as_secs_f64();
    }
}

pub(super) fn run(sc: &Scenario) -> ScenarioResult {
    let mut eng = ScenarioEngine::new(sc);
    eng.run_to(f64::INFINITY);
    eng.finish()
}

/// Run the event loop through the cut `min(bound, drain_horizon)`:
/// the body of the legacy one-shot loop, with the stop criterion
/// generalized from "calendar drained past the drain horizon" to "no
/// event or slot boundary at or below the cut remains".
fn event_loop_to(
    sc: &Scenario,
    cells: &[Mutex<CellRt>],
    st: &mut EngineState,
    driver: StepDriver<'_, '_>,
    bound: f64,
) {
    let cfg = &sc.base;
    let b_eff = bound.min(st.drain_horizon);
    let radio_coupling = st.radio_coupling;
    let tick_s = st.tick_s;
    let ttt_ticks = st.ttt_ticks;
    let t_wireline = st.t_wireline;
    let bg_rate = st.bg_rate;
    let bg_bytes = st.bg_bytes;
    let EngineState {
        nodes,
        router,
        jobs,
        q,
        locs,
        prefix,
        itf,
        pending_ho,
        cluster_rt,
        eligible_ix,
        inflight_seq,
        node_loads,
        power_on,
        evicted_ids,
        seq_evicted,
        batch_evicted,
        views,
        node_ev,
        batch_ev,
        class_model_ids,
        warm,
        model_active,
        slot_events,
        fluid_rt,
        writers,
        ho_ok,
        ..
    } = st;
    let n_models = sc.models.len();

    let mut t_slot = next_slot_time(cells);

    loop {
        let t_q = q.peek_time().unwrap_or(f64::INFINITY);
        if let StepDriver::Frontier(fp) = &driver {
            // Bounded-lag mode: cells may step ahead of the calendar
            // head as long as they stay strictly below the earliest
            // pending *writer* event — the only events that mutate
            // per-cell state (arrivals into banks, radio geometry,
            // fluid rows). Cell-neutral events (compute, control,
            // churn) pop and execute while workers keep stepping.
            // When the head itself is a writer, `t_w == t_q` and the
            // bound collapses onto the cut: the merge below then
            // drains to full quiescence before the handler runs —
            // exactly the old drain-to-quiescence behavior, now paid
            // only when exclusive cell ownership is actually needed.
            let t_w = writers
                .peek()
                .map(|w| f64::from_bits(w.0))
                .unwrap_or(f64::INFINITY);
            debug_assert!(t_w >= t_q || !t_q.is_finite(), "writer heap behind calendar head");
            fp.raise_bound(t_w.min(above(b_eff)));
            // Merge the committed step records strictly below the
            // calendar head in (slot-time, cell) order (events at the
            // head pop first — the serial tie rule). The merge
            // reproduces the serial calendar-insertion sequence, so
            // downstream pops are bit-identical. `above(b_eff)` makes
            // the exclusive frontier bound inclusive of slots exactly
            // at the cut — the same slots the serial driver steps.
            fp.merge_below(t_q.min(above(b_eff)), &mut |rec: StepRec| {
                *slot_events += 1;
                for &job_id in &rec.jobs {
                    let js = &mut jobs[job_id as usize];
                    js.t_comm = Some(rec.t_rx - js.t_gen);
                    q.schedule_at(rec.t_rx + t_wireline, Ev::ComputeEnqueue {
                        job: job_id,
                    });
                }
            });
            // Re-peek: the merge may have filed deliveries into an
            // otherwise-drained calendar (serial covers this via its
            // t_slot alternative) — the stale peek would end the
            // segment with jobs still crossing the wireline.
            let t_q = q.peek_time().unwrap_or(f64::INFINITY);
            if !t_q.is_finite() || t_q > b_eff {
                break;
            }
            // fall through to the calendar pop below
        } else {
            // Calendar events drain before slot boundaries at the same
            // instant (matching the legacy tie order, where the
            // enqueue crossing the wireline landed before the chained
            // Slot event).
            let t_next = t_q.min(t_slot);
            if !t_next.is_finite() || t_next > b_eff {
                break;
            }
            if t_q > t_slot {
                batch_step(
                    &driver,
                    cells,
                    t_slot,
                    radio_coupling,
                    itf,
                    jobs,
                    q,
                    t_wireline,
                    slot_events,
                );
                t_slot = next_slot_time(cells);
                continue;
            }
        }
        let (now, ev) = q.pop().unwrap();
        if is_writer(&ev) {
            let w = writers.pop();
            debug_assert_eq!(w.map(|r| r.0), Some(now.to_bits()), "writer heap desynced");
            drop(w);
        }
        match ev {
            Ev::JobArrival { cell, ue, class } => {
                if now < cfg.horizon {
                    let spec = &sc.classes[class as usize];
                    let ue_ix = ue as usize;
                    // Draws come from the ORIGIN cell's per-(class,
                    // UE) stream — handover moves the radio
                    // attachment, never the traffic streams, so
                    // trajectories stay decomposable per cell seed.
                    // The next gap draws at the *current* phase rate
                    // through `next_arrival` (schedule-free classes
                    // reduce to exactly the legacy draw; zero-rate
                    // phases defer the stream to the next positive
                    // phase).
                    let (n_input, next) = {
                        let mut c = cells[cell as usize].lock().unwrap();
                        let r = &mut c.job_rng[class as usize][ue_ix];
                        (spec.input_tokens.sample(r), next_arrival(spec, r, now))
                    };
                    let job_id = jobs.len() as u64;
                    jobs.push(JobState {
                        class: class as usize,
                        cell,
                        t_gen: now,
                        t_comm: None,
                        t_node_arrival: None,
                        t_service_start: None,
                        t_first_token: None,
                        t_done: None,
                        n_input,
                        n_output: 0,
                        prefill_time: 0.0,
                        decode_time: 0.0,
                        retries: 0,
                        model: NO_MODEL,
                        fate: JobFate::InFlight,
                        measured: now >= cfg.warmup,
                    });
                    // The prompt bytes land in the UE's *current*
                    // serving cell's bank (identity under the legacy
                    // static configuration).
                    let (scell, sue) = match locs.as_deref() {
                        Some(l) => {
                            let (c0, u0) = l[prefix[cell as usize] + ue_ix];
                            (c0 as usize, u0 as usize)
                        }
                        None => (cell as usize, ue_ix),
                    };
                    {
                        let mut c = cells[scell].lock().unwrap();
                        let arrival_slot = (now / c.slot_dur) as u64;
                        let (sr_period, sr_proc) = (c.sr_period, c.sr_proc);
                        c.bank.note_arrival(sue, arrival_slot, sr_period, sr_proc);
                        if c.job_priority {
                            // ICC job-aware prioritization: dedicated SR
                            // resource bypasses the shared cycle.
                            c.bank.note_job_arrival_expedited(sue, arrival_slot, sr_proc);
                        }
                        let bytes = spec.request_bytes(n_input);
                        c.bank.push_job_sdu(sue, Sdu {
                            kind: SduKind::Job { job_id },
                            total_bytes: bytes,
                            bytes_left: bytes,
                            t_arrival: now,
                        });
                    }
                    if let Some(t) = next {
                        // Mirror the calendar's `at.max(now)` clamp so
                        // the heap entry matches the stored time bits.
                        writers.push(Reverse(t.max(now).to_bits()));
                        q.schedule_at(t, Ev::JobArrival { cell, ue, class });
                    }
                }
            }
            Ev::BgArrival { cell, ue } => {
                if now < cfg.horizon {
                    let ue_ix = ue as usize;
                    let gap = {
                        let mut c = cells[cell as usize].lock().unwrap();
                        c.bg_rng[ue_ix].exp(bg_rate)
                    };
                    let (scell, sue) = match locs.as_deref() {
                        Some(l) => {
                            let (c0, u0) = l[prefix[cell as usize] + ue_ix];
                            (c0 as usize, u0 as usize)
                        }
                        None => (cell as usize, ue_ix),
                    };
                    {
                        let mut c = cells[scell].lock().unwrap();
                        let arrival_slot = (now / c.slot_dur) as u64;
                        let (sr_period, sr_proc) = (c.sr_period, c.sr_proc);
                        c.bank.note_arrival(sue, arrival_slot, sr_period, sr_proc);
                        c.bank.push_bg_sdu(sue, Sdu {
                            kind: SduKind::Background,
                            total_bytes: bg_bytes,
                            bytes_left: bg_bytes,
                            t_arrival: now,
                        });
                    }
                    writers.push(Reverse((now + gap).max(now).to_bits()));
                    q.schedule_in(gap, Ev::BgArrival { cell, ue });
                }
            }
            Ev::RadioTick if now >= cfg.horizon => {
                // Radio dynamics end at the horizon: a post-horizon
                // migration could land a UE in a cell whose slot clock
                // already stopped (empty bank past the horizon),
                // stranding its backlog for the whole drain window.
                // Arrivals stop at the horizon too, so frozen
                // positions/attachments during the drain are exact.
            }
            Ev::RadioTick => {
                // Mobility first (positions + refreshed loss caches),
                // then A3 evaluation over the fresh RSRP ordering,
                // then the migrations — all serial on the engine
                // thread between slot batches, in cell-index order, so
                // the threaded schedule stays bit-identical to serial.
                if let Some(mob) = &sc.mobility {
                    for cm in cells {
                        cm.lock().unwrap().advance_mobility(mob, tick_s);
                    }
                }
                if let (Some(ho), Some(l)) = (&sc.handover, locs.as_mut()) {
                    pending_ho.clear();
                    for cm in cells {
                        cm.lock().unwrap().evaluate_handover(
                            ho.hysteresis_db,
                            ttt_ticks,
                            ho_ok,
                            pending_ho,
                        );
                    }
                    for &(tag, from, to) in pending_ho.iter() {
                        let (ck, ci) = l[tag as usize];
                        debug_assert_eq!(ck as usize, from, "stale migration order");
                        let (ue, hot, gu, displaced) = {
                            let mut c = cells[from].lock().unwrap();
                            c.ho_out += 1;
                            c.take_ue(ci as usize)
                        };
                        if let Some(d) = displaced {
                            l[d as usize] = (from as u32, ci);
                        }
                        let mut t = cells[to].lock().unwrap();
                        t.ho_in += 1;
                        let ni = t.admit_ue(ue, hot, gu, ho.interruption_slots);
                        l[tag as usize] = (to as u32, ni as u32);
                    }
                }
                if now < cfg.horizon {
                    writers.push(Reverse((now + tick_s).to_bits()));
                    q.schedule_in(tick_s, Ev::RadioTick);
                }
            }
            Ev::FluidTick => {
                // FluidTick is a writer event, so the frontier is at
                // full quiescence here: every cell frontier sits at or
                // above `now` with no step in flight — safe to
                // republish rows that the next slot batch prices.
                if let Some(frt) = fluid_rt.as_mut() {
                    frt.tick(now, &sc.classes, bg_rate, f64::from(bg_bytes));
                    for fc in &frt.cells {
                        let row = fc.row();
                        cells[fc.cell].lock().unwrap().itf_out.copy_from_slice(&row);
                        if radio_coupling {
                            itf[fc.cell].copy_from_slice(&row);
                        }
                        if let StepDriver::Frontier(fp) = &driver {
                            fp.set_fluid_row(fc.cell, &row);
                        }
                    }
                    // Mean fluid compute load per up node — the Eq 3–6
                    // offered load the far rings push into the tier,
                    // exposed to custom routers via
                    // `NodeView::background_rho`.
                    let lam = frt.lambda_total(&sc.classes, now);
                    let n_up = match cluster_rt.as_ref() {
                        Some(cl) => {
                            (0..cl.n_nodes()).filter(|&i| cl.eligible(i)).count().max(1)
                        }
                        None => nodes.len().max(1),
                    };
                    let mut s_sum = 0.0;
                    let mut r_sum = 0.0;
                    for class in &sc.classes {
                        let r = class.rate_at(now);
                        if r <= 0.0 {
                            continue;
                        }
                        let d = sc.service.reprice(
                            class,
                            class.input_tokens.mean().round().max(1.0) as u32,
                            class.output_tokens.mean().round().max(1.0) as u32,
                            &sc.nodes[0].gpu,
                        );
                        s_sum += r * d.service_time();
                        r_sum += r;
                    }
                    frt.node_rho =
                        if r_sum > 0.0 { lam * (s_sum / r_sum) / n_up as f64 } else { 0.0 };
                    if now < cfg.horizon {
                        let t_next = now + frt.tick_s;
                        writers.push(Reverse(t_next.to_bits()));
                        q.schedule_at(t_next, Ev::FluidTick);
                    }
                }
            }
            Ev::ComputeEnqueue { job } => {
                let (cell_id, class_id, n_input, t_gen, t_comm, retry) = {
                    let js = &jobs[job as usize];
                    (
                        js.cell as usize,
                        js.class,
                        js.n_input,
                        js.t_gen,
                        js.t_comm.expect("enqueue before comm done"),
                        js.retries > 0,
                    )
                };
                let spec = &sc.classes[class_id];
                let allowed: &[usize] = &class_model_ids[class_id];
                // Far-ring offered compute load (0.0 without a fluid
                // tier — `with_background_rho(0.0)` is the identity).
                let bg_rho = fluid_rt.as_ref().map_or(0.0, |f| f.node_rho);
                views.clear();
                let (target, model) = match cluster_rt.as_ref() {
                    Some(cl) => {
                        // Routing sees only `Up` nodes; the pick maps
                        // back to a real tier index.
                        eligible_ix.clear();
                        for (i, (rt, s)) in
                            nodes.iter().zip(sc.nodes.iter()).enumerate()
                        {
                            if cl.eligible(i) {
                                eligible_ix.push(i);
                                let v = rt.view(s).with_background_rho(bg_rho);
                                views.push(if n_models > 0 {
                                    v.with_models(model_views(
                                        s,
                                        i,
                                        n_models,
                                        warm,
                                        model_active,
                                    ))
                                } else {
                                    v
                                });
                            }
                        }
                        if views.is_empty() {
                            // The whole tier is dark: park the job and
                            // retry on the control-tick cadence (this
                            // is not a re-dispatch — no budget spent).
                            q.schedule_in(
                                cl.spec().tick_s,
                                Ev::ComputeEnqueue { job },
                            );
                            continue;
                        }
                        let ctx =
                            RouteCtx::new(class_id, cell_id, now, views, allowed);
                        let d = router.pick(&ctx);
                        assert!(
                            d.node < views.len(),
                            "Routing::pick returned node {} for {} nodes",
                            d.node,
                            views.len()
                        );
                        let model = d.model.or_else(|| ctx.model_for(d.node));
                        (eligible_ix[d.node], model)
                    }
                    None => {
                        for (i, (rt, s)) in
                            nodes.iter().zip(sc.nodes.iter()).enumerate()
                        {
                            let v = rt.view(s).with_background_rho(bg_rho);
                            views.push(if n_models > 0 {
                                v.with_models(model_views(
                                    s,
                                    i,
                                    n_models,
                                    warm,
                                    model_active,
                                ))
                            } else {
                                v
                            });
                        }
                        let ctx =
                            RouteCtx::new(class_id, cell_id, now, views, allowed);
                        let d = router.pick(&ctx);
                        // A routing bug must fail loudly: silently
                        // clamping would report single-node results as
                        // multi-node.
                        assert!(
                            d.node < nodes.len(),
                            "Routing::pick returned node {} for {} nodes",
                            d.node,
                            nodes.len()
                        );
                        (d.node, d.model.or_else(|| ctx.model_for(d.node)))
                    }
                };
                // A model-constrained class is always priced on one of
                // its accepted models, best-first, even when the router
                // placed it on a node hosting none of them.
                let model = match model {
                    None if !allowed.is_empty() => Some(allowed[0]),
                    other => other,
                };
                if let Some(m) = model {
                    assert!(
                        m < n_models,
                        "RouteDecision.model {m} out of range ({n_models} zoo models)"
                    );
                    assert!(
                        allowed.is_empty() || allowed.contains(&m),
                        "RouteDecision.model {m} violates class '{}' accept-list",
                        spec.name
                    );
                }
                // Service realizations draw from the originating cell's
                // stream, in that cell's delivery order — so each cell
                // of an N-cell run matches an independent single-cell
                // run (DESIGN.md §9). A re-dispatched job reuses its
                // realized *token lengths* but re-prices them on the
                // destination tier's roofline (deterministic, no RNG):
                // rng_svc is consumed exactly once per job, in
                // first-delivery order, so node churn can never shift
                // any other job's draws, and a retry landing on a
                // different GPU tier runs at that tier's actual speed
                // instead of the dead node's (DESIGN.md §11). A
                // same-tier retry reproduces the stored demand
                // bit-for-bit.
                let model_spec = model.map(|m| &sc.models[m]);
                let demand = match (retry, model_spec) {
                    (true, Some(ms)) => {
                        let js = &jobs[job as usize];
                        sc.service.reprice_on(
                            spec,
                            ms,
                            js.n_input,
                            js.n_output,
                            &sc.nodes[target].gpu,
                        )
                    }
                    (true, None) => {
                        let js = &jobs[job as usize];
                        sc.service.reprice(spec, js.n_input, js.n_output, &sc.nodes[target].gpu)
                    }
                    (false, Some(ms)) => {
                        let mut c = cells[cell_id].lock().unwrap();
                        sc.service.realize_on(
                            spec,
                            ms,
                            n_input,
                            &sc.nodes[target].gpu,
                            &mut c.rng_svc,
                        )
                    }
                    (false, None) => {
                        let mut c = cells[cell_id].lock().unwrap();
                        sc.service.realize(spec, n_input, &sc.nodes[target].gpu, &mut c.rng_svc)
                    }
                };
                // First activation of a cold model on a node pays the
                // weight-swap latency, charged to this job's prefill.
                // Warm flags persist until the node fails (NodeFail
                // resets its row), so steady state pays nothing.
                let mut swap = 0.0;
                if let Some(m) = model {
                    let w = &mut warm[target * n_models + m];
                    if !*w {
                        *w = true;
                        swap = sc.nodes[target].swap_s;
                    }
                }
                let prefill_time = if swap > 0.0 {
                    demand.prefill_time + swap
                } else {
                    demand.prefill_time
                };
                {
                    let js = &mut jobs[job as usize];
                    js.n_output = demand.n_output;
                    js.prefill_time = prefill_time;
                    js.decode_time = demand.decode_time;
                    js.t_node_arrival = Some(now);
                    js.model = model.map_or(NO_MODEL, |m| m as u32);
                }
                let deadline = t_gen + spec.b_total;
                let epoch = cluster_rt.as_ref().map_or(0, |c| c.epoch(target));
                match &mut nodes[target] {
                    NodeRt::Seq(n) => {
                        let cj = ComputeJob {
                            job_id: job,
                            t_gen,
                            t_comm,
                            deadline,
                            service_time: if swap > 0.0 {
                                demand.service_time() + swap
                            } else {
                                demand.service_time()
                            },
                        };
                        node_ev.clear();
                        n.enqueue(cj, now, node_ev);
                        let track = cluster_rt.is_some();
                        apply_node_events(
                            target,
                            epoch,
                            node_ev,
                            jobs,
                            q,
                            now,
                            track.then(|| &mut inflight_seq[target]),
                        );
                        if n_models > 0 {
                            track_seq_models(target, node_ev, jobs, model_active, n_models);
                        }
                    }
                    NodeRt::Batch(e) => {
                        // Prefix blocks may only be shared by jobs with
                        // identical per-token KV footprint and identical
                        // shared text: the key therefore spans
                        // (model, class, effective prefix length).
                        let (prefix_id, prefix_tokens) = if spec.prefix_tokens > 0 {
                            let eff = spec.prefix_tokens.min(n_input);
                            let mb = model.map_or(0xFFFF, |m| m as u64);
                            (
                                (mb << 48)
                                    | (((class_id as u64) & 0xFFFF) << 32)
                                    | eff as u64,
                                eff,
                            )
                        } else {
                            (0, 0)
                        };
                        let bj = BatchJob {
                            job_id: job,
                            t_gen,
                            t_comm,
                            deadline,
                            n_input,
                            n_output: demand.n_output,
                            prefill_time,
                            decode_time: demand.decode_time,
                            c_llm: model_spec.map_or(spec.c_llm, |ms| ms.c_llm),
                            m_llm: model_spec.map_or(spec.m_llm, |ms| ms.m_llm),
                            kv_bytes_per_token: model_spec
                                .map_or(spec.kv_bytes_per_token, |ms| ms.kv_bytes_per_token()),
                            prefix_id,
                            prefix_tokens,
                        };
                        batch_ev.clear();
                        e.enqueue(bj, now, batch_ev);
                        apply_batch_events(target, epoch, batch_ev, jobs, q, now);
                        if n_models > 0 {
                            track_batch_models(target, batch_ev, jobs, model_active, n_models);
                        }
                        if let Some(cl) = cluster_rt.as_mut() {
                            observe_batch_completions(target, batch_ev, jobs, cl);
                        }
                    }
                }
            }
            Ev::ComputeDone { node, job, epoch } => {
                if cluster_rt.as_ref().map_or(false, |c| !c.event_live(node, epoch)) {
                    // the node failed mid-service; the job was already
                    // evicted and re-dispatched (or lost)
                    continue;
                }
                {
                    let js = &mut jobs[job as usize];
                    js.fate = JobFate::Completed;
                    js.t_done = Some(now);
                }
                if n_models > 0 {
                    let m = jobs[job as usize].model;
                    if m != NO_MODEL {
                        let slot = &mut model_active[node * n_models + m as usize];
                        *slot = slot.saturating_sub(1);
                    }
                }
                if let Some(cl) = cluster_rt.as_mut() {
                    let js = &jobs[job as usize];
                    // sequential TTFT: service start + prefill + one
                    // decode step (the outcome-assembly formula)
                    let start = js.t_service_start.expect("done before start");
                    let tok = js.decode_time / js.n_output.max(1) as f64;
                    cl.observe_ttft(start - js.t_gen + js.prefill_time + tok);
                    cl.observe_completion(node, js.class, js.prefill_time + js.decode_time);
                    inflight_seq[node].retain(|&id| id != job);
                }
                let NodeRt::Seq(n) = &mut nodes[node] else {
                    unreachable!("ComputeDone scheduled for a batching node")
                };
                node_ev.clear();
                n.complete(now, node_ev);
                let track = cluster_rt.is_some();
                apply_node_events(
                    node,
                    epoch,
                    node_ev,
                    jobs,
                    q,
                    now,
                    track.then(|| &mut inflight_seq[node]),
                );
                if n_models > 0 {
                    track_seq_models(node, node_ev, jobs, model_active, n_models);
                }
            }
            Ev::BatchStep { node, epoch } => {
                if cluster_rt.as_ref().map_or(false, |c| !c.event_live(node, epoch)) {
                    // the engine was evicted after this step was armed
                    continue;
                }
                let NodeRt::Batch(e) = &mut nodes[node] else {
                    unreachable!("BatchStep scheduled for a sequential node")
                };
                batch_ev.clear();
                e.step(now, batch_ev);
                apply_batch_events(node, epoch, batch_ev, jobs, q, now);
                if n_models > 0 {
                    track_batch_models(node, batch_ev, jobs, model_active, n_models);
                }
                if let Some(cl) = cluster_rt.as_mut() {
                    observe_batch_completions(node, batch_ev, jobs, cl);
                }
            }
            Ev::ControlTick => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("ControlTick scheduled without a cluster");
                node_loads.clear();
                node_loads.extend(nodes.iter().map(|rt| match rt {
                    NodeRt::Seq(n) => (n.queue_len(), n.busy_servers()),
                    NodeRt::Batch(e) => (e.queue_len(), e.batch_len() as u32),
                }));
                power_on.clear();
                cl.control_tick(now, node_loads, power_on);
                for &i in power_on.iter() {
                    q.schedule_in(
                        sc.node_churn[i].spinup,
                        Ev::NodeUp { node: i, epoch: cl.epoch(i) },
                    );
                }
                if now < cfg.horizon {
                    q.schedule_in(cl.spec().tick_s, Ev::ControlTick);
                }
            }
            Ev::NodeFail { node, epoch } => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("NodeFail scheduled without a cluster");
                if !cl.event_live(node, epoch) {
                    // the node was drained to Down before its failure
                    // fired; the draw is already consumed, nothing dies
                    continue;
                }
                let repair_in = cl.on_fail(node, now);
                q.schedule_in(repair_in, Ev::NodeRepair { node });
                // Evict everything the node owned, in deterministic
                // order: in-service jobs first (start order for
                // sequential, job-id order inside the batch), then the
                // ready queue in discipline order.
                evicted_ids.clear();
                match &mut nodes[node] {
                    NodeRt::Seq(n) => {
                        evicted_ids.extend(inflight_seq[node].drain(..));
                        seq_evicted.clear();
                        n.evict(seq_evicted);
                        evicted_ids.extend(seq_evicted.iter().map(|j| j.job_id));
                    }
                    NodeRt::Batch(e) => {
                        batch_evicted.clear();
                        e.evict(batch_evicted);
                        evicted_ids.extend(batch_evicted.iter().map(|j| j.job_id));
                    }
                }
                if n_models > 0 {
                    // The node lost its HBM contents: every model goes
                    // cold again (next activation re-pays swap_s) and
                    // its in-flight per-model counts reset.
                    warm[node * n_models..(node + 1) * n_models].fill(false);
                    model_active[node * n_models..(node + 1) * n_models].fill(0);
                }
                let budget = cl.spec().retry_budget;
                for &job in evicted_ids.iter() {
                    let js = &mut jobs[job as usize];
                    // service never happened; the re-dispatch (or the
                    // loss report) starts from a clean slate
                    js.t_service_start = None;
                    js.t_first_token = None;
                    if js.retries < budget {
                        js.retries += 1;
                        cl.observe_redispatch(node, js.class);
                        q.schedule_at(now, Ev::ComputeEnqueue { job });
                    } else {
                        js.fate = JobFate::Lost;
                        cl.observe_lost(node, js.class);
                    }
                }
            }
            Ev::NodeRepair { node } => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("NodeRepair scheduled without a cluster");
                let spin = cl.on_repair(node, now);
                q.schedule_in(spin, Ev::NodeUp { node, epoch: cl.epoch(node) });
            }
            Ev::NodeUp { node, epoch } => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("NodeUp scheduled without a cluster");
                if cl.event_live(node, epoch) {
                    if let Some(ttf) = cl.on_up(node, now) {
                        q.schedule_in(ttf, Ev::NodeFail { node, epoch: cl.epoch(node) });
                    }
                }
            }
        }
    }
}


impl<'a> ScenarioEngine<'a> {
    /// Consume the engine and assemble the final [`ScenarioResult`].
    ///
    /// This is the legacy end-of-run outcome assembly, callable at any
    /// quiescent point: jobs still in flight at the cut carry
    /// [`JobFate::InFlight`] and are folded into the loss accounting by
    /// the report layer exactly as drain-window stragglers always were.
    pub fn finish(mut self) -> ScenarioResult {
        let sc = self.sc;
        let cfg = &sc.base;
        let t_wireline = self.st.t_wireline;

        // Assemble outcomes for measured jobs.
        let outcomes: Vec<JobOutcome> = self
            .st
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.measured)
            .map(|(id, j)| {
                let roofline_service = j.prefill_time + j.decode_time;
                let (t_queue, t_service) = match (j.t_node_arrival, j.t_service_start) {
                    (Some(a), Some(s)) => {
                        let svc = match j.t_done {
                            // batched decode stretches the executed service
                            // time; sequential keeps the exact roofline sum
                            Some(d) if j.t_first_token.is_some() => d - s,
                            _ => roofline_service,
                        };
                        (s - a, svc)
                    }
                    _ => (0.0, 0.0),
                };
                let tok = j.decode_time / j.n_output.max(1) as f64;
                let (ttft, tpot) = if j.fate == JobFate::Completed {
                    match (j.t_first_token, j.t_done) {
                        (Some(f), Some(d)) => (
                            f - j.t_gen,
                            if j.n_output > 1 { (d - f) / (j.n_output - 1) as f64 } else { 0.0 },
                        ),
                        // sequential: first token lands one decode step
                        // after the prefill; decode is evenly paced
                        _ => (
                            j.t_comm.unwrap_or(0.0)
                                + t_wireline
                                + t_queue
                                + j.prefill_time
                                + tok,
                            if j.n_output > 1 { tok } else { 0.0 },
                        ),
                    }
                } else {
                    (0.0, 0.0)
                };
                JobOutcome {
                    job_id: id as u64,
                    class_id: j.class as u32,
                    model_id: j.model,
                    cell_id: j.cell,
                    t_gen: j.t_gen,
                    t_comm: j.t_comm.unwrap_or(0.0),
                    t_wireline,
                    t_queue,
                    t_service,
                    ttft,
                    tpot,
                    tokens: j.n_input + j.n_output,
                    fate: j.fate,
                }
            })
            .collect();

        let class_policies: Vec<(String, LatencyManagement)> = sc
            .classes
            .iter()
            .map(|c| (c.name.clone(), management_of(&cfg.scheme, c.b_total)))
            .collect();
        let mut report =
            SimReport::from_outcomes_per_class(&outcomes, &class_policies, sc.cells.len());
        if !sc.models.is_empty() {
            let model_names: Vec<String> =
                sc.models.iter().map(|m| m.name.clone()).collect();
            report.per_model =
                SimReport::bucket_per_model(&outcomes, &model_names, &class_policies);
        }
        if sc.topology.is_some() {
            report.radio = self
                .cells
                .iter()
                .map(|cm| {
                    let c = cm.lock().unwrap();
                    CellRadioReport {
                        handovers_in: c.ho_in,
                        handovers_out: c.ho_out,
                        iot_db: c.iot_stats.clone(),
                    }
                })
                .collect();
        }
        if let Some(cl) = self.st.cluster_rt.as_mut() {
            // Costs cover the whole simulated window including the drain
            // tail — a deterministic bound, unlike the last-event time.
            cl.finalize(self.st.drain_horizon);
            let names: Vec<String> = sc.classes.iter().map(|c| c.name.clone()).collect();
            report.cluster = cl.report(&names);
        }

        // Fluid-tier summary: final + time-averaged activities per
        // far-ring cell, and per-class Eq 3–6 closed forms at the mean
        // fluid cell (λ at the horizon rate phase; μ₁ from the mean
        // air-interface capacity over the mean request size, μ₂ from
        // the deterministic repriced service demand).
        let fluid_report = self.st.fluid_rt.as_ref().map(|frt| {
            let t_end = cfg.horizon;
            let cells_rep: Vec<FluidCellReport> = frt
                .cells
                .iter()
                .map(|fc| FluidCellReport {
                    cell: fc.cell,
                    lambda_jobs: FluidRt::lambda_cell(fc.n_ues, &sc.classes, t_end),
                    activity: fc.activity,
                    mean_activity: if frt.ticks > 0 {
                        fc.act_sum / frt.elapsed()
                    } else {
                        fc.activity
                    },
                })
                .collect();
            let n_f = frt.cells.len().max(1) as f64;
            let mean_cap = frt.cells.iter().map(|c| c.capacity_bps).sum::<f64>() / n_f;
            let mean_pop = frt.cells.iter().map(|c| f64::from(c.n_ues)).sum::<f64>() / n_f;
            let classes_rep: Vec<FluidClassReport> = sc
                .classes
                .iter()
                .map(|class| {
                    let lambda = mean_pop * class.rate_at(t_end);
                    let mean_req =
                        class.request_bytes(class.input_tokens.mean().round() as u32);
                    let d = sc.service.reprice(
                        class,
                        class.input_tokens.mean().round().max(1.0) as u32,
                        class.output_tokens.mean().round().max(1.0) as u32,
                        &sc.nodes[0].gpu,
                    );
                    let p = SystemParams {
                        mu1: if mean_req > 0 { mean_cap / f64::from(mean_req) } else { 0.0 },
                        mu2: 1.0 / d.service_time(),
                        b_total: class.b_total,
                    };
                    let satisfaction = match management_of(&cfg.scheme, class.b_total) {
                        LatencyManagement::Joint { .. } => {
                            joint_satisfaction(&p, lambda, self.st.t_wireline)
                        }
                        LatencyManagement::Disjoint { b_comm, b_comp, .. } => disjoint_satisfaction(
                            &p,
                            lambda,
                            self.st.t_wireline,
                            b_comm,
                            b_comp,
                        ),
                    };
                    FluidClassReport {
                        name: class.name.clone(),
                        lambda_per_cell: lambda,
                        mean_sojourn: tandem_mean_sojourn(&p, lambda),
                        satisfaction,
                    }
                })
                .collect();
            FluidReport { cells: cells_rep, node_rho: frt.node_rho, classes: classes_rep }
        });

        ScenarioResult {
            outcomes,
            report,
            events: self.st.q.processed() + self.st.slot_events,
            speedup: if self.st.wall > 0.0 {
                cfg.horizon / self.st.wall
            } else {
                f64::INFINITY
            },
            fluid: fluid_report,
        }
    }
}

// ---------------------------------------------------------------------------
// Snapshot codecs
//
// Hand-rolled field-order codecs over [`snap::Enc`]/[`snap::Dec`]: every
// field of the dynamic state, in declaration order, with explicit tags
// for enums. No derive machinery, so the wire layout is exactly what is
// written here and stays stable unless `snap::VERSION` is bumped.
// ---------------------------------------------------------------------------

fn fate_to_u8(f: JobFate) -> u8 {
    match f {
        JobFate::Completed => 0,
        JobFate::Dropped => 1,
        JobFate::Lost => 2,
        JobFate::InFlight => 3,
    }
}

fn fate_from_u8(v: u8) -> Result<JobFate, SnapError> {
    Ok(match v {
        0 => JobFate::Completed,
        1 => JobFate::Dropped,
        2 => JobFate::Lost,
        3 => JobFate::InFlight,
        _ => return Err(SnapError::Corrupt { what: "job fate" }),
    })
}

fn enc_ev(e: &mut Enc, ev: &Ev) {
    match *ev {
        Ev::JobArrival { cell, ue, class } => {
            e.u8(0);
            e.u32(cell);
            e.u32(ue);
            e.u32(class);
        }
        Ev::BgArrival { cell, ue } => {
            e.u8(1);
            e.u32(cell);
            e.u32(ue);
        }
        Ev::ComputeEnqueue { job } => {
            e.u8(2);
            e.u64(job);
        }
        Ev::ComputeDone { node, job, epoch } => {
            e.u8(3);
            e.usize(node);
            e.u64(job);
            e.u32(epoch);
        }
        Ev::BatchStep { node, epoch } => {
            e.u8(4);
            e.usize(node);
            e.u32(epoch);
        }
        Ev::RadioTick => e.u8(5),
        Ev::ControlTick => e.u8(6),
        Ev::NodeFail { node, epoch } => {
            e.u8(7);
            e.usize(node);
            e.u32(epoch);
        }
        Ev::NodeRepair { node } => {
            e.u8(8);
            e.usize(node);
        }
        Ev::NodeUp { node, epoch } => {
            e.u8(9);
            e.usize(node);
            e.u32(epoch);
        }
        Ev::FluidTick => e.u8(10),
    }
}

fn dec_ev(d: &mut Dec<'_>) -> Result<Ev, SnapError> {
    Ok(match d.u8("event tag")? {
        0 => Ev::JobArrival {
            cell: d.u32("event cell")?,
            ue: d.u32("event ue")?,
            class: d.u32("event class")?,
        },
        1 => Ev::BgArrival { cell: d.u32("event cell")?, ue: d.u32("event ue")? },
        2 => Ev::ComputeEnqueue { job: d.u64("event job")? },
        3 => Ev::ComputeDone {
            node: d.usize("event node")?,
            job: d.u64("event job")?,
            epoch: d.u32("event epoch")?,
        },
        4 => Ev::BatchStep {
            node: d.usize("event node")?,
            epoch: d.u32("event epoch")?,
        },
        5 => Ev::RadioTick,
        6 => Ev::ControlTick,
        7 => Ev::NodeFail {
            node: d.usize("event node")?,
            epoch: d.u32("event epoch")?,
        },
        8 => Ev::NodeRepair { node: d.usize("event node")? },
        9 => Ev::NodeUp {
            node: d.usize("event node")?,
            epoch: d.u32("event epoch")?,
        },
        10 => Ev::FluidTick,
        _ => return Err(SnapError::Corrupt { what: "event tag" }),
    })
}

fn enc_job(e: &mut Enc, j: &JobState) {
    e.usize(j.class);
    e.u32(j.cell);
    e.f64(j.t_gen);
    e.opt_f64(j.t_comm);
    e.opt_f64(j.t_node_arrival);
    e.opt_f64(j.t_service_start);
    e.opt_f64(j.t_first_token);
    e.opt_f64(j.t_done);
    e.u32(j.n_input);
    e.u32(j.n_output);
    e.f64(j.prefill_time);
    e.f64(j.decode_time);
    e.u32(j.retries);
    e.u32(j.model);
    e.u8(fate_to_u8(j.fate));
    e.bool(j.measured);
}

fn dec_job(d: &mut Dec<'_>) -> Result<JobState, SnapError> {
    Ok(JobState {
        class: d.usize("job class")?,
        cell: d.u32("job cell")?,
        t_gen: d.f64("job t_gen")?,
        t_comm: d.opt_f64("job t_comm")?,
        t_node_arrival: d.opt_f64("job t_node_arrival")?,
        t_service_start: d.opt_f64("job t_service_start")?,
        t_first_token: d.opt_f64("job t_first_token")?,
        t_done: d.opt_f64("job t_done")?,
        n_input: d.u32("job n_input")?,
        n_output: d.u32("job n_output")?,
        prefill_time: d.f64("job prefill")?,
        decode_time: d.f64("job decode")?,
        retries: d.u32("job retries")?,
        model: d.u32("job model")?,
        fate: fate_from_u8(d.u8("job fate")?)?,
        measured: d.bool("job measured")?,
    })
}

fn enc_sdus(e: &mut Enc, sdus: &[Sdu]) {
    e.usize(sdus.len());
    for s in sdus {
        match s.kind {
            SduKind::Job { job_id } => {
                e.u8(0);
                e.u64(job_id);
            }
            SduKind::Background => e.u8(1),
        }
        e.u32(s.total_bytes);
        e.u32(s.bytes_left);
        e.f64(s.t_arrival);
    }
}

fn dec_sdus(d: &mut Dec<'_>) -> Result<Vec<Sdu>, SnapError> {
    let n = d.len("sdu count")?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let kind = match d.u8("sdu kind")? {
            0 => SduKind::Job { job_id: d.u64("sdu job id")? },
            1 => SduKind::Background,
            _ => return Err(SnapError::Corrupt { what: "sdu kind" }),
        };
        v.push(Sdu {
            kind,
            total_bytes: d.u32("sdu total bytes")?,
            bytes_left: d.u32("sdu bytes left")?,
            t_arrival: d.f64("sdu t_arrival")?,
        });
    }
    Ok(v)
}

fn enc_cell(e: &mut Enc, st: &CellRtState) {
    e.usize(st.ues.len());
    for u in &st.ues {
        e.f64(u.link.pos.x);
        e.f64(u.link.pos.y);
        e.bool(u.link.los);
        e.f64(u.link.shadow_db);
        e.u64(u.tag);
        enc_sdus(e, &u.job_sdus);
        enc_sdus(e, &u.bg_sdus);
        e.u8(u.harq_attempt);
        e.u64(u.sr_phase);
        e.u64(u.last_served_slot);
        e.f64(u.hot.avg_thpt);
        e.u64(u.hot.pf_next_slot);
        e.u64(u.hot.blocked_until);
        e.u64(u.hot.grant_ready_slot);
    }
    e.rng_state(&st.rng_mac);
    e.rng_state(&st.rng_svc);
    e.usize(st.job_rng.len());
    for per_class in &st.job_rng {
        e.usize(per_class.len());
        for r in per_class {
            e.rng_state(r);
        }
    }
    e.usize(st.bg_rng.len());
    for r in &st.bg_rng {
        e.rng_state(r);
    }
    e.f64(st.next_slot);
    e.u64(st.slot_idx);
    e.bool(st.ticking);
    e.f64(st.iot_db);
    e.f64s(&st.itf_out);
    let (n, mean, m2, min, max) = st.iot_stats;
    e.u64(n);
    e.f64(mean);
    e.f64(m2);
    e.f64(min);
    e.f64(max);
    e.u64(st.ho_in);
    e.u64(st.ho_out);
    match &st.geo_ues {
        None => e.bool(false),
        Some(geos) => {
            e.bool(true);
            e.usize(geos.len());
            for g in geos {
                e.f64(g.pos.0);
                e.f64(g.pos.1);
                e.usize(g.links.len());
                for &(los, shadow, dist) in &g.links {
                    e.bool(los);
                    e.f64(shadow);
                    e.f64(dist);
                }
                e.f64(g.speed);
                e.f64(g.heading.0);
                e.f64(g.heading.1);
                e.f64(g.waypoint.0);
                e.f64(g.waypoint.1);
                e.rng_state(&g.rng);
                e.u32(g.a3_target);
                e.u32(g.a3_ticks);
            }
        }
    }
}

fn dec_cell(d: &mut Dec<'_>) -> Result<CellRtState, SnapError> {
    let n_ues = d.len("ue count")?;
    let mut ues = Vec::with_capacity(n_ues);
    for _ in 0..n_ues {
        let link = LargeScale {
            pos: Position { x: d.f64("ue pos x")?, y: d.f64("ue pos y")? },
            los: d.bool("ue los")?,
            shadow_db: d.f64("ue shadow")?,
        };
        ues.push(UeSnap {
            link,
            tag: d.u64("ue tag")?,
            job_sdus: dec_sdus(d)?,
            bg_sdus: dec_sdus(d)?,
            harq_attempt: d.u8("ue harq attempt")?,
            sr_phase: d.u64("ue sr phase")?,
            last_served_slot: d.u64("ue last served")?,
            hot: UeHot {
                avg_thpt: d.f64("ue avg thpt")?,
                pf_next_slot: d.u64("ue pf next")?,
                blocked_until: d.u64("ue blocked until")?,
                grant_ready_slot: d.u64("ue grant ready")?,
            },
        });
    }
    let rng_mac = d.rng_state("cell mac rng")?;
    let rng_svc = d.rng_state("cell svc rng")?;
    let n_classes = d.len("job rng class count")?;
    let mut job_rng = Vec::with_capacity(n_classes);
    for _ in 0..n_classes {
        let n = d.len("job rng ue count")?;
        let mut per_class = Vec::with_capacity(n);
        for _ in 0..n {
            per_class.push(d.rng_state("job rng")?);
        }
        job_rng.push(per_class);
    }
    let n_bg = d.len("bg rng count")?;
    let mut bg_rng = Vec::with_capacity(n_bg);
    for _ in 0..n_bg {
        bg_rng.push(d.rng_state("bg rng")?);
    }
    let next_slot = d.f64("cell next slot")?;
    let slot_idx = d.u64("cell slot idx")?;
    let ticking = d.bool("cell ticking")?;
    let iot_db = d.f64("cell iot db")?;
    let itf_out = d.f64s("cell itf out")?;
    let iot_stats = (
        d.u64("iot stats n")?,
        d.f64("iot stats mean")?,
        d.f64("iot stats m2")?,
        d.f64("iot stats min")?,
        d.f64("iot stats max")?,
    );
    let ho_in = d.u64("cell ho in")?;
    let ho_out = d.u64("cell ho out")?;
    let geo_ues = if d.bool("geo flag")? {
        let n = d.len("geo ue count")?;
        let mut geos = Vec::with_capacity(n);
        for _ in 0..n {
            let pos = (d.f64("geo pos x")?, d.f64("geo pos y")?);
            let n_links = d.len("geo link count")?;
            let mut links = Vec::with_capacity(n_links);
            for _ in 0..n_links {
                links.push((
                    d.bool("geo link los")?,
                    d.f64("geo link shadow")?,
                    d.f64("geo link dist")?,
                ));
            }
            geos.push(UeGeoSnap {
                pos,
                links,
                speed: d.f64("geo speed")?,
                heading: (d.f64("geo heading x")?, d.f64("geo heading y")?),
                waypoint: (d.f64("geo waypoint x")?, d.f64("geo waypoint y")?),
                rng: d.rng_state("geo rng")?,
                a3_target: d.u32("geo a3 target")?,
                a3_ticks: d.u32("geo a3 ticks")?,
            });
        }
        Some(geos)
    } else {
        None
    };
    Ok(CellRtState {
        ues,
        rng_mac,
        rng_svc,
        job_rng,
        bg_rng,
        next_slot,
        slot_idx,
        ticking,
        iot_db,
        itf_out,
        iot_stats,
        ho_in,
        ho_out,
        geo_ues,
    })
}

fn enc_cjob(e: &mut Enc, j: &ComputeJob) {
    e.u64(j.job_id);
    e.f64(j.t_gen);
    e.f64(j.t_comm);
    e.f64(j.deadline);
    e.f64(j.service_time);
}

fn dec_cjob(d: &mut Dec<'_>) -> Result<ComputeJob, SnapError> {
    Ok(ComputeJob {
        job_id: d.u64("cjob id")?,
        t_gen: d.f64("cjob t_gen")?,
        t_comm: d.f64("cjob t_comm")?,
        deadline: d.f64("cjob deadline")?,
        service_time: d.f64("cjob service")?,
    })
}

fn enc_bjob(e: &mut Enc, j: &BatchJob) {
    e.u64(j.job_id);
    e.f64(j.t_gen);
    e.f64(j.t_comm);
    e.f64(j.deadline);
    e.u32(j.n_input);
    e.u32(j.n_output);
    e.f64(j.prefill_time);
    e.f64(j.decode_time);
    e.f64(j.c_llm);
    e.f64(j.m_llm);
    e.f64(j.kv_bytes_per_token);
    e.u64(j.prefix_id);
    e.u32(j.prefix_tokens);
}

fn dec_bjob(d: &mut Dec<'_>) -> Result<BatchJob, SnapError> {
    Ok(BatchJob {
        job_id: d.u64("bjob id")?,
        t_gen: d.f64("bjob t_gen")?,
        t_comm: d.f64("bjob t_comm")?,
        deadline: d.f64("bjob deadline")?,
        n_input: d.u32("bjob n_input")?,
        n_output: d.u32("bjob n_output")?,
        prefill_time: d.f64("bjob prefill")?,
        decode_time: d.f64("bjob decode")?,
        c_llm: d.f64("bjob c_llm")?,
        m_llm: d.f64("bjob m_llm")?,
        kv_bytes_per_token: d.f64("bjob kv bytes")?,
        prefix_id: d.u64("bjob prefix id")?,
        prefix_tokens: d.u32("bjob prefix tokens")?,
    })
}

fn enc_node(e: &mut Enc, rt: &NodeRt) {
    match rt {
        NodeRt::Seq(n) => {
            e.u8(0);
            let (busy, dropped, (queue_seq, entries)) = n.snapshot_state();
            e.u32(busy);
            e.u64(dropped);
            e.u64(queue_seq);
            e.usize(entries.len());
            for (key, seq, j) in &entries {
                e.f64(*key);
                e.u64(*seq);
                enc_cjob(e, j);
            }
        }
        NodeRt::Batch(b) => {
            e.u8(1);
            let (kv_used, running, dropped, active, (queue_seq, entries), prefixes) =
                b.snapshot_state();
            e.f64(kv_used);
            e.bool(running);
            e.u64(dropped);
            e.usize(active.len());
            for (j, tokens_left, prefilled, kv_reserved) in &active {
                enc_bjob(e, j);
                e.u32(*tokens_left);
                e.bool(*prefilled);
                e.f64(*kv_reserved);
            }
            e.u64(queue_seq);
            e.usize(entries.len());
            for (key, seq, j) in &entries {
                e.f64(*key);
                e.u64(*seq);
                enc_bjob(e, j);
            }
            e.usize(prefixes.len());
            for (key, bytes, refs) in &prefixes {
                e.u64(*key);
                e.f64(*bytes);
                e.u32(*refs);
            }
        }
    }
}

fn dec_node(
    d: &mut Dec<'_>,
    discipline: Discipline,
    spec: &NodeSpec,
) -> Result<NodeRt, SnapError> {
    let tag = d.u8("node kind")?;
    match (tag, spec.execution) {
        (0, ExecutionModel::Sequential) => {
            let busy = d.u32("node busy")?;
            let dropped = d.u64("node dropped")?;
            let queue_seq = d.u64("node queue seq")?;
            let n = d.len("node queue len")?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                entries.push((
                    d.f64("node queue key")?,
                    d.u64("node queue seq no")?,
                    dec_cjob(d)?,
                ));
            }
            Ok(NodeRt::Seq(ComputeNode::restore(
                discipline,
                spec.n_servers,
                busy,
                dropped,
                queue_seq,
                entries,
            )))
        }
        (1, ExecutionModel::ContinuousBatching { max_batch, kv_budget }) => {
            let kv_used = d.f64("batch kv used")?;
            let running = d.bool("batch running")?;
            let dropped = d.u64("batch dropped")?;
            let n_active = d.len("batch active len")?;
            let mut active = Vec::with_capacity(n_active);
            for _ in 0..n_active {
                active.push((
                    dec_bjob(d)?,
                    d.u32("batch tokens left")?,
                    d.bool("batch prefilled")?,
                    d.f64("batch kv reserved")?,
                ));
            }
            let queue_seq = d.u64("batch queue seq")?;
            let n = d.len("batch queue len")?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                entries.push((
                    d.f64("batch queue key")?,
                    d.u64("batch queue seq no")?,
                    dec_bjob(d)?,
                ));
            }
            let n_prefix = d.len("batch prefix len")?;
            let mut prefixes = Vec::with_capacity(n_prefix);
            for _ in 0..n_prefix {
                prefixes.push((
                    d.u64("batch prefix key")?,
                    d.f64("batch prefix bytes")?,
                    d.u32("batch prefix refs")?,
                ));
            }
            Ok(NodeRt::Batch(BatchEngine::restore(
                discipline,
                spec.gpu,
                max_batch,
                kv_budget,
                kv_used,
                running,
                dropped,
                active,
                queue_seq,
                entries,
                prefixes,
            )))
        }
        _ => Err(SnapError::Corrupt { what: "node kind" }),
    }
}

fn enc_cluster(e: &mut Enc, st: &ClusterRtState) {
    e.usize(st.states.len());
    for &s in &st.states {
        e.u8(s);
    }
    e.usize(st.epochs.len());
    for &v in &st.epochs {
        e.u32(v);
    }
    e.usize(st.repairing.len());
    for &v in &st.repairing {
        e.bool(v);
    }
    e.usize(st.rngs.len());
    for r in &st.rngs {
        e.rng_state(r);
    }
    e.f64s(&st.powered_since);
    e.usize(st.acct.len());
    for &(up, served, redisp, lost, fails) in &st.acct {
        e.f64(up);
        e.u64(served);
        e.u64(redisp);
        e.u64(lost);
        e.u64(fails);
    }
    e.usize(st.class_acct.len());
    for &(gpu_s, joules, dollars, redisp, lost) in &st.class_acct {
        e.f64(gpu_s);
        e.f64(joules);
        e.f64(dollars);
        e.u64(redisp);
        e.u64(lost);
    }
    e.u64(st.jobs_ttft);
    e.u64(st.ttft_violations);
}

fn dec_cluster(d: &mut Dec<'_>) -> Result<ClusterRtState, SnapError> {
    let n = d.len("cluster state count")?;
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        states.push(d.u8("cluster state")?);
    }
    let n = d.len("cluster epoch count")?;
    let mut epochs = Vec::with_capacity(n);
    for _ in 0..n {
        epochs.push(d.u32("cluster epoch")?);
    }
    let n = d.len("cluster repairing count")?;
    let mut repairing = Vec::with_capacity(n);
    for _ in 0..n {
        repairing.push(d.bool("cluster repairing")?);
    }
    let n = d.len("cluster rng count")?;
    let mut rngs = Vec::with_capacity(n);
    for _ in 0..n {
        rngs.push(d.rng_state("cluster rng")?);
    }
    let powered_since = d.f64s("cluster powered since")?;
    let n = d.len("cluster acct count")?;
    let mut acct = Vec::with_capacity(n);
    for _ in 0..n {
        acct.push((
            d.f64("acct up seconds")?,
            d.u64("acct served")?,
            d.u64("acct redispatched")?,
            d.u64("acct lost")?,
            d.u64("acct failures")?,
        ));
    }
    let n = d.len("cluster class acct count")?;
    let mut class_acct = Vec::with_capacity(n);
    for _ in 0..n {
        class_acct.push((
            d.f64("class acct gpu seconds")?,
            d.f64("class acct joules")?,
            d.f64("class acct dollars")?,
            d.u64("class acct redispatched")?,
            d.u64("class acct lost")?,
        ));
    }
    Ok(ClusterRtState {
        states,
        epochs,
        repairing,
        rngs,
        powered_since,
        acct,
        class_acct,
        jobs_ttft: d.u64("cluster jobs ttft")?,
        ttft_violations: d.u64("cluster ttft violations")?,
    })
}

impl<'a> ScenarioEngine<'a> {
    /// Serialize the complete dynamic state at the current quiescent
    /// point into a self-describing binary blob (see DESIGN.md §13).
    ///
    /// The blob is framed with the scenario's config fingerprint;
    /// [`ScenarioEngine::from_snapshot`] refuses blobs whose
    /// fingerprint disagrees with the restoring scenario. Bytes are
    /// independent of thread count, sync mode, and calendar backend:
    /// the event queue serializes in canonical `(time, seq)` order and
    /// per-cell slot cursors are normalized on capture.
    pub fn snapshot(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.usize(self.cells.len());
        for cm in &self.cells {
            let cst = cm.lock().unwrap().snapshot_state();
            enc_cell(&mut e, &cst);
        }
        e.usize(self.st.nodes.len());
        for rt in &self.st.nodes {
            enc_node(&mut e, rt);
        }
        e.u64(self.st.router.cursor());
        e.usize(self.st.jobs.len());
        for j in &self.st.jobs {
            enc_job(&mut e, j);
        }
        let (q_now, q_seq, q_processed, entries) = self.st.q.snapshot_entries();
        e.f64(q_now);
        e.u64(q_seq);
        e.u64(q_processed);
        e.usize(entries.len());
        for (time, seq, ev) in &entries {
            e.f64(*time);
            e.u64(*seq);
            enc_ev(&mut e, ev);
        }
        match &self.st.locs {
            None => e.bool(false),
            Some(l) => {
                e.bool(true);
                e.usize(l.len());
                for &(c, i) in l {
                    e.u32(c);
                    e.u32(i);
                }
            }
        }
        match &self.st.cluster_rt {
            None => e.bool(false),
            Some(cl) => {
                e.bool(true);
                enc_cluster(&mut e, &cl.snapshot_state());
            }
        }
        e.usize(self.st.inflight_seq.len());
        for per_node in &self.st.inflight_seq {
            e.usize(per_node.len());
            for &id in per_node {
                e.u64(id);
            }
        }
        e.u64(self.st.slot_events);
        e.usize(self.st.warm.len());
        for &w in &self.st.warm {
            e.bool(w);
        }
        e.usize(self.st.model_active.len());
        for &v in &self.st.model_active {
            e.u32(v);
        }
        // v3: fluid-tier state. Capacities, unit rows and populations
        // are config-derived; only the evolving activities (and their
        // integrals), the tick counter and the derived node load are
        // serialized.
        match &self.st.fluid_rt {
            None => e.bool(false),
            Some(frt) => {
                e.bool(true);
                e.u64(frt.ticks);
                e.f64(frt.node_rho);
                e.usize(frt.cells.len());
                for fc in &frt.cells {
                    e.f64(fc.activity);
                    e.f64(fc.act_sum);
                }
            }
        }
        snap::frame(self.sc.fingerprint(), &e.into_bytes())
    }

    /// Rebuild an engine mid-run from a [`ScenarioEngine::snapshot`]
    /// blob, validating magic, version, and config fingerprint.
    ///
    /// `sc` must be snapshot-compatible with the scenario that produced
    /// the blob: identical in everything except arrival rates (and the
    /// thread/sync knobs, which never affect results). The fingerprint
    /// enforces exactly that — rates are excluded from it so warm-start
    /// sweeps can fork one warmed checkpoint across rate points.
    pub fn from_snapshot(sc: &'a Scenario, blob: &[u8]) -> Result<Self, SnapError> {
        let payload = snap::unframe(blob, sc.fingerprint())?;
        // Build a pristine engine first: config-derived structure
        // (geometry, routing tables, pool shapes) comes from `sc`; the
        // priming draws below are overwritten wholesale by the restore.
        let mut eng = Self::new(sc);
        let mut d = Dec::new(payload);

        let n_cells = d.len("cell count")?;
        if n_cells != eng.cells.len() {
            return Err(SnapError::Corrupt { what: "cell count" });
        }
        for cm in &eng.cells {
            let cst = dec_cell(&mut d)?;
            cm.lock().unwrap().restore_state(cst);
        }

        let n_nodes = d.len("node count")?;
        if n_nodes != eng.st.nodes.len() {
            return Err(SnapError::Corrupt { what: "node count" });
        }
        let discipline = discipline_of(&sc.base.scheme);
        for (rt, spec) in eng.st.nodes.iter_mut().zip(sc.nodes.iter()) {
            *rt = dec_node(&mut d, discipline, spec)?;
        }

        eng.st.router.set_cursor(d.u64("router cursor")?);

        let n_jobs = d.len("job count")?;
        let mut jobs = Vec::with_capacity(n_jobs);
        for _ in 0..n_jobs {
            jobs.push(dec_job(&mut d)?);
        }
        eng.st.jobs = jobs;

        let q_now = d.f64("queue now")?;
        let q_seq = d.u64("queue seq")?;
        let q_processed = d.u64("queue processed")?;
        let n_ev = d.len("queue entry count")?;
        let mut entries = Vec::with_capacity(n_ev);
        for _ in 0..n_ev {
            entries.push((
                d.f64("queue entry time")?,
                d.u64("queue entry seq")?,
                dec_ev(&mut d)?,
            ));
        }
        eng.st.q = EventQueue::restore(sc.event_queue, q_now, q_seq, q_processed, entries);

        let has_locs = d.bool("locs flag")?;
        if has_locs != eng.st.locs.is_some() {
            return Err(SnapError::Corrupt { what: "ue locator flag" });
        }
        if has_locs {
            let n = d.len("locs count")?;
            let locs = eng.st.locs.as_mut().unwrap();
            if n != locs.len() {
                return Err(SnapError::Corrupt { what: "ue locator count" });
            }
            for slot in locs.iter_mut() {
                *slot = (d.u32("locs cell")?, d.u32("locs index")?);
            }
        }

        let has_cluster = d.bool("cluster flag")?;
        if has_cluster != eng.st.cluster_rt.is_some() {
            return Err(SnapError::Corrupt { what: "cluster flag" });
        }
        if has_cluster {
            let cst = dec_cluster(&mut d)?;
            eng.st.cluster_rt.as_mut().unwrap().restore_state(cst);
        }

        let n_inflight = d.len("inflight node count")?;
        if n_inflight != eng.st.inflight_seq.len() {
            return Err(SnapError::Corrupt { what: "inflight node count" });
        }
        for per_node in eng.st.inflight_seq.iter_mut() {
            per_node.clear();
            let n = d.len("inflight job count")?;
            for _ in 0..n {
                per_node.push(d.u64("inflight job id")?);
            }
        }

        eng.st.slot_events = d.u64("slot event counter")?;

        // Warm flags and per-model in-flight counters (flattened
        // node × zoo; both empty without a model zoo — the fingerprint
        // already pins the zoo itself).
        let n_warm = d.len("warm flag count")?;
        if n_warm != eng.st.warm.len() {
            return Err(SnapError::Corrupt { what: "warm flag count" });
        }
        for slot in eng.st.warm.iter_mut() {
            *slot = d.bool("warm flag")?;
        }
        let n_ma = d.len("model active count")?;
        if n_ma != eng.st.model_active.len() {
            return Err(SnapError::Corrupt { what: "model active count" });
        }
        for slot in eng.st.model_active.iter_mut() {
            *slot = d.u32("model active")?;
        }

        // v3: fluid-tier state (flag must agree with the config — the
        // fingerprint already pins the [fluid] table, so a mismatch
        // here means a corrupt blob, not a config drift).
        let has_fluid = d.bool("fluid flag")?;
        if has_fluid != eng.st.fluid_rt.is_some() {
            return Err(SnapError::Corrupt { what: "fluid flag" });
        }
        if let Some(frt) = eng.st.fluid_rt.as_mut() {
            frt.ticks = d.u64("fluid tick counter")?;
            frt.node_rho = d.f64("fluid node rho")?;
            let n_f = d.len("fluid cell count")?;
            if n_f != frt.cells.len() {
                return Err(SnapError::Corrupt { what: "fluid cell count" });
            }
            for fc in frt.cells.iter_mut() {
                fc.activity = d.f64("fluid activity")?;
                fc.act_sum = d.f64("fluid activity integral")?;
            }
        }
        if !d.is_empty() {
            return Err(SnapError::Corrupt { what: "trailing bytes" });
        }

        // The bounded-lag writer bound is derived from the calendar:
        // rescan the restored queue.
        eng.st.writers = writer_heap(&eng.st.q);

        // Rebuild the interference exchange rows from the restored cell
        // state (same seeding rule the frontier pool uses): a ticking
        // cell republishes its last committed out-row, everything else
        // contributes silence.
        let n = eng.cells.len();
        for (k, cm) in eng.cells.iter().enumerate() {
            let c = cm.lock().unwrap();
            eng.st.itf[k] = if (c.ticking || c.fluid) && !c.itf_out.is_empty() {
                c.itf_out.clone()
            } else {
                vec![0.0; n]
            };
        }

        // Wall-clock restarts at the resume point; `finish` reports
        // speedup for the resumed segment only.
        eng.st.wall = 0.0;
        Ok(eng)
    }
}
