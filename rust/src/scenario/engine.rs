//! The scenario event loop: the Fig 5 pipeline generalized to N
//! workload classes, K cells and M compute nodes.
//!
//! ```text
//!  cell 0: UE job gen ─► RLC ─► slot scheduler ─► gNB 0 ─┐
//!  cell 1: UE job gen ─► RLC ─► slot scheduler ─► gNB 1 ─┤ wireline
//!    ⋮         (each cell: own UeBank/workspace/RNGs)    ⋮    │
//!                                                             ▼
//!     per-class/per-cell outcomes ◄── ServiceModel ◄── Routing ──► node 0..M
//!                                                  (Sequential server
//!                                                   or BatchEngine)
//! ```
//!
//! Stream discipline: every entity draws from its own substream of its
//! *cell's* seed ([`super::cell_seed`]; cell 0 keeps the master seed)
//! from a disjoint id range, the event-handler logic mirrors the legacy
//! `Sls::run` loop line for line, and `TokenDist::Fixed` consumes no
//! randomness — so single-cell, single-class runs are exactly as
//! deterministic and statistically identical to the seed SLS. The
//! execution models consume no randomness either.
//!
//! Determinism rule for multi-cell merging (DESIGN.md §9, §12): the
//! per-cell slot clocks live *outside* the event calendar. At every
//! instant the engine first drains calendar events (in insertion
//! order, as before), then steps the due cells — inline, on the
//! [`StepPool`] barrier workers, or asynchronously via the
//! [`FrontierPool`] conservative scheduler — and merges their
//! delivered SDUs into the calendar in ascending (slot-time,
//! cell-index) order. Because a slot step touches only its own cell's
//! state and the merge order is fixed, every driver's schedule is
//! bit-identical to the serial one.

use std::sync::Mutex;

use crate::cluster::ClusterRt;
use crate::compute::{
    BatchEngine, BatchEvent, BatchJob, ComputeJob, ComputeNode, Discipline, ExecutionModel,
    NodeEvent,
};
use crate::config::{Management, SchemeConfig};
use crate::dess::EventQueue;
use crate::mac::{Sdu, SduKind};
use crate::metrics::{CellRadioReport, JobFate, JobOutcome, LatencyManagement, SimReport};
use crate::phy::channel::Position;
use crate::phy::link::iot_db_from_linear;
use crate::phy::mobility::MobilitySpec;
use crate::sweep::resolve_threads;

use super::cells::{cell_seed, CellRt, CellSync, FrontierPool, StepDriver, StepPool, StepRec};
use super::routing::NodeView;
use super::service::ServiceDemand;
use super::{NodeSpec, Scenario};

/// Map a scheme to the node queue discipline.
pub fn discipline_of(scheme: &SchemeConfig) -> Discipline {
    if scheme.priority_scheme {
        Discipline::DeadlinePriority { drop_hopeless: true }
    } else {
        Discipline::Fifo
    }
}

/// Map a scheme to the satisfaction policy for one class budget.
pub fn management_of(scheme: &SchemeConfig, b_total: f64) -> LatencyManagement {
    match scheme.management {
        Management::Joint => LatencyManagement::Joint { b_total },
        Management::Disjoint { b_comm, b_comp } => {
            LatencyManagement::Disjoint { b_total, b_comm, b_comp }
        }
    }
}

/// Result of one scenario run.
#[derive(Debug)]
pub struct ScenarioResult {
    pub outcomes: Vec<JobOutcome>,
    /// Aggregate report with `per_class` (and, for multi-cell
    /// scenarios, `per_cell`) populated.
    pub report: SimReport,
    /// Simulated events processed (calendar pops + cell-slot steps).
    pub events: u64,
    /// Simulated seconds per wall-clock second.
    pub speedup: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Job of `class` generated at UE `ue` of `cell`.
    JobArrival { cell: u32, ue: u32, class: u32 },
    /// Background packet at UE `ue` of `cell`.
    BgArrival { cell: u32, ue: u32 },
    /// Prompt fully received at the gNB crossed the wireline.
    ComputeEnqueue { job: u64 },
    /// Sequential node `node` finished `job`. `epoch` is the node's
    /// cluster epoch at scheduling time (always 0 without a cluster);
    /// the event is stale — the job was evicted — if the epoch lapsed.
    ComputeDone { node: usize, job: u64, epoch: u32 },
    /// Iteration boundary of node `node`'s batch engine (same epoch
    /// staleness rule as `ComputeDone`).
    BatchStep { node: usize, epoch: u32 },
    /// Coarse radio tick: UE mobility + A3 handover evaluation.
    RadioTick,
    /// Cluster control tick: drain completion + autoscaler evaluation.
    ControlTick,
    /// Node `node` fails (stale if its epoch lapsed — it was drained
    /// to `Down` before the failure fired).
    NodeFail { node: usize, epoch: u32 },
    /// Node `node`'s repair completes; it powers on and spins up.
    NodeRepair { node: usize },
    /// Node `node` finishes spin-up and starts serving.
    NodeUp { node: usize, epoch: u32 },
}

#[derive(Debug, Clone, Copy)]
struct JobState {
    class: usize,
    /// Originating cell (gNB) of the job.
    cell: u32,
    t_gen: f64,
    /// Set when the last prompt byte reaches the gNB.
    t_comm: Option<f64>,
    t_node_arrival: Option<f64>,
    t_service_start: Option<f64>,
    /// First output token emitted (batching nodes; sequential nodes
    /// derive it from the roofline split).
    t_first_token: Option<f64>,
    t_done: Option<f64>,
    /// Realized prompt length (sampled at generation).
    n_input: u32,
    /// Realized output length (set when the service model prices it).
    n_output: u32,
    /// Realized prefill latency (set at node arrival).
    prefill_time: f64,
    /// Realized sequential decode latency (set at node arrival).
    decode_time: f64,
    /// Times this job was re-dispatched after losing its node (cluster
    /// runs only; compared against the retry budget).
    retries: u32,
    fate: JobFate,
    measured: bool,
}

/// Per-node runtime: the legacy sequential server bank or the
/// continuous-batching engine.
enum NodeRt {
    Seq(ComputeNode),
    Batch(BatchEngine),
}

impl NodeRt {
    fn view(&self, spec: &NodeSpec) -> NodeView {
        match self {
            NodeRt::Seq(n) => NodeView {
                queue_len: n.queue_len(),
                busy_servers: n.busy_servers(),
                n_servers: spec.n_servers,
                gpu: spec.gpu,
            },
            NodeRt::Batch(e) => NodeView {
                queue_len: e.queue_len(),
                busy_servers: e.batch_len() as u32,
                n_servers: match spec.execution {
                    ExecutionModel::ContinuousBatching { max_batch, .. } => max_batch,
                    ExecutionModel::Sequential => spec.n_servers,
                },
                gpu: spec.gpu,
            },
        }
    }
}

/// Sequential node-event plumbing: schedule completions for started
/// jobs (stamped with the node's cluster epoch), mark drops. `inflight`
/// is the node's in-service job list, maintained only on cluster runs
/// so a failure can evict mid-service jobs.
fn apply_node_events(
    node: usize,
    epoch: u32,
    events: &[NodeEvent],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    now: f64,
    mut inflight: Option<&mut Vec<u64>>,
) {
    for ev in events {
        match *ev {
            NodeEvent::Started { job, completes_at } => {
                jobs[job.job_id as usize].t_service_start = Some(now);
                if let Some(list) = inflight.as_deref_mut() {
                    list.push(job.job_id);
                }
                q.schedule_at(
                    completes_at,
                    Ev::ComputeDone { node, job: job.job_id, epoch },
                );
            }
            NodeEvent::Dropped { job } => {
                jobs[job.job_id as usize].fate = JobFate::Dropped;
            }
        }
    }
}

/// Batch-engine plumbing: record admissions / token boundaries /
/// completions and schedule the next iteration step (stamped with the
/// node's cluster epoch).
fn apply_batch_events(
    node: usize,
    epoch: u32,
    events: &[BatchEvent],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    now: f64,
) {
    for ev in events {
        match *ev {
            BatchEvent::Admitted { job_id } => {
                jobs[job_id as usize].t_service_start = Some(now);
            }
            BatchEvent::FirstToken { job_id } => {
                jobs[job_id as usize].t_first_token = Some(now);
            }
            BatchEvent::Finished { job_id } => {
                let js = &mut jobs[job_id as usize];
                js.fate = JobFate::Completed;
                js.t_done = Some(now);
            }
            BatchEvent::Dropped { job_id } => {
                jobs[job_id as usize].fate = JobFate::Dropped;
            }
            BatchEvent::StepAt { at } => {
                q.schedule_at(at, Ev::BatchStep { node, epoch });
            }
        }
    }
}

/// Cluster bookkeeping for a batch of engine events: TTFT observations
/// and per-class work attribution for every finished job.
fn observe_batch_completions(
    node: usize,
    events: &[BatchEvent],
    jobs: &[JobState],
    cluster: &mut ClusterRt,
) {
    for ev in events {
        if let BatchEvent::Finished { job_id } = *ev {
            let js = &jobs[job_id as usize];
            if let Some(f) = js.t_first_token {
                cluster.observe_ttft(f - js.t_gen);
            }
            cluster.observe_completion(node, js.class, js.prefill_time + js.decode_time);
        }
    }
}

/// Earliest pending slot boundary across the still-ticking cells
/// (`f64::INFINITY` when every slot clock has stopped).
fn next_slot_time(cells: &[Mutex<CellRt>]) -> f64 {
    let mut t = f64::INFINITY;
    for cm in cells {
        let c = cm.lock().unwrap();
        if c.ticking && c.next_slot < t {
            t = c.next_slot;
        }
    }
    t
}

/// One synchronous slot batch (serial / barrier drivers): refresh the
/// due cells' IoT terms from the one-slot-lagged snapshot, step every
/// due cell, then merge delivered SDUs into the calendar in ascending
/// cell-index order — the determinism rule that makes the threaded
/// schedule bit-identical to a serial cell loop.
#[allow(clippy::too_many_arguments)]
fn batch_step(
    driver: &StepDriver<'_, '_>,
    cells: &[Mutex<CellRt>],
    t_slot: f64,
    radio_coupling: bool,
    itf: &mut [Vec<f64>],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    t_wireline: f64,
    slot_events: &mut u64,
) {
    let t_bits = t_slot.to_bits();
    // Interference-snapshot barrier: before the (possibly parallel)
    // step, every due cell reads the one-slot-lagged neighbor activity
    // into its IoT term. Serial on the engine thread, so the thread
    // count can never reorder it.
    if radio_coupling {
        for (j, cm) in cells.iter().enumerate() {
            let mut c = cm.lock().unwrap();
            if !c.due(t_bits) {
                continue;
            }
            let mut i_mw = 0.0;
            for (k, row) in itf.iter().enumerate() {
                if k != j {
                    i_mw += row[j];
                }
            }
            c.iot_db = iot_db_from_linear(i_mw, c.noise_floor_mw);
        }
    }
    match driver {
        StepDriver::Barrier(p) => p.step_batch(t_slot),
        StepDriver::Serial => {
            for cm in cells {
                let mut c = cm.lock().unwrap();
                if c.due(t_bits) {
                    c.step_slot();
                }
            }
        }
        StepDriver::Frontier(_) => unreachable!("frontier mode never batches"),
    }
    // Merge delivered SDUs into the calendar in ascending cell-index
    // order.
    for (k, cm) in cells.iter().enumerate() {
        let mut c = cm.lock().unwrap();
        if c.last_slot != t_bits {
            continue;
        }
        *slot_events += 1;
        // Gather the stepped cell's outgoing interference for the next
        // batch's snapshot (still on the engine thread — the
        // publication order is cell-index order regardless of which
        // worker stepped the cell). A cell whose clock just stopped
        // (drained past the horizon) transmits nothing more: zero its
        // row instead of letting neighbors price its final slot's
        // activity for the rest of the drain window.
        if radio_coupling {
            if c.ticking {
                itf[k].copy_from_slice(&c.itf_out);
            } else {
                for v in &mut itf[k] {
                    *v = 0.0;
                }
            }
        }
        // TBs land at the end of the slot. The flat delivered buffer
        // is already in grant order.
        let t_rx = t_slot + c.slot_dur;
        for d in &c.ws.delivered {
            if let SduKind::Job { job_id } = d.kind {
                let js = &mut jobs[job_id as usize];
                js.t_comm = Some(t_rx - js.t_gen);
                q.schedule_at(t_rx + t_wireline, Ev::ComputeEnqueue { job: job_id });
            }
        }
        // Invalidate so an un-stepped later batch at the same bit
        // pattern (impossible for monotone clocks, but cheap to rule
        // out) cannot re-merge.
        c.last_slot = u64::MAX;
    }
}

pub(super) fn run(sc: &Scenario) -> ScenarioResult {
    let wall0 = std::time::Instant::now();
    let n_classes = sc.classes.len();
    assert!(n_classes > 0, "scenario needs at least one workload class");
    assert!(!sc.nodes.is_empty(), "scenario needs at least one compute node");
    assert!(!sc.cells.is_empty(), "scenario needs at least one cell (build() defaults one)");

    let cells: Vec<Mutex<CellRt>> = sc
        .cells
        .iter()
        .enumerate()
        .map(|(k, spec)| Mutex::new(CellRt::new(k, spec, &sc.base, n_classes)))
        .collect();

    // Coupled-radio geometry: place the sites, build each cell's
    // per-(UE, site) coupling-loss cache, and mark which neighbor
    // pairs couple (same carrier frequency + numerology — they
    // interfere and are handover candidates).
    if let Some(topo) = &sc.topology {
        let sites: Vec<Position> =
            (0..sc.cells.len()).map(|k| topo.site_position(k)).collect();
        for (k, cm) in cells.iter().enumerate() {
            let coupled: Vec<bool> = sc
                .cells
                .iter()
                .enumerate()
                .map(|(j, other)| {
                    j != k
                        && other.carrier.freq_hz == sc.cells[k].carrier.freq_hz
                        && other.carrier.numerology == sc.cells[k].carrier.numerology
                })
                .collect();
            cm.lock().unwrap().init_geometry(
                k,
                &sites,
                coupled,
                cell_seed(sc.base.seed, k),
                sc.base.cell_r_max,
                sc.mobility.as_ref(),
            );
        }
    }

    // `cell_threads = 1` (the default) steps cells inline; `0` uses all
    // cores. More participants than cells would only idle.
    let participants = resolve_threads(sc.cell_threads).min(cells.len());
    if participants <= 1 {
        event_loop(sc, &cells, StepDriver::Serial, wall0)
    } else {
        match sc.cell_sync {
            CellSync::Barrier => {
                let pool = StepPool::new(&cells, participants);
                std::thread::scope(|scope| {
                    // An unwind out of the event loop (or out of a
                    // worker) would leave the other pool participants
                    // parked on a barrier with no panic path,
                    // deadlocking the scope join — the guard aborts
                    // instead so a bug surfaces as a crash.
                    let _guard = super::cells::AbortOnPanic;
                    for _ in 1..participants {
                        scope.spawn(|| pool.worker());
                    }
                    let result =
                        event_loop(sc, &cells, StepDriver::Barrier(&pool), wall0);
                    pool.shutdown();
                    result
                })
            }
            CellSync::Frontier => {
                let radio_coupling = sc.topology.is_some() && cells.len() > 1;
                let pool =
                    FrontierPool::new(&cells, sc.base.horizon + 2.0, radio_coupling);
                std::thread::scope(|scope| {
                    // A panicking participant poisons the frontier
                    // mutex; the other side's unwrap then panics too —
                    // abort so neither unwind strands the scope join.
                    let _guard = super::cells::AbortOnPanic;
                    for _ in 1..participants {
                        scope.spawn(|| pool.worker());
                    }
                    let result =
                        event_loop(sc, &cells, StepDriver::Frontier(&pool), wall0);
                    pool.shutdown();
                    result
                })
            }
        }
    }
}

fn event_loop(
    sc: &Scenario,
    cells: &[Mutex<CellRt>],
    driver: StepDriver<'_, '_>,
    wall0: std::time::Instant,
) -> ScenarioResult {
    let cfg = &sc.base;
    let n_classes = sc.classes.len();

    let discipline = discipline_of(&cfg.scheme);
    let mut nodes: Vec<NodeRt> = sc
        .nodes
        .iter()
        .map(|n| match n.execution {
            ExecutionModel::Sequential => {
                NodeRt::Seq(ComputeNode::new(discipline, n.n_servers))
            }
            ExecutionModel::ContinuousBatching { max_batch, kv_budget } => {
                NodeRt::Batch(BatchEngine::new(discipline, n.gpu, max_batch, kv_budget))
            }
        })
        .collect();
    let mut router = sc.make_router();
    let t_wireline = cfg.scheme.deployment.wireline_latency();

    let total_ues: usize = sc.cells.iter().map(|c| c.n_ues as usize).sum();
    let mut jobs: Vec<JobState> = Vec::with_capacity(4096);
    // Pre-size the calendar: priming schedules one arrival per
    // (cell, UE, class) plus one background event per UE, and at
    // steady state each sequential node holds up to `n_servers`
    // in-flight ComputeDone events while each batching node keeps one
    // pending BatchStep — account for those too, plus slack for
    // wireline-crossing enqueues, so large multi-node runs never
    // re-allocate right after priming. Slot clocks live outside the
    // calendar.
    let inflight: usize = sc
        .nodes
        .iter()
        .map(|n| match n.execution {
            ExecutionModel::Sequential => n.n_servers as usize,
            ExecutionModel::ContinuousBatching { .. } => 1,
        })
        .sum();
    let mut q: EventQueue<Ev> = EventQueue::with_kind(
        sc.event_queue,
        total_ues * (n_classes + 1) + inflight + 64,
    );

    // Handover bookkeeping: stable global UE ids (tags) and the
    // current (cell, local index) of every UE. Arrival events address
    // UEs by their *origin* identity — the RNG streams never move —
    // and are routed here to the UE's current serving cell.
    let radio_coupling = sc.topology.is_some() && cells.len() > 1;
    let handover_on = sc.handover.is_some() && radio_coupling;
    let prefix: Vec<usize> = {
        let mut acc = 0usize;
        let mut v = Vec::with_capacity(sc.cells.len());
        for c in &sc.cells {
            v.push(acc);
            acc += c.n_ues as usize;
        }
        v
    };
    let mut locs: Option<Vec<(u32, u32)>> = if handover_on {
        let mut v = Vec::with_capacity(total_ues);
        for (k, cm) in cells.iter().enumerate() {
            let mut c = cm.lock().unwrap();
            for i in 0..c.n_ues {
                c.bank.ue_mut(i).tag = v.len() as u64;
                v.push((k as u32, i as u32));
            }
        }
        Some(v)
    } else {
        None
    };
    // One-slot-lagged interference snapshot: `itf[k][j]` is cell k's
    // latest published per-PRB interference (mW) at site j. Updated
    // serially at the merge barrier, consumed serially before the next
    // batch — worker threads never touch it.
    let mut itf: Vec<Vec<f64>> = if radio_coupling {
        (0..cells.len()).map(|_| vec![0.0; cells.len()]).collect()
    } else {
        Vec::new()
    };
    let tick_s = sc
        .mobility
        .as_ref()
        .map(|m| m.tick_s)
        .unwrap_or(MobilitySpec::DEFAULT_TICK_S);
    let ttt_ticks: u32 = sc
        .handover
        .as_ref()
        .map(|h| ((h.ttt_s / tick_s).ceil() as u32).max(1))
        .unwrap_or(1);
    let mut pending_ho: Vec<(u64, usize, usize)> = Vec::new();
    // Reused per-enqueue routing snapshot + node-event buffers (keeps
    // the hot path allocation-free).
    let mut views: Vec<NodeView> = Vec::with_capacity(sc.nodes.len());
    let mut node_ev: Vec<NodeEvent> = Vec::with_capacity(16);
    let mut batch_ev: Vec<BatchEvent> = Vec::with_capacity(64);

    // Elastic control plane (None = static tier: no cluster events, no
    // cluster RNG draws, views built over every node — bit-identical
    // to the pre-cluster engine by construction).
    let mut cluster_rt: Option<ClusterRt> = sc.cluster.map(|spec| {
        ClusterRt::new(
            spec,
            sc.node_churn.clone(),
            sc.nodes.iter().map(|n| n.gpu).collect(),
            n_classes,
            cfg.seed,
        )
    });
    // Cluster scratch: eligible-node index map (router sees only `Up`
    // nodes; picks map back through this), per-node in-service job ids
    // (sequential nodes only), per-tick load snapshot, power-on list,
    // and eviction buffers.
    let mut eligible_ix: Vec<usize> = Vec::with_capacity(sc.nodes.len());
    let mut inflight_seq: Vec<Vec<u64>> = vec![Vec::new(); sc.nodes.len()];
    let mut node_loads: Vec<(usize, u32)> = Vec::with_capacity(sc.nodes.len());
    let mut power_on: Vec<usize> = Vec::with_capacity(sc.nodes.len());
    let mut evicted_ids: Vec<u64> = Vec::new();
    let mut seq_evicted: Vec<ComputeJob> = Vec::new();
    let mut batch_evicted: Vec<BatchJob> = Vec::new();

    // Background packet rate (constant across the run).
    let bg_rate = 1.0 / cfg.background.mean_interval();
    let bg_bytes = cfg.background.packet_bytes;

    // Prime arrival processes (per cell, same per-UE order as the
    // legacy engine). Time-varying classes prime at their t = 0 rate.
    for (k, cm) in cells.iter().enumerate() {
        let mut c = cm.lock().unwrap();
        for ue in 0..c.n_ues {
            for (ci, class) in sc.classes.iter().enumerate() {
                let gap = c.job_rng[ci][ue].exp(class.rate_at(0.0));
                q.schedule_at(
                    gap,
                    Ev::JobArrival { cell: k as u32, ue: ue as u32, class: ci as u32 },
                );
            }
            let gap = c.bg_rng[ue].exp(bg_rate);
            q.schedule_at(gap, Ev::BgArrival { cell: k as u32, ue: ue as u32 });
        }
    }

    // Prime the radio tick (mobility + handover) when geometry is on.
    if sc.topology.is_some() && (sc.mobility.is_some() || sc.handover.is_some()) {
        q.schedule_at(tick_s, Ev::RadioTick);
    }

    // Prime the control plane: one failure event per churning node
    // (infinite-MTBF nodes draw nothing) and the first control tick.
    if let Some(cl) = cluster_rt.as_mut() {
        for i in 0..cl.n_nodes() {
            if let Some(ttf) = cl.time_to_failure(i) {
                q.schedule_at(ttf, Ev::NodeFail { node: i, epoch: cl.epoch(i) });
            }
        }
        q.schedule_at(cl.spec().tick_s, Ev::ControlTick);
    }

    let drain_horizon = cfg.horizon + 2.0;
    let mut slot_events: u64 = 0;
    let mut t_slot = next_slot_time(cells);

    loop {
        let t_q = q.peek_time().unwrap_or(f64::INFINITY);
        if let StepDriver::Frontier(fp) = &driver {
            // Conservative mode: let the frontier advance every cell
            // strictly below the calendar head (events at the head pop
            // first — the serial tie rule), then merge the committed
            // step records in (slot-time, cell) order. The merge
            // reproduces the serial calendar-insertion sequence, so
            // downstream pops are bit-identical.
            fp.advance_to(t_q, &mut |rec: StepRec| {
                slot_events += 1;
                for &job_id in &rec.jobs {
                    let js = &mut jobs[job_id as usize];
                    js.t_comm = Some(rec.t_rx - js.t_gen);
                    q.schedule_at(rec.t_rx + t_wireline, Ev::ComputeEnqueue {
                        job: job_id,
                    });
                }
            });
            // Re-peek: the merge may have filed deliveries into an
            // otherwise-drained calendar (serial covers this via its
            // t_slot alternative) — the stale peek would end the run
            // with jobs still crossing the wireline.
            let t_q = q.peek_time().unwrap_or(f64::INFINITY);
            if !t_q.is_finite() || t_q > drain_horizon {
                break;
            }
            // fall through to the calendar pop below
        } else {
            // Calendar events drain before slot boundaries at the same
            // instant (matching the legacy tie order, where the
            // enqueue crossing the wireline landed before the chained
            // Slot event).
            let t_next = t_q.min(t_slot);
            if !t_next.is_finite() || t_next > drain_horizon {
                break;
            }
            if t_q > t_slot {
                batch_step(
                    &driver,
                    cells,
                    t_slot,
                    radio_coupling,
                    &mut itf,
                    &mut jobs,
                    &mut q,
                    t_wireline,
                    &mut slot_events,
                );
                t_slot = next_slot_time(cells);
                continue;
            }
        }
        let (now, ev) = q.pop().unwrap();
        match ev {
            Ev::JobArrival { cell, ue, class } => {
                if now < cfg.horizon {
                    let spec = &sc.classes[class as usize];
                    let ue_ix = ue as usize;
                    // Draws come from the ORIGIN cell's per-(class,
                    // UE) stream — handover moves the radio
                    // attachment, never the traffic streams, so
                    // trajectories stay decomposable per cell seed.
                    // The next gap draws at the *current* phase rate
                    // (piecewise-constant schedules hold their rate
                    // for many mean inter-arrival times, so re-arming
                    // at the rate in force is the standard
                    // discretization; a schedule-free class reduces to
                    // exactly the legacy draw).
                    let (n_input, gap) = {
                        let mut c = cells[cell as usize].lock().unwrap();
                        let r = &mut c.job_rng[class as usize][ue_ix];
                        (spec.input_tokens.sample(r), r.exp(spec.rate_at(now)))
                    };
                    let job_id = jobs.len() as u64;
                    jobs.push(JobState {
                        class: class as usize,
                        cell,
                        t_gen: now,
                        t_comm: None,
                        t_node_arrival: None,
                        t_service_start: None,
                        t_first_token: None,
                        t_done: None,
                        n_input,
                        n_output: 0,
                        prefill_time: 0.0,
                        decode_time: 0.0,
                        retries: 0,
                        fate: JobFate::InFlight,
                        measured: now >= cfg.warmup,
                    });
                    // The prompt bytes land in the UE's *current*
                    // serving cell's bank (identity under the legacy
                    // static configuration).
                    let (scell, sue) = match &locs {
                        Some(l) => {
                            let (c0, u0) = l[prefix[cell as usize] + ue_ix];
                            (c0 as usize, u0 as usize)
                        }
                        None => (cell as usize, ue_ix),
                    };
                    {
                        let mut c = cells[scell].lock().unwrap();
                        let arrival_slot = (now / c.slot_dur) as u64;
                        let (sr_period, sr_proc) = (c.sr_period, c.sr_proc);
                        c.bank.note_arrival(sue, arrival_slot, sr_period, sr_proc);
                        if c.job_priority {
                            // ICC job-aware prioritization: dedicated SR
                            // resource bypasses the shared cycle.
                            c.bank.note_job_arrival_expedited(sue, arrival_slot, sr_proc);
                        }
                        let bytes = spec.request_bytes(n_input);
                        c.bank.push_job_sdu(sue, Sdu {
                            kind: SduKind::Job { job_id },
                            total_bytes: bytes,
                            bytes_left: bytes,
                            t_arrival: now,
                        });
                    }
                    q.schedule_in(gap, Ev::JobArrival { cell, ue, class });
                }
            }
            Ev::BgArrival { cell, ue } => {
                if now < cfg.horizon {
                    let ue_ix = ue as usize;
                    let gap = {
                        let mut c = cells[cell as usize].lock().unwrap();
                        c.bg_rng[ue_ix].exp(bg_rate)
                    };
                    let (scell, sue) = match &locs {
                        Some(l) => {
                            let (c0, u0) = l[prefix[cell as usize] + ue_ix];
                            (c0 as usize, u0 as usize)
                        }
                        None => (cell as usize, ue_ix),
                    };
                    {
                        let mut c = cells[scell].lock().unwrap();
                        let arrival_slot = (now / c.slot_dur) as u64;
                        let (sr_period, sr_proc) = (c.sr_period, c.sr_proc);
                        c.bank.note_arrival(sue, arrival_slot, sr_period, sr_proc);
                        c.bank.push_bg_sdu(sue, Sdu {
                            kind: SduKind::Background,
                            total_bytes: bg_bytes,
                            bytes_left: bg_bytes,
                            t_arrival: now,
                        });
                    }
                    q.schedule_in(gap, Ev::BgArrival { cell, ue });
                }
            }
            Ev::RadioTick if now >= cfg.horizon => {
                // Radio dynamics end at the horizon: a post-horizon
                // migration could land a UE in a cell whose slot clock
                // already stopped (empty bank past the horizon),
                // stranding its backlog for the whole drain window.
                // Arrivals stop at the horizon too, so frozen
                // positions/attachments during the drain are exact.
            }
            Ev::RadioTick => {
                // Mobility first (positions + refreshed loss caches),
                // then A3 evaluation over the fresh RSRP ordering,
                // then the migrations — all serial on the engine
                // thread between slot batches, in cell-index order, so
                // the threaded schedule stays bit-identical to serial.
                if let Some(mob) = &sc.mobility {
                    for cm in cells {
                        cm.lock().unwrap().advance_mobility(mob, tick_s);
                    }
                }
                if let (Some(ho), Some(l)) = (&sc.handover, locs.as_mut()) {
                    pending_ho.clear();
                    for cm in cells {
                        cm.lock().unwrap().evaluate_handover(
                            ho.hysteresis_db,
                            ttt_ticks,
                            &mut pending_ho,
                        );
                    }
                    for &(tag, from, to) in &pending_ho {
                        let (ck, ci) = l[tag as usize];
                        debug_assert_eq!(ck as usize, from, "stale migration order");
                        let (ue, hot, gu, displaced) = {
                            let mut c = cells[from].lock().unwrap();
                            c.ho_out += 1;
                            c.take_ue(ci as usize)
                        };
                        if let Some(d) = displaced {
                            l[d as usize] = (from as u32, ci);
                        }
                        let mut t = cells[to].lock().unwrap();
                        t.ho_in += 1;
                        let ni = t.admit_ue(ue, hot, gu, ho.interruption_slots);
                        l[tag as usize] = (to as u32, ni as u32);
                    }
                }
                if now < cfg.horizon {
                    q.schedule_in(tick_s, Ev::RadioTick);
                }
            }
            Ev::ComputeEnqueue { job } => {
                let (cell_id, class_id, n_input, t_gen, t_comm, retry) = {
                    let js = &jobs[job as usize];
                    (
                        js.cell as usize,
                        js.class,
                        js.n_input,
                        js.t_gen,
                        js.t_comm.expect("enqueue before comm done"),
                        js.retries > 0,
                    )
                };
                let spec = &sc.classes[class_id];
                views.clear();
                let target = match &cluster_rt {
                    Some(cl) => {
                        // Routing sees only `Up` nodes; the pick maps
                        // back to a real tier index.
                        eligible_ix.clear();
                        for (i, (rt, s)) in
                            nodes.iter().zip(sc.nodes.iter()).enumerate()
                        {
                            if cl.eligible(i) {
                                eligible_ix.push(i);
                                views.push(rt.view(s));
                            }
                        }
                        if views.is_empty() {
                            // The whole tier is dark: park the job and
                            // retry on the control-tick cadence (this
                            // is not a re-dispatch — no budget spent).
                            q.schedule_in(
                                cl.spec().tick_s,
                                Ev::ComputeEnqueue { job },
                            );
                            continue;
                        }
                        let t = router.pick(class_id, cell_id, &views);
                        assert!(
                            t < views.len(),
                            "Routing::pick returned {t} for {} nodes",
                            views.len()
                        );
                        eligible_ix[t]
                    }
                    None => {
                        views.extend(
                            nodes.iter().zip(sc.nodes.iter()).map(|(rt, s)| rt.view(s)),
                        );
                        let t = router.pick(class_id, cell_id, &views);
                        // A routing bug must fail loudly: silently
                        // clamping would report single-node results as
                        // multi-node.
                        assert!(
                            t < nodes.len(),
                            "Routing::pick returned {t} for {} nodes",
                            nodes.len()
                        );
                        t
                    }
                };
                // Service realizations draw from the originating cell's
                // stream, in that cell's delivery order — so each cell
                // of an N-cell run matches an independent single-cell
                // run (DESIGN.md §9). A re-dispatched job reuses its
                // realized demand: rng_svc is consumed exactly once per
                // job, in first-delivery order, so node churn can never
                // shift any other job's draws (DESIGN.md §11).
                let demand = if retry {
                    let js = &jobs[job as usize];
                    ServiceDemand {
                        n_output: js.n_output,
                        prefill_time: js.prefill_time,
                        decode_time: js.decode_time,
                    }
                } else {
                    let mut c = cells[cell_id].lock().unwrap();
                    sc.service.realize(spec, n_input, &sc.nodes[target].gpu, &mut c.rng_svc)
                };
                {
                    let js = &mut jobs[job as usize];
                    js.n_output = demand.n_output;
                    js.prefill_time = demand.prefill_time;
                    js.decode_time = demand.decode_time;
                    js.t_node_arrival = Some(now);
                }
                let deadline = t_gen + spec.b_total;
                let epoch = cluster_rt.as_ref().map_or(0, |c| c.epoch(target));
                match &mut nodes[target] {
                    NodeRt::Seq(n) => {
                        let cj = ComputeJob {
                            job_id: job,
                            t_gen,
                            t_comm,
                            deadline,
                            service_time: demand.service_time(),
                        };
                        node_ev.clear();
                        n.enqueue(cj, now, &mut node_ev);
                        let track = cluster_rt.is_some();
                        apply_node_events(
                            target,
                            epoch,
                            &node_ev,
                            &mut jobs,
                            &mut q,
                            now,
                            track.then(|| &mut inflight_seq[target]),
                        );
                    }
                    NodeRt::Batch(e) => {
                        let bj = BatchJob {
                            job_id: job,
                            t_gen,
                            t_comm,
                            deadline,
                            n_input,
                            n_output: demand.n_output,
                            prefill_time: demand.prefill_time,
                            decode_time: demand.decode_time,
                            c_llm: spec.c_llm,
                            m_llm: spec.m_llm,
                            kv_bytes_per_token: spec.kv_bytes_per_token,
                        };
                        batch_ev.clear();
                        e.enqueue(bj, now, &mut batch_ev);
                        apply_batch_events(target, epoch, &batch_ev, &mut jobs, &mut q, now);
                        if let Some(cl) = cluster_rt.as_mut() {
                            observe_batch_completions(target, &batch_ev, &jobs, cl);
                        }
                    }
                }
            }
            Ev::ComputeDone { node, job, epoch } => {
                if cluster_rt.as_ref().map_or(false, |c| !c.event_live(node, epoch)) {
                    // the node failed mid-service; the job was already
                    // evicted and re-dispatched (or lost)
                    continue;
                }
                {
                    let js = &mut jobs[job as usize];
                    js.fate = JobFate::Completed;
                    js.t_done = Some(now);
                }
                if let Some(cl) = cluster_rt.as_mut() {
                    let js = &jobs[job as usize];
                    // sequential TTFT: service start + prefill + one
                    // decode step (the outcome-assembly formula)
                    let start = js.t_service_start.expect("done before start");
                    let tok = js.decode_time / js.n_output.max(1) as f64;
                    cl.observe_ttft(start - js.t_gen + js.prefill_time + tok);
                    cl.observe_completion(node, js.class, js.prefill_time + js.decode_time);
                    inflight_seq[node].retain(|&id| id != job);
                }
                let NodeRt::Seq(n) = &mut nodes[node] else {
                    unreachable!("ComputeDone scheduled for a batching node")
                };
                node_ev.clear();
                n.complete(now, &mut node_ev);
                let track = cluster_rt.is_some();
                apply_node_events(
                    node,
                    epoch,
                    &node_ev,
                    &mut jobs,
                    &mut q,
                    now,
                    track.then(|| &mut inflight_seq[node]),
                );
            }
            Ev::BatchStep { node, epoch } => {
                if cluster_rt.as_ref().map_or(false, |c| !c.event_live(node, epoch)) {
                    // the engine was evicted after this step was armed
                    continue;
                }
                let NodeRt::Batch(e) = &mut nodes[node] else {
                    unreachable!("BatchStep scheduled for a sequential node")
                };
                batch_ev.clear();
                e.step(now, &mut batch_ev);
                apply_batch_events(node, epoch, &batch_ev, &mut jobs, &mut q, now);
                if let Some(cl) = cluster_rt.as_mut() {
                    observe_batch_completions(node, &batch_ev, &jobs, cl);
                }
            }
            Ev::ControlTick => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("ControlTick scheduled without a cluster");
                node_loads.clear();
                node_loads.extend(nodes.iter().map(|rt| match rt {
                    NodeRt::Seq(n) => (n.queue_len(), n.busy_servers()),
                    NodeRt::Batch(e) => (e.queue_len(), e.batch_len() as u32),
                }));
                power_on.clear();
                cl.control_tick(now, &node_loads, &mut power_on);
                for &i in &power_on {
                    q.schedule_in(
                        sc.node_churn[i].spinup,
                        Ev::NodeUp { node: i, epoch: cl.epoch(i) },
                    );
                }
                if now < cfg.horizon {
                    q.schedule_in(cl.spec().tick_s, Ev::ControlTick);
                }
            }
            Ev::NodeFail { node, epoch } => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("NodeFail scheduled without a cluster");
                if !cl.event_live(node, epoch) {
                    // the node was drained to Down before its failure
                    // fired; the draw is already consumed, nothing dies
                    continue;
                }
                let repair_in = cl.on_fail(node, now);
                q.schedule_in(repair_in, Ev::NodeRepair { node });
                // Evict everything the node owned, in deterministic
                // order: in-service jobs first (start order for
                // sequential, job-id order inside the batch), then the
                // ready queue in discipline order.
                evicted_ids.clear();
                match &mut nodes[node] {
                    NodeRt::Seq(n) => {
                        evicted_ids.extend(inflight_seq[node].drain(..));
                        seq_evicted.clear();
                        n.evict(&mut seq_evicted);
                        evicted_ids.extend(seq_evicted.iter().map(|j| j.job_id));
                    }
                    NodeRt::Batch(e) => {
                        batch_evicted.clear();
                        e.evict(&mut batch_evicted);
                        evicted_ids.extend(batch_evicted.iter().map(|j| j.job_id));
                    }
                }
                let budget = cl.spec().retry_budget;
                for &job in &evicted_ids {
                    let js = &mut jobs[job as usize];
                    // service never happened; the re-dispatch (or the
                    // loss report) starts from a clean slate
                    js.t_service_start = None;
                    js.t_first_token = None;
                    if js.retries < budget {
                        js.retries += 1;
                        cl.observe_redispatch(node, js.class);
                        q.schedule_at(now, Ev::ComputeEnqueue { job });
                    } else {
                        js.fate = JobFate::Lost;
                        cl.observe_lost(node, js.class);
                    }
                }
            }
            Ev::NodeRepair { node } => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("NodeRepair scheduled without a cluster");
                let spin = cl.on_repair(node, now);
                q.schedule_in(spin, Ev::NodeUp { node, epoch: cl.epoch(node) });
            }
            Ev::NodeUp { node, epoch } => {
                let cl = cluster_rt
                    .as_mut()
                    .expect("NodeUp scheduled without a cluster");
                if cl.event_live(node, epoch) {
                    if let Some(ttf) = cl.on_up(node, now) {
                        q.schedule_in(ttf, Ev::NodeFail { node, epoch: cl.epoch(node) });
                    }
                }
            }
        }
    }

    // Assemble outcomes for measured jobs.
    let outcomes: Vec<JobOutcome> = jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| j.measured)
        .map(|(id, j)| {
            let roofline_service = j.prefill_time + j.decode_time;
            let (t_queue, t_service) = match (j.t_node_arrival, j.t_service_start) {
                (Some(a), Some(s)) => {
                    let svc = match j.t_done {
                        // batched decode stretches the executed service
                        // time; sequential keeps the exact roofline sum
                        Some(d) if j.t_first_token.is_some() => d - s,
                        _ => roofline_service,
                    };
                    (s - a, svc)
                }
                _ => (0.0, 0.0),
            };
            let tok = j.decode_time / j.n_output.max(1) as f64;
            let (ttft, tpot) = if j.fate == JobFate::Completed {
                match (j.t_first_token, j.t_done) {
                    (Some(f), Some(d)) => (
                        f - j.t_gen,
                        if j.n_output > 1 { (d - f) / (j.n_output - 1) as f64 } else { 0.0 },
                    ),
                    // sequential: first token lands one decode step
                    // after the prefill; decode is evenly paced
                    _ => (
                        j.t_comm.unwrap_or(0.0)
                            + t_wireline
                            + t_queue
                            + j.prefill_time
                            + tok,
                        if j.n_output > 1 { tok } else { 0.0 },
                    ),
                }
            } else {
                (0.0, 0.0)
            };
            JobOutcome {
                job_id: id as u64,
                class_id: j.class as u32,
                cell_id: j.cell,
                t_gen: j.t_gen,
                t_comm: j.t_comm.unwrap_or(0.0),
                t_wireline,
                t_queue,
                t_service,
                ttft,
                tpot,
                tokens: j.n_input + j.n_output,
                fate: j.fate,
            }
        })
        .collect();

    let class_policies: Vec<(String, LatencyManagement)> = sc
        .classes
        .iter()
        .map(|c| (c.name.clone(), management_of(&cfg.scheme, c.b_total)))
        .collect();
    let mut report =
        SimReport::from_outcomes_per_class(&outcomes, &class_policies, sc.cells.len());
    if sc.topology.is_some() {
        report.radio = cells
            .iter()
            .map(|cm| {
                let c = cm.lock().unwrap();
                CellRadioReport {
                    handovers_in: c.ho_in,
                    handovers_out: c.ho_out,
                    iot_db: c.iot_stats.clone(),
                }
            })
            .collect();
    }
    if let Some(cl) = cluster_rt.as_mut() {
        // Costs cover the whole simulated window including the drain
        // tail — a deterministic bound, unlike the last-event time.
        cl.finalize(drain_horizon);
        let names: Vec<String> = sc.classes.iter().map(|c| c.name.clone()).collect();
        report.cluster = cl.report(&names);
    }
    let wall = wall0.elapsed().as_secs_f64();
    ScenarioResult {
        outcomes,
        report,
        events: q.processed() + slot_events,
        speedup: if wall > 0.0 { cfg.horizon / wall } else { f64::INFINITY },
    }
}
