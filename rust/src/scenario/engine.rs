//! The scenario event loop: the Fig 5 pipeline generalized to N
//! workload classes and M compute nodes.
//!
//! ```text
//! UE job gen (per class) ──► RLC buffers ──► slot scheduler ──► gNB
//!      │                          ▲                              │
//!  background ────────────────────┘               wireline (RAN/MEC)
//!                                                                ▼
//!   per-class outcomes ◄── ServiceModel ◄── Routing ──► node 0..M
//!                                                 (Sequential server
//!                                                  or BatchEngine)
//! ```
//!
//! Stream discipline: every entity draws from its own substream of the
//! master seed from a disjoint id range (no aliasing up to the 1 M UE
//! config cap), the event-handler logic mirrors the legacy `Sls::run`
//! loop line for line, and `TokenDist::Fixed` consumes no randomness —
//! so single-class runs are exactly as deterministic and statistically
//! identical to the seed SLS. The execution models consume no
//! randomness either: a `Sequential` run is bit-for-bit the legacy
//! trajectory, and switching a node to `ContinuousBatching` only adds
//! `BatchStep` iteration-boundary events on that node's timeline.

use crate::compute::{
    BatchEngine, BatchEvent, BatchJob, ComputeJob, ComputeNode, Discipline, ExecutionModel,
    NodeEvent,
};
use crate::config::{Management, SchemeConfig};
use crate::dess::EventQueue;
use crate::mac::{drop_ues, Sdu, SduKind, SlotWorkspace, UeBank};
use crate::mac::UlScheduler;
use crate::metrics::{JobFate, JobOutcome, LatencyManagement, SimReport};
use crate::rng::Rng;

use super::routing::NodeView;
use super::{NodeSpec, Scenario};

/// Map a scheme to the node queue discipline.
pub fn discipline_of(scheme: &SchemeConfig) -> Discipline {
    if scheme.priority_scheme {
        Discipline::DeadlinePriority { drop_hopeless: true }
    } else {
        Discipline::Fifo
    }
}

/// Map a scheme to the satisfaction policy for one class budget.
pub fn management_of(scheme: &SchemeConfig, b_total: f64) -> LatencyManagement {
    match scheme.management {
        Management::Joint => LatencyManagement::Joint { b_total },
        Management::Disjoint { b_comm, b_comp } => {
            LatencyManagement::Disjoint { b_total, b_comm, b_comp }
        }
    }
}

/// Result of one scenario run.
#[derive(Debug)]
pub struct ScenarioResult {
    pub outcomes: Vec<JobOutcome>,
    /// Aggregate report with `per_class` populated.
    pub report: SimReport,
    /// Simulated events processed.
    pub events: u64,
    /// Simulated seconds per wall-clock second.
    pub speedup: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// MAC slot boundary.
    Slot,
    /// Job of `class` generated at UE `ue`.
    JobArrival { ue: usize, class: usize },
    /// Background packet at UE `ue`.
    BgArrival { ue: usize },
    /// Prompt fully received at gNB crossed the wireline.
    ComputeEnqueue { job: u64 },
    /// Sequential node `node` finished `job`.
    ComputeDone { node: usize, job: u64 },
    /// Iteration boundary of node `node`'s batch engine.
    BatchStep { node: usize },
}

#[derive(Debug, Clone, Copy)]
struct JobState {
    class: usize,
    t_gen: f64,
    /// Set when the last prompt byte reaches the gNB.
    t_comm: Option<f64>,
    t_node_arrival: Option<f64>,
    t_service_start: Option<f64>,
    /// First output token emitted (batching nodes; sequential nodes
    /// derive it from the roofline split).
    t_first_token: Option<f64>,
    t_done: Option<f64>,
    /// Realized prompt length (sampled at generation).
    n_input: u32,
    /// Realized output length (set when the service model prices it).
    n_output: u32,
    /// Realized prefill latency (set at node arrival).
    prefill_time: f64,
    /// Realized sequential decode latency (set at node arrival).
    decode_time: f64,
    fate: JobFate,
    measured: bool,
}

/// Per-node runtime: the legacy sequential server bank or the
/// continuous-batching engine.
enum NodeRt {
    Seq(ComputeNode),
    Batch(BatchEngine),
}

impl NodeRt {
    fn view(&self, spec: &NodeSpec) -> NodeView {
        match self {
            NodeRt::Seq(n) => NodeView {
                queue_len: n.queue_len(),
                busy_servers: n.busy_servers(),
                n_servers: spec.n_servers,
                gpu: spec.gpu,
            },
            NodeRt::Batch(e) => NodeView {
                queue_len: e.queue_len(),
                busy_servers: e.batch_len() as u32,
                n_servers: match spec.execution {
                    ExecutionModel::ContinuousBatching { max_batch, .. } => max_batch,
                    ExecutionModel::Sequential => spec.n_servers,
                },
                gpu: spec.gpu,
            },
        }
    }
}

/// Sequential node-event plumbing: schedule completions for started
/// jobs, mark drops.
fn apply_node_events(
    node: usize,
    events: &[NodeEvent],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    now: f64,
) {
    for ev in events {
        match *ev {
            NodeEvent::Started { job, completes_at } => {
                jobs[job.job_id as usize].t_service_start = Some(now);
                q.schedule_at(completes_at, Ev::ComputeDone { node, job: job.job_id });
            }
            NodeEvent::Dropped { job } => {
                jobs[job.job_id as usize].fate = JobFate::Dropped;
            }
        }
    }
}

/// Batch-engine plumbing: record admissions / token boundaries /
/// completions and schedule the next iteration step.
fn apply_batch_events(
    node: usize,
    events: &[BatchEvent],
    jobs: &mut [JobState],
    q: &mut EventQueue<Ev>,
    now: f64,
) {
    for ev in events {
        match *ev {
            BatchEvent::Admitted { job_id } => {
                jobs[job_id as usize].t_service_start = Some(now);
            }
            BatchEvent::FirstToken { job_id } => {
                jobs[job_id as usize].t_first_token = Some(now);
            }
            BatchEvent::Finished { job_id } => {
                let js = &mut jobs[job_id as usize];
                js.fate = JobFate::Completed;
                js.t_done = Some(now);
            }
            BatchEvent::Dropped { job_id } => {
                jobs[job_id as usize].fate = JobFate::Dropped;
            }
            BatchEvent::StepAt { at } => {
                q.schedule_at(at, Ev::BatchStep { node });
            }
        }
    }
}

pub(super) fn run(sc: &Scenario) -> ScenarioResult {
    let wall0 = std::time::Instant::now();
    let cfg = &sc.base;
    let master = cfg.seed;
    let slot_dur = cfg.carrier.slot_duration();
    let n_ues = cfg.n_ues as usize;
    let n_classes = sc.classes.len();
    assert!(n_classes > 0, "scenario needs at least one workload class");
    assert!(!sc.nodes.is_empty(), "scenario needs at least one compute node");

    let scheduler = UlScheduler::new(cfg.mac, cfg.carrier);
    let discipline = discipline_of(&cfg.scheme);
    let mut nodes: Vec<NodeRt> = sc
        .nodes
        .iter()
        .map(|n| match n.execution {
            ExecutionModel::Sequential => {
                NodeRt::Seq(ComputeNode::new(discipline, n.n_servers))
            }
            ExecutionModel::ContinuousBatching { max_batch, kv_budget } => {
                NodeRt::Batch(BatchEngine::new(discipline, n.gpu, max_batch, kv_budget))
            }
        })
        .collect();
    let mut router = sc.make_router();
    let t_wireline = cfg.scheme.deployment.wireline_latency();

    // Independent randomness per concern, with disjoint stream-id
    // ranges: per-(class, UE) job streams start at 0x1000_0000 and are
    // spaced 0x100_0000 per class (well above the 1 M UE config cap);
    // background streams live at 0x2000 + ue, far below them.
    let mut rng_drop = Rng::substream(master, 0xD0);
    let mut rng_mac = Rng::substream(master, 0xAC);
    let mut rng_svc = Rng::substream(master, 0x5E);
    let mut job_rng: Vec<Vec<Rng>> = (0..n_classes)
        .map(|c| {
            (0..n_ues)
                .map(|ue| {
                    Rng::substream(
                        master,
                        0x1000_0000 + 0x100_0000 * c as u64 + ue as u64,
                    )
                })
                .collect()
        })
        .collect();
    let mut ue_bg_rng: Vec<Rng> =
        (0..n_ues).map(|ue| Rng::substream(master, 0x2000 + ue as u64)).collect();

    // Drop UEs in the cell (staggered SR phases) behind the backlog
    // index — the slot scheduler iterates active UEs, not the
    // population.
    let mut bank = UeBank::new(drop_ues(&mut rng_drop, n_ues, cfg.cell_r_min, cfg.cell_r_max));

    let mut jobs: Vec<JobState> = Vec::with_capacity(4096);
    // Pre-size the calendar: priming schedules one arrival per
    // (UE, class) plus one background event per UE and the slot clock.
    let mut q: EventQueue<Ev> = EventQueue::with_capacity(n_ues * (n_classes + 1) + 8);
    // Reused per-slot grant workspace and per-enqueue routing snapshot
    // + node-event buffers (keeps the hot path allocation-free).
    let mut ws = SlotWorkspace::new();
    let mut views: Vec<NodeView> = Vec::with_capacity(sc.nodes.len());
    let mut node_ev: Vec<NodeEvent> = Vec::with_capacity(16);
    let mut batch_ev: Vec<BatchEvent> = Vec::with_capacity(64);

    // Background packet rate (constant across the run; the per-event
    // handler reuses this instead of recomputing the interval).
    let bg_rate = 1.0 / cfg.background.mean_interval();

    // Prime arrival processes + the slot clock.
    for ue in 0..n_ues {
        for (c, class) in sc.classes.iter().enumerate() {
            let gap = job_rng[c][ue].exp(class.rate_per_ue);
            q.schedule_at(gap, Ev::JobArrival { ue, class: c });
        }
        q.schedule_at(ue_bg_rng[ue].exp(bg_rate), Ev::BgArrival { ue });
    }
    q.schedule_at(slot_dur, Ev::Slot);

    let sr_period = cfg.mac.effective_sr_period(cfg.n_ues);
    let sr_proc = cfg.mac.grant_proc_slots;
    let bg_bytes = cfg.background.packet_bytes;
    let drain_horizon = cfg.horizon + 2.0;
    let mut slot_idx: u64 = 0;

    while let Some(t) = q.peek_time() {
        if t > drain_horizon {
            break;
        }
        let (now, ev) = q.pop().unwrap();
        match ev {
            Ev::JobArrival { ue, class } => {
                if now < cfg.horizon {
                    let spec = &sc.classes[class];
                    let n_input = spec.input_tokens.sample(&mut job_rng[class][ue]);
                    let job_id = jobs.len() as u64;
                    jobs.push(JobState {
                        class,
                        t_gen: now,
                        t_comm: None,
                        t_node_arrival: None,
                        t_service_start: None,
                        t_first_token: None,
                        t_done: None,
                        n_input,
                        n_output: 0,
                        prefill_time: 0.0,
                        decode_time: 0.0,
                        fate: JobFate::InFlight,
                        measured: now >= cfg.warmup,
                    });
                    let arrival_slot = (now / slot_dur) as u64;
                    bank.note_arrival(ue, arrival_slot, sr_period, sr_proc);
                    if cfg.mac.job_priority {
                        // ICC job-aware prioritization: dedicated SR
                        // resource bypasses the shared cycle.
                        bank.ue_mut(ue).note_job_arrival_expedited(arrival_slot, sr_proc);
                    }
                    let bytes = spec.request_bytes(n_input);
                    bank.push_job_sdu(ue, Sdu {
                        kind: SduKind::Job { job_id },
                        total_bytes: bytes,
                        bytes_left: bytes,
                        t_arrival: now,
                    });
                    let gap = job_rng[class][ue].exp(spec.rate_per_ue);
                    q.schedule_in(gap, Ev::JobArrival { ue, class });
                }
            }
            Ev::BgArrival { ue } => {
                if now < cfg.horizon {
                    let arrival_slot = (now / slot_dur) as u64;
                    bank.note_arrival(ue, arrival_slot, sr_period, sr_proc);
                    bank.push_bg_sdu(ue, Sdu {
                        kind: SduKind::Background,
                        total_bytes: bg_bytes,
                        bytes_left: bg_bytes,
                        t_arrival: now,
                    });
                    q.schedule_in(ue_bg_rng[ue].exp(bg_rate), Ev::BgArrival { ue });
                }
            }
            Ev::Slot => {
                scheduler.schedule_slot(slot_idx, &mut bank, &mut rng_mac, &mut ws);
                slot_idx += 1;
                // TBs land at the end of the slot. The flat delivered
                // buffer is already in grant order, so iterating it
                // preserves the per-grant enqueue order.
                let t_rx = now + slot_dur;
                for d in &ws.delivered {
                    if let SduKind::Job { job_id } = d.kind {
                        let js = &mut jobs[job_id as usize];
                        js.t_comm = Some(t_rx - js.t_gen);
                        q.schedule_at(
                            t_rx + t_wireline,
                            Ev::ComputeEnqueue { job: job_id },
                        );
                    }
                }
                // Keep the slot clock running while anything is active
                // (O(1): the bank tracks total backlog).
                let active = now < cfg.horizon || bank.has_backlog();
                if active {
                    q.schedule_in(slot_dur, Ev::Slot);
                }
            }
            Ev::ComputeEnqueue { job } => {
                let (class_id, n_input, t_gen, t_comm) = {
                    let js = &jobs[job as usize];
                    (js.class, js.n_input, js.t_gen, js.t_comm.expect("enqueue before comm done"))
                };
                let spec = &sc.classes[class_id];
                views.clear();
                views.extend(nodes.iter().zip(sc.nodes.iter()).map(|(rt, s)| rt.view(s)));
                let target = router.pick(class_id, &views);
                // A routing bug must fail loudly: silently clamping
                // would report single-node results as multi-node.
                assert!(
                    target < nodes.len(),
                    "Routing::pick returned {target} for {} nodes",
                    nodes.len()
                );
                let demand =
                    sc.service.realize(spec, n_input, &sc.nodes[target].gpu, &mut rng_svc);
                {
                    let js = &mut jobs[job as usize];
                    js.n_output = demand.n_output;
                    js.prefill_time = demand.prefill_time;
                    js.decode_time = demand.decode_time;
                    js.t_node_arrival = Some(now);
                }
                let deadline = t_gen + spec.b_total;
                match &mut nodes[target] {
                    NodeRt::Seq(n) => {
                        let cj = ComputeJob {
                            job_id: job,
                            t_gen,
                            t_comm,
                            deadline,
                            service_time: demand.service_time(),
                        };
                        node_ev.clear();
                        n.enqueue(cj, now, &mut node_ev);
                        apply_node_events(target, &node_ev, &mut jobs, &mut q, now);
                    }
                    NodeRt::Batch(e) => {
                        let bj = BatchJob {
                            job_id: job,
                            t_gen,
                            t_comm,
                            deadline,
                            n_input,
                            n_output: demand.n_output,
                            prefill_time: demand.prefill_time,
                            decode_time: demand.decode_time,
                            c_llm: spec.c_llm,
                            m_llm: spec.m_llm,
                            kv_bytes_per_token: spec.kv_bytes_per_token,
                        };
                        batch_ev.clear();
                        e.enqueue(bj, now, &mut batch_ev);
                        apply_batch_events(target, &batch_ev, &mut jobs, &mut q, now);
                    }
                }
            }
            Ev::ComputeDone { node, job } => {
                {
                    let js = &mut jobs[job as usize];
                    js.fate = JobFate::Completed;
                    js.t_done = Some(now);
                }
                let NodeRt::Seq(n) = &mut nodes[node] else {
                    unreachable!("ComputeDone scheduled for a batching node")
                };
                node_ev.clear();
                n.complete(now, &mut node_ev);
                apply_node_events(node, &node_ev, &mut jobs, &mut q, now);
            }
            Ev::BatchStep { node } => {
                let NodeRt::Batch(e) = &mut nodes[node] else {
                    unreachable!("BatchStep scheduled for a sequential node")
                };
                batch_ev.clear();
                e.step(now, &mut batch_ev);
                apply_batch_events(node, &batch_ev, &mut jobs, &mut q, now);
            }
        }
    }

    // Assemble outcomes for measured jobs.
    let outcomes: Vec<JobOutcome> = jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| j.measured)
        .map(|(id, j)| {
            let roofline_service = j.prefill_time + j.decode_time;
            let (t_queue, t_service) = match (j.t_node_arrival, j.t_service_start) {
                (Some(a), Some(s)) => {
                    let svc = match j.t_done {
                        // batched decode stretches the executed service
                        // time; sequential keeps the exact roofline sum
                        Some(d) if j.t_first_token.is_some() => d - s,
                        _ => roofline_service,
                    };
                    (s - a, svc)
                }
                _ => (0.0, 0.0),
            };
            let tok = j.decode_time / j.n_output.max(1) as f64;
            let (ttft, tpot) = if j.fate == JobFate::Completed {
                match (j.t_first_token, j.t_done) {
                    (Some(f), Some(d)) => (
                        f - j.t_gen,
                        if j.n_output > 1 { (d - f) / (j.n_output - 1) as f64 } else { 0.0 },
                    ),
                    // sequential: first token lands one decode step
                    // after the prefill; decode is evenly paced
                    _ => (
                        j.t_comm.unwrap_or(0.0)
                            + t_wireline
                            + t_queue
                            + j.prefill_time
                            + tok,
                        if j.n_output > 1 { tok } else { 0.0 },
                    ),
                }
            } else {
                (0.0, 0.0)
            };
            JobOutcome {
                job_id: id as u64,
                class_id: j.class as u32,
                t_gen: j.t_gen,
                t_comm: j.t_comm.unwrap_or(0.0),
                t_wireline,
                t_queue,
                t_service,
                ttft,
                tpot,
                tokens: j.n_input + j.n_output,
                fate: j.fate,
            }
        })
        .collect();

    let class_policies: Vec<(String, LatencyManagement)> = sc
        .classes
        .iter()
        .map(|c| (c.name.clone(), management_of(&cfg.scheme, c.b_total)))
        .collect();
    let report = SimReport::from_outcomes_per_class(&outcomes, &class_policies);
    let wall = wall0.elapsed().as_secs_f64();
    ScenarioResult {
        outcomes,
        report,
        events: q.processed(),
        speedup: if wall > 0.0 { cfg.horizon / wall } else { f64::INFINITY },
    }
}
