//! Multi-cell sharding: the per-cell radio runtime and the worker pool
//! that steps cells in parallel inside one scenario.
//!
//! A [`CellSpec`] describes one gNB: its UE population and its own
//! MAC/PHY configuration (numerology, SR dimensioning, scheduling
//! policy). At run time each cell becomes a [`CellRt`] owning its own
//! [`UeBank`], [`SlotWorkspace`], [`UlScheduler`] and RNG streams — no
//! radio state is shared between cells, which is what makes the slot
//! pipeline shardable across worker threads.
//!
//! Determinism (DESIGN.md §9): every cell draws from substreams of its
//! own *cell seed* ([`cell_seed`]), so cell `k` of an N-cell scenario
//! realizes exactly the trajectory of an independent single-cell
//! scenario seeded with `cell_seed(master, k)` — the property the
//! N-cell ≡ N-single-cell test pins. Cell 0 keeps the master seed
//! itself, so single-cell scenarios reproduce the legacy SLS streams
//! bit for bit.
//!
//! Threading (DESIGN.md §12): two interchangeable schedulers, both
//! bit-identical to a serial cell loop.
//!
//! * [`StepPool`] — the legacy slot-barrier pool: every slot, all due
//!   cells rendezvous twice on a barrier. Wall-clock is gated by the
//!   slowest cell per slot.
//! * [`FrontierPool`] — conservative parallel DES (the default for
//!   threaded runs): each cell advances asynchronously up to its
//!   coupling horizon. The one-slot-lagged interference snapshot gives
//!   every cell a lookahead of exactly one slot, so a cell may step
//!   boundary `t` once every coupled neighbor has published through
//!   `t - slot` (frontier ≥ `t`) and the calendar holds no event
//!   below `t`. Workers pull the least-advanced runnable cell; the
//!   engine merges the buffered step records in ascending
//!   `(slot-time, cell-index)` order — the serial batch order — so
//!   delivered SDUs enter the calendar in exactly the serial sequence.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Condvar, Mutex};

use crate::config::SimConfig;
use crate::mac::{
    drop_ues, MacConfig, RlcBuffer, Sdu, SduKind, SlotWorkspace, UeBank, UeHot, UeMac,
    UlScheduler,
};
use crate::phy::channel::{LargeScale, Position};
use crate::phy::geometry::{CellGeo, LinkState, UeGeo};
use crate::phy::link::{iot_db_from_linear, thermal_floor_prb_mw, tx_power_prb_dbm};
use crate::phy::mobility::MobilitySpec;
use crate::phy::numerology::{Carrier, Numerology};
use crate::rng::Rng;
use crate::util::stats::Welford;

/// One gNB of a multi-cell scenario: its UE population and its own
/// MAC/PHY configuration. The scheme still owns `mac.job_priority`
/// (synced at build time, exactly like `SimConfig::with_scheme`).
#[derive(Debug, Clone, Copy)]
pub struct CellSpec {
    /// UEs dropped in this cell.
    pub n_ues: u32,
    /// Per-cell MAC configuration (SR dimensioning scales with this
    /// cell's population, not the scenario total).
    pub mac: MacConfig,
    /// Per-cell carrier / numerology (cells may run different SCS; each
    /// keeps its own slot clock).
    pub carrier: Carrier,
}

impl CellSpec {
    /// A cell with the Table I MAC/PHY defaults.
    pub fn new(n_ues: u32) -> Self {
        assert!(n_ues >= 1, "a cell needs at least one UE");
        Self { n_ues, mac: MacConfig::default(), carrier: Carrier::table1() }
    }

    pub fn with_mac(mut self, mac: MacConfig) -> Self {
        self.mac = mac;
        self
    }

    pub fn with_carrier(mut self, carrier: Carrier) -> Self {
        self.carrier = carrier;
        self
    }

    /// Override the cell's NR numerology μ (re-derives the PRB count
    /// for the carrier bandwidth).
    pub fn with_numerology(mut self, mu: u8) -> Self {
        let num = Numerology::new(mu);
        self.carrier = Carrier {
            numerology: num,
            n_prb: Carrier::derive_n_prb(self.carrier.bandwidth_hz, num),
            ..self.carrier
        };
        self
    }
}

/// A3-style handover configuration: a UE migrates to a coupled
/// neighbor cell once the neighbor's coupling loss beats the serving
/// cell's by `hysteresis_db` for `ttt_s` seconds (evaluated on the
/// radio tick). The migration carries the UE's full MAC state —
/// buffers, HARQ counters, PF average — between the two `UeBank`s at a
/// cell-step boundary, and the UE pays `interruption_slots` before its
/// first grant in the new cell (RACH + path switch).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HandoverSpec {
    /// A3 hysteresis (dB) the neighbor must clear.
    pub hysteresis_db: f64,
    /// Time-to-trigger (seconds; rounded up to whole radio ticks).
    pub ttt_s: f64,
    /// Grant blackout in the target cell after the migration (slots).
    pub interruption_slots: u64,
}

impl Default for HandoverSpec {
    fn default() -> Self {
        // 3 dB / 160 ms — the common A3 operating point; 4 slots at
        // 60 kHz = 1 ms of interruption.
        Self { hysteresis_db: 3.0, ttt_s: 0.16, interruption_slots: 4 }
    }
}

/// Which scheduler drives threaded cell stepping. Both are
/// bit-identical to serial; they differ only in wall-clock scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CellSync {
    /// Conservative frontier scheduling (the default): cells advance
    /// asynchronously inside their coupling horizon, no per-slot
    /// rendezvous.
    #[default]
    Frontier,
    /// Legacy slot-barrier pool: all due cells rendezvous every slot.
    Barrier,
}

impl CellSync {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "frontier" => Some(Self::Frontier),
            "barrier" => Some(Self::Barrier),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Frontier => "frontier",
            Self::Barrier => "barrier",
        }
    }
}

/// The master seed of cell `k`'s RNG substreams. Cell 0 keeps the
/// scenario master seed, so single-cell runs reproduce the legacy
/// streams exactly; cell `k` of an N-cell scenario matches an
/// independent single-cell scenario seeded with `cell_seed(master, k)`.
pub fn cell_seed(master: u64, cell: usize) -> u64 {
    if cell == 0 {
        master
    } else {
        // Weyl-style spacing; Rng::substream mixes the result again, so
        // nearby cells decorrelate.
        master ^ (cell as u64).wrapping_mul(0xA076_1D64_78BD_642F)
    }
}

/// Runtime state of one cell: everything the slot pipeline mutates.
/// All fields are cell-private — a slot step never reads another cell —
/// which is the invariant that makes parallel stepping bit-identical to
/// a serial cell loop.
pub(crate) struct CellRt {
    pub(crate) scheduler: UlScheduler,
    pub(crate) bank: UeBank,
    pub(crate) ws: SlotWorkspace,
    /// Per-slot fading/HARQ draws of this cell.
    rng_mac: Rng,
    /// Per-job service realizations of this cell's jobs (consumed in
    /// this cell's delivery order, so it matches a single-cell run).
    pub(crate) rng_svc: Rng,
    /// `[class][local_ue]` arrival + token-length streams.
    pub(crate) job_rng: Vec<Vec<Rng>>,
    /// `[local_ue]` background-traffic streams.
    pub(crate) bg_rng: Vec<Rng>,
    pub(crate) slot_dur: f64,
    /// Absolute time of the next slot boundary (accumulated exactly as
    /// the legacy queue-driven slot chain accumulated it).
    pub(crate) next_slot: f64,
    /// `to_bits()` of the last boundary stepped (sentinel `u64::MAX`
    /// before the first step) — the engine's "stepped in this batch?"
    /// test during the merge pass.
    pub(crate) last_slot: u64,
    slot_idx: u64,
    /// False once the cell is past the horizon with empty buffers; the
    /// slot clock then stops for good (arrivals only occur before the
    /// horizon, so it can never need restarting).
    pub(crate) ticking: bool,
    pub(crate) sr_period: u64,
    pub(crate) sr_proc: u64,
    pub(crate) job_priority: bool,
    /// Drop-time population (RNG streams and SR dimensioning are sized
    /// to it; the bank's live population may drift under handover).
    pub(crate) n_ues: usize,
    horizon: f64,
    /// Geometry/coupling state (`None` = the legacy radio-independent
    /// configuration: fixed interference margin, static UEs).
    pub(crate) geo: Option<CellGeo>,
    /// Interference-over-thermal applied to this cell's next slot (dB).
    /// Without geometry this stays at the receiver's fixed margin, so
    /// the legacy path is bit-identical; with geometry the engine's
    /// snapshot barrier refreshes it from neighbor activity.
    pub(crate) iot_db: f64,
    /// Outgoing interference published by this cell's last slot:
    /// linear mW per PRB received at each site, from this cell's
    /// granted UEs. Written only during this cell's own (parallel)
    /// step; the engine gathers it serially at the merge barrier.
    pub(crate) itf_out: Vec<f64>,
    /// Thermal+noise-figure floor per PRB (mW) — the IoT reference.
    pub(crate) noise_floor_mw: f64,
    /// Per-slot IoT samples (geometry mode only).
    pub(crate) iot_stats: Welford,
    /// Handover counters (UEs migrated into / out of this cell).
    pub(crate) ho_in: u64,
    pub(crate) ho_out: u64,
    /// Fluid-tier background cell (DESIGN.md §15): no UEs, no slot
    /// clock. Its `itf_out` row holds the analytic mean-activity
    /// interference the engine's `FluidTick` refreshes; the slot
    /// pipeline never steps it.
    pub(crate) fluid: bool,
}

impl CellRt {
    pub(crate) fn new(
        idx: usize,
        spec: &CellSpec,
        cfg: &SimConfig,
        n_classes: usize,
    ) -> Self {
        let seed = cell_seed(cfg.seed, idx);
        let n_ues = spec.n_ues as usize;
        // Identical substream ids as the legacy single-cell engine,
        // rooted at the cell seed: per-(class, UE) job streams from
        // 0x1000_0000 spaced 0x100_0000 per class, background at
        // 0x2000 + ue, and the drop/MAC/service streams at their
        // historical ids.
        let mut rng_drop = Rng::substream(seed, 0xD0);
        let bank =
            UeBank::new(drop_ues(&mut rng_drop, n_ues, cfg.cell_r_min, cfg.cell_r_max));
        let job_rng: Vec<Vec<Rng>> = (0..n_classes)
            .map(|c| {
                (0..n_ues)
                    .map(|ue| {
                        Rng::substream(
                            seed,
                            0x1000_0000 + 0x100_0000 * c as u64 + ue as u64,
                        )
                    })
                    .collect()
            })
            .collect();
        let bg_rng: Vec<Rng> =
            (0..n_ues).map(|ue| Rng::substream(seed, 0x2000 + ue as u64)).collect();
        let slot_dur = spec.carrier.slot_duration();
        let scheduler = UlScheduler::new(spec.mac, spec.carrier);
        let iot_db = scheduler.rx.interference_margin_db;
        let noise_floor_mw = thermal_floor_prb_mw(&scheduler.carrier, &scheduler.rx);
        Self {
            scheduler,
            bank,
            ws: SlotWorkspace::new(),
            rng_mac: Rng::substream(seed, 0xAC),
            rng_svc: Rng::substream(seed, 0x5E),
            job_rng,
            bg_rng,
            slot_dur,
            // first boundary, exactly where the legacy engine primed
            // its Slot event
            next_slot: slot_dur,
            last_slot: u64::MAX,
            slot_idx: 0,
            ticking: true,
            sr_period: spec.mac.effective_sr_period(spec.n_ues),
            sr_proc: spec.mac.grant_proc_slots,
            job_priority: spec.mac.job_priority,
            n_ues,
            horizon: cfg.horizon,
            geo: None,
            iot_db,
            itf_out: Vec::new(),
            noise_floor_mw,
            iot_stats: Welford::new(),
            ho_in: 0,
            ho_out: 0,
            fluid: false,
        }
    }

    /// Switch this cell from the fixed-margin, radio-independent model
    /// to geometry-driven coupling: global UE positions around site
    /// `cell`, cached coupling losses toward every site, and a dynamic
    /// interference-over-thermal term (0 dB until neighbors transmit)
    /// in place of the fixed margin.
    pub(crate) fn init_geometry(
        &mut self,
        cell: usize,
        sites: &[Position],
        coupled: Vec<bool>,
        seed: u64,
        cell_r_max: f64,
        mobility: Option<&MobilitySpec>,
    ) {
        let serving: Vec<LargeScale> =
            (0..self.bank.len()).map(|i| self.bank.ue(i).link).collect();
        let geo = CellGeo::new(
            cell,
            sites.to_vec(),
            coupled,
            self.scheduler.carrier.freq_hz,
            seed,
            &serving,
            cell_r_max,
            mobility,
        );
        self.itf_out = vec![0.0; sites.len()];
        self.iot_db = 0.0;
        self.geo = Some(geo);
    }

    /// Advance every UE of this cell by one mobility tick and refresh
    /// the moved UEs' coupling-loss caches + serving-link state. With
    /// `spec.shadow_corr_m` set, each moved UE's per-link shadowing
    /// decorrelates Gudmundson-style over the tick's travel distance
    /// before the loss refresh (disabled = zero extra draws, so the
    /// default run is bit-identical to the uncorrelated model).
    /// Engine-serial (runs between slot batches).
    pub(crate) fn advance_mobility(&mut self, spec: &MobilitySpec, dt: f64) {
        let Some(geo) = self.geo.as_mut() else { return };
        let freq = self.scheduler.carrier.freq_hz;
        let CellGeo { cell, sites, area_center, area_radius, ues, .. } = geo;
        let site = sites[*cell];
        for (i, gu) in ues.iter_mut().enumerate() {
            let prev = gu.pos;
            if spec.model.advance(gu, *area_center, *area_radius, dt) {
                if let Some(d_corr) = spec.shadow_corr_m {
                    let (dx, dy) = (gu.pos.x - prev.x, gu.pos.y - prev.y);
                    gu.decorrelate_shadowing((dx * dx + dy * dy).sqrt(), d_corr);
                }
                gu.refresh_losses(sites, freq);
                let ue = self.bank.ue_mut(i);
                ue.link.pos = Position { x: gu.pos.x - site.x, y: gu.pos.y - site.y };
                if spec.shadow_corr_m.is_some() {
                    // keep the serving link the scheduler prices in
                    // lockstep with the decorrelated geometry cache
                    ue.link.shadow_db = gu.links[*cell].shadow_db;
                }
                self.bank.invalidate_link_cache(i);
            }
        }
    }

    /// A3 evaluation over this cell's UEs: push `(tag, from, to)`
    /// migration orders for every UE whose best coupled neighbor has
    /// beaten the serving cell by the hysteresis for `ttt_ticks`
    /// consecutive radio ticks. `target_ok[j]` gates cell `j` as a
    /// migration target — the engine masks out fluid-tier cells, which
    /// interfere but hold no per-UE state to migrate into (without a
    /// fluid tier the mask is all-true, so A3 is unchanged).
    /// Engine-serial.
    pub(crate) fn evaluate_handover(
        &mut self,
        hysteresis_db: f64,
        ttt_ticks: u32,
        target_ok: &[bool],
        out: &mut Vec<(u64, usize, usize)>,
    ) {
        let Some(geo) = self.geo.as_mut() else { return };
        let serving = geo.cell;
        for (i, gu) in geo.ues.iter_mut().enumerate() {
            let cl_s = gu.links[serving].cl_db;
            let (mut best, mut best_cl) = (usize::MAX, f64::INFINITY);
            for (j, &on) in geo.coupled.iter().enumerate() {
                if on && target_ok[j] && gu.links[j].cl_db < best_cl {
                    best_cl = gu.links[j].cl_db;
                    best = j;
                }
            }
            if best != usize::MAX && cl_s - best_cl > hysteresis_db {
                if gu.a3_target == best as u32 {
                    gu.a3_ticks = gu.a3_ticks.saturating_add(1);
                } else {
                    gu.a3_target = best as u32;
                    gu.a3_ticks = 1;
                }
                if gu.a3_ticks >= ttt_ticks {
                    out.push((self.bank.ue(i).tag, serving, best));
                    gu.a3_target = u32::MAX;
                    gu.a3_ticks = 0;
                }
            } else {
                gu.a3_target = u32::MAX;
                gu.a3_ticks = 0;
            }
        }
    }

    /// Remove local UE `i` (bank and geometry in lockstep — both
    /// swap-remove the same index). Returns the MAC state with its
    /// carried backlog, its hot-lane values, the geometry record, and
    /// the tag of the UE displaced into slot `i` (the caller re-maps
    /// its location).
    pub(crate) fn take_ue(&mut self, i: usize) -> (UeMac, UeHot, UeGeo, Option<u64>) {
        let geo = self.geo.as_mut().expect("handover requires geometry");
        let gu = geo.ues.swap_remove(i);
        let (ue, hot) = self.bank.take_ue(i);
        let displaced =
            if i < self.bank.len() { Some(self.bank.ue(i).tag) } else { None };
        (ue, hot, gu, displaced)
    }

    /// Admit a migrating UE: re-express its serving link relative to
    /// this cell's site (LOS/shadowing from the cached per-link
    /// state), apply the handover interruption, and append it to the
    /// bank + geometry. Returns the new local index.
    pub(crate) fn admit_ue(
        &mut self,
        mut ue: UeMac,
        hot: UeHot,
        mut gu: UeGeo,
        interruption_slots: u64,
    ) -> usize {
        let geo = self.geo.as_mut().expect("handover requires geometry");
        let site = geo.sites[geo.cell];
        let link = &gu.links[geo.cell];
        ue.link = LargeScale {
            pos: Position { x: gu.pos.x - site.x, y: gu.pos.y - site.y },
            los: link.los,
            shadow_db: link.shadow_db,
        };
        gu.a3_target = u32::MAX;
        gu.a3_ticks = 0;
        geo.ues.push(gu);
        let i = self.bank.push_ue(ue, hot);
        self.bank.handover_interrupt(i, self.slot_idx, interruption_slots);
        i
    }

    /// Is this cell's next slot boundary the batch time `t_bits`?
    #[inline]
    pub(crate) fn due(&self, t_bits: u64) -> bool {
        self.ticking && self.next_slot.to_bits() == t_bits
    }

    /// Step the slot due at `self.next_slot`. Touches only this cell's
    /// state; the caller merges `ws.delivered` afterwards (grants and
    /// delivered SDUs stay valid until the next step). In geometry
    /// mode the step also publishes this slot's outgoing interference
    /// into `itf_out` — still cell-private, gathered serially by the
    /// engine at the merge barrier, consumed by neighbors one slot
    /// later (the one-slot-lagged snapshot that keeps parallel cell
    /// steps bit-identical to serial).
    pub(crate) fn step_slot(&mut self) {
        let now = self.next_slot;
        self.scheduler.schedule_slot_iot(
            self.slot_idx,
            &mut self.bank,
            &mut self.rng_mac,
            &mut self.ws,
            self.iot_db,
        );
        if let Some(geo) = &self.geo {
            self.iot_stats.push(self.iot_db);
            for v in &mut self.itf_out {
                *v = 0.0;
            }
            let pc = &self.scheduler.pc;
            let n_prb_tot = self.scheduler.carrier.n_prb as f64;
            for g in &self.ws.grants {
                let ug = &geo.ues[g.ue];
                // open-loop tx power of the actual grant, per PRB
                let p_prb_dbm = tx_power_prb_dbm(ug.links[geo.cell].cl_db, pc, g.n_prb);
                // reuse-1: a neighbor PRB collides with probability
                // n_prb / n_prb_total → scale the per-PRB interference
                let frac = g.n_prb as f64 / n_prb_tot;
                for (j, &on) in geo.coupled.iter().enumerate() {
                    if on {
                        self.itf_out[j] +=
                            10f64.powf((p_prb_dbm - ug.links[j].cl_db) / 10.0) * frac;
                    }
                }
            }
        }
        self.slot_idx += 1;
        self.last_slot = now.to_bits();
        // Same liveness rule as the legacy slot chain: keep ticking
        // while within the horizon or anything is still buffered.
        self.ticking = now < self.horizon || self.bank.has_backlog();
        self.next_slot = now + self.slot_dur;
    }

    /// Capture this cell's complete dynamic state (DESIGN.md §13).
    /// Everything config-derived — scheduler tables, workspace, SR
    /// dimensioning, site/coupling layout — is *not* captured: restore
    /// rebuilds it through [`CellRt::new`] / [`CellRt::init_geometry`]
    /// and then overwrites only the state below. `last_slot` is
    /// normalized to its sentinel: snapshots are taken at quiescence
    /// points where the merge pass has already consumed it, so the
    /// canonical bytes are thread-count and driver independent.
    pub(crate) fn snapshot_state(&self) -> CellRtState {
        let ues = (0..self.bank.len())
            .map(|i| {
                let ue = self.bank.ue(i);
                let (harq_attempt, last_served_slot) = ue.snapshot_state();
                UeSnap {
                    link: ue.link,
                    tag: ue.tag,
                    job_sdus: ue.job_buf.sdus().copied().collect(),
                    bg_sdus: ue.bg_buf.sdus().copied().collect(),
                    harq_attempt,
                    sr_phase: ue.sr_phase,
                    last_served_slot,
                    hot: self.bank.hot(i),
                }
            })
            .collect();
        let geo_ues = self.geo.as_ref().map(|g| {
            g.ues
                .iter()
                .map(|gu| UeGeoSnap {
                    pos: (gu.pos.x, gu.pos.y),
                    links: gu
                        .links
                        .iter()
                        .map(|l| (l.los, l.shadow_db, l.cl_db))
                        .collect(),
                    speed: gu.speed,
                    heading: gu.heading,
                    waypoint: (gu.waypoint.x, gu.waypoint.y),
                    rng: gu.rng.snapshot_state(),
                    a3_target: gu.a3_target,
                    a3_ticks: gu.a3_ticks,
                })
                .collect()
        });
        CellRtState {
            ues,
            rng_mac: self.rng_mac.snapshot_state(),
            rng_svc: self.rng_svc.snapshot_state(),
            job_rng: self
                .job_rng
                .iter()
                .map(|cs| cs.iter().map(|r| r.snapshot_state()).collect())
                .collect(),
            bg_rng: self.bg_rng.iter().map(|r| r.snapshot_state()).collect(),
            next_slot: self.next_slot,
            slot_idx: self.slot_idx,
            ticking: self.ticking,
            iot_db: self.iot_db,
            itf_out: self.itf_out.clone(),
            iot_stats: self.iot_stats.raw(),
            ho_in: self.ho_in,
            ho_out: self.ho_out,
            geo_ues,
        }
    }

    /// Overwrite this cell's dynamic state from a snapshot. The cell
    /// must have been freshly built by [`CellRt::new`] (plus
    /// [`CellRt::init_geometry`] when `st.geo_ues` is present) from
    /// the *same* configuration — the config fingerprint check in
    /// `snapshot::Snapshot` guards this.
    pub(crate) fn restore_state(&mut self, st: CellRtState) {
        assert_eq!(
            st.job_rng.len(),
            self.job_rng.len(),
            "snapshot class count mismatch"
        );
        let ues: Vec<UeMac> = st
            .ues
            .iter()
            .map(|u| {
                UeMac::from_snapshot(
                    u.link,
                    u.tag,
                    RlcBuffer::from_sdus(u.job_sdus.clone()),
                    RlcBuffer::from_sdus(u.bg_sdus.clone()),
                    u.harq_attempt,
                    u.sr_phase,
                    u.last_served_slot,
                )
            })
            .collect();
        self.bank = UeBank::new(ues);
        for (i, u) in st.ues.iter().enumerate() {
            self.bank.set_hot(i, u.hot);
        }
        self.rng_mac = Rng::from_state(st.rng_mac.0, st.rng_mac.1);
        self.rng_svc = Rng::from_state(st.rng_svc.0, st.rng_svc.1);
        for (dst, src) in self.job_rng.iter_mut().zip(&st.job_rng) {
            assert_eq!(dst.len(), src.len(), "snapshot UE-stream count mismatch");
            for (d, s) in dst.iter_mut().zip(src) {
                *d = Rng::from_state(s.0, s.1);
            }
        }
        assert_eq!(self.bg_rng.len(), st.bg_rng.len());
        for (d, s) in self.bg_rng.iter_mut().zip(&st.bg_rng) {
            *d = Rng::from_state(s.0, s.1);
        }
        self.next_slot = st.next_slot;
        self.last_slot = u64::MAX;
        self.slot_idx = st.slot_idx;
        self.ticking = st.ticking;
        self.iot_db = st.iot_db;
        self.itf_out = st.itf_out;
        self.iot_stats = Welford::from_raw(
            st.iot_stats.0,
            st.iot_stats.1,
            st.iot_stats.2,
            st.iot_stats.3,
            st.iot_stats.4,
        );
        self.ho_in = st.ho_in;
        self.ho_out = st.ho_out;
        match (self.geo.as_mut(), st.geo_ues) {
            (Some(geo), Some(gus)) => {
                geo.ues = gus
                    .into_iter()
                    .map(|gu| UeGeo {
                        pos: Position { x: gu.pos.0, y: gu.pos.1 },
                        links: gu
                            .links
                            .into_iter()
                            .map(|(los, shadow_db, cl_db)| LinkState {
                                los,
                                shadow_db,
                                cl_db,
                            })
                            .collect(),
                        speed: gu.speed,
                        heading: gu.heading,
                        waypoint: Position { x: gu.waypoint.0, y: gu.waypoint.1 },
                        rng: Rng::from_state(gu.rng.0, gu.rng.1),
                        a3_target: gu.a3_target,
                        a3_ticks: gu.a3_ticks,
                    })
                    .collect();
                assert_eq!(
                    geo.ues.len(),
                    self.bank.len(),
                    "geometry records must stay index-parallel to the bank"
                );
            }
            (None, None) => {}
            _ => panic!("snapshot geometry mode disagrees with the configuration"),
        }
    }
}

/// Dynamic MAC + hot-lane state of one UE, as captured by a snapshot.
#[derive(Debug, Clone)]
pub(crate) struct UeSnap {
    pub(crate) link: LargeScale,
    pub(crate) tag: u64,
    pub(crate) job_sdus: Vec<Sdu>,
    pub(crate) bg_sdus: Vec<Sdu>,
    pub(crate) harq_attempt: u8,
    pub(crate) sr_phase: u64,
    pub(crate) last_served_slot: u64,
    pub(crate) hot: UeHot,
}

/// Dynamic geometry/mobility state of one UE (`links` rows are
/// `(los, shadow_db, cl_db)`).
#[derive(Debug, Clone)]
pub(crate) struct UeGeoSnap {
    pub(crate) pos: (f64, f64),
    pub(crate) links: Vec<(bool, f64, f64)>,
    pub(crate) speed: f64,
    pub(crate) heading: (f64, f64),
    pub(crate) waypoint: (f64, f64),
    pub(crate) rng: ([u64; 4], Option<f64>),
    pub(crate) a3_target: u32,
    pub(crate) a3_ticks: u32,
}

/// Complete dynamic state of one [`CellRt`]: the UE bank (with hot
/// lanes), every RNG stream position, the slot clock, and the
/// interference/handover bookkeeping.
#[derive(Debug, Clone)]
pub(crate) struct CellRtState {
    pub(crate) ues: Vec<UeSnap>,
    pub(crate) rng_mac: ([u64; 4], Option<f64>),
    pub(crate) rng_svc: ([u64; 4], Option<f64>),
    pub(crate) job_rng: Vec<Vec<([u64; 4], Option<f64>)>>,
    pub(crate) bg_rng: Vec<([u64; 4], Option<f64>)>,
    pub(crate) next_slot: f64,
    pub(crate) slot_idx: u64,
    pub(crate) ticking: bool,
    pub(crate) iot_db: f64,
    pub(crate) itf_out: Vec<f64>,
    pub(crate) iot_stats: (u64, f64, f64, f64, f64),
    pub(crate) ho_in: u64,
    pub(crate) ho_out: u64,
    pub(crate) geo_ues: Option<Vec<UeGeoSnap>>,
}

/// Unwinding past a barrier rendezvous would strand the other
/// participants forever (`std::sync::Barrier` has no poisoning), so a
/// panic on any pool participant — a worker inside `step_slot`, or the
/// engine thread mid-batch — must abort the process instead of
/// deadlocking the scope join. Instantiate one per participant; its
/// `Drop` turns an unwind into a loud crash and is a no-op otherwise.
pub(crate) struct AbortOnPanic;

impl Drop for AbortOnPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "cell-step pool participant panicked — aborting to avoid a \
                 barrier deadlock (see the panic message above)"
            );
            std::process::abort();
        }
    }
}

/// Persistent slot-batch worker pool: `participants - 1` scoped worker
/// threads plus the coordinating engine thread rendezvous on one
/// barrier per batch phase. Workers claim cell indices from an atomic
/// cursor and step the cells due at the batch time; between batches
/// they park on the barrier, so the engine thread has exclusive cell
/// access for arrivals and merging.
pub(crate) struct StepPool<'a> {
    cells: &'a [Mutex<CellRt>],
    cursor: AtomicUsize,
    /// `f64::to_bits` of the batch's slot time.
    t_batch: AtomicU64,
    barrier: Barrier,
    stop: AtomicBool,
}

impl<'a> StepPool<'a> {
    /// `participants` counts the engine thread; spawn
    /// `participants - 1` workers running [`StepPool::worker`].
    pub(crate) fn new(cells: &'a [Mutex<CellRt>], participants: usize) -> Self {
        assert!(participants >= 2, "a pool needs at least one worker");
        Self {
            cells,
            cursor: AtomicUsize::new(0),
            t_batch: AtomicU64::new(0),
            barrier: Barrier::new(participants),
            stop: AtomicBool::new(false),
        }
    }

    /// Worker loop: park, step due cells, park again.
    pub(crate) fn worker(&self) {
        let _guard = AbortOnPanic;
        loop {
            self.barrier.wait();
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            self.drain();
            self.barrier.wait();
        }
    }

    fn drain(&self) {
        let t = self.t_batch.load(Ordering::Acquire);
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.cells.len() {
                break;
            }
            let mut cell = self.cells[i].lock().unwrap();
            if cell.due(t) {
                cell.step_slot();
            }
        }
    }

    /// Coordinator side: step every cell due at `t`, using the parked
    /// workers plus the calling thread. Returns once all cells are
    /// stepped (the caller may then merge without synchronization).
    pub(crate) fn step_batch(&self, t: f64) {
        self.t_batch.store(t.to_bits(), Ordering::Release);
        self.cursor.store(0, Ordering::Release);
        self.barrier.wait();
        self.drain();
        self.barrier.wait();
    }

    /// Release the workers to exit (call once, after the event loop).
    pub(crate) fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        self.barrier.wait();
    }
}

/// How the engine drives cell slot steps (resolved from
/// `cell_threads` + [`CellSync`] at run time).
pub(crate) enum StepDriver<'p, 'a> {
    /// Inline on the engine thread, in cell-index order.
    Serial,
    /// Legacy slot-barrier pool.
    Barrier(&'p StepPool<'a>),
    /// Conservative frontier scheduler.
    Frontier(&'p FrontierPool<'a>),
}

/// One committed cell step, buffered until the engine merges it.
/// Records merge in ascending `(t_bits, cell)` — exactly the order a
/// serial engine would have produced the same slot batches in.
pub(crate) struct StepRec {
    /// `to_bits()` of the stepped slot boundary (positive finite, so
    /// integer order == numeric order).
    t_bits: u64,
    cell: u32,
    /// End of the stepped slot — when the delivered TBs land.
    pub(crate) t_rx: f64,
    /// Delivered job SDU ids, in grant order.
    pub(crate) jobs: Vec<u64>,
}

/// A cell's published per-slot interference row, versioned by the slot
/// boundary it was produced at.
struct PubRow {
    t_bits: u64,
    row: Vec<f64>,
}

struct FrontierInner {
    /// Next unstepped slot boundary per cell (`f64::INFINITY` once the
    /// cell's clock stops). Advances only at step *commit*, so an
    /// in-flight neighbor never looks further along than it is.
    frontier: Vec<f64>,
    claimed: Vec<bool>,
    /// Exclusive upper bound on steppable boundaries: the calendar
    /// head. A boundary at the head time must wait for the event (the
    /// serial tie rule pops calendar events before slot batches).
    bound: f64,
    /// Committed, unmerged step records.
    records: Vec<StepRec>,
    /// Two-deep publication history per cell (coupling mode only).
    /// Coupled neighbors stay within one slot of each other, so the
    /// previous row is always still available when a neighbor needs
    /// the lagged snapshot.
    pubs: Vec<[PubRow; 2]>,
    /// Claimed-but-uncommitted steps.
    inflight: usize,
    stop: bool,
}

/// Conservative parallel-DES scheduler (DESIGN.md §12). Safe-step
/// rule: cell `c` may step boundary `t` iff
///
/// 1. `t < bound` — every calendar event below `t` has been handled
///    (events at `t` exactly pop first, matching the serial tie rule);
/// 2. `t <= limit` — the drain horizon, after which serial never
///    steps a boundary;
/// 3. every coupled neighbor's frontier is `>= t` — its interference
///    publication for `t - slot` is final (lookahead = one slot of
///    the lagged snapshot).
///
/// Workers claim the least `(boundary, cell-index)` eligible cell, so
/// the least-advanced cell is always served first and the frontier
/// advances as a wave. The engine merges committed records in
/// `(t_bits, cell)` order at each quiescence point, reproducing the
/// serial calendar-insertion sequence bit for bit.
pub(crate) struct FrontierPool<'a> {
    cells: &'a [Mutex<CellRt>],
    /// Ascending coupled-neighbor indices per cell (empty without
    /// radio coupling). Uncoupled cells publish structurally-zero
    /// interference toward each other, so summing only coupled rows
    /// (ascending, like the serial snapshot loop) is bit-identical.
    coupled: Vec<Vec<u32>>,
    /// Inclusive drain horizon for slot boundaries.
    limit: f64,
    coupling: bool,
    inner: Mutex<FrontierInner>,
    /// Signals workers: bound advanced / a commit may have unblocked a
    /// neighbor / shutdown.
    work: Condvar,
    /// Signals the engine: a commit happened (quiescence re-check).
    idle: Condvar,
}

impl<'a> FrontierPool<'a> {
    pub(crate) fn new(cells: &'a [Mutex<CellRt>], limit: f64, coupling: bool) -> Self {
        let n = cells.len();
        let mut frontier = Vec::with_capacity(n);
        let mut coupled = Vec::with_capacity(n);
        let mut pubs = Vec::with_capacity(if coupling { n } else { 0 });
        for cm in cells {
            let c = cm.lock().unwrap();
            frontier.push(if c.ticking { c.next_slot } else { f64::INFINITY });
            coupled.push(match (&c.geo, coupling) {
                (Some(g), true) => g
                    .coupled
                    .iter()
                    .enumerate()
                    .filter_map(|(j, &on)| on.then_some(j as u32))
                    .collect(),
                _ => Vec::new(),
            });
            if coupling {
                // Sentinel publications at t = 0.0 (below every
                // boundary), seeded with the cell's current outgoing
                // row — all-zero on a fresh run (matching the serial
                // snapshot's all-zero start), and the last published
                // row when the pool is recreated mid-run by a
                // `run_to` segment, so a resumed frontier run prices
                // exactly the interference the serial merge would.
                // Fluid cells never step but always radiate: their
                // analytic row rides in `itf_out` (both generations
                // carry it, so the lag rule picks it regardless of the
                // neighbor's boundary).
                let row = if (c.ticking || c.fluid) && !c.itf_out.is_empty() {
                    c.itf_out.clone()
                } else {
                    vec![0.0; n]
                };
                pubs.push([PubRow { t_bits: 0, row: vec![0.0; n] }, PubRow {
                    t_bits: 0,
                    row,
                }]);
            }
        }
        Self {
            cells,
            coupled,
            limit,
            coupling,
            inner: Mutex::new(FrontierInner {
                frontier,
                claimed: vec![false; n],
                bound: f64::NEG_INFINITY,
                records: Vec::new(),
                pubs,
                inflight: 0,
                stop: false,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        }
    }

    /// Claim the least `(boundary, cell)` eligible cell and price its
    /// incoming interference from the neighbors' publications. Returns
    /// `(cell, boundary, i_mw)`.
    fn try_claim(&self, inner: &mut FrontierInner) -> Option<(usize, f64, f64)> {
        let mut best: Option<(u64, usize)> = None;
        'cells: for k in 0..inner.frontier.len() {
            if inner.claimed[k] {
                continue;
            }
            let t = inner.frontier[k];
            if !(t < inner.bound) || t > self.limit {
                continue;
            }
            let tb = t.to_bits();
            if let Some(b) = best {
                if (tb, k) >= b {
                    continue;
                }
            }
            for &j in &self.coupled[k] {
                if inner.frontier[j as usize] < t {
                    continue 'cells;
                }
            }
            best = Some((tb, k));
        }
        let (tb, k) = best?;
        let t = f64::from_bits(tb);
        let mut i_mw = 0.0;
        if self.coupling {
            for &j in &self.coupled[k] {
                let p = &inner.pubs[j as usize];
                // newest pub strictly before `t` (p[1] is newest; the
                // one-slot skew bound guarantees p[0] qualifies when
                // p[1] is at `t` itself)
                let row = if p[1].t_bits < tb { &p[1].row } else { &p[0].row };
                i_mw += row[k];
            }
        }
        inner.claimed[k] = true;
        inner.inflight += 1;
        Some((k, t, i_mw))
    }

    /// Step the claimed cell (outside the frontier lock; only the
    /// cell's own mutex is held).
    fn exec_step(&self, k: usize, t: f64, i_mw: f64) -> (StepRec, f64, Option<Vec<f64>>) {
        let mut c = self.cells[k].lock().unwrap();
        debug_assert!(c.due(t.to_bits()), "frontier claimed a non-due cell");
        if self.coupling {
            c.iot_db = iot_db_from_linear(i_mw, c.noise_floor_mw);
        }
        c.step_slot();
        // The merge happens record-side; reset the batch marker here
        // so `due` stays well-defined for the next boundary.
        c.last_slot = u64::MAX;
        let jobs: Vec<u64> = c
            .ws
            .delivered
            .iter()
            .filter_map(|d| match d.kind {
                SduKind::Job { job_id } => Some(job_id),
                SduKind::Background => None,
            })
            .collect();
        let rec = StepRec { t_bits: t.to_bits(), cell: k as u32, t_rx: t + c.slot_dur, jobs };
        let frontier = if c.ticking { c.next_slot } else { f64::INFINITY };
        let publ = self.coupling.then(|| {
            if c.ticking {
                c.itf_out.clone()
            } else {
                // a stopped cell transmits nothing more — same zeroing
                // the serial merge applies to its snapshot row
                vec![0.0; c.itf_out.len()]
            }
        });
        (rec, frontier, publ)
    }

    fn commit(
        &self,
        inner: &mut FrontierInner,
        k: usize,
        (rec, frontier, publ): (StepRec, f64, Option<Vec<f64>>),
    ) {
        inner.frontier[k] = frontier;
        if let Some(row) = publ {
            let p = &mut inner.pubs[k];
            p.swap(0, 1);
            p[1] = PubRow { t_bits: rec.t_bits, row };
        }
        inner.records.push(rec);
        inner.claimed[k] = false;
        inner.inflight -= 1;
        self.work.notify_all();
        self.idle.notify_one();
    }

    /// Worker loop: claim → step → commit, parking when nothing is
    /// eligible under the current bound.
    pub(crate) fn worker(&self) {
        let _guard = AbortOnPanic;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.stop {
                return;
            }
            if let Some((k, t, i_mw)) = self.try_claim(&mut inner) {
                drop(inner);
                let out = self.exec_step(k, t, i_mw);
                inner = self.inner.lock().unwrap();
                self.commit(&mut inner, k, out);
            } else {
                inner = self.work.wait(inner).unwrap();
            }
        }
    }

    /// Raise the steppable bound (monotone; lowering is a no-op) and
    /// wake the workers. Under the bounded-lag merge rule (DESIGN.md
    /// §12) the engine raises the bound to the earliest *cell-writing*
    /// calendar event — not the calendar head — so workers keep
    /// stepping boundaries in `[head, bound)` while the engine handles
    /// cell-neutral events (compute, control, churn) concurrently.
    pub(crate) fn raise_bound(&self, bound: f64) {
        let mut inner = self.inner.lock().unwrap();
        if bound > inner.bound {
            inner.bound = bound;
            self.work.notify_all();
        }
    }

    /// Help step until every boundary strictly below `cut` has
    /// committed (an in-flight claim at boundary `t` holds
    /// `frontier[cell] == t` until commit, so `min frontier >= cut`
    /// implies nothing below `cut` is running), then merge exactly the
    /// records below `cut` in `(t_bits, cell)` order — the serial
    /// calendar-insertion sequence. Records at or above `cut` stay
    /// buffered for a later merge; workers may keep producing them
    /// concurrently, bounded by the current `raise_bound` value.
    pub(crate) fn merge_below(&self, cut: f64, merge: &mut dyn FnMut(StepRec)) {
        let cut_bits = cut.to_bits();
        let mut inner = self.inner.lock().unwrap();
        debug_assert!(
            cut <= inner.bound,
            "merge cut {cut} above the bound {} would under-merge",
            inner.bound
        );
        loop {
            let min_f =
                inner.frontier.iter().copied().fold(f64::INFINITY, f64::min);
            if !(min_f < cut) {
                break;
            }
            if let Some((k, t, i_mw)) = self.try_claim(&mut inner) {
                drop(inner);
                let out = self.exec_step(k, t, i_mw);
                inner = self.inner.lock().unwrap();
                self.commit(&mut inner, k, out);
            } else if inner.inflight == 0 {
                // Nothing runnable and nothing running: the remaining
                // sub-cut frontiers sit beyond the drain limit (or are
                // capped by the current bound) and will never step.
                break;
            } else {
                inner = self.idle.wait(inner).unwrap();
            }
        }
        let mut below = Vec::new();
        let mut i = 0;
        while i < inner.records.len() {
            if inner.records[i].t_bits < cut_bits {
                below.push(inner.records.swap_remove(i));
            } else {
                i += 1;
            }
        }
        drop(inner);
        below.sort_unstable_by_key(|r| (r.t_bits, r.cell));
        for rec in below {
            merge(rec);
        }
    }

    /// Full quiescence at `bound`: no boundary below it is running or
    /// unmerged. On return the engine has exclusive access to every
    /// cell below the bound — the contract cell-writing event handlers
    /// (arrivals, radio ticks, fluid ticks) rely on.
    pub(crate) fn advance_to(&self, bound: f64, merge: &mut dyn FnMut(StepRec)) {
        self.raise_bound(bound);
        self.merge_below(bound, merge);
    }

    /// Replace fluid cell `k`'s published interference row (both
    /// generations, at the `t = 0` sentinel version, so the lag rule
    /// always selects it). Only called from the engine's `FluidTick`
    /// handler at full quiescence — no worker is pricing concurrently.
    pub(crate) fn set_fluid_row(&self, k: usize, row: &[f64]) {
        if !self.coupling {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let p = &mut inner.pubs[k];
        p[0] = PubRow { t_bits: 0, row: row.to_vec() };
        p[1] = PubRow { t_bits: 0, row: row.to_vec() };
    }

    /// Release the workers to exit (call once, after the event loop).
    pub(crate) fn shutdown(&self) {
        self.inner.lock().unwrap().stop = true;
        self.work.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::{Sdu, SduKind};

    #[test]
    fn cell_seed_is_identity_for_cell_zero_and_distinct_elsewhere() {
        assert_eq!(cell_seed(42, 0), 42);
        let seeds: Vec<u64> = (0..8).map(|k| cell_seed(42, k)).collect();
        for i in 0..seeds.len() {
            for j in (i + 1)..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "cells {i} and {j} collide");
            }
        }
        // different masters stay distinct per cell
        assert_ne!(cell_seed(1, 3), cell_seed(2, 3));
    }

    #[test]
    fn spec_numerology_override_rederives_prbs() {
        let spec = CellSpec::new(10).with_numerology(1);
        assert_eq!(spec.carrier.numerology.mu, 1);
        // 100 MHz @ 30 kHz SCS → 273 PRBs (TS 38.101-1)
        assert_eq!(spec.carrier.n_prb, 273);
        assert_eq!(spec.carrier.slot_duration(), 0.5e-3);
    }

    fn rt(idx: usize, seed: u64) -> CellRt {
        let mut cfg = SimConfig::table1();
        cfg.seed = seed;
        cfg.horizon = 1.0;
        CellRt::new(idx, &CellSpec::new(4), &cfg, 1)
    }

    #[test]
    fn cell_runtime_steps_its_own_slot_clock() {
        let mut c = rt(0, 7);
        let first = c.next_slot;
        assert_eq!(first, c.slot_dur);
        assert!(c.due(first.to_bits()));
        c.step_slot();
        assert_eq!(c.last_slot, first.to_bits());
        assert_eq!(c.next_slot, first + c.slot_dur);
        assert!(c.ticking, "within the horizon the clock keeps running");
    }

    #[test]
    fn clock_stops_after_horizon_with_empty_buffers_only() {
        let mut c = rt(0, 7);
        // fast-forward past the horizon
        while c.next_slot < 1.5 {
            c.step_slot();
        }
        assert!(!c.ticking, "idle cell past the horizon must stop");
        // a backlogged cell keeps draining past the horizon
        let mut c = rt(0, 7);
        c.bank.push_bg_sdu(0, Sdu {
            kind: SduKind::Background,
            total_bytes: 1 << 20,
            bytes_left: 1 << 20,
            t_arrival: 0.0,
        });
        while c.next_slot < 1.01 {
            c.step_slot();
        }
        assert!(
            c.ticking || c.bank.total_backlog_bytes() == 0,
            "backlogged cell must keep ticking until drained"
        );
    }

    #[test]
    fn geometry_cell_publishes_interference_and_migrates_ues() {
        let mut cfg = SimConfig::table1();
        cfg.seed = 3;
        cfg.horizon = 1.0;
        let spec = CellSpec::new(4);
        let mut a = CellRt::new(0, &spec, &cfg, 1);
        let mut b = CellRt::new(1, &spec, &cfg, 1);
        let sites =
            vec![Position { x: 0.0, y: 0.0 }, Position { x: 500.0, y: 0.0 }];
        a.init_geometry(0, &sites, vec![false, true], cell_seed(3, 0), cfg.cell_r_max, None);
        b.init_geometry(1, &sites, vec![true, false], cell_seed(3, 1), cfg.cell_r_max, None);
        assert_eq!(a.iot_db, 0.0, "geometry mode starts interference-free");
        for i in 0..4 {
            a.bank.ue_mut(i).tag = i as u64;
            b.bank.ue_mut(i).tag = 4 + i as u64;
        }
        // keep cell a backlogged so every slot grants someone
        a.bank.push_bg_sdu(0, Sdu {
            kind: SduKind::Background,
            total_bytes: 1 << 20,
            bytes_left: 1 << 20,
            t_arrival: 0.0,
        });
        let mut published = false;
        for _ in 0..20 {
            a.step_slot();
            if a.itf_out[1] > 0.0 {
                published = true;
                break;
            }
        }
        assert!(published, "granted slots must publish neighbor interference");
        assert_eq!(a.itf_out[0], 0.0, "a cell never interferes with itself");
        assert!(a.iot_stats.count() > 0, "IoT samples recorded per stepped slot");

        // migrate the backlogged UE 0 from a to b: bytes conserved,
        // bank and geometry stay in lockstep, link re-anchors to site 1
        let carried = a.bank.ue(0).buffered_bytes();
        assert!(carried > 0);
        let total = a.bank.total_backlog_bytes() + b.bank.total_backlog_bytes();
        let (ue, hot, gu, displaced) = a.take_ue(0);
        assert!(displaced.is_some(), "a still has UEs, so one was displaced");
        assert_eq!(a.bank.len(), a.geo.as_ref().unwrap().ues.len());
        let ni = b.admit_ue(ue, hot, gu, 4);
        assert_eq!(ni, 4);
        assert_eq!(b.bank.len(), b.geo.as_ref().unwrap().ues.len());
        assert_eq!(
            a.bank.total_backlog_bytes() + b.bank.total_backlog_bytes(),
            total,
            "handover must conserve buffered bytes"
        );
        assert_eq!(b.bank.ue(4).buffered_bytes(), carried);
        a.bank.check_invariants();
        b.bank.check_invariants();
        // the migrated UE's serving link is now relative to site 1
        let gu = &b.geo.as_ref().unwrap().ues[4];
        let rel = b.bank.ue(4).link.pos;
        assert!((rel.x - (gu.pos.x - 500.0)).abs() < 1e-9);
        assert!((rel.y - gu.pos.y).abs() < 1e-9);
    }

    #[test]
    fn handover_spec_defaults_are_sane() {
        let h = HandoverSpec::default();
        assert!(h.hysteresis_db > 0.0);
        assert!(h.ttt_s > 0.0);
        assert!(h.interruption_slots > 0);
    }

    #[test]
    fn pool_steps_exactly_the_due_cells() {
        let cells: Vec<Mutex<CellRt>> =
            (0..6).map(|k| Mutex::new(rt(k, 11))).collect();
        // Stagger cell 3 two boundaries ahead so it is not due at the
        // first boundary AND its last_slot differs from the batch time
        // (one step would leave last_slot == t0, the batch time).
        let t0 = {
            let mut c3 = cells[3].lock().unwrap();
            c3.step_slot();
            c3.step_slot();
            cells[0].lock().unwrap().next_slot
        };
        let pool = StepPool::new(&cells, 3);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| pool.worker());
            }
            pool.step_batch(t0);
            pool.shutdown();
        });
        for (k, cm) in cells.iter().enumerate() {
            let c = cm.lock().unwrap();
            if k == 3 {
                assert_ne!(c.last_slot, t0.to_bits(), "cell 3 was not due");
            } else {
                assert_eq!(c.last_slot, t0.to_bits(), "cell {k} missed the batch");
            }
        }
    }

    #[test]
    fn frontier_pool_steps_to_the_bound_and_merges_in_order() {
        let cells: Vec<Mutex<CellRt>> =
            (0..3).map(|k| Mutex::new(rt(k, 11))).collect();
        let slot = cells[0].lock().unwrap().slot_dur;
        let pool = FrontierPool::new(&cells, 3.0, false);
        let mut merged: Vec<(u64, u32)> = Vec::new();
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| pool.worker());
            }
            // three boundaries per cell lie strictly below the bound
            pool.advance_to(3.5 * slot, &mut |rec| merged.push((rec.t_bits, rec.cell)));
            pool.shutdown();
        });
        assert_eq!(merged.len(), 9, "3 cells x 3 boundaries below the bound");
        let mut sorted = merged.clone();
        sorted.sort_unstable();
        assert_eq!(merged, sorted, "records merge in (time, cell) order");
        // every cell advanced exactly to its 4th boundary (accumulated
        // the same way step_slot accumulates it)
        let expect = {
            let mut t = slot;
            for _ in 0..3 {
                t += slot;
            }
            t.to_bits()
        };
        for cm in &cells {
            let c = cm.lock().unwrap();
            assert_eq!(c.next_slot.to_bits(), expect);
            assert!(c.ticking);
        }
        // a later bound below the next boundary is a no-op
        let mut extra = 0usize;
        pool.advance_to(3.9 * slot, &mut |_| extra += 1);
        assert_eq!(extra, 0, "no boundary below the new bound remains");
    }

    #[test]
    fn bounded_lag_merge_retains_records_above_the_cut() {
        let cells: Vec<Mutex<CellRt>> =
            (0..2).map(|k| Mutex::new(rt(k, 11))).collect();
        let slot = cells[0].lock().unwrap().slot_dur;
        let pool = FrontierPool::new(&cells, 3.0, false);
        // Bound well past the merge cut: the help-step loop advances
        // every boundary it needs for quiescence below the cut, but
        // only sub-cut records surface now.
        pool.raise_bound(4.5 * slot);
        let mut first: Vec<(u64, u32)> = Vec::new();
        pool.merge_below(2.5 * slot, &mut |rec| first.push((rec.t_bits, rec.cell)));
        assert_eq!(first.len(), 4, "2 cells x boundaries {{1,2}} below the cut");
        let mut sorted = first.clone();
        sorted.sort_unstable();
        assert_eq!(first, sorted, "sub-cut records merge in (time, cell) order");
        assert!(
            first.iter().all(|&(tb, _)| f64::from_bits(tb) < 2.5 * slot),
            "no record at or above the cut may surface early"
        );
        // The retained records surface at the next cut, still ordered.
        let mut rest: Vec<(u64, u32)> = Vec::new();
        pool.merge_below(4.5 * slot, &mut |rec| rest.push((rec.t_bits, rec.cell)));
        assert_eq!(rest.len(), 4, "boundaries {{3,4}} were retained");
        let mut sorted = rest.clone();
        sorted.sort_unstable();
        assert_eq!(rest, sorted);
        assert!(first.last().unwrap() < rest.first().unwrap());
        // lowering the bound is a no-op
        pool.raise_bound(1.0 * slot);
        let mut extra = 0usize;
        pool.merge_below(4.5 * slot, &mut |_| extra += 1);
        assert_eq!(extra, 0);
        pool.shutdown();
    }

    #[test]
    fn fluid_cells_publish_their_row_without_stepping() {
        let mut cfg = SimConfig::table1();
        cfg.seed = 3;
        cfg.horizon = 1.0;
        let spec = CellSpec::new(4);
        let mut a = CellRt::new(0, &spec, &cfg, 1);
        // a fluid background cell: no UEs, clock stopped
        let mut b = CellRt::new(1, &CellSpec { n_ues: 0, ..spec }, &cfg, 1);
        let sites =
            vec![Position { x: 0.0, y: 0.0 }, Position { x: 500.0, y: 0.0 }];
        a.init_geometry(0, &sites, vec![false, true], cell_seed(3, 0), cfg.cell_r_max, None);
        b.init_geometry(1, &sites, vec![true, false], cell_seed(3, 1), cfg.cell_r_max, None);
        b.fluid = true;
        b.ticking = false;
        b.next_slot = f64::INFINITY;
        b.itf_out = vec![2.5e-12, 0.0];
        let cells = vec![Mutex::new(a), Mutex::new(b)];
        let pool = FrontierPool::new(&cells, 3.0, true);
        let slot = cells[0].lock().unwrap().slot_dur;
        let mut n = 0usize;
        pool.advance_to(1.5 * slot, &mut |_| n += 1);
        assert_eq!(n, 1, "only the per-UE cell steps");
        {
            let a = cells[0].lock().unwrap();
            // the fluid neighbor's row priced into cell 0's first slot
            let expect = crate::phy::link::iot_db_from_linear(
                2.5e-12,
                a.noise_floor_mw,
            );
            assert!(
                (a.iot_db - expect).abs() < 1e-12,
                "{} vs {expect}",
                a.iot_db
            );
        }
        // the engine refreshes the row at a fluid tick; later slots
        // price the new value
        pool.set_fluid_row(1, &[5.0e-12, 0.0]);
        pool.advance_to(2.5 * slot, &mut |_| n += 1);
        let a = cells[0].lock().unwrap();
        let expect =
            crate::phy::link::iot_db_from_linear(5.0e-12, a.noise_floor_mw);
        assert!((a.iot_db - expect).abs() < 1e-12);
        pool.shutdown();
    }
}
