//! Fluid (mean-field) far-ring cell tier — DESIGN.md §15.
//!
//! Cells far from the configured focus set drop their per-UE MAC/PHY
//! pipeline entirely. Each fluid cell keeps two scalars of state — a
//! mean *activity* (granted-PRB fraction) relaxing toward the offered
//! load / capacity ratio, and its time integral for reporting — plus a
//! precomputed activity-1.0 interference row. On every coarse
//! `FluidTick` the engine scales that unit row by the current activity
//! and republishes it through the *same* `itf_out` exchange the
//! focus cells' slot pipeline consumes (§10 coupling contract), and
//! accounts the tier's mean offered compute load against the node pool
//! via the paper's Eq 3–6 closed forms.
//!
//! Documented approximations (the fidelity contract, §15):
//! - the cell population collapses to one representative UE at the
//!   mean drop radius with deterministic LOS and zero shadowing;
//! - inter-site loss is priced center-to-center, NLOS, zero shadowing;
//! - offered load uses distribution means (token means, Poisson rates
//!   in force at the tick) — no per-UE burstiness, no HARQ, no
//!   handover into or out of the fluid tier.

use crate::phy::channel::{los_probability, LargeScale, Position};
use crate::phy::geometry::{link_loss_db, TopologySpec};
use crate::phy::link::{
    mean_sinr_db, sinr_to_cqi, tbs_bytes, tx_power_prb_dbm, PowerControl, Receiver,
};
use crate::phy::numerology::Carrier;

use super::workload::WorkloadClass;

/// Configuration of the hybrid-fidelity background tier. Present on a
/// [`super::Scenario`] it splits the cell set in two: cells within
/// `rings` ring-distance of any focus site keep the full per-UE DES
/// pipeline; everything farther becomes a fluid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct FluidSpec {
    /// Focus sites (cell indices) kept at per-UE fidelity, together
    /// with their `rings`-neighborhood.
    pub focus: Vec<usize>,
    /// Ring radius of the per-UE neighborhood around each focus site
    /// ([`TopologySpec::ring_distance`] metric).
    pub rings: u32,
    /// Fluid tick period (seconds) — the coarse clock that refreshes
    /// activities, interference rows, and the background compute load.
    pub tick_s: f64,
    /// Activity relaxation time constant (seconds): per tick the
    /// activity moves a `1 − e^{−tick_s/relax_s}` fraction of the way
    /// to its target, so step responses settle in a few `relax_s`.
    pub relax_s: f64,
}

impl Default for FluidSpec {
    fn default() -> Self {
        Self { focus: vec![0], rings: 1, tick_s: 0.01, relax_s: 0.1 }
    }
}

impl FluidSpec {
    /// Is `cell` in the fluid (background) tier? Fluid iff its ring
    /// distance to *every* focus site exceeds `rings`.
    pub fn is_fluid(&self, topo: &TopologySpec, cell: usize) -> bool {
        !self
            .focus
            .iter()
            .any(|&f| topo.ring_distance(f, cell) <= u64::from(self.rings))
    }
}

/// Mean drop radius of a UE dropped uniformly on the annulus
/// `[r_min, r_max]`: `E[r] = 2(r_max³−r_min³) / (3(r_max²−r_min²))`.
/// The fluid tier's representative UE sits here.
pub(crate) fn representative_radius(r_min: f64, r_max: f64) -> f64 {
    2.0 * (r_max.powi(3) - r_min.powi(3)) / (3.0 * (r_max.powi(2) - r_min.powi(2)))
}

/// Large-scale state of the representative UE: mean radius,
/// deterministic LOS (majority outcome at that distance), no shadowing.
pub(crate) fn representative_ue(d_rep: f64) -> LargeScale {
    LargeScale {
        pos: Position { x: d_rep, y: 0.0 },
        los: los_probability(d_rep) >= 0.5,
        shadow_db: 0.0,
    }
}

/// Uplink air-interface capacity (bytes/s) of a fluid cell: the full
/// carrier granted every slot to the representative UE at its
/// link-adapted CQI.
pub(crate) fn cell_capacity_bytes_per_s(
    carrier: &Carrier,
    pc: &PowerControl,
    rx: &Receiver,
    d_rep: f64,
) -> f64 {
    let ls = representative_ue(d_rep);
    let cqi = sinr_to_cqi(mean_sinr_db(&ls, carrier, pc, rx, carrier.n_prb));
    f64::from(tbs_bytes(carrier, cqi, carrier.n_prb)) / carrier.numerology.slot_duration()
}

/// Activity-1.0 interference row of fluid cell `k`: `row[j]` is the
/// per-PRB power (linear mW) site `j` receives from cell `k`'s uplink
/// when the cell is fully loaded. Transmit power prices the
/// representative UE's own-cell coupling loss through the same
/// open-loop PC formula the per-UE publisher uses; the cross-site loss
/// is center-to-center NLOS with zero shadowing. Scaling by the
/// current activity gives the published row.
pub(crate) fn unit_interference_row(
    topo: &TopologySpec,
    k: usize,
    n_cells: usize,
    carrier: &Carrier,
    pc: &PowerControl,
    d_rep: f64,
) -> Vec<f64> {
    let cl_own = representative_ue(d_rep).coupling_loss_db(carrier.freq_hz);
    let p_tx_dbm = tx_power_prb_dbm(cl_own, pc, carrier.n_prb);
    let own = topo.site_position(k);
    let mut row = vec![0.0; n_cells];
    for (j, slot) in row.iter_mut().enumerate() {
        if j == k {
            continue;
        }
        let cl_to_j = link_loss_db(own, topo.site_position(j), carrier.freq_hz, false, 0.0);
        *slot = 10f64.powf((p_tx_dbm - cl_to_j) / 10.0);
    }
    row
}

/// Runtime state of one fluid cell.
#[derive(Debug, Clone)]
pub(crate) struct FluidCell {
    /// Cell index in the scenario's cell list.
    pub(crate) cell: usize,
    /// Population the cell represents (the spec's `n_ues`; the
    /// per-UE runtime holds zero).
    pub(crate) n_ues: u32,
    /// Uplink capacity (bytes/s) at the representative UE.
    pub(crate) capacity_bps: f64,
    /// Interference row at activity 1.0 (mW per PRB into each site).
    pub(crate) unit_itf: Vec<f64>,
    /// Current mean granted-PRB fraction in `[0, 1]`.
    pub(crate) activity: f64,
    /// `∫ activity dt` — divides by elapsed time for the mean.
    pub(crate) act_sum: f64,
}

impl FluidCell {
    /// The interference row to publish at the current activity.
    pub(crate) fn row(&self) -> Vec<f64> {
        self.unit_itf.iter().map(|v| v * self.activity).collect()
    }
}

/// Runtime state of the whole fluid tier (owned by the engine; stepped
/// by the `FluidTick` handler at full frontier quiescence).
#[derive(Debug)]
pub(crate) struct FluidRt {
    pub(crate) tick_s: f64,
    pub(crate) relax_s: f64,
    /// Ticks processed (snapshot-restored; `ticks × tick_s` is the
    /// elapsed fluid time that normalizes `act_sum`).
    pub(crate) ticks: u64,
    pub(crate) cells: Vec<FluidCell>,
    /// Mean background utilization each up node carries for the fluid
    /// tier (`Σ λ_fluid × s̄ / n_up`); refreshed every tick and exposed
    /// to custom routers through `NodeView::background_rho`.
    pub(crate) node_rho: f64,
}

impl FluidRt {
    pub(crate) fn new(spec: &FluidSpec, cells: Vec<FluidCell>) -> Self {
        Self { tick_s: spec.tick_s, relax_s: spec.relax_s, ticks: 0, cells, node_rho: 0.0 }
    }

    /// Mean uplink bytes/s one UE offers at time `t`: every class at
    /// its rate in force times its mean request size, plus the
    /// background stream.
    pub(crate) fn offered_bytes_per_ue(
        classes: &[WorkloadClass],
        bg_rate: f64,
        bg_bytes: f64,
        t: f64,
    ) -> f64 {
        let mut bytes = bg_rate * bg_bytes;
        for c in classes {
            let mean_request =
                c.input_tokens.mean() * f64::from(c.bytes_per_token) + f64::from(c.overhead_bytes);
            bytes += c.rate_at(t) * mean_request;
        }
        bytes
    }

    /// Target activity of a cell with `n_ues` UEs: offered / capacity,
    /// saturating at 1 (an overloaded fluid cell transmits on every
    /// PRB it has, exactly like a saturated per-UE cell).
    fn target_activity(n_ues: u32, capacity_bps: f64, per_ue_bytes: f64) -> f64 {
        if capacity_bps <= 0.0 {
            return 1.0;
        }
        (f64::from(n_ues) * per_ue_bytes / capacity_bps).min(1.0)
    }

    /// Seed activities at their `t = 0` targets so a run starts in the
    /// steady state the DES population would warm into.
    pub(crate) fn init_activities(&mut self, classes: &[WorkloadClass], bg_rate: f64, bg_bytes: f64) {
        let per_ue = Self::offered_bytes_per_ue(classes, bg_rate, bg_bytes, 0.0);
        for fc in &mut self.cells {
            fc.activity = Self::target_activity(fc.n_ues, fc.capacity_bps, per_ue);
        }
    }

    /// Advance every cell one tick at simulation time `t`: exponential
    /// relaxation toward the current offered/capacity target.
    pub(crate) fn tick(&mut self, t: f64, classes: &[WorkloadClass], bg_rate: f64, bg_bytes: f64) {
        let per_ue = Self::offered_bytes_per_ue(classes, bg_rate, bg_bytes, t);
        let blend = 1.0 - (-self.tick_s / self.relax_s).exp();
        for fc in &mut self.cells {
            let target = Self::target_activity(fc.n_ues, fc.capacity_bps, per_ue);
            fc.activity += blend * (target - fc.activity);
            fc.act_sum += fc.activity * self.tick_s;
        }
        self.ticks += 1;
    }

    /// Job arrival rate (jobs/s, all classes) one fluid cell offers at
    /// time `t`.
    pub(crate) fn lambda_cell(n_ues: u32, classes: &[WorkloadClass], t: f64) -> f64 {
        f64::from(n_ues) * classes.iter().map(|c| c.rate_at(t)).sum::<f64>()
    }

    /// Total job arrival rate of the whole tier at time `t`.
    pub(crate) fn lambda_total(&self, classes: &[WorkloadClass], t: f64) -> f64 {
        self.cells
            .iter()
            .map(|fc| Self::lambda_cell(fc.n_ues, classes, t))
            .sum()
    }

    /// Elapsed fluid time (seconds) — normalizes `act_sum`.
    pub(crate) fn elapsed(&self) -> f64 {
        self.ticks as f64 * self.tick_s
    }
}

/// Per-fluid-cell summary on [`super::engine::ScenarioResult`].
#[derive(Debug, Clone)]
pub struct FluidCellReport {
    /// Cell index in the scenario's cell list.
    pub cell: usize,
    /// Job arrival rate (jobs/s) the cell offered at end of run.
    pub lambda_jobs: f64,
    /// Final mean granted-PRB fraction.
    pub activity: f64,
    /// Time-averaged activity over the run.
    pub mean_activity: f64,
}

/// Per-class analytic (Eq 3–6) summary of the fluid tier's load.
#[derive(Debug, Clone)]
pub struct FluidClassReport {
    pub name: String,
    /// Mean per-fluid-cell arrival rate of the class (jobs/s).
    pub lambda_per_cell: f64,
    /// M/M/1 tandem mean sojourn at that rate (`None` = unstable).
    pub mean_sojourn: Option<f64>,
    /// Closed-form satisfaction probability under the scenario's
    /// latency-management scheme.
    pub satisfaction: f64,
}

/// Fluid-tier section of a scenario result (present iff the scenario
/// configured a [`FluidSpec`] and at least one cell classified fluid).
#[derive(Debug, Clone)]
pub struct FluidReport {
    pub cells: Vec<FluidCellReport>,
    /// Background utilization each up node carried at end of run.
    pub node_rho: f64,
    pub classes: Vec<FluidClassReport>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_follows_ring_distance() {
        let topo = TopologySpec::hex(500.0);
        let spec = FluidSpec { focus: vec![0], rings: 1, ..FluidSpec::default() };
        // 19-site hex spiral: ring 0 = {0}, ring 1 = {1..=6}, ring 2 = {7..=18}.
        for k in 0..19 {
            assert_eq!(spec.is_fluid(&topo, k), k > 6, "cell {k}");
        }
        let wide = FluidSpec { focus: vec![0], rings: 2, ..FluidSpec::default() };
        assert!((0..19).all(|k| !wide.is_fluid(&topo, k)));
        // A second focus site pulls its own neighborhood back to per-UE.
        let two = FluidSpec { focus: vec![0, 18], rings: 0, ..FluidSpec::default() };
        assert!(!two.is_fluid(&topo, 0));
        assert!(!two.is_fluid(&topo, 18));
        assert!(two.is_fluid(&topo, 3));
    }

    #[test]
    fn representative_radius_is_annulus_mean() {
        // Full disc of radius r: E[r] = 2r/3.
        let d = representative_radius(0.0, 300.0);
        assert!((d - 200.0).abs() < 1e-9, "{d}");
        // Thin annulus: mean ≈ the ring radius.
        let d = representative_radius(249.0, 251.0);
        assert!((d - 250.0).abs() < 0.1, "{d}");
        // Monotone in both edges, inside the annulus.
        let d = representative_radius(35.0, 250.0);
        assert!(d > 35.0 && d < 250.0, "{d}");
    }

    #[test]
    fn capacity_positive_and_decays_with_distance() {
        let carrier = Carrier::table1();
        let (pc, rx) = (PowerControl::default(), Receiver::default());
        let near = cell_capacity_bytes_per_s(&carrier, &pc, &rx, 80.0);
        let far = cell_capacity_bytes_per_s(&carrier, &pc, &rx, 800.0);
        assert!(near > 0.0 && far > 0.0);
        assert!(near >= far, "capacity must not grow with distance: {near} < {far}");
    }

    #[test]
    fn unit_row_prices_neighbors_only() {
        let topo = TopologySpec::hex(500.0);
        let carrier = Carrier::table1();
        let pc = PowerControl::default();
        let row = unit_interference_row(&topo, 0, 7, &carrier, &pc, 150.0);
        assert_eq!(row.len(), 7);
        assert_eq!(row[0], 0.0, "no self-interference");
        // Ring-1 sites are equidistant from the center: identical power.
        for j in 2..7 {
            assert!((row[j] - row[1]).abs() < 1e-18, "site {j}: {} vs {}", row[j], row[1]);
        }
        assert!(row[1] > 0.0);
        // A farther publisher injects less into a fixed victim.
        let far = unit_interference_row(&topo, 18, 19, &carrier, &pc, 150.0);
        let near = unit_interference_row(&topo, 1, 19, &carrier, &pc, 150.0);
        assert!(far[0] < near[0]);
    }

    #[test]
    fn activity_relaxes_to_target_and_integrates() {
        let spec = FluidSpec { tick_s: 0.01, relax_s: 0.05, ..FluidSpec::default() };
        let classes = vec![WorkloadClass::translation()];
        let capacity = 1.0e7;
        let mut rt = FluidRt::new(
            &spec,
            vec![FluidCell {
                cell: 7,
                n_ues: 50,
                capacity_bps: capacity,
                unit_itf: vec![1.0e-12, 0.0],
                activity: 0.0,
                act_sum: 0.0,
            }],
        );
        let per_ue = FluidRt::offered_bytes_per_ue(&classes, 0.0, 0.0, 0.0);
        assert!(per_ue > 0.0);
        let target = (50.0 * per_ue / capacity).min(1.0);
        for i in 0..200 {
            rt.tick(i as f64 * spec.tick_s, &classes, 0.0, 0.0);
        }
        let fc = &rt.cells[0];
        assert!((fc.activity - target).abs() < 1e-9 * target.max(1e-12), "after 40 time constants");
        assert_eq!(rt.ticks, 200);
        assert!((rt.elapsed() - 2.0).abs() < 1e-12);
        // The mean sits between start (0) and target, and the row scales.
        let mean = fc.act_sum / rt.elapsed();
        assert!(mean > 0.0 && mean <= target + 1e-12);
        assert!((fc.row()[0] - fc.activity * 1.0e-12).abs() < 1e-24);
        assert_eq!(fc.row()[1], 0.0);
    }

    #[test]
    fn saturated_cell_clamps_at_full_activity() {
        let spec = FluidSpec { tick_s: 0.01, relax_s: 0.01, ..FluidSpec::default() };
        let classes = vec![WorkloadClass::translation()];
        let mut rt = FluidRt::new(
            &spec,
            vec![FluidCell {
                cell: 9,
                n_ues: 10_000,
                capacity_bps: 1.0,
                unit_itf: vec![0.0],
                activity: 0.0,
                act_sum: 0.0,
            }],
        );
        rt.init_activities(&classes, 1.0, 1500.0);
        assert_eq!(rt.cells[0].activity, 1.0);
        for _ in 0..10 {
            rt.tick(0.0, &classes, 1.0, 1500.0);
        }
        assert!(rt.cells[0].activity <= 1.0);
        assert!((rt.cells[0].activity - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lambda_scales_with_population_and_rate_phases() {
        let classes = vec![
            WorkloadClass::translation().with_rate(0.5).with_rate_phase(10.0, 2.0),
            WorkloadClass::chat().with_rate(0.1),
        ];
        let early = FluidRt::lambda_cell(20, &classes, 0.0);
        assert!((early - 20.0 * 0.6).abs() < 1e-12, "{early}");
        let late = FluidRt::lambda_cell(20, &classes, 11.0);
        assert!((late - 20.0 * 2.1).abs() < 1e-12, "{late}");
    }
}
