//! Workload classes: the per-class traffic + job shape of a scenario.
//!
//! A [`WorkloadClass`] bundles what the legacy single-job API spread
//! across `JobTrafficConfig` and `JobSpec`: its own Poisson arrival
//! rate, input/output token *distributions* (mixed LLM workloads have
//! variable prompt and generation lengths), the byte footprint on the
//! air interface, the served model's roofline constants, and the
//! per-class latency budget. A scenario composes N of these.

use crate::llm::{kv_bytes_per_token, JobSpec};
use crate::rng::Rng;
use crate::traffic::JobTrafficConfig;
use crate::util::tomlmini::Document;

/// Token-length distribution of a prompt or a generation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TokenDist {
    /// Every job has exactly `n` tokens (the paper's Table I shape).
    Fixed(u32),
    /// Uniform over `lo..=hi`.
    Uniform { lo: u32, hi: u32 },
    /// Shifted geometric on {1, 2, ...} with the given mean — the
    /// classic model for LLM output lengths (EOS is a per-token coin).
    Geometric { mean: f64 },
}

impl TokenDist {
    pub fn mean(&self) -> f64 {
        match *self {
            TokenDist::Fixed(n) => n as f64,
            TokenDist::Uniform { lo, hi } => (lo as f64 + hi as f64) / 2.0,
            TokenDist::Geometric { mean } => mean,
        }
    }

    /// Draw a realization. `Fixed` consumes no randomness, which
    /// keeps single-class scenarios statistically identical to the
    /// legacy SLS.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match *self {
            TokenDist::Fixed(n) => n,
            TokenDist::Uniform { lo, hi } => {
                let (lo, hi) = (lo.min(hi), lo.max(hi));
                lo + rng.below((hi - lo + 1) as u64) as u32
            }
            TokenDist::Geometric { mean } => {
                if mean <= 1.0 {
                    return 1;
                }
                let p = 1.0 / mean;
                // inversion: k = ceil(ln(1-u) / ln(1-p)) on {1, 2, ...}
                let u = rng.f64();
                let k = ((1.0 - u).ln() / (1.0 - p).ln()).ceil();
                k.max(1.0).min(u32::MAX as f64) as u32
            }
        }
    }

    /// Parse the config syntax: `"fixed:15"`, `"uniform:64..128"`,
    /// `"geometric:96"`. A bare integer means `fixed`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        if let Ok(n) = s.parse::<u32>() {
            return Some(TokenDist::Fixed(n));
        }
        let (kind, arg) = s.split_once(':')?;
        match kind.trim() {
            "fixed" => arg.trim().parse().ok().map(TokenDist::Fixed),
            "uniform" => {
                let (lo, hi) = arg.split_once("..")?;
                let lo = lo.trim().parse().ok()?;
                let hi = hi.trim().parse().ok()?;
                (lo <= hi).then_some(TokenDist::Uniform { lo, hi })
            }
            "geometric" => {
                let mean: f64 = arg.trim().parse().ok()?;
                (mean >= 1.0).then_some(TokenDist::Geometric { mean })
            }
            _ => None,
        }
    }

    /// Inverse of [`TokenDist::parse`] (config round-trips).
    pub fn to_config_string(&self) -> String {
        match *self {
            TokenDist::Fixed(n) => format!("fixed:{n}"),
            TokenDist::Uniform { lo, hi } => format!("uniform:{lo}..{hi}"),
            TokenDist::Geometric { mean } => format!("geometric:{mean}"),
        }
    }
}

/// One piecewise-constant phase of a time-varying arrival schedule:
/// from `t_start` on (until the next phase) the class generates at
/// `rate_per_ue`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RatePhase {
    /// Simulation time the phase takes effect (seconds).
    pub t_start: f64,
    /// Poisson arrival rate per UE during the phase (jobs/s).
    pub rate_per_ue: f64,
}

/// One workload class of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadClass {
    pub name: String,
    /// Poisson arrival rate per UE (jobs/s) — the base rate before the
    /// first entry of `rate_phases` (and the whole run's rate when the
    /// schedule is empty).
    pub rate_per_ue: f64,
    /// Piecewise-constant rate schedule, ascending by `t_start`
    /// (empty = constant `rate_per_ue`, the legacy behavior). The
    /// engine re-arms each arrival at the rate in force at the draw
    /// time, so diurnal load curves run in one pass instead of one
    /// run per phase.
    pub rate_phases: Vec<RatePhase>,
    pub input_tokens: TokenDist,
    pub output_tokens: TokenDist,
    /// Payload bytes per prompt token on the air interface.
    pub bytes_per_token: u32,
    /// Fixed per-request overhead (framing + IP/PDCP headers).
    pub overhead_bytes: u32,
    /// FLOPs per token of the served model (≈ 2 × params).
    pub c_llm: f64,
    /// Model bytes streamed from memory per forward pass.
    pub m_llm: f64,
    /// KV-cache bytes per token of context — gates admission under
    /// continuous batching. Defaults to the dense-FP16 heuristic
    /// [`crate::llm::kv_bytes_per_token`]; override for GQA/MQA models.
    pub kv_bytes_per_token: f64,
    /// Acceptable models from the scenario zoo, by name, best first —
    /// the class's quality floor. Empty = unconstrained: the class
    /// runs on its own `c_llm`/`m_llm` constants (the single-model
    /// legacy path). Names are resolved against the `[[model]]` zoo
    /// at scenario build.
    pub models: Vec<String>,
    /// Leading prompt tokens every job of this class shares (a common
    /// system prompt). Jobs carry a shared-prefix block keyed by
    /// `(class, effective prefix length)`, enabling KV-cache reuse at
    /// continuous-batching nodes. 0 disables prefix reuse.
    pub prefix_tokens: u32,
    /// End-to-end latency budget (seconds).
    pub b_total: f64,
}

impl WorkloadClass {
    /// A class with the Table I defaults under the given name; adjust
    /// with the `with_*` setters.
    pub fn new(name: impl Into<String>) -> Self {
        let t = JobTrafficConfig::default();
        let j = JobSpec::table1();
        Self {
            name: name.into(),
            rate_per_ue: t.rate_per_ue,
            rate_phases: Vec::new(),
            input_tokens: TokenDist::Fixed(t.input_tokens),
            output_tokens: TokenDist::Fixed(j.n_output),
            bytes_per_token: t.bytes_per_token,
            overhead_bytes: t.overhead_bytes,
            c_llm: j.c_llm,
            m_llm: j.m_llm,
            kv_bytes_per_token: kv_bytes_per_token(j.m_llm),
            models: Vec::new(),
            prefix_tokens: 0,
            b_total: j.b_total,
        }
    }

    /// The paper's Table I workload: 15+15 fixed tokens, 80 ms budget.
    pub fn translation() -> Self {
        Self::new("translation")
    }

    /// Interactive chat: geometric prompt/response lengths, sub-second
    /// budget (cf. arXiv:2411.17712's mixed LLM workloads).
    pub fn chat() -> Self {
        Self::new("chat")
            .with_rate(0.3)
            .with_input(TokenDist::Geometric { mean: 48.0 })
            .with_output(TokenDist::Geometric { mean: 96.0 })
            .with_budget(0.500)
    }

    /// Document summarization: long uniform prompts, short fixed
    /// summaries, relaxed budget.
    pub fn summarization() -> Self {
        Self::new("summarization")
            .with_rate(0.1)
            .with_input(TokenDist::Uniform { lo: 256, hi: 512 })
            .with_output(TokenDist::Fixed(64))
            .with_budget(0.400)
    }

    /// Build a class from the legacy single-job config pair (the
    /// [`crate::sim::Sls`] compatibility path). The prompt length
    /// follows `traffic.input_tokens` — the same sync direction
    /// `SimConfig::apply_toml` enforces onto `job.n_input`; a config
    /// that desyncs the two pub fields by hand is represented by the
    /// traffic-side value for both bytes and compute.
    pub fn from_legacy(traffic: &JobTrafficConfig, job: &JobSpec) -> Self {
        Self {
            name: "translation".into(),
            rate_per_ue: traffic.rate_per_ue,
            rate_phases: Vec::new(),
            input_tokens: TokenDist::Fixed(traffic.input_tokens),
            output_tokens: TokenDist::Fixed(job.n_output),
            bytes_per_token: traffic.bytes_per_token,
            overhead_bytes: traffic.overhead_bytes,
            c_llm: job.c_llm,
            m_llm: job.m_llm,
            kv_bytes_per_token: kv_bytes_per_token(job.m_llm),
            models: Vec::new(),
            prefix_tokens: 0,
            b_total: job.b_total,
        }
    }

    pub fn with_rate(mut self, rate_per_ue: f64) -> Self {
        assert!(rate_per_ue > 0.0);
        self.rate_per_ue = rate_per_ue;
        self
    }

    /// Append a rate phase: from `t_start` on, arrivals draw at
    /// `rate_per_ue` jobs/s/UE. Phases must be appended in strictly
    /// ascending `t_start` order. A zero rate silences the class for
    /// the phase's duration: the engine re-arms each arrival stream at
    /// the next phase with a positive rate (an arrival already drawn
    /// before the phase boundary still lands — at most one per stream
    /// per rate drop, the standard piecewise-Poisson discretization).
    pub fn with_rate_phase(mut self, t_start: f64, rate_per_ue: f64) -> Self {
        assert!(t_start >= 0.0, "phase start must be >= 0");
        assert!(rate_per_ue >= 0.0, "phase rate must be >= 0 (0 silences the class)");
        if let Some(last) = self.rate_phases.last() {
            assert!(
                t_start > last.t_start,
                "rate phases must be strictly ascending in t_start"
            );
        }
        self.rate_phases.push(RatePhase { t_start, rate_per_ue });
        self
    }

    /// Arrival rate in force at simulation time `t`: the last phase
    /// whose `t_start` is `<= t`, or the base rate before any phase.
    /// With an empty schedule this is exactly `rate_per_ue`, so
    /// schedule-free classes consume the legacy draw sequence.
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut rate = self.rate_per_ue;
        for p in &self.rate_phases {
            if p.t_start <= t {
                rate = p.rate_per_ue;
            } else {
                break;
            }
        }
        rate
    }

    pub fn with_input(mut self, d: TokenDist) -> Self {
        self.input_tokens = d;
        self
    }

    pub fn with_output(mut self, d: TokenDist) -> Self {
        self.output_tokens = d;
        self
    }

    pub fn with_budget(mut self, b_total: f64) -> Self {
        assert!(b_total > 0.0);
        self.b_total = b_total;
        self
    }

    /// Serve this class with a different model (FLOPs/token, bytes).
    /// Re-derives the default KV footprint for the new size — call
    /// [`WorkloadClass::with_kv_bytes_per_token`] *after* this to
    /// override it.
    pub fn with_model(mut self, c_llm: f64, m_llm: f64) -> Self {
        self.c_llm = c_llm;
        self.m_llm = m_llm;
        self.kv_bytes_per_token = kv_bytes_per_token(m_llm);
        self
    }

    /// Override the KV-cache bytes reserved per context token.
    pub fn with_kv_bytes_per_token(mut self, bytes: f64) -> Self {
        assert!(bytes > 0.0);
        self.kv_bytes_per_token = bytes;
        self
    }

    /// Restrict this class to the given zoo models by name, best
    /// first (the quality floor). Scenario build resolves the names
    /// against the configured `[[model]]` zoo and rejects unknowns.
    pub fn with_models<S: AsRef<str>>(mut self, names: &[S]) -> Self {
        self.models = names.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    /// Declare the class's shared system-prompt length. Jobs reserve
    /// (and prefill) only their non-shared suffix when the class's
    /// prefix block is already resident at the serving node.
    pub fn with_prefix_tokens(mut self, tokens: u32) -> Self {
        self.prefix_tokens = tokens;
        self
    }

    /// Uplink bytes of one request with a realized prompt length.
    /// Saturating: absurd token × byte configurations clamp at
    /// `u32::MAX` instead of wrapping to a tiny SDU.
    pub fn request_bytes(&self, n_input: u32) -> u32 {
        n_input
            .saturating_mul(self.bytes_per_token)
            .saturating_add(self.overhead_bytes)
    }

    /// The [`JobSpec`] of one realized job of this class.
    pub fn job_spec(&self, n_input: u32, n_output: u32) -> JobSpec {
        JobSpec {
            n_input,
            n_output,
            c_llm: self.c_llm,
            m_llm: self.m_llm,
            b_total: self.b_total,
        }
    }
}

/// Serialize classes as `[[workload]]` tables (the inverse of
/// [`workloads_from_toml`]). Rate schedules follow as
/// `[[workload.rate_phase]]` tables referencing their class by name.
/// The mini-TOML dialect cannot represent embedded double quotes in
/// strings, so they are stripped from names.
pub fn workloads_to_toml(classes: &[WorkloadClass]) -> String {
    let clean = |s: &str| -> String { s.chars().filter(|&ch| ch != '"').collect() };
    let mut out = String::new();
    for c in classes {
        let name = clean(&c.name);
        out.push_str("[[workload]]\n");
        out.push_str(&format!("name = \"{name}\"\n"));
        out.push_str(&format!("rate_per_ue = {}\n", c.rate_per_ue));
        out.push_str(&format!("input = \"{}\"\n", c.input_tokens.to_config_string()));
        out.push_str(&format!("output = \"{}\"\n", c.output_tokens.to_config_string()));
        out.push_str(&format!("bytes_per_token = {}\n", c.bytes_per_token));
        out.push_str(&format!("overhead_bytes = {}\n", c.overhead_bytes));
        out.push_str(&format!("c_llm = {}\n", c.c_llm));
        out.push_str(&format!("m_llm = {}\n", c.m_llm));
        out.push_str(&format!("kv_bytes_per_token = {}\n", c.kv_bytes_per_token));
        if !c.models.is_empty() {
            let names: Vec<String> = c.models.iter().map(|m| clean(m)).collect();
            out.push_str(&format!("models = \"{}\"\n", names.join(",")));
        }
        if c.prefix_tokens > 0 {
            out.push_str(&format!("prefix_tokens = {}\n", c.prefix_tokens));
        }
        out.push_str(&format!("b_total = {}\n\n", c.b_total));
    }
    for c in classes {
        let name = clean(&c.name);
        for p in &c.rate_phases {
            out.push_str("[[workload.rate_phase]]\n");
            out.push_str(&format!("class = \"{name}\"\n"));
            out.push_str(&format!("t_start = {}\n", p.t_start));
            out.push_str(&format!("rate_per_ue = {}\n\n", p.rate_per_ue));
        }
    }
    out
}

/// Integer field guard: present-but-mistyped or out-of-range values
/// must error, not wrap through an `as` cast.
pub(crate) fn u32_field(doc: &Document, key: &str, lo: i64, hi: i64) -> anyhow::Result<u32> {
    let v = doc
        .i64(key)
        .ok_or_else(|| anyhow::anyhow!("bad value for '{key}'"))?;
    if !(lo..=hi).contains(&v) {
        anyhow::bail!("'{key}' must be in {lo}..={hi}, got {v}");
    }
    Ok(v as u32)
}

/// Parse every `[[workload]]` table of a document. Unknown keys inside
/// a workload table are rejected.
pub fn workloads_from_toml(doc: &Document) -> anyhow::Result<Vec<WorkloadClass>> {
    let n = doc.array_len("workload");
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let prefix = format!("workload.{i}.");
        let mut w = WorkloadClass::new(format!("class{i}"));
        let mut kv_explicit = false;
        for key in doc.keys().filter(|k| k.starts_with(prefix.as_str())) {
            let field = &key[prefix.len()..];
            let missing = || anyhow::anyhow!("bad value for '{key}'");
            match field {
                "name" => w.name = doc.str(key).ok_or_else(missing)?.to_string(),
                "rate_per_ue" => w.rate_per_ue = doc.f64(key).ok_or_else(missing)?,
                "input" => {
                    let s = doc.str(key).ok_or_else(missing)?;
                    w.input_tokens = TokenDist::parse(s)
                        .ok_or_else(|| anyhow::anyhow!("bad token dist '{s}'"))?;
                }
                "output" => {
                    let s = doc.str(key).ok_or_else(missing)?;
                    w.output_tokens = TokenDist::parse(s)
                        .ok_or_else(|| anyhow::anyhow!("bad token dist '{s}'"))?;
                }
                "bytes_per_token" => {
                    w.bytes_per_token = u32_field(doc, key, 1, 1_000_000)?
                }
                "overhead_bytes" => {
                    w.overhead_bytes = u32_field(doc, key, 0, 1_000_000)?
                }
                "c_llm" => w.c_llm = doc.f64(key).ok_or_else(missing)?,
                "m_llm" => w.m_llm = doc.f64(key).ok_or_else(missing)?,
                "kv_bytes_per_token" => {
                    w.kv_bytes_per_token = doc.f64(key).ok_or_else(missing)?;
                    kv_explicit = true;
                }
                "models" => {
                    let s = doc.str(key).ok_or_else(missing)?;
                    w.models = s
                        .split(',')
                        .map(|m| m.trim().to_string())
                        .filter(|m| !m.is_empty())
                        .collect();
                }
                "prefix_tokens" => w.prefix_tokens = u32_field(doc, key, 0, 1_000_000)?,
                "b_total" => w.b_total = doc.f64(key).ok_or_else(missing)?,
                other => anyhow::bail!("unknown workload key '{other}'"),
            }
        }
        if !kv_explicit {
            // keep the default in sync with an overridden model size
            w.kv_bytes_per_token = kv_bytes_per_token(w.m_llm);
        }
        if w.rate_per_ue <= 0.0
            || w.b_total <= 0.0
            || w.c_llm <= 0.0
            || w.m_llm <= 0.0
            || w.kv_bytes_per_token <= 0.0
        {
            anyhow::bail!(
                "workload '{}' needs positive rate, budget, and model constants",
                w.name
            );
        }
        out.push(w);
    }
    let np = doc.array_len("workload.rate_phase");
    for i in 0..np {
        let prefix = format!("workload.rate_phase.{i}.");
        let mut class: Option<String> = None;
        let mut t_start: Option<f64> = None;
        let mut rate: Option<f64> = None;
        for key in doc.keys().filter(|k| k.starts_with(prefix.as_str())) {
            let field = &key[prefix.len()..];
            let missing = || anyhow::anyhow!("bad value for '{key}'");
            match field {
                "class" => class = Some(doc.str(key).ok_or_else(missing)?.to_string()),
                "t_start" => t_start = Some(doc.f64(key).ok_or_else(missing)?),
                "rate_per_ue" => rate = Some(doc.f64(key).ok_or_else(missing)?),
                other => anyhow::bail!("unknown rate_phase key '{other}'"),
            }
        }
        let class =
            class.ok_or_else(|| anyhow::anyhow!("rate_phase {i} needs a 'class'"))?;
        let t_start =
            t_start.ok_or_else(|| anyhow::anyhow!("rate_phase {i} needs a 't_start'"))?;
        let rate =
            rate.ok_or_else(|| anyhow::anyhow!("rate_phase {i} needs a 'rate_per_ue'"))?;
        if t_start < 0.0 || rate < 0.0 {
            anyhow::bail!(
                "rate_phase {i} needs t_start >= 0 and rate_per_ue >= 0 \
                 (0 silences the class for the phase)"
            );
        }
        let w = out
            .iter_mut()
            .find(|w| w.name == class)
            .ok_or_else(|| {
                anyhow::anyhow!("rate_phase references unknown workload class '{class}'")
            })?;
        if let Some(last) = w.rate_phases.last() {
            if t_start <= last.t_start {
                anyhow::bail!(
                    "rate phases of class '{class}' must be strictly ascending in t_start"
                );
            }
        }
        w.rate_phases.push(RatePhase { t_start, rate_per_ue: rate });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tomlmini::Document;

    #[test]
    fn dist_means_and_samples() {
        let mut rng = Rng::new(1);
        assert_eq!(TokenDist::Fixed(15).sample(&mut rng), 15);
        assert_eq!(TokenDist::Fixed(15).mean(), 15.0);
        let u = TokenDist::Uniform { lo: 10, hi: 20 };
        assert_eq!(u.mean(), 15.0);
        for _ in 0..200 {
            let x = u.sample(&mut rng);
            assert!((10..=20).contains(&x));
        }
        let g = TokenDist::Geometric { mean: 40.0 };
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| g.sample(&mut rng) as f64).sum();
        let m = sum / n as f64;
        assert!((m / 40.0 - 1.0).abs() < 0.05, "mean = {m}");
        assert!((0..1000).all(|_| g.sample(&mut rng) >= 1));
    }

    #[test]
    fn dist_parse_round_trip() {
        for d in [
            TokenDist::Fixed(15),
            TokenDist::Uniform { lo: 64, hi: 128 },
            TokenDist::Geometric { mean: 96.0 },
        ] {
            assert_eq!(TokenDist::parse(&d.to_config_string()), Some(d));
        }
        assert_eq!(TokenDist::parse("15"), Some(TokenDist::Fixed(15)));
        assert_eq!(TokenDist::parse("uniform:9..3"), None);
        assert_eq!(TokenDist::parse("zipf:2"), None);
    }

    #[test]
    fn legacy_class_matches_table1() {
        let w = WorkloadClass::from_legacy(
            &JobTrafficConfig::default(),
            &JobSpec::table1(),
        );
        assert_eq!(w.request_bytes(15), 15 * 4 + 120);
        assert_eq!(w.input_tokens, TokenDist::Fixed(15));
        assert_eq!(w.output_tokens, TokenDist::Fixed(15));
        assert!((w.b_total - 0.080).abs() < 1e-12);
        let spec = w.job_spec(15, 15);
        assert_eq!(spec.total_tokens(), 30);
    }

    #[test]
    fn workload_toml_round_trip() {
        let classes = vec![
            WorkloadClass::chat().with_models(&["7b", "70b"]).with_prefix_tokens(12),
            WorkloadClass::translation(),
            WorkloadClass::summarization().with_models(&["70b"]),
        ];
        let text = workloads_to_toml(&classes);
        let doc = Document::parse(&text).unwrap();
        let back = workloads_from_toml(&doc).unwrap();
        assert_eq!(classes, back);
    }

    #[test]
    fn rate_phase_toml_round_trip() {
        let classes = vec![
            WorkloadClass::chat()
                .with_rate_phase(2.0, 0.9)
                .with_rate_phase(5.0, 0.2),
            WorkloadClass::translation().with_rate_phase(1.5, 3.0),
        ];
        let text = workloads_to_toml(&classes);
        let doc = Document::parse(&text).unwrap();
        let back = workloads_from_toml(&doc).unwrap();
        assert_eq!(classes, back);
    }

    #[test]
    fn rate_at_is_piecewise_constant() {
        let w = WorkloadClass::chat()
            .with_rate(0.5)
            .with_rate_phase(2.0, 1.5)
            .with_rate_phase(6.0, 0.25);
        assert_eq!(w.rate_at(0.0), 0.5);
        assert_eq!(w.rate_at(1.999), 0.5);
        assert_eq!(w.rate_at(2.0), 1.5);
        assert_eq!(w.rate_at(5.9), 1.5);
        assert_eq!(w.rate_at(6.0), 0.25);
        assert_eq!(w.rate_at(1e9), 0.25);
        // empty schedule == the constant base rate everywhere
        let plain = WorkloadClass::chat().with_rate(0.5);
        assert_eq!(plain.rate_at(0.0), 0.5);
        assert_eq!(plain.rate_at(1e6), 0.5);
    }

    #[test]
    fn rate_phase_toml_rejects_bad_schedules() {
        let base = workloads_to_toml(&[WorkloadClass::chat()]);
        let bad = |tail: &str| {
            let doc = Document::parse(&format!("{base}{tail}")).unwrap();
            workloads_from_toml(&doc).unwrap_err().to_string()
        };
        let err = bad("[[workload.rate_phase]]\nclass = \"nope\"\nt_start = 1.0\nrate_per_ue = 0.5\n");
        assert!(err.contains("unknown workload class"), "{err}");
        let err = bad("[[workload.rate_phase]]\nclass = \"chat\"\nt_start = 1.0\nrate_per_ue = -2.0\n");
        assert!(err.contains("positive"), "{err}");
        let err = bad(concat!(
            "[[workload.rate_phase]]\nclass = \"chat\"\nt_start = 3.0\nrate_per_ue = 0.5\n",
            "[[workload.rate_phase]]\nclass = \"chat\"\nt_start = 2.0\nrate_per_ue = 0.5\n",
        ));
        assert!(err.contains("ascending"), "{err}");
        let err = bad("[[workload.rate_phase]]\nclass = \"chat\"\nt_start = 1.0\nwat = 2\n");
        assert!(err.contains("unknown rate_phase key"), "{err}");
    }

    #[test]
    fn workload_toml_rejects_unknown_key() {
        let doc =
            Document::parse("[[workload]]\nname = \"x\"\nfrobnicate = 3").unwrap();
        let err = workloads_from_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("frobnicate"), "{err}");
    }
}
