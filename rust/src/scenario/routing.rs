//! Routing policies over a multi-node compute tier.
//!
//! The legacy SLS owned exactly one `ComputeNode`; a scenario owns N
//! and a [`Routing`] policy decides which node (and, with a model zoo
//! configured, which model) serves each delivered prompt. Policies see
//! only cheap per-node load summaries ([`NodeView`]) bundled into a
//! [`RouteCtx`], mirroring what an edge orchestrator can actually
//! observe per decision. The context object is the extension point:
//! future routing axes (cost, energy, locality) add accessors to
//! `RouteCtx`/`NodeView` instead of churning every implementor's
//! `pick` signature again.

use crate::llm::GpuSpec;

/// One resident model's state at a node, as visible to routers:
/// whether its weights are warm (no swap latency on the next job) and
/// how many admitted jobs are currently running against it.
#[derive(Debug, Clone, Copy)]
pub struct ModelView {
    model: usize,
    warm: bool,
    active_jobs: u32,
}

impl ModelView {
    pub fn new(model: usize, warm: bool, active_jobs: u32) -> Self {
        Self { model, warm, active_jobs }
    }

    /// Index into the scenario's model zoo.
    pub fn model(&self) -> usize {
        self.model
    }

    /// `true` once the node has activated this model (its next job
    /// pays no swap latency).
    pub fn warm(&self) -> bool {
        self.warm
    }

    /// Jobs currently admitted against this model at the node.
    pub fn active_jobs(&self) -> u32 {
        self.active_jobs
    }
}

/// Snapshot of one node's load at routing time. For a
/// continuous-batching node, `busy_servers()` is the current batch
/// size and `n_servers()` its `max_batch` slot cap. Fields are
/// private — policies read through accessors so the engine can evolve
/// what it tracks without breaking implementors.
#[derive(Debug, Clone)]
pub struct NodeView {
    queue_len: usize,
    busy_servers: u32,
    n_servers: u32,
    gpu: GpuSpec,
    kv_headroom: f64,
    models: Vec<ModelView>,
    background_rho: f64,
}

impl NodeView {
    pub fn new(queue_len: usize, busy_servers: u32, n_servers: u32, gpu: GpuSpec) -> Self {
        Self {
            queue_len,
            busy_servers,
            n_servers,
            gpu,
            kv_headroom: f64::INFINITY,
            models: Vec::new(),
            background_rho: 0.0,
        }
    }

    /// Attach the node's free KV-cache bytes (batching nodes).
    pub fn with_kv_headroom(mut self, bytes: f64) -> Self {
        self.kv_headroom = bytes;
        self
    }

    /// Attach the mean offered load of the fluid background tier at
    /// this node, as a server utilization (`λ·s̄` per node). Zero
    /// without a fluid tier.
    pub fn with_background_rho(mut self, rho: f64) -> Self {
        self.background_rho = rho;
        self
    }

    /// Attach the node's resident-model states (model-zoo scenarios;
    /// stays empty — zero allocation — on the single-model path).
    pub fn with_models(mut self, models: Vec<ModelView>) -> Self {
        self.models = models;
        self
    }

    /// Jobs waiting in the node's queue.
    pub fn queue_len(&self) -> usize {
        self.queue_len
    }

    /// Jobs in service (batch size for a continuous-batching node).
    pub fn busy_servers(&self) -> u32 {
        self.busy_servers
    }

    /// Service slots (`max_batch` for a continuous-batching node).
    pub fn n_servers(&self) -> u32 {
        self.n_servers
    }

    /// The node's accelerator pool (capacity-aware custom routers;
    /// `gpu().display_name()` is the label to log).
    pub fn gpu(&self) -> &GpuSpec {
        &self.gpu
    }

    /// Owned copy of the accelerator spec (convenience for callers
    /// that need a `GpuSpec` by value).
    pub fn gpu_spec(&self) -> GpuSpec {
        self.gpu
    }

    /// Jobs in the system at this node (queued + in service).
    pub fn load(&self) -> usize {
        self.queue_len + self.busy_servers as usize
    }

    /// Free KV-cache bytes at this node (`f64::INFINITY` for
    /// sequential nodes, which reserve no KV).
    pub fn kv_headroom(&self) -> f64 {
        self.kv_headroom
    }

    /// Per-model states at this node (empty when no zoo is configured).
    pub fn models(&self) -> &[ModelView] {
        &self.models
    }

    /// Does this node host model `m` (zoo index)? Nodes without model
    /// state (single-model path) host everything.
    pub fn hosts(&self, m: usize) -> bool {
        self.models.is_empty() || self.models.iter().any(|v| v.model == m)
    }

    /// Is model `m` warm at this node? Model-less nodes are always
    /// warm (the single-model path charges no swap latency).
    pub fn is_warm(&self, m: usize) -> bool {
        self.models.is_empty() || self.models.iter().any(|v| v.model == m && v.warm)
    }

    /// Admitted jobs currently running model `m` at this node.
    pub fn model_jobs(&self, m: usize) -> u32 {
        self.models.iter().find(|v| v.model == m).map_or(0, |v| v.active_jobs)
    }

    /// Mean fluid-tier background load at this node (utilization
    /// units, `0.0` when no fluid tier is configured). The built-in
    /// policies ignore it; capacity-aware custom routers can subtract
    /// it from the node's effective headroom.
    pub fn background_rho(&self) -> f64 {
        self.background_rho
    }
}

/// Everything a policy may consult for one routing decision. Borrowed
/// from the engine for the duration of the call; construct with
/// [`RouteCtx::new`] in tests and custom harnesses.
#[derive(Debug)]
pub struct RouteCtx<'a> {
    class_id: usize,
    cell_id: usize,
    now: f64,
    nodes: &'a [NodeView],
    models: &'a [usize],
}

impl<'a> RouteCtx<'a> {
    /// `models` is the job's acceptable model set (zoo indices, class
    /// preference order, best first); empty means "no constraint" —
    /// the single-model path.
    pub fn new(
        class_id: usize,
        cell_id: usize,
        now: f64,
        nodes: &'a [NodeView],
        models: &'a [usize],
    ) -> Self {
        Self { class_id, cell_id, now, nodes, models }
    }

    /// Workload class of the job being routed.
    pub fn class_id(&self) -> usize {
        self.class_id
    }

    /// Originating cell (gNB) of the job.
    pub fn cell_id(&self) -> usize {
        self.cell_id
    }

    /// Simulation time of the routing decision.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Candidate nodes, indexed `0..nodes().len()`.
    pub fn nodes(&self) -> &[NodeView] {
        self.nodes
    }

    /// Acceptable models for this job (zoo indices, best first; empty
    /// = unconstrained).
    pub fn models(&self) -> &[usize] {
        self.models
    }

    /// Can node `i` serve this job at all (hosts at least one
    /// acceptable model)? Always true on the single-model path.
    pub fn eligible(&self, i: usize) -> bool {
        self.models.is_empty() || self.models.iter().any(|&m| self.nodes[i].hosts(m))
    }

    /// The model this job would run on node `i`: the first acceptable
    /// model (class preference order) the node hosts, preferring a
    /// warm copy over a cold one when both tiers are resident.
    pub fn model_for(&self, i: usize) -> Option<usize> {
        if self.models.is_empty() {
            return None;
        }
        self.models
            .iter()
            .copied()
            .find(|&m| self.nodes[i].hosts(m) && self.nodes[i].is_warm(m))
            .or_else(|| self.models.iter().copied().find(|&m| self.nodes[i].hosts(m)))
    }

    /// Package node `i` as a decision, resolving the model choice via
    /// [`RouteCtx::model_for`].
    pub fn decide(&self, node: usize) -> RouteDecision {
        RouteDecision { node, model: self.model_for(node) }
    }
}

/// A policy's answer: the node index, and (when a zoo is configured)
/// the zoo index of the model to run. `model = None` on the
/// single-model path, or when the chosen node hosts no acceptable
/// model (the engine then falls back to the class's first choice for
/// pricing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub node: usize,
    pub model: Option<usize>,
}

/// A routing decision maker. Policies may keep state (e.g. the
/// round-robin cursor); the engine calls `pick` once per job with a
/// [`RouteCtx`] describing the job and the candidate tier.
pub trait Routing: std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Choose a `(node, model)` pair for the job described by `ctx`;
    /// `decision.node` must index `0..ctx.nodes().len()`.
    fn pick(&mut self, ctx: &RouteCtx<'_>) -> RouteDecision;

    /// Opaque per-run policy state, captured by engine snapshots (the
    /// round-robin cursor). Stateless policies keep the defaults;
    /// custom routers with richer state should override both or their
    /// snapshots restore with reset routing state.
    fn cursor(&self) -> u64 {
        0
    }

    /// Restore state captured by [`Routing::cursor`].
    fn set_cursor(&mut self, _cursor: u64) {}
}

/// Send each job to the node with the fewest jobs in system (ties go
/// to the lowest index, keeping runs deterministic), considering only
/// nodes that host an acceptable model.
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl Routing for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn pick(&mut self, ctx: &RouteCtx<'_>) -> RouteDecision {
        let node = ctx
            .nodes()
            .iter()
            .enumerate()
            .filter(|(i, _)| ctx.eligible(*i))
            .min_by_key(|(_, n)| n.load())
            .map(|(i, _)| i)
            // No node hosts an acceptable model: fall back to the
            // least-loaded node overall so the job still lands
            // somewhere deterministic (the engine prices on the
            // class's first-choice model).
            .or_else(|| {
                ctx.nodes().iter().enumerate().min_by_key(|(_, n)| n.load()).map(|(i, _)| i)
            })
            .unwrap_or(0);
        ctx.decide(node)
    }
}

/// Cycle through eligible nodes regardless of load.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Routing for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn pick(&mut self, ctx: &RouteCtx<'_>) -> RouteDecision {
        let nodes = ctx.nodes();
        if nodes.is_empty() {
            return RouteDecision { node: 0, model: None };
        }
        // Advance the cursor over the full tier (so the cadence is
        // independent of per-class constraints), then walk forward to
        // the first eligible node from the cursor position.
        let start = self.next % nodes.len();
        self.next = (self.next + 1) % nodes.len();
        let node = (0..nodes.len())
            .map(|k| (start + k) % nodes.len())
            .find(|&i| ctx.eligible(i))
            .unwrap_or(start);
        ctx.decide(node)
    }

    fn cursor(&self) -> u64 {
        self.next as u64
    }

    fn set_cursor(&mut self, cursor: u64) {
        self.next = cursor as usize;
    }
}

/// Pin each workload class to one node (`class % n_nodes`) — the
/// placement that keeps per-class KV/weight state warm. With a model
/// zoo, an ineligible home node defers to the next eligible index
/// (wrapping), so the pinning stays deterministic per class.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassAffinity;

impl Routing for ClassAffinity {
    fn name(&self) -> &'static str {
        "class_affinity"
    }

    fn pick(&mut self, ctx: &RouteCtx<'_>) -> RouteDecision {
        let nodes = ctx.nodes();
        if nodes.is_empty() {
            return RouteDecision { node: 0, model: None };
        }
        let home = ctx.class_id() % nodes.len();
        let node = (0..nodes.len())
            .map(|k| (home + k) % nodes.len())
            .find(|&i| ctx.eligible(i))
            .unwrap_or(home);
        ctx.decide(node)
    }
}

/// ICC placement: serve each job at its originating gNB's node
/// (`cell % n_nodes`), spilling to the least-loaded eligible neighbor
/// only when the home node's queue exceeds `spill_queue` pending jobs
/// (`u32::MAX` = never spill — strict cell isolation). This is the
/// topology knob that makes ICC-vs-MEC comparisons expressible: ICC
/// pins compute at the RAN node that received the prompt, while a MEC
/// pool behaves like [`LeastLoaded`] over the shared site. An
/// ineligible home node (model zoo) spills immediately.
#[derive(Debug, Clone, Copy)]
pub struct CellAffinity {
    /// Home-node queue length above which jobs spill to neighbors.
    pub spill_queue: u32,
}

impl Default for CellAffinity {
    fn default() -> Self {
        Self { spill_queue: DEFAULT_SPILL_QUEUE }
    }
}

/// Default spill threshold: a handful of queued jobs before a prompt
/// is worth the extra backhaul hop.
pub const DEFAULT_SPILL_QUEUE: u32 = 8;

impl Routing for CellAffinity {
    fn name(&self) -> &'static str {
        "cell_affinity"
    }

    fn pick(&mut self, ctx: &RouteCtx<'_>) -> RouteDecision {
        let nodes = ctx.nodes();
        if nodes.is_empty() {
            return RouteDecision { node: 0, model: None };
        }
        let home = ctx.cell_id() % nodes.len();
        if ctx.eligible(home) && nodes[home].queue_len() <= self.spill_queue as usize {
            return ctx.decide(home);
        }
        // Spill: least-loaded eligible neighbor (ties to the lowest
        // index); degenerate single-node tiers fall back to the home
        // node.
        let node = nodes
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != home && ctx.eligible(*i))
            .min_by_key(|(_, n)| n.load())
            .map(|(i, _)| i)
            .unwrap_or(home);
        ctx.decide(node)
    }
}

/// Config-level routing selector (`[routing] policy = "..."`, with
/// `spill_queue` refining `cell_affinity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    #[default]
    LeastLoaded,
    RoundRobin,
    ClassAffinity,
    CellAffinity {
        spill_queue: u32,
    },
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "least_loaded" | "least-loaded" | "lld" => Some(Self::LeastLoaded),
            "round_robin" | "round-robin" | "rr" => Some(Self::RoundRobin),
            "class_affinity" | "class-affinity" | "affinity" => Some(Self::ClassAffinity),
            "cell_affinity" | "cell-affinity" | "icc" => {
                Some(Self::CellAffinity { spill_queue: DEFAULT_SPILL_QUEUE })
            }
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::LeastLoaded => "least_loaded",
            Self::RoundRobin => "round_robin",
            Self::ClassAffinity => "class_affinity",
            Self::CellAffinity { .. } => "cell_affinity",
        }
    }

    pub fn build(self) -> Box<dyn Routing> {
        match self {
            Self::LeastLoaded => Box::new(LeastLoaded),
            Self::RoundRobin => Box::<RoundRobin>::default(),
            Self::ClassAffinity => Box::new(ClassAffinity),
            Self::CellAffinity { spill_queue } => Box::new(CellAffinity { spill_queue }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(loads: &[(usize, u32)]) -> Vec<NodeView> {
        loads.iter().map(|&(q, b)| NodeView::new(q, b, 2, GpuSpec::a100())).collect()
    }

    fn pick_node(r: &mut dyn Routing, class_id: usize, cell_id: usize, v: &[NodeView]) -> usize {
        r.pick(&RouteCtx::new(class_id, cell_id, 0.0, v, &[])).node
    }

    #[test]
    fn least_loaded_picks_min_with_stable_ties() {
        let mut r = LeastLoaded;
        assert_eq!(pick_node(&mut r, 0, 0, &views(&[(3, 2), (0, 1), (2, 0)])), 1);
        // tie between 0 and 2 → lowest index
        assert_eq!(pick_node(&mut r, 0, 0, &views(&[(1, 0), (5, 1), (1, 0)])), 0);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobin::default();
        let v = views(&[(0, 0), (0, 0), (0, 0)]);
        let picks: Vec<usize> = (0..6).map(|_| pick_node(&mut r, 0, 0, &v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn class_affinity_pins_classes() {
        let mut r = ClassAffinity;
        let v = views(&[(9, 2), (0, 0)]);
        assert_eq!(pick_node(&mut r, 0, 1, &v), 0, "affinity ignores load and cell");
        assert_eq!(pick_node(&mut r, 1, 0, &v), 1);
        assert_eq!(pick_node(&mut r, 2, 0, &v), 0);
    }

    #[test]
    fn cell_affinity_serves_at_home_gnb_until_spill() {
        let mut r = CellAffinity { spill_queue: 2 };
        // home queue within threshold → stay home, whatever the load
        let v = views(&[(2, 2), (0, 0), (0, 0)]);
        assert_eq!(pick_node(&mut r, 0, 0, &v), 0);
        assert_eq!(pick_node(&mut r, 5, 1, &v), 1, "cell 1 maps to node 1");
        assert_eq!(pick_node(&mut r, 0, 4, &v), 1, "cells wrap modulo the tier size");
        // home queue above threshold → spill to least-loaded neighbor
        let v = views(&[(3, 2), (1, 1), (0, 1)]);
        assert_eq!(pick_node(&mut r, 0, 0, &v), 2);
        // never-spill configuration pins regardless of backlog
        let mut strict = CellAffinity { spill_queue: u32::MAX };
        assert_eq!(pick_node(&mut strict, 0, 0, &v), 0);
        // single-node tier cannot spill anywhere
        let v1 = views(&[(100, 2)]);
        assert_eq!(pick_node(&mut r, 0, 0, &v1), 0);
    }

    #[test]
    fn policy_parse_and_names() {
        assert_eq!(RoutingPolicy::parse("rr"), Some(RoutingPolicy::RoundRobin));
        assert_eq!(RoutingPolicy::parse("least_loaded"), Some(RoutingPolicy::LeastLoaded));
        assert_eq!(RoutingPolicy::parse("affinity"), Some(RoutingPolicy::ClassAffinity));
        assert_eq!(
            RoutingPolicy::parse("cell_affinity"),
            Some(RoutingPolicy::CellAffinity { spill_queue: DEFAULT_SPILL_QUEUE })
        );
        assert_eq!(RoutingPolicy::parse("??"), None);
        for p in [
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
            RoutingPolicy::ClassAffinity,
            RoutingPolicy::CellAffinity { spill_queue: 4 },
        ] {
            assert_eq!(p.build().name(), p.name());
        }
    }

    // --- model-aware routing ---

    fn model_views() -> Vec<NodeView> {
        // node 0 hosts model 0 (warm); node 1 hosts models {0, 1}
        // (1 warm, 0 cold); node 2 carries no model state (hosts all).
        vec![
            NodeView::new(0, 0, 2, GpuSpec::a100()).with_models(vec![ModelView::new(0, true, 3)]),
            NodeView::new(0, 0, 2, GpuSpec::a100())
                .with_models(vec![ModelView::new(0, false, 0), ModelView::new(1, true, 1)]),
            NodeView::new(0, 0, 2, GpuSpec::a100()),
        ]
    }

    #[test]
    fn node_view_accessors_expose_model_state() {
        let v = model_views();
        assert!(v[0].hosts(0) && !v[0].hosts(1));
        assert!(v[0].is_warm(0));
        assert_eq!(v[0].model_jobs(0), 3);
        assert!(v[1].hosts(1) && !v[1].is_warm(0) && v[1].is_warm(1));
        // model-less views host everything and are always warm
        assert!(v[2].hosts(7) && v[2].is_warm(7));
        assert_eq!(v[2].model_jobs(7), 0);
        assert_eq!(v[0].load(), 0);
        assert!(v[0].kv_headroom().is_infinite());
        let k = NodeView::new(1, 1, 2, GpuSpec::a100()).with_kv_headroom(42.0);
        assert_eq!(k.kv_headroom(), 42.0);
        assert_eq!(k.load(), 2);
        assert_eq!(k.gpu().display_name(), GpuSpec::a100().display_name());
    }

    #[test]
    fn eligibility_filters_nodes_and_model_for_prefers_warm() {
        let v = model_views();
        let want = [1usize]; // only model 1 acceptable
        let ctx = RouteCtx::new(0, 0, 0.0, &v, &want);
        assert!(!ctx.eligible(0));
        assert!(ctx.eligible(1));
        assert!(ctx.eligible(2), "model-less nodes serve any model");
        assert_eq!(ctx.model_for(1), Some(1));
        // preference order 0-then-1, but node 1 only has model 1 warm
        // → warm copy wins over the cold preferred tier.
        let pref = [0usize, 1];
        let ctx = RouteCtx::new(0, 0, 0.0, &v, &pref);
        assert_eq!(ctx.model_for(1), Some(1));
        assert_eq!(ctx.model_for(0), Some(0));
        // no constraint → no model in the decision
        let ctx = RouteCtx::new(0, 0, 0.0, &v, &[]);
        assert_eq!(ctx.model_for(1), None);
        assert_eq!(ctx.decide(1), RouteDecision { node: 1, model: None });
    }

    #[test]
    fn builtins_respect_model_constraints() {
        let v = model_views();
        let want = [1usize];
        // least-loaded skips node 0 (doesn't host model 1)
        let d = LeastLoaded.pick(&RouteCtx::new(0, 0, 0.0, &v, &want));
        assert_eq!(d.node, 1);
        assert_eq!(d.model, Some(1));
        // round-robin walks past ineligible nodes but keeps cadence
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> =
            (0..3).map(|_| rr.pick(&RouteCtx::new(0, 0, 0.0, &v, &want)).node).collect();
        assert_eq!(picks, vec![1, 1, 2]);
        // class-affinity defers an ineligible home to the next index
        let d = ClassAffinity.pick(&RouteCtx::new(0, 0, 0.0, &v, &want));
        assert_eq!(d.node, 1);
        // cell-affinity spills off an ineligible home immediately
        let d = CellAffinity { spill_queue: u32::MAX }.pick(&RouteCtx::new(0, 0, 0.0, &v, &want));
        assert_eq!(d.node, 1);
    }
}
