//! Routing policies over a multi-node compute tier.
//!
//! The legacy SLS owned exactly one `ComputeNode`; a scenario owns N
//! and a [`Routing`] policy decides which node serves each delivered
//! prompt. Policies see only cheap per-node load summaries
//! ([`NodeView`]), mirroring what an edge orchestrator can actually
//! observe per decision.

use crate::llm::GpuSpec;

/// Snapshot of one node's load at routing time. For a
/// continuous-batching node, `busy_servers` is the current batch size
/// and `n_servers` its `max_batch` slot cap.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    pub queue_len: usize,
    pub busy_servers: u32,
    pub n_servers: u32,
    /// The node's accelerator pool (capacity-aware custom routers;
    /// `gpu.display_name()` is the label to log).
    pub gpu: GpuSpec,
}

impl NodeView {
    /// Jobs in the system at this node (queued + in service).
    pub fn load(&self) -> usize {
        self.queue_len + self.busy_servers as usize
    }
}

/// A routing decision maker. Policies may keep state (e.g. the
/// round-robin cursor); the engine calls `pick` once per job with the
/// job's workload class and originating cell (gNB).
pub trait Routing: std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Choose a node index in `0..nodes.len()` for a job of `class_id`
    /// generated in cell `cell_id`.
    fn pick(&mut self, class_id: usize, cell_id: usize, nodes: &[NodeView]) -> usize;

    /// Opaque per-run policy state, captured by engine snapshots (the
    /// round-robin cursor). Stateless policies keep the defaults;
    /// custom routers with richer state should override both or their
    /// snapshots restore with reset routing state.
    fn cursor(&self) -> u64 {
        0
    }

    /// Restore state captured by [`Routing::cursor`].
    fn set_cursor(&mut self, _cursor: u64) {}
}

/// Send each job to the node with the fewest jobs in system (ties go
/// to the lowest index, keeping runs deterministic).
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl Routing for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn pick(&mut self, _class_id: usize, _cell_id: usize, nodes: &[NodeView]) -> usize {
        nodes
            .iter()
            .enumerate()
            .min_by_key(|(_, n)| n.load())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Cycle through nodes regardless of load.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Routing for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn pick(&mut self, _class_id: usize, _cell_id: usize, nodes: &[NodeView]) -> usize {
        if nodes.is_empty() {
            return 0;
        }
        let i = self.next % nodes.len();
        self.next = (self.next + 1) % nodes.len();
        i
    }

    fn cursor(&self) -> u64 {
        self.next as u64
    }

    fn set_cursor(&mut self, cursor: u64) {
        self.next = cursor as usize;
    }
}

/// Pin each workload class to one node (`class % n_nodes`) — the
/// placement that keeps per-class KV/weight state warm.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassAffinity;

impl Routing for ClassAffinity {
    fn name(&self) -> &'static str {
        "class_affinity"
    }

    fn pick(&mut self, class_id: usize, _cell_id: usize, nodes: &[NodeView]) -> usize {
        if nodes.is_empty() {
            return 0;
        }
        class_id % nodes.len()
    }
}

/// ICC placement: serve each job at its originating gNB's node
/// (`cell % n_nodes`), spilling to the least-loaded neighbor only when
/// the home node's queue exceeds `spill_queue` pending jobs
/// (`u32::MAX` = never spill — strict cell isolation). This is the
/// topology knob that makes ICC-vs-MEC comparisons expressible: ICC
/// pins compute at the RAN node that received the prompt, while a MEC
/// pool behaves like [`LeastLoaded`] over the shared site.
#[derive(Debug, Clone, Copy)]
pub struct CellAffinity {
    /// Home-node queue length above which jobs spill to neighbors.
    pub spill_queue: u32,
}

impl Default for CellAffinity {
    fn default() -> Self {
        Self { spill_queue: DEFAULT_SPILL_QUEUE }
    }
}

/// Default spill threshold: a handful of queued jobs before a prompt
/// is worth the extra backhaul hop.
pub const DEFAULT_SPILL_QUEUE: u32 = 8;

impl Routing for CellAffinity {
    fn name(&self) -> &'static str {
        "cell_affinity"
    }

    fn pick(&mut self, _class_id: usize, cell_id: usize, nodes: &[NodeView]) -> usize {
        if nodes.is_empty() {
            return 0;
        }
        let home = cell_id % nodes.len();
        if nodes[home].queue_len <= self.spill_queue as usize {
            return home;
        }
        // Spill: least-loaded neighbor (ties to the lowest index);
        // degenerate single-node tiers fall back to the home node.
        nodes
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != home)
            .min_by_key(|(_, n)| n.load())
            .map(|(i, _)| i)
            .unwrap_or(home)
    }
}

/// Config-level routing selector (`[routing] policy = "..."`, with
/// `spill_queue` refining `cell_affinity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    #[default]
    LeastLoaded,
    RoundRobin,
    ClassAffinity,
    CellAffinity {
        spill_queue: u32,
    },
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "least_loaded" | "least-loaded" | "lld" => Some(Self::LeastLoaded),
            "round_robin" | "round-robin" | "rr" => Some(Self::RoundRobin),
            "class_affinity" | "class-affinity" | "affinity" => Some(Self::ClassAffinity),
            "cell_affinity" | "cell-affinity" | "icc" => {
                Some(Self::CellAffinity { spill_queue: DEFAULT_SPILL_QUEUE })
            }
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::LeastLoaded => "least_loaded",
            Self::RoundRobin => "round_robin",
            Self::ClassAffinity => "class_affinity",
            Self::CellAffinity { .. } => "cell_affinity",
        }
    }

    pub fn build(self) -> Box<dyn Routing> {
        match self {
            Self::LeastLoaded => Box::new(LeastLoaded),
            Self::RoundRobin => Box::<RoundRobin>::default(),
            Self::ClassAffinity => Box::new(ClassAffinity),
            Self::CellAffinity { spill_queue } => Box::new(CellAffinity { spill_queue }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(loads: &[(usize, u32)]) -> Vec<NodeView> {
        loads
            .iter()
            .map(|&(q, b)| NodeView {
                queue_len: q,
                busy_servers: b,
                n_servers: 2,
                gpu: GpuSpec::a100(),
            })
            .collect()
    }

    #[test]
    fn least_loaded_picks_min_with_stable_ties() {
        let mut r = LeastLoaded;
        assert_eq!(r.pick(0, 0, &views(&[(3, 2), (0, 1), (2, 0)])), 1);
        // tie between 0 and 2 → lowest index
        assert_eq!(r.pick(0, 0, &views(&[(1, 0), (5, 1), (1, 0)])), 0);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobin::default();
        let v = views(&[(0, 0), (0, 0), (0, 0)]);
        let picks: Vec<usize> = (0..6).map(|_| r.pick(0, 0, &v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn class_affinity_pins_classes() {
        let mut r = ClassAffinity;
        let v = views(&[(9, 2), (0, 0)]);
        assert_eq!(r.pick(0, 1, &v), 0, "affinity ignores load and cell");
        assert_eq!(r.pick(1, 0, &v), 1);
        assert_eq!(r.pick(2, 0, &v), 0);
    }

    #[test]
    fn cell_affinity_serves_at_home_gnb_until_spill() {
        let mut r = CellAffinity { spill_queue: 2 };
        // home queue within threshold → stay home, whatever the load
        let v = views(&[(2, 2), (0, 0), (0, 0)]);
        assert_eq!(r.pick(0, 0, &v), 0);
        assert_eq!(r.pick(5, 1, &v), 1, "cell 1 maps to node 1");
        assert_eq!(r.pick(0, 4, &v), 1, "cells wrap modulo the tier size");
        // home queue above threshold → spill to least-loaded neighbor
        let v = views(&[(3, 2), (1, 1), (0, 1)]);
        assert_eq!(r.pick(0, 0, &v), 2);
        // never-spill configuration pins regardless of backlog
        let mut strict = CellAffinity { spill_queue: u32::MAX };
        assert_eq!(strict.pick(0, 0, &v), 0);
        // single-node tier cannot spill anywhere
        let v1 = views(&[(100, 2)]);
        assert_eq!(r.pick(0, 0, &v1), 0);
    }

    #[test]
    fn policy_parse_and_names() {
        assert_eq!(RoutingPolicy::parse("rr"), Some(RoutingPolicy::RoundRobin));
        assert_eq!(RoutingPolicy::parse("least_loaded"), Some(RoutingPolicy::LeastLoaded));
        assert_eq!(RoutingPolicy::parse("affinity"), Some(RoutingPolicy::ClassAffinity));
        assert_eq!(
            RoutingPolicy::parse("cell_affinity"),
            Some(RoutingPolicy::CellAffinity { spill_queue: DEFAULT_SPILL_QUEUE })
        );
        assert_eq!(RoutingPolicy::parse("??"), None);
        for p in [
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
            RoutingPolicy::ClassAffinity,
            RoutingPolicy::CellAffinity { spill_queue: 4 },
        ] {
            assert_eq!(p.build().name(), p.name());
        }
    }
}
