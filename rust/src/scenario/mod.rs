//! The composable Scenario API — the crate's simulation entry point.
//!
//! The legacy [`crate::sim::Sls`] ran exactly one job class, one
//! deterministic service time, and one compute node. A [`Scenario`]
//! instead assembles:
//!
//! * N [`WorkloadClass`]es (own arrival rate, token distributions,
//!   model constants, and latency budget each),
//! * K gNB cells ([`CellSpec`]: per-cell UE population, MAC config and
//!   PHY numerology), each owning its own `UeBank`/slot pipeline and
//!   steppable on worker threads ([`ScenarioBuilder::threads`]) with
//!   bit-identical results,
//! * a pluggable [`ServiceModel`] (deterministic roofline or per-job
//!   token-sampled prefill/decode),
//! * M compute nodes behind a [`Routing`] policy (least-loaded,
//!   round-robin, class-affinity, or cell-affinity — the ICC "serve at
//!   the originating gNB, spill to neighbors" placement),
//!
//! on top of the same 5G uplink SLS substrate (PHY/MAC/traffic). The
//! legacy API is preserved as a thin wrapper: `Sls::new(cfg)` builds a
//! single-class scenario via [`ScenarioBuilder::from_sim_config`]
//! whose event loop preserves the legacy `Sls::run` semantics (same
//! handler logic, per-entity substreams, deterministic per seed; the
//! substream *ids* were re-spaced to kill a >4096-UE aliasing bug, so
//! per-seed realizations differ from the seed repo's).
//!
//! ```no_run
//! use icc6g::config::SchemeConfig;
//! use icc6g::llm::GpuSpec;
//! use icc6g::scenario::{RoutingPolicy, ScenarioBuilder, ServiceModelKind, WorkloadClass};
//!
//! let result = ScenarioBuilder::new()
//!     .scheme(SchemeConfig::icc())
//!     .n_ues(60)
//!     .workload(WorkloadClass::chat())
//!     .workload(WorkloadClass::translation())
//!     .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
//!     .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
//!     .service_kind(ServiceModelKind::TokenSampled)
//!     .routing(RoutingPolicy::LeastLoaded)
//!     .build()
//!     .run();
//! for class in &result.report.per_class {
//!     println!("{}: {:.3}", class.name, class.satisfaction_rate());
//! }
//! ```

pub mod cells;
mod engine;
pub mod fluid;
pub mod routing;
pub mod service;
pub mod workload;

pub use cells::{cell_seed, CellSpec, CellSync, HandoverSpec};
pub use engine::{discipline_of, management_of, ScenarioEngine, ScenarioResult};
pub use fluid::{FluidCellReport, FluidClassReport, FluidReport, FluidSpec};
pub use routing::{
    CellAffinity, ClassAffinity, LeastLoaded, ModelView, NodeView, RouteCtx,
    RouteDecision, RoundRobin, Routing, RoutingPolicy,
};
pub use service::{
    RooflineService, ServiceDemand, ServiceModel, ServiceModelKind, TokenSampledService,
};
pub use workload::{workloads_from_toml, workloads_to_toml, TokenDist, WorkloadClass};

pub use crate::cluster::{AutoscalerKind, ClusterSpec, NodeChurnSpec};
pub use crate::compute::ExecutionModel;
pub use crate::llm::ModelSpec;
pub use crate::dess::EventListKind;
pub use crate::phy::geometry::{SiteLayout, TopologySpec};
pub use crate::phy::mobility::{MobilityModel, MobilitySpec};

use crate::config::{typed_f64, typed_i64, typed_str, SchemeConfig, SimConfig};
use crate::llm::GpuSpec;
use crate::util::tomlmini::Document;

/// One compute node of the tier: an aggregated accelerator pool, its
/// number of parallel servers, and how it executes jobs
/// ([`ExecutionModel::Sequential`] whole-job occupancy vs
/// [`ExecutionModel::ContinuousBatching`] iteration-level batching).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSpec {
    pub gpu: GpuSpec,
    pub n_servers: u32,
    pub execution: ExecutionModel,
    /// Bitmask of zoo models resident on this node (bit `i` = model
    /// `i` of [`Scenario::models`]). `0` = the legacy "hosts every
    /// model" default, which also keeps zoo-free scenarios
    /// bit-identical to the seed. Capped at 64 zoo models.
    pub resident_models: u64,
    /// Model-swap latency (s) charged to the first job that activates
    /// a cold resident model on this node (weights already in HBM;
    /// this prices CUDA-graph/page-table activation, not PCIe loads).
    pub swap_s: f64,
}

impl NodeSpec {
    /// Whether zoo model `m` can serve on this node (an empty resident
    /// set hosts everything — the legacy single-model default).
    pub fn hosts_model(&self, m: usize) -> bool {
        self.resident_models == 0 || (self.resident_models >> m) & 1 == 1
    }
}

/// Factory producing a fresh router per run (routers may keep per-run
/// state, e.g. the round-robin cursor).
type RouterFactory = Box<dyn Fn() -> Box<dyn Routing>>;

/// A fully-assembled scenario. `run` is `&self` and fully
/// deterministic: calling it again reproduces the identical
/// trajectory. Independent replications need distinct seeds — build
/// one scenario per seed via [`ScenarioBuilder::seed`] (as the
/// coordinator sweeps do).
pub struct Scenario {
    pub(crate) base: SimConfig,
    pub(crate) classes: Vec<WorkloadClass>,
    /// The gNBs of the scenario (never empty after `build`; a legacy
    /// single-cell scenario has exactly one, mirrored from `base`).
    pub(crate) cells: Vec<CellSpec>,
    pub(crate) nodes: Vec<NodeSpec>,
    /// The model zoo (`[[model]]` tables / [`ScenarioBuilder::model`]).
    /// Empty = legacy single-model semantics: every class prices on its
    /// own `c_llm`/`m_llm` and routing is model-blind, bit for bit.
    pub(crate) models: Vec<ModelSpec>,
    pub(crate) service: Box<dyn ServiceModel>,
    pub(crate) routing: RoutingPolicy,
    pub(crate) router_factory: Option<RouterFactory>,
    /// Worker threads stepping cells inside `run` (1 = serial, 0 = all
    /// cores). Never changes the results, only the wall clock.
    pub(crate) cell_threads: usize,
    /// Threaded synchronization protocol: conservative frontier PDES
    /// (default) or the legacy per-slot barrier pool. Never changes the
    /// results, only the wall clock.
    pub(crate) cell_sync: CellSync,
    /// Site layout; `Some` switches the radio stack from the fixed
    /// interference margin + static UEs to geometry-driven coupling.
    pub(crate) topology: Option<TopologySpec>,
    /// UE motion model (requires a topology).
    pub(crate) mobility: Option<MobilitySpec>,
    /// A3 handover (requires a topology).
    pub(crate) handover: Option<HandoverSpec>,
    /// Hybrid-fidelity background tier (requires a topology): cells
    /// beyond the focus neighborhood run the fluid mean-field model
    /// of DESIGN.md §15 instead of the per-UE slot pipeline.
    pub(crate) fluid: Option<fluid::FluidSpec>,
    /// Event-list backend of the engine's calendar.
    pub(crate) event_queue: EventListKind,
    /// Elastic control plane (`None` = static always-healthy tier; the
    /// engine then schedules no cluster events and draws no cluster
    /// RNG, keeping the disabled path bit-identical by construction).
    pub(crate) cluster: Option<ClusterSpec>,
    /// Per-node churn parameters, parallel to `nodes`.
    pub(crate) node_churn: Vec<NodeChurnSpec>,
}

impl std::fmt::Debug for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scenario")
            .field("base", &self.base)
            .field("classes", &self.classes)
            .field("cells", &self.cells)
            .field("nodes", &self.nodes)
            .field("models", &self.models)
            .field("service", &self.service)
            .field("routing", &self.routing)
            .field("custom_router", &self.router_factory.is_some())
            .field("cell_threads", &self.cell_threads)
            .field("cell_sync", &self.cell_sync)
            .field("topology", &self.topology)
            .field("mobility", &self.mobility)
            .field("handover", &self.handover)
            .field("fluid", &self.fluid)
            .field("event_queue", &self.event_queue)
            .field("cluster", &self.cluster)
            .field("node_churn", &self.node_churn)
            .finish()
    }
}

impl Scenario {
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder::new()
    }

    /// Run the simulation and aggregate per-class + overall reports.
    pub fn run(&self) -> ScenarioResult {
        engine::run(self)
    }

    pub fn classes(&self) -> &[WorkloadClass] {
        &self.classes
    }

    /// The gNBs of the scenario (at least one).
    pub fn cells(&self) -> &[CellSpec] {
        &self.cells
    }

    /// Total UE population across all cells.
    pub fn total_ues(&self) -> u32 {
        self.cells.iter().map(|c| c.n_ues).sum()
    }

    /// Worker threads stepping cells inside `run` (1 = serial).
    pub fn threads(&self) -> usize {
        self.cell_threads
    }

    /// Threaded cell-synchronization protocol (frontier PDES or the
    /// legacy barrier pool; irrelevant when `threads() <= 1`).
    pub fn cell_sync(&self) -> CellSync {
        self.cell_sync
    }

    /// The site layout of a coupled-radio scenario (None = legacy
    /// radio-independent cells).
    pub fn topology(&self) -> Option<&TopologySpec> {
        self.topology.as_ref()
    }

    pub fn mobility(&self) -> Option<&MobilitySpec> {
        self.mobility.as_ref()
    }

    pub fn handover(&self) -> Option<&HandoverSpec> {
        self.handover.as_ref()
    }

    /// The hybrid-fidelity background tier (`None` = every cell runs
    /// the full per-UE pipeline).
    pub fn fluid(&self) -> Option<&fluid::FluidSpec> {
        self.fluid.as_ref()
    }

    /// The engine's event-list backend.
    pub fn event_queue(&self) -> EventListKind {
        self.event_queue
    }

    /// The elastic control plane (`None` = static tier).
    pub fn cluster(&self) -> Option<&ClusterSpec> {
        self.cluster.as_ref()
    }

    /// Per-node churn parameters (parallel to [`Scenario::nodes`]).
    pub fn node_churn(&self) -> &[NodeChurnSpec] {
        &self.node_churn
    }

    pub fn nodes(&self) -> &[NodeSpec] {
        &self.nodes
    }

    /// The model zoo (empty = legacy single-model semantics).
    pub fn models(&self) -> &[ModelSpec] {
        &self.models
    }

    /// Per-class accept-lists resolved to zoo indices (best model
    /// first, as declared). Empty inner list = class accepts any
    /// model. Names were validated at build time.
    pub(crate) fn class_model_ids(&self) -> Vec<Vec<usize>> {
        self.classes
            .iter()
            .map(|c| {
                c.models
                    .iter()
                    .map(|name| {
                        self.models
                            .iter()
                            .position(|m| &m.name == name)
                            .expect("class model validated at build time")
                    })
                    .collect()
            })
            .collect()
    }

    pub fn scheme(&self) -> &SchemeConfig {
        &self.base.scheme
    }

    /// The configured built-in policy (ignored when a custom router
    /// was installed via [`ScenarioBuilder::routing_model`]).
    pub fn routing(&self) -> RoutingPolicy {
        self.routing
    }

    /// A fresh router for one run.
    pub(crate) fn make_router(&self) -> Box<dyn Routing> {
        match &self.router_factory {
            Some(factory) => factory(),
            None => self.routing.build(),
        }
    }

    pub fn service_name(&self) -> &'static str {
        self.service.name()
    }

    /// Total offered job rate across all cells (jobs/s, all classes).
    pub fn offered_rate(&self) -> f64 {
        self.total_ues() as f64 * self.classes.iter().map(|c| c.rate_per_ue).sum::<f64>()
    }

    /// Structural config fingerprint stamped into snapshots.
    ///
    /// Two scenarios share a fingerprint iff a snapshot taken under one
    /// restores exactly into the other. Deliberately **excluded**:
    ///
    /// * arrival rates (`rate_per_ue` / `[[workload.rate_phase]]`, and
    ///   the legacy `job_traffic.rate_per_ue` mirror) — the warm-start
    ///   sweep forks one warm snapshot across a rate grid; future
    ///   arrivals are drawn from RNG streams whose positions the
    ///   snapshot carries, so past state is rate-independent,
    /// * `cell_threads` / `cell_sync` — thread count and sync protocol
    ///   never change results, so a snapshot taken at 1 thread restores
    ///   bit-identically at 8.
    ///
    /// Everything else that shapes the trajectory (populations, MAC/PHY
    /// config, topology, nodes, service model, routing, cluster spec,
    /// seed, horizon) is hashed via its canonical `Debug` form.
    pub fn fingerprint(&self) -> u64 {
        use std::fmt::Write;
        let mut s = String::new();
        let mut base = self.base.clone();
        base.job_traffic.rate_per_ue = 0.0;
        let _ = write!(s, "base={base:?};");
        for c in &self.classes {
            let mut c = c.clone();
            c.rate_per_ue = 0.0;
            c.rate_phases.clear();
            let _ = write!(s, "class={c:?};");
        }
        let _ = write!(
            s,
            "cells={:?};nodes={:?};models={:?};routing={:?};custom_router={};service={:?};\
             topology={:?};mobility={:?};handover={:?};fluid={:?};event_queue={:?};\
             cluster={:?};churn={:?};",
            self.cells,
            self.nodes,
            self.models,
            self.routing,
            self.router_factory.is_some(),
            self.service,
            self.topology,
            self.mobility,
            self.handover,
            self.fluid,
            self.event_queue,
            self.cluster,
            self.node_churn,
        );
        crate::snapshot::fnv1a(s.as_bytes())
    }
}

/// Assembles a [`Scenario`] from workload classes, a compute tier, a
/// service model and a routing policy, on top of a radio/scheme base
/// (Table I defaults unless overridden).
pub struct ScenarioBuilder {
    base: SimConfig,
    classes: Vec<WorkloadClass>,
    cells: Vec<CellSpec>,
    nodes: Vec<NodeSpec>,
    models: Vec<ModelSpec>,
    /// Per-node resident-model *names*, parallel to `nodes`; resolved
    /// to `NodeSpec::resident_models` bitmasks at build time so nodes
    /// may be declared before (or without) the zoo they reference.
    node_models: Vec<Vec<String>>,
    service: Box<dyn ServiceModel>,
    routing: RoutingPolicy,
    router_factory: Option<RouterFactory>,
    cell_threads: usize,
    cell_sync: CellSync,
    topology: Option<TopologySpec>,
    mobility: Option<MobilitySpec>,
    handover: Option<HandoverSpec>,
    fluid: Option<fluid::FluidSpec>,
    event_queue: EventListKind,
    cluster: Option<ClusterSpec>,
    node_churn: Vec<NodeChurnSpec>,
}

impl std::fmt::Debug for ScenarioBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScenarioBuilder")
            .field("base", &self.base)
            .field("classes", &self.classes)
            .field("cells", &self.cells)
            .field("nodes", &self.nodes)
            .field("models", &self.models)
            .field("node_models", &self.node_models)
            .field("service", &self.service)
            .field("routing", &self.routing)
            .field("custom_router", &self.router_factory.is_some())
            .field("cell_threads", &self.cell_threads)
            .field("cell_sync", &self.cell_sync)
            .field("topology", &self.topology)
            .field("mobility", &self.mobility)
            .field("handover", &self.handover)
            .field("fluid", &self.fluid)
            .field("event_queue", &self.event_queue)
            .field("cluster", &self.cluster)
            .field("node_churn", &self.node_churn)
            .finish()
    }
}

impl Default for ScenarioBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ScenarioBuilder {
    pub fn new() -> Self {
        Self {
            base: SimConfig::table1(),
            classes: Vec::new(),
            cells: Vec::new(),
            nodes: Vec::new(),
            models: Vec::new(),
            node_models: Vec::new(),
            service: Box::new(RooflineService),
            routing: RoutingPolicy::LeastLoaded,
            router_factory: None,
            cell_threads: 1,
            cell_sync: CellSync::Frontier,
            topology: None,
            mobility: None,
            handover: None,
            fluid: None,
            // near-sorted slot/arrival schedules are the calendar
            // queue's home turf; pop order (and hence every result) is
            // backend-independent
            event_queue: EventListKind::Calendar,
            cluster: None,
            node_churn: Vec::new(),
        }
    }

    /// Mirror a legacy [`SimConfig`] as a single-class, single-cell,
    /// single-node scenario (the [`crate::sim::Sls`] compatibility
    /// path).
    pub fn from_sim_config(cfg: &SimConfig) -> Self {
        Self {
            base: cfg.clone(),
            classes: vec![WorkloadClass::from_legacy(&cfg.job_traffic, &cfg.job)],
            cells: Vec::new(),
            nodes: vec![NodeSpec {
                gpu: cfg.gpu,
                n_servers: cfg.n_gpus,
                execution: ExecutionModel::Sequential,
                resident_models: 0,
                swap_s: 0.0,
            }],
            models: Vec::new(),
            node_models: vec![Vec::new()],
            service: Box::new(RooflineService),
            routing: RoutingPolicy::LeastLoaded,
            router_factory: None,
            cell_threads: 1,
            cell_sync: CellSync::Frontier,
            topology: None,
            mobility: None,
            handover: None,
            fluid: None,
            event_queue: EventListKind::Calendar,
            cluster: None,
            node_churn: vec![NodeChurnSpec::default()],
        }
    }

    /// Apply a scheme (also syncs the MAC priority flag).
    pub fn scheme(mut self, scheme: SchemeConfig) -> Self {
        self.base = self.base.with_scheme(scheme);
        self
    }

    pub fn n_ues(mut self, n: u32) -> Self {
        assert!(n >= 1);
        self.base.n_ues = n;
        self
    }

    pub fn horizon(mut self, seconds: f64) -> Self {
        assert!(seconds > 0.0);
        self.base.horizon = seconds;
        self
    }

    pub fn warmup(mut self, seconds: f64) -> Self {
        assert!(seconds >= 0.0);
        self.base.warmup = seconds;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.base.seed = seed;
        self
    }

    /// Add one workload class.
    pub fn workload(mut self, class: WorkloadClass) -> Self {
        self.classes.push(class);
        self
    }

    /// Add one gNB cell. An empty cell list builds the legacy
    /// single-cell scenario from the base config (`n_ues`, MAC,
    /// carrier); the first explicit cell replaces that default.
    pub fn cell(mut self, spec: CellSpec) -> Self {
        self.cells.push(spec);
        self
    }

    /// Add `count` identical cells.
    pub fn cells(mut self, count: usize, spec: CellSpec) -> Self {
        assert!(count >= 1);
        for _ in 0..count {
            self.cells.push(spec);
        }
        self
    }

    /// Worker threads stepping cells inside `run` (default 1 = serial;
    /// 0 = all cores). Thread count never changes the results — the
    /// engine merges per-cell events in cell-index order either way.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cell_threads = threads;
        self
    }

    /// Pick the threaded synchronization protocol (default:
    /// [`CellSync::Frontier`], the conservative PDES; the per-slot
    /// [`CellSync::Barrier`] pool is kept for A/B benchmarking).
    /// Never changes the results, only the wall clock.
    pub fn cell_sync(mut self, sync: CellSync) -> Self {
        self.cell_sync = sync;
        self
    }

    /// Place the cells on a site grid and couple their radios:
    /// neighbor-cell interference becomes a dynamic
    /// interference-over-thermal term computed from previous-slot
    /// granted-PRB activity (replacing the fixed margin), and UEs get
    /// global positions. Without a topology the scenario keeps the
    /// legacy radio-independent cells bit for bit.
    pub fn topology(mut self, topo: TopologySpec) -> Self {
        self.topology = Some(topo);
        self
    }

    /// UE motion on a coarse tick (requires [`ScenarioBuilder::topology`]).
    pub fn mobility(mut self, mob: MobilitySpec) -> Self {
        self.mobility = Some(mob);
        self
    }

    /// A3 handover between coupled cells (requires
    /// [`ScenarioBuilder::topology`]).
    pub fn handover(mut self, ho: HandoverSpec) -> Self {
        self.handover = Some(ho);
        self
    }

    /// Enable the hybrid-fidelity background tier (requires
    /// [`ScenarioBuilder::topology`]): cells farther than
    /// `spec.rings` ring-distance from every focus site run the fluid
    /// mean-field model of DESIGN.md §15 instead of the per-UE slot
    /// pipeline. A focus set covering every cell is bit-identical to
    /// no fluid tier at all.
    pub fn fluid(mut self, spec: fluid::FluidSpec) -> Self {
        self.fluid = Some(spec);
        self
    }

    /// Event-list backend of the engine's calendar (default: calendar
    /// queue; the heap fallback is observationally identical).
    pub fn event_queue(mut self, kind: EventListKind) -> Self {
        self.event_queue = kind;
        self
    }

    /// Add one compute node (sequential whole-job execution).
    pub fn node(self, gpu: GpuSpec, n_servers: u32) -> Self {
        self.node_exec(gpu, n_servers, ExecutionModel::Sequential)
    }

    /// Add one compute node with an explicit execution model.
    /// Continuous-batching nodes must have `n_servers = 1` (the engine
    /// *is* the server); `kv_budget = 0.0` derives the budget at build
    /// time as `mem_bytes − max class m_llm`.
    pub fn node_exec(
        mut self,
        gpu: GpuSpec,
        n_servers: u32,
        execution: ExecutionModel,
    ) -> Self {
        assert!(n_servers >= 1);
        self.nodes.push(NodeSpec {
            gpu,
            n_servers,
            execution,
            resident_models: 0,
            swap_s: 0.0,
        });
        self.node_churn.push(NodeChurnSpec::default());
        self.node_models.push(Vec::new());
        self
    }

    /// Add one model tier to the zoo. Zoo order is catalog order:
    /// classes and nodes reference models by name, reports slice by
    /// it. An empty zoo keeps legacy single-model semantics bit for
    /// bit.
    pub fn model(mut self, spec: ModelSpec) -> Self {
        self.models.push(spec);
        self
    }

    /// Restrict the most recently added node to a resident model set
    /// (call after [`ScenarioBuilder::node`]; names resolve against
    /// the zoo at build time). Without this call a node hosts every
    /// model.
    pub fn node_models<S: AsRef<str>>(mut self, names: &[S]) -> Self {
        let i = self
            .nodes
            .len()
            .checked_sub(1)
            .expect("node_models() must follow a node()");
        self.node_models[i] = names.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    /// Model-swap latency for the most recently added node (call after
    /// [`ScenarioBuilder::node`]): charged once per cold-model
    /// activation.
    pub fn node_swap_s(mut self, swap_s: f64) -> Self {
        let i = self
            .nodes
            .len()
            .checked_sub(1)
            .expect("node_swap_s() must follow a node()");
        self.nodes[i].swap_s = swap_s;
        self
    }

    /// Enable the elastic control plane (DESIGN.md §11): node lifecycle
    /// events, an autoscaler on a coarse control tick, re-dispatch of
    /// work lost to failures, and per-node cost/energy accounting.
    pub fn cluster(mut self, spec: ClusterSpec) -> Self {
        self.cluster = Some(spec);
        self
    }

    /// Churn parameters for the most recently added node (call after
    /// [`ScenarioBuilder::node`]; requires a [`ScenarioBuilder::cluster`]
    /// at build time when the MTBF is finite).
    pub fn node_churn(mut self, churn: NodeChurnSpec) -> Self {
        let i = self
            .nodes
            .len()
            .checked_sub(1)
            .expect("node_churn() must follow a node()");
        self.node_churn[i] = churn;
        self
    }

    /// Install an arbitrary service model implementation.
    pub fn service_model(mut self, model: Box<dyn ServiceModel>) -> Self {
        self.service = model;
        self
    }

    /// Install one of the built-in service models.
    pub fn service_kind(self, kind: ServiceModelKind) -> Self {
        self.service_model(kind.build())
    }

    pub fn routing(mut self, policy: RoutingPolicy) -> Self {
        self.routing = policy;
        self.router_factory = None;
        self
    }

    /// Install a custom [`Routing`] implementation. The factory is
    /// invoked once per `run` so router state (cursors, histories)
    /// stays per-run.
    pub fn routing_model(
        mut self,
        factory: impl Fn() -> Box<dyn Routing> + 'static,
    ) -> Self {
        self.router_factory = Some(Box::new(factory));
        self
    }

    /// Override builder state from a TOML document: `[scenario]` /
    /// `[scheme]` / `[service]` / `[routing]` tables plus
    /// `[[workload]]`, `[[node]]` and `[[cell]]` arrays. Unknown keys
    /// error.
    pub fn apply_toml(mut self, doc: &Document) -> anyhow::Result<Self> {
        for key in doc.keys() {
            let structural = [
                // longest prefix first: "workload.rate_phase.0.class"
                // must resolve against the rate_phase array, not as a
                // malformed member of the workload array.
                ("workload.rate_phase.", "workload.rate_phase"),
                ("workload.", "workload"),
                ("node.", "node"),
                ("cell.", "cell"),
                // no collision with [mobility] model: that key is
                // "mobility.model", which does not start with "model."
                ("model.", "model"),
            ]
            .into_iter()
            .find_map(|(p, name)| key.strip_prefix(p).map(|rest| (rest, name)));
            if let Some((rest, name)) = structural {
                // Parsed structurally below — but only `[[...]]` tables
                // flatten to "<name>.<idx>.<field>" AND register an
                // array count. A plain `[workload]` (or a hand-written
                // `[workload.0]`) would otherwise be silently dropped.
                let consumed = rest
                    .split('.')
                    .next()
                    .and_then(|s| s.parse::<usize>().ok())
                    .map_or(false, |i| i < doc.array_len(name));
                if !consumed {
                    anyhow::bail!("'{key}': use [[{name}]] array-of-tables syntax");
                }
                continue;
            }
            match key {
                // Values are pulled through the shared typed helpers
                // after this name-validation loop.
                "scenario.n_ues" | "scenario.horizon" | "scenario.warmup"
                | "scenario.seed" | "scenario.threads" | "scenario.cell_sync"
                | "scenario.event_queue"
                | "service.model" | "routing.policy" | "routing.spill_queue"
                | "topology.layout" | "topology.isd" | "mobility.model"
                | "mobility.speed" | "mobility.v_min" | "mobility.v_max"
                | "mobility.tick_s" | "mobility.shadow_corr_m"
                | "handover.hysteresis_db" | "handover.ttt_s"
                | "handover.interruption_slots"
                | "fluid.focus" | "fluid.rings" | "fluid.tick_s"
                | "fluid.relax_s" | "cluster.policy"
                | "cluster.tick_s" | "cluster.min_nodes" | "cluster.max_nodes"
                | "cluster.retry_budget" | "cluster.ttft_slo"
                | "cluster.queue_high" | "cluster.queue_low"
                | "cluster.slo_violation_frac" => {}
                // apply_scheme_toml owns the [scheme] key set and
                // rejects unknown or mistyped ones.
                k if k.starts_with("scheme.") => {}
                other => anyhow::bail!("unknown scenario key '{other}'"),
            }
        }
        if let Some(v) = typed_i64(doc, "scenario.n_ues")? {
            if !(1..=1_000_000).contains(&v) {
                anyhow::bail!("'scenario.n_ues' must be in 1..=1000000, got {v}");
            }
            self.base.n_ues = v as u32;
        }
        if let Some(v) = typed_f64(doc, "scenario.horizon")? {
            if v <= 0.0 {
                anyhow::bail!("'scenario.horizon' must be positive, got {v}");
            }
            self.base.horizon = v;
        }
        if let Some(v) = typed_f64(doc, "scenario.warmup")? {
            if v < 0.0 {
                anyhow::bail!("'scenario.warmup' must be >= 0, got {v}");
            }
            self.base.warmup = v;
        }
        if let Some(v) = typed_i64(doc, "scenario.seed")? {
            if v < 0 {
                anyhow::bail!("'scenario.seed' must be >= 0, got {v}");
            }
            self.base.seed = v as u64;
        }
        if let Some(v) = typed_i64(doc, "scenario.threads")? {
            if !(0..=1024).contains(&v) {
                anyhow::bail!("'scenario.threads' must be in 0..=1024, got {v}");
            }
            self.cell_threads = v as usize;
        }
        if let Some(s) = typed_str(doc, "scenario.cell_sync")? {
            self.cell_sync = CellSync::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown cell_sync '{s}' (frontier | barrier)"))?;
        }
        if let Some(s) = typed_str(doc, "scenario.event_queue")? {
            self.event_queue = EventListKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown event_queue '{s}' (calendar | heap)"))?;
        }
        // [topology]: layout + inter-site distance. Presence of either
        // key enables geometry-driven coupling.
        let topo_layout = typed_str(doc, "topology.layout")?;
        let topo_isd = typed_f64(doc, "topology.isd")?;
        if topo_layout.is_some() || topo_isd.is_some() {
            let layout = match topo_layout {
                Some(s) => SiteLayout::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("unknown topology layout '{s}' (hex | linear)")
                })?,
                None => SiteLayout::Hex,
            };
            let isd = topo_isd
                .ok_or_else(|| anyhow::anyhow!("'topology.isd' is required with [topology]"))?;
            if !(1.0..=1e6).contains(&isd) {
                anyhow::bail!("'topology.isd' must be in 1..=1e6 meters, got {isd}");
            }
            self.topology = Some(TopologySpec { layout, isd_m: isd });
        }
        // [mobility]: model + speeds + tick.
        let mob_model = typed_str(doc, "mobility.model")?;
        if mob_model.is_some()
            || doc.get("mobility.speed").is_some()
            || doc.get("mobility.v_min").is_some()
            || doc.get("mobility.v_max").is_some()
            || doc.get("mobility.tick_s").is_some()
            || doc.get("mobility.shadow_corr_m").is_some()
        {
            let speed = typed_f64(doc, "mobility.speed")?;
            let v_min = typed_f64(doc, "mobility.v_min")?;
            let v_max = typed_f64(doc, "mobility.v_max")?;
            for (k, v) in [("speed", speed), ("v_min", v_min), ("v_max", v_max)] {
                if let Some(v) = v {
                    if !(0.0..=1e3).contains(&v) {
                        anyhow::bail!("'mobility.{k}' must be in 0..=1000 m/s, got {v}");
                    }
                }
            }
            let model = match mob_model.unwrap_or("fixed") {
                "fixed" | "fixed_velocity" => {
                    if v_min.is_some() || v_max.is_some() {
                        anyhow::bail!("'mobility.v_min'/'v_max' require model = \"waypoint\"");
                    }
                    MobilityModel::FixedVelocity {
                        speed: speed.ok_or_else(|| {
                            anyhow::anyhow!("'mobility.speed' is required for the fixed model")
                        })?,
                    }
                }
                "waypoint" | "random_waypoint" => {
                    if speed.is_some() {
                        anyhow::bail!("'mobility.speed' is for the fixed model; use v_min/v_max");
                    }
                    let lo = v_min.ok_or_else(|| {
                        anyhow::anyhow!("'mobility.v_min' is required for the waypoint model")
                    })?;
                    let hi = v_max.ok_or_else(|| {
                        anyhow::anyhow!("'mobility.v_max' is required for the waypoint model")
                    })?;
                    if hi < lo {
                        anyhow::bail!("'mobility.v_max' must be >= v_min");
                    }
                    MobilityModel::RandomWaypoint { v_min: lo, v_max: hi }
                }
                other => anyhow::bail!("unknown mobility model '{other}' (fixed | waypoint)"),
            };
            let mut spec = MobilitySpec {
                model,
                tick_s: MobilitySpec::DEFAULT_TICK_S,
                shadow_corr_m: None,
            };
            if let Some(t) = typed_f64(doc, "mobility.tick_s")? {
                if !(1e-4..=10.0).contains(&t) {
                    anyhow::bail!("'mobility.tick_s' must be in 0.0001..=10 s, got {t}");
                }
                spec.tick_s = t;
            }
            if let Some(d) = typed_f64(doc, "mobility.shadow_corr_m")? {
                if !(0.1..=1e5).contains(&d) {
                    anyhow::bail!(
                        "'mobility.shadow_corr_m' must be in 0.1..=1e5 meters, got {d}"
                    );
                }
                spec.shadow_corr_m = Some(d);
            }
            self.mobility = Some(spec);
        }
        // [handover]: A3 parameters; any key enables it.
        if doc.get("handover.hysteresis_db").is_some()
            || doc.get("handover.ttt_s").is_some()
            || doc.get("handover.interruption_slots").is_some()
        {
            let mut ho = HandoverSpec::default();
            if let Some(v) = typed_f64(doc, "handover.hysteresis_db")? {
                if !(0.0..=30.0).contains(&v) {
                    anyhow::bail!("'handover.hysteresis_db' must be in 0..=30 dB, got {v}");
                }
                ho.hysteresis_db = v;
            }
            if let Some(v) = typed_f64(doc, "handover.ttt_s")? {
                if !(0.0..=10.0).contains(&v) {
                    anyhow::bail!("'handover.ttt_s' must be in 0..=10 s, got {v}");
                }
                ho.ttt_s = v;
            }
            if let Some(v) = typed_i64(doc, "handover.interruption_slots")? {
                if !(0..=100_000).contains(&v) {
                    anyhow::bail!("'handover.interruption_slots' must be in 0..=100000, got {v}");
                }
                ho.interruption_slots = v as u64;
            }
            self.handover = Some(ho);
        }
        // [fluid]: hybrid-fidelity background tier; any key enables it.
        if doc.get("fluid.focus").is_some()
            || doc.get("fluid.rings").is_some()
            || doc.get("fluid.tick_s").is_some()
            || doc.get("fluid.relax_s").is_some()
        {
            let mut spec = self.fluid.unwrap_or_default();
            if let Some(s) = typed_str(doc, "fluid.focus")? {
                // comma-separated cell indices, e.g. "0,3,7"
                spec.focus = s
                    .split(',')
                    .map(str::trim)
                    .filter(|t| !t.is_empty())
                    .map(|t| {
                        t.parse::<usize>().map_err(|_| {
                            anyhow::anyhow!("'fluid.focus': bad cell index '{t}'")
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?;
                if spec.focus.is_empty() {
                    anyhow::bail!("'fluid.focus' must name at least one cell");
                }
            }
            if let Some(v) = typed_i64(doc, "fluid.rings")? {
                if !(0..=64).contains(&v) {
                    anyhow::bail!("'fluid.rings' must be in 0..=64, got {v}");
                }
                spec.rings = v as u32;
            }
            if let Some(v) = typed_f64(doc, "fluid.tick_s")? {
                if !(1e-4..=10.0).contains(&v) {
                    anyhow::bail!("'fluid.tick_s' must be in 0.0001..=10 s, got {v}");
                }
                spec.tick_s = v;
            }
            if let Some(v) = typed_f64(doc, "fluid.relax_s")? {
                if !(1e-4..=1e4).contains(&v) {
                    anyhow::bail!("'fluid.relax_s' must be in 0.0001..=1e4 s, got {v}");
                }
                spec.relax_s = v;
            }
            self.fluid = Some(spec);
        }
        // [cluster]: elastic control plane; any key enables it.
        const CLUSTER_KEYS: [&str; 9] = [
            "cluster.policy",
            "cluster.tick_s",
            "cluster.min_nodes",
            "cluster.max_nodes",
            "cluster.retry_budget",
            "cluster.ttft_slo",
            "cluster.queue_high",
            "cluster.queue_low",
            "cluster.slo_violation_frac",
        ];
        if CLUSTER_KEYS.iter().any(|k| doc.get(k).is_some()) {
            let mut spec = self.cluster.unwrap_or_default();
            if let Some(s) = typed_str(doc, "cluster.policy")? {
                spec.policy = AutoscalerKind::parse(s).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown cluster policy '{s}' (fixed | queue_depth | ttft_slo)"
                    )
                })?;
            }
            if let Some(v) = typed_f64(doc, "cluster.tick_s")? {
                if !(1e-3..=60.0).contains(&v) {
                    anyhow::bail!("'cluster.tick_s' must be in 0.001..=60 s, got {v}");
                }
                spec.tick_s = v;
            }
            if let Some(v) = typed_i64(doc, "cluster.min_nodes")? {
                if !(0..=4096).contains(&v) {
                    anyhow::bail!("'cluster.min_nodes' must be in 0..=4096, got {v}");
                }
                spec.min_nodes = v as usize;
            }
            if let Some(v) = typed_i64(doc, "cluster.max_nodes")? {
                if !(1..=4096).contains(&v) {
                    anyhow::bail!("'cluster.max_nodes' must be in 1..=4096, got {v}");
                }
                spec.max_nodes = v as usize;
            }
            if let Some(v) = typed_i64(doc, "cluster.retry_budget")? {
                if !(0..=1000).contains(&v) {
                    anyhow::bail!("'cluster.retry_budget' must be in 0..=1000, got {v}");
                }
                spec.retry_budget = v as u32;
            }
            if let Some(v) = typed_f64(doc, "cluster.ttft_slo")? {
                if !(1e-4..=1e4).contains(&v) {
                    anyhow::bail!("'cluster.ttft_slo' must be in 0.0001..=10000 s, got {v}");
                }
                spec.ttft_slo = v;
            }
            let q_high = typed_i64(doc, "cluster.queue_high")?;
            let q_low = typed_i64(doc, "cluster.queue_low")?;
            if q_high.is_some() || q_low.is_some() {
                match &mut spec.policy {
                    AutoscalerKind::QueueDepth { high, low } => {
                        if let Some(v) = q_high {
                            if !(1..=1_000_000).contains(&v) {
                                anyhow::bail!(
                                    "'cluster.queue_high' must be in 1..=1e6, got {v}"
                                );
                            }
                            *high = v as u32;
                        }
                        if let Some(v) = q_low {
                            if !(0..=1_000_000).contains(&v) {
                                anyhow::bail!(
                                    "'cluster.queue_low' must be in 0..=1e6, got {v}"
                                );
                            }
                            *low = v as u32;
                        }
                    }
                    other => anyhow::bail!(
                        "'cluster.queue_high'/'queue_low' require policy = \
                         \"queue_depth\" (got '{}')",
                        other.name()
                    ),
                }
            }
            if let Some(v) = typed_f64(doc, "cluster.slo_violation_frac")? {
                match &mut spec.policy {
                    AutoscalerKind::TtftSlo { max_violation_frac } => {
                        if !(0.0..=1.0).contains(&v) {
                            anyhow::bail!(
                                "'cluster.slo_violation_frac' must be in 0..=1, got {v}"
                            );
                        }
                        *max_violation_frac = v;
                    }
                    other => anyhow::bail!(
                        "'cluster.slo_violation_frac' requires policy = \"ttft_slo\" \
                         (got '{}')",
                        other.name()
                    ),
                }
            }
            self.cluster = Some(spec);
        }
        if let Some(s) = typed_str(doc, "service.model")? {
            let kind = ServiceModelKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown service model '{s}'"))?;
            self.service = kind.build();
        }
        if let Some(s) = typed_str(doc, "routing.policy")? {
            self.routing = RoutingPolicy::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown routing policy '{s}'"))?;
            self.router_factory = None;
        }
        if let Some(v) = typed_i64(doc, "routing.spill_queue")? {
            if !(0..=1_000_000_000).contains(&v) {
                anyhow::bail!("'routing.spill_queue' must be in 0..=1e9, got {v}");
            }
            match &mut self.routing {
                RoutingPolicy::CellAffinity { spill_queue } => *spill_queue = v as u32,
                other => anyhow::bail!(
                    "'routing.spill_queue' requires policy = \"cell_affinity\" \
                     (got '{}')",
                    other.name()
                ),
            }
        }
        self.base.apply_scheme_toml(doc)?;
        self.apply_cells_toml(doc)?;
        let workloads = workloads_from_toml(doc)?;
        if !workloads.is_empty() {
            self.classes = workloads;
        }
        // [[model]]: the serving zoo. Parsed before [[node]] so node
        // resident sets can reference the names (resolution itself is
        // deferred to build time either way).
        let n_models = doc.array_len("model");
        if n_models > 0 {
            self.models.clear();
            for i in 0..n_models {
                let prefix = format!("model.{i}.");
                let mut name: Option<&str> = None;
                let mut params_b: Option<f64> = None;
                let mut c_llm: Option<f64> = None;
                let mut m_llm: Option<f64> = None;
                let mut kv_bpt: Option<f64> = None;
                let mut resident_gb: Option<f64> = None;
                for key in doc.keys().filter(|k| k.starts_with(prefix.as_str())) {
                    let field = &key[prefix.len()..];
                    let missing = || anyhow::anyhow!("bad value for '{key}'");
                    let pos_f64 = || -> anyhow::Result<f64> {
                        let v = doc.f64(key).ok_or_else(missing)?;
                        if !(v > 0.0 && v.is_finite()) {
                            anyhow::bail!("'{key}' must be positive and finite, got {v}");
                        }
                        Ok(v)
                    };
                    match field {
                        "name" => name = Some(doc.str(key).ok_or_else(missing)?),
                        "params_b" => params_b = Some(pos_f64()?),
                        "c_llm" => c_llm = Some(pos_f64()?),
                        "m_llm" => m_llm = Some(pos_f64()?),
                        "kv_bytes_per_token" => kv_bpt = Some(pos_f64()?),
                        "resident_gb" => resident_gb = Some(pos_f64()?),
                        other => anyhow::bail!("unknown model key '{other}'"),
                    }
                }
                let name = name
                    .ok_or_else(|| anyhow::anyhow!("model {i}: 'name' is required"))?;
                let params_b = params_b.ok_or_else(|| {
                    anyhow::anyhow!("model {i} ('{name}'): 'params_b' is required")
                })?;
                let mut spec = ModelSpec::new(name, params_b * 1e9);
                if let Some(c) = c_llm {
                    spec = spec.with_c_llm(c);
                }
                if let Some(m) = m_llm {
                    spec = spec.with_m_llm(m);
                }
                if let Some(kv) = kv_bpt {
                    spec = spec.with_kv_bytes_per_token(kv);
                }
                if let Some(g) = resident_gb {
                    spec = spec.with_resident_bytes(g * 1e9);
                }
                self.models.push(spec);
            }
        }
        let n_nodes = doc.array_len("node");
        if n_nodes > 0 {
            self.nodes.clear();
            self.node_churn.clear();
            self.node_models.clear();
            for i in 0..n_nodes {
                let prefix = format!("node.{i}.");
                let mut gpu_name: Option<&str> = None;
                let mut scale: Option<f64> = None;
                let mut servers = 1u32;
                let mut batching = false;
                let mut max_batch: Option<u32> = None;
                let mut kv_budget_gb: Option<f64> = None;
                let mut churn = NodeChurnSpec::default();
                let mut resident: Vec<String> = Vec::new();
                let mut swap_s = 0.0_f64;
                for key in doc.keys().filter(|k| k.starts_with(prefix.as_str())) {
                    let field = &key[prefix.len()..];
                    let missing = || anyhow::anyhow!("bad value for '{key}'");
                    match field {
                        "gpu" => gpu_name = Some(doc.str(key).ok_or_else(missing)?),
                        "scale" => {
                            let v = doc.f64(key).ok_or_else(missing)?;
                            if v <= 0.0 {
                                anyhow::bail!("'{key}' must be positive, got {v}");
                            }
                            scale = Some(v);
                        }
                        "servers" => {
                            servers = workload::u32_field(doc, key, 1, 1024)?
                        }
                        "batching" => {
                            batching = doc
                                .get(key)
                                .and_then(|v| v.as_bool())
                                .ok_or_else(|| {
                                    anyhow::anyhow!("'{key}' must be a bool")
                                })?;
                        }
                        "max_batch" => {
                            max_batch = Some(workload::u32_field(doc, key, 1, 4096)?)
                        }
                        "kv_budget_gb" => {
                            let v = doc.f64(key).ok_or_else(missing)?;
                            if v <= 0.0 {
                                anyhow::bail!("'{key}' must be positive, got {v}");
                            }
                            kv_budget_gb = Some(v);
                        }
                        "mtbf" => {
                            let v = doc.f64(key).ok_or_else(missing)?;
                            if v <= 0.0 {
                                anyhow::bail!("'{key}' must be positive, got {v}");
                            }
                            churn.mtbf = v;
                        }
                        "mttr" => {
                            let v = doc.f64(key).ok_or_else(missing)?;
                            if v <= 0.0 || !v.is_finite() {
                                anyhow::bail!("'{key}' must be positive and finite, got {v}");
                            }
                            churn.mttr = v;
                        }
                        "spinup" => {
                            let v = doc.f64(key).ok_or_else(missing)?;
                            if v < 0.0 || !v.is_finite() {
                                anyhow::bail!("'{key}' must be >= 0 and finite, got {v}");
                            }
                            churn.spinup = v;
                        }
                        "models" => {
                            // comma-separated zoo names, e.g. "7b,70b"
                            resident = doc
                                .str(key)
                                .ok_or_else(missing)?
                                .split(',')
                                .map(str::trim)
                                .filter(|s| !s.is_empty())
                                .map(str::to_string)
                                .collect();
                            if resident.is_empty() {
                                anyhow::bail!(
                                    "'{key}' must name at least one model"
                                );
                            }
                        }
                        "swap_s" => {
                            let v = doc.f64(key).ok_or_else(missing)?;
                            if v < 0.0 || !v.is_finite() {
                                anyhow::bail!("'{key}' must be >= 0 and finite, got {v}");
                            }
                            swap_s = v;
                        }
                        other => anyhow::bail!("unknown node key '{other}'"),
                    }
                }
                // Unscaled default so a bare `scale = N` means exactly
                // N of this accelerator, not N x an implicit pool.
                let mut gpu = match gpu_name {
                    Some(name) => GpuSpec::by_name(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown GPU '{name}'"))?,
                    None => GpuSpec::gh200_nvl2(),
                };
                if let Some(s) = scale {
                    gpu = gpu.scaled(s);
                }
                let execution = if batching {
                    ExecutionModel::ContinuousBatching {
                        max_batch: max_batch.ok_or_else(|| {
                            anyhow::anyhow!(
                                "node {i}: batching = true requires 'max_batch'"
                            )
                        })?,
                        // 0 = derive at build time (mem − weights)
                        kv_budget: kv_budget_gb.map_or(0.0, |g| g * 1e9),
                    }
                } else {
                    if max_batch.is_some() || kv_budget_gb.is_some() {
                        anyhow::bail!(
                            "node {i}: 'max_batch'/'kv_budget_gb' require batching = true"
                        );
                    }
                    ExecutionModel::Sequential
                };
                self.nodes.push(NodeSpec {
                    gpu,
                    n_servers: servers,
                    execution,
                    resident_models: 0,
                    swap_s,
                });
                self.node_churn.push(churn);
                self.node_models.push(resident);
            }
        }
        Ok(self)
    }

    /// Parse the `[[cell]]` tables: per-cell UE population (`ues`,
    /// required), replication (`count`), numerology (`mu`), scheduling
    /// policy (`policy = "pf" | "rr"`) and SR dimensioning
    /// (`sr_period_slots`, `sr_slots_per_ue`). Unknown or mistyped
    /// keys error; explicit cells replace the builder's cell list.
    fn apply_cells_toml(&mut self, doc: &Document) -> anyhow::Result<()> {
        let n_cells = doc.array_len("cell");
        if n_cells == 0 {
            return Ok(());
        }
        self.cells.clear();
        for i in 0..n_cells {
            let prefix = format!("cell.{i}.");
            let mut ues: Option<u32> = None;
            let mut count = 1usize;
            let mut mac = self.base.mac;
            let carrier = self.base.carrier;
            let mut mu: Option<u8> = None;
            for key in doc.keys().filter(|k| k.starts_with(prefix.as_str())) {
                let field = &key[prefix.len()..];
                let missing = || anyhow::anyhow!("bad value for '{key}'");
                match field {
                    "ues" => ues = Some(workload::u32_field(doc, key, 1, 1_000_000)?),
                    "count" => {
                        count = workload::u32_field(doc, key, 1, 4096)? as usize
                    }
                    "mu" => mu = Some(workload::u32_field(doc, key, 0, 4)? as u8),
                    "policy" => {
                        mac.policy = match doc.str(key).ok_or_else(missing)? {
                            "pf" => crate::mac::SchedulingPolicy::ProportionalFair,
                            "rr" => crate::mac::SchedulingPolicy::RoundRobin,
                            other => anyhow::bail!("unknown cell policy '{other}'"),
                        }
                    }
                    "sr_period_slots" => {
                        mac.sr_period_slots =
                            workload::u32_field(doc, key, 0, 1_000_000)? as u64
                    }
                    "sr_slots_per_ue" => {
                        let v = doc.f64(key).ok_or_else(missing)?;
                        if !(0.0..=1e6).contains(&v) {
                            anyhow::bail!("'{key}' must be in 0..=1e6, got {v}");
                        }
                        mac.sr_slots_per_ue = v;
                    }
                    other => anyhow::bail!("unknown cell key '{other}'"),
                }
            }
            let n_ues =
                ues.ok_or_else(|| anyhow::anyhow!("cell {i}: 'ues' is required"))?;
            let mut spec = CellSpec { n_ues, mac, carrier };
            if let Some(mu) = mu {
                // same carrier re-derivation as the builder path
                spec = spec.with_numerology(mu);
            }
            for _ in 0..count {
                self.cells.push(spec);
            }
        }
        Ok(())
    }

    /// Finalize. An empty class list defaults to the Table I
    /// translation workload; an empty cell list to one cell mirroring
    /// the base config; an empty node list to the base config's
    /// compute node. Panics on an invalid assembly — use
    /// [`ScenarioBuilder::try_build`] to handle errors (the CLI does).
    pub fn build(self) -> Scenario {
        match self.try_build() {
            Ok(s) => s,
            Err(e) => panic!("invalid scenario: {e}"),
        }
    }

    /// Fallible [`ScenarioBuilder::build`]: enforces the documented
    /// "model must fit" rule (weights ≤ HBM on every node; weights +
    /// KV budget ≤ HBM on batching nodes), derives auto KV budgets,
    /// and rejects batching nodes with parallel servers.
    pub fn try_build(mut self) -> anyhow::Result<Scenario> {
        if self.classes.is_empty() {
            self.classes.push(WorkloadClass::from_legacy(
                &self.base.job_traffic,
                &self.base.job,
            ));
        }
        if self.cells.is_empty() {
            // Legacy single-cell scenario mirrored from the base.
            self.cells.push(CellSpec {
                n_ues: self.base.n_ues,
                mac: self.base.mac,
                carrier: self.base.carrier,
            });
        }
        let total_ues: u64 = self.cells.iter().map(|c| c.n_ues as u64).sum();
        if !(1..=1_000_000).contains(&total_ues) {
            anyhow::bail!(
                "total UE population across cells must be in 1..=1000000, got {total_ues}"
            );
        }
        // Coupled-radio surfaces require the site geometry that
        // defines them.
        if self.topology.is_none() {
            if self.mobility.is_some() {
                anyhow::bail!("[mobility] requires a [topology] (site layout)");
            }
            if self.handover.is_some() {
                anyhow::bail!("[handover] requires a [topology] (site layout)");
            }
            if self.fluid.is_some() {
                anyhow::bail!("[fluid] requires a [topology] (site layout)");
            }
        }
        if let Some(spec) = &self.fluid {
            if spec.focus.is_empty() {
                anyhow::bail!("[fluid] focus must name at least one cell");
            }
            for &f in &spec.focus {
                if f >= self.cells.len() {
                    anyhow::bail!(
                        "[fluid] focus cell {f} out of range (scenario has {} cells)",
                        self.cells.len(),
                    );
                }
            }
            if !(spec.tick_s > 0.0 && spec.tick_s.is_finite()) {
                anyhow::bail!("[fluid] tick_s must be positive and finite");
            }
            if !(spec.relax_s > 0.0 && spec.relax_s.is_finite()) {
                anyhow::bail!("[fluid] relax_s must be positive and finite");
            }
        }
        // The scheme owns job-aware prioritization — same sync rule as
        // `SimConfig::with_scheme`, applied to every cell.
        for cell in &mut self.cells {
            cell.mac.job_priority = self.base.scheme.priority_scheme;
        }
        // Keep the base population coherent with the sharded total for
        // anything still reading `base.n_ues`.
        self.base.n_ues = total_ues as u32;
        if self.nodes.is_empty() {
            self.nodes.push(NodeSpec {
                gpu: self.base.gpu,
                n_servers: self.base.n_gpus,
                execution: ExecutionModel::Sequential,
                resident_models: 0,
                swap_s: 0.0,
            });
        }
        // Every node carries a churn spec (default: never fails) and a
        // resident-model name list (default: hosts everything); the
        // builder paths keep the lists parallel, this covers defaults.
        self.node_churn.resize(self.nodes.len(), NodeChurnSpec::default());
        self.node_models.resize(self.nodes.len(), Vec::new());
        for (i, churn) in self.node_churn.iter().enumerate() {
            if churn.mtbf.is_nan() || churn.mtbf <= 0.0 {
                anyhow::bail!("node {i}: mtbf must be positive");
            }
            if !(churn.mttr > 0.0 && churn.mttr.is_finite()) {
                anyhow::bail!("node {i}: mttr must be positive and finite");
            }
            if !(churn.spinup >= 0.0 && churn.spinup.is_finite()) {
                anyhow::bail!("node {i}: spinup must be >= 0 and finite");
            }
            if churn.mtbf.is_finite() && self.cluster.is_none() {
                anyhow::bail!(
                    "node {i}: a finite mtbf requires a [cluster] control plane \
                     (failures need its repair/re-dispatch machinery)"
                );
            }
        }
        if let Some(spec) = &mut self.cluster {
            if !(spec.tick_s > 0.0 && spec.tick_s.is_finite()) {
                anyhow::bail!("[cluster] tick_s must be positive and finite");
            }
            if spec.ttft_slo.is_nan() || spec.ttft_slo <= 0.0 {
                anyhow::bail!("[cluster] ttft_slo must be positive");
            }
            // "at most the tier" is the natural meaning of an absent or
            // oversized max_nodes
            spec.max_nodes = spec.max_nodes.min(self.nodes.len());
            if spec.min_nodes > spec.max_nodes {
                anyhow::bail!(
                    "[cluster] min_nodes ({}) exceeds max_nodes ({}, tier has {} nodes)",
                    spec.min_nodes,
                    spec.max_nodes,
                    self.nodes.len(),
                );
            }
            match spec.policy {
                AutoscalerKind::QueueDepth { high, low } => {
                    if low >= high {
                        anyhow::bail!(
                            "[cluster] queue_low ({low}) must be < queue_high ({high})"
                        );
                    }
                }
                AutoscalerKind::TtftSlo { max_violation_frac } => {
                    if !(0.0..=1.0).contains(&max_violation_frac) {
                        anyhow::bail!(
                            "[cluster] slo_violation_frac must be in 0..=1, got \
                             {max_violation_frac}"
                        );
                    }
                }
                AutoscalerKind::Fixed => {}
            }
        }
        let max_m_llm = self.classes.iter().map(|c| c.m_llm).fold(0.0_f64, f64::max);
        for (i, node) in self.nodes.iter_mut().enumerate() {
            let mem = node.gpu.mem_bytes;
            for class in &self.classes {
                if class.m_llm > mem {
                    anyhow::bail!(
                        "model of class '{}' ({:.1} GB) does not fit node {i} {} \
                         ({:.1} GB HBM)",
                        class.name,
                        class.m_llm / 1e9,
                        node.gpu.display_name(),
                        mem / 1e9,
                    );
                }
            }
            if let ExecutionModel::ContinuousBatching { max_batch, kv_budget } =
                &mut node.execution
            {
                if *max_batch < 1 {
                    anyhow::bail!("node {i}: max_batch must be >= 1");
                }
                if node.n_servers != 1 {
                    anyhow::bail!(
                        "node {i}: continuous batching requires servers = 1 \
                         (the engine is the server)"
                    );
                }
                if *kv_budget == 0.0 {
                    // auto: whatever HBM the largest served model leaves
                    *kv_budget = mem - max_m_llm;
                    if *kv_budget <= 0.0 {
                        anyhow::bail!(
                            "node {i} {}: no HBM left for KV cache after {:.1} GB \
                             of weights",
                            node.gpu.display_name(),
                            max_m_llm / 1e9,
                        );
                    }
                } else if max_m_llm + *kv_budget > mem {
                    anyhow::bail!(
                        "node {i} {}: weights ({:.1} GB) + KV budget ({:.1} GB) \
                         exceed {:.1} GB HBM",
                        node.gpu.display_name(),
                        max_m_llm / 1e9,
                        *kv_budget / 1e9,
                        mem / 1e9,
                    );
                }
            }
        }
        // Model-zoo resolution and validation (all of it gated on the
        // zoo so zoo-free scenarios never reach this code).
        if self.models.is_empty() {
            if let Some(c) = self.classes.iter().find(|c| !c.models.is_empty()) {
                anyhow::bail!(
                    "class '{}' names accepted models but the scenario declares \
                     no [[model]] zoo",
                    c.name,
                );
            }
            if let Some(i) = self.node_models.iter().position(|m| !m.is_empty()) {
                anyhow::bail!(
                    "node {i} names resident models but the scenario declares \
                     no [[model]] zoo"
                );
            }
        } else {
            if self.models.len() > 64 {
                anyhow::bail!(
                    "at most 64 [[model]] tiers are supported, got {}",
                    self.models.len()
                );
            }
            for (i, m) in self.models.iter().enumerate() {
                if m.name.is_empty() {
                    anyhow::bail!("model {i}: name must be non-empty");
                }
                if self.models[..i].iter().any(|o| o.name == m.name) {
                    anyhow::bail!("duplicate model name '{}'", m.name);
                }
            }
            let resolve = |name: &str| -> anyhow::Result<usize> {
                self.models
                    .iter()
                    .position(|m| m.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown model '{name}' (not in the [[model]] zoo)")
                    })
            };
            for (i, names) in self.node_models.iter().enumerate() {
                let mut mask = 0u64;
                for name in names {
                    mask |= 1u64 << resolve(name)?;
                }
                self.nodes[i].resident_models = mask;
                if !(self.nodes[i].swap_s >= 0.0 && self.nodes[i].swap_s.is_finite()) {
                    anyhow::bail!("node {i}: swap_s must be >= 0 and finite");
                }
                // All resident weights live in HBM simultaneously (the
                // swap prices activation, not reload): Σ resident ≤ mem,
                // and on batching nodes the KV budget must still fit
                // beside them.
                let resident_sum: f64 = self
                    .models
                    .iter()
                    .enumerate()
                    .filter(|(m, _)| self.nodes[i].hosts_model(*m))
                    .map(|(_, spec)| spec.resident_bytes)
                    .sum();
                let mem = self.nodes[i].gpu.mem_bytes;
                if resident_sum > mem {
                    anyhow::bail!(
                        "node {i} {}: resident models need {:.1} GB but only \
                         {:.1} GB HBM is available",
                        self.nodes[i].gpu.display_name(),
                        resident_sum / 1e9,
                        mem / 1e9,
                    );
                }
                if let ExecutionModel::ContinuousBatching { kv_budget, .. } =
                    self.nodes[i].execution
                {
                    if resident_sum + kv_budget > mem {
                        anyhow::bail!(
                            "node {i} {}: resident models ({:.1} GB) + KV budget \
                             ({:.1} GB) exceed {:.1} GB HBM (set kv_budget_gb \
                             explicitly for multi-model nodes)",
                            self.nodes[i].gpu.display_name(),
                            resident_sum / 1e9,
                            kv_budget / 1e9,
                            mem / 1e9,
                        );
                    }
                }
            }
            for class in &self.classes {
                let mut ids = Vec::with_capacity(class.models.len());
                for name in &class.models {
                    let id = resolve(name)?;
                    if ids.contains(&id) {
                        anyhow::bail!(
                            "class '{}': duplicate accepted model '{name}'",
                            class.name,
                        );
                    }
                    ids.push(id);
                }
                if !ids.is_empty()
                    && !self
                        .nodes
                        .iter()
                        .any(|n| ids.iter().any(|&m| n.hosts_model(m)))
                {
                    anyhow::bail!(
                        "class '{}': no node hosts any of its accepted models",
                        class.name,
                    );
                }
            }
        }
        Ok(Scenario {
            base: self.base,
            classes: self.classes,
            cells: self.cells,
            nodes: self.nodes,
            models: self.models,
            service: self.service,
            routing: self.routing,
            router_factory: self.router_factory,
            cell_threads: self.cell_threads,
            cell_sync: self.cell_sync,
            topology: self.topology,
            mobility: self.mobility,
            handover: self.handover,
            fluid: self.fluid,
            event_queue: self.event_queue,
            cluster: self.cluster,
            node_churn: self.node_churn,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(builder: ScenarioBuilder) -> ScenarioBuilder {
        builder.n_ues(20).horizon(5.0).warmup(1.0)
    }

    #[test]
    fn builder_defaults_reproduce_table1_shape() {
        let s = small(ScenarioBuilder::new().scheme(SchemeConfig::icc())).build();
        assert_eq!(s.classes().len(), 1);
        assert_eq!(s.nodes().len(), 1);
        assert_eq!(s.nodes()[0].n_servers, 2);
        assert!((s.offered_rate() - 20.0).abs() < 1e-12);
        let r = s.run();
        assert!(r.report.n_jobs > 30, "n = {}", r.report.n_jobs);
        assert!(r.events > 0);
        assert_eq!(r.report.per_class.len(), 1);
    }

    #[test]
    fn multi_class_run_reports_each_class() {
        let s = small(
            ScenarioBuilder::new()
                .scheme(SchemeConfig::icc())
                .workload(WorkloadClass::translation())
                .workload(WorkloadClass::chat())
                .workload(WorkloadClass::summarization())
                .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
                .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
                .service_kind(ServiceModelKind::TokenSampled),
        )
        .build();
        let r = s.run();
        assert_eq!(r.report.per_class.len(), 3);
        assert_eq!(r.report.per_class[0].name, "translation");
        assert_eq!(r.report.per_class[1].name, "chat");
        for c in &r.report.per_class {
            assert!(c.n_jobs > 0, "class '{}' generated no jobs", c.name);
        }
        let sum: u64 = r.report.per_class.iter().map(|c| c.n_jobs).sum();
        assert_eq!(sum, r.report.n_jobs);
    }

    #[test]
    fn toml_assembles_full_scenario() {
        let doc = Document::parse(
            "[scenario]\nn_ues = 12\nhorizon = 4.0\nseed = 3\n\
             [scheme]\npreset = \"icc\"\n\
             [service]\nmodel = \"token_sampled\"\n\
             [routing]\npolicy = \"rr\"\n\
             [[node]]\ngpu = \"a100\"\nscale = 8\n\
             [[node]]\ngpu = \"a100\"\nscale = 8\nservers = 2\n\
             [[workload]]\nname = \"chat\"\nrate_per_ue = 0.4\ninput = \"geometric:32\"\noutput = \"geometric:64\"\nb_total = 0.5\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.base.n_ues, 12);
        assert_eq!(s.base.seed, 3);
        assert!(s.base.scheme.priority_scheme);
        assert_eq!(s.service_name(), "token_sampled");
        assert_eq!(s.routing(), RoutingPolicy::RoundRobin);
        assert_eq!(s.nodes().len(), 2);
        assert_eq!(s.nodes()[1].n_servers, 2);
        assert!((s.nodes()[0].gpu.a100_equivalents() - 8.0).abs() < 1e-9);
        assert_eq!(s.classes().len(), 1);
        assert_eq!(s.classes()[0].name, "chat");
    }

    #[test]
    fn toml_rejects_unknown_scenario_key() {
        let doc = Document::parse("[scenario]\nn_uez = 10").unwrap();
        assert!(ScenarioBuilder::new().apply_toml(&doc).is_err());
    }

    #[test]
    fn custom_routing_model_is_pluggable() {
        #[derive(Debug)]
        struct PinToLast;
        impl Routing for PinToLast {
            fn name(&self) -> &'static str {
                "pin_to_last"
            }
            fn pick(&mut self, ctx: &RouteCtx<'_>) -> RouteDecision {
                ctx.decide(ctx.nodes().len().saturating_sub(1))
            }
        }
        let s = small(
            ScenarioBuilder::new()
                .scheme(SchemeConfig::icc())
                .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
                .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
                .routing_model(|| Box::new(PinToLast)),
        )
        .build();
        let r = s.run();
        assert!(r.report.n_jobs > 30, "n = {}", r.report.n_jobs);
        assert!(r.report.comp.count() > 0, "custom router must serve jobs");
    }

    #[test]
    fn toml_rejects_out_of_range_scenario_values() {
        for bad in [
            "[scenario]\nn_ues = -1",
            "[scenario]\nn_ues = 0",
            "[scenario]\nhorizon = 0",
            "[scenario]\nwarmup = -2.0",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(
                ScenarioBuilder::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn toml_batching_node_parses_execution_model() {
        let doc = Document::parse(
            "[[node]]\ngpu = \"a100\"\nscale = 8\nbatching = true\nmax_batch = 64\nkv_budget_gb = 20\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(
            s.nodes()[0].execution,
            ExecutionModel::ContinuousBatching { max_batch: 64, kv_budget: 20e9 }
        );
        assert!(s.nodes()[0].execution.is_batching());
    }

    #[test]
    fn toml_batching_keys_strictly_validated() {
        for bad in [
            // max_batch without batching
            "[[node]]\ngpu = \"a100\"\nmax_batch = 8",
            // kv budget without batching
            "[[node]]\ngpu = \"a100\"\nkv_budget_gb = 4.0",
            // batching without max_batch
            "[[node]]\ngpu = \"a100\"\nbatching = true",
            // mistyped flag
            "[[node]]\ngpu = \"a100\"\nbatching = \"yes\"\nmax_batch = 8",
            // out-of-range batch
            "[[node]]\ngpu = \"a100\"\nbatching = true\nmax_batch = 0",
            // non-positive budget
            "[[node]]\ngpu = \"a100\"\nbatching = true\nmax_batch = 8\nkv_budget_gb = -1",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(
                ScenarioBuilder::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn build_rejects_model_larger_than_node_memory() {
        // 60 GB of weights cannot live on a 48 GB L40S.
        let err = ScenarioBuilder::new()
            .workload(WorkloadClass::new("big").with_model(60e9, 60e9))
            .node(GpuSpec::l40s(), 1)
            .try_build()
            .unwrap_err();
        assert!(err.to_string().contains("does not fit"), "{err}");
        // the same model fits a 2× pool
        assert!(ScenarioBuilder::new()
            .workload(WorkloadClass::new("big").with_model(60e9, 60e9))
            .node(GpuSpec::l40s().scaled(2.0), 1)
            .try_build()
            .is_ok());
    }

    #[test]
    fn build_rejects_overcommitted_kv_budget() {
        // 14 GB weights + 70 GB KV > 80 GB A100.
        let err = ScenarioBuilder::new()
            .node_exec(
                GpuSpec::a100(),
                1,
                ExecutionModel::ContinuousBatching { max_batch: 8, kv_budget: 70e9 },
            )
            .try_build()
            .unwrap_err();
        assert!(err.to_string().contains("KV budget"), "{err}");
    }

    #[test]
    fn build_derives_auto_kv_budget_from_free_memory() {
        let s = ScenarioBuilder::new()
            .node_exec(
                GpuSpec::a100(),
                1,
                ExecutionModel::ContinuousBatching { max_batch: 8, kv_budget: 0.0 },
            )
            .build();
        // Table I default class: 14 GB weights on an 80 GB A100
        match s.nodes()[0].execution {
            ExecutionModel::ContinuousBatching { kv_budget, .. } => {
                assert!((kv_budget - 66e9).abs() < 1e6, "kv = {kv_budget}");
            }
            _ => panic!("execution model lost in build"),
        }
    }

    #[test]
    fn build_rejects_batching_with_parallel_servers() {
        let err = ScenarioBuilder::new()
            .node_exec(
                GpuSpec::a100(),
                2,
                ExecutionModel::ContinuousBatching { max_batch: 8, kv_budget: 0.0 },
            )
            .try_build()
            .unwrap_err();
        assert!(err.to_string().contains("servers = 1"), "{err}");
    }

    #[test]
    fn builder_cells_default_mirrors_base_and_sums_populations() {
        // no explicit cells → one legacy cell from the base config
        let s = small(ScenarioBuilder::new().scheme(SchemeConfig::icc())).build();
        assert_eq!(s.cells().len(), 1);
        assert_eq!(s.cells()[0].n_ues, 20);
        assert!(s.cells()[0].mac.job_priority, "scheme must own job_priority");
        // explicit cells replace the base population
        let s = ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(5.0)
            .cells(3, CellSpec::new(10))
            .cell(CellSpec::new(5))
            .build();
        assert_eq!(s.cells().len(), 4);
        assert_eq!(s.total_ues(), 35);
        assert!((s.offered_rate() - 35.0).abs() < 1e-12);
        for c in s.cells() {
            assert!(c.mac.job_priority);
        }
    }

    #[test]
    fn toml_cell_tables_parse_with_count_and_numerology() {
        let doc = Document::parse(
            "[scenario]\nthreads = 2\n\
             [routing]\npolicy = \"cell_affinity\"\nspill_queue = 3\n\
             [[cell]]\nues = 12\ncount = 2\nmu = 1\n\
             [[cell]]\nues = 6\npolicy = \"rr\"\nsr_period_slots = 8\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.cells().len(), 3);
        assert_eq!(s.total_ues(), 30);
        assert_eq!(s.threads(), 2);
        assert_eq!(s.cells()[0].carrier.numerology.mu, 1);
        assert_eq!(s.cells()[0].carrier.n_prb, 273);
        assert_eq!(s.cells()[1].n_ues, 12);
        assert_eq!(
            s.cells()[2].mac.policy,
            crate::mac::SchedulingPolicy::RoundRobin
        );
        assert_eq!(s.cells()[2].mac.sr_period_slots, 8);
        assert_eq!(s.routing(), RoutingPolicy::CellAffinity { spill_queue: 3 });
    }

    #[test]
    fn toml_cell_tables_strictly_validated() {
        for bad in [
            // ues is required
            "[[cell]]\ncount = 2",
            // out-of-range population
            "[[cell]]\nues = 0",
            // bad numerology
            "[[cell]]\nues = 4\nmu = 7",
            // zero replication
            "[[cell]]\nues = 4\ncount = 0",
            // unknown key
            "[[cell]]\nues = 4\nfrobnicate = 1",
            // mistyped policy
            "[[cell]]\nues = 4\npolicy = \"edf\"",
            // single-bracket table must error loudly
            "[cell]\nues = 4",
            // spill_queue without cell_affinity
            "[routing]\npolicy = \"rr\"\nspill_queue = 2",
            // threads out of range
            "[scenario]\nthreads = -1",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(
                ScenarioBuilder::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn build_rejects_oversized_total_population() {
        let err = ScenarioBuilder::new()
            .cells(2, CellSpec::new(600_000))
            .try_build()
            .unwrap_err();
        assert!(err.to_string().contains("1..=1000000"), "{err}");
    }

    #[test]
    fn multi_cell_run_reports_per_cell_slices() {
        let s = ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(4.0)
            .warmup(0.5)
            .cells(2, CellSpec::new(8))
            .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
            .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
            .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
            .build();
        let r = s.run();
        assert_eq!(r.report.per_cell.len(), 2);
        assert_eq!(r.report.per_cell[0].name, "cell0");
        let sum: u64 = r.report.per_cell.iter().map(|c| c.n_jobs).sum();
        assert_eq!(sum, r.report.n_jobs);
        for c in &r.report.per_cell {
            assert!(c.n_jobs > 0, "cell '{}' generated no jobs", c.name);
        }
    }

    #[test]
    fn toml_topology_mobility_handover_tables_parse() {
        let doc = Document::parse(
            "[scenario]\nevent_queue = \"heap\"\n\
             [topology]\nlayout = \"linear\"\nisd = 400.0\n\
             [mobility]\nmodel = \"waypoint\"\nv_min = 1.0\nv_max = 5.0\ntick_s = 0.2\n\
             [handover]\nhysteresis_db = 2.5\nttt_s = 0.4\ninterruption_slots = 8\n\
             [[cell]]\nues = 6\ncount = 2\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.event_queue(), EventListKind::Heap);
        let topo = s.topology().unwrap();
        assert_eq!(topo.layout, SiteLayout::Linear);
        assert_eq!(topo.isd_m, 400.0);
        let mob = s.mobility().unwrap();
        assert_eq!(mob.model, MobilityModel::RandomWaypoint { v_min: 1.0, v_max: 5.0 });
        assert_eq!(mob.tick_s, 0.2);
        let ho = s.handover().unwrap();
        assert_eq!(ho.hysteresis_db, 2.5);
        assert_eq!(ho.ttt_s, 0.4);
        assert_eq!(ho.interruption_slots, 8);
        // correlated shadowing stays off unless asked for
        assert_eq!(mob.shadow_corr_m, None);
        // fixed-velocity spelling
        let doc = Document::parse(
            "[topology]\nisd = 500\n[mobility]\nmodel = \"fixed\"\nspeed = 3.0\n\
             shadow_corr_m = 50.0\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.topology().unwrap().layout, SiteLayout::Hex);
        assert_eq!(
            s.mobility().unwrap().model,
            MobilityModel::FixedVelocity { speed: 3.0 }
        );
        assert_eq!(s.mobility().unwrap().shadow_corr_m, Some(50.0));
    }

    #[test]
    fn toml_cell_sync_parses_and_validates() {
        assert_eq!(ScenarioBuilder::new().build().cell_sync(), CellSync::Frontier);
        let doc = Document::parse("[scenario]\ncell_sync = \"barrier\"").unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.cell_sync(), CellSync::Barrier);
        let doc = Document::parse("[scenario]\ncell_sync = \"frontier\"").unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.cell_sync(), CellSync::Frontier);
        let doc = Document::parse("[scenario]\ncell_sync = \"optimistic\"").unwrap();
        assert!(ScenarioBuilder::new().apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_rate_phase_tables_reach_the_classes() {
        let doc = Document::parse(
            "[[workload]]\nname = \"chat\"\nrate_per_ue = 0.4\n\
             [[workload.rate_phase]]\nclass = \"chat\"\nt_start = 2.0\nrate_per_ue = 1.0\n\
             [[workload.rate_phase]]\nclass = \"chat\"\nt_start = 5.0\nrate_per_ue = 0.1\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        let chat = &s.classes()[0];
        assert_eq!(chat.rate_phases.len(), 2);
        assert_eq!(chat.rate_at(0.0), 0.4);
        assert_eq!(chat.rate_at(3.0), 1.0);
        assert_eq!(chat.rate_at(9.0), 0.1);
    }

    #[test]
    fn toml_coupled_radio_tables_strictly_validated() {
        for bad in [
            // topology needs an ISD
            "[topology]\nlayout = \"hex\"",
            // unknown layout / model / queue
            "[topology]\nlayout = \"ring\"\nisd = 500",
            "[topology]\nisd = 500\n[mobility]\nmodel = \"brownian\"\nspeed = 1",
            "[scenario]\nevent_queue = \"wheel\"",
            // fixed model rejects waypoint keys and vice versa
            "[topology]\nisd = 500\n[mobility]\nmodel = \"fixed\"\nspeed = 1\nv_min = 1",
            "[topology]\nisd = 500\n[mobility]\nmodel = \"waypoint\"\nspeed = 1",
            "[topology]\nisd = 500\n[mobility]\nmodel = \"waypoint\"\nv_min = 5\nv_max = 1",
            // out-of-range values
            "[topology]\nisd = 0",
            "[topology]\nisd = 500\n[mobility]\nmodel = \"fixed\"\nspeed = -1",
            "[topology]\nisd = 500\n[mobility]\nmodel = \"fixed\"\nspeed = 1\nshadow_corr_m = 0",
            "[topology]\nisd = 500\n[mobility]\nmodel = \"fixed\"\nspeed = 1\nshadow_corr_m = -5",
            "[topology]\nisd = 500\n[handover]\nhysteresis_db = 99",
            "[topology]\nisd = 500\n[handover]\nttt_s = -1",
            // unknown keys inside the new tables
            "[topology]\nisd = 500\nfrobnicate = 1",
            "[topology]\nisd = 500\n[handover]\nhys = 3",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(
                ScenarioBuilder::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
        // mobility/handover without topology fail at build time
        for doc in [
            "[mobility]\nmodel = \"fixed\"\nspeed = 3",
            "[handover]\nhysteresis_db = 3",
        ] {
            let doc = Document::parse(doc).unwrap();
            let err = ScenarioBuilder::new()
                .apply_toml(&doc)
                .unwrap()
                .try_build()
                .unwrap_err();
            assert!(err.to_string().contains("topology"), "{err}");
        }
    }

    #[test]
    fn coupled_radio_run_reports_radio_slices_and_heap_matches_calendar() {
        let mk = |kind: EventListKind| {
            ScenarioBuilder::new()
                .scheme(SchemeConfig::icc())
                .horizon(2.0)
                .warmup(0.2)
                .seed(11)
                .cells(3, CellSpec::new(6))
                .topology(TopologySpec::hex(500.0))
                .mobility(MobilitySpec::fixed(20.0))
                .handover(HandoverSpec { hysteresis_db: 1.0, ttt_s: 0.1, interruption_slots: 4 })
                .event_queue(kind)
                .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
                .build()
                .run()
        };
        let cal = mk(EventListKind::Calendar);
        assert_eq!(cal.report.radio.len(), 3, "coupled run must report radio slices");
        for r in &cal.report.radio {
            assert!(r.iot_db.count() > 0, "IoT sampled per stepped slot");
            assert!(r.iot_db.mean() >= 0.0);
        }
        assert!(cal.report.n_jobs > 0);
        // the JSON report carries the radio array
        assert!(cal.report.to_json().contains("per_cell_radio"));
        // heap backend reproduces the identical trajectory
        let heap = mk(EventListKind::Heap);
        assert_eq!(cal.events, heap.events);
        assert_eq!(cal.report.n_jobs, heap.report.n_jobs);
        assert_eq!(
            cal.report.e2e.mean().to_bits(),
            heap.report.e2e.mean().to_bits()
        );
        for (a, b) in cal.report.radio.iter().zip(&heap.report.radio) {
            assert_eq!(a.handovers_in, b.handovers_in);
            assert_eq!(a.handovers_out, b.handovers_out);
            assert_eq!(a.iot_db.mean().to_bits(), b.iot_db.mean().to_bits());
        }
    }

    #[test]
    fn legacy_default_ignores_radio_surfaces_entirely() {
        // no topology → no radio slices, margin-based noise, static UEs
        let s = small(ScenarioBuilder::new().scheme(SchemeConfig::icc())).build();
        assert!(s.topology().is_none());
        let r = s.run();
        assert!(r.report.radio.is_empty());
    }

    #[test]
    fn toml_cluster_table_parses_with_node_churn() {
        let doc = Document::parse(
            "[cluster]\npolicy = \"queue_depth\"\ntick_s = 0.25\nmin_nodes = 1\n\
             max_nodes = 2\nretry_budget = 3\nttft_slo = 0.8\nqueue_high = 6\nqueue_low = 2\n\
             [[node]]\ngpu = \"a100\"\nmtbf = 40.0\nmttr = 10.0\nspinup = 2.0\n\
             [[node]]\ngpu = \"a100\"\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        let c = s.cluster().unwrap();
        assert_eq!(c.policy, AutoscalerKind::QueueDepth { high: 6, low: 2 });
        assert_eq!(c.tick_s, 0.25);
        assert_eq!((c.min_nodes, c.max_nodes), (1, 2));
        assert_eq!(c.retry_budget, 3);
        assert_eq!(c.ttft_slo, 0.8);
        assert_eq!(s.node_churn().len(), 2);
        assert_eq!(
            s.node_churn()[0],
            NodeChurnSpec { mtbf: 40.0, mttr: 10.0, spinup: 2.0 }
        );
        // absent churn keys → the never-fails default
        assert_eq!(s.node_churn()[1], NodeChurnSpec::default());
        // ttft policy accepts its tuning knob
        let doc = Document::parse(
            "[cluster]\npolicy = \"ttft_slo\"\nslo_violation_frac = 0.2\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(
            s.cluster().unwrap().policy,
            AutoscalerKind::TtftSlo { max_violation_frac: 0.2 }
        );
        // max_nodes is clamped to the tier size at build time
        assert_eq!(s.cluster().unwrap().max_nodes, s.nodes().len());
    }

    #[test]
    fn toml_cluster_tables_strictly_validated() {
        for bad in [
            // unknown policy / key
            "[cluster]\npolicy = \"magic\"",
            "[cluster]\nfrobnicate = 1",
            // knobs must match the selected policy
            "[cluster]\npolicy = \"fixed\"\nqueue_high = 4",
            "[cluster]\npolicy = \"queue_depth\"\nslo_violation_frac = 0.1",
            // out-of-range values
            "[cluster]\ntick_s = 0",
            "[cluster]\ntick_s = 100.0",
            "[cluster]\nretry_budget = -1",
            "[cluster]\nttft_slo = 0",
            "[cluster]\npolicy = \"ttft_slo\"\nslo_violation_frac = 1.5",
            // node churn values
            "[cluster]\ntick_s = 0.5\n[[node]]\ngpu = \"a100\"\nmtbf = 0",
            "[cluster]\ntick_s = 0.5\n[[node]]\ngpu = \"a100\"\nmttr = -3",
            "[cluster]\ntick_s = 0.5\n[[node]]\ngpu = \"a100\"\nspinup = -1",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(
                ScenarioBuilder::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
        // build-time coherence checks
        for (bad, needle) in [
            // churn without the control plane
            (
                "[[node]]\ngpu = \"a100\"\nmtbf = 50.0",
                "[cluster]",
            ),
            // hysteresis bounds inverted
            (
                "[cluster]\npolicy = \"queue_depth\"\nqueue_high = 2\nqueue_low = 2",
                "queue_low",
            ),
            // min above the tier size
            (
                "[cluster]\nmin_nodes = 3\n[[node]]\ngpu = \"a100\"",
                "min_nodes",
            ),
        ] {
            let doc = Document::parse(bad).unwrap();
            let err = ScenarioBuilder::new()
                .apply_toml(&doc)
                .unwrap()
                .try_build()
                .unwrap_err();
            assert!(err.to_string().contains(needle), "{bad}: {err}");
        }
    }

    #[test]
    fn toml_model_tables_assemble_the_zoo() {
        let doc = Document::parse(
            "[[model]]\nname = \"7b\"\nparams_b = 7\n\
             [[model]]\nname = \"70b\"\nparams_b = 70\nresident_gb = 70\n\
             kv_bytes_per_token = 262144\n\
             [[node]]\ngpu = \"h200\"\nmodels = \"7b,70b\"\nswap_s = 0.05\n\
             [[node]]\ngpu = \"a100\"\nmodels = \"7b\"\n\
             [[workload]]\nname = \"chat\"\nrate_per_ue = 0.4\nmodels = \"70b,7b\"\n",
        )
        .unwrap();
        let s = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
        assert_eq!(s.models().len(), 2);
        assert_eq!(s.models()[0].name, "7b");
        assert!((s.models()[0].m_llm - 14e9).abs() < 1e-3);
        assert!((s.models()[1].resident_bytes - 70e9).abs() < 1e-3);
        assert_eq!(s.models()[1].kv_bytes_per_token(), 262144.0);
        // node 0 hosts both tiers, node 1 only the 7B
        assert_eq!(s.nodes()[0].resident_models, 0b11);
        assert_eq!(s.nodes()[0].swap_s, 0.05);
        assert_eq!(s.nodes()[1].resident_models, 0b01);
        assert!(s.nodes()[1].hosts_model(0) && !s.nodes()[1].hosts_model(1));
        assert_eq!(s.classes()[0].models, vec!["70b", "7b"]);
        assert_eq!(s.class_model_ids(), vec![vec![1, 0]]);
        // the zoo shapes the snapshot fingerprint
        let plain = ScenarioBuilder::new().build();
        assert_ne!(s.fingerprint(), plain.fingerprint());
    }

    #[test]
    fn model_zoo_strictly_validated() {
        for bad in [
            // name/params required, unknown keys rejected
            "[[model]]\nparams_b = 7",
            "[[model]]\nname = \"7b\"",
            "[[model]]\nname = \"7b\"\nparams_b = 7\nfrobnicate = 1",
            "[[model]]\nname = \"7b\"\nparams_b = -7",
            // single-bracket table must error loudly
            "[model]\nname = \"7b\"",
            // node 'models' must not be empty
            "[[model]]\nname = \"7b\"\nparams_b = 7\n[[node]]\ngpu = \"a100\"\nmodels = \",\"",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(
                ScenarioBuilder::new().apply_toml(&doc).is_err(),
                "accepted: {bad}"
            );
        }
        // build-time coherence checks
        for (bad, needle) in [
            // references without a zoo
            (
                "[[workload]]\nname = \"chat\"\nrate_per_ue = 0.4\nmodels = \"7b\"",
                "no [[model]] zoo",
            ),
            ("[[node]]\ngpu = \"a100\"\nmodels = \"7b\"", "no [[model]] zoo"),
            // unknown / duplicate names
            (
                "[[model]]\nname = \"7b\"\nparams_b = 7\n\
                 [[node]]\ngpu = \"a100\"\nmodels = \"13b\"",
                "unknown model",
            ),
            (
                "[[model]]\nname = \"7b\"\nparams_b = 7\n\
                 [[model]]\nname = \"7b\"\nparams_b = 7",
                "duplicate model name",
            ),
            (
                "[[model]]\nname = \"7b\"\nparams_b = 7\n\
                 [[workload]]\nname = \"chat\"\nrate_per_ue = 0.4\nmodels = \"7b,7b\"",
                "duplicate accepted model",
            ),
            // residency exceeds HBM (2 x 70 GB on a 141 GB H200 is
            // fine, on an 80 GB A100 it is not)
            (
                "[[model]]\nname = \"a\"\nparams_b = 35\n\
                 [[model]]\nname = \"b\"\nparams_b = 35\n\
                 [[node]]\ngpu = \"a100\"\nmodels = \"a,b\"",
                "resident models",
            ),
            // a class whose accept-list no node hosts
            (
                "[[model]]\nname = \"7b\"\nparams_b = 7\n\
                 [[model]]\nname = \"70b\"\nparams_b = 70\n\
                 [[node]]\ngpu = \"h200\"\nmodels = \"7b\"\n\
                 [[workload]]\nname = \"chat\"\nrate_per_ue = 0.4\nmodels = \"70b\"",
                "no node hosts",
            ),
        ] {
            let doc = Document::parse(bad).unwrap();
            let err = ScenarioBuilder::new()
                .apply_toml(&doc)
                .unwrap()
                .try_build()
                .unwrap_err();
            assert!(err.to_string().contains(needle), "{bad}: {err}");
        }
    }

    #[test]
    fn toml_rejects_single_bracket_workload_table() {
        // A plain [workload] table must error loudly, not be dropped.
        let doc = Document::parse("[workload]\nname = \"chat\"").unwrap();
        let err = ScenarioBuilder::new().apply_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("[[workload]]"), "{err}");
    }
}
