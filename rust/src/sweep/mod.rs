//! Parallel sweep runner: fan independent simulation replications out
//! over scoped worker threads, reduce with exact [`SimReport::merge`].
//!
//! A capacity curve is a (seed × load) grid of *independent* scenario
//! runs — embarrassingly parallel. The runner keeps three guarantees:
//!
//! 1. **Determinism** — every replication is a self-contained
//!    single-threaded simulation seeded from the grid, so the work a
//!    thread does never depends on which thread does it.
//! 2. **Exact reduction** — per-point reports are merged in grid order
//!    (seed-ascending), not completion order, so the merged Welford
//!    accumulators are *bit-identical* to a serial sweep.
//! 3. **No dependencies** — plain `std::thread::scope` + an atomic
//!    work cursor; no rayon in the offline dependency universe.
//!
//! `threads = 0` means "use all available parallelism"; `threads = 1`
//! degenerates to an inline serial loop (no threads spawned), which is
//! what the `parallel ≡ serial` equality tests compare against.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::metrics::SimReport;

/// Resolve a thread-count request: 0 → available parallelism.
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Run `f` over `items` on up to `threads` scoped worker threads,
/// returning results **in input order**. Work is claimed from an
/// atomic cursor, so long items don't serialize behind short ones.
/// With `threads <= 1` (after [`resolve_threads`]) the items run
/// inline on the caller's thread.
pub fn run_parallel<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = resolve_threads(threads).min(n.max(1));
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker left a result slot empty"))
        .collect()
}

/// One merged grid point of a sweep: the x value (offered rate,
/// capacity, …) and the seed-merged report.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub x: f64,
    pub report: SimReport,
    /// Replications merged into `report`.
    pub n_reps: u32,
}

/// Sweep an `xs × seeds` grid: run every (x, seed) replication through
/// `run` (in parallel across the whole grid, not just within a point)
/// and merge each point's replications **in seed order** so the result
/// is bit-identical to a serial sweep.
///
/// `run` must be a pure function of its `(x, seed)` arguments.
pub fn sweep_grid(
    xs: &[f64],
    seeds: &[u64],
    threads: usize,
    run: impl Fn(f64, u64) -> SimReport + Sync,
) -> Vec<GridPoint> {
    assert!(!seeds.is_empty(), "sweep needs at least one seed");
    let jobs: Vec<(f64, u64)> = xs
        .iter()
        .flat_map(|&x| seeds.iter().map(move |&s| (x, s)))
        .collect();
    let reports = run_parallel(&jobs, threads, |&(x, s)| run(x, s));
    let mut points = Vec::with_capacity(xs.len());
    let mut it = reports.into_iter();
    for &x in xs {
        let mut agg: Option<SimReport> = None;
        for _ in seeds {
            let r = it.next().expect("grid/report length mismatch");
            agg = Some(match agg {
                None => r,
                Some(mut a) => {
                    a.merge(&r);
                    a
                }
            });
        }
        points.push(GridPoint { x, report: agg.unwrap(), n_reps: seeds.len() as u32 });
    }
    points
}

/// The replication seed list the coordinator sweeps use:
/// `base, base+1000, base+2000, …` (kept stable so pre-existing
/// results reproduce).
pub fn replication_seeds(base: u64, n: u32) -> Vec<u64> {
    (0..n).map(|s| base + 1000 * s as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_parallel_preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        for threads in [1, 2, 8] {
            let out = run_parallel(&items, threads, |&x| x * 3);
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_parallel_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(run_parallel(&empty, 4, |&x| x).is_empty());
        assert_eq!(run_parallel(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn run_parallel_actually_distributes_work() {
        // With more threads than one, at least two distinct threads
        // should claim items (flaky-free: 64 items, each sleeping a
        // hair, 4 workers).
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..64).collect();
        let out = run_parallel(&items, 4, |&x| {
            count.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_micros(100));
            x
        });
        assert_eq!(out.len(), 64);
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn resolve_threads_zero_means_all() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn replication_seed_layout_is_stable() {
        assert_eq!(replication_seeds(1, 3), vec![1, 1001, 2001]);
    }

    // sweep_grid's serial ≡ parallel bit-identity over real scenario
    // runs lives in tests/integration_sweep.rs (needs whole-sim runs).
}
