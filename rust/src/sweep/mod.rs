//! Parallel sweep runner: fan independent simulation replications out
//! over scoped worker threads, reduce with exact [`SimReport::merge`].
//!
//! A capacity curve is a (seed × load) grid of *independent* scenario
//! runs — embarrassingly parallel. The runner keeps three guarantees:
//!
//! 1. **Determinism** — every replication is a self-contained
//!    single-threaded simulation seeded from the grid, so the work a
//!    thread does never depends on which thread does it.
//! 2. **Exact reduction** — per-point reports are merged in grid order
//!    (seed-ascending), not completion order, so the merged Welford
//!    accumulators are *bit-identical* to a serial sweep.
//! 3. **No dependencies** — plain `std::thread::scope` + an atomic
//!    work cursor; no rayon in the offline dependency universe.
//!
//! `threads = 0` means "use all available parallelism"; `threads = 1`
//! degenerates to an inline serial loop (no threads spawned), which is
//! what the `parallel ≡ serial` equality tests compare against.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::metrics::SimReport;
use crate::scenario::{Scenario, ScenarioEngine, WorkloadClass};

/// Resolve a thread-count request: 0 → available parallelism.
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Run `f` over `items` on up to `threads` scoped worker threads,
/// returning results **in input order**. Work is claimed from an
/// atomic cursor, so long items don't serialize behind short ones.
/// With `threads <= 1` (after [`resolve_threads`]) the items run
/// inline on the caller's thread.
pub fn run_parallel<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = resolve_threads(threads).min(n.max(1));
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker left a result slot empty"))
        .collect()
}

/// One merged grid point of a sweep: the x value (offered rate,
/// capacity, …) and the seed-merged report.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub x: f64,
    pub report: SimReport,
    /// Replications merged into `report`.
    pub n_reps: u32,
}

/// Sweep an `xs × seeds` grid: run every (x, seed) replication through
/// `run` (in parallel across the whole grid, not just within a point)
/// and merge each point's replications **in seed order** so the result
/// is bit-identical to a serial sweep.
///
/// `run` must be a pure function of its `(x, seed)` arguments.
pub fn sweep_grid(
    xs: &[f64],
    seeds: &[u64],
    threads: usize,
    run: impl Fn(f64, u64) -> SimReport + Sync,
) -> Vec<GridPoint> {
    assert!(!seeds.is_empty(), "sweep needs at least one seed");
    let jobs: Vec<(f64, u64)> = xs
        .iter()
        .flat_map(|&x| seeds.iter().map(move |&s| (x, s)))
        .collect();
    let reports = run_parallel(&jobs, threads, |&(x, s)| run(x, s));
    let mut points = Vec::with_capacity(xs.len());
    let mut it = reports.into_iter();
    for &x in xs {
        let mut agg: Option<SimReport> = None;
        for _ in seeds {
            let r = it.next().expect("grid/report length mismatch");
            agg = Some(match agg {
                None => r,
                Some(mut a) => {
                    a.merge(&r);
                    a
                }
            });
        }
        points.push(GridPoint { x, report: agg.unwrap(), n_reps: seeds.len() as u32 });
    }
    points
}

/// Validity contract of a warm-start sweep (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarmStart {
    /// Require a rate-invariant warm-up prefix: every grid point's
    /// arrival-rate trajectory must equal the warm run's on
    /// `[0, warm_s)`. With that prefix the forked runs are
    /// *bit-identical* to cold runs (the warm segment replays the
    /// exact same draws). Panics when the prefix is not invariant.
    Exact,
    /// Documented approximation: fork the warm checkpoint even when
    /// the grid varies the rate from t = 0. The warm-up transient is
    /// then simulated at the *reference* (first) grid point's rate;
    /// steady-state metrics converge to the cold sweep's as
    /// `warm_s / horizon → 0`, but the runs are not bit-identical.
    Forced,
}

/// `true` when two classes offer the same arrival rate at every
/// instant of `[0, warm_s)`. Rates are piecewise-constant, so it
/// suffices to compare at 0 and at both schedules' breakpoints below
/// `warm_s`. Bitwise f64 equality on purpose — the warm-start exactness
/// contract is bit-identity, not approximate equality.
fn rate_prefix_invariant(a: &WorkloadClass, b: &WorkloadClass, warm_s: f64) -> bool {
    let mut ts: Vec<f64> = vec![0.0];
    ts.extend(a.rate_phases.iter().map(|p| p.t_start).filter(|&t| t < warm_s));
    ts.extend(b.rate_phases.iter().map(|p| p.t_start).filter(|&t| t < warm_s));
    ts.iter().all(|&t| a.rate_at(t) == b.rate_at(t))
}

/// Warm-start grid sweep: per seed, simulate **one** warm-up segment
/// to `warm_s`, snapshot it, then fork the checkpoint across all
/// `xs` rate points and simulate only the remainder of each run.
///
/// `make(x, seed)` must build the scenario for rate point `x` — the
/// same pure function a cold [`sweep_grid`] closure would wrap. All
/// grid points of a seed must be snapshot-compatible (identical in
/// everything but arrival rates; [`ScenarioEngine::from_snapshot`]
/// enforces this via the config fingerprint). The warm segment runs at
/// `xs[0]`'s rates; see [`WarmStart`] for when the forked runs are
/// bit-identical to cold ones.
///
/// Replications merge in seed order exactly like [`sweep_grid`], so a
/// warm sweep with an invariant prefix is bit-identical to the cold
/// sweep, point for point — just without re-simulating the warm-up
/// `xs.len()` times.
pub fn sweep_grid_warm(
    xs: &[f64],
    seeds: &[u64],
    warm_s: f64,
    threads: usize,
    mode: WarmStart,
    make: impl Fn(f64, u64) -> Scenario + Sync,
) -> Vec<GridPoint> {
    assert!(!xs.is_empty(), "warm sweep needs at least one rate point");
    assert!(!seeds.is_empty(), "sweep needs at least one seed");
    assert!(warm_s.is_finite() && warm_s >= 0.0, "warm_s must be finite and >= 0");

    if mode == WarmStart::Exact {
        // One representative seed suffices: rates are config, not
        // seed-dependent draws.
        let reference = make(xs[0], seeds[0]);
        for &x in &xs[1..] {
            let other = make(x, seeds[0]);
            let ok = reference.classes.len() == other.classes.len()
                && reference
                    .classes
                    .iter()
                    .zip(other.classes.iter())
                    .all(|(a, b)| rate_prefix_invariant(a, b, warm_s));
            assert!(
                ok,
                "WarmStart::Exact requires every grid point to share the \
                 warm-up rate trajectory on [0, {warm_s}s); point x = {x} \
                 diverges (use WarmStart::Forced to accept the approximation)"
            );
        }
    }

    // Phase 1 — one warm segment per seed, in parallel.
    let blobs: Vec<Vec<u8>> = run_parallel(seeds, threads, |&s| {
        let sc = make(xs[0], s);
        let mut eng = ScenarioEngine::new(&sc);
        eng.run_to(warm_s);
        eng.snapshot()
    });

    // Phase 2 — fork each seed's checkpoint across the rate axis.
    let jobs: Vec<(usize, usize)> = (0..xs.len())
        .flat_map(|xi| (0..seeds.len()).map(move |si| (xi, si)))
        .collect();
    let reports = run_parallel(&jobs, threads, |&(xi, si)| {
        let sc = make(xs[xi], seeds[si]);
        let mut eng = ScenarioEngine::from_snapshot(&sc, &blobs[si]).unwrap_or_else(|e| {
            panic!(
                "warm snapshot rejected at x = {}, seed = {}: {e} \
                 (grid points must differ only in arrival rates)",
                xs[xi], seeds[si]
            )
        });
        eng.run_to(f64::INFINITY);
        eng.finish().report
    });

    let mut points = Vec::with_capacity(xs.len());
    let mut it = reports.into_iter();
    for &x in xs {
        let mut agg: Option<SimReport> = None;
        for _ in seeds {
            let r = it.next().expect("grid/report length mismatch");
            agg = Some(match agg {
                None => r,
                Some(mut a) => {
                    a.merge(&r);
                    a
                }
            });
        }
        points.push(GridPoint { x, report: agg.unwrap(), n_reps: seeds.len() as u32 });
    }
    points
}

/// Paired A/B comparison under common random numbers.
#[derive(Debug, Clone)]
pub struct AbReport {
    /// The shared seed list (one paired replication each).
    pub seeds: Vec<u64>,
    /// Per-seed metric of config A, in seed order.
    pub a: Vec<f64>,
    /// Per-seed metric of config B, in seed order.
    pub b: Vec<f64>,
    /// Per-seed paired differences `b[i] - a[i]`.
    pub deltas: Vec<f64>,
    pub mean_a: f64,
    pub mean_b: f64,
    /// Mean of the paired differences (`mean_b - mean_a`).
    pub delta_mean: f64,
    /// Half-width of the 95% CI on `delta_mean` (normal approximation
    /// `1.96·s/√n` over the paired deltas; 0 when n < 2). Pairing on
    /// seed cancels the common simulation noise, so this is typically
    /// far tighter than the unpaired CI on `mean_b - mean_a`.
    pub delta_ci95: f64,
}

impl AbReport {
    /// `true` when the 95% CI on the paired delta excludes zero.
    pub fn significant(&self) -> bool {
        self.delta_ci95 > 0.0 && self.delta_mean.abs() > self.delta_ci95
    }
}

/// Run configs A and B once per seed — the *same* seed on both sides,
/// so every replication pair shares its random numbers (CRN) — and
/// reduce the per-seed metric pairs into paired-delta statistics.
///
/// `metric_a`/`metric_b` must be pure functions of the seed (e.g. "run
/// scenario A at this seed, return satisfaction"). All `2·n` runs
/// execute in parallel; the reduction is in seed order and therefore
/// deterministic.
pub fn sweep_ab(
    seeds: &[u64],
    threads: usize,
    metric_a: impl Fn(u64) -> f64 + Sync,
    metric_b: impl Fn(u64) -> f64 + Sync,
) -> AbReport {
    assert!(!seeds.is_empty(), "A/B comparison needs at least one seed");
    let jobs: Vec<(u64, bool)> =
        seeds.iter().flat_map(|&s| [(s, false), (s, true)]).collect();
    let vals = run_parallel(&jobs, threads, |&(s, is_b)| {
        if is_b {
            metric_b(s)
        } else {
            metric_a(s)
        }
    });
    let a: Vec<f64> = vals.iter().step_by(2).copied().collect();
    let b: Vec<f64> = vals.iter().skip(1).step_by(2).copied().collect();
    let deltas: Vec<f64> = a.iter().zip(b.iter()).map(|(x, y)| y - x).collect();
    let n = deltas.len() as f64;
    let mean_a = a.iter().sum::<f64>() / n;
    let mean_b = b.iter().sum::<f64>() / n;
    let delta_mean = deltas.iter().sum::<f64>() / n;
    let delta_ci95 = if deltas.len() >= 2 {
        let var = deltas.iter().map(|d| (d - delta_mean).powi(2)).sum::<f64>()
            / (n - 1.0);
        1.96 * (var / n).sqrt()
    } else {
        0.0
    };
    AbReport { seeds: seeds.to_vec(), a, b, deltas, mean_a, mean_b, delta_mean, delta_ci95 }
}

/// The replication seed list the coordinator sweeps use:
/// `base, base+1000, base+2000, …` (kept stable so pre-existing
/// results reproduce).
pub fn replication_seeds(base: u64, n: u32) -> Vec<u64> {
    (0..n).map(|s| base + 1000 * s as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_parallel_preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        for threads in [1, 2, 8] {
            let out = run_parallel(&items, threads, |&x| x * 3);
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_parallel_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(run_parallel(&empty, 4, |&x| x).is_empty());
        assert_eq!(run_parallel(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn run_parallel_actually_distributes_work() {
        // With more threads than one, at least two distinct threads
        // should claim items (flaky-free: 64 items, each sleeping a
        // hair, 4 workers).
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..64).collect();
        let out = run_parallel(&items, 4, |&x| {
            count.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_micros(100));
            x
        });
        assert_eq!(out.len(), 64);
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn resolve_threads_zero_means_all() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn replication_seed_layout_is_stable() {
        assert_eq!(replication_seeds(1, 3), vec![1, 1001, 2001]);
    }

    #[test]
    fn ab_pairs_by_seed_and_reduces_deterministically() {
        // metric_a = seed, metric_b = seed + 2 → every paired delta is
        // exactly 2 with zero variance.
        let seeds = [3u64, 5, 9];
        for threads in [1, 4] {
            let r = sweep_ab(&seeds, threads, |s| s as f64, |s| s as f64 + 2.0);
            assert_eq!(r.seeds, seeds);
            assert_eq!(r.a, vec![3.0, 5.0, 9.0]);
            assert_eq!(r.b, vec![5.0, 7.0, 11.0]);
            assert_eq!(r.deltas, vec![2.0, 2.0, 2.0]);
            assert_eq!(r.delta_mean, 2.0);
            assert_eq!(r.delta_ci95, 0.0);
            assert!((r.mean_b - r.mean_a - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn ab_ci_covers_known_spread() {
        // deltas = [0, 2] → mean 1, s = √2, CI = 1.96·√(2/2) = 1.96.
        let r = sweep_ab(&[0, 1], 1, |_| 0.0, |s| 2.0 * s as f64);
        assert!((r.delta_mean - 1.0).abs() < 1e-12);
        assert!((r.delta_ci95 - 1.96).abs() < 1e-12);
        assert!(!r.significant());
        // a one-sided shift with no noise is significant
        let r = sweep_ab(&[1, 2, 3], 1, |_| 0.0, |s| 1.0 + 1e-6 * s as f64);
        assert!(r.significant());
    }

    // sweep_grid's serial ≡ parallel bit-identity over real scenario
    // runs lives in tests/integration_sweep.rs (needs whole-sim runs);
    // sweep_grid_warm's warm ≡ cold bit-identity lives in
    // tests/integration_snapshot.rs.
}
