//! The cell's UE population behind a backlog index.
//!
//! [`UeBank`] owns the per-UE MAC state and maintains an **active set**
//! — the indices of UEs with buffered bytes — so the slot scheduler
//! iterates candidates in O(active) instead of O(population). The
//! index is a swap-remove vector with a per-UE position table (O(1)
//! insert/remove) plus a running total-backlog counter, giving the
//! engine its "anything left to drain?" check in O(1).
//!
//! Invariants (see DESIGN.md §8):
//! * `backlogged` contains exactly the UEs with `buffered_bytes() > 0`
//!   (HARQ-blocked and SR-waiting UEs stay in; they are filtered per
//!   slot by `grant_ready`, which is cheap).
//! * `pos[i]` is the position of UE `i` in `backlogged`, or `NONE`.
//! * `total_backlog` is the byte sum over all UE buffers.
//!
//! All buffer mutations must go through bank methods (`push_job_sdu`,
//! `push_bg_sdu`, `drain_served`) so the index can never go stale;
//! [`UeBank::ue_mut`] hands out the UE for scheduler state (HARQ, PF,
//! SR) that does not move bytes.

use crate::rng::Rng;

use super::rlc::{Sdu, SduDelivered};
use super::scheduler::UeMac;

const NONE: u32 = u32::MAX;

/// The UE population of one cell plus its backlog index.
#[derive(Debug)]
pub struct UeBank {
    ues: Vec<UeMac>,
    /// Indices of backlogged UEs, unordered (swap-remove).
    backlogged: Vec<u32>,
    /// `pos[i]` = index of UE `i` in `backlogged`, or `NONE`.
    pos: Vec<u32>,
    /// Total buffered bytes across the cell.
    total_backlog: u64,
}

impl UeBank {
    /// Build the bank (and its index) from an existing population —
    /// UEs may already hold buffered SDUs.
    pub fn new(ues: Vec<UeMac>) -> Self {
        let mut bank = Self {
            pos: vec![NONE; ues.len()],
            backlogged: Vec::new(),
            total_backlog: 0,
            ues,
        };
        for i in 0..bank.ues.len() {
            let bytes = bank.ues[i].buffered_bytes();
            if bytes > 0 {
                bank.pos[i] = bank.backlogged.len() as u32;
                bank.backlogged.push(i as u32);
                bank.total_backlog += bytes;
            }
        }
        bank
    }

    pub fn len(&self) -> usize {
        self.ues.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ues.is_empty()
    }

    pub fn ue(&self, i: usize) -> &UeMac {
        &self.ues[i]
    }

    /// Mutable UE access for scheduler state (HARQ counters, PF
    /// averages, SR timing). Must NOT be used to push or drain SDUs —
    /// that would bypass the backlog index.
    pub fn ue_mut(&mut self, i: usize) -> &mut UeMac {
        &mut self.ues[i]
    }

    /// Number of UEs with buffered bytes.
    pub fn n_backlogged(&self) -> usize {
        self.backlogged.len()
    }

    /// Any bytes anywhere in the cell? O(1).
    pub fn has_backlog(&self) -> bool {
        !self.backlogged.is_empty()
    }

    /// Total buffered bytes across the cell. O(1).
    pub fn total_backlog_bytes(&self) -> u64 {
        self.total_backlog
    }

    /// Record a data arrival (SR bookkeeping; see
    /// [`UeMac::note_arrival`]).
    pub fn note_arrival(&mut self, i: usize, arrival_slot: u64, period: u64, proc_slots: u64) {
        self.ues[i].note_arrival(arrival_slot, period, proc_slots);
    }

    /// Push a job SDU and index the UE as backlogged.
    pub fn push_job_sdu(&mut self, i: usize, sdu: Sdu) {
        let bytes = sdu.bytes_left as u64;
        self.ues[i].push_job_sdu(sdu);
        self.note_pushed(i, bytes);
    }

    /// Push a background SDU and index the UE as backlogged.
    pub fn push_bg_sdu(&mut self, i: usize, sdu: Sdu) {
        let bytes = sdu.bytes_left as u64;
        self.ues[i].push_bg_sdu(sdu);
        self.note_pushed(i, bytes);
    }

    /// Drain one granted transport block from UE `i`, appending
    /// completed SDUs to `out` and unindexing the UE if its buffers
    /// emptied. Returns the bytes drained.
    pub fn drain_served(
        &mut self,
        i: usize,
        budget: u32,
        job_first: bool,
        out: &mut Vec<SduDelivered>,
    ) -> u64 {
        let before = self.ues[i].buffered_bytes();
        self.ues[i].drain_into(budget, job_first, out);
        let after = self.ues[i].buffered_bytes();
        let drained = before - after;
        self.total_backlog -= drained;
        if after == 0 && self.pos[i] != NONE {
            self.remove(i);
        }
        drained
    }

    /// Collect this slot's grant candidates (backlogged + grant-ready)
    /// into `out`, in ascending UE order. `dense` rebuilds the list by
    /// scanning the whole population — the reference path the
    /// active-set index must match exactly.
    pub(crate) fn candidates_into(&self, slot: u64, dense: bool, out: &mut Vec<u32>) {
        out.clear();
        if dense {
            for (i, ue) in self.ues.iter().enumerate() {
                if ue.buffered_bytes() > 0 && ue.grant_ready(slot) {
                    out.push(i as u32);
                }
            }
        } else {
            for &i in &self.backlogged {
                debug_assert!(self.ues[i as usize].buffered_bytes() > 0);
                if self.ues[i as usize].grant_ready(slot) {
                    out.push(i);
                }
            }
            // The index is unordered (swap-remove); candidates must be
            // in ascending UE order so each consumes the same fading
            // draw as under a dense scan.
            out.sort_unstable();
        }
    }

    /// Remove UE `i` from the bank (A3 handover), returning its MAC
    /// state with buffers, HARQ and PF state intact. The bank's last
    /// UE swaps into slot `i` — the caller must re-map any external
    /// reference to it (its identity is its [`UeMac::tag`]). O(1).
    pub fn take_ue(&mut self, i: usize) -> UeMac {
        let bytes = self.ues[i].buffered_bytes();
        if self.pos[i] != NONE {
            self.remove(i);
            self.total_backlog -= bytes;
        }
        // Both arrays swap-remove at the same index, so the displaced
        // (formerly-last) UE lands at `i` in each.
        self.pos.swap_remove(i);
        let ue = self.ues.swap_remove(i);
        if i < self.ues.len() && self.pos[i] != NONE {
            // repoint the displaced UE's backlog-index slot
            self.backlogged[self.pos[i] as usize] = i as u32;
        }
        ue
    }

    /// Admit a migrating UE (A3 handover target side): appends it to
    /// the population, indexes any carried backlog, and invalidates
    /// its cached link budget (the serving carrier changed). Returns
    /// the UE's new local index.
    pub fn push_ue(&mut self, mut ue: UeMac) -> usize {
        ue.invalidate_link_cache();
        let i = self.ues.len();
        let bytes = ue.buffered_bytes();
        self.ues.push(ue);
        self.pos.push(NONE);
        if bytes > 0 {
            self.pos[i] = self.backlogged.len() as u32;
            self.backlogged.push(i as u32);
            self.total_backlog += bytes;
        }
        i
    }

    fn note_pushed(&mut self, i: usize, bytes: u64) {
        // A zero-byte SDU adds no backlog; indexing the UE anyway
        // would desync the index from `buffered_bytes() > 0`.
        if bytes == 0 {
            return;
        }
        self.total_backlog += bytes;
        if self.pos[i] == NONE {
            self.pos[i] = self.backlogged.len() as u32;
            self.backlogged.push(i as u32);
        }
    }

    fn remove(&mut self, i: usize) {
        let p = self.pos[i];
        debug_assert!(p != NONE, "UE {i} not indexed");
        let last = self.backlogged.pop().unwrap();
        if last != i as u32 {
            self.backlogged[p as usize] = last;
            self.pos[last as usize] = p;
        }
        self.pos[i] = NONE;
    }

    /// Full index-consistency audit (test/debug use; O(population)).
    pub fn check_invariants(&self) {
        let mut total = 0u64;
        for (i, ue) in self.ues.iter().enumerate() {
            let bytes = ue.buffered_bytes();
            total += bytes;
            let indexed = self.pos[i] != NONE;
            assert_eq!(
                indexed,
                bytes > 0,
                "UE {i}: indexed={indexed} but buffered_bytes={bytes}"
            );
            if indexed {
                assert_eq!(self.backlogged[self.pos[i] as usize], i as u32);
            }
        }
        assert_eq!(total, self.total_backlog, "total-backlog counter drifted");
        assert_eq!(
            self.backlogged.len(),
            self.pos.iter().filter(|&&p| p != NONE).count()
        );
    }
}

/// Drop a fresh population of `n` UEs with staggered SR phases (the
/// engine's construction pattern).
pub fn drop_ues(rng: &mut Rng, n: usize, r_min: f64, r_max: f64) -> Vec<UeMac> {
    use crate::phy::channel::LargeScale;
    (0..n)
        .map(|i| UeMac::new(LargeScale::drop(rng, r_min, r_max)).with_sr_phase(i as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::rlc::SduKind;

    fn sdu(kind: SduKind, bytes: u32) -> Sdu {
        Sdu { kind, total_bytes: bytes, bytes_left: bytes, t_arrival: 0.0 }
    }

    fn bank(n: usize) -> UeBank {
        let mut rng = Rng::new(9);
        UeBank::new(drop_ues(&mut rng, n, 35.0, 300.0))
    }

    #[test]
    fn push_and_drain_maintain_index() {
        let mut b = bank(4);
        assert!(!b.has_backlog());
        b.push_bg_sdu(2, sdu(SduKind::Background, 100));
        b.push_job_sdu(0, sdu(SduKind::Job { job_id: 1 }, 50));
        b.check_invariants();
        assert_eq!(b.n_backlogged(), 2);
        assert_eq!(b.total_backlog_bytes(), 150);

        let mut out = Vec::new();
        let drained = b.drain_served(2, 100, false, &mut out);
        assert_eq!(drained, 100);
        assert_eq!(out.len(), 1);
        b.check_invariants();
        assert_eq!(b.n_backlogged(), 1);
        assert_eq!(b.total_backlog_bytes(), 50);

        // partial drain keeps the UE indexed
        let drained = b.drain_served(0, 20, true, &mut out);
        assert_eq!(drained, 20);
        b.check_invariants();
        assert!(b.has_backlog());
        b.drain_served(0, 30, true, &mut out);
        b.check_invariants();
        assert!(!b.has_backlog());
        assert_eq!(b.total_backlog_bytes(), 0);
    }

    #[test]
    fn new_indexes_preloaded_ues() {
        let mut rng = Rng::new(3);
        let mut ues = drop_ues(&mut rng, 3, 35.0, 300.0);
        ues[1].push_bg_sdu(sdu(SduKind::Background, 77));
        let b = UeBank::new(ues);
        b.check_invariants();
        assert_eq!(b.n_backlogged(), 1);
        assert_eq!(b.total_backlog_bytes(), 77);
    }

    #[test]
    fn candidates_sorted_and_match_dense() {
        let mut b = bank(8);
        // push in a scrambled order so the swap-remove index is unordered
        for i in [5usize, 1, 7, 3] {
            b.push_bg_sdu(i, sdu(SduKind::Background, 10 + i as u32));
        }
        let mut active = Vec::new();
        let mut dense = Vec::new();
        b.candidates_into(0, false, &mut active);
        b.candidates_into(0, true, &mut dense);
        assert_eq!(active, dense);
        assert_eq!(active, vec![1, 3, 5, 7]);
        // drain one empty → both paths drop it
        let mut out = Vec::new();
        b.drain_served(3, 1000, false, &mut out);
        b.candidates_into(0, false, &mut active);
        b.candidates_into(0, true, &mut dense);
        assert_eq!(active, dense);
        assert_eq!(active, vec![1, 5, 7]);
    }

    #[test]
    fn drain_of_empty_ue_is_a_safe_noop() {
        // drain_served on an unindexed UE (zero backlog) must not
        // touch the index or underflow the counter.
        let mut b = bank(2);
        let mut out = Vec::new();
        assert_eq!(b.drain_served(1, 100, false, &mut out), 0);
        assert!(out.is_empty());
        b.check_invariants();
        // and zero-byte budget on an indexed UE keeps it indexed
        b.push_bg_sdu(0, sdu(SduKind::Background, 40));
        assert_eq!(b.drain_served(0, 0, false, &mut out), 0);
        assert!(b.has_backlog());
        b.check_invariants();
    }

    #[test]
    fn take_and_push_conserve_ues_and_backlog_across_banks() {
        // Property: random pushes/drains/migrations between two banks
        // conserve the UE population and every buffered byte, and both
        // backlog indices stay consistent throughout — the handover
        // state-carry invariant.
        use crate::util::proptest::check;
        check(20, |g| {
            let seed = g.u64_below(10_000);
            let n = g.usize_range(2, 8);
            let mut rng = Rng::new(seed);
            let mut a = UeBank::new(drop_ues(&mut rng, n, 35.0, 300.0));
            let mut b = UeBank::new(drop_ues(&mut rng, n, 35.0, 300.0));
            let mut script = Rng::new(seed ^ 0x5);
            let mut out = Vec::new();
            let total_ues = a.len() + b.len();
            for _ in 0..200 {
                match script.below(4) {
                    0 => {
                        let bank = if script.bernoulli(0.5) { &mut a } else { &mut b };
                        if !bank.is_empty() {
                            let i = script.below(bank.len() as u64) as usize;
                            bank.push_bg_sdu(
                                i,
                                sdu(SduKind::Background, 1 + script.below(5_000) as u32),
                            );
                        }
                    }
                    1 => {
                        let bank = if script.bernoulli(0.5) { &mut a } else { &mut b };
                        if !bank.is_empty() {
                            let i = script.below(bank.len() as u64) as usize;
                            bank.drain_served(i, script.below(4_000) as u32, false, &mut out);
                        }
                    }
                    _ => {
                        // migrate a random UE in a random direction
                        let a_to_b = script.bernoulli(0.5);
                        let (src, dst) =
                            if a_to_b { (&mut a, &mut b) } else { (&mut b, &mut a) };
                        if !src.is_empty() {
                            let i = script.below(src.len() as u64) as usize;
                            let carried = src.ue(i).buffered_bytes();
                            let ue = src.take_ue(i);
                            crate::prop_assert!(
                                ue.buffered_bytes() == carried,
                                "migration changed the carried backlog"
                            );
                            dst.push_ue(ue);
                        }
                    }
                }
                // check_invariants re-derives both totals from the
                // buffers, so any byte lost or duplicated by a
                // migration is caught here; the migration arm above
                // additionally pins byte-neutrality of the move itself.
                a.check_invariants();
                b.check_invariants();
                crate::prop_assert!(
                    a.len() + b.len() == total_ues,
                    "UE count drifted: {} + {} != {total_ues}",
                    a.len(),
                    b.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn take_ue_repoints_the_displaced_ue() {
        let mut b = bank(5);
        for i in 0..5 {
            b.push_bg_sdu(i, sdu(SduKind::Background, 10 * (i as u32 + 1)));
        }
        let total = b.total_backlog_bytes();
        // removing UE 1 swaps UE 4 into slot 1
        let taken = b.take_ue(1);
        assert_eq!(taken.buffered_bytes(), 20);
        assert_eq!(b.len(), 4);
        assert_eq!(b.total_backlog_bytes(), total - 20);
        assert_eq!(b.ue(1).buffered_bytes(), 50, "displaced UE must land at slot 1");
        b.check_invariants();
        // re-admit into another bank conserves bytes
        let mut other = bank(2);
        let i = other.push_ue(taken);
        assert_eq!(i, 2);
        assert_eq!(other.total_backlog_bytes(), 20);
        other.check_invariants();
        // taking the last UE is the trivial case
        let last = b.len() - 1;
        b.take_ue(last);
        b.check_invariants();
        // empty-buffer UEs migrate without touching the index
        let idle = UeBank::new(drop_ues(&mut Rng::new(4), 1, 35.0, 300.0)).take_ue(0);
        assert_eq!(idle.buffered_bytes(), 0);
        let j = other.push_ue(idle);
        assert_eq!(j, 3);
        other.check_invariants();
        assert_eq!(other.total_backlog_bytes(), 20);
    }

    #[test]
    fn swap_remove_keeps_positions_consistent() {
        let mut b = bank(6);
        for i in 0..6 {
            b.push_bg_sdu(i, sdu(SduKind::Background, 10));
        }
        let mut out = Vec::new();
        // remove from the middle, the front, and the back
        for i in [2usize, 0, 5, 3, 1, 4] {
            b.drain_served(i, 1000, false, &mut out);
            b.check_invariants();
        }
        assert!(!b.has_backlog());
    }
}
