//! The cell's UE population behind a backlog index.
//!
//! [`UeBank`] owns the per-UE MAC state and maintains an **active set**
//! — the indices of UEs with buffered bytes — so the slot scheduler
//! iterates candidates in O(active) instead of O(population). The
//! index is a swap-remove vector with a per-UE position table (O(1)
//! insert/remove) plus a running total-backlog counter, giving the
//! engine its "anything left to drain?" check in O(1).
//!
//! Invariants (see DESIGN.md §8):
//! * `backlogged` contains exactly the UEs with `buffered_bytes() > 0`
//!   (HARQ-blocked and SR-waiting UEs stay in; they are filtered per
//!   slot by `grant_ready`, which is cheap).
//! * `pos[i]` is the position of UE `i` in `backlogged`, or `NONE`.
//! * `total_backlog` is the byte sum over all UE buffers.
//!
//! All buffer mutations must go through bank methods (`push_job_sdu`,
//! `push_bg_sdu`, `drain_served`) so the index can never go stale;
//! [`UeBank::ue_mut`] hands out the UE for scheduler state (HARQ)
//! that does not move bytes.
//!
//! The scheduler's per-slot **hot fields** — PF average and lazy-decay
//! watermark, HARQ block slot, grant-ready slot, cached per-PRB rx
//! power — live in struct-of-arrays lanes parallel to `ues` (DESIGN.md
//! §12): the batched slot-SINR pass and the candidate filter read
//! contiguous memory instead of striding across `UeMac` structs. Lane
//! `i` always belongs to UE `i`; `take_ue`/`push_ue` carry the lanes
//! with the UE as a [`UeHot`] record so handover state-carry is exact.

use crate::phy::link::{rx_power_prb_dbm, PowerControl};
use crate::rng::Rng;

use super::rlc::{Sdu, SduDelivered};
use super::scheduler::{UeMac, METRIC_PRBS};

const NONE: u32 = u32::MAX;

/// The scheduler hot state of one UE, detached from its bank lanes for
/// handover migration ([`UeBank::take_ue`] → [`UeBank::push_ue`]). The
/// rx-power cache is not carried: the serving carrier changes, so the
/// target bank re-derives it on first touch.
#[derive(Debug, Clone, Copy)]
pub struct UeHot {
    /// PF throughput EWMA (bytes/slot), updated through
    /// `pf_next_slot - 1`.
    pub avg_thpt: f64,
    /// First slot whose PF update has not been folded into `avg_thpt`.
    pub pf_next_slot: u64,
    /// Slot index before which the UE cannot be scheduled (HARQ RTT).
    pub blocked_until: u64,
    /// Slot of the first grant opportunity after the SR cycle.
    pub grant_ready_slot: u64,
}

impl Default for UeHot {
    fn default() -> Self {
        Self { avg_thpt: 1.0, pf_next_slot: 0, blocked_until: 0, grant_ready_slot: 0 }
    }
}

/// The UE population of one cell plus its backlog index and the
/// scheduler's SoA hot-field lanes.
#[derive(Debug)]
pub struct UeBank {
    ues: Vec<UeMac>,
    /// Indices of backlogged UEs, unordered (swap-remove).
    backlogged: Vec<u32>,
    /// `pos[i]` = index of UE `i` in `backlogged`, or `NONE`.
    pos: Vec<u32>,
    /// Total buffered bytes across the cell.
    total_backlog: u64,
    /// PF throughput EWMA (bytes/slot), lazily decayed: lane `i`
    /// reflects updates through slot `pf_next_slot[i] - 1`; missed
    /// zero-traffic slots are applied in closed form on touch (see
    /// [`UeBank::pf_avg`]), so idle UEs cost nothing per slot.
    avg_thpt: Vec<f64>,
    /// First slot whose PF update (decay or goodput sample) has not
    /// yet been folded into `avg_thpt`.
    pf_next_slot: Vec<u64>,
    /// Slot index before which UE `i` cannot be scheduled (HARQ RTT).
    blocked_until: Vec<u64>,
    /// Slot of the first grant opportunity after the SR cycle.
    grant_ready_slot: Vec<u64>,
    /// Cached `rx_power_prb_dbm(coupling_loss, pc, METRIC_PRBS)` — the
    /// UE-dependent half of the per-candidate SINR. The log10/powf
    /// work behind it is paid once per position change instead of once
    /// per candidate per slot.
    rx8: Vec<f64>,
    rx8_valid: Vec<bool>,
}

impl UeBank {
    /// Build the bank (and its index) from an existing population —
    /// UEs may already hold buffered SDUs.
    pub fn new(ues: Vec<UeMac>) -> Self {
        let n = ues.len();
        let mut bank = Self {
            pos: vec![NONE; n],
            backlogged: Vec::new(),
            total_backlog: 0,
            avg_thpt: vec![1.0; n],
            pf_next_slot: vec![0; n],
            blocked_until: vec![0; n],
            grant_ready_slot: vec![0; n],
            rx8: vec![0.0; n],
            rx8_valid: vec![false; n],
            ues,
        };
        for i in 0..bank.ues.len() {
            let bytes = bank.ues[i].buffered_bytes();
            if bytes > 0 {
                bank.pos[i] = bank.backlogged.len() as u32;
                bank.backlogged.push(i as u32);
                bank.total_backlog += bytes;
            }
        }
        bank
    }

    pub fn len(&self) -> usize {
        self.ues.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ues.is_empty()
    }

    pub fn ue(&self, i: usize) -> &UeMac {
        &self.ues[i]
    }

    /// Mutable UE access for scheduler state (HARQ counters, PF
    /// averages, SR timing). Must NOT be used to push or drain SDUs —
    /// that would bypass the backlog index.
    pub fn ue_mut(&mut self, i: usize) -> &mut UeMac {
        &mut self.ues[i]
    }

    /// Number of UEs with buffered bytes.
    pub fn n_backlogged(&self) -> usize {
        self.backlogged.len()
    }

    /// Any bytes anywhere in the cell? O(1).
    pub fn has_backlog(&self) -> bool {
        !self.backlogged.is_empty()
    }

    /// Total buffered bytes across the cell. O(1).
    pub fn total_backlog_bytes(&self) -> u64 {
        self.total_backlog
    }

    /// Record that data arrived at `arrival_slot` (the slot whose
    /// scheduling decision could first see it). If the UE had nothing
    /// buffered, it must first fire an SR at its next opportunity
    /// (`period` = `MacConfig::effective_sr_period` for this cell)
    /// and wait `proc_slots` for the gNB to issue the grant.
    pub fn note_arrival(&mut self, i: usize, arrival_slot: u64, period: u64, proc_slots: u64) {
        if self.ues[i].buffered_bytes() == 0 && period > 0 {
            let phase = self.ues[i].sr_phase;
            let next_sr = if arrival_slot % period == phase % period {
                arrival_slot
            } else {
                let offset = (phase % period + period - arrival_slot % period) % period;
                arrival_slot + offset
            };
            self.grant_ready_slot[i] = self.grant_ready_slot[i].max(next_sr + proc_slots);
        }
    }

    /// Job-aware expedited grant (ICC packet prioritization, paper
    /// §IV-B item 1): because job characteristics are transparent to
    /// the communication system, a translation job's arrival uses a
    /// dedicated high-priority SR resource — only the gNB processing
    /// delay applies, the shared SR period is bypassed. This can only
    /// *advance* the grant, never delay it.
    pub fn note_job_arrival_expedited(&mut self, i: usize, arrival_slot: u64, proc_slots: u64) {
        self.grant_ready_slot[i] = self.grant_ready_slot[i].min(arrival_slot + proc_slots);
    }

    /// Can UE `i` receive a grant in `slot`?
    pub fn grant_ready(&self, i: usize, slot: u64) -> bool {
        self.grant_ready_slot[i] <= slot && self.blocked_until[i] <= slot
    }

    /// A3 handover interruption: the UE cannot be granted in its new
    /// cell until `slot + interruption_slots` (RACH + path switch).
    pub fn handover_interrupt(&mut self, i: usize, slot: u64, interruption_slots: u64) {
        self.grant_ready_slot[i] = self.grant_ready_slot[i].max(slot + interruption_slots);
    }

    /// HARQ retransmission hold: no grant for UE `i` before `until`.
    pub(crate) fn harq_block(&mut self, i: usize, until: u64) {
        self.blocked_until[i] = until;
    }

    /// PF average through slot `slot - 1`: applies the closed-form
    /// catch-up `avg · decay^Δ` for the Δ zero-traffic slots since the
    /// last update (`decay = 1 − 1/pf_window`). Equivalent to the
    /// eager per-slot EWMA decay `avg += (0 − avg)/W` the dense
    /// scheduler used to run over the whole population, but paid only
    /// by UEs that are actually touched.
    pub(crate) fn pf_avg(&mut self, i: usize, slot: u64, decay: f64) -> f64 {
        let missed = slot.saturating_sub(self.pf_next_slot[i]);
        if missed > 0 {
            // powi saturates the exponent; past ~2^31 missed slots the
            // factor has long underflowed to 0 anyway.
            self.avg_thpt[i] *= decay.powi(missed.min(i32::MAX as u64) as i32);
            self.pf_next_slot[i] = slot;
        }
        self.avg_thpt[i]
    }

    /// Fold the slot-`slot` goodput sample into the PF EWMA (the
    /// served-UE update; a HARQ-failed grant samples goodput 0).
    pub(crate) fn pf_note_served(&mut self, i: usize, slot: u64, goodput: f64, window: f64) {
        self.avg_thpt[i] += (goodput - self.avg_thpt[i]) / window;
        self.pf_next_slot[i] = slot + 1;
    }

    /// Re-derive UE `i`'s rx-power lane from its serving link if stale
    /// (no-op once warm — identical bits to the scalar recomputation).
    #[inline]
    pub(crate) fn refresh_rx8(&mut self, i: usize, pc: &PowerControl, freq_hz: f64) {
        if !self.rx8_valid[i] {
            self.rx8[i] =
                rx_power_prb_dbm(self.ues[i].link.coupling_loss_db(freq_hz), pc, METRIC_PRBS);
            self.rx8_valid[i] = true;
        }
    }

    /// UE `i`'s cached per-PRB received power (dBm) at the metric
    /// grant size. Must be fresh (see [`UeBank::refresh_rx8`]).
    #[inline]
    pub(crate) fn rx8_dbm(&self, i: usize) -> f64 {
        debug_assert!(self.rx8_valid[i]);
        self.rx8[i]
    }

    /// Refresh-and-read convenience for scalar callers.
    #[inline]
    pub(crate) fn rx_power8_dbm(&mut self, i: usize, pc: &PowerControl, freq_hz: f64) -> f64 {
        self.refresh_rx8(i, pc, freq_hz);
        self.rx8[i]
    }

    /// Drop UE `i`'s cached link budget (call after mutating its
    /// [`UeMac::link`] — mobility, handover).
    pub fn invalidate_link_cache(&mut self, i: usize) {
        self.rx8_valid[i] = false;
    }

    /// Push a job SDU and index the UE as backlogged.
    pub fn push_job_sdu(&mut self, i: usize, sdu: Sdu) {
        let bytes = sdu.bytes_left as u64;
        self.ues[i].push_job_sdu(sdu);
        self.note_pushed(i, bytes);
    }

    /// Push a background SDU and index the UE as backlogged.
    pub fn push_bg_sdu(&mut self, i: usize, sdu: Sdu) {
        let bytes = sdu.bytes_left as u64;
        self.ues[i].push_bg_sdu(sdu);
        self.note_pushed(i, bytes);
    }

    /// Drain one granted transport block from UE `i`, appending
    /// completed SDUs to `out` and unindexing the UE if its buffers
    /// emptied. Returns the bytes drained.
    pub fn drain_served(
        &mut self,
        i: usize,
        budget: u32,
        job_first: bool,
        out: &mut Vec<SduDelivered>,
    ) -> u64 {
        let before = self.ues[i].buffered_bytes();
        self.ues[i].drain_into(budget, job_first, out);
        let after = self.ues[i].buffered_bytes();
        let drained = before - after;
        self.total_backlog -= drained;
        if after == 0 && self.pos[i] != NONE {
            self.remove(i);
        }
        drained
    }

    /// Collect this slot's grant candidates (backlogged + grant-ready)
    /// into `out`, in ascending UE order. `dense` rebuilds the list by
    /// scanning the whole population — the reference path the
    /// active-set index must match exactly.
    pub(crate) fn candidates_into(&self, slot: u64, dense: bool, out: &mut Vec<u32>) {
        out.clear();
        if dense {
            for (i, ue) in self.ues.iter().enumerate() {
                if ue.buffered_bytes() > 0 && self.grant_ready(i, slot) {
                    out.push(i as u32);
                }
            }
        } else {
            for &i in &self.backlogged {
                debug_assert!(self.ues[i as usize].buffered_bytes() > 0);
                if self.grant_ready(i as usize, slot) {
                    out.push(i);
                }
            }
            // The index is unordered (swap-remove); candidates must be
            // in ascending UE order so each consumes the same fading
            // draw as under a dense scan.
            out.sort_unstable();
        }
    }

    /// Remove UE `i` from the bank (A3 handover), returning its MAC
    /// state with buffers and HARQ intact plus its hot lanes (PF
    /// average, HARQ block, grant-ready slot) as a [`UeHot`]. The
    /// bank's last UE swaps into slot `i` — the caller must re-map any
    /// external reference to it (its identity is its [`UeMac::tag`]).
    /// O(1).
    pub fn take_ue(&mut self, i: usize) -> (UeMac, UeHot) {
        let bytes = self.ues[i].buffered_bytes();
        if self.pos[i] != NONE {
            self.remove(i);
            self.total_backlog -= bytes;
        }
        let hot = UeHot {
            avg_thpt: self.avg_thpt[i],
            pf_next_slot: self.pf_next_slot[i],
            blocked_until: self.blocked_until[i],
            grant_ready_slot: self.grant_ready_slot[i],
        };
        // All arrays swap-remove at the same index, so the displaced
        // (formerly-last) UE lands at `i` in each.
        self.pos.swap_remove(i);
        self.avg_thpt.swap_remove(i);
        self.pf_next_slot.swap_remove(i);
        self.blocked_until.swap_remove(i);
        self.grant_ready_slot.swap_remove(i);
        self.rx8.swap_remove(i);
        self.rx8_valid.swap_remove(i);
        let ue = self.ues.swap_remove(i);
        if i < self.ues.len() && self.pos[i] != NONE {
            // repoint the displaced UE's backlog-index slot
            self.backlogged[self.pos[i] as usize] = i as u32;
        }
        (ue, hot)
    }

    /// Admit a migrating UE (A3 handover target side): appends it to
    /// the population, loads its carried hot state into fresh lanes,
    /// indexes any carried backlog, and leaves the rx-power cache
    /// stale (the serving carrier changed — re-derived on first
    /// touch). Returns the UE's new local index.
    pub fn push_ue(&mut self, ue: UeMac, hot: UeHot) -> usize {
        let i = self.ues.len();
        let bytes = ue.buffered_bytes();
        self.ues.push(ue);
        self.pos.push(NONE);
        self.avg_thpt.push(hot.avg_thpt);
        self.pf_next_slot.push(hot.pf_next_slot);
        self.blocked_until.push(hot.blocked_until);
        self.grant_ready_slot.push(hot.grant_ready_slot);
        self.rx8.push(0.0);
        self.rx8_valid.push(false);
        if bytes > 0 {
            self.pos[i] = self.backlogged.len() as u32;
            self.backlogged.push(i as u32);
            self.total_backlog += bytes;
        }
        i
    }

    /// Engine-snapshot view of UE `i`'s hot lanes (same record that
    /// handover migration carries).
    pub(crate) fn hot(&self, i: usize) -> UeHot {
        UeHot {
            avg_thpt: self.avg_thpt[i],
            pf_next_slot: self.pf_next_slot[i],
            blocked_until: self.blocked_until[i],
            grant_ready_slot: self.grant_ready_slot[i],
        }
    }

    /// Restore UE `i`'s hot lanes from a checkpoint. The rx-power
    /// cache is deliberately left stale: it is a pure function of the
    /// restored link and is re-derived bit-identically on first touch.
    pub(crate) fn set_hot(&mut self, i: usize, hot: UeHot) {
        self.avg_thpt[i] = hot.avg_thpt;
        self.pf_next_slot[i] = hot.pf_next_slot;
        self.blocked_until[i] = hot.blocked_until;
        self.grant_ready_slot[i] = hot.grant_ready_slot;
    }

    fn note_pushed(&mut self, i: usize, bytes: u64) {
        // A zero-byte SDU adds no backlog; indexing the UE anyway
        // would desync the index from `buffered_bytes() > 0`.
        if bytes == 0 {
            return;
        }
        self.total_backlog += bytes;
        if self.pos[i] == NONE {
            self.pos[i] = self.backlogged.len() as u32;
            self.backlogged.push(i as u32);
        }
    }

    fn remove(&mut self, i: usize) {
        let p = self.pos[i];
        debug_assert!(p != NONE, "UE {i} not indexed");
        let last = self.backlogged.pop().unwrap();
        if last != i as u32 {
            self.backlogged[p as usize] = last;
            self.pos[last as usize] = p;
        }
        self.pos[i] = NONE;
    }

    /// Full index-consistency audit (test/debug use; O(population)).
    pub fn check_invariants(&self) {
        let n = self.ues.len();
        assert!(
            self.pos.len() == n
                && self.avg_thpt.len() == n
                && self.pf_next_slot.len() == n
                && self.blocked_until.len() == n
                && self.grant_ready_slot.len() == n
                && self.rx8.len() == n
                && self.rx8_valid.len() == n,
            "hot-field lanes out of step with the population"
        );
        let mut total = 0u64;
        for (i, ue) in self.ues.iter().enumerate() {
            let bytes = ue.buffered_bytes();
            total += bytes;
            let indexed = self.pos[i] != NONE;
            assert_eq!(
                indexed,
                bytes > 0,
                "UE {i}: indexed={indexed} but buffered_bytes={bytes}"
            );
            if indexed {
                assert_eq!(self.backlogged[self.pos[i] as usize], i as u32);
            }
        }
        assert_eq!(total, self.total_backlog, "total-backlog counter drifted");
        assert_eq!(
            self.backlogged.len(),
            self.pos.iter().filter(|&&p| p != NONE).count()
        );
    }
}

/// Drop a fresh population of `n` UEs with staggered SR phases (the
/// engine's construction pattern).
pub fn drop_ues(rng: &mut Rng, n: usize, r_min: f64, r_max: f64) -> Vec<UeMac> {
    use crate::phy::channel::LargeScale;
    (0..n)
        .map(|i| UeMac::new(LargeScale::drop(rng, r_min, r_max)).with_sr_phase(i as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::rlc::SduKind;

    fn sdu(kind: SduKind, bytes: u32) -> Sdu {
        Sdu { kind, total_bytes: bytes, bytes_left: bytes, t_arrival: 0.0 }
    }

    fn bank(n: usize) -> UeBank {
        let mut rng = Rng::new(9);
        UeBank::new(drop_ues(&mut rng, n, 35.0, 300.0))
    }

    #[test]
    fn push_and_drain_maintain_index() {
        let mut b = bank(4);
        assert!(!b.has_backlog());
        b.push_bg_sdu(2, sdu(SduKind::Background, 100));
        b.push_job_sdu(0, sdu(SduKind::Job { job_id: 1 }, 50));
        b.check_invariants();
        assert_eq!(b.n_backlogged(), 2);
        assert_eq!(b.total_backlog_bytes(), 150);

        let mut out = Vec::new();
        let drained = b.drain_served(2, 100, false, &mut out);
        assert_eq!(drained, 100);
        assert_eq!(out.len(), 1);
        b.check_invariants();
        assert_eq!(b.n_backlogged(), 1);
        assert_eq!(b.total_backlog_bytes(), 50);

        // partial drain keeps the UE indexed
        let drained = b.drain_served(0, 20, true, &mut out);
        assert_eq!(drained, 20);
        b.check_invariants();
        assert!(b.has_backlog());
        b.drain_served(0, 30, true, &mut out);
        b.check_invariants();
        assert!(!b.has_backlog());
        assert_eq!(b.total_backlog_bytes(), 0);
    }

    #[test]
    fn new_indexes_preloaded_ues() {
        let mut rng = Rng::new(3);
        let mut ues = drop_ues(&mut rng, 3, 35.0, 300.0);
        ues[1].push_bg_sdu(sdu(SduKind::Background, 77));
        let b = UeBank::new(ues);
        b.check_invariants();
        assert_eq!(b.n_backlogged(), 1);
        assert_eq!(b.total_backlog_bytes(), 77);
    }

    #[test]
    fn candidates_sorted_and_match_dense() {
        let mut b = bank(8);
        // push in a scrambled order so the swap-remove index is unordered
        for i in [5usize, 1, 7, 3] {
            b.push_bg_sdu(i, sdu(SduKind::Background, 10 + i as u32));
        }
        let mut active = Vec::new();
        let mut dense = Vec::new();
        b.candidates_into(0, false, &mut active);
        b.candidates_into(0, true, &mut dense);
        assert_eq!(active, dense);
        assert_eq!(active, vec![1, 3, 5, 7]);
        // drain one empty → both paths drop it
        let mut out = Vec::new();
        b.drain_served(3, 1000, false, &mut out);
        b.candidates_into(0, false, &mut active);
        b.candidates_into(0, true, &mut dense);
        assert_eq!(active, dense);
        assert_eq!(active, vec![1, 5, 7]);
    }

    #[test]
    fn drain_of_empty_ue_is_a_safe_noop() {
        // drain_served on an unindexed UE (zero backlog) must not
        // touch the index or underflow the counter.
        let mut b = bank(2);
        let mut out = Vec::new();
        assert_eq!(b.drain_served(1, 100, false, &mut out), 0);
        assert!(out.is_empty());
        b.check_invariants();
        // and zero-byte budget on an indexed UE keeps it indexed
        b.push_bg_sdu(0, sdu(SduKind::Background, 40));
        assert_eq!(b.drain_served(0, 0, false, &mut out), 0);
        assert!(b.has_backlog());
        b.check_invariants();
    }

    #[test]
    fn take_and_push_conserve_ues_and_backlog_across_banks() {
        // Property: random pushes/drains/migrations between two banks
        // conserve the UE population and every buffered byte, and both
        // backlog indices stay consistent throughout — the handover
        // state-carry invariant.
        use crate::util::proptest::check;
        check(20, |g| {
            let seed = g.u64_below(10_000);
            let n = g.usize_range(2, 8);
            let mut rng = Rng::new(seed);
            let mut a = UeBank::new(drop_ues(&mut rng, n, 35.0, 300.0));
            let mut b = UeBank::new(drop_ues(&mut rng, n, 35.0, 300.0));
            let mut script = Rng::new(seed ^ 0x5);
            let mut out = Vec::new();
            let total_ues = a.len() + b.len();
            for _ in 0..200 {
                match script.below(4) {
                    0 => {
                        let bank = if script.bernoulli(0.5) { &mut a } else { &mut b };
                        if !bank.is_empty() {
                            let i = script.below(bank.len() as u64) as usize;
                            bank.push_bg_sdu(
                                i,
                                sdu(SduKind::Background, 1 + script.below(5_000) as u32),
                            );
                        }
                    }
                    1 => {
                        let bank = if script.bernoulli(0.5) { &mut a } else { &mut b };
                        if !bank.is_empty() {
                            let i = script.below(bank.len() as u64) as usize;
                            bank.drain_served(i, script.below(4_000) as u32, false, &mut out);
                        }
                    }
                    _ => {
                        // migrate a random UE in a random direction
                        let a_to_b = script.bernoulli(0.5);
                        let (src, dst) =
                            if a_to_b { (&mut a, &mut b) } else { (&mut b, &mut a) };
                        if !src.is_empty() {
                            let i = script.below(src.len() as u64) as usize;
                            let carried = src.ue(i).buffered_bytes();
                            let (ue, hot) = src.take_ue(i);
                            crate::prop_assert!(
                                ue.buffered_bytes() == carried,
                                "migration changed the carried backlog"
                            );
                            dst.push_ue(ue, hot);
                        }
                    }
                }
                // check_invariants re-derives both totals from the
                // buffers, so any byte lost or duplicated by a
                // migration is caught here; the migration arm above
                // additionally pins byte-neutrality of the move itself.
                a.check_invariants();
                b.check_invariants();
                crate::prop_assert!(
                    a.len() + b.len() == total_ues,
                    "UE count drifted: {} + {} != {total_ues}",
                    a.len(),
                    b.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn take_ue_repoints_the_displaced_ue() {
        let mut b = bank(5);
        for i in 0..5 {
            b.push_bg_sdu(i, sdu(SduKind::Background, 10 * (i as u32 + 1)));
        }
        let total = b.total_backlog_bytes();
        // removing UE 1 swaps UE 4 into slot 1; PF state rides along
        b.pf_note_served(1, 3, 640.0, 100.0);
        let (taken, hot) = b.take_ue(1);
        assert_eq!(taken.buffered_bytes(), 20);
        assert_eq!(hot.pf_next_slot, 4, "hot lanes must be carried");
        assert_eq!(b.len(), 4);
        assert_eq!(b.total_backlog_bytes(), total - 20);
        assert_eq!(b.ue(1).buffered_bytes(), 50, "displaced UE must land at slot 1");
        b.check_invariants();
        // re-admit into another bank conserves bytes and hot state
        let mut other = bank(2);
        let i = other.push_ue(taken, hot);
        assert_eq!(i, 2);
        assert_eq!(other.total_backlog_bytes(), 20);
        let decay = 1.0 - 1.0 / 100.0;
        assert_eq!(other.pf_avg(2, 4, decay).to_bits(), hot.avg_thpt.to_bits());
        other.check_invariants();
        // taking the last UE is the trivial case
        let last = b.len() - 1;
        b.take_ue(last);
        b.check_invariants();
        // empty-buffer UEs migrate without touching the index
        let (idle, idle_hot) =
            UeBank::new(drop_ues(&mut Rng::new(4), 1, 35.0, 300.0)).take_ue(0);
        assert_eq!(idle.buffered_bytes(), 0);
        let j = other.push_ue(idle, idle_hot);
        assert_eq!(j, 3);
        other.check_invariants();
        assert_eq!(other.total_backlog_bytes(), 20);
    }

    #[test]
    fn swap_remove_keeps_positions_consistent() {
        let mut b = bank(6);
        for i in 0..6 {
            b.push_bg_sdu(i, sdu(SduKind::Background, 10));
        }
        let mut out = Vec::new();
        // remove from the middle, the front, and the back
        for i in [2usize, 0, 5, 3, 1, 4] {
            b.drain_served(i, 1000, false, &mut out);
            b.check_invariants();
        }
        assert!(!b.has_backlog());
    }
}
