//! L2 protocol substrate of the SLS: RLC buffering/segmentation, HARQ,
//! the UE population behind its backlog index ([`UeBank`]), and the
//! slot-level uplink scheduler with ICC's job-aware packet
//! prioritization.

pub mod bank;
pub mod harq;
pub mod rlc;
pub mod scheduler;

pub use bank::{drop_ues, UeBank, UeHot};
pub use harq::HarqConfig;
pub use rlc::{RlcBuffer, Sdu, SduDelivered, SduKind};
pub use scheduler::{
    GrantResult, MacConfig, SchedulingPolicy, SlotWorkspace, UeMac, UlScheduler,
};
