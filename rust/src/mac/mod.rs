//! L2 protocol substrate of the SLS: RLC buffering/segmentation, HARQ,
//! and the slot-level uplink scheduler with ICC's job-aware packet
//! prioritization.

pub mod harq;
pub mod rlc;
pub mod scheduler;

pub use harq::HarqConfig;
pub use rlc::{RlcBuffer, Sdu, SduDelivered, SduKind};
pub use scheduler::{GrantResult, MacConfig, SchedulingPolicy, UeMac, UlScheduler};
