//! Slot-level uplink MAC scheduler.
//!
//! Implements the SLS's L2: per-slot PRB allocation across UEs with
//! buffer-status awareness, proportional-fair (or round-robin)
//! ordering, HARQ timing, and — the ICC ingredient — **job-aware
//! packet prioritization** (paper §IV-B): when enabled, prompt bytes of
//! translation jobs are served with strict priority over background
//! traffic, both across UEs and inside each UE's transport block.

use crate::phy::channel::{fast_fading_gain, LargeScale};
use crate::phy::link::{mean_sinr_db, sinr_to_cqi, tbs_bytes, PowerControl, Receiver};
use crate::phy::numerology::Carrier;
use crate::rng::Rng;

use super::harq::HarqConfig;
use super::rlc::{RlcBuffer, Sdu, SduDelivered, SduKind};

/// UE ordering policy among equal-priority candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulingPolicy {
    ProportionalFair,
    RoundRobin,
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct MacConfig {
    pub policy: SchedulingPolicy,
    /// ICC job-aware packet prioritization (paper §IV-B item 1).
    pub job_priority: bool,
    /// PF averaging window in slots.
    pub pf_window: f64,
    /// Cap on PRBs granted to one UE in one slot (0 = no cap).
    pub max_prb_per_ue: u32,
    pub harq: HarqConfig,
    /// Scheduling-request periodicity in slots (TS 38.331
    /// `sr-ProhibitTimer`-style cadence): a UE whose buffer was empty
    /// must wait for its next SR opportunity before being granted.
    pub sr_period_slots: u64,
    /// PUCCH SR resources are shared: each connected UE stretches the
    /// effective SR period by this many slots (cell dimensioning).
    /// `effective_period = max(sr_period_slots, n_ues × sr_slots_per_ue)`.
    pub sr_slots_per_ue: f64,
    /// gNB processing delay between SR reception and the first grant.
    pub grant_proc_slots: u64,
}

impl MacConfig {
    /// Effective SR period for a cell with `n_ues` connected UEs.
    pub fn effective_sr_period(&self, n_ues: u32) -> u64 {
        let scaled = (n_ues as f64 * self.sr_slots_per_ue).ceil() as u64;
        self.sr_period_slots.max(scaled)
    }
}

impl Default for MacConfig {
    fn default() -> Self {
        Self {
            policy: SchedulingPolicy::ProportionalFair,
            job_priority: false,
            pf_window: 100.0,
            max_prb_per_ue: 0,
            harq: HarqConfig::default(),
            // 4 slots @ 60 kHz = 1 ms floor SR period, stretched by
            // 0.25 slots per connected UE (shared PUCCH SR resources);
            // 2 slots = 0.5 ms gNB proc. This makes the uplink grant
            // cycle — and hence T_comm — grow with cell population,
            // the load dependence Fig 6's latency bars show. At 50 UEs
            // the effective period is ~13 slots ≈ 3.2 ms, putting the
            // MEC scheme's 4 ms comm budget (24 − 20 ms wireline) at
            // the margin exactly where the paper's MEC capacity sits.
            // Ablation C sweeps this knob.
            sr_period_slots: 4,
            sr_slots_per_ue: 0.25,
            grant_proc_slots: 2,
        }
    }
}

/// Per-UE MAC state.
#[derive(Debug)]
pub struct UeMac {
    pub link: LargeScale,
    pub job_buf: RlcBuffer,
    pub bg_buf: RlcBuffer,
    /// PF throughput EWMA (bytes/slot).
    avg_thpt: f64,
    /// HARQ attempt counter of the pending TB (0 = fresh data).
    harq_attempt: u8,
    /// Slot index before which this UE cannot be scheduled (HARQ RTT).
    blocked_until: u64,
    /// Slot of the first grant opportunity after the SR cycle.
    grant_ready_slot: u64,
    /// Deterministic SR phase of this UE (index % period).
    sr_phase: u64,
    /// Round-robin recency marker.
    last_served_slot: u64,
}

impl UeMac {
    pub fn new(link: LargeScale) -> Self {
        Self {
            link,
            job_buf: RlcBuffer::new(),
            bg_buf: RlcBuffer::new(),
            avg_thpt: 1.0,
            harq_attempt: 0,
            blocked_until: 0,
            grant_ready_slot: 0,
            sr_phase: 0,
            last_served_slot: 0,
        }
    }

    /// Set the UE's deterministic SR phase (sim uses UE index % period).
    pub fn with_sr_phase(mut self, phase: u64) -> Self {
        self.sr_phase = phase;
        self
    }

    /// Record that data arrived at `arrival_slot` (the slot whose
    /// scheduling decision could first see it). If the UE had nothing
    /// buffered, it must first fire an SR at its next opportunity
    /// (`period` = [`MacConfig::effective_sr_period`] for this cell)
    /// and wait `proc_slots` for the gNB to issue the grant.
    pub fn note_arrival(&mut self, arrival_slot: u64, period: u64, proc_slots: u64) {
        if self.buffered_bytes() == 0 && period > 0 {
            let next_sr = if arrival_slot % period == self.sr_phase % period {
                arrival_slot
            } else {
                let offset = (self.sr_phase % period + period - arrival_slot % period) % period;
                arrival_slot + offset
            };
            self.grant_ready_slot = self.grant_ready_slot.max(next_sr + proc_slots);
        }
    }

    /// Job-aware expedited grant (ICC packet prioritization, paper
    /// §IV-B item 1): because job characteristics are transparent to
    /// the communication system, a translation job's arrival uses a
    /// dedicated high-priority SR resource — only the gNB processing
    /// delay applies, the shared SR period is bypassed. This can only
    /// *advance* the grant, never delay it.
    pub fn note_job_arrival_expedited(&mut self, arrival_slot: u64, proc_slots: u64) {
        self.grant_ready_slot = self.grant_ready_slot.min(arrival_slot + proc_slots);
    }

    /// Can this UE receive a grant in `slot`?
    pub fn grant_ready(&self, slot: u64) -> bool {
        self.grant_ready_slot <= slot && self.blocked_until <= slot
    }

    pub fn push_job_sdu(&mut self, sdu: Sdu) {
        debug_assert!(matches!(sdu.kind, SduKind::Job { .. }));
        self.job_buf.push(sdu);
    }

    pub fn push_bg_sdu(&mut self, sdu: Sdu) {
        debug_assert!(sdu.kind == SduKind::Background);
        self.bg_buf.push(sdu);
    }

    pub fn buffered_bytes(&self) -> u64 {
        self.job_buf.bytes() + self.bg_buf.bytes()
    }

    pub fn has_job_bytes(&self) -> bool {
        !self.job_buf.is_empty()
    }

    /// Drain `budget` bytes. With `job_first`, job SDUs preempt
    /// background; otherwise strict arrival-time FIFO across both
    /// logical channels (the 5G-baseline single-queue behaviour).
    fn drain(&mut self, mut budget: u32, job_first: bool) -> Vec<SduDelivered> {
        let mut out = Vec::new();
        while budget > 0 {
            let use_job = if job_first {
                if !self.job_buf.is_empty() {
                    true
                } else if !self.bg_buf.is_empty() {
                    false
                } else {
                    break;
                }
            } else {
                match (self.job_buf.head_arrival(), self.bg_buf.head_arrival()) {
                    (Some(j), Some(b)) => j <= b,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => break,
                }
            };
            let buf = if use_job { &mut self.job_buf } else { &mut self.bg_buf };
            let before = buf.bytes();
            out.extend(buf.drain(budget));
            let used = (before - buf.bytes()) as u32;
            if used == 0 {
                break;
            }
            budget -= used;
        }
        out
    }
}

/// Outcome of one scheduled UE in one slot.
#[derive(Debug)]
pub struct GrantResult {
    pub ue: usize,
    pub n_prb: u32,
    pub tb_bytes: u32,
    pub harq_ok: bool,
    /// SDUs that completed in this slot (empty if HARQ failed).
    pub delivered: Vec<SduDelivered>,
}

/// The gNB uplink scheduler.
#[derive(Debug)]
pub struct UlScheduler {
    pub cfg: MacConfig,
    pub carrier: Carrier,
    pub pc: PowerControl,
    pub rx: Receiver,
}

impl UlScheduler {
    pub fn new(cfg: MacConfig, carrier: Carrier) -> Self {
        Self { cfg, carrier, pc: PowerControl::default(), rx: Receiver::default() }
    }

    /// Effective CQI of a UE this slot (mean SINR + fast fading).
    fn slot_cqi(&self, ue: &UeMac, n_prb: u32, rng: &mut Rng) -> u8 {
        let mean = mean_sinr_db(&ue.link, &self.carrier, &self.pc, &self.rx, n_prb);
        let fade_db = 10.0 * fast_fading_gain(rng, ue.link.los).log10();
        sinr_to_cqi(mean + fade_db)
    }

    /// Schedule one slot. Mutates UE buffers/HARQ state; returns the
    /// per-UE grant outcomes (delivered SDUs drive the upper layers).
    pub fn schedule_slot(
        &self,
        slot: u64,
        ues: &mut [UeMac],
        rng: &mut Rng,
    ) -> Vec<GrantResult> {
        // 1. Candidates: backlogged + not HARQ-blocked + SR cycle done.
        let mut cand: Vec<usize> = (0..ues.len())
            .filter(|&i| ues[i].buffered_bytes() > 0 && ues[i].grant_ready(slot))
            .collect();
        if cand.is_empty() {
            for ue in ues.iter_mut() {
                ue.avg_thpt += (0.0 - ue.avg_thpt) / self.cfg.pf_window;
            }
            return Vec::new();
        }

        // 2. Order: job-bearing UEs strictly first if prioritization is
        //    on; PF (rate / avg) or RR (least-recently-served) inside
        //    each class. The slot's CQI is drawn ONCE per candidate
        //    (one fast-fading realization per UE per slot) and reused
        //    for the grant — both faster and statistically consistent
        //    (the grant uses the SINR the metric ranked).
        let mut keyed: Vec<(bool, f64, u8, usize)> = cand
            .drain(..)
            .map(|i| {
                let has_job = self.cfg.job_priority && ues[i].has_job_bytes();
                let cqi = self.slot_cqi(&ues[i], 8, rng);
                let metric = match self.cfg.policy {
                    SchedulingPolicy::ProportionalFair => {
                        let inst = tbs_bytes(&self.carrier, cqi, 1) as f64;
                        inst / ues[i].avg_thpt.max(1e-9)
                    }
                    // older service time → larger metric
                    SchedulingPolicy::RoundRobin => -(ues[i].last_served_slot as f64),
                };
                (has_job, metric, cqi, i)
            })
            .collect();
        // job class first, then metric descending, index as tiebreak
        keyed.sort_by(|a, b| {
            b.0.cmp(&a.0)
                .then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.3.cmp(&b.3))
        });

        // 3. Greedy PRB allocation down the ordered list.
        let mut remaining = self.carrier.n_prb;
        let mut results = Vec::new();
        let mut served = vec![false; ues.len()];
        for (_, _, cqi, i) in keyed {
            if remaining == 0 {
                break;
            }
            if cqi == 0 {
                continue; // outage this slot
            }
            let per_prb = tbs_bytes(&self.carrier, cqi, 1).max(1);
            let want = ues[i].buffered_bytes().min(u32::MAX as u64) as u32;
            let mut n_prb = want.div_ceil(per_prb);
            if self.cfg.max_prb_per_ue > 0 {
                n_prb = n_prb.min(self.cfg.max_prb_per_ue);
            }
            n_prb = n_prb.min(remaining).max(1);
            remaining -= n_prb;
            let tb = tbs_bytes(&self.carrier, cqi, n_prb);

            // 4. HARQ outcome.
            let attempt = ues[i].harq_attempt;
            let ok = self.cfg.harq.transmit_ok(rng, attempt);
            let delivered = if ok {
                ues[i].harq_attempt = 0;
                ues[i].drain(tb, self.cfg.job_priority)
            } else {
                ues[i].harq_attempt = attempt.saturating_add(1);
                ues[i].blocked_until = slot + self.cfg.harq.rtt_slots as u64;
                Vec::new()
            };
            let goodput: u32 = if ok { tb.min(want) } else { 0 };
            served[i] = true;
            ues[i].last_served_slot = slot;
            // PF EWMA update for the served UE
            let ue = &mut ues[i];
            ue.avg_thpt += (goodput as f64 - ue.avg_thpt) / self.cfg.pf_window;
            results.push(GrantResult { ue: i, n_prb, tb_bytes: tb, harq_ok: ok, delivered });
        }
        // PF EWMA decay for everyone not served this slot.
        for (i, ue) in ues.iter_mut().enumerate() {
            if !served[i] {
                ue.avg_thpt += (0.0 - ue.avg_thpt) / self.cfg.pf_window;
            }
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phy::channel::Position;

    fn ls(d: f64) -> LargeScale {
        LargeScale { pos: Position { x: d, y: 0.0 }, los: true, shadow_db: 0.0 }
    }

    fn job_sdu(id: u64, bytes: u32, t: f64) -> Sdu {
        Sdu { kind: SduKind::Job { job_id: id }, total_bytes: bytes, bytes_left: bytes, t_arrival: t }
    }

    fn bg_sdu(bytes: u32, t: f64) -> Sdu {
        Sdu { kind: SduKind::Background, total_bytes: bytes, bytes_left: bytes, t_arrival: t }
    }

    fn sched(job_priority: bool) -> UlScheduler {
        let cfg = MacConfig {
            job_priority,
            harq: HarqConfig { bler: 0.0, ..Default::default() },
            ..Default::default()
        };
        UlScheduler::new(cfg, Carrier::table1())
    }

    #[test]
    fn empty_ues_no_grants() {
        let s = sched(false);
        let mut ues = vec![UeMac::new(ls(100.0))];
        let mut rng = Rng::new(1);
        assert!(s.schedule_slot(0, &mut ues, &mut rng).is_empty());
    }

    #[test]
    fn single_ue_small_sdu_delivered_in_one_slot() {
        let s = sched(false);
        let mut ues = vec![UeMac::new(ls(80.0))];
        ues[0].push_job_sdu(job_sdu(1, 600, 0.0));
        let mut rng = Rng::new(2);
        let res = s.schedule_slot(0, &mut ues, &mut rng);
        assert_eq!(res.len(), 1);
        assert!(res[0].harq_ok);
        assert_eq!(res[0].delivered.len(), 1);
        assert_eq!(ues[0].buffered_bytes(), 0);
    }

    #[test]
    fn job_priority_preempts_background_within_ue() {
        // Large bg SDU arrived first; with priority on, the job SDU
        // must still complete first.
        let mut ues = vec![UeMac::new(ls(250.0))];
        ues[0].push_bg_sdu(bg_sdu(200_000, 0.0));
        ues[0].push_job_sdu(job_sdu(9, 600, 1.0));
        let s = sched(true);
        let mut rng = Rng::new(3);
        let mut job_done_slot = None;
        let mut bg_done_slot = None;
        for slot in 0..2000 {
            for r in s.schedule_slot(slot, &mut ues, &mut rng) {
                for d in &r.delivered {
                    match d.kind {
                        SduKind::Job { .. } => job_done_slot.get_or_insert(slot),
                        SduKind::Background => bg_done_slot.get_or_insert(slot),
                    };
                }
            }
            if job_done_slot.is_some() && bg_done_slot.is_some() {
                break;
            }
        }
        let (j, b) = (job_done_slot.unwrap(), bg_done_slot.unwrap());
        assert!(j < b, "job slot {j} !< bg slot {b}");
    }

    #[test]
    fn fifo_baseline_respects_arrival_order() {
        // Without prioritization the earlier bg SDU completes first.
        let mut ues = vec![UeMac::new(ls(250.0))];
        ues[0].push_bg_sdu(bg_sdu(60_000, 0.0));
        ues[0].push_job_sdu(job_sdu(9, 600, 1.0));
        let s = sched(false);
        let mut rng = Rng::new(4);
        let mut first_done = None;
        'outer: for slot in 0..2000 {
            for r in s.schedule_slot(slot, &mut ues, &mut rng) {
                if let Some(d) = r.delivered.first() {
                    first_done = Some(d.kind);
                    break 'outer;
                }
            }
        }
        assert_eq!(first_done.unwrap(), SduKind::Background);
    }

    #[test]
    fn prb_budget_respected() {
        let s = sched(false);
        let mut ues: Vec<UeMac> = (0..40)
            .map(|i| {
                let mut ue = UeMac::new(ls(50.0 + 6.0 * i as f64));
                ue.push_bg_sdu(bg_sdu(1_000_000, 0.0));
                ue
            })
            .collect();
        let mut rng = Rng::new(5);
        let res = s.schedule_slot(0, &mut ues, &mut rng);
        let total: u32 = res.iter().map(|r| r.n_prb).sum();
        assert!(total <= Carrier::table1().n_prb, "total = {total}");
        assert!(!res.is_empty());
    }

    #[test]
    fn harq_failure_blocks_and_retains_bytes() {
        let cfg = MacConfig {
            harq: HarqConfig { bler: 1.0, combining_gain: 1.0, max_tx: 8, rtt_slots: 4 },
            ..Default::default()
        };
        let s = UlScheduler::new(cfg, Carrier::table1());
        let mut ues = vec![UeMac::new(ls(80.0))];
        ues[0].push_job_sdu(job_sdu(1, 500, 0.0));
        let mut rng = Rng::new(6);
        let res = s.schedule_slot(0, &mut ues, &mut rng);
        assert!(!res[0].harq_ok);
        assert_eq!(ues[0].buffered_bytes(), 500);
        // blocked for RTT slots
        assert!(s.schedule_slot(1, &mut ues, &mut rng).is_empty());
        assert!(s.schedule_slot(3, &mut ues, &mut rng).is_empty());
        assert!(!s.schedule_slot(4, &mut ues, &mut rng).is_empty());
    }

    #[test]
    fn pf_shares_between_ues_over_time() {
        // Two backlogged UEs at different distances must both be served
        // over a window (PF fairness), not starved.
        let s = sched(false);
        let mut ues = vec![UeMac::new(ls(60.0)), UeMac::new(ls(280.0))];
        let mut served = [0u32; 2];
        let mut rng = Rng::new(7);
        for slot in 0..400 {
            for ue in ues.iter_mut() {
                if ue.buffered_bytes() < 10_000 {
                    ue.push_bg_sdu(bg_sdu(50_000, slot as f64 * 0.00025));
                }
            }
            for r in s.schedule_slot(slot, &mut ues, &mut rng) {
                served[r.ue] += r.n_prb;
            }
        }
        assert!(served[0] > 0 && served[1] > 0, "served = {served:?}");
    }

    #[test]
    fn job_ues_scheduled_before_bg_ues_under_priority() {
        // UE 0 has only background; UE 1 has a job. With few PRBs
        // available (cap via max_prb_per_ue high but carrier small),
        // the job UE must be first in the grant list.
        let cfg = MacConfig {
            job_priority: true,
            harq: HarqConfig { bler: 0.0, ..Default::default() },
            ..Default::default()
        };
        let s = UlScheduler::new(cfg, Carrier::table1());
        let mut ues = vec![UeMac::new(ls(50.0)), UeMac::new(ls(200.0))];
        ues[0].push_bg_sdu(bg_sdu(500_000, 0.0));
        ues[1].push_job_sdu(job_sdu(1, 600, 0.0));
        let mut rng = Rng::new(8);
        let res = s.schedule_slot(0, &mut ues, &mut rng);
        assert_eq!(res[0].ue, 1, "job UE must be granted first");
    }
}
