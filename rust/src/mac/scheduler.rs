//! Slot-level uplink MAC scheduler.
//!
//! Implements the SLS's L2: per-slot PRB allocation across UEs with
//! buffer-status awareness, proportional-fair (or round-robin)
//! ordering, HARQ timing, and — the ICC ingredient — **job-aware
//! packet prioritization** (paper §IV-B): when enabled, prompt bytes of
//! translation jobs are served with strict priority over background
//! traffic, both across UEs and inside each UE's transport block.

use crate::phy::channel::{fast_fading_gain, LargeScale};
use crate::phy::link::{
    noise_floor_prb_dbm, rx_power_prb_dbm, sinr_to_cqi, sinr_to_cqi_batch, tbs_bytes,
    PowerControl, Receiver,
};
use crate::phy::numerology::Carrier;
use crate::rng::Rng;

use super::bank::UeBank;
use super::harq::HarqConfig;
use super::rlc::{RlcBuffer, Sdu, SduDelivered, SduKind};

/// UE ordering policy among equal-priority candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulingPolicy {
    ProportionalFair,
    RoundRobin,
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct MacConfig {
    pub policy: SchedulingPolicy,
    /// ICC job-aware packet prioritization (paper §IV-B item 1).
    pub job_priority: bool,
    /// PF averaging window in slots.
    pub pf_window: f64,
    /// Cap on PRBs granted to one UE in one slot (0 = no cap).
    pub max_prb_per_ue: u32,
    pub harq: HarqConfig,
    /// Scheduling-request periodicity in slots (TS 38.331
    /// `sr-ProhibitTimer`-style cadence): a UE whose buffer was empty
    /// must wait for its next SR opportunity before being granted.
    pub sr_period_slots: u64,
    /// PUCCH SR resources are shared: each connected UE stretches the
    /// effective SR period by this many slots (cell dimensioning).
    /// `effective_period = max(sr_period_slots, n_ues × sr_slots_per_ue)`.
    pub sr_slots_per_ue: f64,
    /// gNB processing delay between SR reception and the first grant.
    pub grant_proc_slots: u64,
    /// Debug/reference mode: scan the full UE population for
    /// candidates every slot (the pre-active-set behaviour) instead of
    /// consulting the [`UeBank`] backlog index. The two paths must
    /// produce identical schedules — the `active_set_matches_dense`
    /// property test asserts it.
    pub dense_scan: bool,
}

impl MacConfig {
    /// Effective SR period for a cell with `n_ues` connected UEs.
    pub fn effective_sr_period(&self, n_ues: u32) -> u64 {
        let scaled = (n_ues as f64 * self.sr_slots_per_ue).ceil() as u64;
        self.sr_period_slots.max(scaled)
    }
}

impl Default for MacConfig {
    fn default() -> Self {
        Self {
            policy: SchedulingPolicy::ProportionalFair,
            job_priority: false,
            pf_window: 100.0,
            max_prb_per_ue: 0,
            harq: HarqConfig::default(),
            // 4 slots @ 60 kHz = 1 ms floor SR period, stretched by
            // 0.25 slots per connected UE (shared PUCCH SR resources);
            // 2 slots = 0.5 ms gNB proc. This makes the uplink grant
            // cycle — and hence T_comm — grow with cell population,
            // the load dependence Fig 6's latency bars show. At 50 UEs
            // the effective period is ~13 slots ≈ 3.2 ms, putting the
            // MEC scheme's 4 ms comm budget (24 − 20 ms wireline) at
            // the margin exactly where the paper's MEC capacity sits.
            // Ablation C sweeps this knob.
            sr_period_slots: 4,
            sr_slots_per_ue: 0.25,
            grant_proc_slots: 2,
            dense_scan: false,
        }
    }
}

/// PRB assumption of the per-candidate link-quality metric (the CQI
/// the scheduler ranks with is priced at this grant size).
pub(crate) const METRIC_PRBS: u32 = 8;

/// Per-UE MAC state. The per-slot hot fields (PF average, HARQ block,
/// grant-ready slot, rx-power cache) live in [`UeBank`] SoA lanes, not
/// here — this struct holds the cold remainder: buffers, identity,
/// HARQ attempt counter, SR phase.
#[derive(Debug)]
pub struct UeMac {
    /// Serving-cell large-scale channel. Anything that mutates this
    /// (mobility, handover) must call
    /// [`UeBank::invalidate_link_cache`] so the cached link budget is
    /// recomputed.
    pub link: LargeScale,
    /// Stable identity across handovers (the engine's global UE id;
    /// 0 for banks built outside the scenario engine).
    pub tag: u64,
    /// Crate-private: byte-moving access goes through [`UeBank`] so
    /// the backlog index stays in sync.
    pub(crate) job_buf: RlcBuffer,
    pub(crate) bg_buf: RlcBuffer,
    /// HARQ attempt counter of the pending TB (0 = fresh data).
    harq_attempt: u8,
    /// Deterministic SR phase of this UE (index % period).
    pub(crate) sr_phase: u64,
    /// Round-robin recency marker.
    last_served_slot: u64,
}

impl UeMac {
    pub fn new(link: LargeScale) -> Self {
        Self {
            link,
            tag: 0,
            job_buf: RlcBuffer::new(),
            bg_buf: RlcBuffer::new(),
            harq_attempt: 0,
            sr_phase: 0,
            last_served_slot: 0,
        }
    }

    /// Set the UE's deterministic SR phase (sim uses UE index % period).
    pub fn with_sr_phase(mut self, phase: u64) -> Self {
        self.sr_phase = phase;
        self
    }

    /// Engine-snapshot view of the private HARQ/RR fields:
    /// `(harq_attempt, last_served_slot)`.
    pub(crate) fn snapshot_state(&self) -> (u8, u64) {
        (self.harq_attempt, self.last_served_slot)
    }

    /// Rebuild a UE from checkpointed state (buffers may hold
    /// partially-drained SDUs; the bank re-derives its backlog index
    /// from the restored buffers in [`UeBank::new`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_snapshot(
        link: LargeScale,
        tag: u64,
        job_buf: RlcBuffer,
        bg_buf: RlcBuffer,
        harq_attempt: u8,
        sr_phase: u64,
        last_served_slot: u64,
    ) -> Self {
        Self { link, tag, job_buf, bg_buf, harq_attempt, sr_phase, last_served_slot }
    }

    /// Crate-private: byte-moving pushes must go through
    /// [`UeBank::push_job_sdu`] so the backlog index stays in sync
    /// (only [`UeBank::new`] may see pre-loaded buffers).
    pub(crate) fn push_job_sdu(&mut self, sdu: Sdu) {
        debug_assert!(matches!(sdu.kind, SduKind::Job { .. }));
        self.job_buf.push(sdu);
    }

    /// Crate-private: see [`UeMac::push_job_sdu`].
    pub(crate) fn push_bg_sdu(&mut self, sdu: Sdu) {
        debug_assert!(sdu.kind == SduKind::Background);
        self.bg_buf.push(sdu);
    }

    pub fn buffered_bytes(&self) -> u64 {
        self.job_buf.bytes() + self.bg_buf.bytes()
    }

    pub fn has_job_bytes(&self) -> bool {
        !self.job_buf.is_empty()
    }

    /// Drain `budget` bytes into `out`. With `job_first`, job SDUs
    /// preempt background; otherwise strict arrival-time FIFO across
    /// both logical channels (the 5G-baseline single-queue behaviour).
    pub(crate) fn drain_into(
        &mut self,
        mut budget: u32,
        job_first: bool,
        out: &mut Vec<SduDelivered>,
    ) {
        while budget > 0 {
            let use_job = if job_first {
                if !self.job_buf.is_empty() {
                    true
                } else if !self.bg_buf.is_empty() {
                    false
                } else {
                    break;
                }
            } else {
                match (self.job_buf.head_arrival(), self.bg_buf.head_arrival()) {
                    (Some(j), Some(b)) => j <= b,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => break,
                }
            };
            let buf = if use_job { &mut self.job_buf } else { &mut self.bg_buf };
            let used = buf.drain_into(budget, out);
            if used == 0 {
                break;
            }
            budget -= used;
        }
    }
}

/// Outcome of one scheduled UE in one slot. Delivered SDUs live in the
/// slot's shared [`SlotWorkspace::delivered`] buffer; `delivered` is
/// the grant's `[start, end)` range into it (empty if HARQ failed) —
/// read it via [`SlotWorkspace::delivered_of`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GrantResult {
    pub ue: usize,
    pub n_prb: u32,
    pub tb_bytes: u32,
    pub harq_ok: bool,
    pub delivered: (u32, u32),
}

/// Per-slot scheduling buffers, reused across slots so the hot path
/// allocates nothing after warm-up: candidate indices, the sort keys,
/// the grant list, and the flat delivered-SDU buffer all keep their
/// capacity between [`UlScheduler::schedule_slot`] calls.
#[derive(Debug, Default)]
pub struct SlotWorkspace {
    /// Grants issued this slot, in allocation order.
    pub grants: Vec<GrantResult>,
    /// SDUs delivered this slot, in grant order (drain order within a
    /// grant). Upper layers that don't need per-grant attribution can
    /// iterate this flat list directly.
    pub delivered: Vec<SduDelivered>,
    cand: Vec<u32>,
    keyed: Vec<(bool, f64, u8, u32)>,
    /// Per-candidate fast-fading draws (dB) of the batched slot-SINR
    /// pass, filled in one array sweep in ascending-UE order so each
    /// candidate consumes exactly the draw the scalar path would give
    /// it.
    fade_db: Vec<f64>,
    /// Per-candidate SINR (dB) assembled from the bank's contiguous
    /// rx-power lane, the slot noise floor and `fade_db` — the input
    /// array of the chunked CQI kernel.
    sinr_db: Vec<f64>,
    /// Per-candidate CQI from `sinr_to_cqi_batch` over `sinr_db`.
    cqi: Vec<u8>,
    /// Per-CQI single-PRB transport-block bytes, hoisted out of the
    /// per-candidate PF metric (filled lazily from the scheduler's
    /// carrier — a workspace is paired with one scheduler/cell).
    tbs1: Vec<f64>,
}

impl SlotWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// The delivered SDUs of one grant.
    pub fn delivered_of(&self, g: &GrantResult) -> &[SduDelivered] {
        &self.delivered[g.delivered.0 as usize..g.delivered.1 as usize]
    }

    fn clear(&mut self) {
        self.grants.clear();
        self.delivered.clear();
        self.cand.clear();
        self.keyed.clear();
        self.fade_db.clear();
        self.sinr_db.clear();
        self.cqi.clear();
        // tbs1 is carrier-derived, not per-slot: it survives clears.
    }
}

/// The gNB uplink scheduler.
#[derive(Debug)]
pub struct UlScheduler {
    pub cfg: MacConfig,
    pub carrier: Carrier,
    pub pc: PowerControl,
    pub rx: Receiver,
}

impl UlScheduler {
    pub fn new(cfg: MacConfig, carrier: Carrier) -> Self {
        Self { cfg, carrier, pc: PowerControl::default(), rx: Receiver::default() }
    }

    /// Schedule one slot under the receiver's fixed interference
    /// margin (the legacy single-cell model). Coupled-radio callers
    /// use [`UlScheduler::schedule_slot_iot`] with the dynamic
    /// interference-over-thermal term instead; this wrapper is
    /// bit-identical to the pre-coupling scheduler.
    pub fn schedule_slot(
        &self,
        slot: u64,
        bank: &mut UeBank,
        rng: &mut Rng,
        ws: &mut SlotWorkspace,
    ) {
        self.schedule_slot_iot(slot, bank, rng, ws, self.rx.interference_margin_db);
    }

    /// Schedule one slot with an explicit interference-over-thermal
    /// term (dB) on the noise floor. Mutates UE buffers/HARQ state
    /// through the bank; grant outcomes and delivered SDUs land in
    /// `ws` (buffers reused across slots — the hot path allocates
    /// nothing once the workspace is warm).
    ///
    /// Cost is O(k log k) in the number of *candidates* k (backlogged,
    /// grant-ready UEs), not the cell population: candidates come from
    /// the bank's backlog index, PF averages decay lazily in closed
    /// form on touch, and link quality comes from the **batched
    /// slot-SINR pass** — fast-fading draws fill a workspace array in
    /// one ascending-UE sweep, the noise floor is hoisted to one
    /// computation per slot, each UE's received-power term is cached
    /// until it moves, and the PF metric reads a per-CQI TBS table.
    /// With `cfg.dense_scan` the candidate list is instead rebuilt by
    /// a full population scan and every candidate's link budget is
    /// recomputed from scratch (the scalar reference path — both must
    /// produce identical schedules, pinned by the
    /// `active_set_matches_dense` and `batched_sinr_matches_scalar_*`
    /// property tests).
    pub fn schedule_slot_iot(
        &self,
        slot: u64,
        bank: &mut UeBank,
        rng: &mut Rng,
        ws: &mut SlotWorkspace,
        iot_db: f64,
    ) {
        ws.clear();
        // 1. Candidates: backlogged + not HARQ-blocked + SR cycle done,
        //    in ascending UE order (the order fixes which fast-fading
        //    draw each candidate consumes, so index and dense scans
        //    must agree on it).
        bank.candidates_into(slot, self.cfg.dense_scan, &mut ws.cand);
        if ws.cand.is_empty() {
            return;
        }
        let decay = 1.0 - 1.0 / self.cfg.pf_window;
        // Slot-constant noise-plus-interference floor, hoisted out of
        // the candidate loop (same float expression as the historical
        // per-candidate computation, so hoisting cannot drift a bit).
        let noise = noise_floor_prb_dbm(&self.carrier, &self.rx, iot_db);

        // 2. Order: job-bearing UEs strictly first if prioritization is
        //    on; PF (rate / avg) or RR (least-recently-served) inside
        //    each class. The slot's CQI is drawn ONCE per candidate
        //    (one fast-fading realization per UE per slot) and reused
        //    for the grant — both faster and statistically consistent
        //    (the grant uses the SINR the metric ranked).
        if self.cfg.dense_scan {
            // Scalar reference path: recompute every candidate's link
            // budget from scratch (pre-batching behaviour).
            for &iu in &ws.cand {
                let i = iu as usize;
                let has_job = self.cfg.job_priority && bank.ue(i).has_job_bytes();
                let ue = bank.ue(i);
                let mean = rx_power_prb_dbm(
                    ue.link.coupling_loss_db(self.carrier.freq_hz),
                    &self.pc,
                    METRIC_PRBS,
                ) - noise;
                let fade_db = 10.0 * fast_fading_gain(rng, ue.link.los).log10();
                let cqi = sinr_to_cqi(mean + fade_db);
                let metric = match self.cfg.policy {
                    SchedulingPolicy::ProportionalFair => {
                        let inst = tbs_bytes(&self.carrier, cqi, 1) as f64;
                        inst / bank.pf_avg(i, slot, decay).max(1e-9)
                    }
                    // older service time → larger metric
                    SchedulingPolicy::RoundRobin => {
                        -(bank.ue(i).last_served_slot as f64)
                    }
                };
                ws.keyed.push((has_job, metric, cqi, iu));
            }
        } else {
            // Batched slot-SINR pass. Fadings first, in one array
            // sweep over the ascending candidate list — the RNG stream
            // position of each draw is exactly the scalar path's.
            for &iu in &ws.cand {
                ws.fade_db
                    .push(10.0 * fast_fading_gain(rng, bank.ue(iu as usize).link.los).log10());
            }
            if ws.tbs1.is_empty() {
                for cqi in 0..=15u8 {
                    ws.tbs1.push(tbs_bytes(&self.carrier, cqi, 1) as f64);
                }
            }
            // Re-derive any stale rx-power lanes (no-op in steady
            // state), then assemble the candidates' SINR array from
            // the contiguous lane and map it through the chunked
            // branchless CQI kernel. The float expression per lane is
            // `(rx8 − noise) + fade` — the same association the
            // scalar path evaluates, so the split cannot drift a bit.
            for &iu in &ws.cand {
                bank.refresh_rx8(iu as usize, &self.pc, self.carrier.freq_hz);
            }
            for (ci, &iu) in ws.cand.iter().enumerate() {
                ws.sinr_db.push(bank.rx8_dbm(iu as usize) - noise + ws.fade_db[ci]);
            }
            sinr_to_cqi_batch(&ws.sinr_db, &mut ws.cqi);
            for (ci, &iu) in ws.cand.iter().enumerate() {
                let i = iu as usize;
                let has_job = self.cfg.job_priority && bank.ue(i).has_job_bytes();
                let cqi = ws.cqi[ci];
                let metric = match self.cfg.policy {
                    SchedulingPolicy::ProportionalFair => {
                        ws.tbs1[cqi as usize] / bank.pf_avg(i, slot, decay).max(1e-9)
                    }
                    SchedulingPolicy::RoundRobin => {
                        -(bank.ue(i).last_served_slot as f64)
                    }
                };
                ws.keyed.push((has_job, metric, cqi, iu));
            }
        }
        // job class first, then metric descending, index as tiebreak
        ws.keyed.sort_by(|a, b| {
            b.0.cmp(&a.0)
                .then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.3.cmp(&b.3))
        });

        // 3. Greedy PRB allocation down the ordered list.
        let mut remaining = self.carrier.n_prb;
        for &(_, _, cqi, iu) in &ws.keyed {
            let i = iu as usize;
            if remaining == 0 {
                break;
            }
            if cqi == 0 {
                continue; // outage this slot
            }
            let per_prb = tbs_bytes(&self.carrier, cqi, 1).max(1);
            let want = bank.ue(i).buffered_bytes().min(u32::MAX as u64) as u32;
            let mut n_prb = want.div_ceil(per_prb);
            if self.cfg.max_prb_per_ue > 0 {
                n_prb = n_prb.min(self.cfg.max_prb_per_ue);
            }
            n_prb = n_prb.min(remaining).max(1);
            remaining -= n_prb;
            let tb = tbs_bytes(&self.carrier, cqi, n_prb);

            // 4. HARQ outcome.
            let attempt = bank.ue(i).harq_attempt;
            let ok = self.cfg.harq.transmit_ok(rng, attempt);
            let d_start = ws.delivered.len() as u32;
            if ok {
                bank.ue_mut(i).harq_attempt = 0;
                bank.drain_served(i, tb, self.cfg.job_priority, &mut ws.delivered);
            } else {
                bank.ue_mut(i).harq_attempt = attempt.saturating_add(1);
                bank.harq_block(i, slot + self.cfg.harq.rtt_slots as u64);
            }
            let d_end = ws.delivered.len() as u32;
            let goodput: u32 = if ok { tb.min(want) } else { 0 };
            // PF EWMA update for the served UE (goodput 0 on HARQ
            // failure — the same zero-sample the decay would apply).
            bank.ue_mut(i).last_served_slot = slot;
            bank.pf_avg(i, slot, decay);
            bank.pf_note_served(i, slot, goodput as f64, self.cfg.pf_window);
            ws.grants.push(GrantResult {
                ue: i,
                n_prb,
                tb_bytes: tb,
                harq_ok: ok,
                delivered: (d_start, d_end),
            });
        }
        // Unserved candidates (and every idle UE) decay lazily: their
        // pending zero-traffic slots are folded in on the next touch.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::bank::drop_ues;
    use crate::phy::channel::Position;
    use crate::prop_assert;
    use crate::util::proptest::check;

    fn ls(d: f64) -> LargeScale {
        LargeScale { pos: Position { x: d, y: 0.0 }, los: true, shadow_db: 0.0 }
    }

    fn job_sdu(id: u64, bytes: u32, t: f64) -> Sdu {
        Sdu { kind: SduKind::Job { job_id: id }, total_bytes: bytes, bytes_left: bytes, t_arrival: t }
    }

    fn bg_sdu(bytes: u32, t: f64) -> Sdu {
        Sdu { kind: SduKind::Background, total_bytes: bytes, bytes_left: bytes, t_arrival: t }
    }

    fn sched(job_priority: bool) -> UlScheduler {
        let cfg = MacConfig {
            job_priority,
            harq: HarqConfig { bler: 0.0, ..Default::default() },
            ..Default::default()
        };
        UlScheduler::new(cfg, Carrier::table1())
    }

    fn bank_of(ues: Vec<UeMac>) -> UeBank {
        UeBank::new(ues)
    }

    #[test]
    fn empty_ues_no_grants() {
        let s = sched(false);
        let mut bank = bank_of(vec![UeMac::new(ls(100.0))]);
        let mut rng = Rng::new(1);
        let mut ws = SlotWorkspace::new();
        s.schedule_slot(0, &mut bank, &mut rng, &mut ws);
        assert!(ws.grants.is_empty());
    }

    #[test]
    fn single_ue_small_sdu_delivered_in_one_slot() {
        let s = sched(false);
        let mut bank = bank_of(vec![UeMac::new(ls(80.0))]);
        bank.push_job_sdu(0, job_sdu(1, 600, 0.0));
        let mut rng = Rng::new(2);
        let mut ws = SlotWorkspace::new();
        s.schedule_slot(0, &mut bank, &mut rng, &mut ws);
        assert_eq!(ws.grants.len(), 1);
        assert!(ws.grants[0].harq_ok);
        assert_eq!(ws.delivered_of(&ws.grants[0]).len(), 1);
        assert_eq!(bank.ue(0).buffered_bytes(), 0);
        assert!(!bank.has_backlog());
        bank.check_invariants();
    }

    #[test]
    fn job_priority_preempts_background_within_ue() {
        // Large bg SDU arrived first; with priority on, the job SDU
        // must still complete first.
        let mut bank = bank_of(vec![UeMac::new(ls(250.0))]);
        bank.push_bg_sdu(0, bg_sdu(200_000, 0.0));
        bank.push_job_sdu(0, job_sdu(9, 600, 1.0));
        let s = sched(true);
        let mut rng = Rng::new(3);
        let mut ws = SlotWorkspace::new();
        let mut job_done_slot = None;
        let mut bg_done_slot = None;
        for slot in 0..2000 {
            s.schedule_slot(slot, &mut bank, &mut rng, &mut ws);
            for d in &ws.delivered {
                match d.kind {
                    SduKind::Job { .. } => job_done_slot.get_or_insert(slot),
                    SduKind::Background => bg_done_slot.get_or_insert(slot),
                };
            }
            if job_done_slot.is_some() && bg_done_slot.is_some() {
                break;
            }
        }
        let (j, b) = (job_done_slot.unwrap(), bg_done_slot.unwrap());
        assert!(j < b, "job slot {j} !< bg slot {b}");
    }

    #[test]
    fn fifo_baseline_respects_arrival_order() {
        // Without prioritization the earlier bg SDU completes first.
        let mut bank = bank_of(vec![UeMac::new(ls(250.0))]);
        bank.push_bg_sdu(0, bg_sdu(60_000, 0.0));
        bank.push_job_sdu(0, job_sdu(9, 600, 1.0));
        let s = sched(false);
        let mut rng = Rng::new(4);
        let mut ws = SlotWorkspace::new();
        let mut first_done = None;
        for slot in 0..2000 {
            s.schedule_slot(slot, &mut bank, &mut rng, &mut ws);
            if let Some(d) = ws.delivered.first() {
                first_done = Some(d.kind);
                break;
            }
        }
        assert_eq!(first_done.unwrap(), SduKind::Background);
    }

    #[test]
    fn prb_budget_respected() {
        let s = sched(false);
        let mut bank = bank_of((0..40).map(|i| UeMac::new(ls(50.0 + 6.0 * i as f64))).collect());
        for i in 0..40 {
            bank.push_bg_sdu(i, bg_sdu(1_000_000, 0.0));
        }
        let mut rng = Rng::new(5);
        let mut ws = SlotWorkspace::new();
        s.schedule_slot(0, &mut bank, &mut rng, &mut ws);
        let total: u32 = ws.grants.iter().map(|r| r.n_prb).sum();
        assert!(total <= Carrier::table1().n_prb, "total = {total}");
        assert!(!ws.grants.is_empty());
        bank.check_invariants();
    }

    #[test]
    fn harq_failure_blocks_and_retains_bytes() {
        let cfg = MacConfig {
            harq: HarqConfig { bler: 1.0, combining_gain: 1.0, max_tx: 8, rtt_slots: 4 },
            ..Default::default()
        };
        let s = UlScheduler::new(cfg, Carrier::table1());
        let mut bank = bank_of(vec![UeMac::new(ls(80.0))]);
        bank.push_job_sdu(0, job_sdu(1, 500, 0.0));
        let mut rng = Rng::new(6);
        let mut ws = SlotWorkspace::new();
        s.schedule_slot(0, &mut bank, &mut rng, &mut ws);
        assert!(!ws.grants[0].harq_ok);
        assert!(ws.delivered_of(&ws.grants[0]).is_empty());
        assert_eq!(bank.ue(0).buffered_bytes(), 500);
        assert!(bank.has_backlog(), "failed TB must stay indexed");
        // blocked for RTT slots
        for (slot, expect_grant) in [(1, false), (3, false), (4, true)] {
            s.schedule_slot(slot, &mut bank, &mut rng, &mut ws);
            assert_eq!(!ws.grants.is_empty(), expect_grant, "slot {slot}");
        }
    }

    #[test]
    fn pf_shares_between_ues_over_time() {
        // Two backlogged UEs at different distances must both be served
        // over a window (PF fairness), not starved.
        let s = sched(false);
        let mut bank = bank_of(vec![UeMac::new(ls(60.0)), UeMac::new(ls(280.0))]);
        let mut served = [0u32; 2];
        let mut rng = Rng::new(7);
        let mut ws = SlotWorkspace::new();
        for slot in 0..400 {
            for i in 0..2 {
                if bank.ue(i).buffered_bytes() < 10_000 {
                    bank.push_bg_sdu(i, bg_sdu(50_000, slot as f64 * 0.00025));
                }
            }
            s.schedule_slot(slot, &mut bank, &mut rng, &mut ws);
            for r in &ws.grants {
                served[r.ue] += r.n_prb;
            }
        }
        assert!(served[0] > 0 && served[1] > 0, "served = {served:?}");
    }

    #[test]
    fn job_ues_scheduled_before_bg_ues_under_priority() {
        // UE 0 has only background; UE 1 has a job. With few PRBs
        // available (cap via max_prb_per_ue high but carrier small),
        // the job UE must be first in the grant list.
        let cfg = MacConfig {
            job_priority: true,
            harq: HarqConfig { bler: 0.0, ..Default::default() },
            ..Default::default()
        };
        let s = UlScheduler::new(cfg, Carrier::table1());
        let mut bank = bank_of(vec![UeMac::new(ls(50.0)), UeMac::new(ls(200.0))]);
        bank.push_bg_sdu(0, bg_sdu(500_000, 0.0));
        bank.push_job_sdu(1, job_sdu(1, 600, 0.0));
        let mut rng = Rng::new(8);
        let mut ws = SlotWorkspace::new();
        s.schedule_slot(0, &mut bank, &mut rng, &mut ws);
        assert_eq!(ws.grants[0].ue, 1, "job UE must be granted first");
    }

    #[test]
    fn lazy_pf_decay_matches_closed_form() {
        let mut bank = bank_of(vec![UeMac::new(ls(100.0))]);
        let decay = 1.0 - 1.0 / 100.0;
        // served at slot 0 with goodput 500
        bank.pf_avg(0, 0, decay);
        bank.pf_note_served(0, 0, 500.0, 100.0);
        let after_serve = 1.0 + (500.0 - 1.0) / 100.0;
        // touched again at slot 11 → 10 idle slots (1..=10) decayed
        let avg = bank.pf_avg(0, 11, decay);
        assert!((avg - after_serve * decay.powi(10)).abs() < 1e-12, "avg = {avg}");
        // idempotent within the slot
        assert_eq!(avg.to_bits(), bank.pf_avg(0, 11, decay).to_bits());
    }

    /// One scripted cell driven slot-by-slot: arrivals, HARQ losses,
    /// SR waits, drains. The active-set index path and the dense
    /// full-population scan must produce identical grant streams and
    /// identical final UE state.
    #[test]
    fn active_set_matches_dense() {
        check(25, |g| {
            let n_ues = g.usize_range(1, 10);
            let seed = g.u64_below(10_000);
            let bler = g.f64_range(0.0, 0.5);
            let job_priority = g.bool(0.5);
            let n_slots: u64 = 300;

            let mk_cfg = |dense_scan: bool| MacConfig {
                job_priority,
                harq: HarqConfig { bler, ..Default::default() },
                dense_scan,
                ..Default::default()
            };
            let mut drop_rng = Rng::new(seed);
            let ues = drop_ues(&mut drop_rng, n_ues, 35.0, 300.0);
            let mut drop_rng2 = Rng::new(seed);
            let ues2 = drop_ues(&mut drop_rng2, n_ues, 35.0, 300.0);

            let active = UlScheduler::new(mk_cfg(false), Carrier::table1());
            let dense = UlScheduler::new(mk_cfg(true), Carrier::table1());
            let mut bank_a = UeBank::new(ues);
            let mut bank_d = UeBank::new(ues2);
            let mut rng_a = Rng::new(seed ^ 0xA);
            let mut rng_d = Rng::new(seed ^ 0xA);
            let mut arrivals = Rng::new(seed ^ 0xB);
            let (mut ws_a, mut ws_d) = (SlotWorkspace::new(), SlotWorkspace::new());
            let period = active.cfg.effective_sr_period(n_ues as u32);
            let proc = active.cfg.grant_proc_slots;

            for slot in 0..n_slots {
                // identical scripted arrivals into both banks
                for ue in 0..n_ues {
                    if arrivals.bernoulli(0.05) {
                        let bytes = 50 + arrivals.below(5_000) as u32;
                        let job = arrivals.bernoulli(0.4);
                        let t = slot as f64 * 0.00025;
                        for (bank, expedite) in
                            [(&mut bank_a, job_priority), (&mut bank_d, job_priority)]
                        {
                            bank.note_arrival(ue, slot, period, proc);
                            if job {
                                if expedite {
                                    bank.note_job_arrival_expedited(ue, slot, proc);
                                }
                                bank.push_job_sdu(ue, job_sdu(slot, bytes, t));
                            } else {
                                bank.push_bg_sdu(ue, bg_sdu(bytes, t));
                            }
                        }
                    }
                }
                active.schedule_slot(slot, &mut bank_a, &mut rng_a, &mut ws_a);
                dense.schedule_slot(slot, &mut bank_d, &mut rng_d, &mut ws_d);
                prop_assert!(
                    ws_a.grants == ws_d.grants,
                    "slot {slot}: grants diverged\n  active: {:?}\n  dense:  {:?}",
                    ws_a.grants,
                    ws_d.grants
                );
                prop_assert!(
                    ws_a.delivered.len() == ws_d.delivered.len(),
                    "slot {slot}: delivered count diverged"
                );
                bank_a.check_invariants();
            }
            for i in 0..n_ues {
                prop_assert!(
                    bank_a.ue(i).buffered_bytes() == bank_d.ue(i).buffered_bytes(),
                    "UE {i} final backlog diverged"
                );
            }
            prop_assert!(
                bank_a.total_backlog_bytes() == bank_d.total_backlog_bytes(),
                "total backlog diverged"
            );
            Ok(())
        });
    }

    /// The batched slot-SINR pass and the scalar reference path must
    /// also agree when the interference-over-thermal term varies slot
    /// by slot (the coupled-radio regime): identical grant streams and
    /// final state under a scripted, slot-dependent IoT.
    #[test]
    fn batched_sinr_matches_scalar_under_dynamic_iot() {
        check(10, |g| {
            let n_ues = g.usize_range(2, 8);
            let seed = g.u64_below(10_000);
            let n_slots: u64 = 200;
            let mk_cfg = |dense_scan: bool| MacConfig {
                harq: HarqConfig { bler: 0.1, ..Default::default() },
                dense_scan,
                ..Default::default()
            };
            let mut drop_rng = Rng::new(seed);
            let ues = drop_ues(&mut drop_rng, n_ues, 35.0, 300.0);
            let mut drop_rng2 = Rng::new(seed);
            let ues2 = drop_ues(&mut drop_rng2, n_ues, 35.0, 300.0);
            let batched = UlScheduler::new(mk_cfg(false), Carrier::table1());
            let scalar = UlScheduler::new(mk_cfg(true), Carrier::table1());
            let mut bank_b = UeBank::new(ues);
            let mut bank_s = UeBank::new(ues2);
            let mut rng_b = Rng::new(seed ^ 0xA);
            let mut rng_s = Rng::new(seed ^ 0xA);
            let mut arrivals = Rng::new(seed ^ 0xB);
            let (mut ws_b, mut ws_s) = (SlotWorkspace::new(), SlotWorkspace::new());
            let period = batched.cfg.effective_sr_period(n_ues as u32);
            let proc = batched.cfg.grant_proc_slots;
            for slot in 0..n_slots {
                for ue in 0..n_ues {
                    if arrivals.bernoulli(0.1) {
                        let bytes = 100 + arrivals.below(8_000) as u32;
                        let t = slot as f64 * 0.00025;
                        for bank in [&mut bank_b, &mut bank_s] {
                            bank.note_arrival(ue, slot, period, proc);
                            bank.push_bg_sdu(ue, bg_sdu(bytes, t));
                        }
                    }
                }
                // scripted per-slot IoT, identical for both paths
                let iot = (slot % 13) as f64 * 0.7;
                batched.schedule_slot_iot(slot, &mut bank_b, &mut rng_b, &mut ws_b, iot);
                scalar.schedule_slot_iot(slot, &mut bank_s, &mut rng_s, &mut ws_s, iot);
                prop_assert!(
                    ws_b.grants == ws_s.grants,
                    "slot {slot} (iot {iot}): grants diverged\n  batched: {:?}\n  scalar:  {:?}",
                    ws_b.grants,
                    ws_s.grants
                );
            }
            prop_assert!(
                bank_b.total_backlog_bytes() == bank_s.total_backlog_bytes(),
                "final backlog diverged"
            );
            Ok(())
        });
    }

    #[test]
    fn rx_power_cache_invalidation_tracks_link_changes() {
        let pc = PowerControl::default();
        let mut bank = bank_of(vec![UeMac::new(ls(120.0))]);
        let a = bank.rx_power8_dbm(0, &pc, 3.7e9);
        // cached: same value, bit for bit
        assert_eq!(a.to_bits(), bank.rx_power8_dbm(0, &pc, 3.7e9).to_bits());
        // mutate the link WITH invalidation → fresh value
        bank.ue_mut(0).link = ls(260.0);
        bank.invalidate_link_cache(0);
        let b = bank.rx_power8_dbm(0, &pc, 3.7e9);
        assert!(b < a, "farther UE must see less received power: {b} vs {a}");
        // matches the scalar recomputation exactly
        let scalar = rx_power_prb_dbm(bank.ue(0).link.coupling_loss_db(3.7e9), &pc, 8);
        assert_eq!(b.to_bits(), scalar.to_bits());
    }

    #[test]
    fn handover_interrupt_defers_grants() {
        let cfg = MacConfig {
            harq: HarqConfig { bler: 0.0, ..Default::default() },
            sr_period_slots: 0,
            sr_slots_per_ue: 0.0,
            ..Default::default()
        };
        let s = UlScheduler::new(cfg, Carrier::table1());
        let mut bank = bank_of(vec![UeMac::new(ls(80.0))]);
        bank.push_bg_sdu(0, bg_sdu(500, 0.0));
        bank.handover_interrupt(0, 10, 4);
        let mut rng = Rng::new(1);
        let mut ws = SlotWorkspace::new();
        for (slot, expect) in [(10, false), (13, false), (14, true)] {
            s.schedule_slot(slot, &mut bank, &mut rng, &mut ws);
            assert_eq!(!ws.grants.is_empty(), expect, "slot {slot}");
        }
    }
}
