//! RLC-layer buffering: SDU queues with segmentation (paper §IV-A:
//! "input prompts are first converted into RLC packets").
//!
//! Each UE holds two logical channels — **job** (translation prompts)
//! and **background** (Table I: 0.5 Mbps/UE) — so the MAC can apply
//! ICC's job-aware packet prioritization. A transport-block grant
//! drains bytes front-to-back with segmentation; an SDU completes at
//! the gNB when its last byte is delivered.

use std::collections::VecDeque;

/// What an SDU carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SduKind {
    /// Prompt data of translation job `job_id`.
    Job { job_id: u64 },
    /// Best-effort background traffic.
    Background,
}

/// One RLC SDU (an IP packet worth of data).
#[derive(Debug, Clone, Copy)]
pub struct Sdu {
    pub kind: SduKind,
    pub total_bytes: u32,
    pub bytes_left: u32,
    /// Generation time at the UE (seconds).
    pub t_arrival: f64,
}

/// Completion record returned when an SDU fully crosses the air
/// interface.
#[derive(Debug, Clone, Copy)]
pub struct SduDelivered {
    pub kind: SduKind,
    pub total_bytes: u32,
    pub t_arrival: f64,
}

/// A FIFO byte-queue of SDUs with segmentation.
#[derive(Debug, Default)]
pub struct RlcBuffer {
    queue: VecDeque<Sdu>,
    bytes: u64,
}

impl RlcBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, sdu: Sdu) {
        debug_assert!(sdu.bytes_left == sdu.total_bytes && sdu.total_bytes > 0);
        self.bytes += sdu.bytes_left as u64;
        self.queue.push_back(sdu);
    }

    /// Buffered bytes (the MAC buffer-status report).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn is_empty(&self) -> bool {
        self.bytes == 0
    }

    pub fn n_sdus(&self) -> usize {
        self.queue.len()
    }

    /// Arrival time of the head-of-line SDU (None if empty). Used by
    /// the merged-FIFO baseline to interleave logical channels in
    /// strict arrival order.
    pub fn head_arrival(&self) -> Option<f64> {
        self.queue.front().map(|s| s.t_arrival)
    }

    /// Drain up to `budget` bytes (one transport block), returning the
    /// SDUs that *completed* within this TB. Partially-sent SDUs stay
    /// at the head with reduced `bytes_left` (RLC segmentation).
    pub fn drain(&mut self, budget: u32) -> Vec<SduDelivered> {
        let mut done = Vec::new();
        self.drain_into(budget, &mut done);
        done
    }

    /// Snapshot view: the queued SDUs in FIFO order, head (possibly
    /// partially drained) first. Used by engine checkpointing.
    pub(crate) fn sdus(&self) -> impl Iterator<Item = &Sdu> {
        self.queue.iter()
    }

    /// Rebuild a buffer from a snapshot's SDU list (FIFO order). Unlike
    /// [`RlcBuffer::push`], this accepts partially-drained head SDUs
    /// (`bytes_left < total_bytes`) — exactly what a mid-run checkpoint
    /// contains.
    pub(crate) fn from_sdus(sdus: Vec<Sdu>) -> Self {
        let bytes = sdus.iter().map(|s| s.bytes_left as u64).sum();
        Self { queue: sdus.into(), bytes }
    }

    /// Allocation-free [`RlcBuffer::drain`]: completed SDUs are appended
    /// to `out` (a per-slot buffer reused across calls). Returns the
    /// number of bytes drained from the buffer.
    pub fn drain_into(&mut self, mut budget: u32, out: &mut Vec<SduDelivered>) -> u32 {
        let mut drained = 0u32;
        while budget > 0 {
            let Some(front) = self.queue.front_mut() else { break };
            let take = front.bytes_left.min(budget);
            front.bytes_left -= take;
            budget -= take;
            drained += take;
            self.bytes -= take as u64;
            if front.bytes_left == 0 {
                let sdu = self.queue.pop_front().unwrap();
                out.push(SduDelivered {
                    kind: sdu.kind,
                    total_bytes: sdu.total_bytes,
                    t_arrival: sdu.t_arrival,
                });
            }
        }
        drained
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sdu(kind: SduKind, bytes: u32, t: f64) -> Sdu {
        Sdu { kind, total_bytes: bytes, bytes_left: bytes, t_arrival: t }
    }

    #[test]
    fn push_accumulates_bytes() {
        let mut b = RlcBuffer::new();
        b.push(sdu(SduKind::Background, 100, 0.0));
        b.push(sdu(SduKind::Job { job_id: 1 }, 250, 0.1));
        assert_eq!(b.bytes(), 350);
        assert_eq!(b.n_sdus(), 2);
    }

    #[test]
    fn drain_completes_in_fifo_order() {
        let mut b = RlcBuffer::new();
        b.push(sdu(SduKind::Background, 100, 0.0));
        b.push(sdu(SduKind::Job { job_id: 7 }, 50, 0.1));
        let done = b.drain(150);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].kind, SduKind::Background);
        assert_eq!(done[1].kind, SduKind::Job { job_id: 7 });
        assert!(b.is_empty());
    }

    #[test]
    fn segmentation_preserves_partial_state() {
        let mut b = RlcBuffer::new();
        b.push(sdu(SduKind::Job { job_id: 1 }, 1000, 0.0));
        let done = b.drain(400);
        assert!(done.is_empty());
        assert_eq!(b.bytes(), 600);
        let done = b.drain(600);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].total_bytes, 1000);
        assert!(b.is_empty());
    }

    #[test]
    fn drain_zero_budget_noop() {
        let mut b = RlcBuffer::new();
        b.push(sdu(SduKind::Background, 10, 0.0));
        assert!(b.drain(0).is_empty());
        assert_eq!(b.bytes(), 10);
    }

    #[test]
    fn byte_conservation_across_many_drains() {
        let mut b = RlcBuffer::new();
        let mut pushed = 0u64;
        for i in 0..50 {
            let n = 37 + (i * 13) % 200;
            b.push(sdu(SduKind::Background, n, 0.0));
            pushed += n as u64;
        }
        let mut drained = 0u64;
        let mut completed = 0u64;
        while !b.is_empty() {
            let before = b.bytes();
            let done = b.drain(97);
            drained += before - b.bytes();
            completed += done.iter().map(|d| d.total_bytes as u64).sum::<u64>();
        }
        assert_eq!(drained, pushed);
        assert_eq!(completed, pushed);
    }
}
