//! HARQ abstraction: per-TB error + retransmission timing.
//!
//! Link adaptation targets 10% initial BLER (see `phy::link`); each
//! retransmission succeeds independently with combining gain halving
//! the residual error, up to `max_tx` attempts. At this SLS
//! granularity a failed TB keeps its bytes in the RLC buffer and the
//! grant is wasted; the retransmission opportunity arrives after
//! `rtt_slots` (n4 timing: 4 slots at 60 kHz = 1 ms).

use crate::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct HarqConfig {
    /// Initial-transmission block error rate.
    pub bler: f64,
    /// Residual-error multiplier per retransmission (chase combining).
    pub combining_gain: f64,
    /// Maximum transmissions (1 initial + retx).
    pub max_tx: u8,
    /// Slots between a NACK and the retransmission grant.
    pub rtt_slots: u32,
}

impl Default for HarqConfig {
    fn default() -> Self {
        Self { bler: 0.10, combining_gain: 0.5, max_tx: 4, rtt_slots: 4 }
    }
}

impl HarqConfig {
    /// Error probability of the `attempt`-th transmission (0-based).
    pub fn error_prob(&self, attempt: u8) -> f64 {
        self.bler * self.combining_gain.powi(attempt as i32)
    }

    /// Sample the outcome of the `attempt`-th transmission.
    pub fn transmit_ok(&self, rng: &mut Rng, attempt: u8) -> bool {
        if attempt + 1 >= self.max_tx {
            // Last allowed attempt: RLC-level recovery guarantees
            // delivery at this abstraction (residual loss < 1e-4 is
            // below this simulator's resolution).
            return true;
        }
        !rng.bernoulli(self.error_prob(attempt))
    }

    /// Expected number of transmissions per TB.
    pub fn expected_tx(&self) -> f64 {
        let mut e = 0.0;
        let mut p_reach = 1.0; // P(attempt i happens)
        for i in 0..self.max_tx {
            e += p_reach;
            let p_fail = if i + 1 >= self.max_tx { 0.0 } else { self.error_prob(i) };
            p_reach *= p_fail;
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_prob_decays_with_attempts() {
        let h = HarqConfig::default();
        assert!((h.error_prob(0) - 0.10).abs() < 1e-12);
        assert!((h.error_prob(1) - 0.05).abs() < 1e-12);
        assert!((h.error_prob(2) - 0.025).abs() < 1e-12);
    }

    #[test]
    fn last_attempt_always_succeeds() {
        let h = HarqConfig::default();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert!(h.transmit_ok(&mut rng, h.max_tx - 1));
        }
    }

    #[test]
    fn empirical_initial_bler() {
        let h = HarqConfig::default();
        let mut rng = Rng::new(2);
        let n = 100_000;
        let fails = (0..n).filter(|_| !h.transmit_ok(&mut rng, 0)).count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.10).abs() < 0.005, "rate = {rate}");
    }

    #[test]
    fn expected_tx_formula() {
        let h = HarqConfig::default();
        // E[tx] = 1 + 0.1 + 0.1·0.05 + 0.1·0.05·0.025 ≈ 1.105
        let e = h.expected_tx();
        assert!((e - (1.0 + 0.1 + 0.005 + 0.000125)).abs() < 1e-9, "e = {e}");
    }

    #[test]
    fn zero_bler_single_shot() {
        let h = HarqConfig { bler: 0.0, ..Default::default() };
        assert_eq!(h.expected_tx(), 1.0);
    }
}
