//! Closed-form job-satisfaction probabilities (paper Eqs 3–6).
//!
//! In steady state the tagged job's air-interface sojourn `X` and
//! computing sojourn `Y` are independent exponentials (Lemma 1 /
//! Burke's theorem) with rates `a = μ₁ − λ` and `b = μ₂ − λ`. With
//! `t = b_total − t_wireline`:
//!
//! * **Joint** (Eq 3): `P(X + Y ≤ t)` — the hypoexponential CDF.
//! * **Disjoint** (Eq 4): `P(X + Y ≤ t, X ≤ c₁, Y ≤ c₂)` where
//!   `c₁ = b_comm − t_wireline` (the communication budget covers the
//!   wireline hop) and `c₂ = b_comp`. For the paper's parameterization
//!   (`b_comm + b_comp = b_total`) the corner constraint implies the sum
//!   constraint and the probability factorizes; the general piecewise
//!   closed form is implemented (and cross-checked numerically) anyway.

use super::{Policy, Scheme};

/// Tandem-network parameters (paper §III-B uses μ₁=900, μ₂=100 jobs/s,
/// b_total = 80 ms).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemParams {
    /// Air-interface service rate (jobs/s).
    pub mu1: f64,
    /// Computing service rate (jobs/s).
    pub mu2: f64,
    /// Total end-to-end latency budget (s).
    pub b_total: f64,
}

impl SystemParams {
    /// The paper's §III-B configuration.
    pub fn paper() -> Self {
        Self { mu1: 900.0, mu2: 100.0, b_total: 0.080 }
    }

    /// Largest λ for which both queues are stable.
    pub fn stability_limit(&self) -> f64 {
        self.mu1.min(self.mu2)
    }
}

/// CDF of Exp(rate) at x (0 for x < 0).
#[inline]
fn exp_cdf(rate: f64, x: f64) -> f64 {
    if x <= 0.0 { 0.0 } else { -(-rate * x).exp_m1() }
}

/// Hypoexponential CDF: `P(X + Y <= t)` for independent X~Exp(a),
/// Y~Exp(b). Handles the a≈b confluent case.
pub fn hypoexp_cdf(a: f64, b: f64, t: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "rates must be positive (a={a}, b={b})");
    if t <= 0.0 {
        return 0.0;
    }
    let p = if (a - b).abs() < 1e-9 * a.max(b) {
        // Erlang-2 limit: 1 - e^{-at}(1 + at)
        1.0 - (-a * t).exp() * (1.0 + a * t)
    } else {
        1.0 - (b * (-a * t).exp() - a * (-b * t).exp()) / (b - a)
    };
    p.clamp(0.0, 1.0)
}

/// `P(X + Y <= t, X <= c1, Y <= c2)` for independent exponentials —
/// the disjoint-management satisfaction kernel, piecewise closed form.
pub fn truncated_sum_prob(a: f64, b: f64, t: f64, c1: f64, c2: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0);
    if t <= 0.0 || c1 <= 0.0 || c2 <= 0.0 {
        return 0.0;
    }
    // Integrate over X = x in [0, u]; the Y cap is min(c2, t - x).
    let u = c1.min(t);
    let x0 = (t - c2).clamp(0.0, u); // cap switches from c2 to t - x at x0
    let near = (a - b).abs() < 1e-9 * a.max(b);

    // Segment 1: x in [0, x0], Y cap = c2 (constant).
    let seg1 = if x0 > 0.0 { exp_cdf(a, x0) * exp_cdf(b, c2) } else { 0.0 };

    // Segment 2: x in [x0, u], Y cap = t - x.
    //   ∫ a e^{-ax} (1 - e^{-b(t-x)}) dx
    // = (e^{-a x0} - e^{-a u}) - a e^{-bt} ∫_{x0}^{u} e^{-(a-b)x} dx
    let seg2 = if u > x0 {
        let first = (-a * x0).exp() - (-a * u).exp();
        let second = if near {
            a * (-b * t).exp() * (u - x0)
        } else {
            a * (-b * t).exp() * ((-(a - b) * x0).exp() - (-(a - b) * u).exp())
                / (a - b)
        };
        first - second
    } else {
        0.0
    };

    (seg1 + seg2).clamp(0.0, 1.0)
}

/// Eq 3: joint-management satisfaction probability at arrival rate λ.
/// Returns 0 outside the stability region.
pub fn joint_satisfaction(p: &SystemParams, lambda: f64, t_wireline: f64) -> f64 {
    if lambda <= 0.0 {
        return if p.b_total > t_wireline { 1.0 } else { 0.0 };
    }
    if lambda >= p.stability_limit() {
        return 0.0;
    }
    hypoexp_cdf(p.mu1 - lambda, p.mu2 - lambda, p.b_total - t_wireline)
}

/// Eq 4: disjoint-management satisfaction probability.
pub fn disjoint_satisfaction(
    p: &SystemParams,
    lambda: f64,
    t_wireline: f64,
    b_comm: f64,
    b_comp: f64,
) -> f64 {
    let t = p.b_total - t_wireline;
    let c1 = b_comm - t_wireline;
    let c2 = b_comp;
    if lambda <= 0.0 {
        return if t > 0.0 && c1 > 0.0 && c2 > 0.0 { 1.0 } else { 0.0 };
    }
    if lambda >= p.stability_limit() {
        return 0.0;
    }
    truncated_sum_prob(p.mu1 - lambda, p.mu2 - lambda, t, c1, c2)
}

/// Server utilization `λ/μ` of one M/M/1 stage (the fluid tier's
/// per-node background load is expressed in these units). Returns
/// `f64::INFINITY` for a zero-rate server.
pub fn utilization(lambda: f64, mu: f64) -> f64 {
    if mu <= 0.0 { f64::INFINITY } else { lambda / mu }
}

/// Mean end-to-end sojourn `E[X + Y] = 1/(μ₁−λ) + 1/(μ₂−λ)` of the
/// tandem network (Lemma 1 gives independent exponential stage
/// sojourns in steady state). `None` outside the stability region.
pub fn tandem_mean_sojourn(p: &SystemParams, lambda: f64) -> Option<f64> {
    if lambda < 0.0 || lambda >= p.stability_limit() {
        return None;
    }
    Some(1.0 / (p.mu1 - lambda) + 1.0 / (p.mu2 - lambda))
}

/// Satisfaction probability of an arbitrary [`Scheme`].
pub fn scheme_satisfaction(p: &SystemParams, scheme: &Scheme, lambda: f64) -> f64 {
    match scheme.policy {
        Policy::Joint => joint_satisfaction(p, lambda, scheme.t_wireline),
        Policy::Disjoint { b_comm, b_comp } => {
            disjoint_satisfaction(p, lambda, scheme.t_wireline, b_comm, b_comp)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    /// Simpson numerical integration of the disjoint kernel, used as an
    /// independent cross-check of the piecewise closed form.
    fn truncated_sum_numeric(a: f64, b: f64, t: f64, c1: f64, c2: f64) -> f64 {
        if t <= 0.0 || c1 <= 0.0 || c2 <= 0.0 {
            return 0.0;
        }
        let u = c1.min(t);
        let n = 20_000; // even
        let h = u / n as f64;
        let f = |x: f64| {
            let cap = c2.min(t - x);
            a * (-a * x).exp() * exp_cdf(b, cap)
        };
        let mut s = f(0.0) + f(u);
        for i in 1..n {
            let x = i as f64 * h;
            s += f(x) * if i % 2 == 1 { 4.0 } else { 2.0 };
        }
        s * h / 3.0
    }

    #[test]
    fn hypoexp_limits() {
        assert_eq!(hypoexp_cdf(10.0, 20.0, 0.0), 0.0);
        assert!(hypoexp_cdf(10.0, 20.0, 100.0) > 0.999999);
        // symmetric in (a, b)
        let p1 = hypoexp_cdf(3.0, 7.0, 0.4);
        let p2 = hypoexp_cdf(7.0, 3.0, 0.4);
        assert!((p1 - p2).abs() < 1e-12);
    }

    #[test]
    fn hypoexp_confluent_continuity() {
        // a → b limit must agree with the Erlang-2 closed form.
        let b = 50.0;
        let t = 0.03;
        let general = hypoexp_cdf(b * (1.0 + 1e-7), b, t);
        let limit = hypoexp_cdf(b, b, t);
        assert!((general - limit).abs() < 1e-6, "{general} vs {limit}");
    }

    #[test]
    fn hypoexp_dominates_single_stage() {
        // X + Y <= t is harder than X <= t: CDF must be smaller.
        let (a, b, t) = (30.0, 60.0, 0.05);
        assert!(hypoexp_cdf(a, b, t) < exp_cdf(a, t));
        assert!(hypoexp_cdf(a, b, t) < exp_cdf(b, t));
    }

    #[test]
    fn truncated_matches_numeric_integration() {
        // Cases covering every branch: x0=0, 0<x0<u, x0=u, c1>t, c1<t.
        let cases = [
            (800.0, 60.0, 0.075, 0.019, 0.056), // paper-like, c1+c2 = t
            (800.0, 60.0, 0.075, 0.004, 0.056), // MEC-like (x0 interior)
            (100.0, 90.0, 0.050, 0.100, 0.020), // c1 > t
            (100.0, 90.0, 0.050, 0.020, 0.100), // c2 > t
            (50.0, 50.0, 0.080, 0.030, 0.030),  // a == b, caps tight
            (200.0, 30.0, 0.060, 0.050, 0.040), // c1+c2 > t (sum binds)
        ];
        for &(a, b, t, c1, c2) in &cases {
            let closed = truncated_sum_prob(a, b, t, c1, c2);
            let numeric = truncated_sum_numeric(a, b, t, c1, c2);
            assert!(
                (closed - numeric).abs() < 1e-6,
                "({a},{b},{t},{c1},{c2}): closed {closed} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn truncated_equals_product_when_budgets_partition() {
        // c1 + c2 <= t ⇒ the corner constraints imply the sum constraint
        // ⇒ P = P(X<=c1)·P(Y<=c2).
        let (a, b) = (876.0, 53.0);
        let (c1, c2) = (0.019, 0.056);
        let t = c1 + c2;
        let p = truncated_sum_prob(a, b, t, c1, c2);
        let product = exp_cdf(a, c1) * exp_cdf(b, c2);
        assert!((p - product).abs() < 1e-12, "{p} vs {product}");
    }

    #[test]
    fn joint_beats_disjoint_everywhere() {
        // Relaxing constraints can only help: joint ≥ disjoint for the
        // same wireline latency, for all λ. (Property test.)
        let p = SystemParams::paper();
        check(300, |g| {
            let lambda = g.f64_range(0.1, 99.0);
            let bc = g.f64_range(0.001, p.b_total - 0.001);
            let joint = joint_satisfaction(&p, lambda, 0.005);
            let dis = disjoint_satisfaction(&p, lambda, 0.005, bc, p.b_total - bc);
            prop_assert!(
                joint >= dis - 1e-12,
                "λ={lambda} bc={bc}: joint {joint} < disjoint {dis}"
            );
            Ok(())
        });
    }

    #[test]
    fn satisfaction_monotone_decreasing_in_lambda() {
        let p = SystemParams::paper();
        for scheme in Scheme::fig4_schemes() {
            let mut prev = f64::INFINITY;
            for i in 0..100 {
                let lambda = i as f64;
                let s = scheme_satisfaction(&p, &scheme, lambda);
                assert!(
                    s <= prev + 1e-12,
                    "{}: not monotone at λ={lambda}",
                    scheme.name
                );
                prev = s;
            }
        }
    }

    #[test]
    fn shorter_wireline_helps() {
        let p = SystemParams::paper();
        check(200, |g| {
            let lambda = g.f64_range(0.1, 99.0);
            let ran = disjoint_satisfaction(&p, lambda, 0.005, 0.024, 0.056);
            let mec = disjoint_satisfaction(&p, lambda, 0.020, 0.024, 0.056);
            prop_assert!(ran >= mec - 1e-12, "λ={lambda}: ran {ran} < mec {mec}");
            Ok(())
        });
    }

    #[test]
    fn unstable_lambda_gives_zero() {
        let p = SystemParams::paper();
        assert_eq!(joint_satisfaction(&p, 100.0, 0.005), 0.0);
        assert_eq!(joint_satisfaction(&p, 150.0, 0.005), 0.0);
        assert_eq!(disjoint_satisfaction(&p, 100.0, 0.005, 0.024, 0.056), 0.0);
    }

    #[test]
    fn zero_lambda_limits() {
        let p = SystemParams::paper();
        assert_eq!(joint_satisfaction(&p, 0.0, 0.005), 1.0);
        // budget consumed entirely by wireline → unsatisfiable
        assert_eq!(joint_satisfaction(&p, 0.0, 0.085), 0.0);
        assert_eq!(disjoint_satisfaction(&p, 0.0, 0.030, 0.024, 0.056), 0.0);
    }

    #[test]
    fn tandem_mean_sojourn_basics() {
        let p = SystemParams::paper();
        // λ → 0: mean sojourn is the sum of the bare service times.
        let s0 = tandem_mean_sojourn(&p, 0.0).unwrap();
        assert!((s0 - (1.0 / 900.0 + 1.0 / 100.0)).abs() < 1e-12);
        // strictly increasing in λ, diverging toward the limit
        let mut prev = 0.0;
        for i in 0..100 {
            let s = tandem_mean_sojourn(&p, i as f64).unwrap();
            assert!(s > prev, "λ={i}: {s} <= {prev}");
            prev = s;
        }
        // outside the stability region there is no steady state
        assert_eq!(tandem_mean_sojourn(&p, 100.0), None);
        assert_eq!(tandem_mean_sojourn(&p, 250.0), None);
        assert_eq!(tandem_mean_sojourn(&p, -1.0), None);
    }

    #[test]
    fn utilization_is_lambda_over_mu() {
        assert_eq!(utilization(30.0, 100.0), 0.3);
        assert_eq!(utilization(0.0, 100.0), 0.0);
        assert_eq!(utilization(5.0, 0.0), f64::INFINITY);
    }

    #[test]
    fn probabilities_in_unit_interval() {
        let p = SystemParams::paper();
        check(500, |g| {
            let lambda = g.f64_range(0.0, 120.0);
            let tw = g.f64_range(0.0, 0.1);
            let bc = g.f64_range(0.0, 0.1);
            let j = joint_satisfaction(&p, lambda, tw);
            let d = disjoint_satisfaction(&p, lambda, tw, bc, p.b_total - bc);
            prop_assert!((0.0..=1.0).contains(&j), "joint {j}");
            prop_assert!((0.0..=1.0).contains(&d), "disjoint {d}");
            Ok(())
        });
    }
}
