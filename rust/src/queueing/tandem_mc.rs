//! Monte-Carlo discrete-event simulation of the tandem M/M/1 network.
//!
//! Independent validation of the closed forms in [`super::analytic`]
//! and of Lemma 1 (independence of the two sojourn times): we simulate
//! the actual FCFS queues — Poisson arrivals, exponential service at
//! rate μ₁, constant wireline delay, exponential service at rate μ₂ —
//! and measure per-job sojourn times in both stages.

use crate::dess::EventQueue;
use crate::rng::Rng;

use super::{Policy, Scheme};
use super::analytic::SystemParams;

/// Per-job record from the tandem simulation.
#[derive(Debug, Clone, Copy)]
pub struct JobRecord {
    /// Sojourn (wait + service) in the communication queue, seconds.
    pub t_comm: f64,
    /// Sojourn in the computing queue, seconds.
    pub t_comp: f64,
}

impl JobRecord {
    /// End-to-end latency including the wireline constant.
    pub fn e2e(&self, t_wireline: f64) -> f64 {
        self.t_comm + t_wireline + self.t_comp
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival,
    CommDone,
    /// Job (identified by its index) enters the computing queue after
    /// the wireline delay.
    ComputeEnqueue(usize),
    ComputeDone,
}

/// Simulate the tandem network for `n_jobs` completed jobs after a
/// `warmup` fraction is discarded. Returns per-job records.
pub fn simulate_tandem(
    p: &SystemParams,
    lambda: f64,
    t_wireline: f64,
    n_jobs: usize,
    seed: u64,
) -> Vec<JobRecord> {
    assert!(lambda > 0.0 && lambda < p.stability_limit(), "unstable λ");
    let total = n_jobs + n_jobs / 4 + 100; // extra for warmup discard
    let warm = total - n_jobs;

    let mut rng_arr = Rng::substream(seed, 1);
    let mut rng_s1 = Rng::substream(seed, 2);
    let mut rng_s2 = Rng::substream(seed, 3);

    let mut q = EventQueue::new();
    q.schedule_in(rng_arr.exp(lambda), Ev::Arrival);

    // FCFS state. Queue 1 (air interface).
    let mut q1: std::collections::VecDeque<usize> = Default::default();
    let mut busy1 = false;
    // Queue 2 (computing).
    let mut q2: std::collections::VecDeque<usize> = Default::default();
    let mut busy2 = false;

    let mut arrivals: Vec<f64> = Vec::with_capacity(total);
    let mut comm_done: Vec<f64> = vec![0.0; total];
    let mut comp_enter: Vec<f64> = vec![0.0; total];
    let mut records: Vec<JobRecord> = Vec::with_capacity(n_jobs);
    let mut completed = 0usize;
    let mut generated = 0usize;

    while completed < total {
        let (now, ev) = q.pop().expect("event starvation");
        match ev {
            Ev::Arrival => {
                if generated < total {
                    let id = generated;
                    generated += 1;
                    arrivals.push(now);
                    q1.push_back(id);
                    if !busy1 {
                        busy1 = true;
                        q.schedule_in(rng_s1.exp(p.mu1), Ev::CommDone);
                    }
                    q.schedule_in(rng_arr.exp(lambda), Ev::Arrival);
                }
            }
            Ev::CommDone => {
                let id = q1.pop_front().expect("comm queue empty");
                comm_done[id] = now;
                q.schedule_in(t_wireline, Ev::ComputeEnqueue(id));
                if let Some(_) = q1.front() {
                    q.schedule_in(rng_s1.exp(p.mu1), Ev::CommDone);
                } else {
                    busy1 = false;
                }
            }
            Ev::ComputeEnqueue(id) => {
                comp_enter[id] = now;
                q2.push_back(id);
                if !busy2 {
                    busy2 = true;
                    q.schedule_in(rng_s2.exp(p.mu2), Ev::ComputeDone);
                }
            }
            Ev::ComputeDone => {
                let id = q2.pop_front().expect("comp queue empty");
                if completed >= warm {
                    records.push(JobRecord {
                        t_comm: comm_done[id] - arrivals[id],
                        t_comp: now - comp_enter[id],
                    });
                }
                completed += 1;
                if q2.front().is_some() {
                    q.schedule_in(rng_s2.exp(p.mu2), Ev::ComputeDone);
                } else {
                    busy2 = false;
                }
            }
        }
    }
    records
}

/// Empirical satisfaction probability of a [`Scheme`] from simulation.
pub fn empirical_satisfaction(
    p: &SystemParams,
    scheme: &Scheme,
    lambda: f64,
    n_jobs: usize,
    seed: u64,
) -> f64 {
    if lambda >= p.stability_limit() {
        return 0.0;
    }
    let recs = simulate_tandem(p, lambda, scheme.t_wireline, n_jobs, seed);
    let sat = recs
        .iter()
        .filter(|r| match scheme.policy {
            Policy::Joint => r.e2e(scheme.t_wireline) <= p.b_total,
            Policy::Disjoint { b_comm, b_comp } => {
                r.e2e(scheme.t_wireline) <= p.b_total
                    && r.t_comm + scheme.t_wireline <= b_comm
                    && r.t_comp <= b_comp
            }
        })
        .count();
    sat as f64 / recs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queueing::analytic::{
        joint_satisfaction, scheme_satisfaction,
    };

    const N: usize = 60_000;

    #[test]
    fn sojourn_marginals_match_mm1_theory() {
        // M/M/1 sojourn ~ Exp(μ - λ): check both stage means.
        let p = SystemParams::paper();
        let lambda = 60.0;
        let recs = simulate_tandem(&p, lambda, 0.005, N, 42);
        let mean1: f64 = recs.iter().map(|r| r.t_comm).sum::<f64>() / recs.len() as f64;
        let mean2: f64 = recs.iter().map(|r| r.t_comp).sum::<f64>() / recs.len() as f64;
        let exp1 = 1.0 / (p.mu1 - lambda);
        let exp2 = 1.0 / (p.mu2 - lambda);
        assert!((mean1 / exp1 - 1.0).abs() < 0.05, "{mean1} vs {exp1}");
        assert!((mean2 / exp2 - 1.0).abs() < 0.08, "{mean2} vs {exp2}");
    }

    #[test]
    fn lemma1_sojourn_independence() {
        // Pearson correlation of (t_comm, t_comp) ≈ 0 (Lemma 1).
        let p = SystemParams::paper();
        let recs = simulate_tandem(&p, 50.0, 0.005, N, 7);
        let n = recs.len() as f64;
        let m1: f64 = recs.iter().map(|r| r.t_comm).sum::<f64>() / n;
        let m2: f64 = recs.iter().map(|r| r.t_comp).sum::<f64>() / n;
        let (mut cov, mut v1, mut v2) = (0.0, 0.0, 0.0);
        for r in &recs {
            cov += (r.t_comm - m1) * (r.t_comp - m2);
            v1 += (r.t_comm - m1).powi(2);
            v2 += (r.t_comp - m2).powi(2);
        }
        let corr = cov / (v1.sqrt() * v2.sqrt());
        assert!(corr.abs() < 0.03, "corr = {corr}");
    }

    #[test]
    fn empirical_matches_analytic_joint() {
        let p = SystemParams::paper();
        for &lambda in &[20.0, 50.0, 70.0, 85.0] {
            let emp = empirical_satisfaction(
                &p,
                &Scheme::icc_joint_ran(),
                lambda,
                N,
                1000 + lambda as u64,
            );
            let ana = joint_satisfaction(&p, lambda, 0.005);
            assert!(
                (emp - ana).abs() < 0.02,
                "λ={lambda}: emp {emp:.4} vs analytic {ana:.4}"
            );
        }
    }

    #[test]
    fn empirical_matches_analytic_disjoint() {
        let p = SystemParams::paper();
        for scheme in [Scheme::disjoint_ran(), Scheme::mec_disjoint()] {
            for &lambda in &[15.0, 30.0, 45.0] {
                let emp =
                    empirical_satisfaction(&p, &scheme, lambda, N, 77 + lambda as u64);
                let ana = scheme_satisfaction(&p, &scheme, lambda);
                assert!(
                    (emp - ana).abs() < 0.02,
                    "{} λ={lambda}: emp {emp:.4} vs {ana:.4}",
                    scheme.name
                );
            }
        }
    }

    #[test]
    fn determinism_under_fixed_seed() {
        let p = SystemParams::paper();
        let a = empirical_satisfaction(&p, &Scheme::icc_joint_ran(), 40.0, 5_000, 9);
        let b = empirical_satisfaction(&p, &Scheme::icc_joint_ran(), 40.0, 5_000, 9);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "unstable")]
    fn rejects_unstable_lambda() {
        let p = SystemParams::paper();
        simulate_tandem(&p, 150.0, 0.005, 100, 1);
    }
}
