//! Service-capacity solver (paper Definition 2).
//!
//! `λ* = sup{ λ : P(E(λ)) ≥ α }`. Every satisfaction function in this
//! crate is monotone non-increasing in λ (more load → longer sojourns),
//! so the sup is found by bisection over the stability interval.

/// Result of a capacity search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityResult {
    /// The service capacity λ* (jobs/s). 0 if even λ→0 misses α.
    pub lambda_star: f64,
    /// Satisfaction probability at λ* (≥ α unless lambda_star == 0).
    pub p_at_star: f64,
    /// Number of probability evaluations performed.
    pub evals: u32,
}

/// Find `λ* = sup{λ ∈ [0, lambda_max] : p(λ) ≥ α}` by bisection.
///
/// `p` must be monotone non-increasing; `tol` is the absolute λ
/// tolerance of the returned capacity.
pub fn service_capacity(
    mut p: impl FnMut(f64) -> f64,
    alpha: f64,
    lambda_max: f64,
    tol: f64,
) -> CapacityResult {
    assert!((0.0..=1.0).contains(&alpha));
    assert!(lambda_max > 0.0 && tol > 0.0);
    let mut evals = 0u32;
    let mut eval = |l: f64, evals: &mut u32| {
        *evals += 1;
        p(l)
    };

    // Degenerate: even vanishing load misses the target.
    let p0 = eval(tol.min(lambda_max * 1e-6), &mut evals);
    if p0 < alpha {
        return CapacityResult { lambda_star: 0.0, p_at_star: p0, evals };
    }
    // Whole range feasible.
    let p_hi = eval(lambda_max, &mut evals);
    if p_hi >= alpha {
        return CapacityResult { lambda_star: lambda_max, p_at_star: p_hi, evals };
    }

    let (mut lo, mut hi) = (0.0f64, lambda_max);
    let mut p_lo = p0;
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        let pm = eval(mid, &mut evals);
        if pm >= alpha {
            lo = mid;
            p_lo = pm;
        } else {
            hi = mid;
        }
    }
    CapacityResult { lambda_star: lo, p_at_star: p_lo, evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queueing::analytic::{joint_satisfaction, SystemParams};
    use crate::queueing::Scheme;

    #[test]
    fn exact_threshold_recovered() {
        // p(λ) = 1 - λ/100 crosses 0.95 exactly at λ = 5.
        let r = service_capacity(|l| 1.0 - l / 100.0, 0.95, 100.0, 1e-9);
        assert!((r.lambda_star - 5.0).abs() < 1e-6, "{}", r.lambda_star);
        assert!(r.p_at_star >= 0.95 - 1e-9);
    }

    #[test]
    fn infeasible_returns_zero() {
        let r = service_capacity(|_| 0.5, 0.95, 10.0, 1e-6);
        assert_eq!(r.lambda_star, 0.0);
    }

    #[test]
    fn fully_feasible_returns_max() {
        let r = service_capacity(|_| 0.99, 0.95, 10.0, 1e-6);
        assert_eq!(r.lambda_star, 10.0);
    }

    #[test]
    fn eval_count_is_logarithmic() {
        let r = service_capacity(|l| 1.0 - l / 100.0, 0.95, 100.0, 1e-9);
        assert!(r.evals < 64, "evals = {}", r.evals);
    }

    #[test]
    fn paper_headline_98_percent_gain() {
        // §III-B: joint-RAN capacity ≈ +98% over disjoint-MEC at α=0.95.
        let p = SystemParams::paper();
        let alpha = 0.95;
        let cap = |s: Scheme| {
            service_capacity(
                |l| crate::queueing::analytic::scheme_satisfaction(&p, &s, l),
                alpha,
                p.stability_limit() - 1e-6,
                1e-6,
            )
            .lambda_star
        };
        let joint = cap(Scheme::icc_joint_ran());
        let dis_ran = cap(Scheme::disjoint_ran());
        let mec = cap(Scheme::mec_disjoint());
        // Ordering: joint > disjoint-RAN > MEC.
        assert!(joint > dis_ran && dis_ran > mec, "{joint} {dis_ran} {mec}");
        let gain = joint / mec - 1.0;
        assert!(
            (0.85..=1.15).contains(&gain),
            "joint {joint:.2} vs mec {mec:.2}: gain {:.1}% (paper: 98%)",
            gain * 100.0
        );
    }

    #[test]
    fn joint_capacity_value_sane() {
        // Joint-RAN: solving P(X+Y <= 75ms) = 0.95 with μ1=900, μ2=100
        // lands near λ ≈ 59–60 jobs/s.
        let p = SystemParams::paper();
        let r = service_capacity(
            |l| joint_satisfaction(&p, l, 0.005),
            0.95,
            99.9,
            1e-6,
        );
        assert!(
            (55.0..=65.0).contains(&r.lambda_star),
            "λ* = {}",
            r.lambda_star
        );
    }
}
