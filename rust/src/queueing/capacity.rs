//! Service-capacity solver (paper Definition 2).
//!
//! `λ* = sup{ λ : P(E(λ)) ≥ α }`. Every satisfaction function in this
//! crate is monotone non-increasing in λ (more load → longer sojourns),
//! so the sup is found by bisection over the stability interval.

/// Result of a capacity search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityResult {
    /// The service capacity λ* (jobs/s). 0 if even λ→0 misses α.
    pub lambda_star: f64,
    /// Satisfaction probability at λ* (≥ α unless lambda_star == 0).
    pub p_at_star: f64,
    /// Number of probability evaluations performed.
    pub evals: u32,
}

/// Find `λ* = sup{λ ∈ [0, lambda_max] : p(λ) ≥ α}` by bisection.
///
/// `p` must be monotone non-increasing; `tol` is the absolute λ
/// tolerance of the returned capacity.
pub fn service_capacity(
    mut p: impl FnMut(f64) -> f64,
    alpha: f64,
    lambda_max: f64,
    tol: f64,
) -> CapacityResult {
    assert!((0.0..=1.0).contains(&alpha));
    assert!(lambda_max > 0.0 && tol > 0.0);
    let mut evals = 0u32;
    let mut eval = |l: f64, evals: &mut u32| {
        *evals += 1;
        p(l)
    };

    // Degenerate: even vanishing load misses the target.
    let p0 = eval(tol.min(lambda_max * 1e-6), &mut evals);
    if p0 < alpha {
        return CapacityResult { lambda_star: 0.0, p_at_star: p0, evals };
    }
    // Whole range feasible.
    let p_hi = eval(lambda_max, &mut evals);
    if p_hi >= alpha {
        return CapacityResult { lambda_star: lambda_max, p_at_star: p_hi, evals };
    }

    let (mut lo, mut hi) = (0.0f64, lambda_max);
    let mut p_lo = p0;
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        let pm = eval(mid, &mut evals);
        if pm >= alpha {
            lo = mid;
            p_lo = pm;
        } else {
            hi = mid;
        }
    }
    CapacityResult { lambda_star: lo, p_at_star: p_lo, evals }
}

/// [`service_capacity`] with replication-averaged probes: each
/// bisection probe evaluates `p(λ, seed)` for every seed (in parallel
/// over `threads` worker threads; 0 = all cores) and bisects on the
/// seed-mean.
///
/// Simulation-backed satisfaction curves are noisy per replication;
/// probing the *same* seed set at every λ keeps the averaged curve
/// monotone in expectation and the bisection deterministic — the probe
/// sequence (and hence `evals`) is identical for any thread count,
/// because the mean is reduced in fixed seed order.
pub fn service_capacity_replicated(
    p: impl Fn(f64, u64) -> f64 + Sync,
    seeds: &[u64],
    threads: usize,
    alpha: f64,
    lambda_max: f64,
    tol: f64,
) -> CapacityResult {
    assert!(!seeds.is_empty(), "need at least one replication seed");
    service_capacity(
        |l| {
            let vals = crate::sweep::run_parallel(seeds, threads, |&s| p(l, s));
            vals.iter().sum::<f64>() / vals.len() as f64
        },
        alpha,
        lambda_max,
        tol,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queueing::analytic::{joint_satisfaction, SystemParams};
    use crate::queueing::Scheme;

    #[test]
    fn exact_threshold_recovered() {
        // p(λ) = 1 - λ/100 crosses 0.95 exactly at λ = 5.
        let r = service_capacity(|l| 1.0 - l / 100.0, 0.95, 100.0, 1e-9);
        assert!((r.lambda_star - 5.0).abs() < 1e-6, "{}", r.lambda_star);
        assert!(r.p_at_star >= 0.95 - 1e-9);
    }

    #[test]
    fn infeasible_returns_zero() {
        let r = service_capacity(|_| 0.5, 0.95, 10.0, 1e-6);
        assert_eq!(r.lambda_star, 0.0);
    }

    #[test]
    fn fully_feasible_returns_max() {
        let r = service_capacity(|_| 0.99, 0.95, 10.0, 1e-6);
        assert_eq!(r.lambda_star, 10.0);
    }

    #[test]
    fn eval_count_is_logarithmic() {
        let r = service_capacity(|l| 1.0 - l / 100.0, 0.95, 100.0, 1e-9);
        assert!(r.evals < 64, "evals = {}", r.evals);
    }

    /// Deterministic per-seed "noise": a fixed offset per seed, so the
    /// seed-mean of `1 − λ/100 + noise` is `1 − λ/100 + bias` — still
    /// monotone, crossing α at a shifted but computable λ*.
    fn noisy_p(l: f64, seed: u64) -> f64 {
        let noise = ((seed.wrapping_mul(0x9E3779B97F4A7C15) >> 40) as f64
            / (1u64 << 24) as f64
            - 0.5)
            * 0.04; // ±2% replication noise
        (1.0 - l / 100.0 + noise).clamp(0.0, 1.0)
    }

    fn seed_bias(seeds: &[u64]) -> f64 {
        // probe at λ=50 where no seed's value clamps
        seeds.iter().map(|&s| noisy_p(50.0, s) - 0.5).sum::<f64>() / seeds.len() as f64
    }

    #[test]
    fn replicated_probes_average_out_noise() {
        let seeds: Vec<u64> = (0..16).collect();
        let bias = seed_bias(&seeds);
        let r = service_capacity_replicated(noisy_p, &seeds, 1, 0.95, 100.0, 1e-9);
        // mean curve: 1 - λ/100 + bias ≥ 0.95 ⇔ λ ≤ 100·(0.05 + bias)
        let expect = 100.0 * (0.05 + bias);
        assert!(
            (r.lambda_star - expect).abs() < 1e-6,
            "λ* = {}, expect {expect}",
            r.lambda_star
        );
        // a single noisy replication would land up to ±2 λ away
        let lone = service_capacity(|l| noisy_p(l, 3), 0.95, 100.0, 1e-9);
        assert!((lone.lambda_star - 5.0).abs() < 2.5);
    }

    #[test]
    fn replicated_capacity_identical_for_any_thread_count() {
        let seeds: Vec<u64> = (0..8).collect();
        let serial = service_capacity_replicated(noisy_p, &seeds, 1, 0.95, 100.0, 1e-7);
        for threads in [2, 4, 0] {
            let par =
                service_capacity_replicated(noisy_p, &seeds, threads, 0.95, 100.0, 1e-7);
            assert_eq!(serial.lambda_star.to_bits(), par.lambda_star.to_bits());
            assert_eq!(serial.p_at_star.to_bits(), par.p_at_star.to_bits());
            assert_eq!(serial.evals, par.evals);
        }
    }

    #[test]
    fn replicated_single_seed_matches_plain_bisection() {
        let r1 = service_capacity(|l| noisy_p(l, 7), 0.95, 100.0, 1e-9);
        let r2 = service_capacity_replicated(noisy_p, &[7], 1, 0.95, 100.0, 1e-9);
        assert_eq!(r1.lambda_star.to_bits(), r2.lambda_star.to_bits());
        assert_eq!(r1.evals, r2.evals);
    }

    #[test]
    fn paper_headline_98_percent_gain() {
        // §III-B: joint-RAN capacity ≈ +98% over disjoint-MEC at α=0.95.
        let p = SystemParams::paper();
        let alpha = 0.95;
        let cap = |s: Scheme| {
            service_capacity(
                |l| crate::queueing::analytic::scheme_satisfaction(&p, &s, l),
                alpha,
                p.stability_limit() - 1e-6,
                1e-6,
            )
            .lambda_star
        };
        let joint = cap(Scheme::icc_joint_ran());
        let dis_ran = cap(Scheme::disjoint_ran());
        let mec = cap(Scheme::mec_disjoint());
        // Ordering: joint > disjoint-RAN > MEC.
        assert!(joint > dis_ran && dis_ran > mec, "{joint} {dis_ran} {mec}");
        let gain = joint / mec - 1.0;
        assert!(
            (0.85..=1.15).contains(&gain),
            "joint {joint:.2} vs mec {mec:.2}: gain {:.1}% (paper: 98%)",
            gain * 100.0
        );
    }

    #[test]
    fn joint_capacity_value_sane() {
        // Joint-RAN: solving P(X+Y <= 75ms) = 0.95 with μ1=900, μ2=100
        // lands near λ ≈ 59–60 jobs/s.
        let p = SystemParams::paper();
        let r = service_capacity(
            |l| joint_satisfaction(&p, l, 0.005),
            0.95,
            99.9,
            1e-6,
        );
        assert!(
            (55.0..=65.0).contains(&r.lambda_star),
            "λ* = {}",
            r.lambda_star
        );
    }
}
