//! Queueing-theoretic analysis of the ICC system (paper §III, Fig 4).
//!
//! The system is a tandem network: an M/M/1 air-interface queue (rate
//! μ₁) feeding, through a constant wireline delay `t_wireline`, an
//! M/M/1 computing queue (rate μ₂). By Burke's theorem the departure
//! process of the first queue is Poisson(λ) and the sojourn times of a
//! tagged job in the two queues are independent (paper Lemma 1), each
//! exponential with rates `μ₁−λ` and `μ₂−λ`.
//!
//! * [`analytic`] — closed-form satisfaction probabilities for joint
//!   and disjoint latency management (Eqs 3–6).
//! * [`tandem_mc`] — discrete-event Monte-Carlo of the same network,
//!   used to *validate* Lemma 1 and the closed forms.
//! * [`capacity`] — the service-capacity solver (Definition 2).

pub mod analytic;
pub mod capacity;
pub mod tandem_mc;

pub use analytic::{SystemParams, joint_satisfaction, disjoint_satisfaction};
pub use capacity::{service_capacity, service_capacity_replicated, CapacityResult};

/// Latency-management policy (paper §III-A).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// The entire budget `b_total` covers comm + comp jointly.
    Joint,
    /// `b_total` is split into a communication budget (covering
    /// UE→BS *and* wireline) and a computing budget.
    Disjoint { b_comm: f64, b_comp: f64 },
}

/// One of the paper's three evaluated schemes (§III-B / Fig 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scheme {
    pub name: &'static str,
    pub policy: Policy,
    pub t_wireline: f64,
}

impl Scheme {
    /// Joint latency management, RAN compute node (t_wireline = 5 ms).
    pub fn icc_joint_ran() -> Self {
        Self { name: "ICC joint (RAN, 5ms)", policy: Policy::Joint, t_wireline: 0.005 }
    }

    /// Disjoint management, RAN node (5 ms): b_comm=24 ms, b_comp=56 ms.
    pub fn disjoint_ran() -> Self {
        Self {
            name: "Disjoint (RAN, 5ms)",
            policy: Policy::Disjoint { b_comm: 0.024, b_comp: 0.056 },
            t_wireline: 0.005,
        }
    }

    /// 5G MEC baseline: disjoint management, MEC node (20 ms).
    pub fn mec_disjoint() -> Self {
        Self {
            name: "5G MEC disjoint (20ms)",
            policy: Policy::Disjoint { b_comm: 0.024, b_comp: 0.056 },
            t_wireline: 0.020,
        }
    }

    /// All three Fig 4 schemes in the paper's order.
    pub fn fig4_schemes() -> [Scheme; 3] {
        [Self::icc_joint_ran(), Self::disjoint_ran(), Self::mec_disjoint()]
    }
}
