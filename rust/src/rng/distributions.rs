//! Poisson sampling: Knuth inversion for small means, PTRS
//! (Hörmann's transformed-rejection) for large means.

use super::Rng;

/// Sample a Poisson variate with the given mean.
pub fn poisson(rng: &mut Rng, mean: f64) -> u64 {
    assert!(mean >= 0.0, "poisson mean must be >= 0");
    if mean == 0.0 {
        0
    } else if mean < 30.0 {
        poisson_knuth(rng, mean)
    } else {
        poisson_ptrs(rng, mean)
    }
}

/// Knuth's multiplication method — exact, O(mean).
fn poisson_knuth(rng: &mut Rng, mean: f64) -> u64 {
    let l = (-mean).exp();
    let mut k = 0u64;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
        // Numerical safety: for mean < 30 this cannot realistically loop
        // beyond a few hundred iterations.
        if k > 10_000 {
            return k;
        }
    }
}

/// PTRS transformed rejection (W. Hörmann, "The transformed rejection
/// method for generating Poisson random variables", 1993). O(1) for
/// large means.
fn poisson_ptrs(rng: &mut Rng, mean: f64) -> u64 {
    let b = 0.931 + 2.53 * mean.sqrt();
    let a = -0.059 + 0.02483 * b;
    let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
    let v_r = 0.9277 - 3.6224 / (b - 2.0);
    loop {
        let u = rng.f64() - 0.5;
        let v = rng.f64();
        let us = 0.5 - u.abs();
        let k = ((2.0 * a / us + b) * u + mean + 0.43).floor();
        if us >= 0.07 && v <= v_r {
            return k as u64;
        }
        if k < 0.0 || (us < 0.013 && v > us) {
            continue;
        }
        let lg = ln_gamma(k + 1.0);
        if (v * inv_alpha / (a / (us * us) + b)).ln()
            <= k * mean.ln() - mean - lg
        {
            return k as u64;
        }
    }
}

/// Lanczos approximation of ln Γ(x), good to ~1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_factorials() {
        // ln Γ(n+1) = ln n!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0, 5040.0];
        for (n, &f) in facts.iter().enumerate() {
            let lg = ln_gamma(n as f64 + 1.0);
            assert!(
                (lg - (f as f64).ln()).abs() < 1e-10,
                "n = {n}: {lg} vs {}",
                (f as f64).ln()
            );
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Γ(1/2) = sqrt(pi)
        let lg = ln_gamma(0.5);
        assert!((lg - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn poisson_zero_mean() {
        let mut r = Rng::new(1);
        assert_eq!(poisson(&mut r, 0.0), 0);
    }

    #[test]
    fn poisson_ptrs_vs_knuth_distribution() {
        // At mean=29.9 (Knuth) and 30.1 (PTRS), empirical CDFs must agree.
        let n = 60_000;
        let sample = |seed, mean| {
            let mut r = Rng::new(seed);
            let mut v: Vec<u64> = (0..n).map(|_| poisson(&mut r, mean)).collect();
            v.sort_unstable();
            v
        };
        let a = sample(10, 29.9);
        let b = sample(11, 30.1);
        // Compare medians and IQRs roughly
        let med = |v: &Vec<u64>| v[v.len() / 2] as f64;
        assert!((med(&a) - med(&b)).abs() <= 2.0);
    }
}
