//! Deterministic pseudo-random substrate for the simulators.
//!
//! The offline dependency universe has no `rand` crate, and a
//! discrete-event simulator wants *reproducible, splittable* streams
//! anyway (each UE / traffic source / channel gets its own independent
//! stream derived from a master seed, so adding a source never perturbs
//! the others). We implement:
//!
//! * [`SplitMix64`] — seed expander / stream splitter (Steele et al.,
//!   "Fast Splittable Pseudorandom Number Generators", OOPSLA'14).
//! * [`Xoshiro256pp`] — xoshiro256++ 1.0 (Blackman & Vigna), the
//!   general-purpose generator.
//! * Distributions: uniform, exponential, Poisson (inversion + PTRS for
//!   large mean), standard normal (Box–Muller), Bernoulli, log-normal.
//!
//! All algorithms are from the public-domain reference implementations.

mod distributions;
pub use distributions::*;

/// SplitMix64: a tiny 64-bit PRNG used to expand seeds and derive
/// independent substreams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (the seeding procedure recommended by the
    /// xoshiro authors; guarantees a non-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent substream: hash (seed, stream-id) through
    /// SplitMix64. Streams with different ids are statistically
    /// independent for simulation purposes.
    pub fn substream(master_seed: u64, stream_id: u64) -> Self {
        let mut sm = SplitMix64::new(master_seed ^ stream_id.wrapping_mul(0x9E3779B97F4A7C15));
        // burn a few outputs so close ids decorrelate
        sm.next_u64();
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform double in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Raw generator state (engine snapshots). Restoring via
    /// [`Xoshiro256pp::from_state`] resumes the stream at the exact
    /// position, so a checkpointed run replays bit-identically.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a captured stream position.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }
}

/// The simulator-facing RNG: a xoshiro stream plus distribution helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    inner: Xoshiro256pp,
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { inner: Xoshiro256pp::seed_from_u64(seed), gauss_spare: None }
    }

    /// Independent substream for entity `stream_id` under `master_seed`.
    pub fn substream(master_seed: u64, stream_id: u64) -> Self {
        Self { inner: Xoshiro256pp::substream(master_seed, stream_id), gauss_spare: None }
    }

    /// Full stream position for engine snapshots: the xoshiro state
    /// plus the cached Box–Muller spare (without it, a restored run
    /// would consume one extra uniform at the next `gauss` call and
    /// every draw after would diverge).
    pub fn snapshot_state(&self) -> ([u64; 4], Option<f64>) {
        (self.inner.state(), self.gauss_spare)
    }

    /// Rebuild a stream at a position captured by
    /// [`Rng::snapshot_state`].
    pub fn from_state(state: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Self { inner: Xoshiro256pp::from_state(state), gauss_spare }
    }

    #[inline]
    pub fn u64(&mut self) -> u64 {
        self.inner.next_u64()
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        self.inner.next_f64()
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.inner.next_below(n)
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with rate `lambda` (mean 1/lambda).
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // Inversion; (1 - u) avoids ln(0).
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.gauss()
    }

    /// Log-normal: exp(N(mu, sigma)). For dB-valued shadowing use
    /// `normal` directly on the dB scale instead.
    #[inline]
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson variate with the given mean.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        distributions::poisson(self, mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256pp::seed_from_u64(42);
        let mut r2 = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256pp::seed_from_u64(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn substreams_are_decorrelated() {
        let mut a = Rng::substream(7, 0);
        let mut b = Rng::substream(7, 1);
        let n = 10_000;
        let mut dot = 0.0;
        for _ in 0..n {
            dot += (a.f64() - 0.5) * (b.f64() - 0.5);
        }
        let corr = dot / n as f64 / (1.0 / 12.0);
        assert!(corr.abs() < 0.05, "corr = {corr}");
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.005, "mean = {mean}");
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut r = Rng::new(2);
        let mut counts = [0u32; 7];
        let n = 700_000;
        for _ in 0..n {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expected = n as f64 / 7.0;
            assert!(((c as f64) - expected).abs() < 5.0 * expected.sqrt());
        }
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(3);
        let lambda = 4.0;
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.exp(lambda);
            assert!(x >= 0.0);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.25).abs() < 0.005, "mean = {mean}");
        assert!((var - 0.0625).abs() < 0.005, "var = {var}");
    }

    #[test]
    fn exponential_memoryless_tail() {
        // P(X > t) = exp(-lambda t)
        let mut r = Rng::new(4);
        let lambda = 2.0;
        let t = 0.8;
        let n = 200_000;
        let over = (0..n).filter(|_| r.exp(lambda) > t).count();
        let p = over as f64 / n as f64;
        let expect = (-lambda * t).exp();
        assert!((p - expect).abs() < 0.005, "p = {p}, expect = {expect}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.02, "var = {var}");
    }

    #[test]
    fn poisson_moments_small_and_large_mean() {
        let mut r = Rng::new(6);
        for &mean in &[0.3, 3.0, 25.0, 400.0] {
            let n = 50_000;
            let (mut sum, mut sq) = (0.0, 0.0);
            for _ in 0..n {
                let x = r.poisson(mean) as f64;
                sum += x;
                sq += x * x;
            }
            let m = sum / n as f64;
            let v = sq / n as f64 - m * m;
            // Poisson: mean == var == `mean`
            let tol = 5.0 * (mean / n as f64).sqrt().max(0.01);
            assert!((m - mean).abs() < tol, "mean {mean}: m = {m}");
            assert!((v - mean).abs() < 0.1 * mean + 0.3, "mean {mean}: v = {v}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.23)).count();
        assert!(((hits as f64 / n as f64) - 0.23).abs() < 0.01);
    }
}
