//! Shared substrates: CLI args, TOML-subset config parsing, statistics,
//! a mini property-testing engine, and a tiny logger.
//!
//! These replace `clap` / `toml` / `criterion`'s stats / `proptest` /
//! `env_logger`, none of which exist in the offline dependency universe
//! (see DESIGN.md §3 Substitutions).

pub mod args;
pub mod bench;
pub mod jsonmini;
pub mod logger;
pub mod perfgate;
pub mod proptest;
pub mod stats;
pub mod tomlmini;
