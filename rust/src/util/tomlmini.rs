//! Minimal TOML-subset parser for config files (no `serde`/`toml` in the
//! offline registry).
//!
//! Supported: `[table]` / `[table.sub]` headers, `[[table]]`
//! arrays-of-tables, `key = value` with string / integer / float /
//! bool / homogeneous-array values, `#` comments, blank lines. Keys
//! are exposed flat as `"table.sub.key"`; the i-th `[[workload]]`
//! table flattens to `"workload.<i>.key"` and its count is available
//! via [`Document::array_len`]. This covers everything `config/` and
//! `scenario/` need; exotic TOML (dates, inline tables, multi-line
//! strings) is intentionally rejected with an error.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: flat `"table.key"` → [`Value`] map, plus the
/// per-name element counts of `[[table]]` arrays-of-tables.
#[derive(Debug, Default, Clone)]
pub struct Document {
    entries: BTreeMap<String, Value>,
    array_counts: BTreeMap<String, usize>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut doc = Document::default();
        let mut prefix = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix("[[") {
                // Array-of-tables header: [[name]] opens element i and
                // flattens its keys under "name.i.".
                let h = h.strip_suffix("]]").ok_or_else(|| TomlError {
                    line: lineno,
                    msg: "unterminated array-of-tables header".into(),
                })?;
                let h = h.trim();
                if h.is_empty() || h.contains('[') || h.contains(']') {
                    return Err(TomlError {
                        line: lineno,
                        msg: "bad array-of-tables header".into(),
                    });
                }
                let n = doc.array_counts.entry(h.to_string()).or_insert(0);
                prefix = format!("{h}.{n}");
                *n += 1;
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h.strip_suffix(']').ok_or_else(|| TomlError {
                    line: lineno,
                    msg: "unterminated table header".into(),
                })?;
                let h = h.trim();
                if h.is_empty() || h.contains('[') || h.contains(']') {
                    return Err(TomlError {
                        line: lineno,
                        msg: "bad table header".into(),
                    });
                }
                prefix = h.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| TomlError {
                line: lineno,
                msg: "expected 'key = value'".into(),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(TomlError { line: lineno, msg: "empty key".into() });
            }
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            let value = parse_value(val.trim())
                .map_err(|msg| TomlError { line: lineno, msg })?;
            doc.entries.insert(full, value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Number of `[[name]]` tables in the document (0 if none).
    pub fn array_len(&self, name: &str) -> usize {
        self.array_counts.get(name).copied().unwrap_or(0)
    }
}

fn strip_comment(line: &str) -> &str {
    // Honour '#' only outside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote unsupported".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = Document::parse(
            r#"
# top comment
title = "icc"
count = 42
rate = 2.5
on = true

[sim]
seed = 7            # trailing comment
label = "fig6 # not a comment"

[sim.phy]
bandwidth_mhz = 100.0
"#,
        )
        .unwrap();
        assert_eq!(doc.str("title"), Some("icc"));
        assert_eq!(doc.i64("count"), Some(42));
        assert_eq!(doc.f64("rate"), Some(2.5));
        assert_eq!(doc.bool("on"), Some(true));
        assert_eq!(doc.i64("sim.seed"), Some(7));
        assert_eq!(doc.str("sim.label"), Some("fig6 # not a comment"));
        assert_eq!(doc.f64("sim.phy.bandwidth_mhz"), Some(100.0));
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = Document::parse("x = 5").unwrap();
        assert_eq!(doc.f64("x"), Some(5.0));
    }

    #[test]
    fn parses_arrays() {
        let doc = Document::parse("xs = [1, 2, 3]\nys = [1.5, 2.5]\nempty = []").unwrap();
        let xs = doc.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_i64(), Some(3));
        assert_eq!(doc.get("empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn underscored_numbers() {
        let doc = Document::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.i64("n"), Some(1_000_000));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Document::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Document::parse("x = \"unterminated").unwrap_err();
        assert_eq!(err.line, 1);
        let err = Document::parse("[bad\nx = 1").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn rejects_garbage_value() {
        assert!(Document::parse("x = @@").is_err());
    }

    #[test]
    fn array_of_tables_flattens_with_indices() {
        let doc = Document::parse(
            "[[workload]]\nname = \"chat\"\nrate = 0.5\n\n\
             [[workload]]\nname = \"summarize\"\nrate = 0.1\n\n\
             [routing]\npolicy = \"least_loaded\"",
        )
        .unwrap();
        assert_eq!(doc.array_len("workload"), 2);
        assert_eq!(doc.array_len("node"), 0);
        assert_eq!(doc.str("workload.0.name"), Some("chat"));
        assert_eq!(doc.f64("workload.0.rate"), Some(0.5));
        assert_eq!(doc.str("workload.1.name"), Some("summarize"));
        assert_eq!(doc.str("routing.policy"), Some("least_loaded"));
    }

    #[test]
    fn array_of_tables_header_errors() {
        let err = Document::parse("[[workload]\nx = 1").unwrap_err();
        assert_eq!(err.line, 1);
        assert!(Document::parse("[[ ]]").is_err());
    }
}
