//! Minimal CLI argument parser (the offline registry has no `clap`).
//!
//! Supports the subset the `icc6g` binary and the bench harness need:
//! subcommands, `--flag`, `--key value` / `--key=value`, typed getters
//! with defaults, and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum ArgError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String, String),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::Unknown(name) => write!(f, "unknown option '{name}'"),
            ArgError::MissingValue(name) => write!(f, "option '--{name}' expects a value"),
            ArgError::Invalid(name, value, why) => {
                write!(f, "invalid value '{value}' for '--{name}': {why}")
            }
        }
    }
}

impl std::error::Error for ArgError {}

/// Declarative option spec used for usage output and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments: key→value options, bare flags, and positionals.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name) against the specs.
    pub fn parse<I, S>(argv: I, specs: &[OptSpec]) -> Result<Self, ArgError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = argv.into_iter().map(Into::into).peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| ArgError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| ArgError::MissingValue(name.clone()))?,
                    };
                    out.values.insert(name, val);
                } else {
                    out.flags.push(name);
                }
            } else {
                out.positional.push(arg);
            }
        }
        // apply defaults
        for spec in specs {
            if let Some(d) = spec.default {
                out.values.entry(spec.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, ArgError> {
        self.typed(name, |v| v.parse::<f64>())
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, ArgError> {
        self.typed(name, |v| v.parse::<u64>())
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, ArgError> {
        self.typed(name, |v| v.parse::<usize>())
    }

    fn typed<T, E: std::fmt::Display>(
        &self,
        name: &str,
        f: impl Fn(&str) -> Result<T, E>,
    ) -> Result<Option<T>, ArgError> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => f(v).map(Some).map_err(|e| {
                ArgError::Invalid(name.to_string(), v.clone(), e.to_string())
            }),
        }
    }
}

/// Render a usage block from the specs (for `--help`).
pub fn usage(prog: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: {prog} [options]\n\nOptions:\n");
    for spec in specs {
        let val = if spec.takes_value { " <v>" } else { "" };
        let def = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n        {}{def}\n", spec.name, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "rate", help: "arrival rate", takes_value: true, default: Some("1.0") },
            OptSpec { name: "ues", help: "number of UEs", takes_value: true, default: None },
            OptSpec { name: "verbose", help: "chatty", takes_value: false, default: None },
        ]
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = Args::parse(["--rate", "2.5", "--verbose", "sim"], &specs()).unwrap();
        assert_eq!(a.get_f64("rate").unwrap(), Some(2.5));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["sim".to_string()]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(["--ues=60"], &specs()).unwrap();
        assert_eq!(a.get_u64("ues").unwrap(), Some(60));
    }

    #[test]
    fn applies_defaults() {
        let a = Args::parse(Vec::<String>::new(), &specs()).unwrap();
        assert_eq!(a.get_f64("rate").unwrap(), Some(1.0));
        assert_eq!(a.get_u64("ues").unwrap(), None);
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(matches!(
            Args::parse(["--nope"], &specs()),
            Err(ArgError::Unknown(_))
        ));
        assert!(matches!(
            Args::parse(["--rate"], &specs()),
            Err(ArgError::MissingValue(_))
        ));
    }

    #[test]
    fn rejects_bad_typed_value() {
        let a = Args::parse(["--rate", "abc"], &specs()).unwrap();
        assert!(matches!(a.get_f64("rate"), Err(ArgError::Invalid(..))));
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("icc6g", "test", &specs());
        assert!(u.contains("--rate"));
        assert!(u.contains("default: 1.0"));
    }
}
