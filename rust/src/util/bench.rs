//! Micro-bench harness + figure/table output helpers (the `criterion`
//! replacement; criterion is not in the offline registry).
//!
//! * [`bench_fn`] — warmup + timed iterations of a closure, returning
//!   mean / stddev / min / p50 / p95 wall-clock per iteration.
//! * [`Table`] — aligned console tables for "same rows the paper
//!   reports" output, with CSV export to `bench_out/` so figures can be
//!   re-plotted.

use std::fmt::Write as _;
use std::time::Instant;

use super::stats::percentile;

/// Result of a micro-benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

/// Serialize bench results as a JSON array (hand-rolled; no serde in
/// the dependency universe) so perf trajectories can accumulate
/// machine-readable points across commits.
pub fn results_to_json(results: &[BenchResult]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        let name: String = r.name.chars().filter(|&c| c != '"' && c != '\\').collect();
        let _ = write!(
            out,
            "  {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \"std_ns\": {:.1}, \
             \"min_ns\": {:.1}, \"p50_ns\": {:.1}, \"p95_ns\": {:.1}}}",
            name, r.iters, r.mean_ns, r.std_ns, r.min_ns, r.p50_ns, r.p95_ns
        );
    }
    out.push_str("\n]\n");
    out
}

/// Write bench results to a JSON file (e.g. `BENCH_hotpath.json`).
pub fn write_bench_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    std::fs::write(path, results_to_json(results))
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for at least `min_iters` iterations / `min_time_s` seconds
/// (whichever is larger), after `warmup` untimed iterations.
pub fn bench_fn<R>(name: &str, warmup: usize, min_iters: usize, min_time_s: f64,
                   mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(min_iters.max(16));
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= min_iters && start.elapsed().as_secs_f64() >= min_time_s {
            break;
        }
        if samples.len() >= 1_000_000 {
            break; // hard cap
        }
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n.max(2.0);
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        p50_ns: percentile(&samples, 50.0),
        p95_ns: percentile(&samples, 95.0),
    }
}

/// Aligned console table + CSV export, for regenerating paper figures.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write the table as CSV under `bench_out/<file>`.
    pub fn write_csv(&self, file: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_out")?;
        let path = std::path::Path::new("bench_out").join(file);
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join(","));
        }
        std::fs::write(&path, s)?;
        Ok(path)
    }
}

/// Format an f64 cell with the given number of decimals.
pub fn cell(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_measures_something() {
        let r = bench_fn("noop-ish", 2, 50, 0.0, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters >= 50);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.p50_ns <= r.p95_ns + 1.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = Table::new("Fig X", &["lambda", "p_sat"]);
        t.row(&[cell(10.0, 1), cell(0.987, 3)]);
        t.row(&[cell(100.0, 1), cell(0.5, 3)]);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("0.987"));
        let dir = std::env::temp_dir().join(format!("icc6g_tbl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let old = std::env::current_dir().unwrap();
        std::env::set_current_dir(&dir).unwrap();
        let path = t.write_csv("t.csv").unwrap();
        let csv = std::fs::read_to_string(&path).unwrap();
        std::env::set_current_dir(old).unwrap();
        assert!(csv.starts_with("lambda,p_sat\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn bench_json_is_well_formed() {
        let r = BenchResult {
            name: "q\"uote".into(),
            iters: 10,
            mean_ns: 1.25,
            std_ns: 0.5,
            min_ns: 1.0,
            p50_ns: 1.2,
            p95_ns: 1.9,
        };
        let js = results_to_json(&[r.clone(), r]);
        assert!(js.starts_with("[\n"));
        assert!(js.contains("\"mean_ns\": 1.2"));
        assert!(!js.contains('\\'), "quotes must be stripped, not escaped");
        assert_eq!(js.matches('{').count(), 2);
        assert_eq!(js.matches('}').count(), 2);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
