//! Mini property-testing engine (the offline registry has no `proptest`).
//!
//! Provides the subset this repo's invariant tests need: run a property
//! against N randomly generated cases from a deterministic seed, and on
//! failure greedily shrink scalar inputs toward zero to report a small
//! counterexample. Usage:
//!
//! ```ignore
//! check(100, |g| {
//!     let lam = g.f64_range(1.0, 50.0);
//!     let t = g.f64_range(0.001, 1.0);
//!     prop_assert!(cdf(lam, t) <= 1.0 + 1e-12, "cdf out of range");
//!     Ok(())
//! });
//! ```

use crate::rng::Rng;

/// Case generator handed to each property invocation. Records the drawn
/// scalars so the runner can replay / shrink them.
pub struct Gen {
    rng: Rng,
    trace: Vec<f64>,
    /// When replaying a shrunk trace, draws come from here instead.
    replay: Option<Vec<f64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), trace: Vec::new(), replay: None, cursor: 0 }
    }

    fn replay(values: Vec<f64>) -> Self {
        Self { rng: Rng::new(0), trace: Vec::new(), replay: Some(values), cursor: 0 }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut Rng) -> f64) -> f64 {
        let v = match &self.replay {
            Some(vals) => {
                let v = vals.get(self.cursor).copied().unwrap_or(0.0);
                self.cursor += 1;
                v
            }
            None => fresh(&mut self.rng),
        };
        self.trace.push(v);
        v
    }

    /// Uniform float in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.draw(|r| r.range(lo, hi));
        v.clamp(lo, hi)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let v = self.draw(|r| r.below((hi - lo + 1) as u64) as f64);
        lo + (v as usize).min(hi - lo)
    }

    /// Uniform u64 in [0, n).
    pub fn u64_below(&mut self, n: u64) -> u64 {
        let v = self.draw(|r| r.below(n) as f64);
        (v as u64).min(n - 1)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.draw(|r| if r.bernoulli(p) { 1.0 } else { 0.0 }) > 0.5
    }
}

/// Property result: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Run `prop` against `cases` random cases (seeded deterministically).
/// Panics with the (shrunk) counterexample on failure.
pub fn check(cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    check_seeded(0xC0FFEE, cases, prop)
}

/// Like [`check`] with an explicit master seed.
pub fn check_seeded(seed: u64, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let mut g = Gen::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut g) {
            let trace = g.trace.clone();
            let (shrunk, final_msg) = shrink(&trace, &prop, msg);
            panic!(
                "property failed (case {case}/{cases}): {final_msg}\n  inputs (shrunk): {shrunk:?}"
            );
        }
    }
}

/// Greedy scalar shrinking: repeatedly try halving each drawn value
/// toward 0 while the property still fails.
fn shrink(
    trace: &[f64],
    prop: &impl Fn(&mut Gen) -> PropResult,
    mut msg: String,
) -> (Vec<f64>, String) {
    let mut best = trace.to_vec();
    let mut improved = true;
    let mut budget = 200;
    while improved && budget > 0 {
        improved = false;
        for i in 0..best.len() {
            for candidate in [0.0, best[i] / 2.0, best[i].trunc()] {
                if candidate == best[i] {
                    continue;
                }
                let mut attempt = best.clone();
                attempt[i] = candidate;
                let mut g = Gen::replay(attempt.clone());
                if let Err(m) = prop(&mut g) {
                    best = attempt;
                    msg = m;
                    improved = true;
                    break;
                }
            }
            budget -= 1;
            if budget == 0 {
                break;
            }
        }
    }
    (best, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        check(50, |g| {
            let x = g.f64_range(0.0, 10.0);
            prop_assert!(x >= 0.0 && x < 10.0 + 1e-9);
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(100, |g| {
            let x = g.f64_range(0.0, 100.0);
            prop_assert!(x < 90.0, "x too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn shrinking_reduces_counterexample() {
        // Capture the panic message and verify the shrunk input is at the
        // boundary region rather than an arbitrary large draw.
        let result = std::panic::catch_unwind(|| {
            check(200, |g| {
                let x = g.f64_range(0.0, 1000.0);
                prop_assert!(x < 500.0, "boom");
                Ok(())
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // shrunk value should still fail (>= 500) but be pulled toward it
        let inputs: Vec<f64> = msg
            .split('[')
            .nth(1)
            .unwrap()
            .trim_end_matches(']')
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
        assert!(!inputs.is_empty());
        assert!(inputs[0] >= 500.0 && inputs[0] < 1000.0, "inputs = {inputs:?}");
    }

    #[test]
    fn usize_range_inclusive_bounds() {
        check(200, |g| {
            let v = g.usize_range(3, 7);
            prop_assert!((3..=7).contains(&v), "v = {v}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = |seed| {
            let vals = std::cell::RefCell::new(Vec::new());
            check_seeded(seed, 10, |g| {
                vals.borrow_mut().push(g.f64_range(0.0, 1.0));
                Ok(())
            });
            vals.into_inner()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6));
    }
}
