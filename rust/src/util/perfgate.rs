//! Benchmark-regression gate: compare the machine-readable bench
//! outputs (`BENCH_hotpath.json`, `BENCH_scale.json`) against a
//! committed `benchmarks/baseline.json` and fail on regressions beyond
//! a tolerance. Drives the `icc6g bench-diff` subcommand and CI's
//! `perf-gate` job.
//!
//! Baseline format:
//!
//! ```json
//! {
//!   "tolerance": 0.25,
//!   "entries": [
//!     {"key": "scale/sls_scale/1000/active_set/events_per_sec",
//!      "value": 500000.0, "higher_is_better": true}
//!   ]
//! }
//! ```
//!
//! Keys are flattened measurement paths ([`hotpath_metrics`] /
//! [`scale_metrics`]). A measurement regresses when it is worse than
//! `value` by more than `tolerance` in its bad direction (a 2×
//! slowdown at the default 25% tolerance always fails); a baseline key
//! with no measurement also fails, so the gate cannot rot silently.

use crate::util::jsonmini::Value;

#[derive(Debug, Clone)]
pub struct BaselineEntry {
    pub key: String,
    pub value: f64,
    pub higher_is_better: bool,
}

#[derive(Debug, Clone)]
pub struct Baseline {
    /// Allowed relative slack before a delta counts as a regression.
    pub tolerance: f64,
    pub entries: Vec<BaselineEntry>,
}

/// Parse `benchmarks/baseline.json`. Unknown top-level keys (e.g. a
/// `comment`) are ignored; malformed entries error.
pub fn parse_baseline(text: &str) -> anyhow::Result<Baseline> {
    let v = Value::parse(text).map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
    let tolerance = match v.get("tolerance") {
        None => 0.25,
        Some(t) => {
            let t = t
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("baseline: 'tolerance' must be a number"))?;
            if !(0.0..1.0).contains(&t) {
                anyhow::bail!("baseline: 'tolerance' must be in [0, 1), got {t}");
            }
            t
        }
    };
    let rows = v
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("baseline: missing 'entries' array"))?;
    let mut entries = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let key = row
            .get("key")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: missing 'key'"))?;
        let value = row
            .get("value")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: missing 'value'"))?;
        if !(value.is_finite() && value > 0.0) {
            anyhow::bail!("baseline entry {i} ('{key}'): value must be positive");
        }
        let higher_is_better = match row.get("higher_is_better") {
            None => default_higher_is_better(key),
            Some(b) => b.as_bool().ok_or_else(|| {
                anyhow::anyhow!("baseline entry {i}: 'higher_is_better' must be a bool")
            })?,
        };
        entries.push(BaselineEntry { key: key.to_string(), value, higher_is_better });
    }
    Ok(Baseline { tolerance, entries })
}

/// Direction heuristic for keys without an explicit flag: latencies and
/// wall clocks shrink, everything else (rates, speedups) grows.
pub fn default_higher_is_better(key: &str) -> bool {
    !(key.ends_with("/mean_ns") || key.ends_with("/wall_s"))
}

/// Flatten `BENCH_hotpath.json` (the `util::bench` result array) into
/// `hotpath/<name>/mean_ns` measurements.
pub fn hotpath_metrics(text: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let v = Value::parse(text).map_err(|e| anyhow::anyhow!("BENCH_hotpath: {e}"))?;
    let rows = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("BENCH_hotpath: expected a JSON array"))?;
    let mut out = Vec::with_capacity(rows.len());
    for row in rows {
        let (Some(name), Some(mean)) = (
            row.get("name").and_then(|n| n.as_str()),
            row.get("mean_ns").and_then(|m| m.as_f64()),
        ) else {
            continue;
        };
        out.push((format!("hotpath/{name}/mean_ns"), mean));
    }
    Ok(out)
}

/// Flatten `BENCH_scale.json` (the population-scaling bench) into
/// `scale/...` measurements: per-population events/s for both scan
/// modes, the active-vs-dense speedup (machine-independent), and the
/// sweep-harness wall clocks.
pub fn scale_metrics(text: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let v = Value::parse(text).map_err(|e| anyhow::anyhow!("BENCH_scale: {e}"))?;
    let rows = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("BENCH_scale: expected a JSON array"))?;
    let mut out = Vec::with_capacity(rows.len());
    for row in rows {
        let Some(name) = row.get("name").and_then(|n| n.as_str()) else {
            continue;
        };
        match name {
            "sls_scale" => {
                if let (Some(n_ues), Some(mode), Some(eps)) = (
                    row.get("n_ues").and_then(|x| x.as_f64()),
                    row.get("mode").and_then(|x| x.as_str()),
                    row.get("events_per_sec").and_then(|x| x.as_f64()),
                ) {
                    out.push((
                        format!("scale/sls_scale/{}/{mode}/events_per_sec", n_ues as u64),
                        eps,
                    ));
                }
            }
            "speedup_vs_dense" => {
                if let (Some(n_ues), Some(s)) = (
                    row.get("n_ues").and_then(|x| x.as_f64()),
                    row.get("speedup").and_then(|x| x.as_f64()),
                ) {
                    out.push((format!("scale/speedup_vs_dense/{}", n_ues as u64), s));
                }
            }
            "coupled_radio" | "multi_model" => {
                if let (Some(n_ues), Some(eps)) = (
                    row.get("n_ues").and_then(|x| x.as_f64()),
                    row.get("events_per_sec").and_then(|x| x.as_f64()),
                ) {
                    out.push((
                        format!("scale/{name}/{}/events_per_sec", n_ues as u64),
                        eps,
                    ));
                }
            }
            "pdes" => {
                if let (Some(cells), Some(sync), Some(eps)) = (
                    row.get("cells").and_then(|x| x.as_f64()),
                    row.get("sync").and_then(|x| x.as_str()),
                    row.get("events_per_sec").and_then(|x| x.as_f64()),
                ) {
                    out.push((
                        format!("scale/pdes/{}/{sync}/events_per_sec", cells as u64),
                        eps,
                    ));
                }
            }
            // Hybrid-fidelity rows: the 128-cell row carries the
            // equivalent-dense throughput (dense event count over the
            // hybrid wall) and the machine-independent wall ratio; the
            // 256-cell row only the raw hybrid events/s.
            "fluid" => {
                if let Some(cells) = row.get("cells").and_then(|x| x.as_f64()) {
                    if let Some(eq) =
                        row.get("equiv_events_per_sec").and_then(|x| x.as_f64())
                    {
                        out.push((
                            format!("scale/fluid/{}/equiv_events_per_sec", cells as u64),
                            eq,
                        ));
                    }
                    if let Some(s) = row.get("speedup_vs_dense").and_then(|x| x.as_f64()) {
                        out.push((
                            format!("scale/fluid/{}/speedup_vs_dense", cells as u64),
                            s,
                        ));
                    }
                    if let Some(eps) = row.get("events_per_sec").and_then(|x| x.as_f64()) {
                        out.push((
                            format!("scale/fluid/{}/events_per_sec", cells as u64),
                            eps,
                        ));
                    }
                }
            }
            // The warm-start row gates the cold/warm wall-clock ratio
            // (machine-independent), not an absolute wall time.
            "sweep_warm" => {
                if let Some(s) = row.get("speedup").and_then(|x| x.as_f64()) {
                    out.push(("scale/sweep_warm/speedup".to_string(), s));
                }
            }
            sweep if sweep.starts_with("sweep_") => {
                if let Some(w) = row.get("wall_s").and_then(|x| x.as_f64()) {
                    out.push((format!("scale/{sweep}/wall_s"), w));
                }
            }
            _ => {}
        }
    }
    Ok(out)
}

/// One gate comparison.
#[derive(Debug, Clone)]
pub struct Delta {
    pub key: String,
    pub baseline: f64,
    /// `None` when the bench output no longer produces this key.
    pub current: Option<f64>,
    /// current / baseline (1.0 when missing).
    pub ratio: f64,
    pub regressed: bool,
}

/// Compare measurements against the baseline. Every baseline entry is
/// checked; measurements without a baseline entry are informational
/// only (they appear in the table via [`render_markdown`]'s extras).
pub fn diff(baseline: &Baseline, measured: &[(String, f64)]) -> Vec<Delta> {
    baseline
        .entries
        .iter()
        .map(|e| {
            let current = measured
                .iter()
                .find(|(k, _)| *k == e.key)
                .map(|(_, v)| *v);
            match current {
                None => Delta {
                    key: e.key.clone(),
                    baseline: e.value,
                    current: None,
                    ratio: 1.0,
                    regressed: true,
                },
                Some(v) => {
                    let ratio = v / e.value;
                    let regressed = if e.higher_is_better {
                        v < e.value * (1.0 - baseline.tolerance)
                    } else {
                        v > e.value * (1.0 + baseline.tolerance)
                    };
                    Delta {
                        key: e.key.clone(),
                        baseline: e.value,
                        current: Some(v),
                        ratio,
                        regressed,
                    }
                }
            }
        })
        .collect()
}

fn fmt_val(v: f64) -> String {
    if v >= 10_000.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.3}")
    }
}

/// Render the delta table as GitHub-flavored markdown (the CI job tees
/// it into `$GITHUB_STEP_SUMMARY`). `extras` lists measured keys with
/// no baseline entry, shown for trajectory context.
pub fn render_markdown(
    deltas: &[Delta],
    extras: &[(String, f64)],
    tolerance: f64,
) -> String {
    let mut out = String::new();
    out.push_str("### Benchmark-regression gate\n\n");
    out.push_str(&format!(
        "Tolerance: ±{:.0}% vs `benchmarks/baseline.json`\n\n",
        tolerance * 100.0
    ));
    out.push_str("| metric | baseline | current | ratio | status |\n");
    out.push_str("|---|---:|---:|---:|---|\n");
    for d in deltas {
        let (cur, ratio) = match d.current {
            Some(v) => (fmt_val(v), format!("{:.2}x", d.ratio)),
            None => ("missing".to_string(), "—".to_string()),
        };
        let status = if d.regressed { "**REGRESSED**" } else { "ok" };
        out.push_str(&format!(
            "| `{}` | {} | {} | {} | {} |\n",
            d.key,
            fmt_val(d.baseline),
            cur,
            ratio,
            status
        ));
    }
    for (k, v) in extras {
        out.push_str(&format!("| `{k}` | — | {} | — | untracked |\n", fmt_val(v)));
    }
    let n_bad = deltas.iter().filter(|d| d.regressed).count();
    if n_bad > 0 {
        out.push_str(&format!("\n{n_bad} metric(s) regressed beyond tolerance.\n"));
    } else {
        out.push_str("\nAll tracked metrics within tolerance.\n");
    }
    out
}

/// JSON string escaping for measurement keys — bench names are
/// free-form, and an unescaped quote would brick the written baseline.
fn jkey(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' | '\r' | '\t' => out.push(' '),
            c => out.push(c),
        }
    }
    out
}

/// Serialize a refreshed baseline from the current measurements (the
/// `bench-diff --update` path). Directions come from
/// [`default_higher_is_better`].
pub fn baseline_json(measured: &[(String, f64)], tolerance: f64) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"tolerance\": {tolerance},\n"));
    out.push_str("  \"entries\": [");
    for (i, (k, v)) in measured.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"key\": \"{}\", \"value\": {v}, \"higher_is_better\": {}}}",
            jkey(k),
            default_higher_is_better(k)
        ));
    }
    if !measured.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = r#"{
      "tolerance": 0.25,
      "comment": "ignored free-form field",
      "entries": [
        {"key": "scale/sls_scale/1000/active_set/events_per_sec", "value": 1000000.0, "higher_is_better": true},
        {"key": "hotpath/sls: 5s simulated/mean_ns", "value": 200000.0, "higher_is_better": false}
      ]
    }"#;

    #[test]
    fn baseline_parses_with_comment_and_defaults() {
        let b = parse_baseline(BASE).unwrap();
        assert_eq!(b.tolerance, 0.25);
        assert_eq!(b.entries.len(), 2);
        assert!(b.entries[0].higher_is_better);
        assert!(!b.entries[1].higher_is_better);
        // direction defaults derive from the key suffix
        let b2 = parse_baseline(
            "{\"entries\": [{\"key\": \"a/wall_s\", \"value\": 1.0}, {\"key\": \"b/events_per_sec\", \"value\": 2.0}]}",
        )
        .unwrap();
        assert!(!b2.entries[0].higher_is_better);
        assert!(b2.entries[1].higher_is_better);
    }

    #[test]
    fn baseline_rejects_malformed_inputs() {
        for bad in [
            "not json",
            "{\"entries\": 3}",
            "{\"entries\": [{\"value\": 1.0}]}",
            "{\"entries\": [{\"key\": \"k\"}]}",
            "{\"entries\": [{\"key\": \"k\", \"value\": -1.0}]}",
            "{\"tolerance\": 2.0, \"entries\": []}",
        ] {
            assert!(parse_baseline(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn injected_2x_slowdown_fails_the_gate() {
        let b = parse_baseline(BASE).unwrap();
        // events/s halved AND latency doubled — both must trip
        let measured = vec![
            ("scale/sls_scale/1000/active_set/events_per_sec".to_string(), 500_000.0),
            ("hotpath/sls: 5s simulated/mean_ns".to_string(), 400_000.0),
        ];
        let deltas = diff(&b, &measured);
        assert!(deltas.iter().all(|d| d.regressed), "{deltas:?}");
        let md = render_markdown(&deltas, &[], b.tolerance);
        assert!(md.contains("REGRESSED"), "{md}");
    }

    #[test]
    fn deltas_within_tolerance_pass() {
        let b = parse_baseline(BASE).unwrap();
        // 10% slower events/s, 20% slower latency: inside ±25%
        let measured = vec![
            ("scale/sls_scale/1000/active_set/events_per_sec".to_string(), 900_000.0),
            ("hotpath/sls: 5s simulated/mean_ns".to_string(), 240_000.0),
        ];
        let deltas = diff(&b, &measured);
        assert!(deltas.iter().all(|d| !d.regressed), "{deltas:?}");
        // improvements never regress
        let measured = vec![
            ("scale/sls_scale/1000/active_set/events_per_sec".to_string(), 5_000_000.0),
            ("hotpath/sls: 5s simulated/mean_ns".to_string(), 10_000.0),
        ];
        assert!(diff(&b, &measured).iter().all(|d| !d.regressed));
    }

    #[test]
    fn missing_measurement_is_a_failure() {
        let b = parse_baseline(BASE).unwrap();
        let deltas = diff(&b, &[]);
        assert!(deltas.iter().all(|d| d.regressed && d.current.is_none()));
    }

    #[test]
    fn bench_jsons_flatten_to_gate_keys() {
        let hot = "[\n  {\"name\": \"dess: 10k schedule+pop\", \"iters\": 5, \"mean_ns\": 100.0, \"std_ns\": 1.0, \"min_ns\": 1.0, \"p50_ns\": 1.0, \"p95_ns\": 1.0}\n]";
        let m = hotpath_metrics(hot).unwrap();
        assert_eq!(m, vec![("hotpath/dess: 10k schedule+pop/mean_ns".to_string(), 100.0)]);

        let scale = "[\n  {\"name\": \"sls_scale\", \"n_ues\": 1000, \"mode\": \"active_set\", \"events\": 5, \"jobs\": 2, \"wall_s\": 0.1, \"events_per_sec\": 50.0},\n  {\"name\": \"speedup_vs_dense\", \"n_ues\": 1000, \"speedup\": 3.5},\n  {\"name\": \"coupled_radio\", \"n_ues\": 1000, \"events\": 9, \"jobs\": 4, \"wall_s\": 0.2, \"events_per_sec\": 45.0},\n  {\"name\": \"multi_model\", \"n_ues\": 600, \"events\": 8, \"jobs\": 4, \"wall_s\": 0.2, \"events_per_sec\": 40.0},\n  {\"name\": \"pdes\", \"cells\": 16, \"sync\": \"frontier\", \"events\": 7, \"jobs\": 3, \"wall_s\": 0.3, \"events_per_sec\": 33.0},\n  {\"name\": \"sweep_parallel\", \"points\": 4, \"seeds\": 3, \"wall_s\": 1.25},\n  {\"name\": \"fluid\", \"cells\": 128, \"events\": 7, \"jobs\": 3, \"wall_s\": 0.1, \"events_per_sec\": 70.0, \"dense_events\": 21, \"dense_wall_s\": 0.4, \"equiv_events_per_sec\": 210.0, \"speedup_vs_dense\": 4.0},\n  {\"name\": \"fluid\", \"cells\": 256, \"events\": 6, \"jobs\": 2, \"wall_s\": 0.2, \"events_per_sec\": 30.0}\n]";
        let m = scale_metrics(scale).unwrap();
        assert_eq!(m.len(), 10);
        assert_eq!(m[0].0, "scale/sls_scale/1000/active_set/events_per_sec");
        assert_eq!(m[1], ("scale/speedup_vs_dense/1000".to_string(), 3.5));
        assert_eq!(
            m[2],
            ("scale/coupled_radio/1000/events_per_sec".to_string(), 45.0)
        );
        assert_eq!(
            m[3],
            ("scale/multi_model/600/events_per_sec".to_string(), 40.0)
        );
        assert_eq!(m[4], ("scale/pdes/16/frontier/events_per_sec".to_string(), 33.0));
        assert_eq!(m[5], ("scale/sweep_parallel/wall_s".to_string(), 1.25));
        assert_eq!(
            m[6],
            ("scale/fluid/128/equiv_events_per_sec".to_string(), 210.0)
        );
        assert_eq!(m[7], ("scale/fluid/128/speedup_vs_dense".to_string(), 4.0));
        assert_eq!(m[8], ("scale/fluid/128/events_per_sec".to_string(), 70.0));
        assert_eq!(m[9], ("scale/fluid/256/events_per_sec".to_string(), 30.0));
    }

    #[test]
    fn update_round_trips_through_the_parser() {
        let measured = vec![
            ("scale/sls_scale/100/active_set/events_per_sec".to_string(), 1.5e6),
            ("hotpath/mac: one 60-UE slot/mean_ns".to_string(), 2.5e4),
            // quoted/backslashed bench names must survive the writer
            ("hotpath/sls \"fast\" \\ path/mean_ns".to_string(), 3.0e4),
        ];
        let text = baseline_json(&measured, 0.25);
        let b = parse_baseline(&text).unwrap();
        assert_eq!(b.entries.len(), 3);
        assert_eq!(b.entries[0].value, 1.5e6);
        assert!(b.entries[0].higher_is_better);
        assert!(!b.entries[1].higher_is_better);
        // the escaped key parses back to the original name
        assert_eq!(b.entries[2].key, measured[2].0);
        // a fresh measurement set against its own update always passes
        assert!(diff(&b, &measured).iter().all(|d| !d.regressed));
    }
}
